"""Full paper pipeline demo (Fig. 7 end to end) on two benchmarks:

features -> DFA pattern classifier -> pattern-based model table (pretrained on
a corpus like Section V-A) -> dual-Transformer predictor with the thrashing-
aware incremental loss -> policy engine -> simulator GMMU ops — printed as a
Table-VI-style strategy comparison + Fig.-13-style overhead sensitivity.

    PYTHONPATH=src python examples/uvm_oversubscription_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime, simulator, timing, trace
from repro.uvm.uvmsmart import run_uvmsmart

TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)


def main():
    # Section V-A: pretrain the per-pattern models on a different-input corpus
    corpus = [trace.BENCHMARKS[n](scale=0.25, seed=42 + i) for i, n in enumerate(["ATAX", "Backprop", "BICG", "Hotspot", "NW"])]
    print("pretraining pattern-model table on 5-benchmark corpus...")
    table = runtime.pretrain_table(corpus, SMOKE, TCFG, max_rounds=2)
    print(f"  {table.n_models} pattern models, footprint {table.footprint_bytes()/2**20:.2f} MB")

    hdr = f"{'benchmark':12s} {'baseline':>9s} {'TreeHPE':>9s} {'UVMSmart':>9s} {'ours':>9s} {'D+Belady':>9s}  top1"
    print("\npages thrashed @125% oversubscription\n" + hdr)
    for name in ("Hotspot", "NW"):
        tr = trace.get_trace(name, scale=0.3).slice(0, 6000)
        base = simulator.run(tr, policy="lru", prefetch="tree").pages_thrashed
        thpe = simulator.run(tr, policy="hpe", prefetch="tree").pages_thrashed
        bel = simulator.run(tr, policy="belady", prefetch="demand").pages_thrashed
        smart = run_uvmsmart(tr)["pages_thrashed"]
        ours = runtime.run_ours(tr, SMOKE, TCFG, table=table)
        print(f"{name:12s} {base:9d} {thpe:9d} {smart:9d} {ours.stats['pages_thrashed']:9d} {bel:9d}  {ours.top1:.3f}")

        ipcs = [ours.ipc(u, len(tr)) / timing.ipc(simulator.run(tr, policy='lru', prefetch='tree').stats, len(tr)) for u in (1, 10, 50, 100)]
        print(f"{'':12s} normalized IPC vs baseline @ 1/10/50/100us overhead: "
              + " / ".join(f"{x:.2f}" for x in ipcs))


if __name__ == "__main__":
    main()
