"""Serve a small model with batched requests + the paper's technique as a
learned HBM<->host KV-page offload manager (DESIGN.md §2): the prediction
frequency table + page-set chain decide which KV pages stay in HBM while the
cache oversubscribes it.

    PYTHONPATH=src python examples/serve_paged_kv.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Engine


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    total = 96
    params = lm.init(jax.random.key(0), cfg, max_seq=total)
    prompts = jax.random.randint(jax.random.key(1), (4, 70), 0, cfg.vocab_size, jnp.int32)
    print(f"serving {cfg.name}: batch=4, prompt=70, new=24, HBM holds 50% of KV pages")

    for kind in ("lru", "learned"):
        eng = Engine(cfg, params, offload=kind, hbm_fraction=0.5)
        res = eng.generate({"tokens": prompts}, n_new=24, pad_to=total)
        s = res.offload_stats
        hit = s["hbm_hits"] / max(s["hbm_hits"] + s["hbm_misses"], 1)
        print(f"  {kind:8s} residency: hit-rate={hit:.3f} misses={s['hbm_misses']} "
              f"prefetches={s['prefetches']} thrash={s['thrash']}")
    print("sample output tokens:", res.tokens[0, :10].tolist())

    # the mechanism at scale: a long-context decode whose attention mass is
    # skewed (as real prompts are) — 256 KV pages, HBM holds 64
    import numpy as np

    from repro.serving.offload import KVOffloadManager, LRUOffloadManager

    print("\nlong-context simulation: 256 KV pages, HBM capacity 64, Zipf attention")
    rng = np.random.default_rng(0)
    hot = rng.permutation(256)[:48]  # the pages the prompt actually attends to
    for name, mk in (("lru", LRUOffloadManager), ("learned", KVOffloadManager)):
        mgr = mk(256, 64, prefetch_per_step=8)
        for t in range(512):
            mass = np.full(256, 0.01)
            mass[hot] = 1.0
            touched = np.concatenate([hot, rng.integers(0, 256, 6)])
            mgr.on_attention(mass, touched)
        s = mgr.stats
        print(f"  {name:8s} hit-rate={s.hit_rate:.3f} misses={s.hbm_misses} thrash={s.thrash}")


if __name__ == "__main__":
    main()
