"""End-to-end training driver: train an assigned-architecture LM on the
deterministic synthetic pipeline with checkpoint/restore.

Default is a CPU-friendly tiny run; `--hundred-m` trains a ~100M-parameter
qwen2-family config for a few hundred steps (the deliverable-scale run —
expect it to take a while on 1 CPU core; on a real pod the same entry point
lowers through the production mesh via repro.launch.train).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()

    if args.hundred_m:
        # ~100M params: qwen2 family at 12 layers / d=512 (see configs/)
        import repro.configs.qwen2_0_5b as q

        cfg = q.CONFIG.replace(name="qwen2-100m", num_layers=12, d_model=512, num_heads=8, num_kv_heads=2, head_dim=64, d_ff=2048)
        import repro.configs as C

        C._MODULES["qwen2-100m"] = "repro.configs.qwen2_0_5b"  # registry alias
        q.CONFIG = cfg  # the alias resolves to this config

        from repro.models import lm

        print(f"training {cfg.name}: {lm.param_count(cfg, 512)/1e6:.0f}M params")
        argv = ["--arch", "qwen2-100m", "--steps", str(args.steps or 300), "--batch", "4",
                "--seq", "512", "--accum", "2", "--ckpt-every", "50", "--out", "/tmp/repro_100m", "--resume"]
    else:
        argv = ["--arch", args.arch, "--smoke", "--steps", str(args.steps or 30), "--batch", "8",
                "--seq", "128", "--ckpt-every", "10", "--out", "/tmp/repro_tiny", "--resume"]
    return train_mod.main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
