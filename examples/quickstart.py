"""Quickstart: the paper's pipeline in ~30 lines.

Train the pattern-aware, thrashing-aware page predictor online on one GPGPU
trace and compare pages-thrashed against the CUDA-driver baseline
(tree prefetcher + LRU) under 125% memory oversubscription.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime, simulator, trace


def main():
    tr = trace.get_trace("Hotspot", scale=0.3).slice(0, 5000)
    print(f"benchmark=Hotspot accesses={len(tr)} working_set={tr.n_pages} pages")

    baseline = simulator.run(tr, policy="lru", prefetch="tree", oversubscription=1.25)
    print(f"baseline (tree prefetch + LRU):   {baseline.pages_thrashed:6d} pages thrashed")

    ours = runtime.run_ours(tr, SMOKE, TrainConfig(group_size=1024, epochs=2, batch_size=128))
    red = 1 - ours.stats["pages_thrashed"] / max(baseline.pages_thrashed, 1)
    print(f"ours (learned prefetch + evict):  {ours.stats['pages_thrashed']:6d} pages thrashed "
          f"({red:.0%} reduction; paper: 64.4% avg)")
    print(f"predictor online top-1: {ours.top1:.3f} over {ours.n_predictions} predictions, "
          f"{ours.n_models} pattern model(s), {ours.n_classes} delta classes")


if __name__ == "__main__":
    main()
