"""UVM simulator invariants (hypothesis property tests over random traces
live in test_properties.py, guarded on hypothesis being installed)."""
import numpy as np

from repro.uvm import simulator as S
from repro.uvm import trace as T


def _trace_from_blocks(blocks, n_blocks):
    blocks = np.asarray(blocks, np.int32)
    pages = blocks * T.PAGES_PER_BLOCK
    n = len(pages)
    return T.Trace("h", pages, np.zeros(n, np.int32), np.zeros(n, np.int32), np.zeros(n, np.int32), n_blocks * T.PAGES_PER_BLOCK)


def test_no_oversubscription_no_thrash():
    """At 100% (memory == working set) nothing is ever evicted."""
    tr = T.get_trace("Hotspot", scale=0.2)
    res = S.run(tr, policy="lru", prefetch="tree", oversubscription=1.0)
    assert res.pages_thrashed == 0
    assert res.stats["faults"] > 0


def test_streaming_never_thrashes_at_125():
    """Streaming workloads stay ~thrash-free under the baseline (paper: 0;
    we allow <=2 blocks of prefetcher-lookahead alignment noise — cf. the
    paper's own UVMSmart at 416 pages on AddVectors)."""
    for name in ("StreamTriad", "AddVectors", "Pathfinder"):
        res = S.run(T.get_trace(name, scale=0.4), policy="lru", prefetch="tree")
        assert res.pages_thrashed <= 2 * T.PAGES_PER_BLOCK, name
        full = S.run(T.get_trace(name, scale=1.0), policy="lru", prefetch="tree")
        assert full.pages_thrashed == 0, name


def test_published_orderings_hold():
    """Directional reproduction of Tables I/II on reduced traces."""
    scales = {"BICG": 1.0}  # BICG's transposed-walk pressure needs full scale
    for name in ("ATAX", "BICG", "NW", "Hotspot"):
        tr = T.get_trace(name, scale=scales.get(name, 0.5))
        base = S.run(tr, policy="lru", prefetch="tree").pages_thrashed
        hpe = S.run(tr, policy="hpe", prefetch="demand").pages_thrashed
        bel = S.run(tr, policy="belady", prefetch="demand").pages_thrashed
        assert bel <= hpe <= base, (name, base, hpe, bel)
        assert base > 0, name
    # Table II: HPE collapses when paired with the tree prefetcher
    tr = T.get_trace("StreamTriad", scale=0.4)
    tree_hpe = S.run(tr, policy="hpe", prefetch="tree").pages_thrashed
    demand_hpe = S.run(tr, policy="hpe", prefetch="demand").pages_thrashed
    assert tree_hpe > 10 * max(demand_hpe, 1)


def test_thrash_counts_remigrations():
    """A block evicted then migrated again is exactly one thrash event."""
    # capacity 2 blocks, access pattern 0,1,2,0 -> 0 evicted by 2, refetch = thrash
    tr = _trace_from_blocks([0, 1, 2, 0], 4)
    res = S.run(tr, policy="lru", prefetch="demand", oversubscription=2.0)  # cap=2
    assert res.state.thrash_events == 1
    assert res.pages_thrashed == T.PAGES_PER_BLOCK


def test_pinned_blocks_zero_copy():
    import jax.numpy as jnp

    tr = _trace_from_blocks([0, 1, 0, 1, 0], 4)
    state = S.init_state(S.pad_blocks(tr.n_blocks))
    state = state._replace(pinned=state.pinned.at[0].set(True))
    nxt = S.precompute_next_use(tr.block.astype(np.int32), S.pad_blocks(tr.n_blocks))
    state, _ = S._run_segment(
        state, jnp.asarray(tr.block.astype(np.int32)), jnp.asarray(nxt),
        n_blocks=S.pad_blocks(tr.n_blocks), capacity=2, policy="lru", prefetch="demand", n_valid=tr.n_blocks,
    )
    assert int(state.zero_copy) == 3  # three accesses to the pinned block
    assert not bool(state.resident[0])  # pinned blocks never migrate


def test_trace_generators_wellformed():
    for name, fn in T.BENCHMARKS.items():
        tr = fn(scale=0.3)
        assert len(tr) > 50, name
        assert tr.page.min() >= 0 and tr.page.max() < tr.n_pages, name
        assert len(tr.pc) == len(tr.page) == len(tr.tb) == len(tr.kernel), name


def test_table_iii_delta_growth():
    """NW / Srad grow their delta vocabulary across phases; streaming stays flat."""
    from repro.core.features import unique_deltas_per_phase

    nw = unique_deltas_per_phase(T.get_trace("NW", scale=0.6))
    assert nw[-1] > 1.5 * nw[0]
    srad = unique_deltas_per_phase(T.get_trace("Srad-v2", scale=0.6))
    assert srad[-1] > srad[0]
    stream = unique_deltas_per_phase(T.get_trace("StreamTriad", scale=0.6))
    assert stream[-1] <= stream[0] + 2


def test_resume_state_roundtrip():
    """run() returns `key` as raw key_data; feeding that state back in (the
    documented resume path) must re-wrap it — and a segmented run must match
    the single-shot run exactly for time-consistent policies."""
    tr = T.get_trace("Hotspot", scale=0.2)
    half = len(tr) // 2
    for policy in ("lru", "random"):
        full = S.run(tr, policy=policy, prefetch="tree", oversubscription=1.25, seed=3)
        first = S.run(tr.slice(0, half), policy=policy, prefetch="tree", oversubscription=1.25, seed=3)
        assert isinstance(first.state.key, np.ndarray)  # raw key_data round-trips
        resumed = S.run(tr.slice(half, len(tr)), policy=policy, prefetch="tree",
                        oversubscription=1.25, state=first.state)
        assert resumed.stats == full.stats, policy
        np.testing.assert_array_equal(resumed.state.resident, full.state.resident)
        assert int(resumed.state.time) == len(tr)


def test_concurrent_disjoint_page_ranges():
    """Section V-F: each tenant lives in its own page range; the merged
    trace must preserve every access, remap each workload into a disjoint
    window, and keep per-workload temporal order."""
    a = T.get_trace("StreamTriad", scale=0.3)
    b = T.get_trace("Hotspot", scale=0.3)
    tr = T.concurrent([a, b], seed=5)
    assert len(tr) == len(a) + len(b)
    assert tr.n_pages == a.n_pages + b.n_pages
    # tenant of each access is identified by the kernel-id offset (64 * w)
    w = tr.kernel // 64
    pages_a, pages_b = tr.page[w == 0], tr.page[w == 1]
    assert pages_a.max() < a.n_pages  # tenant 0 window: [0, a.n_pages)
    assert pages_b.min() >= a.n_pages and pages_b.max() < tr.n_pages
    np.testing.assert_array_equal(pages_a, a.page)  # temporal order kept
    np.testing.assert_array_equal(pages_b, b.page + a.n_pages)


def test_concurrent_deterministic_under_seed():
    parts = [T.get_trace("ATAX", scale=0.3), T.get_trace("Srad-v2", scale=0.3)]
    t1 = T.concurrent(parts, seed=7)
    t2 = T.concurrent(parts, seed=7)
    t3 = T.concurrent(parts, seed=8)
    np.testing.assert_array_equal(t1.page, t2.page)
    np.testing.assert_array_equal(t1.kernel, t2.kernel)
    assert not np.array_equal(t1.page, t3.page)  # the merge order is seeded


def test_periodic_compression_exact_on_streaming():
    """Period-p compression must shorten streaming scans (the _interleave
    idiom defeats plain RLE) while keeping counters bit-identical to the
    per-access reference."""
    from repro.uvm import reference as REF

    tr = T.get_trace("AddVectors", scale=0.25)
    b = tr.block.astype(np.int32)
    nxt = S.next_use_for(tr)
    rle = S.compress_events(b, nxt)
    per = S.compress_events(b, nxt, periodic=True)
    assert len(per.blk) * 3 <= len(rle.blk)  # >=3x shorter scan
    assert per.rl.sum() == len(b)  # every access is covered exactly once
    for pol in ("lru", "belady", "hpe", "learned"):
        fast = S.run(tr, policy=pol, prefetch="tree")
        ref = REF.run(tr, policy=pol, prefetch="tree")
        assert fast.stats == ref.stats, pol
        np.testing.assert_array_equal(fast.was_evicted, ref.was_evicted)


def test_periodic_divergence_falls_back_exactly():
    """A tiny capacity forces evictions inside periodic windows; the
    runtime divergence check must detect it and rerun on plain RLE events,
    so the counters still match the reference bit-for-bit."""
    from repro.uvm import reference as REF

    blocks = np.concatenate([np.tile([0, 5, 9], 8), [1, 2, 3], np.tile([2, 7], 6)])
    tr = _trace_from_blocks(blocks, 12)
    ev = S.compress_events(tr.block.astype(np.int32), S.next_use_for(tr), periodic=True)
    assert (ev.stride > 1).any()  # periodic windows were detected
    for pol in ("lru", "belady", "hpe", "learned"):
        for oversub in (1.25, 6.0):
            fast = S.run(tr, policy=pol, prefetch="tree", oversubscription=oversub)
            ref = REF.run(tr, policy=pol, prefetch="tree", oversubscription=oversub)
            assert fast.stats == ref.stats, (pol, oversub)


def _assert_segments_many_matches_runs(traces, lane_cells):
    states = [S.init_state(S.bucket_blocks(tr.n_blocks)) for tr in traces]
    cells = [
        (S.POLICY_IDS[pol], S.PREFETCH_IDS[pf], S.capacity_for(tr.n_blocks, os_))
        for tr, (pol, pf, os_) in zip(traces, lane_cells)
    ]
    segs = [(tr.block.astype(np.int32), S.next_use_for(tr)) for tr in traces]
    out = S.run_segments_many(states, segs, cells, [tr.n_blocks for tr in traces])
    for tr, (pol, pf, os_), (state, outs) in zip(traces, lane_cells, out):
        want = S.run(tr, policy=pol, prefetch=pf, oversubscription=os_)
        assert int(state.thrash_events) == int(want.state.thrash_events), (tr.name, pol)
        assert int(state.faults) == int(want.state.faults), (tr.name, pol)
        np.testing.assert_array_equal(outs["fault"], want.fault, err_msg=f"{tr.name}|{pol}")
        np.testing.assert_array_equal(outs["was_evicted"], want.was_evicted, err_msg=f"{tr.name}|{pol}")


def test_run_segments_many_matches_single_runs():
    """The cross-trace lane-batched scan (different event streams per lane)
    must equal per-trace run() for every lane — here with lanes landing in
    DIFFERENT shape buckets, which routes through the single-lane path."""
    traces = [
        T.get_trace("ATAX", scale=0.25).slice(0, 1500),
        T.get_trace("StreamTriad", scale=0.25),
        T.get_trace("Hotspot", scale=0.25).slice(0, 1500),
    ]
    _assert_segments_many_matches_runs(traces, [("lru", "tree", 1.25)] * len(traces))


def test_run_segments_many_vmapped_bucket_matches_single_runs():
    """Five same-bucket lanes (same state width, same event bucket) with
    per-lane policies/capacities: exercises the grouped vmapped scan with
    inert lane padding (5 -> 8), not the small-group serial fallback."""
    rng = np.random.default_rng(3)
    traces = [
        _trace_from_blocks(np.concatenate([np.tile(rng.integers(0, 24, p), 12), rng.integers(0, 24, 40)]), 24)
        for p in (2, 3, 4, 5, 6)  # periodic heads so stride>1 events batch too
    ]
    lane_cells = [
        ("lru", "tree", 1.25), ("belady", "demand", 1.5), ("hpe", "tree", 2.0),
        ("learned", "demand", 1.25), ("lru", "demand", 4.0),
    ]
    _assert_segments_many_matches_runs(traces, lane_cells)


def test_precompute_next_use_matches_scalar_loop():
    rng = np.random.default_rng(0)
    blocks = rng.integers(0, 37, 500).astype(np.int32)
    got = S.precompute_next_use(blocks, 37)
    # scalar reference
    ref = np.full(len(blocks), S.NO_USE, np.int64)
    last = np.full(37, S.NO_USE, np.int64)
    for t in range(len(blocks) - 1, -1, -1):
        ref[t] = last[blocks[t]]
        last[blocks[t]] = t
    np.testing.assert_array_equal(got, np.minimum(ref, S.NO_USE).astype(np.int32))
    assert S.precompute_next_use(np.zeros(0, np.int32), 4).shape == (0,)


def test_compress_events_roundtrip():
    blocks = np.array([3, 3, 3, 1, 1, 2, 3, 3], np.int32)
    nxt = S.precompute_next_use(blocks, 4)
    ev = S.compress_events(blocks, nxt)
    np.testing.assert_array_equal(ev.blk, [3, 1, 2, 3])
    np.testing.assert_array_equal(ev.dt, [0, 3, 5, 6])
    np.testing.assert_array_equal(ev.rl, [3, 2, 1, 2])
    # the event carries the LAST access's next-use (the value that must
    # persist in state), and run lengths cover the stream exactly
    np.testing.assert_array_equal(ev.nxt, nxt[ev.dt + ev.rl - 1])
    assert ev.rl.sum() == len(blocks) == ev.n_access


def test_run_batch_matches_single_runs():
    tr = T.get_trace("ATAX", scale=0.3)
    cells = [(p, f, o) for p in ("lru", "belady", "hpe") for f in ("demand", "tree") for o in (1.25, 1.5)]
    batch = S.run_batch(tr, cells)
    for (p, f, o), got in zip(cells, batch):
        want = S.run(tr, policy=p, prefetch=f, oversubscription=o).stats
        assert got == want, (p, f, o)
