"""UVM simulator invariants — including hypothesis property tests over random
traces."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.uvm import simulator as S
from repro.uvm import trace as T


def _trace_from_blocks(blocks, n_blocks):
    blocks = np.asarray(blocks, np.int32)
    pages = blocks * T.PAGES_PER_BLOCK
    n = len(pages)
    return T.Trace("h", pages, np.zeros(n, np.int32), np.zeros(n, np.int32), np.zeros(n, np.int32), n_blocks * T.PAGES_PER_BLOCK)


@settings(max_examples=12, deadline=None)
@given(
    blocks=st.lists(st.integers(0, 31), min_size=20, max_size=120),
    policy=st.sampled_from(["lru", "random", "hpe", "learned"]),
)
def test_invariants_random_traces(blocks, policy):
    tr = _trace_from_blocks(blocks, 32)
    res = S.run(tr, policy=policy, prefetch="demand", oversubscription=1.5)
    st_ = res.state
    cap = S.capacity_for(tr.n_blocks, 1.5)
    assert int(st_.occupancy) <= cap
    assert int(st_.resident.sum()) == int(st_.occupancy)
    # thrash events can't exceed migrations, faults can't exceed accesses
    assert int(st_.thrash_events) <= int(st_.migrations)
    assert int(st_.faults) <= len(tr)
    # every accessed block was resident or pinned at some point => no fault
    # for blocks re-accessed while resident
    assert int(st_.migrations) >= int(st_.faults) * 0  # migrations well-defined


@settings(max_examples=10, deadline=None)
@given(blocks=st.lists(st.integers(0, 23), min_size=40, max_size=160))
def test_belady_minimizes_faults(blocks):
    """Belady's MIN provably minimises misses: with demand migration,
    faults(Belady) <= faults(any other policy)."""
    oversub = 1.6
    tr = _trace_from_blocks(blocks, 24)
    f_bel = S.run(tr, policy="belady", prefetch="demand", oversubscription=oversub).stats["faults"]
    for policy in ("lru", "random", "hpe"):
        f = S.run(tr, policy=policy, prefetch="demand", oversubscription=oversub).stats["faults"]
        assert f_bel <= f, f"belady {f_bel} > {policy} {f}"


def test_no_oversubscription_no_thrash():
    """At 100% (memory == working set) nothing is ever evicted."""
    tr = T.get_trace("Hotspot", scale=0.2)
    res = S.run(tr, policy="lru", prefetch="tree", oversubscription=1.0)
    assert res.pages_thrashed == 0
    assert res.stats["faults"] > 0


def test_streaming_never_thrashes_at_125():
    """Streaming workloads stay ~thrash-free under the baseline (paper: 0;
    we allow <=2 blocks of prefetcher-lookahead alignment noise — cf. the
    paper's own UVMSmart at 416 pages on AddVectors)."""
    for name in ("StreamTriad", "AddVectors", "Pathfinder"):
        res = S.run(T.get_trace(name, scale=0.4), policy="lru", prefetch="tree")
        assert res.pages_thrashed <= 2 * T.PAGES_PER_BLOCK, name
        full = S.run(T.get_trace(name, scale=1.0), policy="lru", prefetch="tree")
        assert full.pages_thrashed == 0, name


def test_published_orderings_hold():
    """Directional reproduction of Tables I/II on reduced traces."""
    scales = {"BICG": 1.0}  # BICG's transposed-walk pressure needs full scale
    for name in ("ATAX", "BICG", "NW", "Hotspot"):
        tr = T.get_trace(name, scale=scales.get(name, 0.5))
        base = S.run(tr, policy="lru", prefetch="tree").pages_thrashed
        hpe = S.run(tr, policy="hpe", prefetch="demand").pages_thrashed
        bel = S.run(tr, policy="belady", prefetch="demand").pages_thrashed
        assert bel <= hpe <= base, (name, base, hpe, bel)
        assert base > 0, name
    # Table II: HPE collapses when paired with the tree prefetcher
    tr = T.get_trace("StreamTriad", scale=0.4)
    tree_hpe = S.run(tr, policy="hpe", prefetch="tree").pages_thrashed
    demand_hpe = S.run(tr, policy="hpe", prefetch="demand").pages_thrashed
    assert tree_hpe > 10 * max(demand_hpe, 1)


def test_thrash_counts_remigrations():
    """A block evicted then migrated again is exactly one thrash event."""
    # capacity 2 blocks, access pattern 0,1,2,0 -> 0 evicted by 2, refetch = thrash
    tr = _trace_from_blocks([0, 1, 2, 0], 4)
    res = S.run(tr, policy="lru", prefetch="demand", oversubscription=2.0)  # cap=2
    assert res.state.thrash_events == 1
    assert res.pages_thrashed == T.PAGES_PER_BLOCK


def test_pinned_blocks_zero_copy():
    import jax.numpy as jnp

    tr = _trace_from_blocks([0, 1, 0, 1, 0], 4)
    state = S.init_state(S.pad_blocks(tr.n_blocks))
    state = state._replace(pinned=state.pinned.at[0].set(True))
    nxt = S.precompute_next_use(tr.block.astype(np.int32), S.pad_blocks(tr.n_blocks))
    state, _ = S._run_segment(
        state, jnp.asarray(tr.block.astype(np.int32)), jnp.asarray(nxt),
        n_blocks=S.pad_blocks(tr.n_blocks), capacity=2, policy="lru", prefetch="demand", n_valid=tr.n_blocks,
    )
    assert int(state.zero_copy) == 3  # three accesses to the pinned block
    assert not bool(state.resident[0])  # pinned blocks never migrate


def test_trace_generators_wellformed():
    for name, fn in T.BENCHMARKS.items():
        tr = fn(scale=0.3)
        assert len(tr) > 50, name
        assert tr.page.min() >= 0 and tr.page.max() < tr.n_pages, name
        assert len(tr.pc) == len(tr.page) == len(tr.tb) == len(tr.kernel), name


def test_table_iii_delta_growth():
    """NW / Srad grow their delta vocabulary across phases; streaming stays flat."""
    from repro.core.features import unique_deltas_per_phase

    nw = unique_deltas_per_phase(T.get_trace("NW", scale=0.6))
    assert nw[-1] > 1.5 * nw[0]
    srad = unique_deltas_per_phase(T.get_trace("Srad-v2", scale=0.6))
    assert srad[-1] > srad[0]
    stream = unique_deltas_per_phase(T.get_trace("StreamTriad", scale=0.6))
    assert stream[-1] <= stream[0] + 2
