"""Per-arch smoke tests: every assigned architecture instantiates its reduced
config and runs forward / train / prefill / decode on CPU with finite outputs
and the right shapes. Plus teacher-forced decode consistency for one arch per
family (the strongest cheap correctness check)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import lm
from repro.optim import adamw

SEQ, BATCH = 32, 2


def _params(cfg):
    return lm.init(jax.random.key(0), cfg, max_seq=SEQ + 8)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    shape = ShapeConfig("t", SEQ, BATCH, "train")
    batch = lm.make_batch(jax.random.key(1), cfg, shape)
    logits, _ = lm.forward(params, {**batch, "tokens": batch["tokens"][:, :-1]}, cfg)
    assert logits.shape[0] == BATCH and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = adamw.adamw(1e-3)
    step = lm.make_train_step(cfg, opt)
    p2, _, metrics = step(params, opt.init(params), batch, 0)
    assert np.isfinite(float(metrics["total_loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    shape = ShapeConfig("p", SEQ, BATCH, "prefill")
    batch = lm.make_batch(jax.random.key(2), cfg, shape)
    logits, cache = lm.make_prefill(cfg)(params, batch)
    assert logits.shape == (BATCH, 1, cfg.padded_vocab)
    dec = {"token": jnp.zeros((BATCH,), jnp.int32), "pos": jnp.asarray(lm.text_len(cfg, SEQ) - 1, jnp.int32)}
    logits2, cache2 = lm.make_decode_step(cfg)(params, dec, cache)
    assert logits2.shape == (BATCH, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m", "zamba2-7b", "whisper-medium"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy-decode logits must equal teacher-forced forward logits when the
    decode path replays the same tokens against a prefix cache."""
    cfg = get_smoke_config(arch)
    S, prefix = 24, 16
    if cfg.family in ("ssm", "hybrid"):
        # chunked-prefill vs step-decode follow different eval orders; in bf16
        # the recurrence amplifies rounding noise, so check the MATH in fp32
        # (verified exact); bf16 agreement is covered by the dense archs.
        cfg = cfg.replace(ssm_chunk=8, dtype="float32")
    params = _params(cfg)
    tokens = jax.random.randint(jax.random.key(3), (BATCH, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.key(4), (BATCH, cfg.enc_len, cfg.enc_feat)).astype(jnp.bfloat16)

    full_logits, _ = lm.forward(params, batch, cfg)

    pre = {**batch, "tokens": tokens[:, :prefix]}
    logits_p, cache = lm.make_prefill(cfg)(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0].astype(jnp.float32)),
        np.asarray(full_logits[:, prefix - 1].astype(jnp.float32)),
        atol=2e-2, rtol=2e-2,
    )

    # attention caches must be padded to the full length before decoding
    def grow(k, a):
        if k in ("k", "v") and a.ndim >= 3:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, S - a.shape[2])
            return jnp.pad(a, pad)
        return a

    cache = {k: grow(k, v) for k, v in cache.items()}
    decode = lm.make_decode_step(cfg)
    for pos in range(prefix, S):
        step_batch = {"token": tokens[:, pos - 1] * 0 + tokens[:, pos - 1], "pos": jnp.asarray(pos - 1, jnp.int32)}
        # feed the TRUE previous token; compare against teacher-forced logits
        step_batch["token"] = tokens[:, pos]
        logits_d, cache = decode(params, {"token": tokens[:, pos], "pos": jnp.asarray(pos, jnp.int32)}, cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0].astype(jnp.float32)),
            np.asarray(full_logits[:, pos].astype(jnp.float32)),
            atol=3e-2, rtol=3e-2,
        )


def test_vocab_padding_and_loss_mask():
    cfg = get_smoke_config("granite-3-8b")  # vocab 517 pads to 768
    assert cfg.padded_vocab % cfg.vocab_pad == 0 and cfg.padded_vocab >= cfg.vocab_size
    params = _params(cfg)
    shape = ShapeConfig("t", SEQ, BATCH, "train")
    batch = lm.make_batch(jax.random.key(5), cfg, shape)
    loss, _ = lm.loss_fn(params, batch, cfg)
    # loss must be ~log(vocab_size), NOT log(padded_vocab), for random init
    assert float(loss) < np.log(cfg.padded_vocab) + 0.5
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


def test_param_counts_full_configs():
    """Analytic parameter counts of the FULL configs are in the right ballpark
    (no allocation — specs only)."""
    from repro.configs import get_config

    expect = {  # rough published sizes (fraction of a billion)
        "qwen2-0.5b": (0.3, 0.8),
        "qwen3-0.6b": (0.4, 0.9),
        "granite-3-8b": (6.0, 10.0),
        "qwen1.5-4b": (3.0, 5.0),
        "olmoe-1b-7b": (5.5, 8.5),
        "mamba2-370m": (0.25, 0.55),
    }
    for arch, (lo, hi) in expect.items():
        n = lm.param_count(get_config(arch), max_seq=128) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"
    # MoE active < total
    cfg = get_config("olmoe-1b-7b")
    assert lm.active_param_count(cfg) < 0.4 * lm.param_count(cfg)
