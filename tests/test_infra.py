"""Substrate tests: data determinism, optimizer, checkpointing, compression,
elastic planning, sharding resolver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import compression as C
from repro.distributed import sharding
from repro.distributed.elastic import ElasticController, StragglerPolicy, plan_mesh
from repro.models.params import Spec
from repro.optim import adamw


# --- data -------------------------------------------------------------------

def test_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab_size=1000, batch=8, seq_len=16, seed=3)
    p = TokenPipeline(cfg)
    a = p.get(5)
    b = p.get(5)
    np.testing.assert_array_equal(a, b)
    # 2-shard partition == rows of the global batch
    s0 = p.get(5, shard=0, n_shards=2)
    s1 = p.get(5, shard=1, n_shards=2)
    np.testing.assert_array_equal(np.concatenate([s0, s1]), a)
    assert not np.array_equal(p.get(6), a)
    assert a.min() >= 0 and a.max() < 1000


# --- optimizer ----------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    opt = adamw.adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}
        updates, state, _ = opt.update(grads, state, params, step)
        params = adamw.apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_and_schedule():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    lr = adamw.cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0 and abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


# --- checkpointing ---------------------------------------------------------------

def test_checkpoint_roundtrip_retention_atomicity(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"a/b": np.arange(6, dtype=np.float32).reshape(2, 3), "c": np.ones(4, np.int32)}
    for s in (1, 2, 3):
        ck.save(s, tree, extra={"note": s})
    assert ck.steps() == [2, 3]  # retention
    step, restored, extra = ck.restore()
    assert step == 3 and extra["note"] == 3
    np.testing.assert_array_equal(restored["a/b"], tree["a/b"])
    # torn write recovery
    (tmp_path / "step_000000099.tmp").mkdir()
    ck.clean_tmp()
    assert not list(tmp_path.glob("*.tmp"))
    assert ck.latest_step() == 3


def test_checkpoint_integrity_check(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": np.zeros(3, np.float32)})
    # corrupt the leaf
    leaf = tmp_path / "step_000000001" / "w.npy"
    np.save(leaf, np.zeros(5, np.float32))
    with pytest.raises(ValueError):
        ck.restore(1)


# --- compression ------------------------------------------------------------------

# (test_quantize_error_bound moved to test_properties.py — hypothesis-guarded)


def test_error_feedback_unbiased_over_time():
    """EF: the *accumulated* applied update converges to the true gradient sum."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=128).astype(np.float32)) * 0.01
    res = {"g": jnp.zeros(128)}
    applied = jnp.zeros(128)
    for _ in range(50):
        comp, res_ = C.ErrorFeedback.apply({"g": g}, res)
        res = res_
        applied = applied + comp["g"]
    total_true = 50 * g
    rel = float(jnp.linalg.norm(applied - total_true) / jnp.linalg.norm(total_true))
    assert rel < 0.05


def test_compressed_psum_single_device():
    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.linspace(-3, 3, 64)
    f = C.make_compressed_allreduce(mesh, "pod")
    out = f({"x": x})["x"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=0.05)


# --- elastic ---------------------------------------------------------------------

# (test_plan_mesh_properties moved to test_properties.py — hypothesis-guarded)


def test_elastic_events_and_straggler_math():
    ctl = ElasticController(512, prefer_model=16)
    assert ctl.mesh_shape[2] == 16
    new = ctl.on_failure(step=100, surviving=384)
    assert np.prod(new) == 384 and len(ctl.events) == 1
    sp = StragglerPolicy(n_microbatches=8, min_fraction=0.5)
    g = {"w": jnp.ones(4)}
    scaled, ok = sp.combine(g, landed=6)
    assert ok and abs(float(scaled["w"][0]) - 8 / 6) < 1e-6
    _, ok2 = sp.combine(g, landed=2)
    assert not ok2


# --- sharding resolver -------------------------------------------------------------

def test_resolver_divisibility_fallbacks():
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # shapes only matter via sizes
    import jax.sharding as js

    mesh16 = type("M", (), {})()  # fake mesh with .shape mapping
    mesh16.shape = {"data": 16, "model": 16}
    # qwen2-0.5b: 14 heads not divisible -> replicated; ff 4864 sharded
    spec = sharding.resolve_spec(("embed", "heads", None), (896, 14, 64), mesh16)
    assert spec == js.PartitionSpec("data")
    spec = sharding.resolve_spec(("embed", "ff"), (896, 4864), mesh16)
    assert spec == js.PartitionSpec("data", "model")
    # KV cache: kv_heads=8 fails on 16 -> kv_seq picks up the model axis
    spec = sharding.resolve_spec(("layers", "batch", "kv_seq", "kv_heads", None), (40, 128, 32768, 8, 128), mesh16)
    assert spec == js.PartitionSpec(None, "data", "model")
    # ...but kv_heads wins when divisible (priority over kv_seq)
    spec = sharding.resolve_spec(("layers", "batch", "kv_seq", "kv_heads", None), (40, 128, 32768, 16, 128), mesh16)
    assert spec == js.PartitionSpec(None, "data", None, "model")


def test_resolver_multipod_batch():
    meshmp = type("M", (), {})()
    meshmp.shape = {"pod": 2, "data": 16, "model": 16}
    spec = sharding.resolve_spec(("batch", None), (256, 10), meshmp)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"))
    # batch=1 (long_500k): replicated
    spec = sharding.resolve_spec(("batch", None), (1, 10), meshmp)
    assert spec == jax.sharding.PartitionSpec()


def test_per_device_bytes():
    m = type("M", (), {})()
    m.shape = {"data": 16, "model": 16}
    b = sharding.per_device_bytes(m, ("embed", "ff"), (4096, 12800), 4)
    assert b == 4096 * 12800 * 4 // 256
