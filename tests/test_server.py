"""Async fault-stream server (ISSUE 8 tentpole).

The guarantees pinned here:

* **bit-identity across dispatch modes** — a client's action stream
  (records, error lines AND the final summary) is byte-identical whether
  the server dispatches per-connection serially, microbatched-fused, or
  microbatched-vmapped, and identical to the inline ``cli serve`` state
  machine (`StreamSession` + `SyncDispatch`).  Pinned deterministically
  with the real SMOKE trainer and as a hypothesis property over
  arbitrary per-client line soups (malformed lines included) with the
  stub trainer;
* **isolation** — malformed and chaos-transformed clients earn
  structured error records / degraded batches on THEIR connection only;
  clean concurrent clients' streams stay byte-identical to the
  reference, and a server-side chaos schedule degrades softly (health
  machine) instead of crashing the process;
* **admission + lifecycle** — connections over ``max_sessions`` are
  refused with a structured error, idle connections are drained +
  closed by the GC, an overlong line closes only its own connection,
  and duplicate ``hello`` session names are rejected;
* **kill-9/resume** — a ``cli server`` subprocess killed with SIGKILL
  mid-stream resumes from its periodic snapshot under ``--resume`` with
  a byte-identical action tail (reference: the uninterrupted ``cli
  serve`` run of the same stream — one codec, one state machine);
* **AOT export** (`server.aot`) — exported executables reload from the
  cache (trace skipped) and reproduce the jit path's records exactly.

The cross-mode properties run on the same pure-numpy stub trainer as
``tests/test_multi.py``: the invariants at stake live in the gather/
scatter and session plumbing, not in the predictor.
"""
import asyncio
import json
import os
import select
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig, Trainer
from repro.uvm.manager import (
    ChaosSchedule,
    FaultInjector,
    HealthConfig,
    ManagerConfig,
    SnapshotStore,
    TenantMux,
)
from repro.uvm.server import (
    FaultStreamServer,
    ServerConfig,
    StreamSession,
    SyncDispatch,
    drive,
    make_connector,
    run_loadgen,
)
from repro.uvm.server.core import _resolve_engine


# --- the stub predictor stack (same contract as tests/test_multi.py) ---------


class _StubTrainer:
    """Deterministic pure-numpy stand-in for `Trainer`: predicts the
    window's last delta class, counts updates."""

    def new_params(self, seed: int = 0):
        return np.zeros(1)

    def evaluate(self, params, fs, n_active: int):
        pred = fs.delta[:, -1] % max(n_active, 1)
        return pred == fs.label, pred

    def evaluate_many(self, params_list, fs_list, n_active_list):
        return [self.evaluate(p, f, n) for p, f, n in zip(params_list, fs_list, n_active_list)]

    def train_group(self, entry, fs, n_active, *, in_et=None, use_lucir=False, rng=None):
        entry.n_updates += 1
        return entry

    def train_group_many(self, entries, fs_list, n_active_list, *, in_et_list=None, use_lucir=False):
        for e in entries:
            e.n_updates += 1
        return entries


def _stub_cfg(**kw) -> ManagerConfig:
    kw.setdefault("predictor", SMOKE)
    kw.setdefault("train", TrainConfig(group_size=64, epochs=1, batch_size=32))
    kw.setdefault("n_pages", 1024)
    kw.setdefault("n_blocks", 64)
    kw.setdefault("capacity", 16)
    kw.setdefault("use_lucir", False)
    kw.setdefault("use_thrash_term", False)
    return ManagerConfig(**kw)


def _lines(n_batches=8, pages_per=24, seed=0, tenants=("A", "B")):
    """A deterministic observe/feedback JSONL stream (tenant-tagged when
    ``tenants`` is non-empty)."""
    rng = np.random.default_rng(seed)
    out, clock = [], 0
    for b in range(n_batches):
        rec = {"pages": rng.integers(0, 1024, pages_per).tolist()}
        fb = {"feedback": {"was_evicted": [False] * pages_per, "fault_count": clock + 64}}
        clock += 64
        if tenants:
            rec["tenant"] = fb["tenant"] = tenants[b % len(tenants)]
        out.append(json.dumps(rec))
        out.append(json.dumps(fb))
    return out


def _inline_reference(lines, cfg, trainer):
    """What `cli serve` would print for this stream: the byte-level
    reference every server mode must reproduce per connection."""
    session = StreamSession(TenantMux(cfg, trainer=trainer), default_tenant="default")
    dispatch = SyncDispatch(trainer, cfg.use_lucir)
    recs = [r for ln in lines for r in drive(session.step(ln), dispatch)]
    recs += drive(session.drain(), dispatch)
    return recs + [session.summary_line()]


async def _raw_client(path, lines, *, hello=None):
    """Send ``lines``, half-close, and return every output line."""
    reader, writer = await asyncio.open_unix_connection(path)
    try:
        if hello is not None:
            writer.write((json.dumps({"hello": {"session": hello}}) + "\n").encode())
        for ln in lines:
            writer.write((ln.rstrip("\n") + "\n").encode())
        await writer.drain()
        writer.write_eof()
        out = []
        while True:
            raw = await reader.readline()
            if not raw:
                return out
            out.append(raw.decode().rstrip("\n"))
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass


async def _with_server(scfg, trainer, fn, tmp_path):
    server = FaultStreamServer(scfg, trainer=trainer)
    path = str(tmp_path / "srv.sock")
    await server.start(path=path)
    try:
        return await fn(server, path)
    finally:
        await server.shutdown()


def _server_cfg(mode, mcfg, **kw):
    return ServerConfig(manager=mcfg, microbatch=(mode != "serial"),
                        exec_mode=mode if mode != "serial" else "auto", **kw)


# --- engine policy -----------------------------------------------------------


def test_resolve_engine_policy(monkeypatch):
    import jax

    assert _resolve_engine("vmap") == "vmap"
    assert _resolve_engine("fused") == "fused"
    with pytest.raises(ValueError, match="exec_mode"):
        _resolve_engine("turbo")
    monkeypatch.setenv("REPRO_OURS_BATCHED", "1")
    assert _resolve_engine("auto") == "vmap"
    monkeypatch.setenv("REPRO_OURS_BATCHED", "0")
    assert _resolve_engine("auto") == "fused"
    monkeypatch.delenv("REPRO_OURS_BATCHED")
    expected = "vmap" if len(jax.devices()) > 1 else "fused"
    assert _resolve_engine("auto") == expected  # the run_ours_many policy


# --- bit-identity across dispatch modes --------------------------------------


@pytest.mark.parametrize("mode", ["serial", "fused", "vmap"])
def test_server_stream_bit_identical_to_serve(tmp_path, mode):
    """6 concurrent clients replaying the same stream: every connection's
    full output (records + summary) is byte-identical to the inline
    serve state machine, in every dispatch mode."""
    lines = _lines(8)
    mcfg = _stub_cfg()
    expected = _inline_reference(lines, mcfg, _StubTrainer())

    async def run(server, path):
        outs = await asyncio.gather(*(_raw_client(path, lines) for _ in range(6)))
        return outs, server.dispatcher.n_ticks, server.dispatcher.max_eval_lanes

    outs, n_ticks, lanes = asyncio.run(
        _with_server(_server_cfg(mode, mcfg), _StubTrainer(), run, tmp_path))
    for out in outs:
        assert out == expected
    if mode != "serial":
        # the dispatcher genuinely gathered across connections
        assert lanes > 1
        assert n_ticks < 6 * sum(1 for l in lines if "pages" in l)


_MALFORMED = ["not json {", "[1, 2]", '{"pages": ["x"]}',
              '{"pages": [1], "feedback": {}}', ""]


def _random_soup(rng, n_lines):
    """One client's arbitrary line soup: tagged/untagged observes, bare
    fault-clock feedbacks, and malformed junk (each junk line earns
    exactly one structured error record on that connection only)."""
    out = []
    for _ in range(n_lines):
        roll = rng.integers(0, 4)
        if roll <= 1:
            rec = {"pages": rng.integers(0, 1024, rng.integers(1, 13)).tolist()}
            tenant = rng.choice(["A", "B", None])
            if tenant is not None:
                rec["tenant"] = str(tenant)
            out.append(json.dumps(rec))
        elif roll == 2:
            out.append(json.dumps({"feedback": {"fault_count": int(rng.integers(0, 4096))}}))
        else:
            out.append(_MALFORMED[rng.integers(0, len(_MALFORMED))])
    return out


def _assert_soup_equivalence(per_client_lines, tmp):
    """Arbitrary per-client line soups (malformed included), concurrent
    connections: each client's microbatched output is byte-identical to
    its own inline serve reference."""
    mcfg = _stub_cfg()
    expected = [_inline_reference(ls, mcfg, _StubTrainer()) for ls in per_client_lines]

    async def run(server, path):
        return await asyncio.gather(*(_raw_client(path, ls) for ls in per_client_lines))

    outs = asyncio.run(_with_server(_server_cfg("fused", mcfg), _StubTrainer(), run, tmp))
    assert outs == expected


def test_microbatched_equiv_random_soups(tmp_path):
    """Deterministic net over 12 seeded random multi-client line soups
    (always runs; the hypothesis property below widens it when the
    package is available)."""
    for seed in range(12):
        rng = np.random.default_rng(seed)
        soups = [_random_soup(rng, int(rng.integers(1, 11)))
                 for _ in range(int(rng.integers(1, 5)))]
        _assert_soup_equivalence(soups, tmp_path)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    _line_st = st.one_of(
        st.tuples(st.lists(st.integers(0, 1023), min_size=1, max_size=12),
                  st.sampled_from(["A", "B", None])).map(
            lambda t: json.dumps({"pages": t[0], **({"tenant": t[1]} if t[1] else {})})),
        st.integers(0, 4096).map(
            lambda fc: json.dumps({"feedback": {"fault_count": fc}})),
        st.sampled_from(_MALFORMED),
    )

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.lists(_line_st, min_size=1, max_size=10), min_size=1, max_size=4))
    def test_microbatched_equiv_property(tmp_path_factory, per_client_lines):
        _assert_soup_equivalence(per_client_lines, tmp_path_factory.mktemp("prop"))
except ImportError:  # pragma: no cover - the seeded net above still runs
    pass


def test_server_real_trainer_matches_serve(tmp_path):
    """The deterministic pin with the real (SMOKE) trainer: serial and
    microbatched-fused serving both reproduce inline serve exactly."""
    mcfg = _stub_cfg(train=TrainConfig(group_size=32, epochs=1, batch_size=16))
    trainer = Trainer(mcfg.predictor, mcfg.train, mcfg.kind)
    lines = _lines(4, pages_per=32, tenants=())
    expected = _inline_reference(lines, mcfg, trainer)
    for mode in ("serial", "fused"):
        async def run(server, path):
            return await asyncio.gather(*(_raw_client(path, lines) for _ in range(3)))

        outs = asyncio.run(_with_server(_server_cfg(mode, mcfg), trainer, run, tmp_path))
        for out in outs:
            assert out == expected, mode


# --- admission, idle GC, overlong lines, hello -------------------------------


def test_admission_cap_refuses_with_structured_error(tmp_path):
    mcfg = _stub_cfg()

    async def run(server, path):
        campers = [await asyncio.open_unix_connection(path) for _ in range(2)]
        await asyncio.sleep(0.05)  # let both handlers register
        refused = await _raw_client(path, [])
        for r, w in campers:
            w.write_eof()
            while await r.readline():
                pass
            w.close()
        return refused, dict(server.stats)

    refused, stats = asyncio.run(
        _with_server(_server_cfg("fused", mcfg, max_sessions=2), _StubTrainer(), run, tmp_path))
    assert refused == [json.dumps({"error": "server full (2 sessions)", "line": 0})]
    assert stats["refused"] == 1 and stats["served"] == 2


def test_idle_gc_drains_and_closes(tmp_path):
    mcfg = _stub_cfg()
    line = json.dumps({"pages": [1, 2, 3]})

    async def run(server, path):
        reader, writer = await asyncio.open_unix_connection(path)
        writer.write((line + "\n").encode())
        await writer.drain()
        out = []  # no write_eof: only the GC can end this connection
        while True:
            raw = await asyncio.wait_for(reader.readline(), timeout=5.0)
            if not raw:
                break
            out.append(raw.decode().rstrip("\n"))
        writer.close()
        return out, dict(server.stats)

    out, stats = asyncio.run(_with_server(
        _server_cfg("fused", mcfg, idle_timeout_s=0.15), _StubTrainer(), run, tmp_path))
    assert stats["idle_closed"] == 1
    assert json.loads(out[0])["batch"] == 1  # the work done before idling survives


def test_overlong_line_closes_only_its_connection(tmp_path):
    mcfg = _stub_cfg()
    clean = _lines(2)
    expected = _inline_reference(clean, mcfg, _StubTrainer())

    async def run(server, path):
        long = await _raw_client(path, ["x" * 4096])
        good = await _raw_client(path, clean)
        return long, good

    long, good = asyncio.run(_with_server(
        _server_cfg("fused", mcfg, line_limit=256), _StubTrainer(), run, tmp_path))
    assert json.loads(long[0]) == {"error": "line too long", "line": 1}
    assert good == expected


def test_hello_names_and_duplicates(tmp_path):
    mcfg = _stub_cfg()

    async def run(server, path):
        r1, w1 = await asyncio.open_unix_connection(path)
        w1.write((json.dumps({"hello": {"session": "dup"}}) + "\n").encode())
        await w1.drain()
        await asyncio.sleep(0.05)
        names = set(server.sessions)
        second = await _raw_client(path, [json.dumps({"pages": [1]})], hello="dup")
        w1.write_eof()
        while await r1.readline():
            pass
        w1.close()
        return names, second

    names, second = asyncio.run(
        _with_server(_server_cfg("fused", mcfg), _StubTrainer(), run, tmp_path))
    assert "dup" in names
    err = json.loads(second[0])
    assert "already in use" in err["error"]
    assert json.loads(second[1])["batch"] == 1  # the connection itself survives


# --- isolation under malformed + chaos clients (loadgen, over TCP) -----------


def test_loadgen_isolation_malformed_and_chaos(tmp_path):
    """6 concurrent loadgen clients over TCP — one malformed, one
    chaos-transformed: clean clients' action streams stay byte-identical
    to the reference, errors land only on the malformed connection."""
    mcfg = _stub_cfg()
    lines = _lines(6)
    expected_actions = [r for r in _inline_reference(lines, mcfg, _StubTrainer())
                        if r.startswith("{") and "batch" in r]

    async def run(server, _path):
        connect = make_connector(f"127.0.0.1:{server.tcp_port}")
        stats = await run_loadgen(
            connect, lines, 6, hello_prefix="lg-",
            malformed_every=2, malformed_client=4,
            chaos_schedules={5: FaultInjector(ChaosSchedule.parse(
                "drop_batch=0.4,dup_batch=0.3,lose_feedback=0.5,seed=11"))},
        )
        return stats, dict(server.stats)

    async def boot():
        server = FaultStreamServer(_server_cfg("fused", mcfg), trainer=_StubTrainer())
        await server.start(path=str(tmp_path / "srv.sock"), host="127.0.0.1", port=0)
        try:
            return await run(server, None)
        finally:
            await server.shutdown()

    stats, sstats = asyncio.run(boot())
    assert sstats["served"] == 6
    per = stats.per_client
    for r in per[:4]:  # clean clients: byte-identical actions, no errors
        assert r.actions == expected_actions
        assert r.errors == 0
        assert r.comments and r.comments[-1].startswith("# serve batches=6")
    assert per[4].malformed_sent > 0
    assert per[4].errors == per[4].malformed_sent  # one structured error each
    assert per[4].actions == expected_actions  # its own stream is undisturbed
    # the chaos client's transformed stream still yields well-formed actions
    assert per[5].actions and all("batch" in json.loads(a) for a in per[5].actions)
    assert stats.errors == per[4].errors
    assert stats.p50_ms >= 0.0 and stats.faults_per_s > 0


def test_server_side_chaos_degrades_softly(tmp_path):
    """A chaos schedule on the SHARED trainer (`--inject`): dispatch
    failures are absorbed by each session's health machine as degraded
    fallback records — never a traceback, never a lost batch."""
    mcfg = _stub_cfg(health=HealthConfig())
    lines = _lines(10, tenants=())

    async def run(server, path):
        outs = await asyncio.gather(*(_raw_client(path, lines) for _ in range(3)))
        return outs, server.injector

    outs, injector = asyncio.run(_with_server(
        _server_cfg("fused", mcfg, inject="trainer_exc=0.5,seed=3"),
        _StubTrainer(), run, tmp_path))
    assert sum(injector.counts.values()) > 0  # the schedule actually fired
    for out in outs:
        acts = [json.loads(r) for r in out if r.startswith("{")]
        assert all("error" not in a for a in acts)
        assert len(acts) == 10  # every observed batch got an action record
        assert any(a["fallback"] for a in acts)
        assert any(a["health"] == "degraded" for a in acts)


# --- kill-9 / --resume (subprocess) ------------------------------------------


_STREAM_FLAGS = ["--n-pages", "300", "--pages-per-block", "4",
                 "--capacity", "16", "--group-size", "32"]


def _spawn_server(sock, extra):
    """`cli server` in a fresh process (via the api import so the
    persistent XLA compile cache keeps the subprocess compiles warm)."""
    proc = subprocess.Popen(
        [sys.executable, "-c",
         "import sys; import repro.uvm.api as _api; from repro.uvm import cli; "
         "sys.exit(cli.main(sys.argv[1:]))",
         "server", "--socket", sock, *_STREAM_FLAGS, *extra],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        cwd=str(Path(__file__).resolve().parent.parent),
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
    )
    deadline = time.time() + 120
    banner = ""
    while time.time() < deadline:
        r, _, _ = select.select([proc.stdout], [], [], 1.0)
        if r:
            banner = proc.stdout.readline()
            break
        assert proc.poll() is None, "server died before listening"
    assert "# server listening" in banner, banner
    return proc


async def _drive_named(sock, lines, *, n_actions=None):
    """hello 'job' + send `lines`; collect output (all of it on EOF, or
    until `n_actions` action records arrived)."""
    reader, writer = await asyncio.open_unix_connection(sock)
    writer.write((json.dumps({"hello": {"session": "job"}}) + "\n").encode())
    for ln in lines:
        writer.write((ln + "\n").encode())
    await writer.drain()
    if n_actions is None:
        writer.write_eof()
    out, acts = [], 0
    try:
        while True:
            raw = await asyncio.wait_for(reader.readline(), timeout=120)
            if not raw:
                break
            s = raw.decode().rstrip("\n")
            out.append(s)
            acts += s.startswith("{") and "batch" in s
            if n_actions is not None and acts >= n_actions:
                break
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass
    return out


def test_server_kill9_resume_bit_identical_tail(tmp_path, capsys):
    """SIGKILL the checkpointing server subprocess mid-stream; a fresh
    `--resume` server replaying the full stream emits an action tail and
    summary byte-identical to the uninterrupted `cli serve` run."""
    from repro.uvm import cli

    lines = _lines(10, pages_per=40, seed=42, tenants=())
    full = tmp_path / "full.jsonl"
    full.write_text("\n".join(lines) + "\n")
    assert cli.main(["serve", "--input", str(full), *_STREAM_FLAGS]) == 0
    ref = capsys.readouterr().out.strip().splitlines()
    ref_acts = [l for l in ref if l.startswith("{")]
    ck = tmp_path / "ckpt"

    sock = str(tmp_path / "a.sock")
    proc = _spawn_server(sock, ["--checkpoint-dir", str(ck), "--checkpoint-every", "2"])
    try:
        # 13 lines = 6 closed batches + batch 7's observe: stepping line 13
        # flushes the batch-6 round-boundary snapshot before answering, so
        # once action 7 arrives the snapshot is durable — then kill -9
        out = asyncio.run(_drive_named(sock, lines[:13], n_actions=7))
        assert len([l for l in out if l.startswith("{")]) == 7
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        proc.stdout.close()  # the pipe outlives the kill; GC would warn
    assert SnapshotStore(ck / "job").latest_step() == 6

    sock2 = str(tmp_path / "b.sock")
    proc = _spawn_server(sock2, ["--checkpoint-dir", str(ck), "--resume"])
    try:
        res = asyncio.run(_drive_named(sock2, lines))
    finally:
        proc.terminate()
        proc.wait()
        proc.stdout.close()
    assert any(l.startswith("# resumed batch=6") for l in res)
    tail = [l for l in res if l.startswith("{")]
    assert tail == ref_acts[6:]  # byte-identical resumed records
    assert res[-1] == ref[-1]  # identical final summary


# --- AOT export/reload -------------------------------------------------------


def test_aot_export_reload_bit_identical(tmp_path):
    """enable_aot: first run exports (misses), second run reloads from
    disk (hits, no fallback), and both reproduce the jit records
    byte-for-byte."""
    from repro.uvm.server.aot import enable_aot

    mcfg = _stub_cfg(train=TrainConfig(group_size=32, epochs=1, batch_size=16))
    lines = _lines(3, pages_per=32, tenants=())

    def run(cache):
        trainer = Trainer(mcfg.predictor, mcfg.train, mcfg.kind)
        if cache is not None:
            enable_aot(trainer, cache)
        out = _inline_reference(lines, mcfg, trainer)
        return out, (trainer.aot_cache.stats() if cache is not None else None)

    jit, _ = run(None)
    exported, s_exp = run(tmp_path / "aot")
    reloaded, s_rel = run(tmp_path / "aot")
    assert exported == jit
    assert reloaded == jit
    assert s_exp["misses"] >= 1 and s_exp["fallbacks"] == 0
    assert s_rel["hits"] >= 1 and s_rel["misses"] == 0 and s_rel["fallbacks"] == 0
    assert list((tmp_path / "aot").glob("*.jaxexport"))
