"""The declarative experiment API (ISSUE 3 tentpole): specs, registries,
run store, Session, CLI.

The heavier guarantees pinned here:

* spec content hashes are STABLE across processes and releases (hardcoded
  hex — a drift silently orphans every stored run);
* a policy registered via ``register_policy`` sweeps through the vmapped
  ``run_batch`` path with correct counters, without touching
  ``src/repro/uvm/simulator.py``;
* the raw ``run_ours`` path returns bit-identical counters to ``Session``
  (and the retired ``Ctx`` shim stays gone).
"""
import json
import os

import pytest

from repro.configs.predictor_paper import SMOKE
from repro.uvm import registry as REG
from repro.uvm import simulator as S
from repro.uvm import trace as T
from repro.uvm.api import (
    CellSpec,
    ExperimentSpec,
    ModelSpec,
    PolicySpec,
    PrefetchSpec,
    RunStore,
    Session,
    WorkloadSpec,
    register_policy,
    register_prefetcher,
    register_predictor,
)
from repro.uvm.api.specs import PretrainSpec, ProtocolSpec, TrainSpec, spec_from_dict


def _quick_session(tmp_path, **kw) -> Session:
    kw.setdefault("store", RunStore(tmp_path / "runs"))
    return Session(**kw)


# --- specs -------------------------------------------------------------------


def test_spec_json_roundtrip():
    cell = CellSpec(
        WorkloadSpec("ATAX", 0.3, 1500), "sim", PolicySpec("hpe"), PrefetchSpec("demand"), 1.5
    )
    back = CellSpec.from_json(cell.to_json())
    assert back == cell and back.key == cell.key

    exp = ExperimentSpec(
        name="x",
        workloads=(WorkloadSpec("NW"), WorkloadSpec.concurrent(("ATAX", "BICG"), slice_len=512)),
        policies=(PolicySpec("lru"), PolicySpec("belady")),
        prefetchers=(PrefetchSpec("tree"),),
        oversubscriptions=(1.25, 1.5),
    )
    back = ExperimentSpec.from_json(exp.to_json())
    assert back == exp and back.key == exp.key
    assert len(exp.cells()) == 2 * 2 * 1 * 2

    ours = CellSpec(
        WorkloadSpec("Hotspot"), "ours", PolicySpec("learned"), PrefetchSpec("none"),
        model=ModelSpec(predictor=SMOKE, train=TrainSpec(), pretrain=PretrainSpec(seed0=123)),
    )
    assert CellSpec.from_json(ours.to_json()) == ours

    proto = ProtocolSpec(WorkloadSpec("NW"), "ours", ModelSpec(pretrain=PretrainSpec()), prior=("abc",))
    assert ProtocolSpec.from_json(proto.to_json()) == proto
    # generic reconstruction (what `cli report` relies on)
    assert spec_from_dict("CellSpec", cell.to_dict()) == cell


def test_spec_content_hash_stability():
    """Pinned hex: a hash-scheme change orphans every stored run — bump
    specs.SCHEMA intentionally instead, and regenerate these constants.
    (Regenerated for SCHEMA 2: PR 5's mux tenancy changed what a
    concurrent `ours` result means; regenerated again when PR 7 grew
    `WorkloadSpec.drift`, which moves every workload hash; regenerated
    for SCHEMA 3 when PR 9 grew `ModelSpec.qos` capacity partitioning.)"""
    assert WorkloadSpec("ATAX").key == "7363c55d1784e19f"
    assert CellSpec(WorkloadSpec("ATAX")).key == "d9894afe33c1a780"
    # any field change moves the key
    keys = {
        CellSpec(WorkloadSpec("ATAX")).key,
        CellSpec(WorkloadSpec("ATAX", scale=0.5)).key,
        CellSpec(WorkloadSpec("ATAX"), policy=PolicySpec("hpe")).key,
        CellSpec(WorkloadSpec("ATAX"), oversubscription=1.5).key,
        CellSpec(WorkloadSpec("ATAX"), strategy="uvmsmart").key,
        CellSpec(WorkloadSpec.drifting(("StreamTriad", "PtrChase"))).key,
    }
    assert len(keys) == 6


def test_cellspec_validation():
    with pytest.raises(ValueError):
        CellSpec(WorkloadSpec("ATAX"), "bogus")
    with pytest.raises(ValueError):
        CellSpec(WorkloadSpec("ATAX"), "ours")  # no model


# --- registries --------------------------------------------------------------


def test_registry_duplicate_name_rejected():
    with pytest.raises(ValueError):
        register_policy("lru", lambda st, i, t: (st.last_access,))
    with pytest.raises(ValueError):
        register_prefetcher("tree", lambda r, b, v, n: r)
    with pytest.raises(ValueError):
        register_predictor("transformer", lambda cfg: None)
    with REG.scoped():
        register_policy("tmp_policy", lambda st, i, t: (st.last_access,))
        with pytest.raises(ValueError):
            register_policy("tmp_policy", lambda st, i, t: (st.last_access,))
    assert "tmp_policy" not in REG.policy_names()  # scoped() restored


def test_builtin_ids_stable():
    assert S.POLICY_IDS == {"lru": 0, "random": 1, "belady": 2, "hpe": 3, "learned": 4}
    assert S.PREFETCH_IDS == {"demand": 0, "tree": 1, "none": 0}
    assert set(REG.predictor_names()) >= {"transformer", "lstm", "cnn", "mlp"}


def test_registered_policy_rides_run_batch():
    """A ~5-line custom policy sweeps through the vmapped run_batch path —
    no simulator.py edits: a builtin-clone must be bit-identical to the
    builtin in the SAME sweep, and an actually-different policy must match
    its own single-cell run."""
    tr = T.get_trace("ATAX", scale=0.25).slice(0, 1500)
    with REG.scoped():
        register_policy("lru_clone", lambda st, i, t: (st.last_access,))
        register_policy("mru", lambda st, i, t: (-st.last_access,))
        out = S.run_batch(tr, [
            ("lru", "tree", 1.25), ("lru_clone", "tree", 1.25),
            ("mru", "tree", 1.25), ("mru", "demand", 1.5),
        ])
        assert out[0] == out[1]
        assert out[2] != out[0]
        for cell, got in zip([("mru", "tree", 1.25), ("mru", "demand", 1.5)], out[2:]):
            want = S.run(tr, policy=cell[0], prefetch=cell[1], oversubscription=cell[2]).stats
            assert got == want, cell
    # builtins unaffected after the scope ends
    assert S.run_batch(tr, [("lru", "tree", 1.25)])[0] == S.run(tr, policy="lru", prefetch="tree").stats


def test_registered_prefetcher_rides_run_batch():
    """A registered prefetcher (here: a clone of the builtin tree mask)
    dispatches through the same traced branch table."""
    from repro.uvm.simulator import _tree_mask

    tr = T.get_trace("Hotspot", scale=0.25).slice(0, 1500)
    with REG.scoped():
        register_prefetcher("tree_clone", _tree_mask)
        out = S.run_batch(tr, [("lru", "tree", 1.25), ("lru", "tree_clone", 1.25)])
        assert out[0] == out[1]


def test_scoped_registration_never_leaves_stale_jits():
    """Version numbers are monotonic across scoped() rollbacks: a scan
    compiled INSIDE a scope must never be served to a later registration
    that happens to land on the same version number (it would silently run
    the wrong branch table — lru2 below would clamp onto `learned`)."""
    from repro.uvm.simulator import _tree_mask

    tr = T.get_trace("ATAX", scale=0.25).slice(0, 1500)
    want = S.run_batch(tr, [("lru", "tree", 1.25)])[0]
    with REG.scoped():
        register_prefetcher("tree2", _tree_mask)
        S.run_batch(tr, [("lru", "tree2", 1.25)])  # compiles at the scope's version
    with REG.scoped():
        register_policy("lru2", lambda st, i, t: (st.last_access,))
        assert S.run_batch(tr, [("lru2", "tree", 1.25)])[0] == want


def test_registered_policy_via_session(tmp_path):
    """The Session/CellSpec path accepts registered policies, but never
    PERSISTS their cells: a spec hashes a plugin by name only, so a changed
    implementation under the same name must not be served stale results."""
    with REG.scoped():
        register_policy("mru2", lambda st, i, t: (-st.last_access,))
        s = _quick_session(tmp_path, scale=0.25, cap=1500)
        got = s.run(CellSpec(s.workload("ATAX"), "sim", PolicySpec("mru2"), PrefetchSpec("tree"), 1.25))
        want = S.run(s.trace("ATAX"), policy="mru2", prefetch="tree").stats
        assert got == want
        assert list(s.store.records()) == []  # plugin cells stay in-process only


# --- run store ---------------------------------------------------------------


def test_run_store_roundtrip_and_corruption(tmp_path):
    store = RunStore(tmp_path / "runs")
    spec = CellSpec(WorkloadSpec("ATAX"))
    assert store.get(spec) is None
    p = store.put(spec, {"pages_thrashed": 7})
    assert p is not None and store.get(spec) == {"pages_thrashed": 7}
    assert store.hits == 1 and store.misses == 1 and store.writes == 1
    for garbage in ("{torn", "[1, 2]", '"not a record"'):  # all read as misses
        p.write_text(garbage)
        assert store.get(spec) is None
        assert [k for k, _ in RunStore(tmp_path / "runs").records()] == []


def test_run_store_killed_writer_leaves_no_damage(tmp_path):
    """A writer killed mid-publish leaves only a `.tmp.<pid>` turd: the
    published record (if any) still reads back, the turd is invisible to
    get()/records(), and a later publish succeeds over it."""
    store = RunStore(tmp_path / "runs")
    spec = CellSpec(WorkloadSpec("ATAX"))
    p = store.put(spec, {"faults": 1})
    # simulate a crash between tmp-write and os.replace: a half-written
    # tmp file sits next to the (old) published record
    turd = p.with_suffix(".tmp.99999")
    turd.write_text('{"schema": 2, "key": "' + spec.key + '", "result": {"faults":')
    assert store.get(spec) == {"faults": 1}
    assert [k for k, _ in store.records()] == [spec.key]
    # republish over the turd: atomic replace still lands the new record
    assert store.put(spec, {"faults": 2}) == p
    assert store.get(spec) == {"faults": 2}
    assert turd.exists()  # turds are inert, never silently adopted


def test_run_store_torn_record_reads_as_miss_then_heals(tmp_path):
    """A torn published file (crash mid-sector, disk-full truncation) must
    read as a miss everywhere, and re-running the cell heals it."""
    store = RunStore(tmp_path / "runs")
    spec = CellSpec(WorkloadSpec("ATAX"))
    p = store.put(spec, {"faults": 3})
    whole = p.read_text()
    for cut in (1, len(whole) // 2, len(whole) - 2):  # torn at any offset
        p.write_text(whole[:cut])
        assert store.get(spec) is None
        assert [k for k, _ in store.records()] == []
    # wrong-key aliasing (a renamed file) is also rejected, not served
    other = CellSpec(WorkloadSpec("BICG"))
    store.put(other, {"faults": 9})
    os.replace(store.path(other.key), p)
    assert store.get(spec) is None
    # the heal: republishing restores a byte-identical good record
    assert store.put(spec, {"faults": 3}) == p
    assert p.read_text() == whole and store.get(spec) == {"faults": 3}


def test_run_store_disabled(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RUN_STORE", "0")
    store = RunStore(tmp_path / "runs")
    spec = CellSpec(WorkloadSpec("ATAX"))
    assert store.put(spec, {"x": 1}) is None and store.get(spec) is None
    assert not (tmp_path / "runs").exists()


def test_sweep_served_from_store_across_sessions(tmp_path):
    exp = ExperimentSpec(
        workloads=(WorkloadSpec("ATAX", 0.25, 1500),),
        policies=(PolicySpec("lru"), PolicySpec("hpe")),
        prefetchers=(PrefetchSpec("demand"), PrefetchSpec("tree")),
        oversubscriptions=(1.25,),
    )
    s1 = _quick_session(tmp_path)
    r1 = s1.sweep(exp)
    assert s1.counters["computed"] == 4
    s2 = _quick_session(tmp_path)  # fresh process-equivalent: memory cold
    r2 = s2.sweep(exp)
    assert r2 == r1
    assert s2.counters == {"memory_hits": 0, "store_hits": 4, "computed": 0}


def test_random_policy_not_persisted(tmp_path):
    """`random` counters depend on lane padding (documented contract) — the
    store must never serve one sweep's random cell to a different sweep."""
    s = _quick_session(tmp_path, scale=0.25, cap=1500)
    s.sims("ATAX", [("random", "demand", 1.25), ("lru", "demand", 1.25)])
    kinds = [rec["spec"]["policy"]["name"] for _, rec in s.store.records()]
    assert "lru" in kinds and "random" not in kinds


# --- Session vs the deprecated entry points ---------------------------------


def test_session_sim_bit_identical_to_run(tmp_path):
    s = _quick_session(tmp_path, scale=0.25, cap=1500)
    for pol, pf, os_ in [("lru", "tree", 1.25), ("hpe", "demand", 1.5), ("belady", "demand", 1.25)]:
        want = S.run(s.trace("NW"), policy=pol, prefetch=pf, oversubscription=os_).stats
        assert s.sim("NW", pol, pf, os_) == want


def test_ctx_shim_is_gone():
    """The deprecated Ctx alias completed its removal schedule: importing
    it must fail, while benchmarks.common's surviving re-exports stay."""
    from benchmarks import common

    assert not hasattr(common, "Ctx")
    with pytest.raises(ImportError):
        from repro.uvm.api.session import Ctx  # noqa: F401
    # the moved quick-config survives under its old name ONE more PR, but
    # now warns (in-tree call sites migrated to CONFIG_QUICK in PR 10;
    # removal schedule in docs/API.md)
    from repro.configs.predictor_paper import CONFIG, CONFIG_QUICK

    with pytest.warns(DeprecationWarning, match="PCFG_QUICK is deprecated"):
        assert common.PCFG_QUICK is CONFIG_QUICK
    with pytest.warns(DeprecationWarning, match="PCFG_FULL is deprecated"):
        assert common.PCFG_FULL is CONFIG


def test_session_ours_bit_identical_to_run_ours(tmp_path, monkeypatch):
    """Session's learned cells reproduce raw run_ours exactly (counters AND
    accuracy), and a second session serves them from the store."""
    from repro.uvm import runtime as R

    monkeypatch.setattr(R, "PRETRAIN_CACHE_DIR", tmp_path / "cache")  # keep repo cache clean
    tr_name = "Hotspot"
    s = _quick_session(tmp_path, scale=0.3, cap=3000,
                       model=ModelSpec(predictor=SMOKE, train=TrainSpec()))
    res = s.ours(tr_name)
    want = R.run_ours(
        s.trace(tr_name), SMOKE, s.tcfg,
        oversubscription=1.25, table=s.pretrained(s.default_pretrain),
    )
    assert res.stats == want.stats
    assert res.top1 == want.top1 and res.n_predictions == want.n_predictions

    s2 = _quick_session(tmp_path, scale=0.3, cap=3000,
                        model=ModelSpec(predictor=SMOKE, train=TrainSpec()))
    res2 = s2.ours(tr_name)
    assert s2.counters["store_hits"] == 1 and s2.counters["computed"] == 0
    assert res2.stats == res.stats and res2.top1 == res.top1
    assert res2.per_group_acc == res.per_group_acc


def test_session_uvmsmart_matches_direct(tmp_path):
    from repro.uvm.uvmsmart import run_uvmsmart

    s = _quick_session(tmp_path, scale=0.25, cap=1500)
    assert s.uvmsmart("ATAX") == run_uvmsmart(s.trace("ATAX"), oversubscription=1.25)


def test_protocol_chain_cached_link_by_link(tmp_path, monkeypatch):
    """fig11's shape: links share one fine-tuned table, so link specs carry
    the chain prefix and a full rerun is served entirely from the store."""
    from repro.uvm import runtime as R

    monkeypatch.setattr(R, "PRETRAIN_CACHE_DIR", tmp_path / "cache")  # keep repo cache clean
    model = ModelSpec(predictor=SMOKE, train=TrainSpec())
    s = _quick_session(tmp_path, scale=0.25, cap=1500, model=model)
    pre = PretrainSpec(scale=0.15, seed0=123, benchmarks=("ATAX", "Hotspot"))
    chain = s.protocol_chain(["ATAX", "Hotspot"], "ours", pretrain=pre)
    assert len(chain) == 2 and all(r.n_samples > 0 for r in chain)

    s2 = _quick_session(tmp_path, scale=0.25, cap=1500, model=model)
    chain2 = s2.protocol_chain(["ATAX", "Hotspot"], "ours", pretrain=pre)
    assert s2.counters["computed"] == 0
    assert [r.top1 for r in chain2] == [r.top1 for r in chain]
    # a different prefix is a different spec: reordering misses the store
    s3 = _quick_session(tmp_path, scale=0.25, cap=1500, model=model)
    s3.protocol_chain(["Hotspot", "ATAX"], "ours", pretrain=pre)
    assert s3.counters["computed"] == 2


# --- CLI ---------------------------------------------------------------------


def test_cli_sweep_cache_hit_roundtrip(tmp_path, capsys):
    from repro.uvm import cli

    argv = ["sweep", "--benchmarks", "ATAX", "--policies", "lru", "--prefetchers",
            "demand", "tree", "--oversubs", "1.25", "--runs-dir", str(tmp_path / "runs"),
            "--scale", "0.25", "--cap", "1500"]
    assert cli.main(argv) == 0
    out1 = capsys.readouterr().out
    assert "hits=0 computed=2" in out1
    assert cli.main(argv) == 0
    out2 = capsys.readouterr().out
    assert "hits=2 computed=0" in out2
    # identical result lines (the cell rows, ignoring the counters line)
    rows = lambda s: [l for l in s.splitlines() if "thrash=" in l]
    assert rows(out1) == rows(out2)

    assert cli.main(["report", "--runs-dir", str(tmp_path / "runs")]) == 0
    rep = capsys.readouterr().out
    assert "2 stored runs" in rep and "ATAX" in rep


def test_cli_spec_dump_and_replay(tmp_path, capsys):
    from repro.uvm import cli

    spec_path = tmp_path / "exp.json"
    argv = ["sweep", "--benchmarks", "ATAX", "--policies", "lru", "--oversubs", "1.25",
            "--runs-dir", str(tmp_path / "runs"), "--scale", "0.25", "--cap", "1500",
            "--dump-spec", str(spec_path)]
    assert cli.main(argv) == 0
    capsys.readouterr()
    assert ExperimentSpec.from_json(spec_path.read_text()).cells()
    assert cli.main(["sweep", "--spec", str(spec_path), "--runs-dir", str(tmp_path / "runs")]) == 0
    assert "computed=0" in capsys.readouterr().out


def test_cli_run_single_cell(tmp_path, capsys):
    from repro.uvm import cli

    assert cli.main(["run", "--benchmark", "ATAX", "--policy", "belady", "--prefetch", "demand",
                     "--scale", "0.25", "--cap", "1500", "--runs-dir", str(tmp_path / "runs")]) == 0
    out = capsys.readouterr().out
    want = S.run(T.get_trace("ATAX", scale=0.25).slice(0, 1500), policy="belady", prefetch="demand").stats
    assert f"thrash={want['pages_thrashed']}" in out


def test_cli_run_and_sweep_share_store_keys(tmp_path, capsys):
    """`run` must hash a cell identically to `sweep`/Session for EVERY
    strategy (non-sim strategies canonicalise policy/prefetch) — otherwise
    the same logical run is recomputed and stored twice."""
    from repro.uvm import cli

    common = ["--scale", "0.25", "--cap", "1500", "--runs-dir", str(tmp_path / "runs")]
    assert cli.main(["sweep", "--benchmarks", "ATAX", "--strategy", "uvmsmart"] + common) == 0
    assert "computed=1" in capsys.readouterr().out
    assert cli.main(["run", "--benchmark", "ATAX", "--strategy", "uvmsmart"] + common) == 0
    assert "hits=1 computed=0" in capsys.readouterr().out
