"""Drifting-workload zoo (ISSUE 7 tentpole) — the generator contracts the
drift benchmark and golden cells stand on:

* zoo generators are deterministic under a fixed seed (same seed -> bit
  identical, different seed -> different stream);
* abrupt phase traces are EXACT segment concatenations: every segment is
  bit-equal to its standalone base generator's prefix (table9's claim is
  about re-classification, so each phase must be the genuine pattern);
* gradual phase traces only touch the blend windows — outside them the
  stream is bit-equal to the abrupt splice, and the blend is a MERGE
  (per-phase access order preserved, access multiset conserved);
* tenant churn: `trace.concurrent(starts=...)` admits tenants late and
  lets them leave early without breaking the per-tenant subsequence
  invariants, and ``starts=None`` stays bit-identical to the legacy
  static schedule (the PR 5 concurrent goldens must not move);
* the versioned JSONL fault log round-trips bit-identically (tenanted and
  untenanted) and rejects malformed/mixed/unversioned input loudly;
* end-to-end with the REAL trainer: `reclass_hysteresis` never flips on a
  lone disagreeing window, flips exactly once per genuine phase change,
  and the displaced pattern's model entry stays warm across a switch-back.
"""
import io

import numpy as np
import pytest

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime as R
from repro.uvm import trace as T
from repro.uvm import zoo as Z
from repro.uvm.manager import FaultBatch, Outcomes

SCALE = 0.3


def _eq(a: T.Trace, b: T.Trace) -> bool:
    same = (a.name == b.name and a.n_pages == b.n_pages
            and a.tenant_names == b.tenant_names)
    for f in ("page", "pc", "tb", "kernel"):
        same = same and np.array_equal(getattr(a, f), getattr(b, f))
    if (a.tenant is None) != (b.tenant is None):
        return False
    if a.tenant is not None:
        same = same and np.array_equal(a.tenant, b.tenant)
    return same


def _tuples(tr: T.Trace) -> np.ndarray:
    """Accesses as sortable (page, pc, tb, kernel) rows (multiset compare)."""
    return np.sort(np.stack([tr.page, tr.pc, tr.tb, tr.kernel], 1).view(
        [("p", "i4"), ("c", "i4"), ("t", "i4"), ("k", "i4")]).ravel())


# --- zoo generators ----------------------------------------------------------


@pytest.mark.parametrize("name", sorted(Z.PATTERNS))
def test_zoo_generator_deterministic(name):
    a = Z.PATTERNS[name](scale=SCALE)
    b = Z.PATTERNS[name](scale=SCALE)
    assert _eq(a, b)
    assert not np.array_equal(a.page, Z.PATTERNS[name](scale=SCALE, seed=99).page)


def test_zoo_registries_consistent():
    assert set(Z.CATEGORY) == set(Z.PATTERNS)
    assert not set(Z.PATTERNS) & set(T.BENCHMARKS)  # no shadowing
    assert Z.workload_names() == sorted(T.BENCHMARKS) + sorted(Z.PATTERNS)


def test_get_trace_resolves_suite_and_zoo():
    assert _eq(Z.get_trace("PtrChase", scale=SCALE), Z.pointer_chase(scale=SCALE))
    assert _eq(Z.get_trace("StreamTriad", scale=SCALE), T.get_trace("StreamTriad", scale=SCALE))
    with pytest.raises(KeyError):
        Z.get_trace("NoSuchWorkload")


def test_pointer_chase_walk_covers_every_page():
    tr = Z.pointer_chase(scale=SCALE, passes=1)
    assert len(np.unique(tr.page)) == tr.n_pages  # one full cycle, no repeats


def test_random_scan_fresh_draws_per_kernel():
    tr = Z.random_scan(scale=SCALE, iters=2)
    k0, k1 = tr.page[tr.kernel == 0], tr.page[tr.kernel == 1]
    assert not np.array_equal(k0, k1)  # nothing to memorize across kernels


# --- phase-change traces -----------------------------------------------------


def test_phase_trace_abrupt_segments_bit_exact():
    seg = 600
    phases = ("StreamTriad", "PtrChase", "ATAX")
    tr = Z.phase_trace(phases, scale=SCALE, segment=seg)
    assert tr.name == "drift:StreamTriad>PtrChase>ATAX"
    lo = 0
    for p in phases:
        base = Z.get_trace(p, scale=SCALE)
        n = min(len(base), seg)
        for f in ("page", "pc", "tb", "kernel"):
            assert np.array_equal(getattr(tr, f)[lo:lo + n], getattr(base, f)[:n]), (p, f)
        lo += n
    assert len(tr) == lo
    assert tr.n_pages == max(Z.get_trace(p, scale=SCALE).n_pages for p in phases)


def test_phase_trace_gradual_blend_is_windowed_merge():
    seg, w = 600, 150
    phases = ("StreamTriad", "PtrChase")
    ab = Z.phase_trace(phases, scale=SCALE, segment=seg)
    gr = Z.phase_trace(phases, scale=SCALE, segment=seg, switch="gradual", mix_window=w)
    assert gr.name == "drift:StreamTriad>PtrChase|gradual"
    assert len(gr) == len(ab)
    # outside the blend window the stream is bit-equal to the abrupt splice
    for f in ("page", "pc", "tb", "kernel"):
        assert np.array_equal(getattr(gr, f)[:seg - w], getattr(ab, f)[:seg - w])
        assert np.array_equal(getattr(gr, f)[seg + w:], getattr(ab, f)[seg + w:])
    # the blend permutes whole accesses — the access multiset is conserved
    assert np.array_equal(_tuples(gr), _tuples(ab))
    # and it is a MERGE: each phase's own accesses keep their order
    win = slice(seg - w, seg + w)
    out_tail, in_head = ab.page[seg - w:seg], ab.page[seg:seg + w]
    blended = gr.page[win]
    from_a = blended[gr.pc[win] == ab.pc[seg - 1]] if len(set(ab.pc[win])) > 1 else None
    if from_a is not None:  # pc distinguishes the phases in this pairing
        assert np.array_equal(from_a, out_tail)
        assert np.array_equal(blended[gr.pc[win] != ab.pc[seg - 1]], in_head)
    # the gradual switch is seeded: rebuilding reproduces it bit-exactly
    assert _eq(gr, Z.phase_trace(phases, scale=SCALE, segment=seg,
                                 switch="gradual", mix_window=w))


def test_phase_trace_validation():
    with pytest.raises(ValueError, match="at least two"):
        Z.phase_trace(("StreamTriad",))
    with pytest.raises(ValueError, match="unknown switch"):
        Z.phase_trace(("StreamTriad", "ATAX"), switch="instant")
    with pytest.raises(KeyError):
        Z.phase_trace(("StreamTriad", "NoSuchWorkload"))


# --- tenant churn + the concurrent() starts fix ------------------------------


def test_concurrent_starts_none_bit_identical_to_legacy_zero_starts():
    parts = [T.get_trace(n, scale=SCALE) for n in ("StreamTriad", "Hotspot")]
    legacy = T.concurrent(parts, seed=0, slice_len=256)
    explicit = T.concurrent(parts, seed=0, slice_len=256, starts=[0, 0])
    assert _eq(legacy, explicit)


def _per_tenant_ok(tr: T.Trace, parts):
    """Per-tenant subsequence invariants: order, offsets and tag mapping."""
    offset = 0
    for i, p in enumerate(parts):
        mine = tr.tenant == i
        assert np.array_equal(tr.page[mine], p.page[:mine.sum()] + offset)
        assert np.array_equal(tr.pc[mine], p.pc[:mine.sum()] + 16 * i)
        assert np.array_equal(tr.kernel[mine], p.kernel[:mine.sum()] + 64 * i)
        offset += p.n_pages


def test_concurrent_late_join_is_honored():
    parts = [T.get_trace("StreamTriad", scale=SCALE), T.get_trace("Hotspot", scale=SCALE)]
    tr = T.concurrent(parts, seed=0, slice_len=128, starts=[0, 700])
    _per_tenant_ok(tr, parts)
    assert len(tr) == sum(len(p) for p in parts)  # nobody truncated
    first = np.flatnonzero(tr.tenant == 1)[0]
    assert first >= 700  # tenant 1 admitted only after its join point
    assert np.all(tr.tenant[:first] == 0)


def test_concurrent_early_leave_keeps_schedule_going():
    parts = [T.get_trace("StreamTriad", scale=SCALE).slice(0, 200),
             T.get_trace("Hotspot", scale=SCALE)]
    tr = T.concurrent(parts, seed=0, slice_len=128, starts=[0, 0])
    _per_tenant_ok(tr, parts)
    last0 = np.flatnonzero(tr.tenant == 0)[-1]
    assert last0 < len(tr) - 1  # tenant 0 leaves early, the stream continues
    assert np.all(tr.tenant[last0 + 1:] == 1)


def test_concurrent_all_deferred_jumps_to_earliest_joiner():
    parts = [T.get_trace("StreamTriad", scale=SCALE).slice(0, 300),
             T.get_trace("Hotspot", scale=SCALE).slice(0, 300)]
    # every tenant joins in the future: the clock must jump, not deadlock
    tr = T.concurrent(parts, seed=0, slice_len=128, starts=[5000, 9000])
    _per_tenant_ok(tr, parts)
    assert len(tr) == 600
    assert tr.tenant[0] == 0  # earliest joiner admitted first


def test_concurrent_empty_tenant_keeps_index_reserved():
    parts = [T.get_trace("StreamTriad", scale=SCALE).slice(0, 0),
             T.get_trace("Hotspot", scale=SCALE).slice(0, 256)]
    tr = T.concurrent(parts, seed=0, slice_len=128, starts=[0, 0])
    assert tr.tenant_names == ("StreamTriad", "Hotspot")
    assert np.all(tr.tenant == 1)  # index 0 reserved but absent
    assert len(tr) == 256


def test_concurrent_starts_validation():
    parts = [T.get_trace("StreamTriad", scale=SCALE)]
    with pytest.raises(ValueError, match="starts must align"):
        T.concurrent(parts, starts=[0, 0])


def test_tenant_churn_trace_shape():
    tr = Z.tenant_churn(("StreamTriad", "Hotspot"), scale=SCALE,
                        joins=(0, 500), spans=(0, 600))
    assert tr.name == "churn:StreamTriad+Hotspot"
    assert tr.tenant_names == ("StreamTriad", "Hotspot")
    assert np.flatnonzero(tr.tenant == 1)[0] >= 500  # join honored
    assert (tr.tenant == 1).sum() == 600  # span truncates tenant 1
    parts = [T.get_trace("StreamTriad", scale=SCALE),
             T.get_trace("Hotspot", scale=SCALE).slice(0, 600)]
    _per_tenant_ok(tr, parts)


def test_tenant_churn_auto_staggers_joins():
    tr = Z.tenant_churn(("StreamTriad", "Hotspot"), scale=SCALE)
    total = len(tr)
    first1 = np.flatnonzero(tr.tenant == 1)[0]
    # default stagger: tenant 1 joins mid-stream — at its nominal total//4
    # point, or when every earlier tenant drains first (the clock jump)
    assert first1 >= min(total // 4, (tr.tenant == 0).sum())
    assert tr.tenant[0] == 0
    assert _eq(tr, Z.tenant_churn(("StreamTriad", "Hotspot"), scale=SCALE))


# --- fault-log interchange ---------------------------------------------------


def test_fault_log_roundtrip_untenanted(tmp_path):
    tr = Z.phase_trace(("StreamTriad", "PtrChase"), scale=SCALE, segment=500)
    path = tmp_path / "log.jsonl"
    lines = T.to_fault_log(tr, str(path))
    assert lines == path.read_text().count("\n") - 1  # + the header comment
    head = path.read_text().splitlines()[0]
    assert head.startswith(f"{T._FAULT_LOG_MAGIC} v{T.FAULT_LOG_VERSION} ")
    assert _eq(T.from_fault_log(str(path)), tr)


def test_fault_log_roundtrip_tenanted_file_object():
    tr = Z.tenant_churn(("StreamTriad", "Hotspot"), scale=SCALE, slice_len=100)
    buf = io.StringIO()
    T.to_fault_log(tr, buf, batch=64)
    buf.seek(0)
    back = T.from_fault_log(buf)
    assert _eq(back, tr)
    # batches never straddle a tenant boundary: every data line is one tenant
    buf.seek(0)
    import json
    for line in buf:
        if line.startswith("#"):
            continue
        rec = json.loads(line)
        tags = np.unique(tr.tenant[np.isin(tr.page, rec["pages"])])
        assert rec["tenant"] in tags.tolist()


def test_fault_log_rejects_missing_header():
    with pytest.raises(ValueError, match="not a uvm-fault-log"):
        T.from_fault_log(io.StringIO('{"pages": [1, 2]}\n'))
    with pytest.raises(ValueError, match="not a uvm-fault-log"):
        T.from_fault_log(io.StringIO(""))


def test_fault_log_rejects_unsupported_version():
    with pytest.raises(ValueError, match="unsupported fault-log version"):
        T.from_fault_log(io.StringIO('# uvm-fault-log v999 {}\n{"pages": [1]}\n'))


def test_fault_log_rejects_mixed_tagged_untagged():
    log = ('# uvm-fault-log v1 {"name": "x", "n_pages": 8, "tenant_names": ["a"]}\n'
           '{"pages": [1], "tenant": 0}\n'
           '{"pages": [2]}\n')
    with pytest.raises(ValueError, match="mixed tagged/untagged"):
        T.from_fault_log(io.StringIO(log))


def test_fault_log_drives_run_ours_identically():
    """An exported+reingested churn trace produces the exact counters and
    accuracy of the original (the golden file pins the same pair)."""
    tcfg = TrainConfig(group_size=1024, epochs=2, batch_size=128)
    tr = Z.tenant_churn(("StreamTriad", "Hotspot"), scale=SCALE, slice_len=1024)
    tr = tr.slice(0, min(len(tr), 2048))
    buf = io.StringIO()
    T.to_fault_log(tr, buf)
    buf.seek(0)
    a = R.run_ours(tr, SMOKE, tcfg)
    b = R.run_ours(T.from_fault_log(buf), SMOKE, tcfg)
    assert a.stats == b.stats and a.top1 == b.top1


# --- real-trainer re-classification end to end (satellite: hysteresis) -------


def _concat(parts):
    n_pages = max(p.n_pages for p in parts)
    arrs = [np.concatenate([getattr(p, f) for p in parts]).astype(np.int32)
            for f in ("page", "pc", "tb", "kernel")]
    return T.Trace("seq", *arrs, n_pages)


def test_reclass_hysteresis_end_to_end_real_trainer():
    """The full pipeline (DFA classifier + REAL NN trainer, not the numpy
    stub): a single disagreeing window never flips the active pattern, a
    genuine phase change flips exactly once, and the displaced pattern's
    model entry stays warm — its update count freezes during the foreign
    phase and resumes (not resets) after the switch-back."""
    G = 256
    stream = T.get_trace("StreamTriad", scale=0.6)
    noise = Z.random_scan(scale=0.3)
    # [4 stream windows | 1-window blip | 4 stream | 4 noise | 4 stream]
    tr = _concat([stream.slice(0, 4 * G), noise.slice(0, G),
                  stream.slice(4 * G, 8 * G), noise.slice(G, 5 * G),
                  stream.slice(8 * G, 12 * G)])
    tcfg = TrainConfig(group_size=G, epochs=2, batch_size=128)
    mgr = R.manager_for(tr, SMOKE, tcfg, reclass_interval=G, reclass_hysteresis=2)
    clock, pats, switches, updates = 0, [], [], []
    for lo in range(0, len(tr), G):
        hi = min(lo + G, len(tr))
        act = mgr.observe(FaultBatch(tr.page[lo:hi], pc=tr.pc[lo:hi],
                                     tb=tr.tb[lo:hi], kernel=tr.kernel[lo:hi]))
        clock += hi - lo
        mgr.feedback(Outcomes(was_evicted=np.zeros(hi - lo, bool), fault_count=clock))
        pats.append(act.pattern)
        switches.append(mgr.n_pattern_switches)
        entry = mgr.table.slots.get(mgr.table.slot_of(pats[0]))
        updates.append(0 if entry is None else entry.n_updates)
    # every window re-ran the classifier...
    assert mgr.n_reclassifications == len(pats) == 17
    # ...but the lone blip window (index 4) never flips: the first 9
    # windows (2 stream phases around the blip) keep the seeded pattern
    assert pats[:9] == [pats[0]] * 9 and switches[8] == 0
    # exactly one switch per GENUINE phase change (noise phase + back)
    assert mgr.n_pattern_switches == 2
    away = next(i for i, p in enumerate(pats) if p != pats[0])
    back = next(i for i in range(away, len(pats)) if pats[i] == pats[0])
    assert 9 <= away <= 12 < back  # flips inside the long noise phase only
    assert pats[-1] == pats[0]  # switch-back re-activates the SAME pattern id
    # displaced entry: frozen while the noise pattern is active, then warm —
    # its count RESUMES above the frozen value instead of restarting
    frozen = updates[away - 1]
    assert frozen >= 4  # it genuinely trained through the first phases
    assert all(u == frozen for u in updates[away:back])
    assert updates[-1] > frozen


# --- property bodies (shared by pinned cases and the hypothesis net) ---------


def _check_phase_trace_deterministic(phases, seed, segment, gradual, w):
    """Any phase mix, seed, segment and switch mode rebuilds bit-exactly."""
    kw = dict(scale=SCALE, seed=seed, segment=segment)
    if gradual:
        kw.update(switch="gradual", mix_window=w)
    assert _eq(Z.phase_trace(phases, **kw), Z.phase_trace(phases, **kw))


def _check_gradual_conserves(phases, seed, segment, w):
    """Gradual vs abrupt: same length, same access multiset, bit-equal
    outside every boundary's blend window."""
    ab = Z.phase_trace(phases, scale=SCALE, seed=seed, segment=segment)
    gr = Z.phase_trace(phases, scale=SCALE, seed=seed, segment=segment,
                       switch="gradual", mix_window=w)
    assert len(gr) == len(ab)
    assert np.array_equal(_tuples(gr), _tuples(ab))
    lens = [min(len(Z.get_trace(p, scale=SCALE)), segment) for p in phases]
    bounds = np.cumsum(lens)[:-1]
    untouched = np.ones(len(ab), bool)
    for b in bounds:
        untouched[max(b - w, 0):min(b + w, len(ab))] = False
    assert np.array_equal(gr.page[untouched], ab.page[untouched])


def _check_churn_subsequence(seed, joins, spans, slice_len):
    """Arbitrary joins/spans/slice sizes: per-tenant access order, page
    offsets and pc/kernel namespacing always survive the churn."""
    names = ("StreamTriad", "Hotspot", "ATAX")[:len(joins)]
    tr = Z.tenant_churn(names, scale=SCALE, seed=seed, joins=tuple(joins),
                        spans=tuple(spans[:len(joins)]), slice_len=slice_len)
    parts = []
    for i, nm in enumerate(names):
        p = Z.get_trace(nm, scale=SCALE)
        span = spans[i] if spans[i] else len(p)
        parts.append(p.slice(0, min(len(p), span)))
    _per_tenant_ok(tr, parts)
    assert len(tr) == sum(len(p) for p in parts)


def _check_faultlog_roundtrip(pages, n_tenants, tagged, batch):
    """Arbitrary synthetic traces (tenanted or not, any batch size):
    to_fault_log -> from_fault_log is the identity."""
    n = len(pages)
    rng = np.random.default_rng(0)
    tr = T.Trace(
        "fuzz", np.asarray(pages, np.int32),
        rng.integers(0, 16, n).astype(np.int32),
        rng.integers(0, 8, n).astype(np.int32),
        np.sort(rng.integers(0, 4, n)).astype(np.int32),
        max(pages) + 1,
        tenant=rng.integers(0, n_tenants, n).astype(np.int32) if tagged else None,
        tenant_names=tuple(f"t{i}" for i in range(n_tenants)) if tagged else (),
    )
    buf = io.StringIO()
    T.to_fault_log(tr, buf, batch=batch)
    buf.seek(0)
    assert _eq(T.from_fault_log(buf), tr)


@pytest.mark.parametrize("phases,seed,segment,gradual,w", [
    (("StreamTriad", "RandomScan"), 7, 300, False, 0),
    (("PtrChase", "ATAX", "StridedNoise"), 123456789, 555, True, 64),
    (("RandomScan", "RandomScan"), 0, 90, True, 200),
])
def test_phase_trace_deterministic_pinned(phases, seed, segment, gradual, w):
    _check_phase_trace_deterministic(phases, seed, segment, gradual, w)


@pytest.mark.parametrize("phases,seed,segment,w", [
    (("StreamTriad", "ATAX"), 0, 400, 100),
    (("PtrChase", "StridedNoise", "StreamTriad"), 42, 250, 300),
])
def test_gradual_blend_conserves_pinned(phases, seed, segment, w):
    _check_gradual_conserves(phases, seed, segment, w)


@pytest.mark.parametrize("seed,joins,spans,slice_len", [
    (0, [0, 900], [0, 0, 0], 128),
    (3, [400, 0, 1800], [700, 0, 500], 64),
    (9, [2000, 2000], [100, 100, 0], 512),
])
def test_churn_subsequence_invariant_pinned(seed, joins, spans, slice_len):
    _check_churn_subsequence(seed, joins, spans, slice_len)


@pytest.mark.parametrize("pages,n_tenants,tagged,batch", [
    ([0], 1, False, 1),
    ([5, 5, 5, 9, 0, 4999], 3, True, 2),
    (list(range(50)), 2, True, 32),
])
def test_faultlog_roundtrip_pinned(pages, n_tenants, tagged, batch):
    _check_faultlog_roundtrip(pages, n_tenants, tagged, batch)


# --- hypothesis net ----------------------------------------------------------


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    _POOL = ("StreamTriad", "ATAX", "PtrChase", "StridedNoise", "RandomScan")

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.sampled_from(_POOL), min_size=2, max_size=4),
           st.integers(0, 2 ** 31 - 1), st.integers(50, 700),
           st.booleans(), st.integers(1, 200))
    def test_phase_trace_deterministic_hypothesis(phases, seed, segment, gradual, w):
        _check_phase_trace_deterministic(phases, seed, segment, gradual, w)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.sampled_from(_POOL), min_size=2, max_size=3),
           st.integers(0, 2 ** 31 - 1), st.integers(100, 600), st.integers(1, 300))
    def test_gradual_blend_conserves_accesses_hypothesis(phases, seed, segment, w):
        _check_gradual_conserves(phases, seed, segment, w)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1),
           st.lists(st.integers(0, 2000), min_size=2, max_size=3),
           st.lists(st.integers(0, 1200), min_size=3, max_size=3),
           st.integers(16, 512))
    def test_churn_subsequence_invariant_hypothesis(seed, joins, spans, slice_len):
        _check_churn_subsequence(seed, joins, spans, slice_len)

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(0, 5000), min_size=1, max_size=60),
           st.integers(1, 4), st.booleans(), st.integers(1, 32))
    def test_fault_log_roundtrip_hypothesis(pages, n_tenants, tagged, batch):
        _check_faultlog_roundtrip(pages, n_tenants, tagged, batch)

except ImportError:  # pragma: no cover - tier-1 must collect without hypothesis
    pass
