"""Regenerate (or drift-check) the simulator equivalence goldens
(tests/golden/sim_golden.json).

Run from the repo root:

    PYTHONPATH=src python tests/golden/generate_sim_golden.py            # rewrite
    PYTHONPATH=src python tests/golden/generate_sim_golden.py --check    # CI drift gate

The goldens come from the FROZEN pre-refactor reference scan
(repro.uvm.reference) — never from the fast path the goldens exist to
check. They pin pages_thrashed/faults/migrated_blocks/zero_copy for all 11
benchmarks x {lru, belady, hpe, learned} x {demand, tree} x {1.25, 1.5}
at scale=0.25 / cap=2000 (integer-only simulator state => platform-stable),
plus one Section V-F concurrent multi-workload trace over the same matrix.
`random` is excluded: its draws depend on array padding, which the fast path
is free to change.

``--check`` regenerates every cell in memory from the reference scan and
fails (exit 1) on ANY difference vs the committed JSON, so silent golden
rot (a trace-generator change without a regeneration, a hand-edited file)
cannot survive CI.  ``--traces NAME ...`` restricts the (re)generation to
those trace keys.
"""
import argparse
import json
from pathlib import Path

from repro.uvm import reference as S
from repro.uvm import trace as T

OUT = Path(__file__).parent / "sim_golden.json"

SCALE, CAP = 0.25, 2000
POLICIES = ("lru", "belady", "hpe", "learned")
PREFETCHERS = ("demand", "tree")
OVERSUBS = (1.25, 1.5)


def golden_concurrent_trace() -> T.Trace:
    """The pinned Section V-F cell: a streaming + a regular workload
    interleaved at scheduler-slice granularity (same construction in
    tests/test_sim_equivalence.py)."""
    parts = []
    for name in ("StreamTriad", "Hotspot"):
        tr = T.get_trace(name, scale=SCALE)
        parts.append(tr.slice(0, min(len(tr), CAP)))
    return T.concurrent(parts, seed=0, slice_len=256)


def generate(traces_filter=None, verbose: bool = True) -> dict:
    traces = {}
    for name in T.BENCHMARKS:
        tr = T.get_trace(name, scale=SCALE)
        traces[name] = tr.slice(0, min(len(tr), CAP))
    conc = golden_concurrent_trace()
    traces[f"concurrent:{conc.name}"] = conc
    out = {}
    for name, tr in traces.items():
        if traces_filter is not None and name not in traces_filter:
            continue
        for pol in POLICIES:
            for pf in PREFETCHERS:
                for os_ in OVERSUBS:
                    st = S.run(tr, policy=pol, prefetch=pf, oversubscription=os_).stats
                    out[f"{name}|{pol}|{pf}|{os_}"] = {
                        k: st[k] for k in ("pages_thrashed", "faults", "migrated_blocks", "zero_copy")
                    }
                    if verbose:
                        print(name, pol, pf, os_, out[f"{name}|{pol}|{pf}|{os_}"], flush=True)
    return out


def check(traces_filter=None, path: Path = OUT) -> int:
    committed = json.loads(path.read_text())
    fresh = generate(traces_filter, verbose=False)
    bad = []
    for key, want in fresh.items():
        if key not in committed:
            bad.append(f"missing from committed file: {key}")
        elif committed[key] != want:
            bad.append(f"drifted: {key} ({committed[key]} != {want})")
    if traces_filter is None:
        bad += [f"stale committed cell: {k}" for k in committed if k not in fresh]
    if bad:
        print(f"golden drift in {path}:")
        for b in bad:
            print("  -", b)
        print("regenerate with: PYTHONPATH=src python tests/golden/generate_sim_golden.py")
        return 1
    print(f"golden ok: {len(fresh)} cells bit-identical to {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="regenerate in memory (reference scan) and fail on any diff")
    ap.add_argument("--traces", nargs="*", default=None,
                    help="restrict to these trace keys (default: all)")
    args = ap.parse_args(argv)
    if args.check:
        return check(args.traces)
    out = generate(args.traces)
    if args.traces is not None:
        out = {**json.loads(OUT.read_text()), **out}
    OUT.write_text(json.dumps(out, indent=0, sort_keys=True) + "\n")
    print("wrote", OUT, len(out), "cells")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
