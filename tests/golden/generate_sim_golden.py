"""Regenerate the simulator equivalence goldens (tests/golden/sim_golden.json).

Run from the repo root:

    PYTHONPATH=src python tests/golden/generate_sim_golden.py

The goldens come from the FROZEN pre-refactor reference scan
(repro.uvm.reference) — never from the fast path the goldens exist to
check. They pin pages_thrashed/faults/migrated_blocks/zero_copy for all 11
benchmarks x {lru, belady, hpe, learned} x {demand, tree} x {1.25, 1.5}
at scale=0.25 / cap=2000 (integer-only simulator state => platform-stable),
plus one Section V-F concurrent multi-workload trace over the same matrix.
`random` is excluded: its draws depend on array padding, which the fast path
is free to change.
"""
import json
from pathlib import Path

import numpy as np

from repro.uvm import reference as S
from repro.uvm import trace as T

SCALE, CAP = 0.25, 2000
POLICIES = ("lru", "belady", "hpe", "learned")
PREFETCHERS = ("demand", "tree")
OVERSUBS = (1.25, 1.5)


def golden_concurrent_trace() -> T.Trace:
    """The pinned Section V-F cell: a streaming + a regular workload
    interleaved at scheduler-slice granularity (same construction in
    tests/test_sim_equivalence.py)."""
    parts = []
    for name in ("StreamTriad", "Hotspot"):
        tr = T.get_trace(name, scale=SCALE)
        parts.append(tr.slice(0, min(len(tr), CAP)))
    return T.concurrent(parts, seed=0, slice_len=256)


def main():
    out = {}
    traces = {name: None for name in T.BENCHMARKS}
    for name in T.BENCHMARKS:
        tr = T.get_trace(name, scale=SCALE)
        traces[name] = tr.slice(0, min(len(tr), CAP))
    conc = golden_concurrent_trace()
    traces[f"concurrent:{conc.name}"] = conc
    for name, tr in traces.items():
        for pol in POLICIES:
            for pf in PREFETCHERS:
                for os_ in OVERSUBS:
                    st = S.run(tr, policy=pol, prefetch=pf, oversubscription=os_).stats
                    out[f"{name}|{pol}|{pf}|{os_}"] = {
                        k: st[k] for k in ("pages_thrashed", "faults", "migrated_blocks", "zero_copy")
                    }
                    print(name, pol, pf, os_, out[f"{name}|{pol}|{pf}|{os_}"], flush=True)
    path = Path(__file__).parent / "sim_golden.json"
    path.write_text(json.dumps(out, indent=0, sort_keys=True) + "\n")
    print("wrote", path, len(out), "cells")


if __name__ == "__main__":
    main()
