"""Regenerate (or drift-check) tests/golden/ours_golden.json — the
learned-runtime pins.

One cell per benchmark: `runtime.run_ours` at scale 0.3 / cap 3000 with the
SMOKE predictor and the test-suite TrainConfig, recording the simulator
counters AND the accuracy outputs (top1 / warm_top1 / n_predictions /
n_classes / n_models, floats at full repr precision).  The committed file
is the contract the streaming `OversubscriptionManager` refactor is pinned
against: rebuilding `run_ours` on the manager must NOT move a single
counter or accuracy bit on any benchmark.

PR 5 adds the Section V-F concurrent cells: each tenant pair is pinned
under BOTH treatments — ``|merged`` (one manager over the interleaved
stream, the pre-mux baseline) and ``|mux`` (the `TenantMux` per-tenant
pipelines, including the per-tenant top-1 split).

    PYTHONPATH=src python tests/golden/generate_ours_golden.py            # rewrite
    PYTHONPATH=src python tests/golden/generate_ours_golden.py --check    # CI drift gate
    PYTHONPATH=src python tests/golden/generate_ours_golden.py --check --cells AddVectors

``--check`` regenerates in memory and fails (exit 1) on ANY difference vs
the committed JSON — silent golden rot (a generator/trace change without a
regeneration, or a hand-edited file) cannot survive CI.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime as R
from repro.uvm import trace as T

OUT = Path(__file__).with_name("ours_golden.json")

SCALE, CAP = 0.3, 3000
TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)
#: Section V-F tenant pairs pinned under both treatments (slice_len equals
#: the training group size so each observed batch is one tenant's stream)
CONCURRENT_PAIRS = (("StreamTriad", "Hotspot"), ("ATAX", "Srad-v2"))


def _bench_trace(name: str) -> T.Trace:
    tr = T.get_trace(name, scale=SCALE)
    return tr.slice(0, min(len(tr), CAP))


def _payload(res) -> dict:
    out = {
        "stats": res.stats,
        "top1": res.top1,
        "warm_top1": res.warm_top1,
        "n_predictions": res.n_predictions,
        "n_classes": res.n_classes,
        "n_models": res.n_models,
        "per_group_acc": res.per_group_acc,
    }
    if res.per_tenant_top1 is not None:
        out["per_tenant_top1"] = res.per_tenant_top1
    return out


def cell(name: str) -> dict:
    return _payload(R.run_ours(_bench_trace(name), SMOKE, TCFG))


def concurrent_cell(pair: tuple[str, str], multi_tenant: bool) -> dict:
    tr = T.concurrent([_bench_trace(n) for n in pair], seed=0, slice_len=TCFG.group_size)
    return _payload(R.run_ours(tr, SMOKE, TCFG, multi_tenant=multi_tenant))


def generate(cells: list[str] | None = None) -> dict:
    golden = {}
    for name in T.BENCHMARKS:
        if cells is None or name in cells:
            golden[name] = cell(name)
    for pair in CONCURRENT_PAIRS:
        for label, mt in (("merged", False), ("mux", True)):
            key = f"concurrent:{'+'.join(pair)}|{label}"
            if cells is None or key in cells:
                golden[key] = concurrent_cell(pair, mt)
    return golden


def check(cells: list[str] | None = None, path: Path = OUT) -> int:
    committed = json.loads(path.read_text())
    fresh = generate(cells)
    bad = []
    for key, want in fresh.items():
        if key not in committed:
            bad.append(f"missing from committed file: {key}")
        elif committed[key] != want:
            fields = [f for f in want if committed[key].get(f) != want[f]]
            bad.append(f"drifted: {key} (fields: {fields})")
    if cells is None:
        bad += [f"stale committed cell (generator no longer emits it): {k}"
                for k in committed if k not in fresh]
    if bad:
        print(f"golden drift in {path}:")
        for b in bad:
            print("  -", b)
        print("regenerate with: PYTHONPATH=src python tests/golden/generate_ours_golden.py")
        return 1
    print(f"golden ok: {len(fresh)} cells bit-identical to {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="regenerate in memory and fail on any diff vs the committed JSON")
    ap.add_argument("--cells", nargs="*", default=None,
                    help="restrict to these cell keys (default: all)")
    args = ap.parse_args(argv)
    if args.check:
        return check(args.cells)
    golden = generate(args.cells)
    if args.cells is not None:  # partial regen: merge into the committed file
        golden = {**json.loads(OUT.read_text()), **golden}
    OUT.write_text(json.dumps(golden, indent=1, sort_keys=True) + "\n")
    print(f"wrote {OUT} ({len(golden)} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
