"""Regenerate (or drift-check) tests/golden/ours_golden.json — the
learned-runtime pins.

One cell per benchmark: `runtime.run_ours` at scale 0.3 / cap 3000 with the
SMOKE predictor and the test-suite TrainConfig, recording the simulator
counters AND the accuracy outputs (top1 / warm_top1 / n_predictions /
n_classes / n_models, floats at full repr precision).  The committed file
is the contract the streaming `OversubscriptionManager` refactor is pinned
against: rebuilding `run_ours` on the manager must NOT move a single
counter or accuracy bit on any benchmark.

PR 5 adds the Section V-F concurrent cells: each tenant pair is pinned
under BOTH treatments — ``|merged`` (one manager over the interleaved
stream, the pre-mux baseline) and ``|mux`` (the `TenantMux` per-tenant
pipelines, including the per-tenant top-1 split).

PR 9 adds the budgeted-mux cells: each concurrent pair re-pinned under two
QoS variants — ``|qos`` (percentile stability, asymmetric floors) and
``|qos-gmr`` (GMR stability, even floors with a tilted elastic share) —
recording the per-tenant fairness ledger and the final budgets alongside
the usual counters.  The pre-existing ``|merged``/``|mux`` cells are NOT
touched: budgets-off must stay bit-identical.

PR 7 adds the drifting-workload cells (the zoo): an abrupt phase change
run with periodic re-classification, a gradual (blended-boundary) phase
change, a tenant-churn stream through the mux, and a fault-log round-trip
replay of that churn trace — pinning that `from_fault_log(to_fault_log(t))`
drives `run_ours` to the exact same counters as the original trace.

    PYTHONPATH=src python tests/golden/generate_ours_golden.py            # rewrite
    PYTHONPATH=src python tests/golden/generate_ours_golden.py --check    # CI drift gate
    PYTHONPATH=src python tests/golden/generate_ours_golden.py --check --cells AddVectors

``--check`` regenerates in memory and fails (exit 1) on ANY difference vs
the committed JSON — silent golden rot (a generator/trace change without a
regeneration, or a hand-edited file) cannot survive CI.
"""
from __future__ import annotations

import argparse
import io
import json
from pathlib import Path

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime as R
from repro.uvm import trace as T
from repro.uvm import zoo as Z
from repro.uvm.api import QosSpec, QosTierSpec

OUT = Path(__file__).with_name("ours_golden.json")

SCALE, CAP = 0.3, 3000
TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)
#: Section V-F tenant pairs pinned under both treatments (slice_len equals
#: the training group size so each observed batch is one tenant's stream)
CONCURRENT_PAIRS = (("StreamTriad", "Hotspot"), ("ATAX", "Srad-v2"))


def _bench_trace(name: str) -> T.Trace:
    tr = T.get_trace(name, scale=SCALE)
    return tr.slice(0, min(len(tr), CAP))


def _payload(res) -> dict:
    out = {
        "stats": res.stats,
        "top1": res.top1,
        "warm_top1": res.warm_top1,
        "n_predictions": res.n_predictions,
        "n_classes": res.n_classes,
        "n_models": res.n_models,
        "per_group_acc": res.per_group_acc,
    }
    if res.per_tenant_top1 is not None:
        out["per_tenant_top1"] = res.per_tenant_top1
    if res.budgets is not None:  # budgeted cells only — legacy cells unchanged
        out["budgets"] = res.budgets
        out["per_tenant_stats"] = res.per_tenant_stats
    return out


def cell(name: str) -> dict:
    return _payload(R.run_ours(_bench_trace(name), SMOKE, TCFG))


def concurrent_cell(pair: tuple[str, str], multi_tenant: bool) -> dict:
    tr = T.concurrent([_bench_trace(n) for n in pair], seed=0, slice_len=TCFG.group_size)
    return _payload(R.run_ours(tr, SMOKE, TCFG, multi_tenant=multi_tenant))


#: PR 9 QoS variants per concurrent pair: (spec builder, oversubscription).
#: ``qos`` pins asymmetric floors under the default percentile stability at
#: moderate pressure; ``qos-gmr`` pins even floors with a tilted elastic
#: share under the GMR scorer at heavy pressure (both registered stability
#: kinds run through the gate, and the two cells pin distinct counters).
QOS_VARIANTS = {
    "qos": (lambda pair: QosSpec(tiers=(QosTierSpec(pair[0], floor=0.5, share=1.0),
                                        QosTierSpec(pair[1], floor=0.1, share=1.0))),
            2.5),
    "qos-gmr": (lambda pair: QosSpec(tiers=(QosTierSpec(pair[0], floor=0.25, share=2.0),
                                            QosTierSpec(pair[1], floor=0.25, share=1.0)),
                                     stability="gmr", interval=2),
                5.0),
}


def qos_cell(pair: tuple[str, str], spec: QosSpec, oversub: float) -> dict:
    tr = T.concurrent([_bench_trace(n) for n in pair], seed=0, slice_len=TCFG.group_size)
    return _payload(R.run_ours(tr, SMOKE, TCFG, oversubscription=oversub, qos=spec))


def _churn_trace() -> T.Trace:
    tr = Z.tenant_churn(("StreamTriad", "Hotspot"), scale=SCALE, slice_len=TCFG.group_size)
    return tr.slice(0, min(len(tr), CAP))


def _faultlog_roundtrip(tr: T.Trace) -> T.Trace:
    buf = io.StringIO()
    T.to_fault_log(tr, buf)
    buf.seek(0)
    return T.from_fault_log(buf)


#: PR 7 drifting cells — keyed builders so ``--cells`` partial regeneration
#: works on them like any benchmark cell
DRIFT_CELLS = {
    "drift:StreamTriad>PtrChase|abrupt": lambda: R.run_ours(
        Z.phase_trace(("StreamTriad", "PtrChase"), scale=SCALE, segment=1500),
        SMOKE, TCFG, reclass_interval=256, reclass_hysteresis=2),
    "drift:ATAX>StridedNoise|gradual": lambda: R.run_ours(
        Z.phase_trace(("ATAX", "StridedNoise"), scale=SCALE, segment=1500,
                      switch="gradual", mix_window=200),
        SMOKE, TCFG, reclass_interval=256, reclass_hysteresis=2),
    "churn:StreamTriad+Hotspot|mux": lambda: R.run_ours(_churn_trace(), SMOKE, TCFG),
    "faultlog:churn:StreamTriad+Hotspot|mux": lambda: R.run_ours(
        _faultlog_roundtrip(_churn_trace()), SMOKE, TCFG),
}


def generate(cells: list[str] | None = None) -> dict:
    golden = {}
    for name in T.BENCHMARKS:
        if cells is None or name in cells:
            golden[name] = cell(name)
    for pair in CONCURRENT_PAIRS:
        for label, mt in (("merged", False), ("mux", True)):
            key = f"concurrent:{'+'.join(pair)}|{label}"
            if cells is None or key in cells:
                golden[key] = concurrent_cell(pair, mt)
        for label, (build, oversub) in QOS_VARIANTS.items():
            key = f"concurrent:{'+'.join(pair)}|{label}"
            if cells is None or key in cells:
                golden[key] = qos_cell(pair, build(pair), oversub)
    for key, build in DRIFT_CELLS.items():
        if cells is None or key in cells:
            golden[key] = _payload(build())
    return golden


def check(cells: list[str] | None = None, path: Path = OUT) -> int:
    committed = json.loads(path.read_text())
    fresh = generate(cells)
    bad = []
    for key, want in fresh.items():
        if key not in committed:
            bad.append(f"missing from committed file: {key}")
        elif committed[key] != want:
            fields = [f for f in want if committed[key].get(f) != want[f]]
            bad.append(f"drifted: {key} (fields: {fields})")
    if cells is None:
        bad += [f"stale committed cell (generator no longer emits it): {k}"
                for k in committed if k not in fresh]
    if bad:
        print(f"golden drift in {path}:")
        for b in bad:
            print("  -", b)
        print("regenerate with: PYTHONPATH=src python tests/golden/generate_ours_golden.py")
        return 1
    print(f"golden ok: {len(fresh)} cells bit-identical to {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="regenerate in memory and fail on any diff vs the committed JSON")
    ap.add_argument("--cells", nargs="*", default=None,
                    help="restrict to these cell keys (default: all)")
    args = ap.parse_args(argv)
    if args.check:
        return check(args.cells)
    golden = generate(args.cells)
    if args.cells is not None:  # partial regen: merge into the committed file
        golden = {**json.loads(OUT.read_text()), **golden}
    OUT.write_text(json.dumps(golden, indent=1, sort_keys=True) + "\n")
    print(f"wrote {OUT} ({len(golden)} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
