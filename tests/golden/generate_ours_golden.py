"""Regenerate tests/golden/ours_golden.json — the learned-runtime pins.

One cell per benchmark: `runtime.run_ours` at scale 0.3 / cap 3000 with the
SMOKE predictor and the test-suite TrainConfig, recording the simulator
counters AND the accuracy outputs (top1 / warm_top1 / n_predictions /
n_classes / n_models, floats at full repr precision).  The committed file
is the contract the streaming `OversubscriptionManager` refactor is pinned
against: rebuilding `run_ours` on the manager must NOT move a single
counter or accuracy bit on any benchmark.

    PYTHONPATH=src python tests/golden/generate_ours_golden.py
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime as R
from repro.uvm import trace as T

OUT = Path(__file__).with_name("ours_golden.json")

SCALE, CAP = 0.3, 3000
TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)


def cell(name: str) -> dict:
    tr = T.get_trace(name, scale=SCALE)
    tr = tr.slice(0, min(len(tr), CAP))
    res = R.run_ours(tr, SMOKE, TCFG)
    return {
        "stats": res.stats,
        "top1": res.top1,
        "warm_top1": res.warm_top1,
        "n_predictions": res.n_predictions,
        "n_classes": res.n_classes,
        "n_models": res.n_models,
        "per_group_acc": res.per_group_acc,
    }


def main() -> int:
    golden = {name: cell(name) for name in T.BENCHMARKS}
    OUT.write_text(json.dumps(golden, indent=1, sort_keys=True) + "\n")
    print(f"wrote {OUT} ({len(golden)} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
