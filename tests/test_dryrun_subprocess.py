"""The dry-run machinery end-to-end in a subprocess with 8 placeholder
devices (the full 512-device sweep runs via `python -m repro.launch.dryrun`;
its committed results live in experiments/dryrun/)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


@pytest.mark.parametrize("arch,shape", [("qwen2-0.5b", "decode_32k"), ("mamba2-370m", "long_500k")])
def test_dryrun_small_mesh(arch, shape, tmp_path):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8", PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape,
         "--mesh", "4x2", "--out", str(tmp_path), "--force"],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads((tmp_path / f"{arch}__{shape}__4x2.json").read_text())
    assert rec["status"] == "ok", rec
    rl = rec["roofline"]
    assert rl["compute_s"] > 0 and rl["memory_s"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")


def test_committed_sweep_is_complete():
    """Every (arch x shape) cell has a single-pod AND multi-pod record, and
    non-skipped cells compiled."""
    d = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("sweep not yet generated")
    from repro.configs import ARCHS, SHAPES, cell_supported, get_config

    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                f = d / f"{arch}__{shape}__{mesh}.json"
                assert f.exists(), f"{f} missing"
                rec = json.loads(f.read_text())
                supported, _ = cell_supported(get_config(arch), SHAPES[shape])
                assert rec["status"] == ("ok" if supported else "skipped"), (arch, shape, mesh, rec["status"])
