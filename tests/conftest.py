import os
import sys
from pathlib import Path

# tests run against the source tree (PYTHONPATH=src also works)
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# Smoke tests must see exactly ONE device (the dry-run sets its own flag in a
# subprocess); keep any user XLA_FLAGS but never force a device count here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

# Share the persistent XLA compilation cache with benchmarks/ (same dir as
# benchmarks.common): a test run pre-warms the simulator/predictor compiles,
# so a benchmark run right after starts from warm executables.
try:
    jax.config.update(
        "jax_compilation_cache_dir",
        os.environ.get("REPRO_JAX_CACHE", str(Path.home() / ".cache" / "repro_jax")),
    )
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.3)
except Exception:
    pass
import pytest  # noqa: E402

from repro.configs.base import ShapeConfig  # noqa: E402


@pytest.fixture(scope="session")
def tiny_train_shape():
    return ShapeConfig("tiny_train", 32, 2, "train")


@pytest.fixture(scope="session")
def tiny_prefill_shape():
    return ShapeConfig("tiny_prefill", 32, 2, "prefill")


@pytest.fixture()
def rng():
    return jax.random.key(0)
