import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.thrash_ce import kernel as K
from repro.kernels.thrash_ce import ref as R

SWEEP = [
    (128, 64, 40, 0.5, jnp.float32),
    (256, 128, 128, 0.9, jnp.float32),
    (128, 256, 200, 0.0, jnp.float32),
    (128, 64, 64, 0.5, jnp.bfloat16),
]


@pytest.mark.parametrize("B,V,n_active,mu,dtype", SWEEP)
def test_thrash_ce_fwd_bwd(B, V, n_active, mu, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    logits = jax.random.normal(ks[0], (B, V)).astype(dtype)
    labels = jax.random.randint(ks[1], (B,), 0, n_active, jnp.int32)
    et = jax.random.bernoulli(ks[2], 0.3, (B,))
    f1 = K.thrash_ce(logits, labels, et, n_active, mu, 128, True)
    f2 = R.thrash_ce_ref(logits, labels, et, mu, n_active)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(float(f1), float(f2), atol=tol, rtol=tol)
    g1 = jax.grad(lambda l: K.thrash_ce(l, labels, et, n_active, mu, 128, True))(logits)
    g2 = R.thrash_ce_grad_ref(logits, labels, et, mu, n_active)
    np.testing.assert_allclose(np.asarray(g1, np.float32), np.asarray(g2, np.float32), atol=tol, rtol=tol)


def test_thrash_semantics():
    """mu>0 REDUCES the gradient pull toward an E∪T label (Eq. 2 semantics)."""
    B, V = 64, 32
    logits = jnp.zeros((B, V))
    labels = jnp.full((B,), 3, jnp.int32)
    et = jnp.ones((B,), bool)
    g_mu = jax.grad(lambda l: K.thrash_ce(l, labels, et, V, 0.8, 64, True))(logits)
    g_0 = jax.grad(lambda l: K.thrash_ce(l, labels, et, V, 0.0, 64, True))(logits)
    # gradient that increases p(label) is negative at the label column
    assert float(g_mu[0, 3]) > float(g_0[0, 3])  # weaker pull (less negative)
