"""Equivalence gate for the prediction-frequency-table kernels.

The chain is: Pallas kernel == jnp ref == LoopPredictionFrequencyTable (the
frozen per-block oracle) == the vectorized host table — on conflict-heavy
streams that exercise way eviction, saturation, and first-on-ties argmin.
"""
import numpy as np
import pytest

from repro.core.policy import (
    COUNTER_MAX,
    LoopPredictionFrequencyTable,
    PallasPredictionFrequencyTable,
    PredictionFrequencyTable,
)
from repro.kernels.freq_table import ops, ref

GEOMS = [
    (1024, 16),  # the paper's table
    (8, 4),      # tiny: every set conflicts
    (96, 3),     # non-power-of-two rows/ways
]


def _stream(rng, n_sets, ways, n):
    """Conflict-heavy stream: ~3x more distinct tags than table capacity,
    plus hot repeats so saturating counters actually saturate."""
    cold = rng.integers(0, n_sets * ways * 3, n)
    hot = rng.integers(0, n_sets, n)  # one hot tag per set
    pick = rng.random(n) < 0.3
    return np.where(pick, hot, cold).astype(np.int64)


@pytest.mark.parametrize("n_sets,ways", GEOMS)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_update_lookup_match_loop_oracle(n_sets, ways, use_kernel):
    rng = np.random.default_rng(n_sets)
    b = _stream(rng, n_sets, ways, 4096 if n_sets == 1024 else 600)
    loop = LoopPredictionFrequencyTable(n_sets, ways)
    loop.update(b)
    t, c = ops.freq_update(
        np.full((n_sets, ways), -1, np.int32), np.zeros((n_sets, ways), np.int32),
        b, use_kernel=use_kernel, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(t), loop.tags.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(c), loop.counters)
    q = rng.integers(0, n_sets * ways * 3, 500).astype(np.int64)
    lk = ops.freq_lookup(loop.tags, loop.counters, q, use_kernel=use_kernel, interpret=True)
    np.testing.assert_array_equal(np.asarray(lk), loop.lookup_many(q).astype(np.int32))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_counter_saturation(use_kernel):
    """A 100x-repeated block pins at COUNTER_MAX, exactly like the oracle."""
    b = np.concatenate([np.full(100, 5), np.array([6, 7])]).astype(np.int64)
    loop = LoopPredictionFrequencyTable(8, 4)
    loop.update(b)
    t, c = ops.freq_update(np.full((8, 4), -1, np.int32), np.zeros((8, 4), np.int32),
                           b, use_kernel=use_kernel, interpret=True)
    assert int(np.asarray(c).max()) == COUNTER_MAX
    np.testing.assert_array_equal(np.asarray(t), loop.tags.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(c), loop.counters)


def test_update_is_incremental():
    """Batch boundaries are invisible: many small updates == one big one."""
    rng = np.random.default_rng(7)
    b = _stream(rng, 64, 4, 900)
    one = PallasPredictionFrequencyTable(64, 4)
    one.update(b)
    many = PallasPredictionFrequencyTable(64, 4)
    for chunk in np.array_split(b, 13):
        many.update(chunk)
    np.testing.assert_array_equal(one.tags, many.tags)
    np.testing.assert_array_equal(one.counters, many.counters)


def test_pallas_table_drop_in():
    """The kernelized table is a drop-in for the host table: same state
    after interleaved update/lookup/flush traffic, same dense export, and
    it pickles (the manager snapshots it)."""
    import pickle

    rng = np.random.default_rng(123)
    host = PredictionFrequencyTable()
    pall = PallasPredictionFrequencyTable()
    for _ in range(5):
        b = _stream(rng, 1024, 16, 2000)
        host.update(b)
        pall.update(b)
        q = rng.integers(0, 1024 * 16 * 3, 400)
        np.testing.assert_array_equal(host.lookup_many(q), pall.lookup_many(q))
    np.testing.assert_array_equal(host.tags, pall.tags)
    np.testing.assert_array_equal(host.counters, pall.counters)
    np.testing.assert_array_equal(host.dense(4096), pall.dense(4096))
    host.on_intervals(3)
    pall.on_intervals(3)
    assert host.flushes == pall.flushes == 1
    np.testing.assert_array_equal(host.tags, pall.tags)
    back = pickle.loads(pickle.dumps(pall))
    assert isinstance(back, PallasPredictionFrequencyTable)
    np.testing.assert_array_equal(back.tags, pall.tags)


def test_kernel_ref_agree_on_padding_sentinel():
    """-1 entries are update no-ops (the pow2 padding contract)."""
    t0 = np.full((8, 4), -1, np.int32)
    c0 = np.zeros((8, 4), np.int32)
    b = np.array([3, -1, 3, -1, -1, 11], np.int64)
    tk, ck = ops.freq_update(t0, c0, b, use_kernel=True, interpret=True)
    tr_, cr = ref.freq_update_ref(t0, c0, np.array([3, -1, 3, -1, -1, 11], np.int32))
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(tr_))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    loop = LoopPredictionFrequencyTable(8, 4)
    loop.update(np.array([3, 3, 11]))
    np.testing.assert_array_equal(np.asarray(tk), loop.tags.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(ck), loop.counters)


def test_block_id_domain_guard():
    with pytest.raises(ValueError):
        ops.freq_update(np.full((8, 4), -1, np.int32), np.zeros((8, 4), np.int32),
                        np.array([2**40]), use_kernel=True, interpret=True)
