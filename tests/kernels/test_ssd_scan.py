import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import kernel as K
from repro.kernels.ssd_scan import ref as R

SWEEP = [
    # B, L, H, P, N, chunk, dtype
    (2, 128, 4, 16, 32, 32, jnp.float32),
    (1, 64, 2, 32, 16, 16, jnp.float32),
    (2, 96, 3, 8, 64, 32, jnp.float32),
    (1, 128, 4, 16, 32, 64, jnp.bfloat16),
]


def _inputs(B, L, H, P, N, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))).astype(dtype)
    A_log = (jax.random.normal(ks[2], (H,)) * 0.5).astype(jnp.float32)
    b = jax.random.normal(ks[3], (B, L, N)).astype(dtype)
    c = jax.random.normal(ks[4], (B, L, N)).astype(dtype)
    return x, dt, A_log, b, c


@pytest.mark.parametrize("B,L,H,P,N,chunk,dtype", SWEEP)
def test_ssd_kernel_vs_ref(B, L, H, P, N, chunk, dtype):
    x, dt, A_log, b, c = _inputs(B, L, H, P, N, dtype)
    y1, s1 = K.ssd_pallas(x, dt, A_log, b, c, chunk=chunk, interpret=True)
    y2, s2 = R.ssd_ref(x, dt, A_log, b, c, chunk)
    # bf16 has ~2^-8 relative precision; accumulated over an L=128 chunked
    # scan the kernel-vs-ref drift legitimately exceeds 3e-2 on single
    # elements (seed suite failed here deterministically)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=tol, rtol=tol)


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_chunked_ref_matches_sequential(chunk):
    """State-space duality: any chunking must equal the step recurrence."""
    x, dt, A_log, b, c = _inputs(2, 64, 3, 8, 16, jnp.float32, seed=7)
    y1, s1 = R.ssd_ref(x, dt, A_log, b, c, chunk)
    y2, s2 = R.ssd_sequential(x, dt, A_log, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


def test_initial_state_carry():
    """Prefill-with-carry: splitting a sequence across two calls must match."""
    x, dt, A_log, b, c = _inputs(1, 64, 2, 8, 16, jnp.float32, seed=9)
    y_full, s_full = R.ssd_ref(x, dt, A_log, b, c, 16)
    y1, s1 = R.ssd_ref(x[:, :32], dt[:, :32], A_log, b[:, :32], c[:, :32], 16)
    y2, s2 = R.ssd_ref(x[:, 32:], dt[:, 32:], A_log, b[:, 32:], c[:, 32:], 16, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4, rtol=1e-4)
