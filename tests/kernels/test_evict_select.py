"""Equivalence gate for the packed-priority victim-selection kernel.

Interpret mode on CPU (the CI path): the Pallas program must match both the
pure-jnp oracle and the simulator's own chained masked-argmin loop
(``_lex_argmin`` semantics) bit for bit, including the 4-key QoS
``evict_pref`` geometry with negative preference values.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.evict_select import kernel as K
from repro.kernels.evict_select import ref as R
from repro.uvm import simulator as S

# (n_blocks, n_keys, key_lo, key_hi, n_evict)
SWEEP = [
    (128, 3, 0, 8, 17),        # heavy key ties -> index tiebreak matters
    (128, 4, -4, 4, 31),       # QoS geometry: leading key, negative values
    (256, 1, 0, 2, 64),        # single key, near-degenerate
    (512, 3, -1000, 1000, 5),  # wide keys, few victims
    (96, 2, 0, 3, 200),        # n_evict > candidates: drain and stop
]


def _loop_select(cand, keys, n_evict):
    """The simulator's per-victim loop, inlined as an independent oracle."""
    cand = np.asarray(cand).copy()
    vict = np.zeros_like(cand)
    for _ in range(int(n_evict)):
        if not cand.any():
            break
        v = int(S._lex_argmin(jnp.asarray(cand), *(jnp.asarray(k) for k in keys)))
        cand[v] = False
        vict[v] = True
    return vict


@pytest.mark.parametrize("nb,nk,lo,hi,ne", SWEEP)
def test_evict_select_matches_ref_and_loop(nb, nk, lo, hi, ne):
    rng = np.random.default_rng(nb * 7 + nk)
    cand = rng.random(nb) < 0.6
    keys = tuple(rng.integers(lo, hi + 1, nb).astype(np.int32) for _ in range(nk))
    got = np.asarray(K.evict_select(cand, keys, ne, interpret=True))
    want_ref = np.asarray(R.evict_select_ref(cand, keys, ne))
    want_loop = _loop_select(cand, keys, ne)
    np.testing.assert_array_equal(got, want_ref)
    np.testing.assert_array_equal(got, want_loop)
    assert got.sum() == min(ne, cand.sum())


def test_evict_select_zero_and_empty():
    nb = 64
    keys = (np.zeros(nb, np.int32),)
    assert not np.asarray(K.evict_select(np.ones(nb, bool), keys, 0, interpret=True)).any()
    assert not np.asarray(K.evict_select(np.zeros(nb, bool), keys, 9, interpret=True)).any()


def test_evict_select_vmap_lanes():
    """The simulator calls the kernel under vmap (lane axis -> grid axis)."""
    rng = np.random.default_rng(3)
    lanes, nb = 5, 128
    cand = rng.random((lanes, nb)) < 0.5
    keys = tuple(rng.integers(-3, 9, (lanes, nb)).astype(np.int32) for _ in range(4))
    ne = np.array([0, 3, 11, 64, 200], np.int32)
    batched = jax.vmap(lambda c, k0, k1, k2, k3, n: K.evict_select(
        c, (k0, k1, k2, k3), n, interpret=True))
    got = np.asarray(batched(cand, *keys, ne))
    for i in range(lanes):
        want = np.asarray(R.evict_select_ref(cand[i], tuple(k[i] for k in keys), ne[i]))
        np.testing.assert_array_equal(got[i], want)


def test_key_padding_is_inert():
    """Absent trailing keys pad with zeros — a constant key never changes a
    lexicographic argmin, so 2-key and zero-padded-4-key runs agree."""
    rng = np.random.default_rng(11)
    nb = 128
    cand = rng.random(nb) < 0.7
    k = tuple(rng.integers(0, 5, nb).astype(np.int32) for _ in range(2))
    a = np.asarray(K.evict_select(cand, k, 20, interpret=True))
    b = np.asarray(K.evict_select(cand, k + (np.zeros(nb, np.int32),) * 2, 20, interpret=True))
    np.testing.assert_array_equal(a, b)
