import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops
from repro.kernels.decode_attention.ref import decode_ref

SWEEP = [
    (2, 2, 2, 64, 512, 300, jnp.float32),
    (1, 4, 1, 128, 1024, 1000, jnp.float32),
    (4, 1, 8, 64, 512, None, jnp.float32),
    (2, 2, 2, 64, 512, 77, jnp.bfloat16),
]


@pytest.mark.parametrize("B,KV,G,D,T,kv_len,dtype", SWEEP)
def test_decode_attention_sweep(B, KV, G, D, T, kv_len, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, KV, G, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, KV, D)).astype(dtype)
    out = ops.decode_attention(q, k, v, kv_len=kv_len, interpret=True)
    ref = decode_ref(q, k, v, kv_len=kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_model_layout_passthrough():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (2, 1, 2, 2, 64))  # (B,1,K,G,D) model layout
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    out = ops.decode_attention(q, k, v, kv_len=100, interpret=True)
    assert out.shape == (2, 1, 2, 2, 64)
    ref = decode_ref(q, k, v, kv_len=100)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref), atol=1e-5, rtol=1e-5)
