"""Pallas flash-attention kernel vs pure-jnp oracle: shape/dtype sweep in
interpret mode (CPU executes the kernel body)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import attention_ref

SWEEP = [
    # B, S, KV, G, D, T, causal, kv_len, dtype
    (2, 128, 2, 2, 64, 128, True, None, jnp.float32),
    (1, 128, 1, 4, 128, 256, False, 200, jnp.float32),
    (2, 256, 4, 1, 64, 256, True, 180, jnp.float32),
    (1, 128, 2, 2, 64, 128, True, None, jnp.bfloat16),
    (1, 256, 1, 1, 128, 512, True, None, jnp.float32),
]


@pytest.mark.parametrize("B,S,KV,G,D,T,causal,kv_len,dtype", SWEEP)
def test_flash_attention_sweep(B, S, KV, G, D, T, causal, kv_len, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, KV, D)).astype(dtype)
    out = K.flash_attention(q, k, v, causal=causal, kv_len=kv_len, bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, kv_len=kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_q_offset():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 1, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 1, 64))
    v = jax.random.normal(ks[2], (1, 256, 1, 64))
    out = K.flash_attention(q, k, v, causal=True, q_offset=64, bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)
