"""Device-sharded `run_batch` (ISSUE 2): sweep lanes are split across every
visible device.  XLA device counts are fixed at process start, so the
multi-device run happens in a subprocess with forced host devices (via the
shared `repro.uvm.sweeps` harness); its counters must be bit-identical to
this process's single-device run (the simulator state is integer-only and
lanes are independent)."""
from repro.uvm import simulator as S
from repro.uvm import trace as T
from repro.uvm.sweeps import EQUIV_CELLS, run_batch_forced_devices


def test_sharded_run_batch_matches_single_device():
    tr = T.get_trace("BICG", scale=0.25)
    tr = tr.slice(0, min(len(tr), 1500))
    want = S.run_batch(tr, EQUIV_CELLS)
    got = run_batch_forced_devices("BICG", scale=0.25, cap=1500)
    assert got == want


def test_sharded_kernel_path_matches_single_device_scan():
    """The Pallas victim-selection path composes with lane sharding: a
    forced-4-device subprocess pinned onto REPRO_SIM_KERNELS=1 must be
    bit-identical to this process's single-device SCAN-path sweep (no real
    multi-device hardware here, so forced host devices are the vehicle)."""
    tr = T.get_trace("BICG", scale=0.25)
    tr = tr.slice(0, min(len(tr), 1200))
    want = S.run_batch(tr, EQUIV_CELLS, kernels=False)
    got = run_batch_forced_devices("BICG", scale=0.25, cap=1200, kernels=True)
    assert got == want


def test_lane_shardings_single_device_fallback():
    """In this (single-device) process the helpers must decline to shard."""
    import jax

    from repro.distributed.compat import lane_shardings, lanes_mesh

    if len(jax.devices()) == 1:
        assert lanes_mesh(16) is None
        assert lane_shardings(16) == (None, None)
    # an indivisible lane count must never be sharded
    assert lanes_mesh(7) is None or 7 % len(jax.devices()) == 0
