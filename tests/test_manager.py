"""The streaming OversubscriptionManager API (ISSUE 4 tentpole).

The heavier guarantees pinned here:

* the manager-rebuilt ``runtime.run_ours`` reproduces the pre-refactor
  monolith bit for bit — counters AND accuracy — on ALL 11 benchmarks
  (tests/golden/ours_golden.json, regenerate via
  tests/golden/generate_ours_golden.py);
* the vectorized ``PredictionFrequencyTable`` is exactly the per-block
  loop (way-conflict evictions, insertion order, saturation, flushes);
* ONE manager instance drives both the trace simulator and the serving
  KV-offload path;
* classifiers / frequency-table engines are registry plugins like PR 3's
  policies.
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.core.policy import LoopPredictionFrequencyTable, PredictionFrequencyTable
from repro.uvm import registry as REG
from repro.uvm import runtime as R
from repro.uvm import simulator as S
from repro.uvm import trace as T
from repro.uvm.manager import (
    FaultBatch,
    ManagerConfig,
    OnlineFeatureStream,
    Outcomes,
    OversubscriptionManager,
)

GOLDEN = json.loads((Path(__file__).parent / "golden" / "ours_golden.json").read_text())
SCALE, CAP = 0.3, 3000  # must match tests/golden/generate_ours_golden.py
TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)


def _bench_trace(name: str) -> T.Trace:
    tr = T.get_trace(name, scale=SCALE)
    return tr.slice(0, min(len(tr), CAP))


def _toy_manager(**kw) -> OversubscriptionManager:
    cfg = ManagerConfig(
        predictor=SMOKE, train=TrainConfig(group_size=64, epochs=1, batch_size=32),
        n_pages=1024, n_blocks=64, capacity=16, **kw,
    )
    return OversubscriptionManager(cfg)


# --- vectorized frequency table vs the frozen loop ---------------------------


def test_freq_table_vectorized_equals_loop_conflict_heavy():
    """Tiny geometry (4 sets x 2 ways) forces way-conflict evictions and
    same-set insertion ordering on every batch; interleaved flushes."""
    rng = np.random.default_rng(7)
    vec, loop = PredictionFrequencyTable(4, 2), LoopPredictionFrequencyTable(4, 2)
    for step in range(40):
        blocks = rng.integers(0, 24, size=rng.integers(0, 60))
        vec.update(blocks)
        loop.update(blocks)
        if step % 5 == 4:
            vec.on_intervals(2)
            loop.on_intervals(2)
        assert np.array_equal(vec.tags, loop.tags), step
        assert np.array_equal(vec.counters, loop.counters), step
    probe = rng.integers(0, 30, 64)
    assert np.array_equal(vec.lookup_many(probe), np.array([loop.lookup(int(b)) for b in probe]))
    assert np.array_equal(vec.dense(64), loop.dense(64))
    assert vec.flushes == loop.flushes > 0


def test_freq_table_saturation_and_paper_geometry():
    """6-bit saturation at the paper's 1024x16 geometry: one hot block
    pushed past COUNTER_MAX, batched vs loop."""
    from repro.core.policy import COUNTER_MAX

    vec, loop = PredictionFrequencyTable(), LoopPredictionFrequencyTable()
    hot = np.full(200, 5, np.int64)  # 200 touches of one block in one batch
    vec.update(hot)
    loop.update(hot)
    assert vec.lookup(5) == loop.lookup(5) == COUNTER_MAX
    assert np.array_equal(vec.tags, loop.tags) and np.array_equal(vec.counters, loop.counters)


# --- manager vs the committed run_ours goldens -------------------------------


@pytest.mark.parametrize("name", sorted(T.BENCHMARKS))
def test_run_ours_bit_identical_to_golden(name):
    """The manager-rebuilt driver must not move a single counter or
    accuracy bit vs the pre-refactor monolith, on any benchmark."""
    res = R.run_ours(_bench_trace(name), SMOKE, TCFG)
    g = GOLDEN[name]
    assert res.stats == g["stats"]
    assert res.top1 == g["top1"]
    assert res.warm_top1 == g["warm_top1"]
    assert res.per_group_acc == g["per_group_acc"]
    assert res.n_predictions == g["n_predictions"]
    assert res.n_classes == g["n_classes"]
    assert res.n_models == g["n_models"]


def test_online_stream_matches_feature_stream():
    """Appending a trace batch-by-batch yields byte-identical window
    samples to the whole-trace FeatureStream."""
    import dataclasses

    from repro.core.features import DeltaVocab, FeatureStream

    tr = _bench_trace("ATAX")
    ref_vocab, on_vocab = DeltaVocab(SMOKE.delta_vocab), DeltaVocab(SMOKE.delta_vocab)
    ref = FeatureStream(tr, ref_vocab, SMOKE.history, page_vocab=SMOKE.page_vocab,
                        pc_vocab=SMOKE.pc_vocab, tb_vocab=SMOKE.tb_vocab)
    on = OnlineFeatureStream(on_vocab, SMOKE.history, page_vocab=SMOKE.page_vocab,
                             pc_vocab=SMOKE.pc_vocab, tb_vocab=SMOKE.tb_vocab)
    for g0 in range(0, len(tr), 700):  # batch size coprime to the group size
        g1 = min(g0 + 700, len(tr))
        span = on.append(tr.page[g0:g1], tr.pc[g0:g1], tr.tb[g0:g1])
        assert span == (g0, g1)
        a, b = ref.windows(g0, g1), on.windows(g0, g1)
        for f in dataclasses.fields(a):
            va, vb = getattr(a, f.name), getattr(b, f.name)
            assert va.dtype == vb.dtype and np.array_equal(va, vb), f.name
        assert np.array_equal(on.page_at(b.t_index - 1), tr.page[b.t_index - 1])
        # retention is bounded: only history + current batch stay resident
        assert len(on._page) <= SMOKE.history + (g1 - g0)
    assert ref_vocab.table == on_vocab.table
    assert len(on) == len(tr)
    with pytest.raises(IndexError):
        on.windows(0, 700)  # the first batch's span slid out of retention


def test_interval_constant_matches_simulator():
    """INTERVAL_FAULTS is a deliberate literal (the manager stays importable
    without the simulator) — this pin is what keeps the two cadences from
    silently drifting apart."""
    from repro.uvm.manager import INTERVAL_FAULTS

    assert INTERVAL_FAULTS == S.INTERVAL


def test_fault_clock_rebases_on_consumer_switch():
    """A warm manager handed to a consumer whose fault clock restarts at 0
    must keep its flush/chain intervals advancing (not stall forever)."""
    mgr = _toy_manager()
    mgr.observe(FaultBatch(np.arange(32)))
    mgr.feedback(Outcomes(fault_count=10 * 64))  # consumer 1: 10 intervals
    assert mgr._flush_interval == 10
    mgr.observe(FaultBatch(np.arange(32)))
    mgr.feedback(Outcomes(fault_count=3 * 64))  # consumer 2 restarted at 0
    assert mgr._flush_interval == 13  # 10 (re-based) + 3, not stalled at 10


def test_manager_misuse_raises():
    mgr = _toy_manager()
    with pytest.raises(RuntimeError):
        mgr.feedback(Outcomes())
    mgr.observe(FaultBatch(np.arange(32)))
    with pytest.raises(RuntimeError):
        mgr.observe(FaultBatch(np.arange(32)))
    with pytest.raises(ValueError):
        mgr.feedback(Outcomes(was_evicted=np.zeros(3, bool), fault_count=0))  # misaligned
    mgr.feedback(Outcomes(was_evicted=np.zeros(32, bool), fault_count=0))
    assert mgr.n_predictions > 0


def test_actions_surface_and_flush_cadence():
    """A predictable stream warms the gate (prefetches flow), the advisory
    pre-evict ranking stays within the observed blocks, and reported fault
    counts drive the 3-interval flush."""
    mgr = _toy_manager()
    ppb = mgr.cfg.pages_per_block
    warmed = False
    for step in range(8):
        pages = (np.arange(64) + step * 16) % 1024
        a = mgr.observe(FaultBatch(pages))
        assert a.n_samples > 0
        if a.counters is not None:
            warmed = True
            assert a.counters.shape == (mgr.cfg.n_blocks,)
            assert all(b < mgr.cfg.n_blocks for b in a.prefetch_blocks)
        assert set(np.asarray(a.pre_evict_blocks).tolist()) <= set(range(mgr.cfg.n_blocks))
        mgr.feedback(Outcomes(was_evicted=np.zeros(64, bool), fault_count=64 * (step + 1)))
    assert warmed
    assert mgr.freq_table.flushes >= 1  # 8 intervals reported -> >=2 flushes at cadence 3
    assert mgr.top1 > 0


# --- one manager instance, two consumers -------------------------------------


def test_same_manager_instance_drives_simulator_and_serving():
    """The acceptance pin: ONE OversubscriptionManager drives a trace
    through the simulator, then — same instance, learned state intact —
    decides KV-page residency for the serving offload path."""
    from repro.serving.offload import LearnedOffloadManager

    tr = _bench_trace("Hotspot")
    mgr = R.manager_for(tr, SMOKE, TCFG)

    # phase 1: the trace simulator driver
    res = R.run_ours(tr, SMOKE, TCFG, manager=mgr)
    assert res.stats == GOLDEN["Hotspot"]["stats"]  # externally-built == internal
    n_updates_after_sim = sum(e.n_updates for e in mgr.table.slots.values())
    assert n_updates_after_sim > 0

    # phase 2: the serving KV-offload adapter, SAME manager instance
    kv_pages = mgr.cfg.n_pages // mgr.cfg.pages_per_block
    off = LearnedOffloadManager(kv_pages, max(kv_pages // 4, 1), manager=mgr, group=32)
    rng = np.random.default_rng(0)
    for step in range(120):
        mass = np.zeros(kv_pages)
        touched = np.unique(rng.integers(0, kv_pages, 8))
        mass[touched] = 1.0
        off.on_attention(mass, touched)
    st = off.stats
    assert st.hbm_hits + st.hbm_misses > 0
    assert off.last_actions is not None  # the manager actually produced actions
    # the predictor kept fine-tuning on the serving stream
    assert sum(e.n_updates for e in mgr.table.slots.values()) > n_updates_after_sim


def test_offload_adapter_block_unit_is_kv_page():
    """With a block-granular shared manager (pages_per_block=16), the
    adapter's scaled observations must keep the manager's block unit ==
    the KV page id: emitted prefetches and frequency counters come back in
    KV-page units (the regression was reading dense[p // 16])."""
    from repro.serving.offload import LearnedOffloadManager

    kv_pages = 64
    cfg = ManagerConfig(
        predictor=SMOKE, train=TrainConfig(group_size=32, epochs=1, batch_size=16),
        n_pages=kv_pages * 16, n_blocks=kv_pages, capacity=16, pages_per_block=16,
    )
    mgr = OversubscriptionManager(cfg)
    off = LearnedOffloadManager(kv_pages, 16, manager=mgr, group=32)
    prefetched = []
    for step in range(200):
        touched = (np.arange(4) + step * 2) % kv_pages  # predictable stream
        mass = np.zeros(kv_pages)
        mass[touched] = 1.0
        off.on_attention(mass, touched)
        if off.last_actions is not None:
            prefetched += np.asarray(off.last_actions.prefetch_blocks).tolist()
    assert prefetched and max(prefetched) < kv_pages  # actions are kv pages
    tags = mgr.freq_table.tags[mgr.freq_table.tags >= 0]
    assert tags.size == 0 or tags.max() < kv_pages  # counters keyed by kv page
    assert np.array_equal(off._freq_dense(), mgr.freq_table.dense(kv_pages))
    with pytest.raises(ValueError):  # a manager too small for the pool is rejected
        LearnedOffloadManager(kv_pages * 2, 16, manager=OversubscriptionManager(cfg))


def test_learned_offload_manager_decision_stream():
    """Decision-stream smoke: the manager-backed offload manager surfaces
    the same stats dict the LRU/attention managers do, with sane values."""
    import dataclasses

    from repro.serving.offload import LearnedOffloadManager

    rng = np.random.default_rng(1)
    n_pages, cap = 48, 12
    mgr = LearnedOffloadManager(n_pages, cap, group=32)
    hot = np.arange(6)
    for _ in range(200):
        mass = np.zeros(n_pages)
        mass[hot] = 1.0
        cold = rng.integers(6, n_pages, 3)
        mass[cold] = 0.2
        mgr.on_attention(mass, np.concatenate([hot, cold]))
    st = dataclasses.asdict(mgr.stats)
    assert set(st) == {"hbm_hits", "hbm_misses", "prefetches", "evictions", "thrash"}
    assert st["hbm_hits"] + st["hbm_misses"] == 200 * 9
    assert mgr.stats.hit_rate > 0.4
    assert mgr.manager.n_predictions > 0 and mgr.manager.n_models >= 1


def test_session_manager_is_the_ours_stack(tmp_path):
    """Session.manager() hands out the same configured object an `ours`
    cell drives: replaying the workload through it reproduces the golden."""
    from repro.uvm.api import ModelSpec, RunStore, Session, TrainSpec

    s = Session(scale=SCALE, cap=CAP, model=ModelSpec(predictor=SMOKE, train=TrainSpec(
        group_size=TCFG.group_size, epochs=TCFG.epochs, batch_size=TCFG.batch_size,
    )), store=RunStore(tmp_path / "runs"))
    mgr = s.manager("ATAX")
    assert isinstance(mgr, OversubscriptionManager)
    # tcfg deliberately omitted: the driver must batch by the MANAGER's
    # configured group size, not this call's TrainConfig() default
    res = R.run_ours(s.trace("ATAX"), manager=mgr)
    assert res.stats == GOLDEN["ATAX"]["stats"]
    assert res.top1 == GOLDEN["ATAX"]["top1"]


# --- component registries ----------------------------------------------------


def test_classifier_and_freq_table_are_plugins():
    """An alternative classifier/engine is a ~20-line registration, like
    PR 3's policies; builtin names stay claimed."""
    assert "dfa" in REG.classifier_names() and "setassoc" in REG.freq_table_names()
    with pytest.raises(ValueError):
        REG.register_classifier("dfa", lambda: None)
    with pytest.raises(ValueError):
        REG.register_freq_table("setassoc", lambda: None)

    class _ConstantClassifier:
        def classify(self, blocks, kernels):
            return 0

        def reset(self):
            pass

    class _DictFreqTable:
        """Unbounded exact counting — no set-associative conflicts."""

        def __init__(self):
            self.counts = {}
            self.flushes = 0

        def update(self, blocks):
            for b in np.asarray(blocks, np.int64):
                self.counts[int(b)] = self.counts.get(int(b), 0) + 1

        def lookup_many(self, blocks):
            return np.array([self.counts.get(int(b), -1) for b in blocks], np.int64)

        def dense(self, n_blocks):
            out = np.full(n_blocks, -1, np.int32)
            for b, c in self.counts.items():
                if b < n_blocks:
                    out[b] = c
            return out

        def on_intervals(self, n):
            self.counts.clear()
            self.flushes += 1

    with REG.scoped():
        REG.register_classifier("constant", _ConstantClassifier)
        REG.register_freq_table("dict", _DictFreqTable)
        mgr = _toy_manager(classifier="constant", freq_table="dict")
        assert isinstance(mgr.freq_table, _DictFreqTable)
        for step in range(6):
            a = mgr.observe(FaultBatch((np.arange(64) + step * 8) % 1024))
            assert a.pattern == 0  # the constant classifier decided
            mgr.feedback(Outcomes(fault_count=0))
        assert mgr.n_predictions > 0
    assert "constant" not in REG.classifier_names()  # scoped() restored
    assert "dict" not in REG.freq_table_names()


def test_unknown_component_raises():
    with pytest.raises(KeyError):
        _toy_manager(classifier="nope")
    with pytest.raises(KeyError):
        _toy_manager(freq_table="nope")


# --- the serve sidecar -------------------------------------------------------


def test_cli_serve_jsonl_roundtrip(tmp_path, capsys):
    from repro.uvm import cli

    stream = tmp_path / "faults.jsonl"
    lines = []
    for b in range(4):
        pages = [(i + b * 5) % 300 for i in range(40)]
        lines.append(json.dumps({"pages": pages}))
        if b % 2 == 0:  # odd batches auto-close (no feedback line)
            lines.append(json.dumps({"feedback": {"was_evicted": [False] * 40, "fault_count": 64 * (b + 1)}}))
    stream.write_text("\n".join(lines) + "\n")
    assert cli.main(["serve", "--input", str(stream), "--n-pages", "300",
                     "--pages-per-block", "4", "--capacity", "16", "--group-size", "32"]) == 0
    out = capsys.readouterr().out.strip().splitlines()
    actions = [json.loads(l) for l in out if l.startswith("{")]
    assert len(actions) == 4
    for a in actions:
        assert {"batch", "pattern", "n_samples", "accuracy", "warm",
                "prefetch_blocks", "pre_evict_blocks"} <= set(a)
        assert all(isinstance(b, int) and 0 <= b < 75 for b in a["prefetch_blocks"])
    assert out[-1].startswith("# serve batches=4")


# --- hypothesis net ----------------------------------------------------------


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        batches=st.lists(
            st.lists(st.integers(0, 47), min_size=0, max_size=80), min_size=1, max_size=6
        ),
        n_sets=st.sampled_from([2, 4, 8]),
        ways=st.sampled_from([1, 2, 3]),
        flush_every=st.integers(1, 3),
    )
    def test_freq_table_equality_hypothesis(batches, n_sets, ways, flush_every):
        """Vectorized vs loop on arbitrary block streams: small geometries
        maximise way conflicts; interval flushes interleave with updates."""
        vec, loop = PredictionFrequencyTable(n_sets, ways), LoopPredictionFrequencyTable(n_sets, ways)
        for i, blocks in enumerate(batches):
            vec.update(np.asarray(blocks, np.int64))
            loop.update(np.asarray(blocks, np.int64))
            if (i + 1) % flush_every == 0:
                vec.on_intervals(1)
                loop.on_intervals(1)
            assert np.array_equal(vec.tags, loop.tags)
            assert np.array_equal(vec.counters, loop.counters)
        assert np.array_equal(vec.dense(48), loop.dense(48))

except ImportError:  # pragma: no cover - tier-1 must collect without hypothesis
    pass
