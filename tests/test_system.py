"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig, run_protocol
from repro.uvm import runtime as R
from repro.uvm import simulator as S
from repro.uvm import timing
from repro.uvm import trace as T
from repro.uvm.uvmsmart import run_uvmsmart

TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)


@pytest.fixture(scope="module")
def hotspot():
    return T.get_trace("Hotspot", scale=0.3).slice(0, 5000)


def test_offline_beats_online(hotspot):
    """Fig. 4's core claim: future-knowledge (offline) training upper-bounds
    strictly-causal online training."""
    online = run_protocol(hotspot, SMOKE, TCFG, mode="online_single")
    offline = run_protocol(hotspot, SMOKE, TCFG, mode="offline")
    assert offline.top1 > online.top1
    assert offline.top1 > 0.5


def test_ours_reduces_thrashing_vs_baseline(hotspot):
    base = S.run(hotspot, policy="lru", prefetch="tree")
    ours = R.run_ours(hotspot, SMOKE, TCFG)
    assert base.pages_thrashed > 0
    assert ours.stats["pages_thrashed"] < 0.5 * base.pages_thrashed  # paper: -64.4% avg
    assert ours.top1 > 0.3


def test_predictor_learns_synthetic_period():
    """A strictly periodic delta stream must be near-perfectly predictable."""
    n = 3000
    pages = np.cumsum(np.tile([1, 2, 3, 4], n // 4)).astype(np.int32) % 4096
    tr = T.Trace("periodic", pages, np.zeros(n, np.int32), np.zeros(n, np.int32), np.zeros(n, np.int32), 4096)
    res = run_protocol(tr, SMOKE, TCFG, mode="online_single")
    # strictly-causal protocol: the first group is predicted by an untrained
    # model, so assert convergence rather than the cold-start average
    assert res.per_group[-1] > 0.9
    assert res.top1 > 0.5


def test_uvmsmart_and_ipc_ordering(hotspot):
    base = S.run(hotspot, policy="lru", prefetch="tree")
    smart = run_uvmsmart(hotspot)
    ours = R.run_ours(hotspot, SMOKE, TCFG)
    n = len(hotspot)
    ipc_base = timing.ipc(base.stats, n)
    ipc_ours = ours.ipc(pred_overhead_us=1.0, n_accesses=n)
    # Fig. 14 directionally: ours beats the baseline at 1us overhead
    assert ipc_ours > ipc_base
    # Fig. 13: IPC decays monotonically with prediction overhead
    ipcs = [ours.ipc(pred_overhead_us=u, n_accesses=n) for u in (1, 10, 20, 50, 100)]
    assert all(a >= b for a, b in zip(ipcs, ipcs[1:]))
    assert smart["pages_thrashed"] >= 0


def test_crash_benchmarks_survive_at_150():
    """Section V-D: at 150% some UVMSmart benchmarks 'crash' (thrash storm);
    ours keeps thrash bounded on the same trace."""
    tr = T.get_trace("ATAX", scale=0.6)
    base = S.run(tr, policy="lru", prefetch="tree", oversubscription=1.5)
    ours = R.run_ours(tr, SMOKE, TCFG, oversubscription=1.5)
    assert ours.stats["pages_thrashed"] <= base.pages_thrashed


def test_run_ours_many_matches_serial(hotspot):
    """The cross-benchmark vmapped engine runs each lane with its own model
    table / freq table / simulator state, so its results must match running
    each trace alone (integer simulator counters are scheduling-invariant;
    the vmapped predictor reproduced serial floats exactly on CPU).  Four
    lanes, so the >=MIN_VMAP_LANES vmapped evaluate/train/simulate branches
    actually engage rather than the small-group serial fallbacks."""
    traces = [
        hotspot,
        T.get_trace("ATAX", scale=0.3).slice(0, 3000),
        T.get_trace("Srad-v2", scale=0.3).slice(0, 3000),
        T.get_trace("StreamTriad", scale=0.3).slice(0, 3000),
    ]
    serial = [R.run_ours(tr, SMOKE, TCFG) for tr in traces]
    batched = R.run_ours_many(traces, SMOKE, TCFG)
    for s, b in zip(serial, batched):
        assert b.stats == s.stats
        assert b.n_predictions == s.n_predictions
        assert abs(b.top1 - s.top1) < 1e-6


def test_serving_offload_learned_beats_lru():
    """The paper's policy engine applied to KV pages: on a skewed attention
    pattern, learned residency must hit at least as often as LRU."""
    from repro.serving.offload import KVOffloadManager, LRUOffloadManager

    rng = np.random.default_rng(0)
    n_pages, cap, steps = 64, 16, 400
    hot = np.arange(8)  # pages attended every step

    def drive(mgr):
        for t in range(steps):
            mass = np.zeros(n_pages)
            mass[hot] = 1.0
            cold = rng.integers(8, n_pages, 4)
            mass[cold] = 0.2
            touched = np.concatenate([hot, cold])
            mgr.on_attention(mass, touched)
        return mgr.stats

    learned = drive(KVOffloadManager(n_pages, cap))
    lru = drive(LRUOffloadManager(n_pages, cap))
    assert learned.hit_rate >= lru.hit_rate
    assert learned.hit_rate > 0.6
