"""Serving stack: paged KV correctness, engine greedy decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.kv_cache import PAGE_TOKENS, PagedKV


def test_paged_kv_roundtrip():
    kv = PagedKV.create(n_layers=2, n_pages=8, kv_heads=2, head_dim=4, batch=2, max_pages=4)
    L, K, D = 2, 2, 4
    toks = []
    for t in range(PAGE_TOKENS + 3):  # crosses a page boundary
        lk = jnp.full((L, K, D), float(t))
        kv.append_token(0, lk, lk + 100)
        toks.append(t)
    k, v = kv.gather(0, PAGE_TOKENS + 3)
    assert k.shape == (L, PAGE_TOKENS + 3, K, D)
    np.testing.assert_allclose(np.asarray(k[0, :, 0, 0]), np.arange(PAGE_TOKENS + 3))
    np.testing.assert_allclose(np.asarray(v[0, :, 0, 0]), np.arange(PAGE_TOKENS + 3) + 100)
    assert kv.seq_lens[0] == PAGE_TOKENS + 3
    assert (kv.block_table[0, :2] >= 0).all()


def test_engine_greedy_matches_forward():
    """Engine decode must reproduce the argmax chain of teacher-forced
    forward passes (dense family)."""
    cfg = get_smoke_config("qwen2-0.5b")
    params = lm.init(jax.random.key(0), cfg, max_seq=64)
    prompt = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size, jnp.int32)
    eng = Engine(cfg, params)
    res = eng.generate({"tokens": prompt}, n_new=6, pad_to=20)

    # reference: iterative full forward
    toks = prompt
    ref = []
    for _ in range(6):
        logits, _ = lm.forward(params, {"tokens": toks}, cfg)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], 1)
    np.testing.assert_array_equal(res.tokens, np.stack(ref, 1))


import pytest


@pytest.mark.parametrize("offload", ["learned", "manager"])
def test_engine_offload_stats_surface(offload):
    """Every offload kind — attention-EMA ('learned') and the streaming
    OversubscriptionManager ('manager') — reports the same decision-stream
    surface through the engine."""
    cfg = get_smoke_config("qwen3-0.6b")
    params = lm.init(jax.random.key(2), cfg, max_seq=96)
    prompt = jax.random.randint(jax.random.key(3), (1, 70), 0, cfg.vocab_size, jnp.int32)
    eng = Engine(cfg, params, offload=offload, hbm_fraction=0.5)
    res = eng.generate({"tokens": prompt}, n_new=8, pad_to=96)
    s = res.offload_stats
    assert s is not None and s["hbm_hits"] + s["hbm_misses"] > 0
    assert set(s) == {"hbm_hits", "hbm_misses", "prefetches", "evictions", "thrash"}
