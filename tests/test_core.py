"""Paper-core units: losses (Eqs. 2-3), DFA pattern classifier, model table,
prediction-frequency table, feature extraction."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, pattern
from repro.core.features import DeltaVocab, FeatureStream, extract
from repro.core.model_table import ModelTable
from repro.core.policy import COUNTER_MAX, PredictionFrequencyTable
from repro.uvm import trace as T


# --- losses ------------------------------------------------------------------

def test_thrash_term_is_negative_ce():
    logits = jax.random.normal(jax.random.key(0), (16, 8))
    labels = jnp.arange(16) % 8
    et = jnp.ones(16, bool)
    nll = losses.ce(logits, labels, 8)
    th = losses.thrash_term(logits, labels, et, 8)
    np.testing.assert_allclose(float(th), -float(nll.mean()), rtol=1e-6)


def test_lucir_zero_for_identical_features():
    f = jax.random.normal(jax.random.key(1), (4, 16))
    assert float(losses.lucir_distill(f, f).mean()) < 1e-6
    g = -f  # opposite direction -> distance 2
    np.testing.assert_allclose(float(losses.lucir_distill(g, f).mean()), 2.0, rtol=1e-5)


def test_total_loss_composition():
    logits = jax.random.normal(jax.random.key(2), (8, 6))
    labels = jnp.zeros(8, jnp.int32)
    f = jax.random.normal(jax.random.key(3), (8, 4))
    et = jnp.zeros(8, bool)
    base, m0 = losses.total_loss(logits, f, labels, n_active=6)
    full, m1 = losses.total_loss(logits, f, labels, n_active=6, f_old=f, in_et=et, lam=0.7, mu=0.3)
    # identical features + empty S => same value
    np.testing.assert_allclose(float(base), float(full), atol=1e-5)


def test_thrash_term_reduces_et_probability():
    """One SGD step with mu>0 lowers p(label) for E∪T samples vs mu=0."""
    rng = jax.random.key(4)
    logits_w = jax.random.normal(rng, (12, 6)) * 0.1  # learnable "logits" directly
    labels = jnp.full((12,), 2, jnp.int32)
    et = jnp.ones((12,), bool)

    def prob_after(mu):
        def loss(lw):
            l, _ = losses.total_loss(lw, jnp.ones((12, 4)), labels, n_active=6, in_et=et, mu=mu)
            return l

        g = jax.grad(loss)(logits_w)
        new = logits_w - 0.5 * g
        return float(jax.nn.softmax(new, -1)[:, 2].mean())

    assert prob_after(0.9) < prob_after(0.0)


# --- pattern classifier --------------------------------------------------------

def test_pattern_classes():
    c = pattern.PatternClassifier()
    lin = np.arange(100)
    assert c.classify(lin, np.zeros(100)) == pattern.LINEAR
    c.reset()
    rnd = np.random.default_rng(0).integers(0, 1000, 100)
    assert c.classify(rnd, np.zeros(100)) in (pattern.RANDOM, pattern.MIXED)
    c.reset()
    # re-reference across kernel boundaries -> reuse class
    blocks = np.concatenate([np.arange(50), np.arange(50)])
    kernels = np.concatenate([np.zeros(50), np.ones(50)])
    cls = c.classify(blocks[:50], kernels[:50])
    cls2 = c.classify(blocks[50:], kernels[50:])
    assert cls2 >= 3  # reuse variant


def test_benchmark_categories_match_published():
    c = pattern.PatternClassifier()
    tr = T.get_trace("StreamTriad", scale=0.3)
    assert c.classify(tr.block, tr.kernel) == pattern.LINEAR
    c.reset()
    tr = T.get_trace("Hotspot", scale=0.2)
    cls = c.classify(tr.block, tr.kernel)
    assert cls >= 3  # reuse (regular)


# --- model table -----------------------------------------------------------------

def test_model_table_direct_mapped():
    table = ModelTable(lambda s: {"w": jnp.full((2,), float(s))}, n_slots=4)
    e0 = table.get(0)
    e0b = table.get(0)
    assert e0 is e0b and table.hits == 1 and table.misses == 1
    table.snapshot_prev(0)
    assert table.get(0).prev_params is not None
    assert table.footprint_bytes() == 2 * 4 * 2  # params + prev snapshot


# --- prediction frequency table ---------------------------------------------------

def test_freq_table_counts_and_flush():
    t = PredictionFrequencyTable(n_sets=16, ways=2)
    t.update(np.array([5, 5, 5, 7]))
    assert t.lookup(5) == 3 and t.lookup(7) == 1 and t.lookup(9) == -1
    dense = t.dense(16)
    assert dense[5] == 3 and dense[9] == -1
    t.on_intervals(3)  # flush cadence
    assert t.lookup(5) == -1 and t.flushes == 1


def test_freq_table_saturation_and_conflict():
    t = PredictionFrequencyTable(n_sets=4, ways=1)
    t.update(np.full(100, 3))
    assert t.lookup(3) == COUNTER_MAX
    t.update(np.array([7]))  # 7 % 4 == 3 % 4 -> evicts the way
    assert t.lookup(3) == -1 and t.lookup(7) == 1


def test_storage_matches_paper():
    t = PredictionFrequencyTable()
    assert t.storage_bits() == (6 * 16 + 48) * 1024  # == 18KB (Section IV-E)


# --- features --------------------------------------------------------------------

# (test_feature_windows_alignment moved to test_properties.py — hypothesis-guarded)


def test_stream_matches_batch_extract():
    tr = T.get_trace("ATAX", scale=0.4)
    v1, v2 = DeltaVocab(512), DeltaVocab(512)
    fs1 = extract(tr, v1, history=6)
    stream = FeatureStream(tr, v2, history=6)
    a = stream.windows(0, len(tr) // 2)
    b = stream.windows(len(tr) // 2, len(tr))
    np.testing.assert_array_equal(np.concatenate([a.label, b.label]), fs1.label)
    np.testing.assert_array_equal(np.concatenate([a.delta, b.delta]), fs1.delta)
    assert v1.table == v2.table


def test_vocab_overflow_hashes():
    v = DeltaVocab(4)
    ids = [v.encode_one(d) for d in (1, 2, 3, 4, 99, 1)]
    assert max(ids) < 4 and ids[-1] == ids[0]
