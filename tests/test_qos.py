"""QoS subsystem (PR 9): per-tenant capacity partitioning.

The guarantees pinned here:

* tier parsing/validation and the elastic partition math — guaranteed
  floors (pro-rata scaled when over-promised), the share*stability split
  of the elastic pool, and budgets never exceeding capacity;
* pressure-driven rebalancing: a thrashing tenant's budget shrinks toward
  its floor and the reclaimed blocks flow to its stable neighbour;
* the `evict_pref` artifact: over-budget tenants' resident blocks (and
  unowned residents) carry -1, within-budget tenants' blocks are NEVER
  marked;
* release returns a departed tenant's claim to the pool and budgets
  rebalance over the live set;
* the registered stability scorer family (`percentile`, `gmr`);
* `QosSpec` round-trips through JSON, moves the content hash, and
  resolves tier names onto a concurrent trace's tenant ids;
* end to end: a budgeted `run_ours` reports `per_tenant_stats` and
  `budgets`, rejects untagged traces, and the wire protocol only grows a
  `"budget"` field when one is supplied (legacy streams byte-identical).
"""
import json

import numpy as np
import pytest

from repro.uvm.qos import BudgetController, QosTier, parse_tier_flags
from repro.uvm.qos.stability import gmr_scorer, percentile_scorer


# -- tiers + parsing ----------------------------------------------------------

def test_tier_validation():
    QosTier(floor=0.0, share=0.0)  # boundary values are legal
    QosTier(floor=1.0)
    with pytest.raises(ValueError):
        QosTier(floor=1.5)
    with pytest.raises(ValueError):
        QosTier(floor=-0.1)
    with pytest.raises(ValueError):
        QosTier(share=-1.0)


def test_parse_tier_flags():
    tiers = parse_tier_flags(["A:0.5", "B:0.1:2.0"])
    assert tiers == {"A": QosTier(0.5, 1.0), "B": QosTier(0.1, 2.0)}
    assert parse_tier_flags(None) == {} and parse_tier_flags([]) == {}
    for bad in ("A", "A:0.5:1.0:9", ":0.5", "A:not-a-float"):
        with pytest.raises(ValueError):
            parse_tier_flags([bad])


# -- the elastic partition ----------------------------------------------------

def test_guaranteed_floors_and_elastic_split():
    c = BudgetController(100, 128, tiers={"A": QosTier(0.5), "B": QosTier(0.2)})
    c.admit("A")
    c.admit("B")
    # empty histories score 1.0, equal shares: elastic 30 splits 15/15
    assert c.budgets == {"A": 65, "B": 35}
    assert sum(c.budgets.values()) <= c.capacity


def test_overpromised_floors_scale_pro_rata():
    c = BudgetController(100, 128, tiers={"A": QosTier(0.9), "B": QosTier(0.9)})
    c.admit("A")
    c.admit("B")
    # 0.9 + 0.9 > 1 scales to 0.5 each; no elastic pool remains
    assert c.budgets == {"A": 50, "B": 50}


def test_share_weights_tilt_the_elastic_pool():
    c = BudgetController(90, 128, tiers={"A": QosTier(0.0, share=2.0),
                                         "B": QosTier(0.0, share=1.0)})
    c.admit("A")
    c.admit("B")
    assert c.budgets == {"A": 60, "B": 30}


def test_pressure_shrinks_the_thrasher():
    c = BudgetController(100, 128, tiers={"A": QosTier(0.1), "B": QosTier(0.1)})
    c.admit("A")
    c.admit("B")
    even = dict(c.budgets)
    for _ in range(8):
        c.observe_pressure("A", 1.0)   # A thrashes every round
        c.observe_pressure("B", 0.0)   # B never does
        c.step()
    assert c.scores["A"] < c.scores["B"]
    assert c.budgets["A"] < even["A"] and c.budgets["B"] > even["B"]
    # the guarantee holds whatever the pressure: floor(0.1 * 100) = 10
    assert c.budgets["A"] >= 10
    assert sum(c.budgets.values()) <= c.capacity


def test_interval_batches_recomputes():
    c = BudgetController(100, 128, interval=3)
    c.admit("A")
    c.admit("B")
    before = dict(c.budgets)
    c.observe_pressure("A", 1.0)
    c.step()   # round 1: no recompute yet
    c.step()   # round 2
    assert c.budgets == before
    c.step()   # round 3: recompute fires
    assert c.budgets != before


def test_all_zero_weights_split_evenly():
    c = BudgetController(100, 128, tiers={"A": QosTier(0.0, share=0.0),
                                          "B": QosTier(0.0, share=0.0)})
    c.admit("A")
    c.admit("B")
    assert c.budgets == {"A": 50, "B": 50}


# -- ownership, release, evict_pref ------------------------------------------

def test_first_toucher_ownership():
    c = BudgetController(10, 16)
    c.observe_blocks("A", [0, 1, 2])
    c.observe_blocks("B", [2, 3, -1, 99])   # 2 already A's; -1/99 out of range
    assert c.block_owner[0] == c.block_owner[2] == c._index["A"]
    assert c.block_owner[3] == c._index["B"]
    assert c.block_owner[4] == -1


def test_release_returns_blocks_and_rebalances():
    c = BudgetController(10, 16, tiers={"A": QosTier(0.3), "B": QosTier(0.3)})
    c.observe_blocks("A", [0, 1])
    c.observe_blocks("B", [2, 3])
    with_b = c.budgets["A"]
    c.release("B")
    assert np.all(c.block_owner[[2, 3]] == -1)      # claim returned to the pool
    assert "B" not in c.budgets and "B" not in c.tenants
    assert c.budgets["A"] > with_b                  # the live tenant absorbs it
    c.release("B")                                  # idempotent


def test_evict_pref_marks_only_over_budget_and_unowned():
    c = BudgetController(4, 8, tiers={"A": QosTier(0.5), "B": QosTier(0.25)})
    c.observe_blocks("A", [0, 1])      # budget 3 -> within budget
    c.observe_blocks("B", [2, 3, 4])   # budget 1 -> 3 resident = over
    resident = np.ones(8, bool)
    pref = c.evict_pref(resident)
    assert pref.dtype == np.int32 and pref.shape == (8,)
    assert np.all(pref[[0, 1]] == 0)          # under-budget tenant: untouched
    assert np.all(pref[[2, 3, 4]] == -1)      # over-budget tenant: evict first
    assert np.all(pref[[5, 6, 7]] == -1)      # unowned residents: evict first
    # non-resident blocks are never marked, whoever owns them
    pref = c.evict_pref(np.zeros(8, bool))
    assert not pref.any()


def test_evict_pref_empty_controller_is_all_zero():
    c = BudgetController(4, 8)
    assert not c.evict_pref(np.ones(8, bool)).any()


def test_state_restore_roundtrip():
    c = BudgetController(100, 32, tiers={"A": QosTier(0.4, 2.0)}, stability="gmr",
                         interval=2)
    c.observe_blocks("A", [0, 1])
    c.observe_blocks("B", [2])
    c.observe_pressure("A", 0.8)
    c.step()
    c.step()
    c2 = BudgetController(100, 32, tiers={"A": QosTier(0.4, 2.0)})
    c2.restore(c.state())
    assert c2.budgets == c.budgets and c2.scores == c.scores
    assert np.array_equal(c2.block_owner, c.block_owner)
    assert c2.stability == "gmr" and c2.interval == 2
    # the restored controller keeps evolving identically
    for x in (c, c2):
        x.observe_pressure("A", 1.0)
        x.step()
        x.step()
    assert c2.budgets == c.budgets


# -- stability scorers --------------------------------------------------------

def test_percentile_scorer():
    s = percentile_scorer(q=90.0, window=4)
    assert s([]) == 1.0                       # presumed stable until observed
    assert s([0.0, 0.0, 0.0]) == 1.0
    assert s([1.0, 1.0, 1.0]) == 0.0
    assert s([9.0]) == 0.0                    # clipped into [0, 1]
    # window: ancient thrash beyond the last 4 samples is forgotten
    assert s([1.0] + [0.0] * 4) == 1.0


def test_gmr_scorer():
    s = gmr_scorer(window=4)
    assert s([]) == 1.0
    assert s([1.0, 1.0]) == pytest.approx(0.0, abs=1e-5)
    # one spike washes out multiplicatively but still costs something
    assert 0.5 < s([1.0, 0.0, 0.0, 0.0]) < 1.0


def test_stability_registry():
    from repro.uvm import registry as reg
    assert {"percentile", "gmr"} <= set(reg.stability_names())
    with pytest.raises(ValueError):
        reg.register_stability("percentile", percentile_scorer)
    with pytest.raises(KeyError):
        reg.stability_factory("no-such-scorer")


# -- QosSpec ------------------------------------------------------------------

def test_qos_spec_roundtrip_and_key():
    from repro.uvm.api import ModelSpec, QosSpec, QosTierSpec
    spec = QosSpec(tiers=(QosTierSpec("A", floor=0.5), QosTierSpec("B", share=2.0)),
                   stability="gmr", interval=2)
    m = ModelSpec(qos=spec)
    m2 = ModelSpec.from_dict(json.loads(m.to_json()))
    assert m2 == m and m2.key == m.key
    assert ModelSpec().key != m.key          # the qos block moves the hash
    assert ModelSpec.from_dict(json.loads(ModelSpec().to_json())).qos is None


def test_qos_spec_controller_maps_tenant_names():
    from repro.uvm.api import QosSpec, QosTierSpec
    spec = QosSpec(tiers=(QosTierSpec("right", floor=0.5),), interval=3)
    c = spec.controller(100, 128, tenant_names=("left", "right"))
    assert isinstance(c, BudgetController)
    assert c.interval == 3
    c.admit(0)   # "left": no tier -> default (floor 0)
    c.admit(1)   # "right": floor 0.5 -> guaranteed 50
    assert c.budgets[1] >= 50 > c.budgets[0]


# -- runtime + wire integration ----------------------------------------------

def _qos_run(**kw):
    from repro.configs.predictor_paper import SMOKE
    from repro.core.incremental import TrainConfig
    from repro.uvm import runtime as R
    from repro.uvm import trace as T
    from repro.uvm.api import QosSpec, QosTierSpec

    parts = [T.get_trace(n, scale=0.2) for n in ("StreamTriad", "Hotspot")]
    tr = T.concurrent(parts, seed=0, slice_len=256)
    spec = QosSpec(tiers=(QosTierSpec("StreamTriad", floor=0.5),
                          QosTierSpec("Hotspot", floor=0.2)))
    tcfg = TrainConfig(group_size=256, epochs=1, batch_size=64)
    return R.run_ours(tr, SMOKE, tcfg, qos=spec, **kw)


def test_run_ours_budgeted_reports_fairness():
    res = _qos_run()
    assert set(res.per_tenant_stats) == {"0", "1"}
    for st in res.per_tenant_stats.values():
        assert {"pages_thrashed", "faults", "accesses"} <= set(st)
        assert st["accesses"] > 0
    assert res.budgets and all(v >= 0 for v in res.budgets.values())


def test_run_ours_qos_requires_tenants():
    from repro.configs.predictor_paper import SMOKE
    from repro.core.incremental import TrainConfig
    from repro.uvm import runtime as R
    from repro.uvm import trace as T
    from repro.uvm.api import QosSpec

    tr = T.get_trace("ATAX", scale=0.2)
    with pytest.raises(ValueError, match="tenant"):
        R.run_ours(tr, SMOKE, TrainConfig(group_size=256, epochs=1, batch_size=64),
                   qos=QosSpec())


def test_encode_record_budget_field_is_optional():
    from repro.uvm.server.protocol import encode_record

    class FakeActions:
        prefetch_blocks = np.array([1, 2])
        pre_evict_blocks = np.array([3])
        pattern = 0
        n_samples = 4
        accuracy = 0.5
        warm = 0.5
        health = "healthy"
        fallback = False

    legacy = encode_record(7, FakeActions(), tenant="A")
    budgeted = encode_record(7, FakeActions(), tenant="A", budget=12)
    assert "budget" not in json.loads(legacy)
    assert json.loads(budgeted)["budget"] == 12
    assert json.loads(budgeted).pop("budget") is not None
    b = json.loads(budgeted)
    del b["budget"]
    assert b == json.loads(legacy)   # the field is purely additive
