"""Expert-parallel (shard_map + all-to-all) MoE dispatch must match the
pure-pjit scatter dispatch when capacity is generous (no drops): run both in
a subprocess with 8 placeholder devices on a (data=2, model=4) mesh."""
import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.distributed import sharding
from repro.models import moe
from repro.models.params import init_params

cfg = get_smoke_config("olmoe-1b-7b").replace(num_experts=8, top_k=2, capacity_factor=8.0)
specs = moe.moe_specs(cfg)
params = init_params(jax.random.key(0), specs, jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32)

with sharding.use_mesh_rules(mesh):
    os.environ["REPRO_MOE_IMPL"] = "scatter"
    y_scatter, aux_s = jax.jit(lambda p, xx: moe.moe_ffn(p, xx, cfg))(params, x)
    os.environ["REPRO_MOE_IMPL"] = "ep"
    y_ep, aux_e = jax.jit(lambda p, xx: moe.moe_ffn(p, xx, cfg))(params, x)

err = float(jnp.abs(y_scatter - y_ep).max())
ref = float(jnp.abs(y_scatter).max())
aux_err = abs(float(aux_s) - float(aux_e))
print(f"RESULT err={err:.2e} ref={ref:.2e} aux_err={aux_err:.2e}")
assert err <= 1e-4 * max(ref, 1.0), (err, ref)
assert aux_err < 1e-4, aux_err
print("OK")
"""


def test_ep_matches_scatter_multidevice():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "OK" in out.stdout
