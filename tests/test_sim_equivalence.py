"""Bit-exactness suite for the simulator fast path (ISSUE 1 tentpole).

The committed goldens in tests/golden/sim_golden.json were produced by the
pre-refactor per-access scan (now frozen as repro.uvm.reference) for all 11
benchmarks x {lru, belady, hpe, learned} x {demand, tree} x {1.25, 1.5}.
The packed-priority / fault-event-compressed fast path — single-run AND
vmapped batch — must reproduce every counter exactly.

(`random` is exempt by documented contract: its draws depend on the padded
state shape. Random-trace equivalence against the live reference, including
per-access outputs and final state arrays, is covered by the hypothesis
tests in test_properties.py.)
"""
import json
from pathlib import Path

import pytest

from repro.uvm import simulator as S
from repro.uvm import trace as T

GOLDEN = json.loads((Path(__file__).parent / "golden" / "sim_golden.json").read_text())
SCALE, CAP = 0.25, 2000  # must match tests/golden/generate_sim_golden.py
COUNTERS = ("pages_thrashed", "faults", "migrated_blocks", "zero_copy")


from repro.uvm.sweeps import EQUIV_CELLS as CELLS  # noqa: E402


def _trace(name):
    tr = T.get_trace(name, scale=SCALE)
    return tr.slice(0, min(len(tr), CAP))


def _concurrent_trace():
    # must match tests/golden/generate_sim_golden.py:golden_concurrent_trace
    return T.concurrent([_trace("StreamTriad"), _trace("Hotspot")], seed=0, slice_len=256)


@pytest.mark.parametrize("name", sorted(T.BENCHMARKS))
def test_counters_match_prerefactor_golden(name):
    tr = _trace(name)
    # the whole benchmark row in ONE vmapped scan
    batch = S.run_batch(tr, CELLS)
    for (pol, pf, os_), got in zip(CELLS, batch):
        want = GOLDEN[f"{name}|{pol}|{pf}|{os_}"]
        assert {k: got[k] for k in COUNTERS} == want, (name, pol, pf, os_)


def test_concurrent_counters_match_prerefactor_golden():
    """The Section V-F multi-workload cell: disjoint-range interleaved
    streams through the same fast path (periodic compression sees the
    per-tenant streaming phases; counters must still be bit-exact)."""
    tr = _concurrent_trace()
    batch = S.run_batch(tr, CELLS)
    for (pol, pf, os_), got in zip(CELLS, batch):
        want = GOLDEN[f"concurrent:{tr.name}|{pol}|{pf}|{os_}"]
        assert {k: got[k] for k in COUNTERS} == want, (pol, pf, os_)


def test_golden_covers_full_matrix():
    assert len(GOLDEN) == (11 + 1) * 4 * 2 * 2


def test_single_run_matches_golden_spot_checks():
    """A few cells through the unbatched path too (it shares the scan but
    not the lane padding with run_batch)."""
    for name, pol, pf, os_ in (
        ("NW", "belady", "tree", 1.25),
        ("Hotspot", "hpe", "demand", 1.5),
        ("BICG", "learned", "tree", 1.5),
        ("StreamTriad", "lru", "tree", 1.25),
    ):
        got = S.run(_trace(name), policy=pol, prefetch=pf, oversubscription=os_).stats
        want = GOLDEN[f"{name}|{pol}|{pf}|{os_}"]
        assert {k: got[k] for k in COUNTERS} == want, (name, pol, pf, os_)
