"""Chunked online-softmax attention (the XLA path the dry-run lowers) against
the full-softmax oracle, across GQA shapes and masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ref import attention_ref
from repro.models import layers as L


@pytest.mark.parametrize(
    "B,S,K,G,D,T,causal,kv_len",
    [
        (2, 64, 2, 2, 16, 64, True, None),
        (1, 32, 1, 4, 32, 128, False, 100),
        (2, 16, 4, 1, 16, 64, True, 48),
        (1, 1, 2, 2, 16, 96, False, 51),  # decode-style
    ],
)
def test_chunked_matches_ref(B, S, K, G, D, T, causal, kv_len):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, D))
    k = jax.random.normal(ks[1], (B, T, K, D))
    v = jax.random.normal(ks[2], (B, T, K, D))
    out = L._attend_chunked(q, k, v, q_offset=0, causal=causal, kv_len=kv_len, kv_chunk=32)
    ref = attention_ref(q, k, v, causal=causal, q_offset=0, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_q_offset_decode_semantics():
    """q_offset shifts the causal frontier exactly."""
    ks = jax.random.split(jax.random.key(1), 3)
    B, S, K, G, D, T = 1, 4, 1, 1, 8, 32
    q = jax.random.normal(ks[0], (B, S, K, G, D))
    k = jax.random.normal(ks[1], (B, T, K, D))
    v = jax.random.normal(ks[2], (B, T, K, D))
    out = L._attend_chunked(q, k, v, q_offset=10, causal=True, kv_chunk=8)
    ref = attention_ref(q, k, v, causal=True, q_offset=10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position inner products."""
    x = jax.random.normal(jax.random.key(2), (1, 8, 2, 16))
    pos = jnp.arange(8)
    r = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1), np.linalg.norm(np.asarray(r), axis=-1), rtol=1e-5
    )
    # <rope(x, i), rope(y, j)> depends only on (i - j)
    y = jax.random.normal(jax.random.key(3), (1, 8, 2, 16))
    ry = L.rope(y, pos, 10_000.0)
    d01 = float(jnp.sum(r[0, 0, 0] * ry[0, 1, 0]))
    r2 = L.rope(x, pos + 5, 10_000.0)
    ry2 = L.rope(y, pos + 5, 10_000.0)
    d56 = float(jnp.sum(r2[0, 0, 0] * ry2[0, 1, 0]))
    assert abs(d01 - d56) < 1e-4
