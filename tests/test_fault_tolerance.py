"""Fault-tolerant streaming (ISSUE 6 tentpole).

The guarantees pinned here:

* **snapshot/restore is invisible**: a `run_ours` drive interrupted at ANY
  group boundary, snapshotted, and resumed into a FRESH manager finishes
  with counters and accuracy bit-identical to the committed golden (and a
  hypothesis net sweeps arbitrary snapshot points on the stub stack);
* the :class:`TenantMux` composes per-tenant snapshots (shared frequency
  table serialized exactly once) with the same bit-identical guarantee;
* :class:`SnapshotStore` publishes atomically, GCs old snapshots, detects
  payload corruption by checksum, and sweeps crashed-writer turds;
* the **health state machine** walks healthy -> degraded (exponential
  backoff, rule-based fallback actions) -> recovering -> healthy, catching
  dispatch exceptions, NaN params/outputs and latency-budget overruns —
  and with health off (the default) failures still fail HARD, so the
  golden paths can never silently degrade;
* the seeded chaos harness (:class:`FaultInjector`) is deterministic and
  the `cli serve --inject` / `--checkpoint-dir --resume` paths survive
  injected faults and kill/resume with a bit-identical tail.
"""
import dataclasses
import importlib.util
import json
import pickle
from pathlib import Path

import numpy as np
import pytest

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime as R
from repro.uvm import simulator as S
from repro.uvm import trace as T
from repro.uvm.manager import (
    ChaosError,
    ChaosSchedule,
    FaultBatch,
    FaultInjector,
    HealthConfig,
    ManagerConfig,
    Outcomes,
    OversubscriptionManager,
    SnapshotStore,
    STATE_VERSION,
    TenantMux,
)

GOLDEN = json.loads((Path(__file__).parent / "golden" / "ours_golden.json").read_text())
SCALE, CAP = 0.3, 3000  # must match tests/golden/generate_ours_golden.py
TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)


def _bench_trace(name: str) -> T.Trace:
    tr = T.get_trace(name, scale=SCALE)
    return tr.slice(0, min(len(tr), CAP))


# --- the stub predictor stack (fast, deterministic, no jit retraces) ---------


class _StubTrainer:
    """Pure-numpy trainer double (same contract as test_multi's): the
    snapshot/health plumbing under test lives in the manager, not the NN."""

    def new_params(self, seed: int = 0):
        return np.zeros(1)

    def evaluate(self, params, fs, n_active: int):
        pred = fs.delta[:, -1] % max(n_active, 1)
        return pred == fs.label, pred

    def evaluate_many(self, params_list, fs_list, n_active_list):
        return [self.evaluate(p, f, n) for p, f, n in zip(params_list, fs_list, n_active_list)]

    def train_group(self, entry, fs, n_active, *, in_et=None, use_lucir=False, rng=None):
        entry.n_updates += 1
        return entry

    def train_group_many(self, entries, fs_list, n_active_list, *, in_et_list=None, use_lucir=False):
        for e in entries:
            e.n_updates += 1
        return entries


class _FlakyTrainer(_StubTrainer):
    """Raises on a scripted set of evaluate calls (dispatch failures)."""

    def __init__(self, fail_on=()):
        self.calls = 0
        self.fail_on = set(fail_on)

    def evaluate(self, params, fs, n_active: int):
        self.calls += 1
        if self.calls in self.fail_on:
            raise RuntimeError(f"flaky dispatch #{self.calls}")
        return super().evaluate(params, fs, n_active)

    def evaluate_many(self, params_list, fs_list, n_active_list):
        self.calls += 1
        if self.calls in self.fail_on:
            raise RuntimeError(f"flaky batched dispatch #{self.calls}")
        return [_StubTrainer.evaluate(self, p, f, n)
                for p, f, n in zip(params_list, fs_list, n_active_list)]


def _stub_cfg(**kw) -> ManagerConfig:
    kw.setdefault("predictor", SMOKE)
    kw.setdefault("train", TrainConfig(group_size=64, epochs=1, batch_size=32))
    kw.setdefault("n_pages", 1024)
    kw.setdefault("n_blocks", 64)
    kw.setdefault("capacity", 16)
    kw.setdefault("use_lucir", False)
    kw.setdefault("use_thrash_term", False)
    return ManagerConfig(**kw)


def _stub_manager(trainer=None, **kw) -> OversubscriptionManager:
    return OversubscriptionManager(_stub_cfg(**kw), trainer=trainer or _StubTrainer())


def _batch(rng, n=64):
    return FaultBatch(rng.integers(0, 1024, n))


def _drive(mgr, rng, rounds, clock_step=128, start_clock=0):
    """Drive `rounds` observe/feedback rounds; returns the action tuples
    (the full decision stream, for bit-identity asserts)."""
    out, clock = [], start_clock
    for _ in range(rounds):
        b = _batch(rng)
        a = mgr.observe(b)
        clock += clock_step
        mgr.feedback(Outcomes(was_evicted=np.zeros(len(b), bool), fault_count=clock))
        out.append((
            tuple(np.asarray(a.prefetch_blocks).tolist()),
            tuple(np.asarray(a.pre_evict_blocks).tolist()),
            None if a.counters is None else tuple(np.asarray(a.counters).tolist()),
            a.pattern, a.accuracy, a.warm, a.health, a.fallback,
        ))
    return out


# --- snapshot/restore: bit-identical continuation ----------------------------


def test_manager_snapshot_restore_bit_identical_stub():
    """Split a 12-round drive at round 5: snapshot -> pickle -> restore
    into a FRESH manager; the tail decision stream and accuracy match the
    uninterrupted twin exactly."""
    ref = _drive(_stub_manager(), np.random.default_rng(0), 12)

    a = _stub_manager()
    _drive(a, np.random.default_rng(0), 12)  # twin consuming the same rng

    m1 = _stub_manager()
    rng = np.random.default_rng(0)
    head = _drive(m1, rng, 5)
    blob = pickle.dumps(m1.state())  # through bytes, like a real checkpoint
    m2 = _stub_manager()
    m2.restore(pickle.loads(blob))
    tail = _drive(m2, rng, 7, start_clock=5 * 128)
    assert head + tail == ref
    assert m2.top1 == a.top1 and m2.n_predictions == a.n_predictions
    assert m2.vocab.table == a.vocab.table
    assert np.array_equal(m2.freq_table.tags, a.freq_table.tags)
    assert np.array_equal(m2._chain_li, a._chain_li)


def test_snapshot_rejects_pending_version_and_config_drift():
    m = _stub_manager()
    m.observe_begin(_batch(np.random.default_rng(1)))
    with pytest.raises(RuntimeError, match="pending"):
        m.state()
    m.observe_finish(None, None)
    m.feedback(Outcomes(np.zeros(64, bool), 64))
    st = m.state()
    bad = dict(st, version=STATE_VERSION + 1)
    with pytest.raises(ValueError, match="version"):
        _stub_manager().restore(bad)
    other = _stub_manager(capacity=8)  # different geometry
    with pytest.raises(ValueError, match="different ManagerConfig"):
        other.restore(st)
    # health on/off does NOT change the signature: enabling the health
    # machine on resume of a legacy snapshot is legitimate (serve does it)
    healthy = _stub_manager(health=HealthConfig())
    healthy.restore(st)
    assert healthy.health_state == "healthy"


def test_golden_pinned_snapshot_restore_real_predictor():
    """The committed ATAX golden, reproduced through a mid-run checkpoint:
    drive run_ours's exact loop, snapshot after group 1, restore into a
    fresh manager_for() product, finish — stats AND accuracy match the
    golden bit for bit."""
    tr = _bench_trace("ATAX")
    mgr = R.manager_for(tr, SMOKE, TCFG)
    nb, cap = mgr.cfg.n_blocks, mgr.cfg.capacity
    state = S.init_state(nb, 0)
    blocks = tr.block.astype(np.int32)
    nxt = S.next_use_for(tr)
    G = TCFG.group_size
    bounds = list(range(0, len(tr), G))
    for i, g0 in enumerate(bounds):
        g1 = min(g0 + G, len(tr))
        actions = mgr.observe(R._group_batch(tr, g0, g1))
        state = R._apply_actions(state, actions, nb, cap)
        state, outs = S.run_segment(
            state, blocks[g0:g1], nxt[g0:g1],
            capacity=cap, policy="learned", prefetch="demand", n_valid=tr.n_blocks,
        )
        mgr.feedback(Outcomes(np.asarray(outs["was_evicted"]), int(state.fault_count)))
        if i == 1:  # checkpoint + process death + resume
            blob = pickle.dumps(mgr.state())
            mgr = R.manager_for(tr, SMOKE, TCFG)
            mgr.restore(pickle.loads(blob))
    res = R._result(mgr, state, len(tr))
    g = GOLDEN["ATAX"]
    assert res.stats == g["stats"]
    assert res.top1 == g["top1"]
    assert res.warm_top1 == g["warm_top1"]
    assert res.per_group_acc == g["per_group_acc"]
    assert res.n_predictions == g["n_predictions"]


@pytest.mark.parametrize("shared", [False, True])
def test_mux_snapshot_restore_bit_identical(shared):
    """Tenant-tagged drive through TenantMux, snapshotted mid-stream and
    restored into a fresh mux: identical accuracy + frequency state per
    tenant.  The shared frequency table is serialized ONCE and rebound to
    every restored tenant."""
    def mk():
        return TenantMux(_stub_cfg(), [0, 1], shared_freq_table=shared,
                         auto_create=False, trainer=_StubTrainer())

    def drive(mux, rng, rounds, start_clock=0):
        clock = start_clock
        for _ in range(rounds):
            pages = rng.integers(0, 1024, 48)
            tags = rng.integers(0, 2, 48)
            mux.observe(FaultBatch(pages, tenant=tags))
            clock += 96
            mux.feedback(Outcomes(np.zeros(48, bool), clock))

    ref = mk()
    drive(ref, np.random.default_rng(3), 10)

    m1 = mk()
    rng = np.random.default_rng(3)
    drive(m1, rng, 4)
    blob = pickle.dumps(m1.state())
    m2 = mk()
    m2.restore(pickle.loads(blob))
    if shared:  # one shared table object, rebound across all tenants
        assert m2.managers[0].freq_table._table is m2.managers[1].freq_table._table
    drive(m2, rng, 6, start_clock=4 * 96)
    assert m2.top1 == ref.top1
    assert m2.per_tenant_top1 == ref.per_tenant_top1
    for t in (0, 1):
        a, b = m2.managers[t], ref.managers[t]
        assert np.array_equal(a.freq_table.dense(64), b.freq_table.dense(64))
        assert a.vocab.table == b.vocab.table
        assert a._flush_interval == b._flush_interval


def test_mux_snapshot_rejects_mid_round():
    mux = TenantMux(_stub_cfg(), [0], auto_create=False, trainer=_StubTrainer())
    mux.observe(FaultBatch(np.arange(32), tenant=np.zeros(32, np.int64)))
    with pytest.raises(RuntimeError, match="mid-round"):
        mux.state()


# --- SnapshotStore -----------------------------------------------------------


def test_snapshot_store_roundtrip_gc_and_corruption(tmp_path):
    store = SnapshotStore(tmp_path / "ckpt", keep=3)
    assert store.latest_step() is None
    with pytest.raises(FileNotFoundError):
        store.restore()
    for step in range(1, 6):
        store.save(step, {"n": step}, extra={"batches": step * 10})
    assert store.steps() == [3, 4, 5]  # GC keeps the newest `keep`
    step, state, extra = store.restore()
    assert (step, state, extra) == (5, {"n": 5}, {"batches": 50})
    assert store.restore(step=3)[1] == {"n": 3}
    # flip one payload byte: the manifest checksum must catch it
    payload = store.dir / f"snap_{4:09d}" / "state.pkl"
    raw = bytearray(payload.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    payload.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="content-hash"):
        store.restore(step=4)
    # a crashed writer's tmp dir is swept, never adopted
    turd = tmp_path / "ckpt" / "snap_000000099.tmp"
    turd.mkdir()
    (turd / "state.pkl").write_bytes(b"half")
    store.clean_tmp()
    assert not turd.exists() and store.latest_step() == 5


# --- the health state machine ------------------------------------------------


def test_health_off_fails_hard():
    """cfg.health=None (the default, every golden path) must re-raise the
    dispatch error unchanged — no silent degradation."""
    m = _stub_manager(trainer=_FlakyTrainer(fail_on={1}))
    with pytest.raises(RuntimeError, match="flaky dispatch"):
        m.observe(_batch(np.random.default_rng(0)))


def test_health_state_machine_walk():
    """2 consecutive dispatch failures: backoff doubles 1 -> 2, the blackout
    rounds serve fallback actions, then recovery needs 2 clean dispatches
    before re-promoting to healthy."""
    m = _stub_manager(trainer=_FlakyTrainer(fail_on={1, 2}),
                      health=HealthConfig(recovery_successes=2))
    acts = _drive(m, np.random.default_rng(0), 10)
    healths = [a[6] for a in acts]
    fallbacks = [a[7] for a in acts]
    assert healths == (
        ["degraded"]                # round 1: fault #1, backoff=1
        + ["degraded"]              # round 2: backoff burn (blackout)
        + ["degraded"]              # round 3: recovery retry -> fault #2, backoff=2
        + ["degraded", "degraded"]  # rounds 4-5: burn the doubled backoff
        + ["recovering", "healthy"]  # rounds 6-7: two clean dispatches
        + ["healthy"] * 3
    )
    assert fallbacks == [True] * 5 + [False] * 5
    assert m.n_health_faults == 2
    assert m.n_fallbacks == 5
    assert m.n_recoveries == 1
    assert m.health_state == "healthy"
    assert "flaky" in m.last_health_error


def test_fallback_actions_are_rule_based_floor():
    """Degraded rounds serve buddy tree-prefetch + pure-LRU pre-eviction:
    counters=None (gate closed), warm=False, bounded prefetch."""
    m = _stub_manager(trainer=_FlakyTrainer(fail_on={1}), health=HealthConfig())
    pages = np.tile([0, 16, 320], 22)  # blocks {0, 1, 20}, enough for windows
    a = m.observe(FaultBatch(pages))
    assert a.fallback and a.health == "degraded"
    assert a.counters is None and not a.warm and a.accuracy is None
    assert set(np.asarray(a.prefetch_blocks)) == {0, 1, 21}  # buddy siblings
    m.feedback(Outcomes(np.zeros(len(pages), bool), 64))
    assert m.n_fallbacks == 1


def test_nan_params_quarantined_and_reinitialized():
    """A NaN-poisoned model entry is caught BEFORE dispatch and its slot
    re-initialized, so the retry after backoff runs a fresh model."""
    m = _stub_manager(health=HealthConfig(recovery_successes=1))
    rng = np.random.default_rng(2)
    _drive(m, rng, 1)
    slot = next(iter(m.table.slots))  # the one pattern slot the round used
    poisoned = m.table.slots[slot]
    poisoned.params = np.full(1, np.nan)
    acts = _drive(m, rng, 3)
    assert [a[6] for a in acts] == ["degraded", "degraded", "healthy"]
    assert m.n_health_faults == 1 and "non-finite model params" in m.last_health_error
    assert np.all(np.isfinite(m.table.slots[slot].params))  # quarantine re-init


def test_nan_output_and_latency_budget_demote():
    class _NaNTrainer(_StubTrainer):
        def evaluate(self, params, fs, n_active):
            return np.full(len(fs.label), np.nan), np.full(len(fs.label), np.nan)

    m = _stub_manager(trainer=_NaNTrainer(), health=HealthConfig(recovery_successes=1))
    a = m.observe(_batch(np.random.default_rng(0)))
    assert a.fallback and m.n_health_faults == 1
    assert "non-finite predictor output" in m.last_health_error

    class _SlowTrainer(_StubTrainer):
        def evaluate(self, params, fs, n_active):
            import time

            time.sleep(0.02)
            return super().evaluate(params, fs, n_active)

    m2 = _stub_manager(trainer=_SlowTrainer(),
                       health=HealthConfig(latency_budget_ms=1.0))
    a2 = m2.observe(_batch(np.random.default_rng(0)))
    assert a2.fallback and "budget" in m2.last_health_error


def test_train_failure_closes_round_without_update():
    class _TrainBomb(_StubTrainer):
        def train_group(self, entry, fs, n_active, **kw):
            raise RuntimeError("train boom")

    m = _stub_manager(trainer=_TrainBomb(), health=HealthConfig())
    rng = np.random.default_rng(0)
    b = _batch(rng)
    m.observe(b)
    m.feedback(Outcomes(np.zeros(len(b), bool), 64))  # must not raise
    assert m.n_health_faults == 1 and m._pending is None
    # and with health off the same failure is fatal
    m2 = _stub_manager(trainer=_TrainBomb())
    b2 = _batch(rng)
    m2.observe(b2)
    with pytest.raises(RuntimeError, match="train boom"):
        m2.feedback(Outcomes(np.zeros(len(b2), bool), 64))


def test_mux_batched_dispatch_failure_degrades_all_tenants():
    mux = TenantMux(_stub_cfg(health=HealthConfig(recovery_successes=1)), [0, 1],
                    auto_create=False, trainer=_FlakyTrainer(fail_on={1}))
    pages, tags = np.arange(48) * 16, np.tile([0, 1], 24)
    mux.observe(FaultBatch(pages, tenant=tags))
    mux.feedback(Outcomes(np.zeros(48, bool), 64))
    assert mux.n_health_faults == 2  # both tenants rode the failed dispatch
    assert set(mux.health_states.values()) == {"degraded"}
    for _ in range(3):
        mux.observe(FaultBatch(pages, tenant=tags))
        mux.feedback(Outcomes(np.zeros(48, bool), 64))
    assert set(mux.health_states.values()) == {"healthy"}
    assert mux.n_recoveries == 2


# --- the chaos harness -------------------------------------------------------


def test_chaos_schedule_parse_and_validation(tmp_path):
    s = ChaosSchedule.parse("trainer_exc=0.3,nan_output=0.1,seed=7")
    assert (s.trainer_exc, s.nan_output, s.seed) == (0.3, 0.1, 7)
    plan = tmp_path / "plan.json"
    plan.write_text(json.dumps({"drop_batch": 0.5, "seed": 9}))
    s2 = ChaosSchedule.parse(f"@{plan}")
    assert s2.drop_batch == 0.5 and s2.seed == 9
    assert ChaosSchedule.parse("") == ChaosSchedule()
    with pytest.raises(ValueError, match="unknown chaos keys"):
        ChaosSchedule.parse("typo_key=0.5")
    with pytest.raises(ValueError, match="not key=value"):
        ChaosSchedule.parse("trainer_exc")
    with pytest.raises(ValueError, match=r"outside \[0, 1\]"):
        ChaosSchedule(trainer_exc=1.5)
    assert ChaosSchedule.parse("seed=3").to_dict()["seed"] == 3


def test_chaos_injection_is_seed_deterministic():
    sched = ChaosSchedule(seed=11, trainer_exc=0.5, train_exc=0.3, nan_params=0.2)

    def run():
        inj = FaultInjector(sched)
        m = _stub_manager(trainer=inj.wrap_trainer(_StubTrainer()),
                          health=HealthConfig(recovery_successes=1))
        acts = _drive(m, np.random.default_rng(5), 20)
        return dict(inj.counts), [a[6] for a in acts], m.top1

    assert run() == run()
    counts, healths, _ = run()
    assert counts["trainer_exc"] > 0 and "degraded" in healths and "healthy" in healths


def test_chaos_trainer_raises_chaos_error_without_health():
    inj = FaultInjector(ChaosSchedule(seed=0, trainer_exc=1.0))
    m = _stub_manager(trainer=inj.wrap_trainer(_StubTrainer()))
    with pytest.raises(ChaosError):
        m.observe(_batch(np.random.default_rng(0)))


def test_chaos_freq_table_wrapper_drops_updates():
    from repro.core.policy import PredictionFrequencyTable

    inj = FaultInjector(ChaosSchedule(seed=0, drop_freq_update=1.0))
    t = inj.wrap_freq_table(PredictionFrequencyTable())
    t.update(np.asarray([1, 2, 3]))
    assert t.lookup(1) == -1  # never admitted: the update was dropped
    assert int(np.sum(t.counters)) == 0
    assert inj.counts["drop_freq_update"] == 1  # one fire per update() call


def test_chaos_transform_lines():
    obs = [json.dumps({"pages": [i]}) for i in range(4)]
    fb = json.dumps({"feedback": {"fault_count": 1}})
    lines = [obs[0], "# comment", "", fb, obs[1], obs[2], obs[3]]
    # pass-through schedule: byte-identical stream, no randomness consumed
    inj = FaultInjector(ChaosSchedule(seed=0))
    assert list(inj.transform_lines(lines)) == lines
    # drop everything droppable: only blanks/comments + feedback survive
    # losing feedback too leaves just the structural lines
    inj2 = FaultInjector(ChaosSchedule(seed=0, drop_batch=1.0, lose_feedback=1.0))
    assert list(inj2.transform_lines(lines)) == ["# comment", ""]
    # delayed feedback is re-delivered after the next delivered line
    inj3 = FaultInjector(ChaosSchedule(seed=0, delay_feedback=1.0))
    out = list(inj3.transform_lines([obs[0], fb, obs[1]]))
    assert out == [obs[0], obs[1], fb]
    # a held line at EOF still drains
    out2 = list(inj3.transform_lines([obs[0], fb]))
    assert out2 == [obs[0], fb]
    # duplication doubles observe lines deterministically
    inj4 = FaultInjector(ChaosSchedule(seed=0, dup_batch=1.0))
    assert list(inj4.transform_lines([obs[0]])) == [obs[0], obs[0]]


# --- serve hardening (in-process, for coverage) ------------------------------


def _serve_lines(n_batches=8, pages_per=40):
    rng = np.random.default_rng(42)
    lines, clock = [], 0
    for b in range(n_batches):
        t = "A" if b % 2 == 0 else "B"
        pages = rng.integers(0, 300, pages_per).tolist()
        lines.append(json.dumps({"pages": pages, "tenant": t}))
        clock += 64
        lines.append(json.dumps({"feedback": {"was_evicted": [False] * pages_per,
                                              "fault_count": clock}, "tenant": t}))
    return lines


_SERVE_ARGS = ["--n-pages", "300", "--pages-per-block", "4",
               "--capacity", "16", "--group-size", "32"]


def _recs(out):
    return [json.loads(l) for l in out.strip().splitlines() if l.startswith("{")]


def test_cli_serve_inject_never_tracebacks(tmp_path, capsys):
    """Chaos-injected serve: exit 0, structured records only, the health
    machine degrades then recovers, and the chaos summary line reports
    what fired."""
    from repro.uvm import cli

    stream = tmp_path / "faults.jsonl"
    stream.write_text("\n".join(_serve_lines(12)) + "\n")
    assert cli.main(["serve", "--input", str(stream), *_SERVE_ARGS,
                     "--inject", "trainer_exc=0.4,seed=3"]) == 0
    out = capsys.readouterr().out
    assert "Traceback" not in out
    acts = [r for r in _recs(out) if "batch" in r]
    healths = [a["health"] for a in acts]
    assert "degraded" in healths and "healthy" in healths
    assert any(a["fallback"] for a in acts)
    assert "# chaos schedule=" in out and "fired=" in out
    assert "health_faults=" in out and "fallbacks=" in out


def test_cli_serve_checkpoint_resume_bit_identical_tail(tmp_path, capsys):
    """Kill/resume invariant: run the full stream once for reference; run
    a truncated prefix with checkpointing (simulating a kill), then
    --resume on the full stream — the resumed tail records and the final
    summary are byte-identical to the uninterrupted run."""
    from repro.uvm import cli

    lines = _serve_lines(12)
    full, head = tmp_path / "full.jsonl", tmp_path / "head.jsonl"
    full.write_text("\n".join(lines) + "\n")
    head.write_text("\n".join(lines[:12]) + "\n")  # 6 closed batches
    ck = tmp_path / "ckpt"

    assert cli.main(["serve", "--input", str(full), *_SERVE_ARGS]) == 0
    ref = capsys.readouterr().out.strip().splitlines()

    assert cli.main(["serve", "--input", str(head), *_SERVE_ARGS,
                     "--checkpoint-dir", str(ck), "--checkpoint-every", "2"]) == 0
    capsys.readouterr()
    store = SnapshotStore(ck)
    assert store.latest_step() == 6  # final flush at EOF

    assert cli.main(["serve", "--input", str(full), *_SERVE_ARGS,
                     "--checkpoint-dir", str(ck), "--resume"]) == 0
    res = capsys.readouterr().out.strip().splitlines()
    assert any(l.startswith("# resumed batch=6") for l in res)
    tail = [l for l in res if l.startswith("{")]
    ref_tail = [l for l in ref if l.startswith("{")][6:]
    assert tail == ref_tail  # byte-identical records
    assert res[-1] == ref[-1]  # identical final summary

    # resuming from an EARLIER snapshot replays the gap identically too
    # (the resume run above flushed its own final snapshot; prune back to 4)
    import shutil

    for s in SnapshotStore(ck).steps():
        if s != 4:
            shutil.rmtree(store.dir / f"snap_{s:09d}")
    assert cli.main(["serve", "--input", str(full), *_SERVE_ARGS,
                     "--checkpoint-dir", str(ck), "--resume"]) == 0
    res2 = capsys.readouterr().out.strip().splitlines()
    assert any(l.startswith("# resumed batch=4") for l in res2)
    assert [l for l in res2 if l.startswith("{")] == [l for l in ref if l.startswith("{")][4:]


def test_cli_serve_resume_requires_checkpoint_dir(tmp_path, capsys):
    from repro.uvm import cli

    stream = tmp_path / "s.jsonl"
    stream.write_text("\n".join(_serve_lines(1)) + "\n")
    assert cli.main(["serve", "--input", str(stream), "--resume"]) == 2
    assert "checkpoint-dir" in capsys.readouterr().err


# --- serving-layer checkpointing ---------------------------------------------


def test_offload_manager_checkpoint_resume(tmp_path):
    from repro.serving.offload import LearnedOffloadManager

    def drive(mgr, steps, rng):
        for _ in range(steps):
            touched = rng.integers(0, 64, 16)
            mass = np.zeros(64)
            mass[touched] = 1.0
            mgr.on_attention(mass, touched)

    ref = LearnedOffloadManager(64, 16, group=32)
    drive(ref, 20, np.random.default_rng(9))

    m1 = LearnedOffloadManager(64, 16, group=32,
                               checkpoint_dir=tmp_path / "ck", checkpoint_every=2)
    rng = np.random.default_rng(9)
    drive(m1, 10, rng)
    assert SnapshotStore(tmp_path / "ck").latest_step() is not None
    m2 = LearnedOffloadManager(64, 16, group=32,
                               checkpoint_dir=tmp_path / "ck", resume=True)
    # roll forward to m1's live position (the snapshot may lag by < every)
    assert m2._observed_batches <= m1._observed_batches
    m2.restore(m1.state())
    drive(m2, 10, rng)
    assert dataclasses.asdict(m2.stats) == dataclasses.asdict(ref.stats)
    assert np.array_equal(m2.resident, ref.resident)
    assert m2.manager.top1 == ref.manager.top1


def test_model_spec_health_threads_to_manager(tmp_path):
    from repro.uvm.api import Session
    from repro.uvm.api.store import RunStore

    s = Session(scale=0.25, cap=1500, store=RunStore(tmp_path / "runs"))
    mgr = s.manager("NW", health=True, latency_budget_ms=2.5)
    assert mgr.cfg.health is not None
    assert mgr.cfg.health.latency_budget_ms == 2.5
    assert s.manager("NW").cfg.health is None  # off by default


# --- hypothesis: snapshot anywhere is invisible ------------------------------

if importlib.util.find_spec("hypothesis"):
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 9), st.integers(10, 999))
    def test_snapshot_point_invisible_hypothesis(cut, seed):
        """For ANY snapshot point and input stream, interrupt+restore is
        invisible: the stitched decision stream equals the uninterrupted
        one (stub stack; 10 rounds, cut at round `cut`)."""
        ref = _drive(_stub_manager(), np.random.default_rng(seed), 10)
        m1 = _stub_manager()
        rng = np.random.default_rng(seed)
        head = _drive(m1, rng, cut)
        m2 = _stub_manager()
        m2.restore(pickle.loads(pickle.dumps(m1.state())))
        tail = _drive(m2, rng, 10 - cut, start_clock=cut * 128)
        assert head + tail == ref
