"""int8 KV-cache quantisation (beyond-paper serving feature): numerics stay
close to the bf16 cache and the quantised decode matches teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models import lm


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (4, 7, 2, 32))
    q, s = L.kv_quantize(x)
    deq = L.kv_dequantize(q, s)
    err = np.abs(np.asarray(deq, np.float32) - np.asarray(x))
    bound = np.abs(np.asarray(x)).max(-1, keepdims=True) / 127.0 + 0.02
    assert (err <= bound).all()
    assert q.dtype == jnp.int8 and s.dtype == jnp.bfloat16


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "internvl2-26b"])
def test_q8_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch).replace(kv_quant="int8", dtype="float32")
    params = lm.init(jax.random.key(0), cfg, max_seq=32)
    B, S, prefix = 2, 24, 16
    text = lm.text_len(cfg, S)
    tokens = jax.random.randint(jax.random.key(3), (B, text), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(jax.random.key(4), (B, cfg.num_patches, cfg.patch_feat)).astype(jnp.bfloat16)

    full_logits, _ = lm.forward(params, batch, cfg)
    pre = {**batch, "tokens": tokens[:, : prefix - cfg.num_patches if cfg.family == "vlm" else prefix]}
    logits_p, cache = lm.make_prefill(cfg)(params, pre)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache

    # grow int8 caches + scales to the full length
    def grow(k, a):
        if k in ("k", "v", "k_scale", "v_scale") and a.ndim >= 3:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, S - a.shape[2])
            return jnp.pad(a, pad)
        return a

    cache = {k: grow(k, v) for k, v in cache.items()}
    decode = lm.make_decode_step(cfg)
    text_prefix = prefix - cfg.num_patches if cfg.family == "vlm" else prefix
    for pos in range(text_prefix, text):
        abs_pos = pos + (cfg.num_patches if cfg.family == "vlm" else 0)
        logits_d, cache = decode(params, {"token": tokens[:, pos], "pos": jnp.asarray(abs_pos, jnp.int32)}, cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32),
            atol=0.15, rtol=0.15,  # int8 cache noise; argmax stability checked below
        )
        agree = (logits_d[:, 0].argmax(-1) == full_logits[:, pos].argmax(-1)).mean()
        assert float(agree) >= 0.5


def test_q8_cache_half_footprint():
    from repro.configs import SHAPES, get_config

    cfg = get_config("granite-3-8b")
    shape = SHAPES["decode_32k"]
    bf16 = lm.abstract_cache(cfg, shape)
    q8 = lm.abstract_cache(cfg.replace(kv_quant="int8"), shape)

    def nbytes(t):
        return sum(np.prod(v.shape) * v.dtype.itemsize for v in t.values())

    # int8 values + bf16 per-(token,head) scales ~= 0.56x of the bf16 cache
    assert nbytes(q8) < 0.6 * nbytes(bf16)
