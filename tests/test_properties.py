"""Hypothesis property tests (consolidated from test_core / test_infra /
test_uvm_sim so those modules stay collectable without hypothesis).

This module is guarded by ``pytest.importorskip``: tier-1 collection must
never hard-error when hypothesis is absent (see requirements.txt), and the
non-property tests keep running either way.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (see requirements.txt)")
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.features import DeltaVocab, extract
from repro.distributed import compression as C
from repro.distributed.elastic import plan_mesh
from repro.uvm import reference as REF
from repro.uvm import simulator as S
from repro.uvm import trace as T


def _trace_from_blocks(blocks, n_blocks):
    blocks = np.asarray(blocks, np.int32)
    pages = blocks * T.PAGES_PER_BLOCK
    n = len(pages)
    return T.Trace("h", pages, np.zeros(n, np.int32), np.zeros(n, np.int32), np.zeros(n, np.int32), n_blocks * T.PAGES_PER_BLOCK)


# --- uvm simulator ---------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    blocks=st.lists(st.integers(0, 31), min_size=20, max_size=120),
    policy=st.sampled_from(["lru", "random", "hpe", "learned"]),
)
def test_invariants_random_traces(blocks, policy):
    tr = _trace_from_blocks(blocks, 32)
    res = S.run(tr, policy=policy, prefetch="demand", oversubscription=1.5)
    st_ = res.state
    cap = S.capacity_for(tr.n_blocks, 1.5)
    assert int(st_.occupancy) <= cap
    assert int(st_.resident.sum()) == int(st_.occupancy)
    # thrash events can't exceed migrations, faults can't exceed accesses
    assert int(st_.thrash_events) <= int(st_.migrations)
    assert int(st_.faults) <= len(tr)
    # every accessed block was resident or pinned at some point => no fault
    # for blocks re-accessed while resident
    assert int(st_.migrations) >= int(st_.faults) * 0  # migrations well-defined


@settings(max_examples=10, deadline=None)
@given(blocks=st.lists(st.integers(0, 23), min_size=40, max_size=160))
def test_belady_minimizes_faults(blocks):
    """Belady's MIN provably minimises misses: with demand migration,
    faults(Belady) <= faults(any other policy)."""
    oversub = 1.6
    tr = _trace_from_blocks(blocks, 24)
    f_bel = S.run(tr, policy="belady", prefetch="demand", oversubscription=oversub).stats["faults"]
    for policy in ("lru", "random", "hpe"):
        f = S.run(tr, policy=policy, prefetch="demand", oversubscription=oversub).stats["faults"]
        assert f_bel <= f, f"belady {f_bel} > {policy} {f}"


def _assert_fast_matches_reference(tr, policy, prefetch, oversub):
    a = S.run(tr, policy=policy, prefetch=prefetch, oversubscription=oversub)
    b = REF.run(tr, policy=policy, prefetch=prefetch, oversubscription=oversub)
    assert a.stats == b.stats
    np.testing.assert_array_equal(a.fault, b.fault)
    np.testing.assert_array_equal(a.thrash, b.thrash)
    np.testing.assert_array_equal(a.was_evicted, b.was_evicted)
    nb = len(b.state.resident)  # fast path may pad the block axis further
    for field in ("resident", "evicted_once", "last_access", "last_interval", "next_use"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.state, field))[:nb], np.asarray(getattr(b.state, field)), err_msg=field
        )


@settings(max_examples=8, deadline=None)
@given(
    blocks=st.lists(st.integers(0, 47), min_size=10, max_size=200),
    policy=st.sampled_from(["lru", "belady", "hpe", "learned"]),
    prefetch=st.sampled_from(["demand", "tree"]),
    oversub=st.sampled_from([1.1, 1.25, 1.5, 2.0]),
)
def test_fast_path_matches_reference(blocks, policy, prefetch, oversub):
    """The compressed/packed-priority fast path is bit-identical to the
    frozen pre-refactor reference on arbitrary traces: counters, per-access
    outputs, AND the final per-block state (`random` is exempt by contract —
    its draws depend on array padding)."""
    _assert_fast_matches_reference(_trace_from_blocks(blocks, 48), policy, prefetch, oversub)


@settings(max_examples=10, deadline=None)
@given(
    period=st.lists(st.integers(0, 47), min_size=2, max_size=8),
    reps=st.integers(4, 24),
    prefix=st.lists(st.integers(0, 47), min_size=0, max_size=30),
    suffix=st.lists(st.integers(0, 47), min_size=0, max_size=30),
    policy=st.sampled_from(["lru", "belady", "hpe", "learned"]),
    prefetch=st.sampled_from(["demand", "tree"]),
    oversub=st.sampled_from([1.1, 1.25, 1.5, 2.0, 8.0]),
)
def test_fast_path_matches_reference_periodic(period, reps, prefix, suffix, policy, prefetch, oversub):
    """Period-p traces (the streaming `_interleave` idiom) exercise the
    aggregate-event merge AND — at high oversubscription, where windows get
    evicted mid-flight — the runtime divergence fallback.  Both paths must
    stay bit-identical to the reference."""
    blocks = prefix + list(period) * reps + suffix
    _assert_fast_matches_reference(_trace_from_blocks(blocks, 48), policy, prefetch, oversub)


@settings(max_examples=6, deadline=None)
@given(
    blocks_a=st.lists(st.integers(0, 15), min_size=20, max_size=120),
    blocks_b=st.lists(st.integers(0, 15), min_size=20, max_size=120),
    policy=st.sampled_from(["lru", "belady", "hpe", "learned"]),
    seed=st.integers(0, 3),
)
def test_fast_path_matches_reference_concurrent(blocks_a, blocks_b, policy, seed):
    """Section V-F multi-workload traces (disjoint-range scheduler-slice
    interleaving) through the fast path, against the reference."""
    tr = T.concurrent(
        [_trace_from_blocks(blocks_a, 16), _trace_from_blocks(blocks_b, 16)],
        seed=seed, slice_len=16,
    )
    _assert_fast_matches_reference(tr, policy, "tree", 1.25)


@settings(max_examples=6, deadline=None)
@given(
    blocks=st.lists(st.integers(0, 31), min_size=15, max_size=90),
    policy=st.sampled_from(["lru", "random", "belady", "hpe", "learned"]),
    oversub=st.sampled_from([1.1, 1.5, 2.0]),
)
def test_kernel_path_matches_scan_path(blocks, policy, oversub):
    """REPRO_SIM_KERNELS routes victim selection through the Pallas kernel
    (interpret mode on CPU); counters, outputs and state must be
    bit-identical to the while_loop scan path — INCLUDING ``random``, whose
    fold_in draw is deterministic per step, so one-kernel-per-step and
    one-argmin-per-victim see the same keys."""
    tr = _trace_from_blocks(blocks, 32)
    a = S.run(tr, policy=policy, prefetch="tree", oversubscription=oversub, kernels=False)
    b = S.run(tr, policy=policy, prefetch="tree", oversubscription=oversub, kernels=True)
    assert a.stats == b.stats
    np.testing.assert_array_equal(a.fault, b.fault)
    np.testing.assert_array_equal(a.was_evicted, b.was_evicted)
    for field in ("resident", "evicted_once", "last_access", "freq"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.state, field)), np.asarray(getattr(b.state, field)), err_msg=field
        )


_PREF_LANE = st.one_of(
    st.none(),  # no-budget lane interleaved with budgeted ones
    st.lists(st.integers(-3, 3), min_size=32, max_size=32),  # negative + non-uniform
)


@settings(max_examples=8, deadline=None)
@given(
    lane_blocks=st.lists(
        st.lists(st.integers(0, 31), min_size=10, max_size=60), min_size=4, max_size=6
    ),
    prefs=st.lists(_PREF_LANE, min_size=6, max_size=6),
    policy=st.sampled_from(["lru", "hpe", "learned"]),
)
def test_evict_pref_padding_invariant(lane_blocks, prefs, policy):
    """The `evict_pref` padding claim, hardened (ISSUE 10 satellite): lanes
    whose prefs are negative, non-uniform, or ``None``-interleaved must run
    bit-identically batched (``run_segments_many`` pads lanes and ``None``
    entries with zero pref rows) and solo (``run_segment``).  Zero-filled
    PADDING blocks never become candidates (padding blocks are never
    resident), and a ``None`` lane's all-zero pref row is a constant leading
    key, which never changes an argmin — this property is the proof."""
    nb = 32
    cap = 20
    cell = (S.POLICY_IDS[policy], S.PREFETCH_IDS["tree"], cap)
    states = [S.init_state(nb) for _ in lane_blocks]
    segs = []
    for lb in lane_blocks:
        b = np.asarray(lb, np.int32)
        segs.append((b, S.precompute_next_use(b, nb)))
    eps = [None if prefs[i] is None else np.asarray(prefs[i], np.int32)
           for i in range(len(lane_blocks))]
    batched = S.run_segments_many(
        states, segs, [cell] * len(segs), [nb] * len(segs), evict_prefs=eps
    )
    for i, (st_b, outs_b) in enumerate(batched):
        st_s, outs_s = S.run_segment(
            S.init_state(nb), *segs[i], capacity=cap, policy=policy, prefetch="tree",
            n_valid=nb, evict_pref=eps[i],
        )
        for field in ("resident", "evicted_once", "occupancy", "faults", "thrash_events"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_b, field)), np.asarray(getattr(st_s, field)),
                err_msg=f"lane {i} {field}",
            )
        for k in outs_s:
            np.testing.assert_array_equal(outs_b[k], outs_s[k], err_msg=f"lane {i} {k}")


# --- compression -----------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=300))
def test_quantize_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s, shp = C.quantize(x, block=64)
    deq = C.dequantize(q, s, shp)
    # error per element bounded by half a quant step of its block
    blocks = np.abs(np.asarray(x)).max() if len(xs) else 0
    err = np.abs(np.asarray(deq) - np.asarray(x)).max()
    assert err <= max(blocks / 127.0, 1e-6) + 1e-6


# --- elastic ---------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096))
def test_plan_mesh_properties(n):
    pod, data, model = plan_mesh(n)
    assert pod * data * model == n
    assert model <= 16


# --- features --------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(pages=st.lists(st.integers(0, 500), min_size=15, max_size=80))
def test_feature_windows_alignment(pages):
    pages = np.asarray(pages, np.int32)
    n = len(pages)
    tr = T.Trace("x", pages, np.zeros(n, np.int32), np.zeros(n, np.int32), np.zeros(n, np.int32), 512)
    vocab = DeltaVocab(256)
    fs = extract(tr, vocab, history=4)
    # label at sample i is the delta class of access t_index[i]
    deltas = np.diff(pages.astype(np.int64), prepend=pages[0])
    for i in range(len(fs)):
        t = fs.t_index[i]
        assert fs.label[i] == vocab.table.get(int(deltas[t]), fs.label[i])
        assert fs.label_page[i] == pages[t]
