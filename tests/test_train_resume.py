"""Fault-tolerance integration: a training run killed at step k and resumed
from its checkpoint must produce the SAME final state as an uninterrupted run
(deterministic pipeline + exact checkpoint restore)."""
import json
import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(args, timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
         "--batch", "2", "--seq", "32", "--log-every", "1"] + args,
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    final = [l for l in out.stdout.splitlines() if l.startswith("{\"final_loss\"")]
    return json.loads(final[-1])


def test_resume_matches_uninterrupted(tmp_path):
    a = tmp_path / "a"
    b = tmp_path / "b"
    # uninterrupted 8 steps
    full = _run(["--steps", "8", "--out", str(a)])
    # interrupted at 4, resumed to 8
    _run(["--steps", "4", "--ckpt-every", "4", "--out", str(b)])
    resumed = _run(["--steps", "8", "--ckpt-every", "4", "--out", str(b), "--resume"])
    assert abs(full["final_loss"] - resumed["final_loss"]) < 1e-4, (full, resumed)


def test_straggler_drop_still_trains(tmp_path):
    out = _run(["--steps", "6", "--accum", "2", "--simulate-straggler-drop", "--out", str(tmp_path / "s")])
    assert out["final_loss"] < 6.5  # finite + sane
