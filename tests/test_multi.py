"""Multi-tenant streaming management (ISSUE 5 tentpole).

The guarantees pinned here:

* the Section V-F concurrent golden cells — `runtime.run_ours` over a
  tenant-tagged `trace.concurrent()` merge is bit-pinned under BOTH
  treatments (merged-single-manager baseline AND the `TenantMux`),
  exactly like the 11 single-tenant benchmarks;
* demuxing a merge through `TenantMux` with ISOLATED tables is counter-
  and top-1-identical to running each tenant's stream through its own
  standalone `OversubscriptionManager` (deterministic pin + a hypothesis
  net over arbitrary interleavings and fault clocks);
* streaming periodic re-classification: the classifier re-runs every
  `reclass_interval` faults and hysteresis never flips the active pattern
  on a single disagreeing window;
* the `cli serve` sidecar's tenant field and structured error lines
  (malformed input can never produce a traceback).

The hypothesis properties drive the manager with a stub trainer (pure
numpy, deterministic): the properties at stake live in the demux/clock/
flush/hysteresis plumbing, not the predictor, and a real NN would retrace
jits on every example's batch shape.
"""
import dataclasses
import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig
from repro.uvm import runtime as R
from repro.uvm import trace as T
from repro.uvm.manager import (
    FaultBatch,
    ManagerConfig,
    Outcomes,
    OversubscriptionManager,
    TenantMux,
)

GOLDEN = json.loads((Path(__file__).parent / "golden" / "ours_golden.json").read_text())
SCALE, CAP = 0.3, 3000  # must match tests/golden/generate_ours_golden.py
TCFG = TrainConfig(group_size=1024, epochs=2, batch_size=128)
CONCURRENT_PAIRS = (("StreamTriad", "Hotspot"), ("ATAX", "Srad-v2"))


def _bench_trace(name: str) -> T.Trace:
    tr = T.get_trace(name, scale=SCALE)
    return tr.slice(0, min(len(tr), CAP))


def _concurrent_trace(pair) -> T.Trace:
    return T.concurrent([_bench_trace(n) for n in pair], seed=0, slice_len=TCFG.group_size)


# --- the stub predictor stack (fast, deterministic, no jit retraces) ---------


class _StubTrainer:
    """Deterministic pure-numpy stand-in for `Trainer`: predicts the
    window's last delta class, counts updates. Exercises every manager
    code path (eval -> actions -> fine-tune) at hypothesis speed."""

    def new_params(self, seed: int = 0):
        return np.zeros(1)

    def evaluate(self, params, fs, n_active: int):
        pred = fs.delta[:, -1] % max(n_active, 1)
        return pred == fs.label, pred

    def evaluate_many(self, params_list, fs_list, n_active_list):
        return [self.evaluate(p, f, n) for p, f, n in zip(params_list, fs_list, n_active_list)]

    def train_group(self, entry, fs, n_active, *, in_et=None, use_lucir=False, rng=None):
        entry.n_updates += 1
        return entry

    def train_group_many(self, entries, fs_list, n_active_list, *, in_et_list=None, use_lucir=False):
        for e in entries:
            e.n_updates += 1
        return entries


def _stub_cfg(**kw) -> ManagerConfig:
    kw.setdefault("predictor", SMOKE)
    kw.setdefault("train", TrainConfig(group_size=64, epochs=1, batch_size=32))
    kw.setdefault("n_pages", 1024)
    kw.setdefault("n_blocks", 64)
    kw.setdefault("capacity", 16)
    kw.setdefault("use_lucir", False)  # the stub has no params to snapshot
    kw.setdefault("use_thrash_term", False)
    return ManagerConfig(**kw)


def _stub_mux(tenants, **kw) -> TenantMux:
    shared = kw.pop("shared_freq_table", False)
    cfg = _stub_cfg(**kw)
    return TenantMux(cfg, tenants, shared_freq_table=shared,
                     auto_create=False, trainer=_StubTrainer())


def _stub_manager(**kw) -> OversubscriptionManager:
    return OversubscriptionManager(_stub_cfg(**kw), trainer=_StubTrainer())


def _drive_equivalence(tenant_batches, fault_counts, tenants=(0, 1)):
    """Drive a mux with tagged merged batches and standalone managers with
    the demuxed sub-batches; assert identical per-tenant state."""
    mux = _stub_mux(tenants)
    solo = {t: _stub_manager() for t in tenants}
    for (pages, tags), fc in zip(tenant_batches, fault_counts):
        mux.observe(FaultBatch(pages, tenant=tags))
        mux.feedback(Outcomes(was_evicted=np.zeros(len(pages), bool), fault_count=fc))
        seen = []
        for t in tags:  # first-appearance order, like the mux split
            if t not in seen:
                seen.append(t)
        for t in seen:
            idx = np.flatnonzero(tags == t)
            solo[t].observe(FaultBatch(pages[idx]))
            solo[t].feedback(Outcomes(was_evicted=np.zeros(len(idx), bool), fault_count=fc))
    for t in tenants:
        m, s = mux.managers[t], solo[t]
        assert m.top1 == s.top1
        assert m.per_group == s.per_group
        assert m.n_predictions == s.n_predictions
        assert m.vocab.table == s.vocab.table
        assert np.array_equal(m.freq_table.dense(64), s.freq_table.dense(64))
        assert np.array_equal(m.freq_table.tags, s.freq_table.tags)
        assert m.freq_table.flushes == s.freq_table.flushes
        assert m._flush_interval == s._flush_interval
        assert m._interval == s._interval
        assert np.array_equal(m._chain_li, s._chain_li)


# --- concurrent golden cells (merged baseline AND mux, bit-pinned) -----------


@pytest.mark.parametrize("pair", CONCURRENT_PAIRS, ids=lambda p: "+".join(p))
@pytest.mark.parametrize("treatment", ["merged", "mux"])
def test_concurrent_golden_bit_identical(pair, treatment):
    """The Section V-F cells must not move a counter or accuracy bit under
    either tenancy treatment (regenerate via generate_ours_golden.py)."""
    res = R.run_ours(_concurrent_trace(pair), SMOKE, TCFG, multi_tenant=treatment == "mux")
    g = GOLDEN[f"concurrent:{'+'.join(pair)}|{treatment}"]
    assert res.stats == g["stats"]
    assert res.top1 == g["top1"]
    assert res.warm_top1 == g["warm_top1"]
    assert res.per_group_acc == g["per_group_acc"]
    assert res.n_predictions == g["n_predictions"]
    assert res.n_classes == g["n_classes"]
    assert res.n_models == g["n_models"]
    if treatment == "mux":
        assert res.per_tenant_top1 == g["per_tenant_top1"]
    else:
        assert res.per_tenant_top1 is None


def test_golden_check_mode(tmp_path):
    """The drift gate: --check passes on the committed file and fails on a
    tampered copy (scoped to one cheap cell so the test stays quick)."""
    spec = importlib.util.spec_from_file_location(
        "generate_ours_golden", Path(__file__).parent / "golden" / "generate_ours_golden.py"
    )
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)
    assert gen.check(["AddVectors"]) == 0
    tampered = dict(GOLDEN)
    tampered["AddVectors"] = {**tampered["AddVectors"], "top1": 0.123}
    bad = tmp_path / "ours_golden.json"
    bad.write_text(json.dumps(tampered))
    assert gen.check(["AddVectors"], path=bad) == 1
    missing = tmp_path / "missing.json"
    missing.write_text(json.dumps({k: v for k, v in GOLDEN.items() if k != "AddVectors"}))
    assert gen.check(["AddVectors"], path=missing) == 1


# --- mux == standalone managers (isolated tables) ----------------------------


def test_mux_equivalent_to_standalone_real_predictor():
    """The headline property on a real concurrent merge with the REAL
    predictor stack: per-tenant accuracy, vocabulary, frequency-table
    state and flush clocks all match standalone managers fed the demuxed
    stream."""
    tr = _concurrent_trace(("StreamTriad", "Hotspot")).slice(0, 3000)
    cfg_kw = dict(
        predictor=SMOKE, train=TrainConfig(group_size=512, epochs=1, batch_size=64),
        n_pages=tr.n_pages, n_blocks=256, capacity=64,
    )
    mux = TenantMux(ManagerConfig(**cfg_kw), (0, 1), auto_create=False)
    solo = {t: OversubscriptionManager(ManagerConfig(**cfg_kw)) for t in (0, 1)}
    G, fc = 512, 0
    for g0 in range(0, len(tr), G):
        g1 = min(g0 + G, len(tr))
        tags = tr.tenant[g0:g1]
        fc += (g1 - g0) // 4
        mux.observe(FaultBatch(tr.page[g0:g1], tr.pc[g0:g1], tr.tb[g0:g1], tr.kernel[g0:g1], tenant=tags))
        mux.feedback(Outcomes(was_evicted=np.zeros(g1 - g0, bool), fault_count=fc))
        for t in (0, 1):
            idx = np.flatnonzero(tags == t)
            if len(idx) == 0:
                continue
            solo[t].observe(FaultBatch(
                tr.page[g0:g1][idx], tr.pc[g0:g1][idx], tr.tb[g0:g1][idx], tr.kernel[g0:g1][idx]))
            solo[t].feedback(Outcomes(was_evicted=np.zeros(len(idx), bool), fault_count=fc))
    for t in (0, 1):
        m, s = mux.managers[t], solo[t]
        assert m.top1 == s.top1 and m.per_group == s.per_group
        assert np.array_equal(m.freq_table.dense(256), s.freq_table.dense(256))
        assert m._flush_interval == s._flush_interval


def test_mux_shared_vs_isolated_freq_table():
    """'mux-shared' gives every tenant ONE table object (the paper's single
    SRAM budget); isolated gives each its own. The combined dense export
    follows suit."""
    shared = _stub_mux((0, 1), shared_freq_table=True)
    # each manager holds a no-flush VIEW of the one shared table
    assert shared.managers[0].freq_table._table is shared.managers[1].freq_table._table is shared._shared_freq
    isolated = _stub_mux((0, 1))
    assert isolated.managers[0].freq_table is not isolated.managers[1].freq_table
    pages = np.arange(64)
    tags = np.repeat([0, 1], 32)
    for mux in (shared, isolated):
        for step in range(4):
            mux.observe(FaultBatch((pages + 16 * step) % 1024, tenant=tags))
            mux.feedback(Outcomes(fault_count=16 * (step + 1)))
    dense = np.maximum.reduce([m.freq_table.dense(64) for m in isolated.managers.values()])
    assert np.array_equal(isolated._combined_dense(), dense)
    assert np.array_equal(shared._combined_dense(), shared.managers[0].freq_table.dense(64))


def test_shared_table_flush_cadence_is_per_device_interval():
    """The shared table must flush on the DEVICE interval clock, not once
    per tenant per interval: N tenants reporting the same global clock
    flush exactly as often as one standalone manager would."""
    mux = _stub_mux((0, 1, 2), shared_freq_table=True)
    solo = _stub_manager()
    tags = np.repeat([0, 1, 2], 16)
    for step in range(7):  # 7 device intervals -> 2 flushes at cadence 3
        fc = 64 * (step + 1)
        mux.observe(FaultBatch(np.arange(48) % 1024, tenant=tags))
        mux.feedback(Outcomes(fault_count=fc))
        solo.observe(FaultBatch(np.arange(48) % 1024))
        solo.feedback(Outcomes(fault_count=fc))
    assert mux._shared_freq.flushes == solo.freq_table.flushes == 2
    # the managers' views surface the shared table's state
    assert mux.managers[0].freq_table.flushes == 2


def test_tenant_feedback_then_round_feedback():
    """Closing one tenant's batch explicitly (the serve sidecar's per-line
    pairing) must drop it from the pending round: a subsequent round-level
    feedback closes ONLY the remaining tenants, nobody raises, nobody's
    fine-tune is lost."""
    mux = _stub_mux((0, 1))
    pages = np.arange(64)
    tags = np.repeat([0, 1], 32)
    mux.observe(FaultBatch(pages, tenant=tags))
    mux.feedback(Outcomes(fault_count=10), tenant=0)
    mux.feedback(Outcomes(was_evicted=np.zeros(64, bool), fault_count=12))  # closes tenant 1 only
    assert mux._round is None
    # both tenants are cleanly observable again
    out = mux.observe(FaultBatch(pages, tenant=tags))
    assert set(out.per_tenant) == {0, 1}
    mux.feedback(Outcomes(fault_count=20))


def test_reclass_windows_advance_without_feedback():
    """A feedback-less consumer (the serve auto-close mode reports no
    fault counts) must still re-classify: the observed-access clock is the
    fallback window trigger."""
    mgr = _reclass_manager([0] * 10, interval=64, k=2)
    for _ in range(6):
        mgr.observe(FaultBatch(np.arange(48)))
        mgr.feedback(Outcomes(fault_count=0))  # the clock never moves
    # seed + a window every ceil(64/48)=2nd batch thereafter
    assert mgr.classifier.calls >= 3


def test_mux_fault_clock_rebase_through_consumer_switch():
    """The global fault clock re-bases per tenant manager exactly like a
    single manager would (a consumer restart must not stall the flush
    cadence of any tenant)."""
    mux = _stub_mux((0,))
    mux.observe(FaultBatch(np.arange(32), tenant=np.zeros(32, np.int64)))
    mux.feedback(Outcomes(fault_count=10 * 64))
    assert mux.managers[0]._flush_interval == 10
    mux.observe(FaultBatch(np.arange(32), tenant=np.zeros(32, np.int64)))
    mux.feedback(Outcomes(fault_count=3 * 64))  # restarted consumer clock
    assert mux.managers[0]._flush_interval == 13


def test_mux_misuse_raises():
    mux = _stub_mux((0, 1))
    with pytest.raises(RuntimeError):
        mux.feedback(Outcomes())  # no pending round
    with pytest.raises(KeyError):  # auto_create=False rejects unknown tags
        mux.observe(FaultBatch(np.arange(8), tenant=np.full(8, 7)))
    with pytest.raises(ValueError):  # misaligned tag array
        FaultBatch(np.arange(8), tenant=np.zeros(3))
    mux2 = _stub_mux((0,))
    mux2.observe(FaultBatch(np.arange(8), tenant=np.zeros(8, np.int64)))
    with pytest.raises(RuntimeError):  # same tenant observed twice
        mux2.observe(FaultBatch(np.arange(8), tenant=np.zeros(8, np.int64)))


def test_mux_auto_create_admits_new_tenants():
    mux = TenantMux(_stub_cfg(), trainer=_StubTrainer())  # auto_create default
    out = mux.observe(FaultBatch(np.arange(16), tenant=np.repeat(["A", "B"], 8)))
    assert set(out.per_tenant) == {"A", "B"} and len(mux.managers) == 2
    mux.feedback(Outcomes(fault_count=8))
    assert mux.per_tenant_top1.keys() == {"A", "B"}


def test_run_ours_many_mux_lane_matches_serial():
    """A tenant-tagged lane through the lockstep engine must reproduce the
    serial mux driver bit for bit (single-tenant lanes already pinned)."""
    conc = _concurrent_trace(("StreamTriad", "Hotspot")).slice(0, 1200)
    tcfg = TrainConfig(group_size=256, epochs=1, batch_size=64)
    serial = R.run_ours(conc, SMOKE, tcfg)
    [many] = R.run_ours_many([conc], SMOKE, tcfg)
    assert many.stats == serial.stats
    assert many.top1 == serial.top1
    assert many.per_tenant_top1 == serial.per_tenant_top1


# --- streaming periodic re-classification ------------------------------------


def _check_hysteresis_property(script, k):
    """Property body (shared by the hypothesis net and any local driver):
    whenever the active pattern changes, the challenger proposed it in k
    CONSECUTIVE windows; with k>=2 a lone disagreeing window never flips."""
    mgr = _reclass_manager(script, interval=64, k=k)
    seen = []
    _drive_windows(mgr, seen, len(script))
    proposals = script[: mgr.classifier.calls]
    for i in range(1, len(seen)):
        if seen[i] != seen[i - 1]:  # a switch surfaced at window i
            run = proposals[i - k + 1 : i + 1]
            assert run == [seen[i]] * k, (script, k, seen)
    if k >= 2:
        for i in range(1, len(proposals) - 1):
            lone = proposals[i] != proposals[i - 1] and proposals[i] != proposals[i + 1]
            if lone:
                assert seen[i] != proposals[i] or proposals[i] == seen[i - 1], (script, seen)


def _check_serve_line_contract(line: str):
    """Property body: the serve decoder returns a decoded tuple or raises
    the structured _ServeLineError — never anything else; accepted observe
    payloads are numpy-ready."""
    from repro.uvm.cli import _ServeLineError, _decode_serve_line

    try:
        kind, (tenant, tagged), payload = _decode_serve_line(line, "default")
    except _ServeLineError:
        return
    assert kind in ("observe", "feedback", "hello")
    if kind == "observe":
        assert payload["pages"].dtype == np.int64


class _ScriptedClassifier:
    """Replays a fixed pattern sequence; counts invocations."""

    def __init__(self, script):
        self.script = list(script)
        self.calls = 0

    def classify(self, blocks, kernels):
        pat = self.script[min(self.calls, len(self.script) - 1)]
        self.calls += 1
        return pat

    def reset(self):
        pass


def _reclass_manager(script, interval=64, k=2):
    cfg = _stub_cfg(reclass_interval=interval, reclass_hysteresis=k)
    return OversubscriptionManager(cfg, trainer=_StubTrainer(),
                                   classifier=_ScriptedClassifier(script))


def _drive_windows(mgr, patterns_seen, n_windows, faults_per_window=64):
    fc = mgr._fault_base + mgr._fault_raw
    for _ in range(n_windows):
        a = mgr.observe(FaultBatch(np.arange(48)))
        patterns_seen.append(a.pattern)
        fc += faults_per_window
        mgr.feedback(Outcomes(fault_count=fc))


def test_reclass_single_disagreeing_window_never_flips():
    """One divergent classification window must NEVER switch the active
    pattern (hysteresis k=2): LINEAR, one RANDOM blip, LINEAR again."""
    mgr = _reclass_manager([0, 0, 2, 0, 0, 0], interval=64, k=2)
    seen = []
    _drive_windows(mgr, seen, 6)
    assert seen == [0] * 6  # the blip at window 3 never surfaced
    assert mgr.n_pattern_switches == 0


def test_reclass_k_consecutive_windows_switch():
    """k consecutive agreeing windows DO switch, exactly once, and the
    displaced pattern's model entry survives in the table."""
    mgr = _reclass_manager([0, 0, 2, 2, 2, 2], interval=64, k=2)
    seen = []
    _drive_windows(mgr, seen, 6)
    assert seen == [0, 0, 0, 2, 2, 2]  # switch lands ON the k-th agreeing window
    assert mgr.n_pattern_switches == 1
    assert 0 in mgr.table.slots and 2 in mgr.table.slots  # both models warm


def test_reclass_interval_gates_classifier_calls():
    """Between windows the classifier does not run at all (the whole point:
    bounded classification work on an endless stream)."""
    mgr = _reclass_manager([0] * 10, interval=128, k=2)
    seen = []
    _drive_windows(mgr, seen, 8, faults_per_window=64)  # 2 batches per window
    # call 1 seeds; thereafter every 128 faults = every second batch
    assert mgr.classifier.calls == 1 + 3
    assert seen == [0] * 8
    legacy = OversubscriptionManager(_stub_cfg(), trainer=_StubTrainer(),
                                     classifier=_ScriptedClassifier([0] * 10))
    _drive_windows(legacy, [], 8)
    assert legacy.classifier.calls == 8  # reclass_interval=0: every batch


# --- hypothesis net ----------------------------------------------------------


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @st.composite
    def _tagged_stream(draw):
        """Arbitrary tenant interleavings + a fault clock with restarts."""
        n_tenants = draw(st.integers(1, 3))
        n_batches = draw(st.integers(1, 6))
        batches, fault_counts = [], []
        clock = 0
        for _ in range(n_batches):
            n = draw(st.integers(1, 48))
            pages = np.asarray(draw(st.lists(st.integers(0, 1023), min_size=n, max_size=n)))
            tags = np.asarray(draw(st.lists(st.integers(0, n_tenants - 1), min_size=n, max_size=n)))
            batches.append((pages, tags))
            if draw(st.booleans()):
                clock = draw(st.integers(0, 64))  # consumer restart (rebase)
            else:
                clock += draw(st.integers(0, 256))
            fault_counts.append(clock)
        return n_tenants, batches, fault_counts

    @settings(max_examples=40, deadline=None)
    @given(_tagged_stream())
    def test_mux_standalone_equivalence_hypothesis(stream):
        """Demux through TenantMux with isolated tables == standalone
        managers, under ARBITRARY interleavings, batch shapes and fault
        clocks (incl. restarts): accuracy, vocab, counters, flush cadence
        and chain state all match per tenant."""
        n_tenants, batches, fault_counts = stream
        _drive_equivalence(batches, fault_counts, tenants=tuple(range(n_tenants)))

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(0, 2), min_size=1, max_size=12), st.integers(1, 3))
    def test_reclass_hysteresis_property(script, k):
        """Whenever the active pattern changes, the challenger proposed it
        in k CONSECUTIVE windows; with k>=2 a single disagreeing window
        (its neighbours differing) never flips."""
        _check_hysteresis_property(script, k)

    @settings(max_examples=150, deadline=None)
    @given(st.text(max_size=120))
    def test_serve_line_decoder_fuzz_text(line):
        """Arbitrary text: the serve decoder returns a decoded tuple or
        raises the structured _ServeLineError — never anything else."""
        _check_serve_line_contract(line)

    _json_scalars = st.one_of(st.none(), st.booleans(), st.integers(-4, 400),
                              st.floats(allow_nan=False), st.text(max_size=6))

    @settings(max_examples=150, deadline=None)
    @given(st.dictionaries(
        st.sampled_from(["pages", "feedback", "tenant", "pc", "tb", "kernel",
                         "was_evicted", "fault_count", "junk"]),
        st.one_of(_json_scalars, st.lists(_json_scalars, max_size=6),
                  st.dictionaries(st.sampled_from(["was_evicted", "fault_count", "x"]),
                                  st.one_of(_json_scalars, st.lists(_json_scalars, max_size=6)),
                                  max_size=3)),
        max_size=5,
    ))
    def test_serve_line_decoder_fuzz_records(rec):
        """Arbitrary JSON records: same contract, plus any accepted observe
        payload really is numpy-convertible."""
        _check_serve_line_contract(json.dumps(rec))

except ImportError:  # pragma: no cover - tier-1 must collect without hypothesis
    pass


# --- the serve sidecar: tenants + error lines --------------------------------


def test_cli_serve_tenant_roundtrip(tmp_path, capsys):
    """Tagged lines route to per-tenant pipelines (tenant echoed on the
    action line), untagged lines keep the legacy single-manager shape, and
    malformed lines become structured {"error", "line"} records — never a
    traceback."""
    from repro.uvm import cli

    lines = []
    for b in range(4):
        t = "A" if b % 2 == 0 else "B"
        lines.append(json.dumps({"pages": [(i + b * 5) % 300 for i in range(40)], "tenant": t}))
        lines.append(json.dumps({"feedback": {"was_evicted": [False] * 40,
                                              "fault_count": 64 * (b + 1)}, "tenant": t}))
    lines += [
        "not json at all",
        json.dumps({"pages": "nope"}),
        json.dumps({"pages": [1, 2], "feedback": {}}),
        # an outcome report with nothing to apply it to is lost data
        json.dumps({"feedback": {"was_evicted": [False], "fault_count": 3}, "tenant": "C"}),
        json.dumps({"pages": [1, 2, 3], "tenant": 5.5}),  # non-str/int tenant
        # a bare fault_count with no pending batch seeds the clock (legacy
        # PR-4 input, accepted silently — no error line)
        json.dumps({"feedback": {"fault_count": 999}}),
        json.dumps({"pages": [1, 2, 3]}),  # untagged -> default tenant
        # misaligned was_evicted must be a structured error, not a traceback
        json.dumps({"feedback": {"was_evicted": [True, True], "fault_count": 999}}),
    ]
    stream = tmp_path / "faults.jsonl"
    stream.write_text("\n".join(lines) + "\n")
    assert cli.main(["serve", "--input", str(stream), "--n-pages", "300",
                     "--pages-per-block", "4", "--capacity", "16", "--group-size", "32"]) == 0
    out = capsys.readouterr().out.strip().splitlines()
    recs = [json.loads(l) for l in out if l.startswith("{")]
    acts = [r for r in recs if "batch" in r]
    errs = [r for r in recs if "error" in r]
    assert [a.get("tenant") for a in acts] == ["A", "B", "A", "B", None]
    assert len(errs) == 6 and all(isinstance(e["line"], int) for e in errs)
    assert "expected 3, got 2" in errs[-1]["error"]  # misaligned was_evicted
    assert out[-1].startswith("# serve batches=5")
    assert "tenants=3 errors=6" in out[-1]


def test_cli_serve_strict_exit(tmp_path, capsys):
    from repro.uvm import cli

    stream = tmp_path / "bad.jsonl"
    stream.write_text("garbage\n")
    assert cli.main(["serve", "--input", str(stream), "--n-pages", "64"]) == 0
    assert cli.main(["serve", "--input", str(stream), "--n-pages", "64", "--strict"]) == 2
    capsys.readouterr()


# --- spec/session surface ----------------------------------------------------


def test_tenancy_spec_round_trip_and_validation():
    from repro.uvm.api import ModelSpec

    m = ModelSpec(tenancy="mux-shared", reclass_interval=256, reclass_hysteresis=3)
    back = ModelSpec.from_dict(m.to_dict())
    assert back == m and back.key == m.key
    assert ModelSpec.from_dict(ModelSpec().to_dict()).tenancy == "mux"
    with pytest.raises(ValueError):
        ModelSpec(tenancy="bogus")


def test_session_routes_concurrent_ours_through_mux(tmp_path):
    """An `ours` cell on a concurrent workload runs the mux (per-tenant
    top-1 recorded, store round-trip included); tenancy='merged' forces
    the baseline and reproduces the merged golden."""
    from repro.uvm.api import ModelSpec, RunStore, Session, TrainSpec

    s = Session(scale=SCALE, cap=CAP, model=ModelSpec(predictor=SMOKE, train=TrainSpec(
        group_size=TCFG.group_size, epochs=TCFG.epochs, batch_size=TCFG.batch_size,
    )), store=RunStore(tmp_path / "runs"))
    w = s.concurrent(("StreamTriad", "Hotspot"), slice_len=TCFG.group_size)
    # strip the session's default pretrain so the cells match the golden
    cell_mux = dataclasses.replace(s.ours_cell(w), model=s.model)
    cell_merged = dataclasses.replace(
        s.ours_cell(w), model=dataclasses.replace(s.model, tenancy="merged"))
    assert cell_mux.key != cell_merged.key  # tenancy is part of the contract
    res_mux, res_merged = s.sweep([cell_mux, cell_merged])
    g_mux = GOLDEN["concurrent:StreamTriad+Hotspot|mux"]
    g_merged = GOLDEN["concurrent:StreamTriad+Hotspot|merged"]
    assert res_mux.stats == g_mux["stats"] and res_mux.top1 == g_mux["top1"]
    assert res_mux.per_tenant_top1 == g_mux["per_tenant_top1"]
    assert res_merged.stats == g_merged["stats"] and res_merged.top1 == g_merged["top1"]
    # store round-trip preserves the per-tenant split
    s2 = Session(scale=SCALE, cap=CAP, model=s.model, store=RunStore(tmp_path / "runs"))
    again = s2.sweep([cell_mux])[0]
    assert s2.counters["store_hits"] == 1 and s2.counters["computed"] == 0
    assert again.per_tenant_top1 == res_mux.per_tenant_top1


def test_offload_adapter_reclass_knobs():
    """The serving adapter threads the re-classification knobs into its
    default manager (the endless decode stream is where windowed
    classification pays); behavior with interval 0 is the legacy cadence."""
    from repro.serving.offload import LearnedOffloadManager

    off = LearnedOffloadManager(32, 8, group=16, reclass_interval=128, reclass_hysteresis=3)
    assert off.manager.cfg.reclass_interval == 128
    assert off.manager.cfg.reclass_hysteresis == 3
    rng = np.random.default_rng(0)
    for step in range(40):
        mass = np.zeros(32)
        touched = np.unique(rng.integers(0, 32, 6))
        mass[touched] = 1.0
        off.on_attention(mass, touched)
    assert off.stats.hbm_hits + off.stats.hbm_misses > 0
    assert off.manager.n_reclassifications >= 1


def test_session_manager_accepts_tenant_lists():
    from repro.uvm.api import Session

    s = Session(scale=0.25, cap=800)
    mux = s.manager(["StreamTriad", "Hotspot"])
    assert isinstance(mux, TenantMux) and len(mux.managers) == 2
    assert isinstance(s.manager("ATAX"), OversubscriptionManager)
    merged = s.manager(s.concurrent(("StreamTriad", "Hotspot")), tenancy="merged")
    assert not isinstance(merged, TenantMux)
