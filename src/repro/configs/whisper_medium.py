"""whisper-medium [audio] — 24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865.

Enc-dec; the conv audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, enc_len, enc_feat). Encoder length is
whisper's native 1500 (30 s window); the assigned seq_len drives the decoder.
LayerNorm + GELU MLP + learned decoder positions, biases everywhere (whisper
style). [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,  # decoder layers
    enc_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    norm="ln",
    act="gelu",
    pos="learned",
    qkv_bias=True,
    enc_len=1500,
    enc_feat=128,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="whisper-medium-smoke",
    num_layers=2,
    enc_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    enc_len=24,
    enc_feat=16,
)
