"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304.

64 experts, top-8 routing, qk_norm. [arXiv:2409.02060; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,  # unused for routed layers; kept for completeness
    moe_d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    qk_norm=True,
    num_experts=64,
    top_k=8,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(
    name="olmoe-1b-7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    moe_d_ff=32,
    vocab_size=503,
    num_experts=8,
    top_k=2,
)
