"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.

Mamba2 backbone + shared attention block applied every 6 SSM layers
(weights shared across applications; each application keeps its own KV cache).
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    conv_width=4,
    attn_every=6,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(
    name="zamba2-7b-smoke",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    ssm_state=16,
    ssm_headdim=16,
    attn_every=2,
    ssm_chunk=16,
)
