"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, num_patches, patch_feat) which are projected
and prepended to the token sequence. Backbone = InternLM2-style decoder (GQA,
SwiGLU). [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    num_patches=256,
    patch_feat=3200,  # InternViT-6B hidden size
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="internvl2-26b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=517,
    num_patches=8,
    patch_feat=24,
)
