"""Config registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Every assigned architecture is selectable with ``--arch <id>`` in the
launchers; ``ARCHS`` lists the 10 assigned IDs in pool order.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    LONG_CONTEXT_FAMILIES,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_supported,
)

_MODULES = {
    "whisper-medium": "repro.configs.whisper_medium",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "predictor-paper": "repro.configs.predictor_paper",
}

ARCHS = [a for a in _MODULES if a != "predictor-paper"]


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).SMOKE
