"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.

GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(
    name="granite-3-8b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=517,
)
