"""Model + input-shape configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm: str = "rms"  # rms | ln
    act: str = "swiglu"  # swiglu | gelu
    pos: str = "rope"  # rope | learned | none
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): shared attention block applied every `attn_every` SSM layers
    attn_every: int = 0

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_len: int = 1500  # whisper 30s window -> 1500 frames
    enc_feat: int = 128  # stub frontend feature dim (precomputed frame embeddings)

    # vlm (internvl2)
    num_patches: int = 0
    patch_feat: int = 0  # stub frontend patch-embedding dim

    # numerics / padding
    dtype: str = "bfloat16"
    vocab_pad: int = 256
    kv_quant: str = "none"  # none | int8 — per-token-head symmetric KV quantisation

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad)

    @property
    def ssm_dinner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_dinner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Analytic parameter / FLOP accounting (used by the roofline report).
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        from repro.models import lm

        return lm.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import lm

        return lm.active_param_count(self)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run the long_500k cell (sub-quadratic sequence mixing).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell applies; reason if not."""
    if shape.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, "full-attention arch: long_500k skipped (quadratic prefill / unbounded KV); see DESIGN.md"
    return True, ""
