"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408/expert vocab=151936.

4 shared + 60 routed experts, top-4 routing, QKV bias.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Sharding note: 60 routed experts do not divide the 16-way model axis; under
expert-parallel dispatch the routed experts are padded to 64 with router
masking (see DESIGN.md §4). `num_experts` stays at the published 60 — padding
is an implementation detail of the dispatcher.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="qwen2-moe-a2.7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    moe_d_ff=32,
    vocab_size=503,
    num_experts=6,
    num_shared_experts=2,
    top_k=2,
)
