"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.

qk_norm, GQA, head_dim=128. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="qwen3-0.6b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=503,
)
