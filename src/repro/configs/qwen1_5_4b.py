"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.

QKV bias (MHA: kv == q heads). [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="qwen1.5-4b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=112,
    vocab_size=503,
)
