"""mamba2-370m [ssm] — 48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality). [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=1,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    conv_width=4,
    norm="rms",
)

SMOKE = CONFIG.replace(
    name="mamba2-370m-smoke",
    num_layers=2,
    d_model=64,
    ssm_state=16,
    ssm_headdim=16,
    vocab_size=503,
    ssm_chunk=16,
)
