"""The paper's own model: the dual-block Transformer page predictor.

This is not one of the assigned LM architectures — it is the paper's
contribution (Section IV-B), registered here so that the same launcher /
trainer / dry-run machinery can train it at fleet scale
(``--arch predictor-paper``). Dimensions follow the paper's footprint budget
(Table IV: 0.27–0.73 MB parameters per pattern model).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class PredictorConfig:
    name: str = "predictor-paper"
    history: int = 10  # input sequence length (Section IV-D)
    d_model: int = 64
    num_heads: int = 2
    num_layers: int = 2  # Transformer layers per block (regular / irregular)
    d_ff: int = 128
    # feature vocabularies (hashed)
    page_vocab: int = 4096
    delta_vocab: int = 1024  # output classes: page deltas (grows incrementally)
    pc_vocab: int = 512
    tb_vocab: int = 512
    dropout: float = 0.0
    # LUCIR cosine classifier
    cosine_scale: float = 16.0
    # loss weights (Eq. 3)
    lucir_lambda: float = 0.5
    thrash_mu: float = 0.5
    num_patterns: int = 6  # DFA classes


CONFIG = PredictorConfig()
SMOKE = PredictorConfig(name="predictor-paper-smoke", d_model=16, d_ff=32, num_heads=2, num_layers=1, page_vocab=64, delta_vocab=32, pc_vocab=16, tb_vocab=16)

# Quick-scale predictor (the benchmarks' and the CLI's `--scale quick`
# default): small enough for CPU minutes, but with a delta vocabulary that
# does NOT alias the benchmarks' delta sets (SMOKE's 32-entry vocab
# hash-collides NW's hundreds of deltas into noise).
CONFIG_QUICK = PredictorConfig(
    name="predictor-quick", d_model=32, num_heads=2, num_layers=1, d_ff=64,
    page_vocab=2048, delta_vocab=512, pc_vocab=64, tb_vocab=64,
)
