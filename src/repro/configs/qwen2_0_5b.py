"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.

GQA, QKV bias. [arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

# Reduced config of the same family for CPU smoke tests.
SMOKE = CONFIG.replace(
    name="qwen2-0.5b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
)
