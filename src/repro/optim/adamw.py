"""AdamW + global-norm clipping + schedules, pytree-functional (no optax).

Optimizer state mirrors the parameter pytree (m, v in fp32), so the sharding
resolver shards it exactly like the parameters (ZeRO-style when params are
FSDP-sharded on the data axis).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    m: dict
    v: dict


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def constant_schedule(lr_val: float):
    return lambda step: jnp.asarray(lr_val, jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def adamw(
    lr: Callable | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip_norm: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_schedule(lr)

    def init(params) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros))

    def update(grads, state: OptState, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
        bc1 = 1 - b1**stepf
        bc2 = 1 - b2**stepf
        lr_t = lr_fn(step)

        def upd(mm, vv, p):
            mhat = mm / bc1
            vhat = vv / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, OptState(m=m, v=v), gnorm

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype), params, updates)
