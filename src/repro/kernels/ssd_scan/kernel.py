"""Chunked SSD (Mamba-2) Pallas TPU kernel.

Grid: (B, nc) with the chunk dimension sequential; the inter-chunk state
(H, P, N) fp32 lives in VMEM scratch, so the recurrence never round-trips
HBM between chunks. Per chunk the kernel computes the intra-chunk quadratic
term + the state contribution exactly like the ref (same einsum graph, fp32).

VMEM budget per program at mamba2-370m dims (Q=256, H=32, P=64, N=128):
  state 32*64*128*4 = 1.0 MB, decay/attention intermediates (Q,Q,H) fp32
  = 8.4 MB, chunk inputs ~1.3 MB -> ~11 MB: fits a v5e core's ~16 MB VMEM
  with Q=256; Q is the tuning knob recorded in §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, final_ref, state_ref, *, nc):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    f32 = jnp.float32
    xq = x_ref[0].astype(f32)  # (Q, H, P)
    dtq = dt_ref[0].astype(f32)  # (Q, H)
    bq = b_ref[0].astype(f32)  # (Q, N)
    cq = c_ref[0].astype(f32)  # (Q, N)
    a = -jnp.exp(a_ref[...].astype(f32))  # (H,)
    state = state_ref[...]  # (H, P, N)

    dA = dtq * a  # (Q, H)
    cum = jnp.cumsum(dA, axis=0)

    # incoming-state contribution
    y_inter = jnp.einsum("qn,hpn->qhp", cq, state) * jnp.exp(cum)[..., None]

    # intra-chunk quadratic term
    Q = xq.shape[0]
    scores = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())), preferred_element_type=f32)  # (Q, Q)
    diff = cum[:, None, :] - cum[None, :, :]  # (i, j, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    att = jnp.where((ii >= jj)[..., None], jnp.exp(diff), 0.0)
    w = att * scores[..., None] * dtq[None, :, :]  # (i, j, H)
    y_intra = jnp.einsum("ijh,jhp->ihp", w, xq)

    # state update for the next chunk
    decay_last = jnp.exp(cum[-1:, :] - cum)  # (Q, H)
    contrib = jnp.einsum("qh,qn,qhp->hpn", decay_last * dtq, bq, xq)
    state_ref[...] = state * jnp.exp(cum[-1])[:, None, None] + contrib

    y_ref[0] = (y_inter + y_intra).astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _fin():
        final_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A_log, b, c, *, chunk: int, initial_state=None, interpret=False):
    """x: (B,L,H,P); dt: (B,L,H); A_log: (H,); b,c: (B,L,N).

    Returns (y, final_state). interpret=True validates on CPU. NOTE: the
    kernel zero-initialises state; a non-zero initial_state falls back to the
    reference (prefill-with-carry is rare in training).
    """
    from repro.kernels.ssd_scan import ref

    if initial_state is not None:
        return ref.ssd_ref(x, dt, A_log, b, c, chunk, initial_state=initial_state)
    B, L, H, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0
    nc = L // chunk

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda i, j: (i, j, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda i, j: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, b, c)
    return y, state
