"""Dispatching wrapper for the SSD mixer: Pallas TPU kernel when enabled,
pure-XLA chunked reference otherwise (the dry-run lowering target)."""
from __future__ import annotations

from repro.kernels.ssd_scan import ref


def ssd(x, dt, A_log, b, c, *, chunk: int, initial_state=None):
    from repro.models.layers import use_pallas

    if use_pallas():
        from repro.kernels.ssd_scan import kernel

        return kernel.ssd_pallas(x, dt, A_log, b, c, chunk=chunk, initial_state=initial_state)
    return ref.ssd_ref(x, dt, A_log, b, c, chunk, initial_state=initial_state)
