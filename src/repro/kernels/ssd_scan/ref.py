"""Pure-jnp oracle for the chunked SSD (Mamba-2) sequence mixer.

Semantics (per batch, head):
    S_t = exp(dt_t * a) * S_{t-1} + dt_t * (b_t ⊗ x_t)      S in R^{P x N}
    y_t = S_t^T-contraction with c_t  (+ no D-skip here; the model adds it)

Chunked evaluation (arXiv:2405.21060): within-chunk quadratic term plus an
across-chunk recurrence carried by a lax.scan. Everything runs in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk(state, xq, dtq, a, bq, cq):
    """One chunk. state: (B,H,P,N) fp32; xq: (B,Q,H,P); dtq: (B,Q,H);
    a: (H,) negative decay rates; bq, cq: (B,Q,N). Returns (state', y).

    Numerics: decay/softplus paths in fp32; the large x/b/c tensors stay in
    their input dtype (bf16 in training) with fp32 einsum accumulation —
    casting them wholesale to fp32 doubled the chunk traffic for no accuracy
    benefit (EXPERIMENTS.md §Perf cell A-3)."""
    f32 = jnp.float32
    wt = xq.dtype  # working dtype of the LARGE tensors (bf16 in training)
    dtq = dtq.astype(f32)
    dA = dtq * a  # (B,Q,H), negative
    cum = jnp.cumsum(dA, axis=1)  # (B,Q,H) fp32

    # contribution of the incoming state (state itself stays fp32 in carry)
    y_inter = jnp.einsum("bqn,bhpn->bqhp", cq, state.astype(wt), preferred_element_type=f32) * jnp.exp(cum)[..., None]

    # within-chunk quadratic term
    Q = xq.shape[1]
    scores = jnp.einsum("bin,bjn->bij", cq, bq, preferred_element_type=f32)  # (B,Q,Q)
    diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    att = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
    w = (att * scores[..., None] * dtq[:, None, :, :]).astype(wt)  # (B,i,j,H)
    y_intra = jnp.einsum("bijh,bjhp->bihp", w, xq, preferred_element_type=f32)

    # state passed to the next chunk
    decay_last = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
    contrib = jnp.einsum(
        "bqh,bqn,bqhp->bhpn", (decay_last * dtq).astype(wt), bq, xq, preferred_element_type=f32
    )
    state = state * jnp.exp(cum[:, -1])[:, :, None, None] + contrib
    # cast inside the body: the stacked (nc,B,Q,H,P) output is written in the
    # working dtype, not fp32 (halves the scan-output traffic, §Perf A-4a)
    return state, (y_inter + y_intra).astype(wt)


def ssd_ref(x, dt, A_log, b, c, chunk: int, initial_state=None):
    """x: (B,L,H,P); dt: (B,L,H) post-softplus; A_log: (H,); b,c: (B,L,N).

    Returns (y: (B,L,H,P) in x.dtype, final_state: (B,H,P,N) fp32).
    """
    Bb, Lq, H, P = x.shape
    N = b.shape[-1]
    if Lq % chunk:
        raise ValueError(f"seq len {Lq} not divisible by chunk {chunk}")
    nc = Lq // chunk
    a = -jnp.exp(A_log.astype(jnp.float32))

    # The recurrence serialises the sequence axis, so the residual stream's
    # act_seq sharding must be exchanged for HEAD sharding here — without
    # explicit constraints XLA gathers seq and then just replicates the whole
    # mixer over the model axis (§Perf cell A-6).
    from repro.distributed.sharding import constrain

    def to_chunks(t, head_axis):
        r = jnp.moveaxis(t.reshape((Bb, nc, chunk) + t.shape[2:]), 1, 0)
        axes = (None, "batch", None) + ((("ssm_heads",) + (None,) * (r.ndim - 4)) if head_axis else ((None,) * (r.ndim - 3)))
        return constrain(r, *axes)

    xs = (to_chunks(x, True), to_chunks(dt, True), to_chunks(b, False), to_chunks(c, False))
    state0 = (
        jnp.zeros((Bb, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    state0 = constrain(state0, "batch", "ssm_heads", None, None)

    def body(state, inp):
        xq, dtq, bq, cq = inp
        state, y = ssd_chunk(state, xq, dtq, a, bq, cq)
        return state, y

    # checkpoint: the (Q,Q,H) quadratic intermediates are rematerialised in
    # the backward pass instead of being stacked across chunks as residuals
    # (a (nc,B,Q,Q,H) fp32 tensor otherwise dominates training peak memory —
    # EXPERIMENTS.md §Perf cell A).
    state, ys = jax.lax.scan(jax.checkpoint(body), state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, Lq, H, P).astype(x.dtype)
    return y, state


def ssd_sequential(x, dt, A_log, b, c, initial_state=None):
    """O(L) step-by-step reference (the 'truth' the chunked form must match)."""
    Bb, Lq, H, P = x.shape
    N = b.shape[-1]
    f32 = jnp.float32
    a = -jnp.exp(A_log.astype(f32))
    state = (
        jnp.zeros((Bb, H, P, N), f32) if initial_state is None else initial_state.astype(f32)
    )

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a)  # (B,H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, bt, xt
        )
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(b.astype(f32), 1, 0),
        jnp.moveaxis(c.astype(f32), 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state
