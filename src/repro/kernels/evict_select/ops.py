"""Dispatcher: Pallas victim selection when enabled, jnp oracle otherwise."""
from __future__ import annotations

from repro.kernels.evict_select import kernel, ref


def evict_select(cand, keys, n_evict, *, use_kernel=False, interpret=False):
    if use_kernel:
        return kernel.evict_select(cand, keys, n_evict, interpret=interpret)
    return ref.evict_select_ref(cand, keys, n_evict)
