"""Pure-jnp oracle for packed-priority victim selection.

Mirrors the simulator's eviction loop (``repro.uvm.simulator._evict_fit``)
exactly: victims are picked one at a time by a chained masked-argmin over
the per-step lexicographic key tuple (up to 4 int32 keys — the optional
leading QoS ``evict_pref`` plus the policy's padded 3-tuple), ties broken
by lowest block index, each victim removed from the candidate set before
the next draw.  The keys are constant for the whole step (the simulator's
documented invariant: nothing an eviction changes feeds back into the
keys), so ``n_evict`` victims are exactly the first ``n_evict`` blocks in
the (k0, k1, k2, k3, index) lexicographic order restricted to candidates.

The oracle keeps the simulator's loop shape (``while_loop`` of masked
argmins) so the kernel equivalence tests pin the Pallas kernel against the
very program the scan path runs, not a re-derivation of it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lex_argmin_ref(cand, *keys):
    """Index of the lexicographically-smallest key tuple among candidates
    (verbatim ``repro.uvm.simulator._lex_argmin``)."""
    for k in keys:
        kk = jnp.where(cand, k, jnp.iinfo(jnp.int32).max)
        cand = cand & (kk == kk.min())
    return jnp.argmax(cand)


def evict_select_ref(cand, keys, n_evict):
    """Victim mask: the ``n_evict`` lowest-priority candidate blocks.

    ``cand`` is the evictable mask (resident & ~pinned & ~protected),
    ``keys`` a tuple of up to 4 int32 arrays (leading key first), and
    ``n_evict`` the number of victims (already clamped by the caller to
    ``min(max(occ - capacity, 0), cand.sum())`` — the loop below also
    stops when candidates run out, like the simulator's ``cond``).
    """
    cand = jnp.asarray(cand, bool)
    keys = tuple(jnp.asarray(k, jnp.int32) for k in keys)
    iota = jnp.arange(cand.shape[0], dtype=jnp.int32)

    def cond(c):
        i, cand_now, _ = c
        return (i < n_evict) & cand_now.any()

    def body(c):
        i, cand_now, vict = c
        v = lex_argmin_ref(cand_now, *keys)
        hit = iota == v
        return i + 1, cand_now & ~hit, vict | hit

    _, _, vict = jax.lax.while_loop(
        cond, body, (jnp.int32(0), cand, jnp.zeros_like(cand))
    )
    return vict
