"""Packed-priority victim-selection Pallas kernel.

The simulator's eviction hot path re-reads four (NB,) key arrays from the
traced scan state on every victim draw; this kernel loads the candidate
mask and the full lexicographic key tuple into VMEM ONCE and walks the
whole multi-victim selection in-core — one kernel invocation per scan
step instead of one masked-argmin sweep per victim (the GPUVM bet:
management-loop state stays device-resident).

Bit-identity contract: the victim set equals the simulator's chained
masked-argmin ``while_loop`` (``_lex_argmin`` semantics — smallest
(k0, k1, k2, k3) tuple first, ties to the lowest block index), because
the keys are constant for the whole step.  The kernel is shape-generic
over NB and composes with ``vmap`` (the batching rule adds a lane grid
axis) and ``lax.scan`` — the simulator calls it inside its per-event
step.  ``interpret=True`` runs the identical program as jnp ops so CPU
CI exercises the kernel path bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32_MAX = jnp.iinfo(jnp.int32).max


def _select_kernel(cand_ref, k0_ref, k1_ref, k2_ref, k3_ref, n_ref, vict_ref):
    """One program: select ``n_ref[0]`` victims from the VMEM-resident keys."""
    cand = cand_ref[...] != 0
    keys = (k0_ref[...], k1_ref[...], k2_ref[...], k3_ref[...])
    n = n_ref[0]
    nb = cand.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)[:, 0]

    def cond(c):
        i, cand_now, _ = c
        return (i < n) & cand_now.any()

    def body(c):
        i, cand_now, vict = c
        m = cand_now
        for k in keys:
            kk = jnp.where(m, k, I32_MAX)
            m = m & (kk == kk.min())
        victim = jnp.argmax(m)
        hit = iota == victim
        return i + 1, cand_now & ~hit, vict | hit

    _, _, vict = jax.lax.while_loop(
        cond, body, (jnp.int32(0), cand, jnp.zeros_like(cand))
    )
    vict_ref[...] = vict.astype(jnp.int32)


def evict_select(cand, keys, n_evict, *, interpret: bool = False):
    """Victim mask (bool (NB,)): the ``n_evict`` lowest-priority candidates.

    ``keys`` is a tuple of up to 4 int32 (NB,) arrays, leading key first
    (missing keys are padded with constant zeros, which never change a
    lexicographic argmin).  ``n_evict`` is an int32 scalar — the kernel's
    in-core loop also stops when candidates run out, mirroring the
    simulator's ``cond``, so an over-large ``n_evict`` cannot overdraw.
    """
    cand = jnp.asarray(cand)
    nb = cand.shape[0]
    keys = tuple(jnp.asarray(k, jnp.int32) for k in keys)
    if not 1 <= len(keys) <= 4:
        raise ValueError(f"evict_select takes 1-4 keys, got {len(keys)}")
    keys = keys + (jnp.zeros(nb, jnp.int32),) * (4 - len(keys))
    vict = pl.pallas_call(
        _select_kernel,
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.int32),
        interpret=interpret,
    )(cand.astype(jnp.int32), *keys, jnp.full((1,), n_evict, jnp.int32))
    return vict != 0
