"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package is <name>/{kernel.py, ops.py, ref.py}: the pallas_call
with explicit BlockSpec VMEM tiling, the jit'd dispatching wrapper, and the
pure-jnp oracle the interpret-mode test sweeps assert against.

  flash_attention   — FA2-style grouped-query attention; online-softmax state
                      in VMEM scratch across the sequential KV grid dim.
  decode_attention  — flash-decode: one token vs a long KV cache, purely
                      KV-bandwidth-bound (the decode roofline floor).
  ssd_scan          — Mamba-2 chunked SSD; inter-chunk state in VMEM scratch,
                      the (Q,Q,H) quadratic term never leaves the core.
  thrash_ce         — the PAPER's loss hot-spot: fused padded-class masking +
                      logsumexp + thrashing weight (Eqs. 2-3), fwd + bwd via
                      custom_vjp.

Enable in the model stack with REPRO_USE_PALLAS=1 (the dry-run lowers the
pure-XLA paths; EXPERIMENTS.md §Perf quantifies the kernel credit).
"""
