"""Pure-jnp oracle for grouped-query flash attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, q_offset=0, kv_len=None):
    """q: (B,S,K,G,D); k,v: (B,T,K,D). fp32 math. Returns (B,S,K,G,D)."""
    B, S, K, G, D = q.shape
    T = k.shape[1]
    scale = D ** -0.5
    s = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(S)
    k_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    if kv_len is not None:
        mask = mask & (k_pos[None, :] < kv_len)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
