"""Jitted wrapper for the flash-attention kernel (model layout pass-through)."""
from __future__ import annotations

from repro.kernels.flash_attention import kernel, ref


def flash_attention(q, k, v, *, causal=True, q_offset=0, kv_len=None, interpret=False):
    return kernel.flash_attention(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len, interpret=interpret)


attention_ref = ref.attention_ref
