"""Flash attention (FA2-style) Pallas TPU kernel, grouped-query layout.

Grid: (B, K, nQ, nKV) with the KV dimension innermost (sequential on TPU),
so the online-softmax state lives in VMEM scratch across KV steps and scores
NEVER touch HBM — this is the kernel credit quantified in EXPERIMENTS.md
§Perf against the XLA chunked path's score traffic.

Block shapes: q (G, BQ, D), k/v (BK, D) per (batch, kv-head) program.
BQ/BK default 128/256 — MXU-aligned (multiples of 128 on the contracted and
lane dims; D is the model's head_dim, 64/112/128 in the assigned archs).
VMEM working set per program ~ G*BQ*D(fp32 acc) + BK*D*2 + G*BQ*BK scores
≈ 2-6 MB at the defaults: fits the ~16MB/core budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 256
NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc, m, l, *, causal, bq, bk, n_kv, q_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG)
        l[...] = jnp.zeros_like(l)

    q = q_ref[0, 0]  # (G, BQ, D)
    k = k_ref[0, 0]  # (BK, D)
    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(
        (q * scale).astype(jnp.float32), k.astype(jnp.float32),
        (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (G, BQ, BK)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (q_pos >= k_pos)
    mask = mask & (k_pos < len_ref[0])
    s = jnp.where(mask[None], s, NEG)

    m_new = jnp.maximum(m[...], s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m[...] - m_new)
    l[...] = l[...] * alpha + p.sum(-1)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0],
        (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (G, BQ, D)
    acc[...] = acc[...] * alpha[..., None] + pv
    m[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l[...][..., None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_offset", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, q_offset=0, kv_len=None, bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=False):
    """q: (B,S,K,G,D) grouped query; k,v: (B,T,K,D). Returns (B,S,K,G,D)."""
    B, S, K, G, D = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    n_q, n_kv = S // bq, T // bk
    qg = jnp.moveaxis(q, 1, 3)  # (B, K, G, S, D)
    kk = jnp.moveaxis(k, 2, 1)  # (B, K, T, D)
    vv = jnp.moveaxis(v, 2, 1)
    lens = jnp.full((1,), T if kv_len is None else kv_len, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, causal=causal, bq=bq, bk=bk, n_kv=n_kv, q_offset=q_offset),
        grid=(B, K, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1,), lambda b, h, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq, D), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kk, vv, lens)
    return jnp.moveaxis(out, 3, 1)  # (B, S, K, G, D)
