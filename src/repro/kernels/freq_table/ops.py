"""Dispatcher for the prediction-frequency-table kernels.

Pads block streams to power-of-two buckets (update pads with the ``-1``
no-op sentinel; lookup results are sliced back to the real length) so
repeated manager batches of drifting sizes reuse a few compiled kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.freq_table import kernel, ref
from repro.util import pow2_bucket


def default_interpret() -> bool:
    """Interpret mode on backends with no Mosaic lowering (CPU CI)."""
    return jax.default_backend() == "cpu"


def _pad_blocks(blocks, fill: int):
    b = np.asarray(blocks, np.int64).ravel()
    if b.size and not (-1 <= b.min() and b.max() < 2**31):
        raise ValueError("freq_table kernels take int32 block ids (>= -1)")
    n = pow2_bucket(max(b.size, 1), 64)
    out = np.full(n, fill, np.int32)
    out[: b.size] = b
    return out, b.size


def freq_update(tags, counters, blocks, *, use_kernel=False, interpret=False):
    """Updated (tags, counters) after streaming ``blocks`` (any int dtype)."""
    b, _ = _pad_blocks(blocks, -1)
    if use_kernel:
        return kernel.freq_update(tags, counters, b, interpret=interpret)
    return ref.freq_update_ref(tags, counters, b)


def freq_lookup(tags, counters, blocks, *, use_kernel=False, interpret=False):
    """Counter per block, -1 on miss (int32, same length as ``blocks``)."""
    b, n = _pad_blocks(blocks, -1)
    if use_kernel:
        out = kernel.freq_lookup(tags, counters, b, interpret=interpret)
    else:
        out = ref.freq_lookup_ref(jnp.asarray(tags, jnp.int32),
                                  jnp.asarray(counters, jnp.int32), b)
    return out[:n]
