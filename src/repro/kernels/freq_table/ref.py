"""Pure-jnp oracle for the prediction-frequency-table kernels.

Mirrors :class:`repro.core.policy.LoopPredictionFrequencyTable` — the frozen
per-block semantics oracle — one row update per streamed block: first-hit way,
else first-empty way, else evict the lowest-counter way (first on ties), then
one saturating increment.  The vectorized host table is pinned against the
same oracle (tests/test_manager.py), so kernel == ref == host table is one
equivalence chain.

``blocks`` entries of ``-1`` are padding no-ops for ``update`` (real block
ids are never negative); ``lookup`` runs the host ``lookup_many`` expression
verbatim (padding results are sliced off by the caller).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import COUNTER_MAX


def freq_update_ref(tags, counters, blocks):
    """Stream ``blocks`` through the table; returns (tags, counters).

    ``tags``/``counters`` are int32 (S, W); ``blocks`` int32 (N,) with -1
    padding.  One ``lax.scan`` step per streamed block — arrival order IS
    the update order, exactly the loop oracle.
    """
    tags = jnp.asarray(tags, jnp.int32)
    counters = jnp.asarray(counters, jnp.int32)
    blocks = jnp.asarray(blocks, jnp.int32)
    n_sets, ways = tags.shape
    wi = jnp.arange(ways, dtype=jnp.int32)

    def first(mask):
        return jnp.min(jnp.where(mask, wi, ways)).astype(jnp.int32)

    def step(carry, b):
        tags, counters = carry
        active = b >= 0
        s = jnp.where(active, b % n_sets, 0)
        row_t, row_c = tags[s], counters[s]
        hit = row_t == b
        is_hit = hit.any()
        empty = row_t == -1
        min_c = row_c.min()
        ins = jnp.where(empty.any(), first(empty), first(row_c == min_c))
        way = jnp.where(is_hit, first(hit), ins)
        sel = (wi == way) & active
        base = jnp.where(is_hit, row_c[way], 0)
        new_t = jnp.where(sel, b, row_t)
        new_c = jnp.where(sel, jnp.minimum(base + 1, COUNTER_MAX), row_c)
        return (tags.at[s].set(new_t), counters.at[s].set(new_c)), None

    (tags, counters), _ = jax.lax.scan(step, (tags, counters), blocks)
    return tags, counters


def freq_lookup_ref(tags, counters, blocks):
    """Current counter per block, -1 on miss — the host ``lookup_many``
    expression (first-hit way via ``argmax``) as jnp ops."""
    tags = jnp.asarray(tags, jnp.int32)
    counters = jnp.asarray(counters, jnp.int32)
    blocks = jnp.asarray(blocks, jnp.int32)
    n_sets = tags.shape[0]
    s = blocks % n_sets
    rows_t = tags[s]
    rows_c = counters[s]
    hit = rows_t == blocks[:, None]
    way = jnp.argmax(hit, axis=1)
    cnt = jnp.take_along_axis(rows_c, way[:, None], axis=1)[:, 0]
    return jnp.where(hit.any(axis=1), cnt, -1).astype(jnp.int32)
