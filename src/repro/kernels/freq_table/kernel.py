"""Prediction-frequency-table Pallas kernels (update stream + lookup).

The host table round-trips every ``update``/``lookup_many`` batch through
numpy scatter waves; these kernels keep the whole (S, W) tag/counter state
VMEM-resident and walk the block stream in-core — the GPUVM bet applied to
the paper's 18KB table (1024 sets x 16 ways fits VMEM with room to spare).

``update`` tiles the set axis across the grid: each program owns a disjoint
row tile, streams the ENTIRE block sequence in a ``fori_loop``, and applies
only the blocks hashing into its tile — programs never write the same row,
and within a program arrival order is preserved, so the result is exactly
the per-block loop oracle (first-hit way, first-empty way, lowest-counter
eviction with first-on-ties, saturating +1).  ``lookup`` is one program
gathering per-block rows with the same first-hit-way rule.

``interpret=True`` runs the identical program as jnp ops (CPU CI gate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.policy import COUNTER_MAX

_MAX_TILE = 128  # set-rows per program: 128 x 16 x int32 = 8KB per operand


def _set_tile(n_sets: int) -> int:
    if n_sets <= _MAX_TILE:
        return n_sets
    tile = _MAX_TILE
    while n_sets % tile:
        tile //= 2
    return tile


def _update_kernel(blocks_ref, tags_ref, cnt_ref, out_tags_ref, out_cnt_ref,
                   *, n_sets: int, tile: int):
    t0 = pl.program_id(0) * tile
    out_tags_ref[...] = tags_ref[...]
    out_cnt_ref[...] = cnt_ref[...]
    ways = tags_ref.shape[1]
    wi = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def first(mask):
        return jnp.min(jnp.where(mask, wi, ways)).astype(jnp.int32)

    def body(i, carry):
        b = blocks_ref[i]
        s = b % n_sets
        local = s - t0
        mine = (b >= 0) & (local >= 0) & (local < tile)
        idx = jnp.where(mine, local, 0)
        row_t = out_tags_ref[pl.ds(idx, 1), :]
        row_c = out_cnt_ref[pl.ds(idx, 1), :]
        hit = row_t == b
        is_hit = hit.any()
        empty = row_t == -1
        min_c = row_c.min()
        ins = jnp.where(empty.any(), first(empty), first(row_c == min_c))
        way = jnp.where(is_hit, first(hit), ins)
        sel = (wi == way) & mine
        base = jnp.where(is_hit, jnp.sum(jnp.where(wi == way, row_c, 0)), 0)
        out_tags_ref[pl.ds(idx, 1), :] = jnp.where(sel, b, row_t)
        out_cnt_ref[pl.ds(idx, 1), :] = jnp.where(
            sel, jnp.minimum(base + 1, COUNTER_MAX), row_c
        )
        return carry

    jax.lax.fori_loop(0, blocks_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def freq_update(tags, counters, blocks, *, interpret: bool = False):
    """Stream ``blocks`` (int32 (N,), -1 = no-op padding) through the table;
    returns the updated (tags, counters), both int32 (S, W)."""
    tags = jnp.asarray(tags, jnp.int32)
    counters = jnp.asarray(counters, jnp.int32)
    blocks = jnp.asarray(blocks, jnp.int32)
    n_sets, ways = tags.shape
    tile = _set_tile(n_sets)
    return pl.pallas_call(
        functools.partial(_update_kernel, n_sets=n_sets, tile=tile),
        grid=(n_sets // tile,),
        in_specs=[
            pl.BlockSpec(blocks.shape, lambda i: (0,)),
            pl.BlockSpec((tile, ways), lambda i: (i, 0)),
            pl.BlockSpec((tile, ways), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, ways), lambda i: (i, 0)),
            pl.BlockSpec((tile, ways), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_sets, ways), jnp.int32),
            jax.ShapeDtypeStruct((n_sets, ways), jnp.int32),
        ],
        interpret=interpret,
    )(blocks, tags, counters)


def _lookup_kernel(blocks_ref, tags_ref, cnt_ref, out_ref, *, n_sets: int):
    ways = tags_ref.shape[1]
    wi = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def body(i, carry):
        b = blocks_ref[i]
        s = b % n_sets
        row_t = tags_ref[pl.ds(s, 1), :]
        row_c = cnt_ref[pl.ds(s, 1), :]
        hit = row_t == b
        # first-hit way, exactly lookup_many's ``hit.argmax``
        way = jnp.min(jnp.where(hit, wi, ways)).astype(jnp.int32)
        cnt = jnp.sum(jnp.where(wi == jnp.where(hit.any(), way, 0), row_c, 0))
        out_ref[i] = jnp.where(hit.any(), cnt, -1)
        return carry

    jax.lax.fori_loop(0, blocks_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def freq_lookup(tags, counters, blocks, *, interpret: bool = False):
    """Current counter per block (int32 (N,)), -1 on miss."""
    tags = jnp.asarray(tags, jnp.int32)
    counters = jnp.asarray(counters, jnp.int32)
    blocks = jnp.asarray(blocks, jnp.int32)
    n_sets = tags.shape[0]
    return pl.pallas_call(
        functools.partial(_lookup_kernel, n_sets=n_sets),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, jnp.int32),
        interpret=interpret,
    )(blocks, tags, counters)
