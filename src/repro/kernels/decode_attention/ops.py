"""Jitted wrapper matching the model layer's grouped layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention import kernel, ref


def decode_attention(q, k, v, *, q_offset=0, kv_len=None, causal=False, interpret=False):
    """q: (B,1,K,G,D) (model layout) or (B,K,G,D). Returns model layout."""
    squeeze = q.ndim == 5
    if squeeze:
        q4 = q[:, 0]
    else:
        q4 = q
    T = k.shape[1]
    lens = T if kv_len is None else kv_len
    out = kernel.decode_attention_kernelcall(q4, k, v, lens, interpret=interpret)
    return out[:, None] if squeeze else out


decode_ref = ref.decode_ref
