"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Grid: (B, K, nKV) with the KV dim sequential; the (G, D) accumulator stays in
VMEM scratch, so per step the chip only streams the KV blocks — the kernel is
purely KV-bandwidth-bound, which is the roofline floor for decode. Paged KV
is handled by the caller passing a gathered view (block-table indirection
happens at the XLA level; fusing it into the kernel via PrefetchScalarGridSpec
is the recorded follow-on optimisation in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc, m, l, *, bk, n_kv):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG)
        l[...] = jnp.zeros_like(l)

    q = q_ref[0, 0]  # (G, D)
    k = k_ref[0, 0]  # (BK, D)
    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(
        (q * scale).astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (G, BK)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(k_pos < len_ref[0], s, NEG)

    m_new = jnp.maximum(m[...], s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m[...] - m_new)
    l[...] = l[...] * alpha + p.sum(-1)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (G, D)
    acc[...] = acc[...] * alpha[..., None] + pv
    m[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l[...][..., None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_kernelcall(q, k, v, kv_len, *, bk=DEFAULT_BK, interpret=False):
    """q: (B,K,G,D); k,v: (B,T,K,D); kv_len: scalar int32."""
    B, K, G, D = q.shape
    T = k.shape[1]
    bk = min(bk, T)
    assert T % bk == 0
    n_kv = T // bk
    kk = jnp.moveaxis(k, 2, 1)
    vv = jnp.moveaxis(v, 2, 1)
    lens = jnp.full((1,), kv_len, jnp.int32)
    return pl.pallas_call(
        functools.partial(_dec_kernel, bk=bk, n_kv=n_kv),
        grid=(B, K, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(q, kk, vv, lens)
