"""Pure-jnp oracle for single-token (decode) attention over a long KV cache."""
from __future__ import annotations

import jax.numpy as jnp


def decode_ref(q, k, v, *, kv_len=None, causal=False, q_offset=0):
    """q: (B,1,K,G,D) wait — canonical: q (B,K,G,D); k,v: (B,T,K,D)."""
    if q.ndim == 5:  # (B,1,K,G,D)
        q = q[:, 0]
    B, K, G, D = q.shape
    T = k.shape[1]
    scale = D ** -0.5
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    k_pos = jnp.arange(T)
    mask = jnp.ones((T,), bool)
    if kv_len is not None:
        mask = mask & (k_pos < kv_len)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
