"""Dispatcher: Pallas fused loss when enabled, composed jnp ops otherwise."""
from __future__ import annotations

from repro.kernels.thrash_ce import kernel, ref


def thrash_ce_loss(logits, labels, in_et, n_active, mu=0.5, *, use_kernel=False, interpret=False):
    if use_kernel:
        return kernel.thrash_ce(logits, labels, in_et, n_active, mu, kernel.DEFAULT_BB, interpret)
    return ref.thrash_ce_ref(logits, labels, in_et, mu, n_active)
