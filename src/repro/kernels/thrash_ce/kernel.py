"""Fused thrashing-aware CE loss Pallas kernel (fwd + bwd, custom_vjp).

The predictor's hot loss op: per sample it fuses padded-class masking,
logsumexp, label pick, and the thrashing weight (1 - mu*in_et) in one VMEM
pass over the (BB, V) logits block — and the backward kernel emits
(softmax - onehot) * w / B without re-reading anything but the logits block.
Delta vocab V <= 4096 so a whole row fits VMEM comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 128
NEG = -1e30


def _fwd_kernel(logits_ref, labels_ref, et_ref, na_ref, loss_ref, *, mu, v):
    lg = logits_ref[...].astype(jnp.float32)  # (BB, V)
    cls = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 1)
    lg = jnp.where(cls >= na_ref[0], NEG, lg)
    m = lg.max(-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), -1)) + m[:, 0]
    onehot = cls == labels_ref[...][:, None]
    ll = jnp.sum(jnp.where(onehot, lg, 0.0), -1)
    w = 1.0 - mu * et_ref[...].astype(jnp.float32)
    loss_ref[...] = (lse - ll) * w


def _bwd_kernel(logits_ref, labels_ref, et_ref, na_ref, g_ref, dlogits_ref, *, mu, v):
    lg = logits_ref[...].astype(jnp.float32)
    cls = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 1)
    lg = jnp.where(cls >= na_ref[0], NEG, lg)
    m = lg.max(-1, keepdims=True)
    e = jnp.exp(lg - m)
    p = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    onehot = (cls == labels_ref[...][:, None]).astype(jnp.float32)
    w = (1.0 - mu * et_ref[...].astype(jnp.float32))[:, None]
    dlogits_ref[...] = ((p - onehot) * w * g_ref[0]).astype(dlogits_ref.dtype)


def _call_fwd(logits, labels, in_et, n_active, mu, bb, interpret):
    B, V = logits.shape
    bb = min(bb, B)
    assert B % bb == 0
    na = jnp.full((1,), n_active, jnp.int32)
    per = pl.pallas_call(
        functools.partial(_fwd_kernel, mu=mu, v=V),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, V), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(logits, labels, in_et.astype(jnp.int32), na)
    return per.mean()


def _call_bwd(logits, labels, in_et, n_active, mu, g, bb, interpret):
    B, V = logits.shape
    bb = min(bb, B)
    na = jnp.full((1,), n_active, jnp.int32)
    gg = jnp.full((1,), g / B, jnp.float32)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, mu=mu, v=V),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, V), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, V), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, V), logits.dtype),
        interpret=interpret,
    )(logits, labels, in_et.astype(jnp.int32), na, gg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def thrash_ce(logits, labels, in_et, n_active, mu=0.5, bb=DEFAULT_BB, interpret=False):
    return _call_fwd(logits, labels, in_et, n_active, mu, bb, interpret)


def _vjp_fwd(logits, labels, in_et, n_active, mu, bb, interpret):
    return _call_fwd(logits, labels, in_et, n_active, mu, bb, interpret), (logits, labels, in_et, n_active)


def _vjp_bwd(mu, bb, interpret, res, g):
    logits, labels, in_et, n_active = res
    dl = _call_bwd(logits, labels, in_et, n_active, mu, g, bb, interpret)
    return dl, None, None, None


thrash_ce.defvjp(_vjp_fwd, _vjp_bwd)
