"""Pure-jnp oracle for the fused thrashing-aware CE loss (paper Eqs. 2-3
combined over a batch):

    per-sample: nll_i * (1 - mu * in_et_i)

i.e. standard CE for ordinary samples, and CE + mu * L_thra (the additive
inverse of CE) for samples whose target page is evicted/thrashed. Gradient
wrt logits: (softmax - onehot) * (1 - mu*in_et) / B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def thrash_ce_ref(logits, labels, in_et, mu: float, n_active: int):
    lm = jnp.where(jnp.arange(logits.shape[-1]) >= n_active, -1e30, logits.astype(jnp.float32))
    lse = jax.nn.logsumexp(lm, -1)
    ll = jnp.take_along_axis(lm, labels[:, None], 1)[:, 0]
    nll = lse - ll
    w = 1.0 - mu * in_et.astype(jnp.float32)
    return (nll * w).mean()


def thrash_ce_grad_ref(logits, labels, in_et, mu: float, n_active: int):
    return jax.grad(lambda lg: thrash_ce_ref(lg, labels, in_et, mu, n_active))(logits)
