"""repro: the paper's learned UVM-oversubscription manager as a JAX/TPU framework.

Layers
------
- ``repro.core``     — the paper's contribution: pattern-aware, thrashing-aware,
  incrementally-trained page predictor + policy engine.
- ``repro.uvm``      — trace-driven unified-memory simulator substrate (the
  GPGPU-Sim replacement): benchmarks, prefetchers, eviction policies, timing.
- ``repro.models``   — the assigned 10-architecture LM zoo.
- ``repro.kernels``  — Pallas TPU kernels for the compute hot-spots.
- ``repro.distributed / data / optim / checkpoint`` — training substrates.
- ``repro.serving``  — paged-KV serving engine with the paper's technique as a
  learned HBM<->host offload manager.
- ``repro.launch``   — production mesh, multi-pod dry-run, roofline, drivers.
"""

__version__ = "1.0.0"
