"""Fault-tolerant sharded checkpointing (no orbax).

Layout per step:
    <dir>/step_000123.tmp/          # written first
        manifest.json               # tree structure, shapes, dtypes, step
        <escaped-path>.npy          # one file per leaf (per-host shard-aware)
    <dir>/step_000123/              # atomic rename AFTER all writes land

Guarantees:
  * atomicity — a crash mid-write leaves only a .tmp dir, never a torn
    checkpoint; `latest_step` ignores .tmp.
  * resumability — restore() rebuilds the pytree and re-shards it onto ANY
    mesh (elastic restarts: the surviving-device mesh may differ).
  * retention — keep the last k checkpoints.
  * integrity — manifest records per-leaf shape/dtype; mismatches fail loudly.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path

import jax
import numpy as np


def _esc(path: str) -> str:
    return path.replace("/", "__")


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree: dict, extra: dict | None = None) -> Path:
        """tree: flat {path: array}. Gathers to host then writes atomically."""
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}, "extra": extra or {}}
        for path, arr in tree.items():
            np_arr = np.asarray(jax.device_get(arr))
            np.save(tmp / f"{_esc(path)}.npy", np_arr)
            manifest["leaves"][path] = {"shape": list(np_arr.shape), "dtype": str(np_arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()
        return final

    # -- read ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                if (p / "manifest.json").exists():
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int | None = None, shardings: dict | None = None) -> tuple[int, dict, dict]:
        """Returns (step, tree, extra). With `shardings`, leaves are placed
        onto devices per the (possibly different) target mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        tree = {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(d / f"{_esc(path)}.npy")
            if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
                raise ValueError(f"corrupt leaf {path}: {arr.shape}/{arr.dtype} vs manifest {meta}")
            if shardings and path in shardings:
                arr = jax.device_put(arr, shardings[path])
            tree[path] = arr
        return step, tree, manifest.get("extra", {})

    # -- retention ----------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    def clean_tmp(self):
        """Crash recovery: drop torn writes."""
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)
