"""Memory-access traces + synthetic generators for the paper's 11 benchmarks.

The paper traces real CUDA benchmarks under GPGPU-Sim; without a GPU we
generate seeded synthetic traces whose *structure* matches the published
characterisation:

  * access-pattern class per benchmark (streaming / stencil-reuse / wavefront
    / random-gather / phased, Table VII & Fig. 5),
  * unique-delta growth across program phases (Table III),
  * re-reference behaviour that produces the published thrash ORDERING under
    the rule-based policies (Table I/VI: e.g. streaming benchmarks never
    thrash, NW thrashes hardest, BICG/Srad keep capacity misses even under
    Belady).

A trace is page-granular: (page, pc, tb, kernel) per access. The simulator
migrates at 64KB basic-block granularity (16 x 4KB pages), like the CUDA
runtime it models.

Generator contract the simulator's period-p event compression relies on:
streaming kernels are built with :func:`_interleave`, which walks its p
streams in lockstep — one access from each stream per iteration.  With
chunk-aligned allocations (:func:`_align`) the resulting BLOCK stream is a
fixed-period sequence (``b0 b1 .. bp-1`` repeated ``PAGES_PER_BLOCK``
times before every block advances), which the simulator detects host-side
and compresses into per-window aggregate events
(see ``repro/uvm/simulator.py``).  Nothing here may assume that
compression exists — it is exactness-checked at runtime — but keeping the
interleave idiom periodic is what makes streaming sweeps fast.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

PAGE_SIZE = 4096
PAGES_PER_BLOCK = 16  # 64KB basic block

#: external UVM fault-log interchange schema (see :func:`to_fault_log`)
FAULT_LOG_VERSION = 1
_FAULT_LOG_MAGIC = "# uvm-fault-log"


@dataclasses.dataclass
class Trace:
    name: str
    page: np.ndarray  # int32 (T,)
    pc: np.ndarray  # int32 (T,)
    tb: np.ndarray  # int32 (T,)
    kernel: np.ndarray  # int32 (T,) kernel-launch index
    n_pages: int  # working-set size in pages
    #: per-access tenant index for Section V-F concurrent merges (None for
    #: single-workload traces); index i names ``tenant_names[i]``
    tenant: np.ndarray | None = None
    tenant_names: tuple[str, ...] = ()

    def __len__(self) -> int:
        return len(self.page)

    @property
    def n_blocks(self) -> int:
        return (self.n_pages + PAGES_PER_BLOCK - 1) // PAGES_PER_BLOCK

    @property
    def block(self) -> np.ndarray:
        return self.page // PAGES_PER_BLOCK

    def deltas(self) -> np.ndarray:
        d = np.diff(self.page.astype(np.int64), prepend=self.page[0])
        return d

    def slice(self, lo: int, hi: int) -> "Trace":
        return Trace(
            self.name, self.page[lo:hi], self.pc[lo:hi], self.tb[lo:hi], self.kernel[lo:hi], self.n_pages,
            tenant=None if self.tenant is None else self.tenant[lo:hi], tenant_names=self.tenant_names,
        )


class _Builder:
    def __init__(self, name: str, n_pages: int, seed: int):
        self.name = name
        self.n_pages = n_pages
        self.rng = np.random.default_rng(seed)
        self.page: list[np.ndarray] = []
        self.pc: list[np.ndarray] = []
        self.tb: list[np.ndarray] = []
        self.kern: list[np.ndarray] = []
        self.kernel_id = 0

    def emit(self, pages: np.ndarray, pc: int):
        pages = np.asarray(pages, np.int64) % self.n_pages
        self.page.append(pages.astype(np.int32))
        self.pc.append(np.full(len(pages), pc, np.int32))
        # thread-block id ~ position within the kernel's iteration space
        self.tb.append((np.arange(len(pages)) // 64).astype(np.int32))
        self.kern.append(np.full(len(pages), self.kernel_id, np.int32))

    def next_kernel(self):
        self.kernel_id += 1

    def build(self) -> Trace:
        return Trace(
            self.name,
            np.concatenate(self.page),
            np.concatenate(self.pc),
            np.concatenate(self.tb),
            np.concatenate(self.kern),
            self.n_pages,
        )


def _align(n: int, m: int = 512) -> int:
    """Allocations are chunk-aligned (cudaMallocManaged rounds to 2MB chunks);
    misaligned synthetic arrays would create chunk-straddling artefacts the
    real runtime never sees."""
    return max(int(round(n / m)), 1) * m


def _interleave(*streams: np.ndarray) -> np.ndarray:
    n = min(len(s) for s in streams)
    out = np.empty(n * len(streams), np.int64)
    for i, s in enumerate(streams):
        out[i :: len(streams)] = s[:n]
    return out


# ---------------------------------------------------------------------------
# Benchmark generators. `scale` multiplies the working set + trace length.
# ---------------------------------------------------------------------------

def addvectors(scale: float = 1.0, seed: int = 0) -> Trace:
    """c[i] = a[i] + b[i]: pure streaming over 3 arrays, never re-referenced."""
    n = _align(int(1536 * scale))  # pages per array
    b = _Builder("AddVectors", 3 * n, seed)
    a_s = np.arange(n)
    b.emit(_interleave(a_s, n + a_s, 2 * n + a_s), pc=0)
    return b.build()


def streamtriad(scale: float = 1.0, seed: int = 1) -> Trace:
    """a[i] = b[i] + s*c[i]: streaming; strong temporal pattern proximity."""
    n = _align(int(1536 * scale))
    b = _Builder("StreamTriad", 3 * n, seed)
    idx = np.arange(n)
    b.emit(_interleave(idx, n + idx, 2 * n + idx), pc=0)
    return b.build()


def _stream_with_gathers(stream: np.ndarray, gathers: np.ndarray, per: int = 24, g: int = 8) -> np.ndarray:
    """Streamed pages with periodic random gathers (GPU coalescing means the
    matrix stream dominates the fault sequence; vector gathers punctuate it)."""
    ns = len(stream) // per * per
    chunks = stream[:ns].reshape(-1, per)
    gs = np.resize(gathers, (len(chunks), g))
    return np.concatenate([chunks, gs], axis=1).reshape(-1)


def atax(scale: float = 1.0, seed: int = 2, iters: int = 4) -> Trace:
    """y = A^T (A x), iterated (the benchmark loops its kernels): A streamed
    twice per iteration; x gathered randomly (random class)."""
    rows = max(int(48 * scale), 48)
    cols = max(int(48 * scale), 48)
    A = rows * cols // 8  # pages of A (8 matrix rows per page-ish)
    n = A + rows + cols
    b = _Builder("ATAX", n, seed)
    a_pages = np.arange(A)
    for _ in range(iters):
        # tmp = A x — stream A rows, gather x (random reuse)
        b.emit(_stream_with_gathers(a_pages, A + b.rng.integers(0, rows, A)), pc=0)
        b.next_kernel()
        # y = A^T tmp — stream A again (re-reference => thrash at 125%)
        b.emit(_stream_with_gathers(a_pages, A + rows + b.rng.integers(0, cols, A)), pc=1)
        b.next_kernel()
    return b.build()


def bicg(scale: float = 1.0, seed: int = 3) -> Trace:
    """BiCG: q = A p, s = A^T r — A re-referenced with transposed order."""
    rows = max(int(52 * scale), 52)
    side = max(int(np.sqrt(rows * rows // 8)), 2)
    A = side * side  # pages of A (kept square for the transposed walk)
    n = A + 4 * rows
    b = _Builder("BICG", n, seed)
    a_pages = np.arange(A)
    at = (np.arange(A).reshape(side, side).T).reshape(-1)
    for _ in range(3):  # the solver iterates
        b.emit(_stream_with_gathers(a_pages, A + b.rng.integers(0, rows, A)), pc=0)
        b.next_kernel()
        # transposed walk: column-major => large strided deltas, heavy thrash
        b.emit(_stream_with_gathers(at, A + 2 * rows + b.rng.integers(0, rows, A)), pc=1)
        b.next_kernel()
    return b.build()


def mvt(scale: float = 1.0, seed: int = 4) -> Trace:
    """x1 += A y1; x2 += A^T y2. A's live rows are interleaved with allocated
    but untouched padding rows (10 of 16 blocks live): demand variants fit and
    never thrash; the tree prefetcher's garbage overflows capacity (paper:
    baseline 2912, every demand variant 0)."""
    blocks = max(int(120 * scale), 48)
    bpp = 16
    live_block = (np.arange(blocks) % 16) < 10
    live = np.concatenate([np.arange(bpp) + blk * bpp for blk in np.nonzero(live_block)[0]])
    b = _Builder("MVT", blocks * bpp, seed)
    b.emit(live, pc=0)
    b.next_kernel()
    side = int(np.sqrt(len(live)))
    at = live[: side * side].reshape(side, side).T.reshape(-1)
    b.emit(at, pc=1)
    return b.build()


def hotspot(scale: float = 1.0, seed: int = 5, iters: int = 12) -> Trace:
    """2D stencil, iterative. The LIVE stencil rows occupy 9 of every 16
    blocks of the allocation (row padding / halo pages are allocated but never
    touched). The live set fits device memory, so demand-load policies never
    thrash — but the tree prefetcher sees >50%-valid chunks and drags in the
    dead blocks, overflowing capacity and evicting live rows (the paper's
    baseline-thrash mechanism for regular benchmarks)."""
    blocks = int(160 * scale)
    bpp = 16  # pages per block
    live_block = (np.arange(blocks) % 16) < 9
    live_pages = np.concatenate([np.arange(bpp) + blk * bpp for blk in np.nonzero(live_block)[0]])
    b = _Builder("Hotspot", blocks * bpp, seed)
    for it in range(iters):
        reads = _interleave(live_pages, live_pages + 1, live_pages - 1)
        b.emit(reads, pc=it % 3)
        b.next_kernel()
    return b.build()


def srad_v2(scale: float = 1.0, seed: int = 6, iters: int = 10) -> Trace:
    """SRAD: image grid, 2 kernels/iter, growing delta vocabulary across phases."""
    grid = int(768 * scale)
    b = _Builder("Srad-v2", 2 * grid, seed)
    for it in range(iters):
        idx = np.arange(grid)
        stride = 1 + it  # phase-dependent stride -> new deltas appear over time
        b.emit(_interleave(idx, (idx + stride), grid + idx), pc=0)
        b.next_kernel()
        b.emit(_interleave(grid + idx, (grid + idx + stride)), pc=1)
        b.next_kernel()
    return b.build()


def nw(scale: float = 1.0, seed: int = 7) -> Trace:
    """Needleman-Wunsch: anti-diagonal wavefront; delta vocab explodes (mixed)."""
    side = int(72 * scale)  # matrix side in pages^(1/2) units
    n = side * side // 2
    b = _Builder("NW", n, seed)
    width = int(np.sqrt(n))
    pages = []
    for d in range(2 * width - 1):  # anti-diagonals
        i = np.arange(max(0, d - width + 1), min(d + 1, width))
        j = d - i
        diag = i * width + j
        pages.append(diag)
        if d and d % 16 == 0:
            pages.append(diag[:: max(len(diag) // 4, 1)] - width)  # reference back rows
    b.emit(np.concatenate(pages), pc=0)
    b.next_kernel()
    # second pass: traceback (reverse walk, re-references everything)
    b.emit(np.concatenate(pages[::-1])[: 2 * n], pc=1)
    return b.build()


def backprop(scale: float = 1.0, seed: int = 8) -> Trace:
    """Two-layer NN: weights are re-read fwd+bwd but always interleaved with
    the (once-streamed) activation pages, so the weight set stays hot and
    NOTHING thrashes under demand load or driver-LRU (paper: 0 everywhere
    except Tree.+HPE, whose chain never sees the prefetches)."""
    w = _align(int(1280 * scale))  # weight pages, re-referenced
    act = _align(int(512 * scale))  # activation pages, streamed once
    b = _Builder("Backprop", w + act, seed)
    wp = np.arange(w)
    # weights stream in warp-coalesced chunks, punctuated by slowly-advancing
    # activation pages (chunked, so the delta stream stays learnable)
    ap_fwd = w + np.repeat(np.arange(act // 2), max(w // (act // 2), 1))
    ap_bwd = w + act // 2 + np.repeat(np.arange(act // 2), max(w // (act // 2), 1))
    b.emit(_stream_with_gathers(wp, ap_fwd, per=24, g=8), pc=0)
    b.next_kernel()
    b.emit(_stream_with_gathers(wp[::-1], ap_bwd, per=24, g=8), pc=1)
    return b.build()


def pathfinder(scale: float = 1.0, seed: int = 9) -> Trace:
    """Row-by-row DP: streams each row, re-uses only the previous row."""
    rows, row_pages = int(24 * scale), int(96 * scale)
    b = _Builder("Pathfinder", rows * row_pages, seed)
    for r in range(rows):
        cur = r * row_pages + np.arange(row_pages)
        prev = np.maximum(cur - row_pages, 0)
        b.emit(_interleave(cur, prev), pc=0)
    return b.build()


def twodconv(scale: float = 1.0, seed: int = 10) -> Trace:
    """2D convolution: single streaming pass with row-neighbour deltas."""
    grid = _align(int(1800 * scale))
    b = _Builder("2DCONV", 2 * grid, seed)
    idx = np.arange(grid)
    width = int(np.sqrt(grid))
    reads = _interleave(idx, idx + 1, idx + width, grid + idx)  # in, in+dx, in+dy, out
    b.emit(reads, pc=0)
    return b.build()


BENCHMARKS = {
    "AddVectors": addvectors,
    "ATAX": atax,
    "Backprop": backprop,
    "BICG": bicg,
    "Hotspot": hotspot,
    "MVT": mvt,
    "NW": nw,
    "Pathfinder": pathfinder,
    "Srad-v2": srad_v2,
    "2DCONV": twodconv,
    "StreamTriad": streamtriad,
}

# published access-pattern category (Table VII + Section V-F)
CATEGORY = {
    "AddVectors": "streaming",
    "StreamTriad": "streaming",
    "2DCONV": "streaming",
    "Pathfinder": "streaming",
    "Hotspot": "regular",
    "Srad-v2": "regular",
    "Backprop": "regular",
    "MVT": "regular",
    "NW": "mixed",
    "ATAX": "random",
    "BICG": "random",
}


def get_trace(name: str, scale: float = 1.0) -> Trace:
    return BENCHMARKS[name](scale=scale)


def concurrent(traces: list[Trace], seed: int = 0, slice_len: int = 256,
               starts: list[int] | None = None) -> Trace:
    """Interleave multiple workloads in disjoint page ranges (Section V-F).

    Interleaving is at SCHEDULER-SLICE granularity (not per access): on real
    hardware each tenant's warps burst their own fault stream, so the
    migration stream keeps per-workload temporal locality (the property
    Fig. 5 visualises) while the global stream mixes pattern classes.

    The merge is TENANT-TAGGED: ``.tenant`` carries each access's workload
    index (``tenant_names`` maps it back to the constituent trace name), so
    multi-tenant consumers (:class:`repro.uvm.manager.TenantMux`) can demux
    the stream without re-deriving the schedule.  Page/pc/tb/kernel arrays
    are unchanged — single-manager consumers see the exact pre-PR-5 trace.

    The tenant set is NOT assumed static: ``starts[i]`` delays tenant ``i``'s
    admission until at least that many merged accesses have been produced
    (a session JOINING mid-run), and a tenant whose trace runs out simply
    LEAVES the schedule (its accesses end early).  The positional invariants
    hold regardless of churn: tag value ``i`` always names
    ``tenant_names[i]``, per-tenant access order is preserved, and a tenant
    that contributes no accesses at all (an empty or fully-deferred trace)
    keeps its index reserved — consumers must not assume every name appears
    in ``.tenant``.  When every not-yet-exhausted tenant is still waiting to
    join, the clock jumps to the earliest joiner instead of deadlocking.
    ``starts=None`` is the legacy static schedule, bit-identical to PR 5.
    """
    rng = np.random.default_rng(seed)
    offset = 0
    parts = []
    for t in traces:
        parts.append((t.page + offset, t.pc, t.tb, t.kernel))
        offset += t.n_pages
    joins = [0] * len(parts) if starts is None else [int(s) for s in starts]
    if len(joins) != len(parts):
        raise ValueError(f"starts must align with traces (expected {len(parts)}, got {len(joins)})")
    # random MERGE: pick a random workload each turn, take its NEXT slice —
    # cross-workload interleaving with strict temporal order per workload
    cursors = [0] * len(parts)
    produced = 0
    slices = []
    while any(cursors[i] < len(p[0]) for i, p in enumerate(parts)):
        live = [i for i, p in enumerate(parts)
                if cursors[i] < len(p[0]) and joins[i] <= produced]
        if not live:
            # every remaining tenant joins later: jump to the earliest one
            nxt = min(joins[i] for i, p in enumerate(parts) if cursors[i] < len(p[0]))
            live = [i for i, p in enumerate(parts)
                    if cursors[i] < len(p[0]) and joins[i] <= nxt]
        w = int(rng.choice(live))
        lo = cursors[w]
        hi = min(lo + slice_len, len(parts[w][0]))
        slices.append((w, lo, hi))
        cursors[w] = hi
        produced += hi - lo
    page, pc, tb, kern, tnt = [], [], [], [], []
    for w, lo, hi in slices:
        p = parts[w]
        page.append(p[0][lo:hi])
        pc.append(p[1][lo:hi] + 16 * w)
        tb.append(p[2][lo:hi])
        kern.append(p[3][lo:hi] + 64 * w)
        tnt.append(np.full(hi - lo, w, np.int32))
    cat = lambda chunks: (np.concatenate(chunks) if chunks else np.zeros(0, np.int64)).astype(np.int32)
    return Trace(
        "+".join(t.name for t in traces),
        cat(page),
        cat(pc),
        cat(tb),
        cat(kern),
        offset,
        tenant=cat(tnt),
        tenant_names=tuple(t.name for t in traces),
    )


# ---------------------------------------------------------------------------
# External UVM fault-log interchange (versioned JSONL).
# ---------------------------------------------------------------------------


def to_fault_log(trace: Trace, path, batch: int = 256) -> int:
    """Export a trace as a versioned JSONL UVM fault log; returns the number
    of data lines written.

    The format is exactly what ``python -m repro.uvm.cli serve`` consumes, so
    an exported (or externally captured) log replays through the live
    streaming manager unmodified:

    * one header COMMENT line carrying the schema version and trace metadata
      (``serve`` skips ``#`` lines)::

          # uvm-fault-log v1 {"name": ..., "n_pages": ..., "tenant_names": [...]}

    * one JSON object per fault batch: ``{"pages": [...], "pc": [...],
      "tb": [...], "kernel": [...]}`` plus ``"tenant": <index into
      tenant_names>`` on tenant-tagged traces.  Batches never straddle a
      tenant boundary, so each line is one tenant's coherent burst.

    ``path`` is a filesystem path or any text file object.
    :func:`from_fault_log` is the exact inverse (bit-identical round trip).
    """
    fh = open(path, "w") if isinstance(path, (str, bytes)) or hasattr(path, "__fspath__") else path
    try:
        meta = {"name": trace.name, "n_pages": int(trace.n_pages),
                "tenant_names": list(trace.tenant_names)}
        fh.write(f"{_FAULT_LOG_MAGIC} v{FAULT_LOG_VERSION} "
                 f"{json.dumps(meta, separators=(',', ':'))}\n")
        # split first at tenant-change boundaries, then at the batch size
        n = len(trace)
        if trace.tenant is not None and n:
            bounds = [0, *(np.flatnonzero(np.diff(trace.tenant)) + 1).tolist(), n]
        else:
            bounds = [0, n] if n else [0]
        lines = 0
        for b0, b1 in zip(bounds, bounds[1:]):
            for lo in range(b0, b1, batch):
                hi = min(lo + batch, b1)
                rec = {
                    "pages": trace.page[lo:hi].tolist(),
                    "pc": trace.pc[lo:hi].tolist(),
                    "tb": trace.tb[lo:hi].tolist(),
                    "kernel": trace.kernel[lo:hi].tolist(),
                }
                if trace.tenant is not None:
                    rec["tenant"] = int(trace.tenant[lo])
                fh.write(json.dumps(rec, separators=(",", ":")) + "\n")
                lines += 1
        return lines
    finally:
        if fh is not path:
            fh.close()


def from_fault_log(path) -> Trace:
    """Rebuild a :class:`Trace` from a versioned JSONL UVM fault log (the
    inverse of :func:`to_fault_log`; also accepts hand-written or externally
    captured logs that follow the schema).  ``path`` is a filesystem path or
    any text file object.  Raises ``ValueError`` on a missing/unsupported
    header or malformed records — ingestion fails loudly, replay through
    ``cli serve`` is where per-line fault tolerance lives."""
    fh = open(path) if isinstance(path, (str, bytes)) or hasattr(path, "__fspath__") else path
    try:
        meta = None
        page, pc, tb, kern, tnt = [], [], [], [], []
        tagged = False
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            if line.startswith("#"):
                if meta is None and line.startswith(_FAULT_LOG_MAGIC):
                    head = line[len(_FAULT_LOG_MAGIC):].strip().split(None, 1)
                    if not head or head[0] != f"v{FAULT_LOG_VERSION}":
                        raise ValueError(
                            f"unsupported fault-log version {head[0] if head else '?'!r} "
                            f"(supported: v{FAULT_LOG_VERSION})"
                        )
                    meta = json.loads(head[1]) if len(head) > 1 else {}
                continue
            if meta is None:
                raise ValueError(f"not a uvm-fault-log: line {lineno} precedes the "
                                 f"'{_FAULT_LOG_MAGIC} v{FAULT_LOG_VERSION}' header")
            rec = json.loads(line)
            pages = rec["pages"]
            n = len(pages)
            page.append(np.asarray(pages, np.int32))
            pc.append(np.asarray(rec.get("pc", [0] * n), np.int32))
            tb.append(np.asarray(rec.get("tb", [0] * n), np.int32))
            kern.append(np.asarray(rec.get("kernel", [0] * n), np.int32))
            if "tenant" in rec:
                tagged = True
                tnt.append(np.full(n, int(rec["tenant"]), np.int32))
            if tagged and len(tnt) != len(page):
                raise ValueError(f"line {lineno}: mixed tagged/untagged batches "
                                 f"(a tenant-tagged log must tag every batch)")
        if meta is None:
            raise ValueError(f"not a uvm-fault-log: missing '{_FAULT_LOG_MAGIC}' header line")
        cat = lambda chunks: np.concatenate(chunks) if chunks else np.zeros(0, np.int32)
        pages = cat(page)
        return Trace(
            meta.get("name", "fault-log"),
            pages,
            cat(pc),
            cat(tb),
            cat(kern),
            int(meta.get("n_pages", int(pages.max()) + 1 if len(pages) else 1)),
            tenant=cat(tnt) if tagged else None,
            tenant_names=tuple(meta.get("tenant_names", ())),
        )
    finally:
        if fh is not path:
            fh.close()
