"""Command-line front end for the declarative experiment API.

    PYTHONPATH=src python -m repro.uvm.cli run   --benchmark ATAX --policy lru --prefetch tree
    PYTHONPATH=src python -m repro.uvm.cli sweep --benchmarks ATAX BICG --policies lru hpe \
        --prefetchers demand tree --oversubs 1.25 1.5
    PYTHONPATH=src python -m repro.uvm.cli sweep --spec experiment.json
    PYTHONPATH=src python -m repro.uvm.cli report
    PYTHONPATH=src python -m repro.uvm.cli serve --input faults.jsonl --n-pages 4096
    PYTHONPATH=src python -m repro.uvm.cli export --phases StreamTriad PtrChase --out faults.jsonl

Every executed cell is published to the content-addressed run store
(``experiments/runs/`` by default; ``--runs-dir`` relocates it), so a
repeated invocation is served entirely from disk — the final
``# sweep cells=N hits=H computed=C`` line says how much work actually ran
(CI asserts ``computed=0`` on the second pass). ``--dump-spec`` writes the
composed :class:`~repro.uvm.api.specs.ExperimentSpec` as JSON, the
declarative artifact ``sweep --spec`` replays.

``serve`` is the streaming side: it drives a live multi-tenant
:class:`~repro.uvm.manager.TenantMux` over a JSONL fault stream (stdin or
``--input``), emitting one JSON action line (prefetch + pre-evict block
ids, pattern, accuracy) per observed batch — the skeleton of a deployable
UVM-backend sidecar.  Input lines::

    {"pages": [0, 1, 2, ...], "pc": [...], "tb": [...], "kernel": [...]}
    {"pages": [...], "tenant": "job-a"}
    {"feedback": {"was_evicted": [false, ...], "fault_count": 128}, "tenant": "job-a"}

``pc``/``tb``/``kernel`` are optional.  The optional ``tenant`` field
(string or int) routes the line to that tenant's own classifier ->
predictor pipeline — tenants are admitted on first contact and the action
line echoes the tag; untagged lines share the ``--default-tenant``
pipeline.  A ``feedback`` line closes its tenant's pending batch (untagged:
the most recently observed one) — without one the batch auto-closes on the
tenant's next observation, fine-tuning without the thrashing term and
leaving the fault clock unchanged.  Malformed lines never produce a
traceback: each yields a structured ``{"error": ..., "line": N}`` record
(and a non-zero exit under ``--strict``).

``export`` is the replay bridge: it writes any workload — a registered
benchmark, a zoo pattern, or a drifting trace composed on the command line
(``--phases``/``--switch``/``--mix-window``, or ``--drift-kind churn`` with
``--joins``/``--spans``) — as a versioned JSONL UVM fault log
(:func:`repro.uvm.trace.to_fault_log`) whose lines feed straight into
``serve``; real logs in the same schema ingest back through
:func:`repro.uvm.trace.from_fault_log`.  The action records ``serve`` emits
carry the live classifier verdict in their ``"pattern"`` field, so a
drifting replay shows the re-classification switch as it happens (tune it
with ``--reclass-interval``/``--reclass-hysteresis``).

``serve`` is fault-tolerant end to end: the degraded-mode health machine
is always on (action records carry ``"health"``/``"fallback"``; a trainer
failure degrades to rule-based actions instead of crashing),
``--checkpoint-dir``/``--checkpoint-every`` persist versioned snapshots at
round boundaries, ``--resume`` restores the latest one and replays only
the unconsumed input tail (bit-identical actions), SIGTERM/SIGINT drain
gracefully (close pending batches, flush a final snapshot + the stats
record), and ``--inject`` runs a seeded chaos schedule against the live
pipeline.  Note: ``--inject`` composed with ``--resume`` replays the
stream-transport faults deterministically but not the dispatch-fault
positions (the injector's RNG is not checkpointed).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.uvm.api import (
    ExperimentSpec,
    ModelSpec,
    PolicySpec,
    PrefetchSpec,
    RunStore,
    Session,
    WorkloadSpec,
)
from repro.uvm.api.specs import PAPER_TRAIN, TrainSpec, parse_scale
from repro.uvm.trace import PAGES_PER_BLOCK
from repro.uvm.zoo import workload_names


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--scale", default="quick",
                    help="'quick' (0.4x traces, <=6000 accesses), 'paper', or a float")
    ap.add_argument("--cap", type=int, default=None, help="max trace length (overrides the scale preset)")
    ap.add_argument("--runs-dir", default=None, help="run-store root (default experiments/runs)")
    ap.add_argument("--no-store", action="store_true", help="compute without reading/writing the run store")


def _session(args) -> Session:
    scale, cap = parse_scale(args.scale, args.cap)
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    if args.no_store:
        store.enabled = False
    model = ModelSpec(train=PAPER_TRAIN if args.scale == "paper" else TrainSpec())
    if args.scale == "paper":
        from repro.configs.predictor_paper import CONFIG

        model = dataclasses.replace(model, predictor=CONFIG)
    return Session(scale=scale, cap=cap, model=model, store=store)


def _strategy_model(session: Session, strategy: str, kind: str) -> ModelSpec | None:
    if strategy != "ours":
        return None
    return dataclasses.replace(session.model, kind=kind, pretrain=session.default_pretrain)


def _print_cell(cell, result) -> None:
    if cell.strategy == "sim":
        label = f"{cell.policy.name}+{cell.prefetch.name}"
    elif cell.strategy == "ours":
        label = f"ours[{cell.model.kind}]"
    else:
        label = "uvmsmart"
    stats = result.stats if hasattr(result, "stats") else result
    extra = f" top1={result.top1:.3f}" if hasattr(result, "top1") else ""
    print(f"{cell.workload.benchmark:>12} {label:>16} @{cell.oversubscription:<5} "
          f"thrash={stats['pages_thrashed']} faults={stats['faults']} "
          f"migrated={stats['migrated_blocks']}{extra}  key={cell.key}")


def cmd_run(args) -> int:
    session = _session(args)
    # build the cell through ExperimentSpec so it hashes IDENTICALLY to the
    # sweep path (non-sim strategies canonicalise their policy/prefetch
    # fields there — a different spelling here would duplicate store entries)
    spec = ExperimentSpec(
        name="run",
        workloads=(session.workload(args.benchmark),),
        strategy=args.strategy,
        policies=(PolicySpec(args.policy),),
        prefetchers=(PrefetchSpec(args.prefetch),),
        oversubscriptions=(args.oversub,),
        model=_strategy_model(session, args.strategy, args.kind),
    )
    [cell] = spec.cells()
    result = session.run(cell)
    _print_cell(cell, result)
    _report_counts("run", session, 1)
    return 0


def _sweep_spec(args, session: Session) -> ExperimentSpec:
    if args.spec:
        return ExperimentSpec.from_json(Path(args.spec).read_text())
    workloads = tuple(session.workload(b) for b in (args.benchmarks or session.benches))
    return ExperimentSpec(
        name=args.name,
        workloads=workloads,
        strategy=args.strategy,
        policies=tuple(PolicySpec(p) for p in args.policies),
        prefetchers=tuple(PrefetchSpec(p) for p in args.prefetchers),
        oversubscriptions=tuple(args.oversubs),
        model=_strategy_model(session, args.strategy, args.kind),
    )


def _report_counts(verb: str, session: Session, n_cells: int) -> None:
    c = session.counters
    hits = c["memory_hits"] + c["store_hits"]
    print(f"# {verb} cells={n_cells} hits={hits} computed={c['computed']} store={session.store.root}")


def cmd_sweep(args) -> int:
    session = _session(args)
    spec = _sweep_spec(args, session)
    if args.dump_spec:
        Path(args.dump_spec).write_text(json.dumps(spec.to_dict(), indent=2, sort_keys=True) + "\n")
        print(f"# wrote {args.dump_spec} (replay with: python -m repro.uvm.cli sweep --spec {args.dump_spec})")
    cells = spec.cells()
    results = session.sweep(cells)
    for cell, result in zip(cells, results):
        _print_cell(cell, result)
    _report_counts("sweep", session, len(cells))
    return 0


def cmd_report(args) -> int:
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    rows = []
    for key, rec in store.records():
        spec, result = rec.get("spec", {}), rec.get("result", {})
        if rec.get("kind") == "CellSpec":
            w = spec["workload"]
            stats = result.get("stats", result)
            rows.append({
                "key": key, "kind": "cell", "benchmark": w["benchmark"],
                "strategy": spec["strategy"],
                "policy": spec["policy"]["name"], "prefetch": spec["prefetch"]["name"],
                "oversub": spec["oversubscription"], "scale": w["scale"],
                "pages_thrashed": stats.get("pages_thrashed"), "faults": stats.get("faults"),
                "top1": round(result["top1"], 3) if "top1" in result else "",
            })
        elif rec.get("kind") == "ProtocolSpec":
            rows.append({
                "key": key, "kind": "protocol", "benchmark": spec["workload"]["benchmark"],
                "strategy": spec["mode"], "policy": "", "prefetch": "",
                "oversub": "", "scale": spec["workload"]["scale"],
                "pages_thrashed": "", "faults": "",
                "top1": round(result["top1"], 3),
            })
    if args.benchmark:
        rows = [r for r in rows if r["benchmark"] == args.benchmark]
    if not rows:
        print(f"# empty run store at {store.root}")
        return 0
    cols = list(rows[0])
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols)
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {args.csv} ({len(rows)} rows)")
    widths = {c: max(len(str(c)), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    print(f"# {len(rows)} stored runs in {store.root}")
    return 0


# back-compat aliases: the codec moved to repro.uvm.server.protocol so the
# single-connection sidecar and the async server share one schema
from repro.uvm.server.protocol import ProtocolError as _ServeLineError  # noqa: E402
from repro.uvm.server.protocol import decode_line as _decode_serve_line  # noqa: E402,F401


def _manager_config(args):
    """The per-session ManagerConfig both streaming surfaces (serve and
    server) build from the same flag set."""
    from repro.configs.predictor_paper import CONFIG_QUICK
    from repro.uvm.manager import HealthConfig, ManagerConfig

    n_blocks = (args.n_pages + args.pages_per_block - 1) // args.pages_per_block
    capacity = args.capacity if args.capacity is not None else max(int(n_blocks / args.oversub), 1)
    return ManagerConfig(
        predictor=CONFIG_QUICK,
        train=dataclasses.replace(TrainSpec(), group_size=args.group_size).to_train_config(),
        kind=args.kind, n_pages=args.n_pages, n_blocks=n_blocks, capacity=capacity,
        pages_per_block=args.pages_per_block,
        classifier=args.classifier, freq_table=args.freq_table,
        reclass_interval=args.reclass_interval, reclass_hysteresis=args.reclass_hysteresis,
        # the streaming surfaces always run the degraded-mode health
        # machine: a live stream must fail SOFT into rule-based actions
        health=HealthConfig(latency_budget_ms=args.latency_budget_ms),
    )


def _qos_controller(args, cfg):
    """The per-session BudgetController the shared --qos-* flags describe
    (``None`` when no tier is declared = the legacy shared pool)."""
    if not args.qos_tier:
        return None
    from repro.uvm.qos import BudgetController, parse_tier_flags

    return BudgetController(
        cfg.capacity, cfg.n_blocks, tiers=parse_tier_flags(args.qos_tier),
        stability=args.qos_stability, interval=args.qos_interval,
    )


def cmd_serve(args) -> int:
    import signal

    from repro.uvm.manager import TenantMux
    from repro.uvm.server.session import StreamSession, SyncDispatch, drive

    cfg = _manager_config(args)
    # tenants are admitted on first contact (auto_create): every "tenant"-
    # tagged line gets its own classifier->predictor pipeline; untagged
    # lines share the --default-tenant one (the single-workload case)
    mux = TenantMux(cfg, shared_freq_table=args.shared_freq_table,
                    qos=_qos_controller(args, cfg))
    injector = None
    if args.inject:
        from repro.uvm.manager import ChaosSchedule, FaultInjector

        # wrap BEFORE any tenant is admitted so lazily-created managers
        # inherit the chaos trainer (and restore() rebuilds through it)
        injector = FaultInjector(ChaosSchedule.parse(args.inject))
        mux.trainer = injector.wrap_trainer(mux.trainer)
    store = None
    if args.checkpoint_dir:
        from repro.uvm.manager import SnapshotStore

        store = SnapshotStore(args.checkpoint_dir)
        store.clean_tmp()  # sweep turds a killed writer left behind
    session = StreamSession(mux, default_tenant=args.default_tenant,
                            store=store, checkpoint_every=args.checkpoint_every)
    if args.resume:
        if store is None:
            print("# serve --resume requires --checkpoint-dir", file=sys.stderr)
            return 2
        if store.latest_step() is not None:
            batches, resume_lineno = session.resume_latest()
            print(f"# resumed batch={batches} lineno={resume_lineno} "
                  f"tenants={len(mux.managers)} from {store.dir}", flush=True)
    # open the input only after every early-exit validation above: an
    # early `return 2` must not leak the handle (pytest's unraisable
    # gate turns the ResourceWarning into a failure)
    fh = sys.stdin if args.input == "-" else open(args.input)
    dispatch = SyncDispatch(mux.trainer, cfg.use_lucir)

    # SIGTERM/SIGINT: finish the current line, close pending batches, flush
    # a final snapshot + the stats record, exit 0 (a drain, not a crash)
    stop: dict = {}
    installed = {}
    for signame in ("SIGTERM", "SIGINT"):
        signum = getattr(signal, signame)
        try:
            installed[signum] = signal.signal(
                signum, lambda s, _frame: stop.__setitem__("signal", s)
            )
        except ValueError:  # not the main thread (embedded callers)
            pass
    line_iter = injector.transform_lines(fh) if injector is not None else fh
    try:
        for line in line_iter:
            if stop:
                break
            for rec in drive(session.step(line), dispatch):
                print(rec, flush=True)
        drive(session.drain(), dispatch)
    finally:
        for signum, old in installed.items():
            signal.signal(signum, old)
        if fh is not sys.stdin:
            fh.close()
    if store is not None:
        session.save_snapshot()
    if injector is not None:
        fired = {k: injector.counts[k] for k in sorted(injector.counts)}
        print(f"# chaos schedule={json.dumps(injector.schedule.to_dict(), sort_keys=True)} "
              f"fired={json.dumps(fired)}", flush=True)
    if stop:
        print(f"# serve shutdown signal={stop['signal']} (state flushed)", flush=True)
    print(session.summary_line())
    return 2 if session.errors and args.strict else 0


def cmd_server(args) -> int:
    """Async fault-stream server: many concurrent serve sessions, one
    cross-connection microbatched trainer dispatch per tick."""
    import asyncio
    import signal

    from repro.core.incremental import Trainer
    from repro.uvm.server.core import FaultStreamServer, ServerConfig

    mcfg = _manager_config(args)
    cfg = ServerConfig(
        manager=mcfg, default_tenant=args.default_tenant,
        shared_freq_table=args.shared_freq_table,
        max_sessions=args.max_sessions, idle_timeout_s=args.idle_timeout,
        gather_spins=args.gather_spins, microbatch=not args.serial,
        exec_mode=args.engine,
        checkpoint_dir=args.checkpoint_dir, checkpoint_every=args.checkpoint_every,
        resume=args.resume, inject=args.inject,
        qos_tiers=args.qos_tier, qos_stability=args.qos_stability,
        qos_interval=args.qos_interval,
    )
    if args.socket is None and args.port is None:
        print("# server needs --socket PATH and/or --port N", file=sys.stderr)
        return 2

    async def main() -> int:
        trainer = Trainer(mcfg.predictor, mcfg.train, mcfg.kind)
        if args.aot_cache:
            from repro.uvm.server.aot import enable_aot

            enable_aot(trainer, args.aot_cache)
        server = FaultStreamServer(cfg, trainer=trainer)
        await server.start(path=args.socket, host=args.host if args.port is not None else None,
                           port=args.port or 0)
        where = " ".join(filter(None, [
            f"unix={args.socket}" if args.socket else None,
            f"tcp={args.host}:{server.tcp_port}" if args.port is not None else None,
        ]))
        mode = "serial" if args.serial else f"batched-{server.dispatcher.engine}"
        print(f"# server listening {where} mode={mode} "
              f"max_sessions={cfg.max_sessions}", flush=True)
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for signum in (signal.SIGTERM, signal.SIGINT):
            try:
                loop.add_signal_handler(signum, stop.set)
            except NotImplementedError:  # pragma: no cover - non-unix loops
                pass
        stopper = asyncio.ensure_future(stop.wait())
        forever = asyncio.ensure_future(server.serve_forever())
        await asyncio.wait({stopper, forever}, return_when=asyncio.FIRST_COMPLETED)
        forever.cancel()
        await server.shutdown()
        if server.injector is not None:
            inj = server.injector
            fired = {k: inj.counts[k] for k in sorted(inj.counts)}
            print(f"# chaos schedule={json.dumps(inj.schedule.to_dict(), sort_keys=True)} "
                  f"fired={json.dumps(fired)}", flush=True)
        if args.aot_cache:
            print(f"# aot cache={args.aot_cache} {json.dumps(trainer.aot_cache.stats())}",
                  flush=True)
        print(server.summary_line(), flush=True)
        return 0

    return asyncio.run(main())


def cmd_loadgen(args) -> int:
    """Deterministic multi-client replay of an exported fault log against
    a running server; reports faults/sec + p50/p99 action latency."""
    import asyncio

    from repro.uvm.server.loadgen import make_connector, run_loadgen

    with (sys.stdin if args.input == "-" else open(args.input)) as fh:
        lines = [l.rstrip("\n") for l in fh if l.strip() and not l.startswith("#")]
    chaos_schedules = {}
    if args.inject is not None:
        from repro.uvm.manager import ChaosSchedule, FaultInjector

        chaos_schedules[args.chaos_client] = FaultInjector(ChaosSchedule.parse(args.inject))
    stats = asyncio.run(run_loadgen(
        make_connector(args.connect), lines, args.clients, rate=args.rate,
        repeat=args.repeat, hello_prefix=args.hello_prefix,
        chaos_schedules=chaos_schedules,
        malformed_every=args.malformed_every, malformed_client=args.malformed_client,
    ))
    if args.json:
        payload = {k: v for k, v in dataclasses.asdict(stats).items() if k != "per_client"}
        Path(args.json).write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# loadgen clients={stats.clients} lines={stats.lines_sent} "
          f"actions={stats.actions} errors={stats.errors} faults={stats.faults} "
          f"wall_s={stats.wall_s:.3f} faults_per_s={stats.faults_per_s:.1f} "
          f"p50_ms={stats.p50_ms:.2f} p99_ms={stats.p99_ms:.2f}")
    return 0


def _export_workload(args, session: Session) -> WorkloadSpec:
    if args.phases:
        return WorkloadSpec.drifting(
            tuple(args.phases), kind=args.drift_kind, scale=session.scale, cap=session.cap,
            segment=args.segment, switch=args.switch, mix_window=args.mix_window,
            joins=tuple(args.joins or ()), spans=tuple(args.spans or ()),
            slice_len=args.slice_len, seed=args.seed,
        )
    if not args.benchmark:
        raise SystemExit("export needs --benchmark or --phases")
    return session.workload(args.benchmark)


def cmd_export(args) -> int:
    from repro.uvm.trace import to_fault_log

    session = _session(args)
    w = _export_workload(args, session)
    tr = session.trace(w)
    out = sys.stdout if args.out == "-" else args.out
    lines = to_fault_log(tr, out, batch=args.batch)
    print(f"# export workload={w.benchmark} accesses={len(tr)} n_pages={tr.n_pages} "
          f"tenants={len(tr.tenant_names)} lines={lines} out={args.out}",
          file=sys.stderr if args.out == "-" else sys.stdout)
    return 0


SUBCOMMANDS = {"run": cmd_run, "sweep": cmd_sweep, "report": cmd_report,
               "serve": cmd_serve, "server": cmd_server, "loadgen": cmd_loadgen,
               "export": cmd_export}


def _add_stream_flags(p) -> None:
    """The per-session manager surface `serve` and `server` share: one
    flag set -> one ManagerConfig (:func:`_manager_config`), so the two
    streaming surfaces cannot drift apart."""
    p.add_argument("--n-pages", type=int, default=4096, help="working-set size in pages")
    p.add_argument("--pages-per-block", type=int, default=PAGES_PER_BLOCK,
                   help="pages per management block (1 = manage pages directly)")
    p.add_argument("--oversub", type=float, default=1.25,
                   help="oversubscription level (sets the prefetch-budget capacity)")
    p.add_argument("--capacity", type=int, default=None,
                   help="device capacity in blocks (overrides --oversub)")
    p.add_argument("--kind", default="transformer", help="registered predictor kind")
    p.add_argument("--classifier", default="dfa", help="registered pattern classifier")
    p.add_argument("--freq-table", default="setassoc", help="registered frequency-table engine")
    p.add_argument("--group-size", type=int, default=512, help="fine-tune schedule group size")
    p.add_argument("--default-tenant", default="default",
                   help="tenant id for JSONL lines without a per-line 'tenant' field "
                        "(tagged lines each get their own classifier->predictor pipeline)")
    p.add_argument("--shared-freq-table", action="store_true",
                   help="tenants share ONE prediction-frequency table (default: isolated per tenant)")
    p.add_argument("--reclass-interval", type=int, default=0,
                   help="re-run the pattern classifier every N faults (observed accesses "
                        "when no feedback reports a fault count; 0 = every batch)")
    p.add_argument("--reclass-hysteresis", type=int, default=2,
                   help="consecutive agreeing windows before a pattern switch")
    p.add_argument("--checkpoint-dir", default=None,
                   help="snapshot directory (versioned, content-hashed manager state; "
                        "also written once on shutdown; the server keeps one "
                        "subdirectory per hello-named session)")
    p.add_argument("--checkpoint-every", type=int, default=0,
                   help="snapshot after every N observed batches, at the next fully "
                        "fed-back round boundary (0 = only the shutdown snapshot)")
    p.add_argument("--resume", action="store_true",
                   help="restore the latest snapshot in --checkpoint-dir and skip the "
                        "input lines it already consumed (the resumed action tail is "
                        "bit-identical to an uninterrupted run)")
    p.add_argument("--inject", default=None,
                   help="seeded chaos schedule, 'key=prob,...,seed=N' or '@plan.json' "
                        "(see repro.uvm.manager.chaos); exercises the health machine — "
                        "degraded rounds answer with rule-based fallback actions "
                        "(health/fallback fields on every action record)")
    p.add_argument("--latency-budget-ms", type=float, default=0.0,
                   help="per-observe dispatch budget in ms; overruns demote the learned "
                        "path to degraded health (0 = no budget)")
    p.add_argument("--qos-tier", action="append", default=None, metavar="TENANT:FLOOR[:SHARE]",
                   help="per-tenant QoS tier (repeatable): guaranteed FLOOR fraction of "
                        "device capacity plus elastic SHARE weight (default 1.0); any "
                        "--qos-tier turns on budgeted eviction — over-budget tenants' "
                        "blocks are evicted before any under-budget tenant's, and each "
                        "action record gains the tenant's current 'budget'")
    p.add_argument("--qos-stability", default="percentile",
                   help="registered stability scorer weighting the elastic pool "
                        "(percentile | gmr; see repro.uvm.qos)")
    p.add_argument("--qos-interval", type=int, default=1,
                   help="feedback rounds between budget recomputes")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.uvm.cli", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    p_run = sub.add_parser("run", help="execute (or look up) one experiment cell")
    _add_common(p_run)
    p_run.add_argument("--benchmark", required=True, choices=workload_names())
    p_run.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_run.add_argument("--policy", default="lru", help="registered eviction policy (sim)")
    p_run.add_argument("--prefetch", default="tree", help="registered prefetcher (sim)")
    p_run.add_argument("--oversub", type=float, default=1.25)
    p_run.add_argument("--kind", default="transformer", help="registered predictor kind (ours)")

    p_sweep = sub.add_parser("sweep", help="execute a cross-product of cells in batched lanes")
    _add_common(p_sweep)
    p_sweep.add_argument("--spec", default=None, help="ExperimentSpec JSON to replay (overrides the axes)")
    p_sweep.add_argument("--name", default="sweep")
    p_sweep.add_argument("--benchmarks", nargs="*", default=None, choices=workload_names())
    p_sweep.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_sweep.add_argument("--policies", nargs="*", default=["lru"])
    p_sweep.add_argument("--prefetchers", nargs="*", default=["tree"])
    p_sweep.add_argument("--oversubs", nargs="*", type=float, default=[1.25])
    p_sweep.add_argument("--kind", default="transformer")
    p_sweep.add_argument("--dump-spec", default=None, help="write the composed ExperimentSpec JSON here")

    p_rep = sub.add_parser("report", help="tabulate the persistent run store")
    p_rep.add_argument("--runs-dir", default=None)
    p_rep.add_argument("--benchmark", default=None)
    p_rep.add_argument("--csv", default=None, help="also write the table as CSV")

    p_srv = sub.add_parser("serve", help="drive the streaming manager over a JSONL fault stream")
    p_srv.add_argument("--input", default="-", help="JSONL fault-batch stream ('-' = stdin)")
    _add_stream_flags(p_srv)
    p_srv.add_argument("--strict", action="store_true",
                       help="exit non-zero if any malformed line was reported")

    p_ssrv = sub.add_parser(
        "server",
        help="async fault-stream server: many concurrent serve sessions, one "
             "cross-connection microbatched ('tenant'-aware, health-guarded) "
             "trainer dispatch per tick; action records carry the same "
             '"pattern"/"health"/"fallback" fields as serve',
    )
    _add_stream_flags(p_ssrv)
    p_ssrv.add_argument("--socket", default=None,
                        help="unix socket path to listen on (and/or --port)")
    p_ssrv.add_argument("--host", default="127.0.0.1", help="TCP bind host (with --port)")
    p_ssrv.add_argument("--port", type=int, default=None,
                        help="TCP port to listen on (0 = ephemeral, announced on startup)")
    p_ssrv.add_argument("--max-sessions", type=int, default=4096,
                        help="admission cap: concurrent connections beyond it are refused "
                             "with a structured error record")
    p_ssrv.add_argument("--idle-timeout", type=float, default=0.0,
                        help="close (drain + snapshot) connections idle this many seconds "
                             "(0 = never)")
    p_ssrv.add_argument("--gather-spins", type=int, default=2,
                        help="event-loop passes the dispatcher waits per tick so every "
                             "connection with buffered input stages its half")
    p_ssrv.add_argument("--serial", action="store_true",
                        help="per-connection serial dispatch instead of cross-connection "
                             "microbatching (the serve_perf baseline; action streams are "
                             "bit-identical either way)")
    p_ssrv.add_argument("--engine", choices=("auto", "vmap", "fused"), default="auto",
                        help="how a microbatched tick executes: 'vmap' stacks every lane "
                             "into one vmapped dispatch (pays on multi-device), 'fused' "
                             "sweeps the warm serial jits in one worker hop (single-device "
                             "default); 'auto' picks by device count, REPRO_OURS_BATCHED "
                             "overrides")
    p_ssrv.add_argument("--aot-cache", default=None,
                        help="directory of AOT-exported trainer executables: compile-once "
                             "artifacts reloaded on start so a fresh process skips the "
                             "per-process jit traces (falls back to jit on any mismatch)")

    p_lg = sub.add_parser(
        "loadgen",
        help="deterministic multi-client load generator: replay an exported "
             "fault log over N concurrent server connections at a target rate",
    )
    p_lg.add_argument("--connect", required=True,
                      help="server address: 'unix:/path/to.sock' or 'host:port'")
    p_lg.add_argument("--input", default="-",
                      help="JSONL fault log every client replays ('-' = stdin)")
    p_lg.add_argument("--clients", type=int, default=8, help="concurrent connections")
    p_lg.add_argument("--rate", type=float, default=0.0,
                      help="per-client lines/second pacing (0 = as fast as the "
                           "closed loop allows)")
    p_lg.add_argument("--repeat", type=int, default=1, help="replay passes per client")
    p_lg.add_argument("--hello-prefix", default=None,
                      help="send a hello line naming each session '<prefix><idx>' "
                           "(binds server-side checkpoints/resume)")
    p_lg.add_argument("--malformed-every", type=int, default=0,
                      help="the --malformed-client injects a non-JSON line every N lines")
    p_lg.add_argument("--malformed-client", type=int, default=None,
                      help="index of the client that injects malformed lines")
    p_lg.add_argument("--inject", default=None,
                      help="seeded chaos schedule applied to the --chaos-client's OUTGOING "
                           "stream (transform_lines: drops/dups/reorders/losses)")
    p_lg.add_argument("--chaos-client", type=int, default=0,
                      help="index of the client whose stream --inject transforms")
    p_lg.add_argument("--json", default=None, help="also write the aggregate stats as JSON")

    p_exp = sub.add_parser(
        "export",
        help="write a workload (benchmark or drifting zoo trace) as a versioned "
             "JSONL UVM fault log, ready to replay through `serve`",
    )
    _add_common(p_exp)
    p_exp.add_argument("--benchmark", default=None, choices=workload_names(),
                       help="a registered workload (the 11-benchmark suite + the zoo patterns)")
    p_exp.add_argument("--phases", nargs="*", default=None,
                       help="build a drifting zoo trace instead: two or more workload names, "
                            "spliced (--drift-kind phase) or merged as churning tenants "
                            "(--drift-kind churn)")
    p_exp.add_argument("--drift-kind", default="phase", choices=("phase", "churn"))
    p_exp.add_argument("--segment", type=int, default=1500,
                       help="accesses per phase segment (--drift-kind phase)")
    p_exp.add_argument("--switch", default="abrupt", choices=("abrupt", "gradual"),
                       help="phase-boundary style; 'gradual' blends --mix-window accesses")
    p_exp.add_argument("--mix-window", type=int, default=0,
                       help="accesses blended around each gradual phase boundary")
    p_exp.add_argument("--joins", nargs="*", type=int, default=None,
                       help="per-tenant admission offsets in merged accesses (churn; "
                            "default: auto-staggered)")
    p_exp.add_argument("--spans", nargs="*", type=int, default=None,
                       help="per-tenant access budgets (churn; 0 = the full trace)")
    p_exp.add_argument("--slice-len", type=int, default=256, help="scheduler-slice length (churn)")
    p_exp.add_argument("--seed", type=int, default=0, help="zoo generator seed")
    p_exp.add_argument("--batch", type=int, default=256, help="accesses per fault-log line")
    p_exp.add_argument("--out", default="-", help="output path ('-' = stdout)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return SUBCOMMANDS[args.command](args)


if __name__ == "__main__":
    raise SystemExit(main())
