"""Command-line front end for the declarative experiment API.

    PYTHONPATH=src python -m repro.uvm.cli run   --benchmark ATAX --policy lru --prefetch tree
    PYTHONPATH=src python -m repro.uvm.cli sweep --benchmarks ATAX BICG --policies lru hpe \
        --prefetchers demand tree --oversubs 1.25 1.5
    PYTHONPATH=src python -m repro.uvm.cli sweep --spec experiment.json
    PYTHONPATH=src python -m repro.uvm.cli report
    PYTHONPATH=src python -m repro.uvm.cli serve --input faults.jsonl --n-pages 4096

Every executed cell is published to the content-addressed run store
(``experiments/runs/`` by default; ``--runs-dir`` relocates it), so a
repeated invocation is served entirely from disk — the final
``# sweep cells=N hits=H computed=C`` line says how much work actually ran
(CI asserts ``computed=0`` on the second pass). ``--dump-spec`` writes the
composed :class:`~repro.uvm.api.specs.ExperimentSpec` as JSON, the
declarative artifact ``sweep --spec`` replays.

``serve`` is the streaming side: it drives one live
:class:`~repro.uvm.manager.OversubscriptionManager` over a JSONL fault
stream (stdin or ``--input``), emitting one JSON action line (prefetch +
pre-evict block ids, pattern, accuracy) per observed batch — the skeleton
of a deployable UVM-backend sidecar.  Input lines::

    {"pages": [0, 1, 2, ...], "pc": [...], "tb": [...], "kernel": [...]}
    {"feedback": {"was_evicted": [false, ...], "fault_count": 128}}

(``pc``/``tb``/``kernel`` optional; a ``feedback`` line closes the
previous batch — without one the batch auto-closes, fine-tuning without
the thrashing term and leaving the fault clock unchanged.)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.uvm.api import (
    ExperimentSpec,
    ModelSpec,
    PolicySpec,
    PrefetchSpec,
    RunStore,
    Session,
    WorkloadSpec,
)
from repro.uvm.api.specs import PAPER_TRAIN, TrainSpec, parse_scale
from repro.uvm.trace import BENCHMARKS, PAGES_PER_BLOCK


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--scale", default="quick",
                    help="'quick' (0.4x traces, <=6000 accesses), 'paper', or a float")
    ap.add_argument("--cap", type=int, default=None, help="max trace length (overrides the scale preset)")
    ap.add_argument("--runs-dir", default=None, help="run-store root (default experiments/runs)")
    ap.add_argument("--no-store", action="store_true", help="compute without reading/writing the run store")


def _session(args) -> Session:
    scale, cap = parse_scale(args.scale, args.cap)
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    if args.no_store:
        store.enabled = False
    model = ModelSpec(train=PAPER_TRAIN if args.scale == "paper" else TrainSpec())
    if args.scale == "paper":
        from repro.configs.predictor_paper import CONFIG

        model = dataclasses.replace(model, predictor=CONFIG)
    return Session(scale=scale, cap=cap, model=model, store=store)


def _strategy_model(session: Session, strategy: str, kind: str) -> ModelSpec | None:
    if strategy != "ours":
        return None
    return dataclasses.replace(session.model, kind=kind, pretrain=session.default_pretrain)


def _print_cell(cell, result) -> None:
    if cell.strategy == "sim":
        label = f"{cell.policy.name}+{cell.prefetch.name}"
    elif cell.strategy == "ours":
        label = f"ours[{cell.model.kind}]"
    else:
        label = "uvmsmart"
    stats = result.stats if hasattr(result, "stats") else result
    extra = f" top1={result.top1:.3f}" if hasattr(result, "top1") else ""
    print(f"{cell.workload.benchmark:>12} {label:>16} @{cell.oversubscription:<5} "
          f"thrash={stats['pages_thrashed']} faults={stats['faults']} "
          f"migrated={stats['migrated_blocks']}{extra}  key={cell.key}")


def cmd_run(args) -> int:
    session = _session(args)
    # build the cell through ExperimentSpec so it hashes IDENTICALLY to the
    # sweep path (non-sim strategies canonicalise their policy/prefetch
    # fields there — a different spelling here would duplicate store entries)
    spec = ExperimentSpec(
        name="run",
        workloads=(session.workload(args.benchmark),),
        strategy=args.strategy,
        policies=(PolicySpec(args.policy),),
        prefetchers=(PrefetchSpec(args.prefetch),),
        oversubscriptions=(args.oversub,),
        model=_strategy_model(session, args.strategy, args.kind),
    )
    [cell] = spec.cells()
    result = session.run(cell)
    _print_cell(cell, result)
    _report_counts("run", session, 1)
    return 0


def _sweep_spec(args, session: Session) -> ExperimentSpec:
    if args.spec:
        return ExperimentSpec.from_json(Path(args.spec).read_text())
    workloads = tuple(session.workload(b) for b in (args.benchmarks or session.benches))
    return ExperimentSpec(
        name=args.name,
        workloads=workloads,
        strategy=args.strategy,
        policies=tuple(PolicySpec(p) for p in args.policies),
        prefetchers=tuple(PrefetchSpec(p) for p in args.prefetchers),
        oversubscriptions=tuple(args.oversubs),
        model=_strategy_model(session, args.strategy, args.kind),
    )


def _report_counts(verb: str, session: Session, n_cells: int) -> None:
    c = session.counters
    hits = c["memory_hits"] + c["store_hits"]
    print(f"# {verb} cells={n_cells} hits={hits} computed={c['computed']} store={session.store.root}")


def cmd_sweep(args) -> int:
    session = _session(args)
    spec = _sweep_spec(args, session)
    if args.dump_spec:
        Path(args.dump_spec).write_text(json.dumps(spec.to_dict(), indent=2, sort_keys=True) + "\n")
        print(f"# wrote {args.dump_spec} (replay with: python -m repro.uvm.cli sweep --spec {args.dump_spec})")
    cells = spec.cells()
    results = session.sweep(cells)
    for cell, result in zip(cells, results):
        _print_cell(cell, result)
    _report_counts("sweep", session, len(cells))
    return 0


def cmd_report(args) -> int:
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    rows = []
    for key, rec in store.records():
        spec, result = rec.get("spec", {}), rec.get("result", {})
        if rec.get("kind") == "CellSpec":
            w = spec["workload"]
            stats = result.get("stats", result)
            rows.append({
                "key": key, "kind": "cell", "benchmark": w["benchmark"],
                "strategy": spec["strategy"],
                "policy": spec["policy"]["name"], "prefetch": spec["prefetch"]["name"],
                "oversub": spec["oversubscription"], "scale": w["scale"],
                "pages_thrashed": stats.get("pages_thrashed"), "faults": stats.get("faults"),
                "top1": round(result["top1"], 3) if "top1" in result else "",
            })
        elif rec.get("kind") == "ProtocolSpec":
            rows.append({
                "key": key, "kind": "protocol", "benchmark": spec["workload"]["benchmark"],
                "strategy": spec["mode"], "policy": "", "prefetch": "",
                "oversub": "", "scale": spec["workload"]["scale"],
                "pages_thrashed": "", "faults": "",
                "top1": round(result["top1"], 3),
            })
    if args.benchmark:
        rows = [r for r in rows if r["benchmark"] == args.benchmark]
    if not rows:
        print(f"# empty run store at {store.root}")
        return 0
    cols = list(rows[0])
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols)
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {args.csv} ({len(rows)} rows)")
    widths = {c: max(len(str(c)), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    print(f"# {len(rows)} stored runs in {store.root}")
    return 0


def cmd_serve(args) -> int:
    import numpy as np

    from repro.configs.predictor_paper import CONFIG_QUICK
    from repro.uvm.manager import FaultBatch, ManagerConfig, Outcomes, OversubscriptionManager

    n_blocks = (args.n_pages + args.pages_per_block - 1) // args.pages_per_block
    capacity = args.capacity if args.capacity is not None else max(int(n_blocks / args.oversub), 1)
    cfg = ManagerConfig(
        predictor=CONFIG_QUICK,
        train=dataclasses.replace(TrainSpec(), group_size=args.group_size).to_train_config(),
        kind=args.kind, n_pages=args.n_pages, n_blocks=n_blocks, capacity=capacity,
        pages_per_block=args.pages_per_block,
        classifier=args.classifier, freq_table=args.freq_table,
    )
    mgr = OversubscriptionManager(cfg)
    fh = sys.stdin if args.input == "-" else open(args.input)
    pending = False
    last_fault = 0
    batches = 0
    try:
        for line in fh:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            rec = json.loads(line)
            if "feedback" in rec:
                fb = rec["feedback"] or {}
                last_fault = int(fb.get("fault_count", last_fault))
                if pending:
                    we = fb.get("was_evicted")
                    mgr.feedback(Outcomes(
                        was_evicted=np.asarray(we, bool) if we is not None else None,
                        fault_count=last_fault,
                    ))
                    pending = False
                continue
            if "pages" not in rec:
                raise SystemExit(f"serve: line needs 'pages' or 'feedback': {line[:80]}")
            if pending:  # auto-close the previous batch (no outcome report)
                mgr.feedback(Outcomes(fault_count=last_fault))
            actions = mgr.observe(FaultBatch(
                np.asarray(rec["pages"], np.int64),
                rec.get("pc"), rec.get("tb"), rec.get("kernel"),
            ))
            pending = True
            batches += 1
            print(json.dumps({
                "batch": batches,
                "pattern": actions.pattern,
                "n_samples": actions.n_samples,
                "accuracy": actions.accuracy,
                "warm": actions.warm,
                "prefetch_blocks": np.asarray(actions.prefetch_blocks).tolist(),
                "pre_evict_blocks": np.asarray(actions.pre_evict_blocks).tolist(),
            }), flush=True)
        if pending:
            mgr.feedback(Outcomes(fault_count=last_fault))
    finally:
        if fh is not sys.stdin:
            fh.close()
    print(f"# serve batches={batches} predictions={mgr.n_predictions} "
          f"patterns={mgr.n_models} classes={mgr.n_classes} top1={mgr.top1:.3f}")
    return 0


SUBCOMMANDS = {"run": cmd_run, "sweep": cmd_sweep, "report": cmd_report, "serve": cmd_serve}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.uvm.cli", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    p_run = sub.add_parser("run", help="execute (or look up) one experiment cell")
    _add_common(p_run)
    p_run.add_argument("--benchmark", required=True, choices=sorted(BENCHMARKS))
    p_run.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_run.add_argument("--policy", default="lru", help="registered eviction policy (sim)")
    p_run.add_argument("--prefetch", default="tree", help="registered prefetcher (sim)")
    p_run.add_argument("--oversub", type=float, default=1.25)
    p_run.add_argument("--kind", default="transformer", help="registered predictor kind (ours)")

    p_sweep = sub.add_parser("sweep", help="execute a cross-product of cells in batched lanes")
    _add_common(p_sweep)
    p_sweep.add_argument("--spec", default=None, help="ExperimentSpec JSON to replay (overrides the axes)")
    p_sweep.add_argument("--name", default="sweep")
    p_sweep.add_argument("--benchmarks", nargs="*", default=None, choices=sorted(BENCHMARKS))
    p_sweep.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_sweep.add_argument("--policies", nargs="*", default=["lru"])
    p_sweep.add_argument("--prefetchers", nargs="*", default=["tree"])
    p_sweep.add_argument("--oversubs", nargs="*", type=float, default=[1.25])
    p_sweep.add_argument("--kind", default="transformer")
    p_sweep.add_argument("--dump-spec", default=None, help="write the composed ExperimentSpec JSON here")

    p_rep = sub.add_parser("report", help="tabulate the persistent run store")
    p_rep.add_argument("--runs-dir", default=None)
    p_rep.add_argument("--benchmark", default=None)
    p_rep.add_argument("--csv", default=None, help="also write the table as CSV")

    p_srv = sub.add_parser("serve", help="drive the streaming manager over a JSONL fault stream")
    p_srv.add_argument("--input", default="-", help="JSONL fault-batch stream ('-' = stdin)")
    p_srv.add_argument("--n-pages", type=int, default=4096, help="working-set size in pages")
    p_srv.add_argument("--pages-per-block", type=int, default=PAGES_PER_BLOCK,
                       help="pages per management block (1 = manage pages directly)")
    p_srv.add_argument("--oversub", type=float, default=1.25,
                       help="oversubscription level (sets the prefetch-budget capacity)")
    p_srv.add_argument("--capacity", type=int, default=None,
                       help="device capacity in blocks (overrides --oversub)")
    p_srv.add_argument("--kind", default="transformer", help="registered predictor kind")
    p_srv.add_argument("--classifier", default="dfa", help="registered pattern classifier")
    p_srv.add_argument("--freq-table", default="setassoc", help="registered frequency-table engine")
    p_srv.add_argument("--group-size", type=int, default=512, help="fine-tune schedule group size")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return SUBCOMMANDS[args.command](args)


if __name__ == "__main__":
    raise SystemExit(main())
