"""Command-line front end for the declarative experiment API.

    PYTHONPATH=src python -m repro.uvm.cli run   --benchmark ATAX --policy lru --prefetch tree
    PYTHONPATH=src python -m repro.uvm.cli sweep --benchmarks ATAX BICG --policies lru hpe \
        --prefetchers demand tree --oversubs 1.25 1.5
    PYTHONPATH=src python -m repro.uvm.cli sweep --spec experiment.json
    PYTHONPATH=src python -m repro.uvm.cli report
    PYTHONPATH=src python -m repro.uvm.cli serve --input faults.jsonl --n-pages 4096
    PYTHONPATH=src python -m repro.uvm.cli export --phases StreamTriad PtrChase --out faults.jsonl

Every executed cell is published to the content-addressed run store
(``experiments/runs/`` by default; ``--runs-dir`` relocates it), so a
repeated invocation is served entirely from disk — the final
``# sweep cells=N hits=H computed=C`` line says how much work actually ran
(CI asserts ``computed=0`` on the second pass). ``--dump-spec`` writes the
composed :class:`~repro.uvm.api.specs.ExperimentSpec` as JSON, the
declarative artifact ``sweep --spec`` replays.

``serve`` is the streaming side: it drives a live multi-tenant
:class:`~repro.uvm.manager.TenantMux` over a JSONL fault stream (stdin or
``--input``), emitting one JSON action line (prefetch + pre-evict block
ids, pattern, accuracy) per observed batch — the skeleton of a deployable
UVM-backend sidecar.  Input lines::

    {"pages": [0, 1, 2, ...], "pc": [...], "tb": [...], "kernel": [...]}
    {"pages": [...], "tenant": "job-a"}
    {"feedback": {"was_evicted": [false, ...], "fault_count": 128}, "tenant": "job-a"}

``pc``/``tb``/``kernel`` are optional.  The optional ``tenant`` field
(string or int) routes the line to that tenant's own classifier ->
predictor pipeline — tenants are admitted on first contact and the action
line echoes the tag; untagged lines share the ``--default-tenant``
pipeline.  A ``feedback`` line closes its tenant's pending batch (untagged:
the most recently observed one) — without one the batch auto-closes on the
tenant's next observation, fine-tuning without the thrashing term and
leaving the fault clock unchanged.  Malformed lines never produce a
traceback: each yields a structured ``{"error": ..., "line": N}`` record
(and a non-zero exit under ``--strict``).

``export`` is the replay bridge: it writes any workload — a registered
benchmark, a zoo pattern, or a drifting trace composed on the command line
(``--phases``/``--switch``/``--mix-window``, or ``--drift-kind churn`` with
``--joins``/``--spans``) — as a versioned JSONL UVM fault log
(:func:`repro.uvm.trace.to_fault_log`) whose lines feed straight into
``serve``; real logs in the same schema ingest back through
:func:`repro.uvm.trace.from_fault_log`.  The action records ``serve`` emits
carry the live classifier verdict in their ``"pattern"`` field, so a
drifting replay shows the re-classification switch as it happens (tune it
with ``--reclass-interval``/``--reclass-hysteresis``).

``serve`` is fault-tolerant end to end: the degraded-mode health machine
is always on (action records carry ``"health"``/``"fallback"``; a trainer
failure degrades to rule-based actions instead of crashing),
``--checkpoint-dir``/``--checkpoint-every`` persist versioned snapshots at
round boundaries, ``--resume`` restores the latest one and replays only
the unconsumed input tail (bit-identical actions), SIGTERM/SIGINT drain
gracefully (close pending batches, flush a final snapshot + the stats
record), and ``--inject`` runs a seeded chaos schedule against the live
pipeline.  Note: ``--inject`` composed with ``--resume`` replays the
stream-transport faults deterministically but not the dispatch-fault
positions (the injector's RNG is not checkpointed).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.uvm.api import (
    ExperimentSpec,
    ModelSpec,
    PolicySpec,
    PrefetchSpec,
    RunStore,
    Session,
    WorkloadSpec,
)
from repro.uvm.api.specs import PAPER_TRAIN, TrainSpec, parse_scale
from repro.uvm.trace import PAGES_PER_BLOCK
from repro.uvm.zoo import workload_names


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--scale", default="quick",
                    help="'quick' (0.4x traces, <=6000 accesses), 'paper', or a float")
    ap.add_argument("--cap", type=int, default=None, help="max trace length (overrides the scale preset)")
    ap.add_argument("--runs-dir", default=None, help="run-store root (default experiments/runs)")
    ap.add_argument("--no-store", action="store_true", help="compute without reading/writing the run store")


def _session(args) -> Session:
    scale, cap = parse_scale(args.scale, args.cap)
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    if args.no_store:
        store.enabled = False
    model = ModelSpec(train=PAPER_TRAIN if args.scale == "paper" else TrainSpec())
    if args.scale == "paper":
        from repro.configs.predictor_paper import CONFIG

        model = dataclasses.replace(model, predictor=CONFIG)
    return Session(scale=scale, cap=cap, model=model, store=store)


def _strategy_model(session: Session, strategy: str, kind: str) -> ModelSpec | None:
    if strategy != "ours":
        return None
    return dataclasses.replace(session.model, kind=kind, pretrain=session.default_pretrain)


def _print_cell(cell, result) -> None:
    if cell.strategy == "sim":
        label = f"{cell.policy.name}+{cell.prefetch.name}"
    elif cell.strategy == "ours":
        label = f"ours[{cell.model.kind}]"
    else:
        label = "uvmsmart"
    stats = result.stats if hasattr(result, "stats") else result
    extra = f" top1={result.top1:.3f}" if hasattr(result, "top1") else ""
    print(f"{cell.workload.benchmark:>12} {label:>16} @{cell.oversubscription:<5} "
          f"thrash={stats['pages_thrashed']} faults={stats['faults']} "
          f"migrated={stats['migrated_blocks']}{extra}  key={cell.key}")


def cmd_run(args) -> int:
    session = _session(args)
    # build the cell through ExperimentSpec so it hashes IDENTICALLY to the
    # sweep path (non-sim strategies canonicalise their policy/prefetch
    # fields there — a different spelling here would duplicate store entries)
    spec = ExperimentSpec(
        name="run",
        workloads=(session.workload(args.benchmark),),
        strategy=args.strategy,
        policies=(PolicySpec(args.policy),),
        prefetchers=(PrefetchSpec(args.prefetch),),
        oversubscriptions=(args.oversub,),
        model=_strategy_model(session, args.strategy, args.kind),
    )
    [cell] = spec.cells()
    result = session.run(cell)
    _print_cell(cell, result)
    _report_counts("run", session, 1)
    return 0


def _sweep_spec(args, session: Session) -> ExperimentSpec:
    if args.spec:
        return ExperimentSpec.from_json(Path(args.spec).read_text())
    workloads = tuple(session.workload(b) for b in (args.benchmarks or session.benches))
    return ExperimentSpec(
        name=args.name,
        workloads=workloads,
        strategy=args.strategy,
        policies=tuple(PolicySpec(p) for p in args.policies),
        prefetchers=tuple(PrefetchSpec(p) for p in args.prefetchers),
        oversubscriptions=tuple(args.oversubs),
        model=_strategy_model(session, args.strategy, args.kind),
    )


def _report_counts(verb: str, session: Session, n_cells: int) -> None:
    c = session.counters
    hits = c["memory_hits"] + c["store_hits"]
    print(f"# {verb} cells={n_cells} hits={hits} computed={c['computed']} store={session.store.root}")


def cmd_sweep(args) -> int:
    session = _session(args)
    spec = _sweep_spec(args, session)
    if args.dump_spec:
        Path(args.dump_spec).write_text(json.dumps(spec.to_dict(), indent=2, sort_keys=True) + "\n")
        print(f"# wrote {args.dump_spec} (replay with: python -m repro.uvm.cli sweep --spec {args.dump_spec})")
    cells = spec.cells()
    results = session.sweep(cells)
    for cell, result in zip(cells, results):
        _print_cell(cell, result)
    _report_counts("sweep", session, len(cells))
    return 0


def cmd_report(args) -> int:
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    rows = []
    for key, rec in store.records():
        spec, result = rec.get("spec", {}), rec.get("result", {})
        if rec.get("kind") == "CellSpec":
            w = spec["workload"]
            stats = result.get("stats", result)
            rows.append({
                "key": key, "kind": "cell", "benchmark": w["benchmark"],
                "strategy": spec["strategy"],
                "policy": spec["policy"]["name"], "prefetch": spec["prefetch"]["name"],
                "oversub": spec["oversubscription"], "scale": w["scale"],
                "pages_thrashed": stats.get("pages_thrashed"), "faults": stats.get("faults"),
                "top1": round(result["top1"], 3) if "top1" in result else "",
            })
        elif rec.get("kind") == "ProtocolSpec":
            rows.append({
                "key": key, "kind": "protocol", "benchmark": spec["workload"]["benchmark"],
                "strategy": spec["mode"], "policy": "", "prefetch": "",
                "oversub": "", "scale": spec["workload"]["scale"],
                "pages_thrashed": "", "faults": "",
                "top1": round(result["top1"], 3),
            })
    if args.benchmark:
        rows = [r for r in rows if r["benchmark"] == args.benchmark]
    if not rows:
        print(f"# empty run store at {store.root}")
        return 0
    cols = list(rows[0])
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols)
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {args.csv} ({len(rows)} rows)")
    widths = {c: max(len(str(c)), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    print(f"# {len(rows)} stored runs in {store.root}")
    return 0


class _ServeLineError(ValueError):
    """A malformed JSONL line — reported as a structured error line, never
    a traceback (a long-lived sidecar must survive garbage input)."""


def _decode_serve_line(line: str, default_tenant: str):
    """Validate one JSONL line into ``(kind, tenant, payload)`` where kind
    is ``'observe'`` or ``'feedback'``.  Raises :class:`_ServeLineError`
    with a one-line reason on anything malformed."""
    import numpy as np

    try:
        rec = json.loads(line)
    except json.JSONDecodeError as e:
        raise _ServeLineError(f"bad json: {e.msg}") from None
    if not isinstance(rec, dict):
        raise _ServeLineError(f"line must be a JSON object, got {type(rec).__name__}")
    tenant = rec.get("tenant", None)
    if tenant is not None and not isinstance(tenant, (str, int)):
        raise _ServeLineError(f"'tenant' must be a string or int, got {type(tenant).__name__}")
    tagged = tenant is not None
    tenant = default_tenant if tenant is None else tenant
    if ("pages" in rec) == ("feedback" in rec):
        raise _ServeLineError("line needs exactly one of 'pages' or 'feedback'")
    if "feedback" in rec:
        fb = rec["feedback"] or {}
        if not isinstance(fb, dict):
            raise _ServeLineError("'feedback' must be a JSON object")
        we = fb.get("was_evicted")
        if we is not None and (not isinstance(we, list) or any(not isinstance(x, bool) for x in we)):
            raise _ServeLineError("'was_evicted' must be a list of booleans")
        fc = fb.get("fault_count")
        if fc is not None and (isinstance(fc, bool) or not isinstance(fc, int) or fc < 0):
            raise _ServeLineError("'fault_count' must be a non-negative integer")
        return "feedback", (tenant, tagged), {"was_evicted": we, "fault_count": fc}
    pages = rec["pages"]
    if not isinstance(pages, list) or any(isinstance(p, bool) or not isinstance(p, int) or p < 0 for p in pages):
        raise _ServeLineError("'pages' must be a list of non-negative integers")
    sides = {}
    for ch in ("pc", "tb", "kernel"):
        v = rec.get(ch)
        if v is not None and (not isinstance(v, list) or len(v) != len(pages)
                              or any(isinstance(x, bool) or not isinstance(x, int) for x in v)):
            raise _ServeLineError(f"'{ch}' must be a list of ints aligned with 'pages'")
        sides[ch] = v
    return "observe", (tenant, tagged), {"pages": np.asarray(pages, np.int64), **sides}


def cmd_serve(args) -> int:
    import signal

    import numpy as np

    from repro.configs.predictor_paper import CONFIG_QUICK
    from repro.uvm.manager import FaultBatch, HealthConfig, ManagerConfig, Outcomes, TenantMux

    n_blocks = (args.n_pages + args.pages_per_block - 1) // args.pages_per_block
    capacity = args.capacity if args.capacity is not None else max(int(n_blocks / args.oversub), 1)
    cfg = ManagerConfig(
        predictor=CONFIG_QUICK,
        train=dataclasses.replace(TrainSpec(), group_size=args.group_size).to_train_config(),
        kind=args.kind, n_pages=args.n_pages, n_blocks=n_blocks, capacity=capacity,
        pages_per_block=args.pages_per_block,
        classifier=args.classifier, freq_table=args.freq_table,
        reclass_interval=args.reclass_interval, reclass_hysteresis=args.reclass_hysteresis,
        # the sidecar always runs the degraded-mode health machine: a live
        # stream must fail SOFT into rule-based actions, never crash
        health=HealthConfig(latency_budget_ms=args.latency_budget_ms),
    )
    # tenants are admitted on first contact (auto_create): every "tenant"-
    # tagged line gets its own classifier->predictor pipeline; untagged
    # lines share the --default-tenant one (the single-workload case)
    mux = TenantMux(cfg, shared_freq_table=args.shared_freq_table)
    injector = None
    if args.inject:
        from repro.uvm.manager import ChaosSchedule, FaultInjector

        # wrap BEFORE any tenant is admitted so lazily-created managers
        # inherit the chaos trainer (and restore() rebuilds through it)
        injector = FaultInjector(ChaosSchedule.parse(args.inject))
        mux.trainer = injector.wrap_trainer(mux.trainer)
    store = None
    if args.checkpoint_dir:
        from repro.uvm.manager import SnapshotStore

        store = SnapshotStore(args.checkpoint_dir)
        store.clean_tmp()  # sweep turds a killed writer left behind
    fh = sys.stdin if args.input == "-" else open(args.input)
    pending: dict = {}  # tenant -> pending batch length (None: closed)
    last_fault = 0
    last_tenant = args.default_tenant
    batches = 0
    errors = 0
    lineno = 0
    resume_lineno = 0
    if args.resume:
        if store is None:
            print("# serve --resume requires --checkpoint-dir", file=sys.stderr)
            return 2
        if store.latest_step() is not None:
            step, state, extra = store.restore()
            mux.restore(state)
            pending = {k: None for k in mux.managers}
            batches = extra.get("batches", step)
            errors = extra.get("errors", 0)
            last_fault = extra.get("last_fault", 0)
            last_tenant = extra.get("last_tenant", args.default_tenant)
            resume_lineno = extra.get("lineno", 0)
            print(f"# resumed batch={batches} lineno={resume_lineno} "
                  f"tenants={len(mux.managers)} from {store.dir}", flush=True)

    def close(tenant, outcomes):
        mux.feedback(outcomes, tenant=tenant)
        pending[tenant] = None

    def extra_record():
        return {"lineno": lineno, "batches": batches, "errors": errors,
                "last_fault": last_fault, "last_tenant": last_tenant}

    # SIGTERM/SIGINT: finish the current line, close pending batches, flush
    # a final snapshot + the stats record, exit 0 (a drain, not a crash)
    stop: dict = {}
    installed = {}
    for signame in ("SIGTERM", "SIGINT"):
        signum = getattr(signal, signame)
        try:
            installed[signum] = signal.signal(
                signum, lambda s, _frame: stop.__setitem__("signal", s)
            )
        except ValueError:  # not the main thread (embedded callers)
            pass
    checkpoint_due = False
    line_iter = injector.transform_lines(fh) if injector is not None else fh
    try:
        for line in line_iter:
            if stop:
                break
            # snapshots happen only at fully-closed round boundaries (every
            # tenant's pending batch fed back); a due checkpoint waits here
            # until the boundary comes around
            if checkpoint_due and all(v is None for v in pending.values()):
                store.save(batches, mux.state(), extra=extra_record())
                checkpoint_due = False
            lineno += 1
            if lineno <= resume_lineno:
                continue  # consumed before the snapshot we restored from
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                kind, (tenant, tagged), payload = _decode_serve_line(line, args.default_tenant)
                if kind == "feedback":
                    if not tagged:
                        tenant = last_tenant  # untagged: closes the previous batch
                    we = payload["was_evicted"]
                    if pending.get(tenant) is None and we is not None:
                        # an outcome report with nothing to apply it to is
                        # lost data -> error; a bare fault_count line merely
                        # seeds the clock (legacy input, accepted silently)
                        raise _ServeLineError(f"feedback for tenant {tenant!r} without a pending batch")
                    if we is not None and len(we) != pending[tenant]:
                        raise _ServeLineError(
                            f"'was_evicted' must have one entry per access of tenant "
                            f"{tenant!r}'s pending batch (expected {pending[tenant]}, got {len(we)})"
                        )
                    if payload["fault_count"] is not None:
                        last_fault = payload["fault_count"]
                    if pending.get(tenant) is not None:
                        close(tenant, Outcomes(
                            was_evicted=np.asarray(we, bool) if we is not None else None,
                            fault_count=last_fault,
                        ))
                    continue
                if pending.get(tenant) is not None:  # auto-close (no outcome report)
                    close(tenant, Outcomes(fault_count=last_fault))
                out = mux.observe(FaultBatch(
                    payload["pages"], payload["pc"], payload["tb"], payload["kernel"],
                    tenant=tenant,
                ))
                actions = out.per_tenant[tenant]
                pending[tenant] = len(payload["pages"])
                last_tenant = tenant
                batches += 1
                rec = {
                    "batch": batches,
                    "pattern": actions.pattern,
                    "n_samples": actions.n_samples,
                    "accuracy": actions.accuracy,
                    "warm": actions.warm,
                    "health": actions.health,
                    "fallback": actions.fallback,
                    "prefetch_blocks": np.asarray(actions.prefetch_blocks).tolist(),
                    "pre_evict_blocks": np.asarray(actions.pre_evict_blocks).tolist(),
                }
                if tagged:
                    rec["tenant"] = tenant
                print(json.dumps(rec), flush=True)
                if store is not None and args.checkpoint_every and batches % args.checkpoint_every == 0:
                    checkpoint_due = True
            except _ServeLineError as e:
                errors += 1
                print(json.dumps({"error": str(e), "line": lineno}), flush=True)
        for tenant, p in pending.items():
            if p is not None:
                close(tenant, Outcomes(fault_count=last_fault))
    finally:
        for signum, old in installed.items():
            signal.signal(signum, old)
        if fh is not sys.stdin:
            fh.close()
    if store is not None:
        store.save(batches, mux.state(), extra=extra_record())
    if injector is not None:
        fired = {k: injector.counts[k] for k in sorted(injector.counts)}
        print(f"# chaos schedule={json.dumps(injector.schedule.to_dict(), sort_keys=True)} "
              f"fired={json.dumps(fired)}", flush=True)
    if stop:
        print(f"# serve shutdown signal={stop['signal']} (state flushed)", flush=True)
    print(f"# serve batches={batches} predictions={mux.n_predictions} "
          f"patterns={mux.n_models} classes={mux.n_classes} top1={mux.top1:.3f} "
          f"tenants={len(mux.managers)} errors={errors} "
          f"health_faults={mux.n_health_faults} fallbacks={mux.n_fallbacks} "
          f"recoveries={mux.n_recoveries}")
    return 2 if errors and args.strict else 0


def _export_workload(args, session: Session) -> WorkloadSpec:
    if args.phases:
        return WorkloadSpec.drifting(
            tuple(args.phases), kind=args.drift_kind, scale=session.scale, cap=session.cap,
            segment=args.segment, switch=args.switch, mix_window=args.mix_window,
            joins=tuple(args.joins or ()), spans=tuple(args.spans or ()),
            slice_len=args.slice_len, seed=args.seed,
        )
    if not args.benchmark:
        raise SystemExit("export needs --benchmark or --phases")
    return session.workload(args.benchmark)


def cmd_export(args) -> int:
    from repro.uvm.trace import to_fault_log

    session = _session(args)
    w = _export_workload(args, session)
    tr = session.trace(w)
    out = sys.stdout if args.out == "-" else args.out
    lines = to_fault_log(tr, out, batch=args.batch)
    print(f"# export workload={w.benchmark} accesses={len(tr)} n_pages={tr.n_pages} "
          f"tenants={len(tr.tenant_names)} lines={lines} out={args.out}",
          file=sys.stderr if args.out == "-" else sys.stdout)
    return 0


SUBCOMMANDS = {"run": cmd_run, "sweep": cmd_sweep, "report": cmd_report,
               "serve": cmd_serve, "export": cmd_export}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.uvm.cli", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    p_run = sub.add_parser("run", help="execute (or look up) one experiment cell")
    _add_common(p_run)
    p_run.add_argument("--benchmark", required=True, choices=workload_names())
    p_run.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_run.add_argument("--policy", default="lru", help="registered eviction policy (sim)")
    p_run.add_argument("--prefetch", default="tree", help="registered prefetcher (sim)")
    p_run.add_argument("--oversub", type=float, default=1.25)
    p_run.add_argument("--kind", default="transformer", help="registered predictor kind (ours)")

    p_sweep = sub.add_parser("sweep", help="execute a cross-product of cells in batched lanes")
    _add_common(p_sweep)
    p_sweep.add_argument("--spec", default=None, help="ExperimentSpec JSON to replay (overrides the axes)")
    p_sweep.add_argument("--name", default="sweep")
    p_sweep.add_argument("--benchmarks", nargs="*", default=None, choices=workload_names())
    p_sweep.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_sweep.add_argument("--policies", nargs="*", default=["lru"])
    p_sweep.add_argument("--prefetchers", nargs="*", default=["tree"])
    p_sweep.add_argument("--oversubs", nargs="*", type=float, default=[1.25])
    p_sweep.add_argument("--kind", default="transformer")
    p_sweep.add_argument("--dump-spec", default=None, help="write the composed ExperimentSpec JSON here")

    p_rep = sub.add_parser("report", help="tabulate the persistent run store")
    p_rep.add_argument("--runs-dir", default=None)
    p_rep.add_argument("--benchmark", default=None)
    p_rep.add_argument("--csv", default=None, help="also write the table as CSV")

    p_srv = sub.add_parser("serve", help="drive the streaming manager over a JSONL fault stream")
    p_srv.add_argument("--input", default="-", help="JSONL fault-batch stream ('-' = stdin)")
    p_srv.add_argument("--n-pages", type=int, default=4096, help="working-set size in pages")
    p_srv.add_argument("--pages-per-block", type=int, default=PAGES_PER_BLOCK,
                       help="pages per management block (1 = manage pages directly)")
    p_srv.add_argument("--oversub", type=float, default=1.25,
                       help="oversubscription level (sets the prefetch-budget capacity)")
    p_srv.add_argument("--capacity", type=int, default=None,
                       help="device capacity in blocks (overrides --oversub)")
    p_srv.add_argument("--kind", default="transformer", help="registered predictor kind")
    p_srv.add_argument("--classifier", default="dfa", help="registered pattern classifier")
    p_srv.add_argument("--freq-table", default="setassoc", help="registered frequency-table engine")
    p_srv.add_argument("--group-size", type=int, default=512, help="fine-tune schedule group size")
    p_srv.add_argument("--default-tenant", default="default",
                       help="tenant id for JSONL lines without a per-line 'tenant' field "
                            "(tagged lines each get their own classifier->predictor pipeline)")
    p_srv.add_argument("--shared-freq-table", action="store_true",
                       help="tenants share ONE prediction-frequency table (default: isolated per tenant)")
    p_srv.add_argument("--reclass-interval", type=int, default=0,
                       help="re-run the pattern classifier every N faults (observed accesses "
                            "when no feedback reports a fault count; 0 = every batch)")
    p_srv.add_argument("--reclass-hysteresis", type=int, default=2,
                       help="consecutive agreeing windows before a pattern switch")
    p_srv.add_argument("--strict", action="store_true",
                       help="exit non-zero if any malformed line was reported")
    p_srv.add_argument("--checkpoint-dir", default=None,
                       help="snapshot directory (versioned, content-hashed manager state; "
                            "also written once on shutdown)")
    p_srv.add_argument("--checkpoint-every", type=int, default=0,
                       help="snapshot after every N observed batches, at the next fully "
                            "fed-back round boundary (0 = only the shutdown snapshot)")
    p_srv.add_argument("--resume", action="store_true",
                       help="restore the latest snapshot in --checkpoint-dir and skip the "
                            "input lines it already consumed (the resumed action tail is "
                            "bit-identical to an uninterrupted run)")
    p_srv.add_argument("--inject", default=None,
                       help="seeded chaos schedule, 'key=prob,...,seed=N' or '@plan.json' "
                            "(see repro.uvm.manager.chaos); exercises the health machine — "
                            "degraded rounds answer with rule-based fallback actions "
                            "(health/fallback fields on every action record)")
    p_srv.add_argument("--latency-budget-ms", type=float, default=0.0,
                       help="per-observe dispatch budget in ms; overruns demote the learned "
                            "path to degraded health (0 = no budget)")

    p_exp = sub.add_parser(
        "export",
        help="write a workload (benchmark or drifting zoo trace) as a versioned "
             "JSONL UVM fault log, ready to replay through `serve`",
    )
    _add_common(p_exp)
    p_exp.add_argument("--benchmark", default=None, choices=workload_names(),
                       help="a registered workload (the 11-benchmark suite + the zoo patterns)")
    p_exp.add_argument("--phases", nargs="*", default=None,
                       help="build a drifting zoo trace instead: two or more workload names, "
                            "spliced (--drift-kind phase) or merged as churning tenants "
                            "(--drift-kind churn)")
    p_exp.add_argument("--drift-kind", default="phase", choices=("phase", "churn"))
    p_exp.add_argument("--segment", type=int, default=1500,
                       help="accesses per phase segment (--drift-kind phase)")
    p_exp.add_argument("--switch", default="abrupt", choices=("abrupt", "gradual"),
                       help="phase-boundary style; 'gradual' blends --mix-window accesses")
    p_exp.add_argument("--mix-window", type=int, default=0,
                       help="accesses blended around each gradual phase boundary")
    p_exp.add_argument("--joins", nargs="*", type=int, default=None,
                       help="per-tenant admission offsets in merged accesses (churn; "
                            "default: auto-staggered)")
    p_exp.add_argument("--spans", nargs="*", type=int, default=None,
                       help="per-tenant access budgets (churn; 0 = the full trace)")
    p_exp.add_argument("--slice-len", type=int, default=256, help="scheduler-slice length (churn)")
    p_exp.add_argument("--seed", type=int, default=0, help="zoo generator seed")
    p_exp.add_argument("--batch", type=int, default=256, help="accesses per fault-log line")
    p_exp.add_argument("--out", default="-", help="output path ('-' = stdout)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return SUBCOMMANDS[args.command](args)


if __name__ == "__main__":
    raise SystemExit(main())
