"""Command-line front end for the declarative experiment API.

    PYTHONPATH=src python -m repro.uvm.cli run   --benchmark ATAX --policy lru --prefetch tree
    PYTHONPATH=src python -m repro.uvm.cli sweep --benchmarks ATAX BICG --policies lru hpe \
        --prefetchers demand tree --oversubs 1.25 1.5
    PYTHONPATH=src python -m repro.uvm.cli sweep --spec experiment.json
    PYTHONPATH=src python -m repro.uvm.cli report

Every executed cell is published to the content-addressed run store
(``experiments/runs/`` by default; ``--runs-dir`` relocates it), so a
repeated invocation is served entirely from disk — the final
``# sweep cells=N hits=H computed=C`` line says how much work actually ran
(CI asserts ``computed=0`` on the second pass). ``--dump-spec`` writes the
composed :class:`~repro.uvm.api.specs.ExperimentSpec` as JSON, the
declarative artifact ``sweep --spec`` replays.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from repro.uvm.api import (
    ExperimentSpec,
    ModelSpec,
    PolicySpec,
    PrefetchSpec,
    RunStore,
    Session,
    WorkloadSpec,
)
from repro.uvm.api.specs import PAPER_TRAIN, TrainSpec, parse_scale
from repro.uvm.trace import BENCHMARKS


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--scale", default="quick",
                    help="'quick' (0.4x traces, <=6000 accesses), 'paper', or a float")
    ap.add_argument("--cap", type=int, default=None, help="max trace length (overrides the scale preset)")
    ap.add_argument("--runs-dir", default=None, help="run-store root (default experiments/runs)")
    ap.add_argument("--no-store", action="store_true", help="compute without reading/writing the run store")


def _session(args) -> Session:
    scale, cap = parse_scale(args.scale, args.cap)
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    if args.no_store:
        store.enabled = False
    model = ModelSpec(train=PAPER_TRAIN if args.scale == "paper" else TrainSpec())
    if args.scale == "paper":
        from repro.configs.predictor_paper import CONFIG

        model = dataclasses.replace(model, predictor=CONFIG)
    return Session(scale=scale, cap=cap, model=model, store=store)


def _strategy_model(session: Session, strategy: str, kind: str) -> ModelSpec | None:
    if strategy != "ours":
        return None
    return dataclasses.replace(session.model, kind=kind, pretrain=session.default_pretrain)


def _print_cell(cell, result) -> None:
    if cell.strategy == "sim":
        label = f"{cell.policy.name}+{cell.prefetch.name}"
    elif cell.strategy == "ours":
        label = f"ours[{cell.model.kind}]"
    else:
        label = "uvmsmart"
    stats = result.stats if hasattr(result, "stats") else result
    extra = f" top1={result.top1:.3f}" if hasattr(result, "top1") else ""
    print(f"{cell.workload.benchmark:>12} {label:>16} @{cell.oversubscription:<5} "
          f"thrash={stats['pages_thrashed']} faults={stats['faults']} "
          f"migrated={stats['migrated_blocks']}{extra}  key={cell.key}")


def cmd_run(args) -> int:
    session = _session(args)
    # build the cell through ExperimentSpec so it hashes IDENTICALLY to the
    # sweep path (non-sim strategies canonicalise their policy/prefetch
    # fields there — a different spelling here would duplicate store entries)
    spec = ExperimentSpec(
        name="run",
        workloads=(session.workload(args.benchmark),),
        strategy=args.strategy,
        policies=(PolicySpec(args.policy),),
        prefetchers=(PrefetchSpec(args.prefetch),),
        oversubscriptions=(args.oversub,),
        model=_strategy_model(session, args.strategy, args.kind),
    )
    [cell] = spec.cells()
    result = session.run(cell)
    _print_cell(cell, result)
    _report_counts("run", session, 1)
    return 0


def _sweep_spec(args, session: Session) -> ExperimentSpec:
    if args.spec:
        return ExperimentSpec.from_json(Path(args.spec).read_text())
    workloads = tuple(session.workload(b) for b in (args.benchmarks or session.benches))
    return ExperimentSpec(
        name=args.name,
        workloads=workloads,
        strategy=args.strategy,
        policies=tuple(PolicySpec(p) for p in args.policies),
        prefetchers=tuple(PrefetchSpec(p) for p in args.prefetchers),
        oversubscriptions=tuple(args.oversubs),
        model=_strategy_model(session, args.strategy, args.kind),
    )


def _report_counts(verb: str, session: Session, n_cells: int) -> None:
    c = session.counters
    hits = c["memory_hits"] + c["store_hits"]
    print(f"# {verb} cells={n_cells} hits={hits} computed={c['computed']} store={session.store.root}")


def cmd_sweep(args) -> int:
    session = _session(args)
    spec = _sweep_spec(args, session)
    if args.dump_spec:
        Path(args.dump_spec).write_text(json.dumps(spec.to_dict(), indent=2, sort_keys=True) + "\n")
        print(f"# wrote {args.dump_spec} (replay with: python -m repro.uvm.cli sweep --spec {args.dump_spec})")
    cells = spec.cells()
    results = session.sweep(cells)
    for cell, result in zip(cells, results):
        _print_cell(cell, result)
    _report_counts("sweep", session, len(cells))
    return 0


def cmd_report(args) -> int:
    store = RunStore(args.runs_dir) if args.runs_dir else RunStore()
    rows = []
    for key, rec in store.records():
        spec, result = rec.get("spec", {}), rec.get("result", {})
        if rec.get("kind") == "CellSpec":
            w = spec["workload"]
            stats = result.get("stats", result)
            rows.append({
                "key": key, "kind": "cell", "benchmark": w["benchmark"],
                "strategy": spec["strategy"],
                "policy": spec["policy"]["name"], "prefetch": spec["prefetch"]["name"],
                "oversub": spec["oversubscription"], "scale": w["scale"],
                "pages_thrashed": stats.get("pages_thrashed"), "faults": stats.get("faults"),
                "top1": round(result["top1"], 3) if "top1" in result else "",
            })
        elif rec.get("kind") == "ProtocolSpec":
            rows.append({
                "key": key, "kind": "protocol", "benchmark": spec["workload"]["benchmark"],
                "strategy": spec["mode"], "policy": "", "prefetch": "",
                "oversub": "", "scale": spec["workload"]["scale"],
                "pages_thrashed": "", "faults": "",
                "top1": round(result["top1"], 3),
            })
    if args.benchmark:
        rows = [r for r in rows if r["benchmark"] == args.benchmark]
    if not rows:
        print(f"# empty run store at {store.root}")
        return 0
    cols = list(rows[0])
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols)
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {args.csv} ({len(rows)} rows)")
    widths = {c: max(len(str(c)), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    print(f"# {len(rows)} stored runs in {store.root}")
    return 0


SUBCOMMANDS = {"run": cmd_run, "sweep": cmd_sweep, "report": cmd_report}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.uvm.cli", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    p_run = sub.add_parser("run", help="execute (or look up) one experiment cell")
    _add_common(p_run)
    p_run.add_argument("--benchmark", required=True, choices=sorted(BENCHMARKS))
    p_run.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_run.add_argument("--policy", default="lru", help="registered eviction policy (sim)")
    p_run.add_argument("--prefetch", default="tree", help="registered prefetcher (sim)")
    p_run.add_argument("--oversub", type=float, default=1.25)
    p_run.add_argument("--kind", default="transformer", help="registered predictor kind (ours)")

    p_sweep = sub.add_parser("sweep", help="execute a cross-product of cells in batched lanes")
    _add_common(p_sweep)
    p_sweep.add_argument("--spec", default=None, help="ExperimentSpec JSON to replay (overrides the axes)")
    p_sweep.add_argument("--name", default="sweep")
    p_sweep.add_argument("--benchmarks", nargs="*", default=None, choices=sorted(BENCHMARKS))
    p_sweep.add_argument("--strategy", default="sim", choices=("sim", "ours", "uvmsmart"))
    p_sweep.add_argument("--policies", nargs="*", default=["lru"])
    p_sweep.add_argument("--prefetchers", nargs="*", default=["tree"])
    p_sweep.add_argument("--oversubs", nargs="*", type=float, default=[1.25])
    p_sweep.add_argument("--kind", default="transformer")
    p_sweep.add_argument("--dump-spec", default=None, help="write the composed ExperimentSpec JSON here")

    p_rep = sub.add_parser("report", help="tabulate the persistent run store")
    p_rep.add_argument("--runs-dir", default=None)
    p_rep.add_argument("--benchmark", default=None)
    p_rep.add_argument("--csv", default=None, help="also write the table as CSV")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return SUBCOMMANDS[args.command](args)


if __name__ == "__main__":
    raise SystemExit(main())
