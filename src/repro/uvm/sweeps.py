"""Shared sweep definitions + the forced-multi-device subprocess harness.

One home for the policy x prefetch x oversubscription equivalence matrix
(previously copied into the golden suite, the sharded test and the perf
gate) and for the "rerun this sweep in a subprocess with N forced host XLA
devices" check both CI entry points use — XLA fixes its device count at
process start, so exercising the lane-sharded path from a single-device
process requires a child process.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

EQUIV_CELLS = [
    (pol, pf, os_)
    for pol in ("lru", "belady", "hpe", "learned")
    for pf in ("demand", "tree")
    for os_ in (1.25, 1.5)
]  # 16 cells: the equivalence-suite matrix (`random` exempt by contract)

_REPO = Path(__file__).resolve().parents[3]


def run_batch_forced_devices(bench: str, scale: float, cap: int, cells=EQUIV_CELLS, devices: int = 4,
                             kernels: bool = False) -> list[dict]:
    """`simulator.run_batch` over a named benchmark trace in a subprocess
    with ``devices`` forced host devices; returns its per-cell stats.

    The child asserts the device count AND that the lane mesh engaged, so a
    silently-unsharded run cannot masquerade as a passing check.  Counters
    are integer state, so callers may require bit-equality with their own
    single-device run.  ``kernels=True`` additionally pins the child onto
    the Pallas victim-selection path (REPRO_SIM_KERNELS=1, asserted in the
    child) — the sharded + kernel composition gate.
    """
    code = (
        "import json\n"
        "import jax\n"
        f"assert len(jax.devices()) == {devices}, jax.devices()\n"
        "from repro.distributed.compat import lanes_mesh\n"
        f"assert lanes_mesh({len(cells)}) is not None  # the sweep really is sharded\n"
        "from repro.uvm import simulator as S, trace as T\n"
        + (f"assert S.sim_kernels_enabled()  # the sweep really is kernelized\n" if kernels else "")
        + f"tr = T.get_trace({bench!r}, scale={scale}); tr = tr.slice(0, min(len(tr), {cap}))\n"
        f"print(json.dumps(S.run_batch(tr, {cells!r})))\n"
    )
    env = dict(
        os.environ,
        PYTHONPATH=str(_REPO / "src"),
        JAX_PLATFORMS="cpu",
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices} " + os.environ.get("XLA_FLAGS", ""),
    )
    if kernels:
        env["REPRO_SIM_KERNELS"] = "1"
    out = subprocess.run([sys.executable, "-c", code], env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])
