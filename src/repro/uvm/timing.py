"""IPC timing proxy (replaces GPGPU-Sim's cycle model; constants = Table V).

    GPU core clock        1481 MHz
    far-fault latency     45 us          (batched: concurrent warps overlap)
    CPU-GPU interconnect  PCIe 3.0 16x -> 16 GB/s
    zero-copy access      200 core cycles
    DRAM access           100 core cycles
    prediction overhead   1..100 us per prediction (Fig. 13 sweep)

IPC is reported normalised (paper Figs. 13/14), so the instructions-per-
access constant cancels.
"""
from __future__ import annotations

CORE_MHZ = 1481.0
FAR_FAULT_US = 45.0
PCIE_BYTES_PER_S = 16e9
ZERO_COPY_CYCLES = 200
DRAM_CYCLES = 100
BLOCK_BYTES = 64 * 1024
INSTR_PER_ACCESS = 20.0
FAULT_OVERLAP = 16.0  # concurrent far-faults amortised across warps


def cycles(stats: dict, n_accesses: int, *, pred_overhead_us: float = 0.0, n_predictions: int = 0) -> float:
    base = n_accesses * INSTR_PER_ACCESS  # pipeline
    base += n_accesses * 0.1 * DRAM_CYCLES  # L2-miss fraction
    c = base
    c += stats["faults"] * FAR_FAULT_US * CORE_MHZ / FAULT_OVERLAP
    # PCIe transfers OVERLAP kernel execution (cudaMemPrefetchAsync — the
    # paper's premise for why prefetching beats demand load despite moving
    # more bytes); only transfer time exceeding the compute window stalls.
    mig = stats["migrated_blocks"] * BLOCK_BYTES / PCIE_BYTES_PER_S * CORE_MHZ * 1e6
    c += max(mig - base, 0.0)
    c += stats["zero_copy"] * ZERO_COPY_CYCLES
    c += n_predictions * pred_overhead_us * CORE_MHZ
    return float(c)


def ipc(stats: dict, n_accesses: int, **kw) -> float:
    return n_accesses * INSTR_PER_ACCESS / cycles(stats, n_accesses, **kw)


def normalized_ipc(stats: dict, ref_stats: dict, n_accesses: int, **kw) -> float:
    """IPC relative to a reference strategy on the same trace."""
    return ipc(stats, n_accesses, **kw) / ipc(ref_stats, n_accesses)
