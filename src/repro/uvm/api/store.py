"""Content-addressed persistent run store under ``experiments/runs/``.

Every executed cell/protocol result is published as one JSON file at
``<root>/<key[:2]>/<key>.json`` where ``key`` is the spec's content hash —
so a result is found by ANY process that builds an equal spec, and a spec
change (workload, policy, model config, schema …) can never alias a stale
result.  Records are self-describing::

    {"schema": 1, "kind": "CellSpec", "key": "…", "spec": {...}, "result": {...}}

Writes are atomic (tmp + ``os.replace``) and failures are soft: an
unwritable checkout just runs without the memo, a torn/corrupt file reads
as a miss.  ``REPRO_RUN_STORE=0`` disables the store entirely.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path

from repro.uvm.api.specs import SCHEMA

DEFAULT_ROOT = Path("experiments") / "runs"


class RunStore:
    def __init__(self, root: str | Path = DEFAULT_ROOT, *, enabled: bool | None = None):
        self.root = Path(root)
        if enabled is None:
            enabled = os.environ.get("REPRO_RUN_STORE", "1") != "0"
        self.enabled = enabled
        self.hits = 0
        self.misses = 0
        self.writes = 0
        self._lock = threading.Lock()  # Session drives gets/puts from a thread pool

    def path(self, key: str) -> Path:
        return self.root / key[:2] / f"{key}.json"

    def get(self, spec) -> dict | None:
        """The stored result payload for ``spec``, or None."""
        if not self.enabled:
            return None
        p = self.path(spec.key)
        try:
            rec = json.loads(p.read_text())
            if not isinstance(rec, dict) or rec.get("schema") != SCHEMA or rec.get("key") != spec.key:
                raise ValueError("stale, torn, or mismatched record")
            result = rec["result"]
        except (OSError, ValueError, KeyError):
            with self._lock:
                self.misses += 1
            return None
        with self._lock:
            self.hits += 1
        return result

    def put(self, spec, result: dict) -> Path | None:
        """Atomically publish ``result`` for ``spec``; returns the path
        (None when disabled or the directory is unwritable)."""
        if not self.enabled:
            return None
        p = self.path(spec.key)
        rec = {
            "schema": SCHEMA,
            "kind": type(spec).__name__,
            "key": spec.key,
            "spec": spec.to_dict(),
            "result": result,
        }
        try:
            p.parent.mkdir(parents=True, exist_ok=True)
            tmp = p.with_suffix(f".tmp.{os.getpid()}")
            tmp.write_text(json.dumps(rec, sort_keys=True) + "\n")
            os.replace(tmp, p)
        except OSError:
            return None  # read-only checkouts still work, just without the memo
        with self._lock:
            self.writes += 1
        return p

    def records(self):
        """Iterate every (key, record) in the store (for `cli report`)."""
        if not self.root.exists():
            return
        for p in sorted(self.root.glob("*/*.json")):
            try:
                rec = json.loads(p.read_text())
            except (OSError, ValueError):
                continue
            if isinstance(rec, dict) and rec.get("schema") == SCHEMA:
                yield rec.get("key", p.stem), rec

    def __repr__(self) -> str:
        return f"RunStore({self.root}, hits={self.hits}, misses={self.misses}, writes={self.writes})"
