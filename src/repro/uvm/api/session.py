"""`Session`: the spec-driven experiment runner behind tables, figures,
tests and the CLI.

A Session executes :class:`~repro.uvm.api.specs.CellSpec` /
:class:`~repro.uvm.api.specs.ProtocolSpec` cells and serves every repeat
from two layers of cache:

* an in-process memo (one entry per spec content key), and
* the persistent content-addressed :class:`~repro.uvm.api.store.RunStore`
  under ``experiments/runs/`` — so a second process (or a CLI invocation
  after a benchmark run) never recomputes a cell it can look up.

Compatible cells are auto-grouped into the batched engines:

* ``sim`` cells on the same workload run as ONE vmapped
  :func:`repro.uvm.simulator.run_batch` sweep (policy/prefetch/capacity are
  traced lane parameters — any registered policy rides along);
* ``ours`` cells sharing a model run through the adaptive cross-benchmark
  engine (vmapped :func:`repro.uvm.runtime.run_ours_many` on multi-device,
  thread-pooled serial otherwise — REPRO_OURS_BATCHED forces);
* ``uvmsmart`` cells overlap on the host thread pool.

Counters are bit-identical to the single-cell entry points for every policy
except ``random`` (whose PRNG draws depend on lane padding — documented
contract; its cells are therefore memoised in-process but never persisted).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from pathlib import Path

from repro.uvm.api.specs import (
    CellSpec,
    ExperimentSpec,
    ModelSpec,
    PolicySpec,
    PrefetchSpec,
    PretrainSpec,
    ProtocolSpec,
    TrainSpec,
    WorkloadSpec,
    PAPER_TRAIN,
    SCALE_PRESETS,
)
from repro.uvm.api.store import RunStore


def enable_compile_cache() -> None:
    """Persistent XLA compilation cache: the simulator's unified scan and
    the predictor's train/eval jits compile once per shape-bucket EVER, not
    once per process. Harmless if the dir is unwritable (JAX falls back
    silently)."""
    import jax

    cache_dir = os.environ.get("REPRO_JAX_CACHE", str(Path.home() / ".cache" / "repro_jax"))
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.3)
    except Exception:
        pass


enable_compile_cache()

from repro.configs.predictor_paper import CONFIG as PCFG_PAPER  # noqa: E402
from repro.core.incremental import RunResult, run_protocol  # noqa: E402
from repro.uvm import runtime as R  # noqa: E402
from repro.uvm import simulator as S  # noqa: E402
from repro.uvm import timing  # noqa: E402
from repro.uvm import trace as T  # noqa: E402
from repro.uvm import zoo  # noqa: E402
from repro.uvm.runtime import LearnedRunResult  # noqa: E402
from repro.uvm.uvmsmart import run_uvmsmart  # noqa: E402

ALL_BENCH = list(T.BENCHMARKS)
FEATURED = ["ATAX", "BICG", "Hotspot", "NW", "Srad-v2"]  # the paper's focus set

#: predictor kinds whose implementation lives in this repo (safe to persist)
_BUILTIN_PREDICTORS = frozenset({"transformer", "lstm", "cnn", "mlp"})


def _learned_to_payload(res: LearnedRunResult) -> dict:
    return dataclasses.asdict(res)


def _payload_to_learned(payload: dict) -> LearnedRunResult:
    return LearnedRunResult(**payload)


def _protocol_to_payload(res: RunResult) -> dict:
    # the summary the tables/figures consume; the per-sample arrays are
    # derived data too bulky to persist per cell
    return {
        "top1": res.top1, "per_group": list(res.per_group),
        "n_classes": res.n_classes, "n_models": res.n_models, "n_samples": res.n_samples,
    }


def _payload_to_protocol(payload: dict) -> RunResult:
    return RunResult(
        top1=payload["top1"], per_group=payload["per_group"],
        n_classes=payload["n_classes"], n_models=payload["n_models"],
        n_samples=payload["n_samples"], predictions=None, t_index=None, correct=None,
    )


class Session:
    """Spec-driven runner with a persistent run store (see module docs).

    ``Session()`` is quick scale; ``Session.paper()`` is the full generator
    sizes and the paper's predictor.  ``store=None`` uses the default
    ``experiments/runs/`` store; pass a :class:`RunStore` to relocate it or
    ``RunStore(enabled=False)`` / env ``REPRO_RUN_STORE=0`` to disable
    persistence.
    """

    # Every rule-based cell the tables/figures touch; computed together so one
    # vmapped scan per (benchmark, oversubscription) fills the whole cache row.
    STANDARD_CELLS = (
        ("lru", "tree"), ("lru", "demand"), ("hpe", "demand"),
        ("hpe", "tree"), ("belady", "demand"),
    )

    def __init__(
        self,
        scale: float = SCALE_PRESETS["quick"][0],
        cap: int = SCALE_PRESETS["quick"][1],
        model: ModelSpec | None = None,
        benches: list | None = None,
        store: RunStore | None = None,
    ):
        self.scale = scale
        self.cap = cap
        self.model = model if model is not None else ModelSpec()
        self.benches = list(benches) if benches is not None else list(ALL_BENCH)
        self.store = store if store is not None else RunStore()
        self._tcfg = self.model.train.to_train_config()
        self._traces: dict = {}
        self._results: dict = {}  # spec key -> result object (in-process memo)
        self._pretrained: dict = {}  # (recipe, model-config) key -> ModelTable master
        self.counters = {"memory_hits": 0, "store_hits": 0, "computed": 0}
        # _lookup/_record run inside _warm_many's thread pool; the counters'
        # read-modify-write (and the memo insert) must not lose updates —
        # ci greps exact `computed=N` lines
        self._cache_lock = threading.Lock()

    @classmethod
    def paper(cls, **kw) -> "Session":
        kw.setdefault("scale", SCALE_PRESETS["paper"][0])
        kw.setdefault("cap", SCALE_PRESETS["paper"][1])
        kw.setdefault("model", ModelSpec(predictor=PCFG_PAPER, train=PAPER_TRAIN))
        return cls(**kw)

    # -- config views (what the retired benchmark context exposed) ----------

    @property
    def pcfg(self):
        return self.model.predictor

    @property
    def tcfg(self):
        return self._tcfg

    @property
    def default_pretrain(self) -> PretrainSpec:
        """The benchmark suite's Section V-A recipe at this session's scale."""
        return PretrainSpec(scale=self.scale * 0.6)

    # -- workloads ----------------------------------------------------------

    def workload(self, name: str) -> WorkloadSpec:
        return WorkloadSpec(name, self.scale, self.cap)

    def concurrent(self, tenants, *, slice_len: int = 256, seed: int = 0) -> WorkloadSpec:
        """A Section V-F multi-tenant workload of this session's scale."""
        return WorkloadSpec.concurrent(tenants, scale=self.scale, cap=self.cap, slice_len=slice_len, seed=seed)

    def drifting(self, phases, **kw) -> WorkloadSpec:
        """A drifting zoo workload (phase change or tenant churn) of this
        session's scale — see :meth:`WorkloadSpec.drifting` for the knobs."""
        kw.setdefault("scale", self.scale)
        kw.setdefault("cap", self.cap)
        return WorkloadSpec.drifting(phases, **kw)

    def _workload(self, w) -> WorkloadSpec:
        return self.workload(w) if isinstance(w, str) else w

    def trace(self, w: WorkloadSpec | str) -> T.Trace:
        w = self._workload(w)
        if w.key not in self._traces:
            if w.drift is not None:
                d = w.drift
                if d.kind == "churn":
                    tr = zoo.tenant_churn(d.phases, scale=w.scale, seed=d.seed,
                                          joins=d.joins, spans=d.spans, slice_len=w.slice_len)
                else:
                    tr = zoo.phase_trace(d.phases, scale=w.scale, seed=d.seed,
                                         segment=d.segment, switch=d.switch,
                                         mix_window=d.mix_window)
                self._traces[w.key] = tr.slice(0, min(len(tr), w.cap))
            elif w.tenants:
                parts = [self.trace(WorkloadSpec(t, w.scale, w.cap)) for t in w.tenants]
                self._traces[w.key] = T.concurrent(parts, seed=w.seed, slice_len=w.slice_len)
            else:
                tr = zoo.get_trace(w.benchmark, scale=w.scale)
                self._traces[w.key] = tr.slice(0, min(len(tr), w.cap))
        return self._traces[w.key]

    def ipc(self, w: WorkloadSpec | str, stats: dict, **kw) -> float:
        return timing.ipc(stats, len(self.trace(w)), **kw)

    # -- cache plumbing ------------------------------------------------------

    def _lookup(self, spec, from_payload):
        """Memory first, then the persistent store (reconstructing the
        result object); None on a full miss."""
        key = spec.key
        with self._cache_lock:
            if key in self._results:
                self.counters["memory_hits"] += 1
                return self._results[key]
        payload = self.store.get(spec)
        if payload is not None:
            res = from_payload(payload)
            with self._cache_lock:
                self._results[key] = res
                self.counters["store_hits"] += 1
            return res
        return None

    def _record(self, spec, result, to_payload, *, persist: bool = True):
        with self._cache_lock:
            self._results[spec.key] = result
            self.counters["computed"] += 1
        if persist:
            self.store.put(spec, to_payload(result))
        return result

    # -- spec execution ------------------------------------------------------

    def run(self, cell: CellSpec):
        """Execute (or look up) one cell; returns its stats dict
        (sim/uvmsmart) or :class:`LearnedRunResult` (ours)."""
        return self.sweep([cell])[0]

    def sweep(self, cells) -> list:
        """Execute a list of cells (or an :class:`ExperimentSpec`), serving
        repeats from the store and auto-grouping the misses into the batched
        engines. Results align with the input order."""
        if isinstance(cells, ExperimentSpec):
            cells = cells.cells()
        cells = list(cells)
        results: dict[int, object] = {}
        missing: list[tuple[int, CellSpec]] = []
        for i, cell in enumerate(cells):
            hit = self._lookup(cell, self._payload_decoder(cell))
            if hit is not None:
                results[i] = hit
            else:
                missing.append((i, cell))

        sim_by_workload: dict[str, list[tuple[int, CellSpec]]] = {}
        ours_by_model: dict[str, list[tuple[int, CellSpec]]] = {}
        smart: list[tuple[int, CellSpec]] = []
        for i, cell in missing:
            if cell.strategy == "sim":
                sim_by_workload.setdefault(cell.workload.key, []).append((i, cell))
            elif cell.strategy == "ours":
                ours_by_model.setdefault(f"{cell.model.key}|{cell.oversubscription}|{cell.seed}", []).append((i, cell))
            else:
                smart.append((i, cell))

        for group in sim_by_workload.values():
            results.update(self._run_sim_group(group))
        for group in ours_by_model.values():
            results.update(self._run_ours_group(group))
        results.update(self._run_uvmsmart_group(smart))
        return [results[i] for i in range(len(cells))]

    def _payload_decoder(self, cell: CellSpec):
        if cell.strategy != "ours":
            return lambda p: p

        def decode(payload: dict) -> LearnedRunResult:
            res = _payload_to_learned(payload)
            if not res.n_accesses:  # record stored before the field existed
                res.n_accesses = len(self.trace(cell.workload))
            return res

        return decode

    def _run_sim_group(self, group) -> dict[int, dict]:
        """All sim cells of one workload in ONE vmapped run_batch sweep."""
        _, first = group[0]
        tr = self.trace(first.workload)
        tuples = [(c.policy.name, c.prefetch.name, c.oversubscription) for _, c in group]
        stats = S.run_batch(tr, tuples, seeds=[c.seed for _, c in group])
        out = {}
        for (i, cell), st in zip(group, stats):
            out[i] = self._record(cell, st, lambda p: p, persist=self._persistable(cell))
        return out

    @staticmethod
    def _persistable(cell: CellSpec) -> bool:
        """Whether a cell's result may enter the PERSISTENT store.

        Two exemptions (memoised in-process only):
        * ``random`` — counters depend on lane padding (documented contract);
        * plugin strategies — a spec hashes a registered policy/prefetcher/
          predictor by NAME only, so a changed implementation under the same
          name would silently be served the old result across processes.
          Builtins are pinned by the golden suite; plugins are not.
        """
        if cell.strategy == "uvmsmart":
            return True
        if cell.strategy == "ours":
            return cell.model.kind in _BUILTIN_PREDICTORS
        return (
            cell.policy.name != "random"
            and cell.policy.name in S.POLICIES
            and cell.prefetch.name in S.PREFETCHERS
        )

    def _run_ours_group(self, group) -> dict[int, LearnedRunResult]:
        """Learned cells sharing one ModelSpec: the adaptive engine of the
        benchmark suite (vmapped lockstep on multi-device, thread-pooled
        serial on one device; REPRO_OURS_BATCHED forces)."""
        if not group:
            return {}
        import jax

        _, first = group[0]
        model, oversub = first.model, first.oversubscription
        kw = dict(
            kind=model.kind,
            use_thrash_term=model.use_thrash_term,
            use_lucir=model.use_lucir,
            seed=first.seed,  # cells group by (model, oversub, seed)
            # tenancy only matters on tenant-tagged (concurrent) workloads:
            # 'merged' forces the single-manager baseline, otherwise the
            # drivers auto-route tagged traces through the TenantMux
            multi_tenant=False if model.tenancy == "merged" else None,
            shared_freq_table=model.tenancy == "mux-shared",
            reclass_interval=model.reclass_interval,
            reclass_hysteresis=model.reclass_hysteresis,
            health=model.health_config(),
            qos=model.qos,
        )
        tcfg = model.train.to_train_config()

        def table():
            if model.pretrain is None:
                return None
            # the table must be pretrained with the CELL's model configs
            # (which may differ from this session's defaults)
            return self.pretrained(
                model.pretrain, pcfg=model.predictor, train=model.train, kind=model.kind
            )

        def run_one(item):
            i, cell = item
            res = R.run_ours(
                self.trace(cell.workload), model.predictor, tcfg,
                oversubscription=oversub, table=table(), **kw,
            )
            return i, self._record(cell, res, _learned_to_payload, persist=self._persistable(cell))

        knob = os.environ.get("REPRO_OURS_BATCHED", "")
        batched = len(group) > 1 and knob != "0" and (knob == "1" or len(jax.devices()) > 1)
        if not batched:
            if model.pretrain is not None:
                table()  # build (or load) the shared table once, serially
            return dict(self._warm_many(run_one, group))
        results = R.run_ours_many(
            [self.trace(c.workload) for _, c in group], model.predictor, tcfg,
            oversubscription=oversub,
            tables=[table() for _ in group] if model.pretrain is not None else None, **kw,
        )
        return {
            i: self._record(cell, res, _learned_to_payload, persist=self._persistable(cell))
            for (i, cell), res in zip(group, results)
        }

    def _run_uvmsmart_group(self, group) -> dict[int, dict]:
        def run_one(item):
            i, cell = item
            st = run_uvmsmart(self.trace(cell.workload), oversubscription=cell.oversubscription, seed=cell.seed)
            return i, self._record(cell, st, lambda p: p)

        return dict(self._warm_many(run_one, group))

    @staticmethod
    def _warm_many(run_one, todo: list) -> list:
        """Run one item serially (so the pool hits warm compiles), then the
        rest through a small thread pool. Each item is a self-contained
        computation, so results are identical to the serial path regardless
        of scheduling; JAX releases the GIL during compiled execution and
        the slight oversubscription hides host<->device sync stalls."""
        from concurrent.futures import ThreadPoolExecutor

        results = []
        if todo:
            results.append(run_one(todo[0]))
        if len(todo) <= 1:
            return results
        with ThreadPoolExecutor(max_workers=min(4, 2 * (os.cpu_count() or 1))) as pool:
            results.extend(pool.map(run_one, todo[1:]))
        return results

    # -- named conveniences (the shapes the tables/figures consume) ---------

    def _sim_cell(self, w, policy: str, prefetch: str, oversub: float) -> CellSpec:
        return CellSpec(self._workload(w), "sim", PolicySpec(policy), PrefetchSpec(prefetch), oversub)

    def sims(self, w, cells: list) -> list[dict]:
        """Batched sweep: (policy, prefetch, oversub) tuples over one
        workload in ONE vmapped scan (bit-identical to per-cell S.run for
        non-random policies)."""
        return self.sweep([self._sim_cell(w, p, f, os_) for p, f, os_ in cells])

    def sim(self, w, policy: str, prefetch: str, oversub: float = 1.25) -> dict:
        """One rule-based cell; a miss warms the whole STANDARD_CELLS row
        for (workload, oversub) in one sweep, like the row-oriented tables
        consume it."""
        cell = self._sim_cell(w, policy, prefetch, oversub)
        hit = self._lookup(cell, self._payload_decoder(cell))
        if hit is not None:
            return hit
        todo = [(p, f, oversub) for p, f in self.STANDARD_CELLS]
        if (policy, prefetch, oversub) not in todo:
            todo.append((policy, prefetch, oversub))
        row = self.sims(w, todo)
        return row[todo.index((policy, prefetch, oversub))]

    def _ours_model(self, **kw) -> ModelSpec:
        unknown = set(kw) - {"kind", "use_thrash_term", "use_lucir",
                             "tenancy", "reclass_interval", "reclass_hysteresis",
                             "health", "latency_budget_ms", "qos"}
        if unknown:
            raise TypeError(f"unknown learned-run options: {sorted(unknown)}")
        return dataclasses.replace(self.model, pretrain=self.default_pretrain, **kw)

    def ours_cell(self, w, oversub: float = 1.25, seed: int = 0, **kw) -> CellSpec:
        return CellSpec(
            self._workload(w), "ours", PolicySpec("learned"), PrefetchSpec("none"),
            oversub, self._ours_model(**kw), seed,
        )

    def ours(self, w, oversub: float = 1.25, seed: int = 0, **kw) -> LearnedRunResult:
        """The paper's full learned runtime on one workload (Section IV).
        ``seed`` seeds the simulator state (like sim cells); model/training
        seeds live in the ModelSpec's TrainSpec.  Internally every ``ours``
        cell drives a streaming
        :class:`~repro.uvm.manager.OversubscriptionManager` through the
        simulator (``runtime.run_ours`` is that driver); :meth:`manager`
        hands you the same object for any other fault source."""
        return self.run(self.ours_cell(w, oversub, seed, **kw))

    def manager(self, w, oversub: float = 1.25, *, pretrained: bool = False, **kw):
        """A streaming :class:`~repro.uvm.manager.OversubscriptionManager`
        configured for workload ``w`` at this session's model/scale — the
        exact object an ``ours`` cell drives.  ``pretrained=True`` starts
        it from this session's Section V-A table (a fresh clone).  Feed it
        any fault source: the simulator, the serving KV-offload adapter
        (:class:`repro.serving.offload.LearnedOffloadManager`), or the
        ``cli serve`` JSONL stream.

        A tenant list (``manager(["ATAX", "BICG"])``) or a concurrent
        :class:`WorkloadSpec` returns the multi-tenant
        :class:`~repro.uvm.manager.TenantMux` instead (one pipeline per
        tenant; ``tenancy='mux-shared'`` shares the frequency table,
        ``tenancy='merged'`` falls back to one merged-stream manager)."""
        if isinstance(w, (list, tuple)):
            w = self.concurrent(tuple(w))
        model = self._ours_model(**kw)
        table = (
            self.pretrained(model.pretrain, pcfg=model.predictor, train=model.train, kind=model.kind)
            if pretrained else None
        )
        common = dict(
            oversubscription=oversub, kind=model.kind, table=table,
            use_thrash_term=model.use_thrash_term, use_lucir=model.use_lucir,
            reclass_interval=model.reclass_interval,
            reclass_hysteresis=model.reclass_hysteresis,
            health=model.health_config(),
        )
        tr = self.trace(w)
        if tr.tenant is not None and model.tenancy != "merged":
            return R.mux_for(
                tr, model.predictor, model.train.to_train_config(),
                shared_freq_table=model.tenancy == "mux-shared",
                qos=model.qos, **common,
            )
        return R.manager_for(tr, model.predictor, model.train.to_train_config(), **common)

    def ours_many(self, names: list, oversub: float = 1.25, **kw) -> list[LearnedRunResult]:
        """Warm the learned-run cache for many benchmarks in one grouped
        sweep (the engines overlap/batch across lanes)."""
        return self.sweep([self.ours_cell(n, oversub, **kw) for n in names])

    def _uvmsmart_cell(self, w, oversub: float) -> CellSpec:
        return CellSpec(self._workload(w), "uvmsmart", PolicySpec("adaptive"), PrefetchSpec("adaptive"), oversub)

    def uvmsmart(self, w, oversub: float = 1.25) -> dict:
        return self.run(self._uvmsmart_cell(w, oversub))

    def uvmsmart_many(self, names: list, oversub: float = 1.25) -> list[dict]:
        return self.sweep([self._uvmsmart_cell(n, oversub) for n in names])

    # -- pretraining + protocols --------------------------------------------

    def pretrained(self, pspec: PretrainSpec | None = None, *,
                   pcfg=None, train: TrainSpec | None = None, kind: str = "transformer"):
        """Section V-A pretrained per-pattern table for ``pspec`` (default:
        this session's recipe); built/loaded once per (recipe, predictor,
        training, kind) and CLONED per use (fine-tuning mutates the
        entries).

        ``pcfg``/``train``/``kind`` default to this session's model, but
        cells carry their own :class:`ModelSpec` — the table must be
        pretrained with the configs AND architecture of the model that will
        fine-tune it (transformer weights fed to an lstm trainer crash)."""
        pspec = pspec or self.default_pretrain
        pcfg = pcfg if pcfg is not None else self.pcfg
        train = train if train is not None else self.model.train
        memo_key = (pspec.key, pcfg, train, kind)
        if memo_key not in self._pretrained:
            corpus = [
                T.BENCHMARKS[n](scale=pspec.scale, seed=pspec.seed0 + i)
                for i, n in enumerate(pspec.benchmarks)
            ]
            self._pretrained[memo_key] = R.pretrain_table(
                corpus, pcfg, train.to_train_config(), kind=kind, max_rounds=pspec.max_rounds
            )
        return self._pretrained[memo_key].clone()

    def protocol(self, w, mode: str, kind: str = "transformer",
                 pretrain: PretrainSpec | None = None) -> RunResult:
        """One prediction-accuracy protocol run (strictly-causal top-1).
        ``pretrain`` (with ``mode='ours'``) starts from a fresh clone of
        that recipe's table — the paper's pretrain-then-finetune protocol."""
        return self.protocol_chain([w], mode, kind=kind, pretrain=pretrain)[0]

    def protocol_chain(self, workloads: list, mode: str, *, kind: str = "transformer",
                       pretrain: PretrainSpec | None = None) -> list[RunResult]:
        """Protocol runs that SHARE one pretrained table, fine-tuned link by
        link (fig11's shape): link i's result depends on links < i, so each
        link's spec carries the chain prefix in ``prior`` and the chain is
        served from the store only when every link hits."""
        model = dataclasses.replace(self.model, kind=kind, pretrain=pretrain)
        specs, prior = [], ()
        for w in workloads:
            w = self._workload(w)
            specs.append(ProtocolSpec(w, mode, model, prior))
            if pretrain is not None:
                prior = prior + (w.key,)
        hits = [self._lookup(s, _payload_to_protocol) for s in specs]
        if all(h is not None for h in hits):
            return hits
        table = (
            self.pretrained(pretrain, pcfg=model.predictor, train=model.train, kind=kind)
            if pretrain is not None else None
        )
        tcfg = model.train.to_train_config()
        out = []
        for spec in specs:
            res = run_protocol(
                self.trace(spec.workload), model.predictor, tcfg,
                mode=mode, kind=kind, table=table,
            )
            out.append(self._record(
                spec, res, _protocol_to_payload,
                persist=spec.model.kind in _BUILTIN_PREDICTORS,
            ))
        return out
