"""Frozen, JSON-serializable experiment specs with stable content-hash keys.

Every spec is an immutable dataclass whose canonical JSON (sorted keys,
no whitespace) is hashed into a 16-hex ``key`` — the content address the
run store files results under.  Two specs with equal fields have equal
keys in every process; any field change (including nested specs) changes
the key.  ``SCHEMA`` is folded into the hash so that a semantic change to
what a result MEANS can invalidate every stored run at once.

Round trip: ``spec.to_dict()`` / ``spec.to_json()`` and
``spec_from_dict(kind, d)`` / ``SpecClass.from_dict(d)``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterator

from repro.configs.predictor_paper import CONFIG_QUICK, PredictorConfig
from repro.core.incremental import TrainConfig

SCHEMA = 3  # bump to invalidate every stored run
# SCHEMA 3 (PR 9): the QoS subsystem — ModelSpec grew a `qos` block
# (per-tenant tiers + budget controller knobs) and budgeted muxes release
# departed tenants' counters; a concurrent `ours` result now depends on
# the capacity-partitioning regime it ran under, so results stored under
# SCHEMA 2 no longer mean the same thing.
# SCHEMA 2 (PR 5): concurrent `ours` cells route through the TenantMux
# (per-tenant pipelines) instead of one merged-stream manager, and
# ModelSpec grew tenancy/re-classification fields — results stored under
# SCHEMA 1 no longer mean the same thing.
# (PR 6 grew ModelSpec health/latency_budget_ms WITHOUT a schema bump:
# `ours` keys move — defaults are behavior-identical, old cells simply
# recompute — while rule-based cells keep their keys and stored results.)

#: corpus the paper's Section V-A pretraining draws from (5 benchmarks,
#: different inputs) — shared default of Session.pretrained / fig11 / table7
PRETRAIN_BENCHES = ("ATAX", "Backprop", "BICG", "Hotspot", "NW")


def spec_key(spec) -> str:
    """Stable 16-hex content hash of a spec (type name + schema + fields)."""
    payload = json.dumps(
        {"kind": type(spec).__name__, "schema": SCHEMA, "spec": dataclasses.asdict(spec)},
        sort_keys=True, separators=(",", ":"),
    )
    return hashlib.md5(payload.encode()).hexdigest()[:16]


class _SpecBase:
    """Mixin: content key + JSON round trip for frozen spec dataclasses."""

    @property
    def key(self) -> str:
        return spec_key(self)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, s: str):
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class DriftSpec(_SpecBase):
    """A drifting-workload recipe from the zoo (:mod:`repro.uvm.zoo`).

    ``kind='phase'`` splices ``phases`` (benchmark or zoo-pattern names)
    into one stream, ``segment`` accesses each; ``switch='gradual'`` blends
    ``mix_window`` accesses around every boundary (``'abrupt'`` cuts hard).
    ``kind='churn'`` merges ``phases`` as tenants that JOIN after
    ``joins[i]`` merged accesses and LEAVE after ``spans[i]`` of their own
    (0/absent = full trace; empty ``joins`` auto-staggers)."""

    kind: str = "phase"  # phase | churn
    phases: tuple[str, ...] = ()
    segment: int = 1500  # accesses per phase (kind='phase')
    switch: str = "abrupt"  # abrupt | gradual
    mix_window: int = 0  # blended accesses per boundary (switch='gradual')
    joins: tuple[int, ...] = ()  # per-tenant admission offsets (kind='churn')
    spans: tuple[int, ...] = ()  # per-tenant access budgets (kind='churn')
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("phase", "churn"):
            raise ValueError(f"unknown drift kind {self.kind!r}; 'phase' or 'churn'")
        if self.switch not in ("abrupt", "gradual"):
            raise ValueError(f"unknown drift switch {self.switch!r}; 'abrupt' or 'gradual'")
        if len(self.phases) < 2:
            raise ValueError("a drift spec needs at least two phases/tenants")

    @classmethod
    def from_dict(cls, d: dict) -> "DriftSpec":
        return cls(
            kind=d.get("kind", "phase"), phases=tuple(d.get("phases", ())),
            segment=d.get("segment", 1500), switch=d.get("switch", "abrupt"),
            mix_window=d.get("mix_window", 0), joins=tuple(d.get("joins", ())),
            spans=tuple(d.get("spans", ())), seed=d.get("seed", 0),
        )


@dataclasses.dataclass(frozen=True)
class WorkloadSpec(_SpecBase):
    """A trace to drive: one benchmark generator, a concurrent merge, or a
    drifting zoo workload.

    ``tenants`` non-empty makes this a Section V-F multi-workload trace:
    each tenant benchmark is generated at (scale, cap) and merged at
    scheduler-slice granularity into disjoint page ranges
    (:func:`repro.uvm.trace.concurrent` with ``slice_len``/``seed``).

    ``drift`` non-None builds the trace through the zoo instead
    (:func:`repro.uvm.zoo.phase_trace` / :func:`~repro.uvm.zoo.tenant_churn`
    at this spec's ``scale``, capped at ``cap``; churn merges reuse
    ``slice_len``); ``benchmark`` is then just the display label.
    (PR 7 grew this field WITHOUT a schema bump, like PR 6's ModelSpec
    growth: the default is behavior-identical, old cells simply recompute.)
    """

    benchmark: str
    scale: float = 0.4
    cap: int = 6000  # max trace length (quick mode)
    tenants: tuple[str, ...] = ()
    slice_len: int = 256
    seed: int = 0  # concurrent-merge seed (unused for single-tenant)
    drift: DriftSpec | None = None

    @classmethod
    def concurrent(cls, tenants, *, scale: float = 0.4, cap: int = 6000,
                   slice_len: int = 256, seed: int = 0) -> "WorkloadSpec":
        tenants = tuple(tenants)
        return cls("+".join(tenants), scale, cap, tenants, slice_len, seed)

    @classmethod
    def drifting(cls, phases, *, kind: str = "phase", scale: float = 0.4,
                 cap: int = 6000, segment: int = 1500, switch: str = "abrupt",
                 mix_window: int = 0, joins=(), spans=(), slice_len: int = 256,
                 seed: int = 0) -> "WorkloadSpec":
        """A zoo workload: ``kind='phase'`` splices ``phases`` with the given
        switch style; ``kind='churn'`` merges them as joining/leaving
        tenants."""
        phases = tuple(phases)
        sep = "+" if kind == "churn" else ">"
        label = ("churn:" if kind == "churn" else "drift:") + sep.join(phases)
        drift = DriftSpec(kind=kind, phases=phases, segment=segment, switch=switch,
                          mix_window=mix_window, joins=tuple(joins), spans=tuple(spans),
                          seed=seed)
        return cls(label, scale, cap, slice_len=slice_len, drift=drift)

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        return cls(
            benchmark=d["benchmark"], scale=d["scale"], cap=d["cap"],
            tenants=tuple(d.get("tenants", ())),
            slice_len=d.get("slice_len", 256), seed=d.get("seed", 0),
            drift=DriftSpec.from_dict(d["drift"]) if d.get("drift") else None,
        )


@dataclasses.dataclass(frozen=True)
class PolicySpec(_SpecBase):
    """An eviction policy by registered name (see registry.policy_names())."""

    name: str = "lru"

    @classmethod
    def from_dict(cls, d: dict) -> "PolicySpec":
        return cls(name=d["name"])


@dataclasses.dataclass(frozen=True)
class PrefetchSpec(_SpecBase):
    """A prefetcher by registered name (see registry.prefetcher_names())."""

    name: str = "tree"

    @classmethod
    def from_dict(cls, d: dict) -> "PrefetchSpec":
        return cls(name=d["name"])


@dataclasses.dataclass(frozen=True)
class TrainSpec(_SpecBase):
    """Frozen mirror of :class:`repro.core.incremental.TrainConfig`."""

    group_size: int = 1024
    epochs: int = 2
    batch_size: int = 128
    lr: float = 3e-3
    seed: int = 0
    table_slots: int = 8

    def to_train_config(self) -> TrainConfig:
        return TrainConfig(**dataclasses.asdict(self))

    @classmethod
    def from_train_config(cls, tcfg: TrainConfig) -> "TrainSpec":
        return cls(**dataclasses.asdict(tcfg))

    @classmethod
    def from_dict(cls, d: dict) -> "TrainSpec":
        return cls(**d)


#: the paper-scale training schedule (Session.paper()'s default)
PAPER_TRAIN = TrainSpec(group_size=2048, epochs=3, batch_size=256)

#: the shared (trace scale, cap) presets behind every `--scale quick|paper`
#: flag (CLI, sim_perf) and the Session defaults / Session.paper()
SCALE_PRESETS = {"quick": (0.4, 6000), "paper": (1.0, 60_000)}


def parse_scale(scale_arg: str, cap_arg: int | None = None) -> tuple[float, int]:
    """Resolve a `--scale` flag ('quick'/'paper'/float string) + optional
    `--cap` override to (scale, cap) — the one parser every CLI shares."""
    if scale_arg in SCALE_PRESETS:
        scale, cap = SCALE_PRESETS[scale_arg]
    else:
        scale, cap = float(scale_arg), SCALE_PRESETS["quick"][1]
    return scale, (cap_arg if cap_arg is not None else cap)


@dataclasses.dataclass(frozen=True)
class PretrainSpec(_SpecBase):
    """Section V-A offline pretraining recipe: a corpus of benchmark runs
    with different inputs (``seed0 + i``) feeding ``pretrain_table``."""

    benchmarks: tuple[str, ...] = PRETRAIN_BENCHES
    scale: float = 0.24
    seed0: int = 777
    max_rounds: int = 2

    @classmethod
    def from_dict(cls, d: dict) -> "PretrainSpec":
        return cls(
            benchmarks=tuple(d.get("benchmarks", PRETRAIN_BENCHES)),
            scale=d["scale"], seed0=d["seed0"], max_rounds=d["max_rounds"],
        )


@dataclasses.dataclass(frozen=True)
class QosTierSpec(_SpecBase):
    """One tenant's QoS contract in a spec: ``tenant`` names the workload
    (a :func:`repro.uvm.trace.concurrent` part name, or a serve-session
    tenant id), ``floor`` its guaranteed fraction of device capacity,
    ``share`` its weight over the elastic pool the floors leave over."""

    tenant: str
    floor: float = 0.0
    share: float = 1.0

    @classmethod
    def from_dict(cls, d: dict) -> "QosTierSpec":
        return cls(tenant=d["tenant"], floor=d.get("floor", 0.0),
                   share=d.get("share", 1.0))


@dataclasses.dataclass(frozen=True)
class QosSpec(_SpecBase):
    """The per-tenant capacity-partitioning block of a learned run: QoS
    tiers plus the :class:`~repro.uvm.qos.BudgetController` knobs.
    ``stability`` names a registered stability scorer (``percentile`` /
    ``gmr``), ``interval`` how many feedback rounds pass between budget
    recomputes.  Tenants without a tier get the all-elastic default
    (floor 0, share 1)."""

    tiers: tuple[QosTierSpec, ...] = ()
    stability: str = "percentile"
    interval: int = 1

    @classmethod
    def from_dict(cls, d: dict) -> "QosSpec":
        return cls(
            tiers=tuple(QosTierSpec.from_dict(t) for t in d.get("tiers", ())),
            stability=d.get("stability", "percentile"),
            interval=d.get("interval", 1),
        )

    def controller(self, capacity: int, n_blocks: int, tenant_names=()):
        """Build the :class:`~repro.uvm.qos.BudgetController` this spec
        describes.  ``tenant_names`` maps integer tenant ids (a trace's
        ``tenant_names`` tuple) onto the spec's name-keyed tiers so the
        same spec serves both trace-driven and streaming paths."""
        from repro.uvm.qos import BudgetController, QosTier

        tiers: dict = {t.tenant: QosTier(t.floor, t.share) for t in self.tiers}
        for i, name in enumerate(tenant_names or ()):
            if name in tiers:
                tiers[i] = tiers[name]
        return BudgetController(
            capacity, n_blocks, tiers=tiers,
            stability=self.stability, interval=self.interval,
        )


#: how a concurrent (tenant-tagged) workload is managed by an `ours` cell
TENANCIES = ("mux", "mux-shared", "merged")


@dataclasses.dataclass(frozen=True)
class ModelSpec(_SpecBase):
    """Everything that determines a learned run besides the workload:
    predictor architecture (a registered ``kind``), its config, the
    training schedule, the Eq. 3 ablation switches, and the optional
    Section V-A pretraining recipe.

    ``tenancy`` picks the multi-tenant treatment of concurrent workloads
    (ignored for single-tenant ones): ``mux`` (default) demultiplexes into
    per-tenant pipelines with isolated frequency tables, ``mux-shared``
    shares ONE frequency table across tenants (the paper's single 18KB
    SRAM budget), ``merged`` is the pre-mux single-manager baseline.
    ``reclass_interval``/``reclass_hysteresis`` are the streaming periodic
    re-classification knobs (0 = classify every observed batch).

    ``health``/``latency_budget_ms`` opt the run into the degraded-mode
    health state machine (:class:`repro.uvm.manager.HealthConfig`):
    dispatch failures and non-finite model outputs fall back to rule-based
    actions instead of raising.  Off by default — the goldens pin the
    legacy fail-hard path bit for bit.

    ``qos`` opts a ``mux`` run into per-tenant capacity partitioning
    (:class:`QosSpec` → a :class:`~repro.uvm.qos.BudgetController`);
    ``None`` (default) is the legacy shared pool, pinned by the goldens."""

    kind: str = "transformer"
    predictor: PredictorConfig = CONFIG_QUICK
    train: TrainSpec = TrainSpec()
    use_thrash_term: bool = True
    use_lucir: bool = True
    pretrain: PretrainSpec | None = None
    tenancy: str = "mux"
    reclass_interval: int = 0
    reclass_hysteresis: int = 2
    health: bool = False
    latency_budget_ms: float = 0.0
    qos: QosSpec | None = None

    def __post_init__(self):
        if self.tenancy not in TENANCIES:
            raise ValueError(f"unknown tenancy {self.tenancy!r}; one of {TENANCIES}")

    @classmethod
    def from_dict(cls, d: dict) -> "ModelSpec":
        return cls(
            kind=d["kind"],
            predictor=PredictorConfig(**d["predictor"]),
            train=TrainSpec.from_dict(d["train"]),
            use_thrash_term=d["use_thrash_term"],
            use_lucir=d["use_lucir"],
            pretrain=PretrainSpec.from_dict(d["pretrain"]) if d.get("pretrain") else None,
            tenancy=d.get("tenancy", "mux"),
            reclass_interval=d.get("reclass_interval", 0),
            reclass_hysteresis=d.get("reclass_hysteresis", 2),
            health=d.get("health", False),
            latency_budget_ms=d.get("latency_budget_ms", 0.0),
            qos=QosSpec.from_dict(d["qos"]) if d.get("qos") else None,
        )

    def health_config(self):
        """The manager-side :class:`~repro.uvm.manager.HealthConfig` this
        spec asks for (``None`` when the health machine is off)."""
        if not self.health:
            return None
        from repro.uvm.manager import HealthConfig

        return HealthConfig(latency_budget_ms=self.latency_budget_ms)


@dataclasses.dataclass(frozen=True)
class CellSpec(_SpecBase):
    """One experiment cell: a workload under one management strategy.

    ``strategy`` picks the engine:
      * ``sim``       — rule-based (policy, prefetch) through the simulator
      * ``ours``      — the paper's learned runtime (``model`` required)
      * ``uvmsmart``  — the UVMSmart adaptive baseline
    """

    workload: WorkloadSpec
    strategy: str = "sim"
    policy: PolicySpec = PolicySpec("lru")
    prefetch: PrefetchSpec = PrefetchSpec("tree")
    oversubscription: float = 1.25
    model: ModelSpec | None = None
    seed: int = 0

    def __post_init__(self):
        if self.strategy not in ("sim", "ours", "uvmsmart"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.strategy == "ours" and self.model is None:
            raise ValueError("strategy 'ours' needs a ModelSpec")

    @classmethod
    def from_dict(cls, d: dict) -> "CellSpec":
        return cls(
            workload=WorkloadSpec.from_dict(d["workload"]),
            strategy=d["strategy"],
            policy=PolicySpec.from_dict(d["policy"]),
            prefetch=PrefetchSpec.from_dict(d["prefetch"]),
            oversubscription=d["oversubscription"],
            model=ModelSpec.from_dict(d["model"]) if d.get("model") else None,
            seed=d.get("seed", 0),
        )


@dataclasses.dataclass(frozen=True)
class ProtocolSpec(_SpecBase):
    """A prediction-accuracy protocol run (Figs. 4/6/10/11, Table VII).

    ``prior`` is the chain context: the benchmark names whose ``ours``
    protocol runs already fine-tuned the shared pretrained table before
    this one (fig11 reuses ONE table across its featured benchmarks, so a
    link's result depends on the links before it — the content hash must
    too). Empty for independent runs."""

    workload: WorkloadSpec
    mode: str = "online_single"  # online_single | online_multi | ours | offline
    model: ModelSpec = ModelSpec()
    prior: tuple[str, ...] = ()

    def __post_init__(self):
        if self.mode not in ("online_single", "online_multi", "ours", "offline"):
            raise ValueError(f"unknown protocol mode {self.mode!r}")

    @classmethod
    def from_dict(cls, d: dict) -> "ProtocolSpec":
        return cls(
            workload=WorkloadSpec.from_dict(d["workload"]),
            mode=d["mode"],
            model=ModelSpec.from_dict(d["model"]),
            prior=tuple(d.get("prior", ())),
        )


@dataclasses.dataclass(frozen=True)
class ExperimentSpec(_SpecBase):
    """A named cross product of cells: workloads x policies x prefetchers x
    oversubscription levels (for ``strategy='sim'``), or workloads x
    oversubscriptions (for ``ours`` / ``uvmsmart``, which have no
    policy/prefetch axis). ``extra_cells`` appends arbitrary cells."""

    name: str = "experiment"
    workloads: tuple[WorkloadSpec, ...] = ()
    strategy: str = "sim"
    policies: tuple[PolicySpec, ...] = (PolicySpec("lru"),)
    prefetchers: tuple[PrefetchSpec, ...] = (PrefetchSpec("tree"),)
    oversubscriptions: tuple[float, ...] = (1.25,)
    model: ModelSpec | None = None
    seed: int = 0
    extra_cells: tuple[CellSpec, ...] = ()

    def cells(self) -> list[CellSpec]:
        out: list[CellSpec] = []
        for w in self.workloads:
            for os_ in self.oversubscriptions:
                if self.strategy == "sim":
                    out += [
                        CellSpec(w, "sim", pol, pf, os_, None, self.seed)
                        for pol in self.policies for pf in self.prefetchers
                    ]
                else:
                    out.append(CellSpec(
                        w, self.strategy, PolicySpec("learned" if self.strategy == "ours" else "adaptive"),
                        PrefetchSpec("none" if self.strategy == "ours" else "adaptive"),
                        os_, self.model, self.seed,
                    ))
        return out + list(self.extra_cells)

    def __iter__(self) -> Iterator[CellSpec]:
        return iter(self.cells())

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        return cls(
            name=d.get("name", "experiment"),
            workloads=tuple(WorkloadSpec.from_dict(w) for w in d.get("workloads", ())),
            strategy=d.get("strategy", "sim"),
            policies=tuple(PolicySpec.from_dict(p) for p in d.get("policies", ({"name": "lru"},))),
            prefetchers=tuple(PrefetchSpec.from_dict(p) for p in d.get("prefetchers", ({"name": "tree"},))),
            oversubscriptions=tuple(d.get("oversubscriptions", (1.25,))),
            model=ModelSpec.from_dict(d["model"]) if d.get("model") else None,
            seed=d.get("seed", 0),
            extra_cells=tuple(CellSpec.from_dict(c) for c in d.get("extra_cells", ())),
        )


_SPEC_KINDS = {
    cls.__name__: cls
    for cls in (DriftSpec, WorkloadSpec, PolicySpec, PrefetchSpec, TrainSpec,
                PretrainSpec, QosTierSpec, QosSpec, ModelSpec, CellSpec,
                ProtocolSpec, ExperimentSpec)
}


def spec_from_dict(kind: str, d: dict):
    """Reconstruct any spec from (class name, to_dict() payload)."""
    return _SPEC_KINDS[kind].from_dict(d)
