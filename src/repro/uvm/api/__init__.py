"""`repro.uvm.api` — the declarative experiment surface.

One stable, composable seam over the five historical entry points
(``simulator.run``/``run_batch``, ``runtime.run_ours``/``run_ours_many``,
``uvmsmart.run_uvmsmart``, ``incremental.run_protocol`` and the
benchmark suite's retired in-process cache):

* **Specs** (:mod:`repro.uvm.api.specs`) — frozen, JSON-serializable
  dataclasses (`WorkloadSpec`, `PolicySpec`, `PrefetchSpec`, `ModelSpec`,
  `CellSpec`, `ProtocolSpec`, `ExperimentSpec`), each with a stable
  content-hash `.key`.
* **Registries** (:mod:`repro.uvm.registry`) — `register_policy`,
  `register_prefetcher`, `register_predictor`: the builtin strategies are
  default entries; a new policy is a ~20-line registration that rides the
  packed-priority vmapped scan.
* **Session + run store** (:mod:`repro.uvm.api.session`,
  :mod:`repro.uvm.api.store`) — `Session` executes cells, auto-grouping
  compatible ones into the batched `run_batch` / `run_ours_many` lanes, and
  persists every result content-addressed under ``experiments/runs/``.
* **CLI** — ``python -m repro.uvm.cli {run,sweep,report}``.

See docs/API.md for the cookbook.
"""
from repro.uvm.api.specs import (
    CellSpec,
    DriftSpec,
    ExperimentSpec,
    ModelSpec,
    PolicySpec,
    PrefetchSpec,
    PretrainSpec,
    ProtocolSpec,
    QosSpec,
    QosTierSpec,
    TrainSpec,
    WorkloadSpec,
    spec_from_dict,
    spec_key,
)
from repro.uvm.api.store import RunStore
from repro.uvm.api.session import ALL_BENCH, FEATURED, Session
from repro.uvm.registry import (
    register_policy,
    register_prefetcher,
    register_predictor,
    register_classifier,
    register_freq_table,
    register_stability,
    policy_names,
    prefetcher_names,
    predictor_names,
    classifier_names,
    freq_table_names,
    stability_names,
)

__all__ = [
    "WorkloadSpec", "DriftSpec", "PolicySpec", "PrefetchSpec", "TrainSpec",
    "PretrainSpec", "ModelSpec", "CellSpec", "ProtocolSpec", "ExperimentSpec",
    "QosSpec", "QosTierSpec",
    "spec_key", "spec_from_dict",
    "RunStore", "Session", "ALL_BENCH", "FEATURED",
    "register_policy", "register_prefetcher", "register_predictor",
    "register_classifier", "register_freq_table", "register_stability",
    "policy_names", "prefetcher_names", "predictor_names",
    "classifier_names", "freq_table_names", "stability_names",
]
