"""Frozen pre-refactor simulator hot path (the bit-exactness oracle).

This is the original per-access ``lax.scan`` step with the nested
``while_loop`` eviction (`_lex_argmin` re-scanned per victim) that
``simulator.py`` replaced with the packed-priority / fault-event-compressed
fast path.  It is kept verbatim so the equivalence suite can check the fast
path against the reference on arbitrary (hypothesis-generated) traces, not
just the committed goldens.  Keep it slow and obvious; never optimise it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.uvm.simulator import (
    CHUNK_BLOCKS,
    INTERVAL,
    NO_USE,
    SimResult,
    SimState,
    _tree_mask,
    capacity_for,
    init_state,
    pad_blocks,
)
from repro.uvm.trace import Trace


def precompute_next_use(blocks: np.ndarray, n_blocks: int) -> np.ndarray:
    """next_use[t] = index of the next access to blocks[t] after t (else INF)."""
    nxt = np.full(len(blocks), NO_USE, np.int64)
    last = np.full(n_blocks, NO_USE, np.int64)
    for t in range(len(blocks) - 1, -1, -1):
        nxt[t] = last[blocks[t]]
        last[blocks[t]] = t
    return np.minimum(nxt, NO_USE).astype(np.int32)


def _lex_argmin(cand, *keys):
    """Index of the lexicographically-smallest key tuple among candidates."""
    for k in keys:
        kk = jnp.where(cand, k, jnp.iinfo(jnp.int32).max)
        cand = cand & (kk == kk.min())
    return jnp.argmax(cand)


def _victim(state: SimState, policy: str, interval_now, evictable):
    """Eviction victim index under the given policy (exact int32 lexicographic)."""
    la = state.last_access
    if policy == "lru":
        keys = (la,)
    elif policy == "random":
        keys = (jax.random.randint(jax.random.fold_in(state.key, state.time), la.shape, 0, 1 << 30, jnp.int32),)
    elif policy == "belady":
        keys = (-state.next_use,)  # farthest next use evicted first
    elif policy == "hpe":
        age = jnp.clip(interval_now - state.last_interval, 0, 2)  # 0=new..2=old
        keys = (-age, la)
    elif policy == "learned":
        age = jnp.clip(interval_now - state.last_interval, 0, 2)
        keys = (-age, state.freq, la)
    else:
        raise ValueError(policy)
    return _lex_argmin(evictable, *keys)


def _evict_until_fit(state: SimState, capacity: int, policy: str, protect, interval_now):
    """Evict lowest-priority resident blocks until occupancy <= capacity."""

    def cond(c):
        resident, evicted_once, occ = c
        any_evictable = (resident & ~state.pinned & ~protect).any()
        return (occ > capacity) & any_evictable

    def body(c):
        resident, evicted_once, occ = c
        evictable = resident & ~state.pinned & ~protect
        victim = _victim(state._replace(resident=resident, evicted_once=evicted_once), policy, interval_now, evictable)
        resident = resident.at[victim].set(False)
        evicted_once = evicted_once.at[victim].set(True)
        return resident, evicted_once, occ - 1

    resident, evicted_once, occ = jax.lax.while_loop(
        cond, body, (state.resident, state.evicted_once, state.occupancy)
    )
    return state._replace(resident=resident, evicted_once=evicted_once, occupancy=occ)


def make_step(n_blocks: int, capacity: int, policy: str, prefetch: str, n_valid: int):
    valid = jnp.arange(n_blocks) < n_valid

    def step(state: SimState, inp):
        blk, nxt = inp
        t = state.time
        is_pinned = state.pinned[blk]
        fault = (~state.resident[blk]) & (~is_pinned)

        # demand block migrates on fault
        mig = jnp.zeros(n_blocks, bool).at[blk].set(fault)
        resident1 = state.resident | mig
        if prefetch == "tree":
            pf = _tree_mask(resident1, blk, valid, n_blocks) & fault
            mig = mig | pf
        newly = mig & ~state.resident
        n_new = newly.sum(dtype=jnp.int32)
        thrash = (newly & state.evicted_once).sum(dtype=jnp.int32)

        interval_now = state.fault_count // INTERVAL
        state2 = state._replace(
            resident=state.resident | newly,
            occupancy=state.occupancy + n_new,
            fault_count=state.fault_count + fault.astype(jnp.int32),
            thrash_events=state.thrash_events + thrash,
            migrations=state.migrations + n_new,
            faults=state.faults + fault.astype(jnp.int32),
            zero_copy=state.zero_copy + is_pinned.astype(jnp.int32),
            # prefetched blocks count as freshly used by the DRIVER's LRU
            last_access=jnp.where(newly | (jnp.arange(n_blocks) == blk), t, state.last_access),
            # ...but HPE's page-set chain only sees DEMAND touches (Section
            # III-B); the paper's engine ("learned") updates it with both.
            last_interval=jnp.where(
                (newly if policy == "learned" else jnp.zeros_like(newly)) | (jnp.arange(n_blocks) == blk),
                interval_now,
                state.last_interval,
            ),
            next_use=state.next_use.at[blk].set(nxt),
        )
        protect = jnp.zeros(n_blocks, bool).at[blk].set(True)
        state3 = _evict_until_fit(state2, capacity, policy, protect, interval_now)
        out = {
            "fault": fault,
            "thrash": thrash,
            "was_evicted": state.evicted_once[blk],
        }
        return state3._replace(time=t + 1), out

    return step


@partial(jax.jit, static_argnames=("n_blocks", "capacity", "policy", "prefetch", "n_valid"))
def _run_segment(state, blocks, next_use, n_blocks, capacity, policy, prefetch, n_valid):
    step = make_step(n_blocks, capacity, policy, prefetch, n_valid)
    return jax.lax.scan(step, state, (blocks, next_use))


def run(
    trace: Trace,
    *,
    policy: str = "lru",
    prefetch: str = "tree",
    oversubscription: float = 1.25,
    state: SimState | None = None,
    seed: int = 0,
) -> SimResult:
    """Reference run: full trace under (policy x prefetch), original semantics."""
    blocks = trace.block.astype(np.int32)
    nb = pad_blocks(trace.n_blocks)
    cap = capacity_for(trace.n_blocks, oversubscription)
    nxt = precompute_next_use(blocks, nb)
    st = state if state is not None else init_state(nb, seed)
    st, outs = _run_segment(
        st, jnp.asarray(blocks), jnp.asarray(nxt),
        n_blocks=nb, capacity=cap, policy=policy,
        prefetch="demand" if prefetch == "none" else prefetch,
        n_valid=trace.n_blocks,
    )
    st = st._replace(key=jax.random.key_data(st.key))  # numpy-safe
    return SimResult(
        state=jax.tree.map(np.asarray, st),
        fault=np.asarray(outs["fault"]),
        thrash=np.asarray(outs["thrash"]),
        was_evicted=np.asarray(outs["was_evicted"]),
    )
