"""Append-only feature encoder for the streaming manager.

:class:`repro.core.features.FeatureStream` needs the whole trace up front;
an online manager only ever sees the next fault batch.  This encoder
appends batches and yields the SAME window samples `FeatureStream.windows`
would produce over the concatenated stream — byte-identical arrays, so a
driver that replays a trace through :class:`OversubscriptionManager`
reproduces the monolithic `run_ours` bit for bit (the delta vocabulary
grows in arrival order, window history crosses batch boundaries, the first
``history`` accesses never become samples).

Memory is BOUNDED: only the last ``history`` encoded rows survive between
batches (that tail is all a future window can reach, and the previous raw
page is all the delta encoder needs), so an endless stream — the ``cli
serve`` sidecar, the serving offload adapter — costs O(history + batch)
resident, not O(stream).  Indices stay global: ``windows``/``page_at``
take stream positions and refuse spans that slid out of retention.
"""
from __future__ import annotations

import numpy as np

from repro.core.features import DeltaVocab, FeatureSet

_FIELDS = ("_page", "_ph", "_dcls", "_pch", "_tbh")


class OnlineFeatureStream:
    """Incremental (page, pc, tb) encoder with cross-batch window history."""

    def __init__(self, vocab: DeltaVocab, history: int = 10, *, page_vocab=4096, pc_vocab=512, tb_vocab=512):
        self.vocab = vocab
        self.history = history
        self.page_vocab, self.pc_vocab, self.tb_vocab = page_vocab, pc_vocab, tb_vocab
        self._off = 0  # global stream index of the retained arrays' row 0
        self._page = np.zeros(0, np.int32)  # raw page ids (label_page / prev-page)
        self._ph = np.zeros(0, np.int32)
        self._dcls = np.zeros(0, np.int32)
        self._pch = np.zeros(0, np.int32)
        self._tbh = np.zeros(0, np.int32)

    def __len__(self) -> int:
        """Global stream length (includes rows already trimmed)."""
        return self._off + len(self._page)

    def page_at(self, idx: np.ndarray) -> np.ndarray:
        """Raw page ids at GLOBAL stream positions (must be retained)."""
        local = np.asarray(idx) - self._off
        if local.size and int(local.min()) < 0:
            raise IndexError(f"stream position {int(np.asarray(idx).min())} slid out of retention")
        return self._page[local]

    def append(self, page: np.ndarray, pc: np.ndarray, tb: np.ndarray) -> tuple[int, int]:
        """Encode one batch; returns its [g0, g1) span in the stream."""
        pg = np.asarray(page, np.int64)
        g0 = len(self)
        if len(pg) == 0:
            return g0, g0
        # delta of the batch's first access reaches back across the batch
        # boundary (FeatureStream: prev = page[lo-1] if lo else page[0])
        prev = np.int64(self._page[-1]) if g0 else pg[0]
        deltas = np.diff(pg, prepend=prev)
        # trim to what future calls can still address: the NEXT batch's
        # windows reach back `history` rows; the delta encoder needs row -1
        keep = max(self.history, 1)
        if len(self._page) > keep:
            drop = len(self._page) - keep
            self._off += drop
            for f in _FIELDS:
                setattr(self, f, getattr(self, f)[drop:])
        self._page = np.concatenate([self._page, np.asarray(page).astype(np.int32)])
        self._ph = np.concatenate([self._ph, (pg % self.page_vocab).astype(np.int32)])
        self._dcls = np.concatenate([self._dcls, self.vocab.encode(deltas)])
        self._pch = np.concatenate([self._pch, (np.asarray(pc) % self.pc_vocab).astype(np.int32)])
        self._tbh = np.concatenate([self._tbh, (np.asarray(tb) % self.tb_vocab).astype(np.int32)])
        return g0, len(self)

    def windows(self, lo: int, hi: int) -> FeatureSet:
        """Window samples for GLOBAL stream span [lo, hi) —
        `FeatureStream.windows` verbatim (same index math, same dtypes)."""
        lo = max(lo, self.history)
        n = max(hi - lo, 0)
        if n == 0:
            e = np.zeros((0, self.history), np.int32)
            z = np.zeros((0,), np.int32)
            return FeatureSet(e, e.copy(), e.copy(), e.copy(), z, z.copy(), z.copy())
        if lo - self.history < self._off:
            raise IndexError(f"window span [{lo}, {hi}) reaches rows that slid out of retention")
        idx = (lo - self._off) + np.arange(n)[:, None] - np.arange(self.history, 0, -1)[None, :]
        sl = slice(lo - self._off, hi - self._off)
        return FeatureSet(
            page=self._ph[idx],
            delta=self._dcls[idx],
            pc=self._pch[idx],
            tb=self._tbh[idx],
            label=self._dcls[sl].astype(np.int32),
            label_page=self._page[sl].astype(np.int32),
            t_index=(lo + np.arange(n)).astype(np.int32),
        )
