"""Seeded fault injection for the streaming manager (the chaos harness).

Robustness claims need reproducible failures: :class:`FaultInjector`
wraps the manager's collaborators — the trainer, the frequency table, the
serve sidecar's input line stream — and injects faults drawn from ONE
seeded generator according to a declarative :class:`ChaosSchedule`, so a
chaos run replays bit-for-bit from ``(schedule, input)``.

Injected fault classes (each an independent per-event probability):

* ``trainer_exc`` — ``evaluate``/``evaluate_many`` raises :class:`ChaosError`
  (a dispatch failure: the health machine must degrade, not crash);
* ``nan_output`` — the predictor returns NaN float arrays (caught by
  ``check_result``'s output validation);
* ``train_exc`` — ``train_group``/``train_group_many`` raises (a lost
  fine-tune: the round must still close);
* ``nan_params`` — a fine-tuned entry's params are NaN-poisoned (caught
  by ``guard_dispatch``'s pre-dispatch finiteness check, which
  quarantines + re-initializes the slot);
* ``drop_batch`` / ``dup_batch`` / ``reorder_batch`` — observe lines
  vanish, repeat, or arrive late (stream-transport faults);
* ``lose_feedback`` / ``delay_feedback`` — outcome reports vanish or
  arrive after later lines (the manager's auto-close path must cope);
* ``drop_freq_update`` — frequency-table updates are silently lost
  (degraded telemetry, not an error: actions stay well-formed).

Wire-up (the ``cli serve --inject`` flags do exactly this)::

    inj = FaultInjector(ChaosSchedule.parse("trainer_exc=0.3,seed=7"))
    mux.trainer = inj.wrap_trainer(mux.trainer)
    for line in inj.transform_lines(fh): ...
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from pathlib import Path

import numpy as np


class ChaosError(RuntimeError):
    """The injected dispatch failure (distinguishable from real bugs)."""


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """Declarative, seedable fault plan — the full specification of one
    chaos run.  Frozen so a schedule can never drift mid-run; JSON
    round-trippable (:meth:`to_dict`) for experiment records."""

    seed: int = 0
    trainer_exc: float = 0.0
    nan_output: float = 0.0
    train_exc: float = 0.0
    nan_params: float = 0.0
    drop_batch: float = 0.0
    dup_batch: float = 0.0
    reorder_batch: float = 0.0
    lose_feedback: float = 0.0
    delay_feedback: float = 0.0
    drop_freq_update: float = 0.0

    def __post_init__(self):
        for f in dataclasses.fields(self):
            if f.name == "seed":
                continue
            v = getattr(self, f.name)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"chaos probability {f.name}={v} outside [0, 1]")

    @classmethod
    def parse(cls, spec: str) -> "ChaosSchedule":
        """``'trainer_exc=0.3,nan_output=0.1,seed=7'`` inline, or
        ``'@plan.json'`` to load a JSON dict from disk."""
        if spec.startswith("@"):
            d = json.loads(Path(spec[1:]).read_text())
        else:
            d = {}
            for part in filter(None, (p.strip() for p in spec.split(","))):
                key, sep, val = part.partition("=")
                if not sep:
                    raise ValueError(f"chaos spec entry {part!r} is not key=value")
                d[key] = val
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown chaos keys {sorted(unknown)}; known: {sorted(known)}")
        typed = {k: int(v) if k == "seed" else float(v) for k, v in d.items()}
        return cls(**typed)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _nan_like(tree):
    """NaN-poison every floating leaf of a pytree (ints pass through)."""
    import jax

    def poison(a):
        a = np.asarray(a)
        return np.full_like(a, np.nan) if np.issubdtype(a.dtype, np.floating) else a

    return jax.tree.map(poison, tree)


class _ChaosTrainer:
    """Delegating trainer proxy: same dispatch surface, injected faults."""

    def __init__(self, inner, injector: "FaultInjector"):
        self._inner = inner
        self._injector = injector

    def __getattr__(self, name):  # new_params, cfg, caches, ... pass through
        return getattr(self._inner, name)

    def evaluate(self, params, fs, n_active):
        if self._injector._fire("trainer_exc"):
            raise ChaosError("injected trainer exception (evaluate)")
        corr, pred = self._inner.evaluate(params, fs, n_active)
        if self._injector._fire("nan_output"):
            return np.full(len(np.asarray(corr)), np.nan), np.full(len(np.asarray(pred)), np.nan)
        return corr, pred

    def evaluate_many(self, params_list, fs_list, n_active_list):
        if self._injector._fire("trainer_exc"):
            raise ChaosError("injected trainer exception (evaluate_many)")
        out = self._inner.evaluate_many(params_list, fs_list, n_active_list)
        return [
            (np.full(len(np.asarray(c)), np.nan), np.full(len(np.asarray(p)), np.nan))
            if self._injector._fire("nan_output") else (c, p)
            for c, p in out
        ]

    def train_group(self, entry, fs, n_active, **kw):
        if self._injector._fire("train_exc"):
            raise ChaosError("injected trainer exception (train_group)")
        entry = self._inner.train_group(entry, fs, n_active, **kw)
        if self._injector._fire("nan_params"):
            entry.params = _nan_like(entry.params)
        return entry

    def train_group_many(self, entries, fs_list, n_active_list, **kw):
        if self._injector._fire("train_exc"):
            raise ChaosError("injected trainer exception (train_group_many)")
        out = self._inner.train_group_many(entries, fs_list, n_active_list, **kw)
        for entry in entries:
            if self._injector._fire("nan_params"):
                entry.params = _nan_like(entry.params)
        return out


class _ChaosFreqTable:
    """Delegating frequency-table proxy dropping a fraction of updates."""

    def __init__(self, inner, injector: "FaultInjector"):
        self._inner = inner
        self._injector = injector

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def update(self, blocks):
        if self._injector._fire("drop_freq_update"):
            return
        self._inner.update(blocks)


class FaultInjector:
    """One seeded RNG driving every injection site, so a chaos run is a
    pure function of ``(schedule, input stream)``.  ``counts`` tallies
    what actually fired (the chaos suite asserts on it)."""

    def __init__(self, schedule: ChaosSchedule):
        self.schedule = schedule
        self.rng = np.random.default_rng(schedule.seed)
        self.counts: Counter = Counter()

    def _fire(self, key: str) -> bool:
        p = getattr(self.schedule, key)
        if p <= 0.0:
            return False  # zero-probability sites consume no randomness
        hit = bool(self.rng.random() < p)
        if hit:
            self.counts[key] += 1
        return hit

    def wrap_trainer(self, trainer) -> _ChaosTrainer:
        return _ChaosTrainer(trainer, self)

    def wrap_freq_table(self, table) -> _ChaosFreqTable:
        return _ChaosFreqTable(table, self)

    def transform_lines(self, lines):
        """Apply the stream-transport faults to an iterable of serve JSONL
        lines: observe lines drop/duplicate/reorder, feedback lines get
        lost or delayed.  Held (reordered/delayed) lines are re-delivered
        right after the next delivered line; blanks and comments pass
        through untouched (they consume no randomness)."""
        held: list = []
        for line in lines:
            s = line.strip()
            if not s or s.startswith("#"):
                yield line
                continue
            if '"feedback"' in s:
                if self._fire("lose_feedback"):
                    continue
                if self._fire("delay_feedback"):
                    held.append(line)
                    continue
                yield line
            else:
                if self._fire("drop_batch"):
                    continue
                if self._fire("reorder_batch"):
                    held.append(line)
                    continue
                yield line
                if self._fire("dup_batch"):
                    yield line
            while held:
                yield held.pop(0)
        yield from held
