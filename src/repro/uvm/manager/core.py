"""`OversubscriptionManager` — the paper's online pipeline as a streaming API.

The framework (Fig. 2) is an ONLINE system: a pattern classifier feeding a
per-pattern predictor whose predictions drive a policy engine that
prefetches and pre-evicts on the live fault stream.  This module is that
pipeline with the workload decoupled: a consumer pushes fault batches in
and gets management actions out, then reports what actually happened so
the predictor can fine-tune causally.

Stepwise protocol (one round per fault batch)::

    mgr = OversubscriptionManager(ManagerConfig(n_pages=..., n_blocks=..., capacity=...))
    actions = mgr.observe(FaultBatch(page=pages))   # classify -> predict -> engine
    ... apply actions.prefetch_blocks / actions.counters / actions.pre_evict_blocks ...
    mgr.feedback(Outcomes(was_evicted=..., fault_count=...))  # causal fine-tune

Consumers in-tree: :func:`repro.uvm.runtime.run_ours` (the trace simulator
driver — counters and top-1 bit-identical to the pre-refactor monolith,
pinned by tests/golden/ours_golden.json),
:class:`repro.serving.offload.LearnedOffloadManager` (KV-page offload at
serving time) and ``python -m repro.uvm.cli serve`` (a JSONL fault-stream
sidecar).

Every component is swappable through :mod:`repro.uvm.registry`:
``classifier`` (builtin ``dfa``), ``freq_table`` (builtin ``setassoc``),
``kind`` (the registered predictor architectures) — an alternative
classifier or engine is a ~20-line registration, exactly like PR 3's
eviction policies.

Lockstep drivers (``run_ours_many``) batch the model dispatches across
many managers through the staged halves ``observe_begin``/``observe_finish``
and ``feedback_begin``/``feedback_finish``; ``observe``/``feedback`` are
those halves glued together with this manager's own trainer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.predictor_paper import CONFIG_QUICK, PredictorConfig
from repro.core.features import DeltaVocab, FeatureSet
from repro.core.incremental import Entry, TrainConfig, Trainer
from repro.core.model_table import ModelTable
from repro.core.pattern import LINEAR, RANDOM, RANDOM_REUSE, PatternClassifier
from repro.core.policy import PredictionFrequencyTable, predicted_blocks
from repro.uvm import registry as _registry
from repro.uvm.manager.stream import OnlineFeatureStream
from repro.uvm.trace import PAGES_PER_BLOCK

#: page-set-chain interval, in faults (= repro.uvm.simulator.INTERVAL; kept
#: literal so the manager stays importable without pulling the simulator)
INTERVAL_FAULTS = 64


# --- protocol payloads -------------------------------------------------------


@dataclasses.dataclass
class FaultBatch:
    """One batch of the demand stream: raw page ids plus the optional
    side-channel features the predictor consumes (absent channels are
    zeros, which hash to one bucket — harmless, just less signal).

    ``tenant`` tags each access with its workload (any hashable id, or a
    scalar for a whole-batch tag).  A plain :class:`OversubscriptionManager`
    ignores it; :class:`repro.uvm.manager.TenantMux` demultiplexes on it."""

    page: np.ndarray
    pc: np.ndarray | None = None
    tb: np.ndarray | None = None
    kernel: np.ndarray | None = None
    tenant: np.ndarray | None = None

    def __post_init__(self):
        self.page = np.asarray(self.page)
        n = len(self.page)
        z = lambda a: np.zeros(n, np.int32) if a is None else np.asarray(a)
        self.pc, self.tb, self.kernel = z(self.pc), z(self.tb), z(self.kernel)
        if self.tenant is not None and np.ndim(self.tenant) > 0:
            self.tenant = np.asarray(self.tenant)
            if len(self.tenant) != n:
                raise ValueError(
                    f"tenant tags must align with pages (expected {n}, got {len(self.tenant)})"
                )

    def __len__(self) -> int:
        return len(self.page)


@dataclasses.dataclass
class Actions:
    """The policy engine's output for one observed batch.

    ``prefetch_blocks`` — block ids to stage ahead of use (Section IV-D
    gating: repeated prediction + confidence-scaled budget; empty while the
    pattern model is cold/random).  ``pre_evict_blocks`` — advisory victim
    ranking, worst first (oldest chain partition, lowest prediction
    frequency — the `learned` eviction key); consumers with their own
    residency state may ignore it and read ``counters`` instead.
    ``counters`` — the dense per-block prediction-frequency export the
    simulator's `learned` policy consumes (``None`` when the prefetch gate
    is closed, matching the monolithic runtime's update cadence)."""

    prefetch_blocks: np.ndarray
    pre_evict_blocks: np.ndarray
    counters: np.ndarray | None
    pattern: int
    accuracy: float | None  # this batch's strictly-causal top-1 (None: no samples)
    n_samples: int
    warm: bool


@dataclasses.dataclass
class Outcomes:
    """What actually happened after the consumer applied a batch's actions:
    per-access E∪T membership (the thrashing-loss signal) and the
    cumulative far-fault count (advances the flush/chain intervals)."""

    was_evicted: np.ndarray | None = None  # bool per access of the LAST batch
    fault_count: int = 0


@dataclasses.dataclass
class EvalRequest:
    """Staged-observe handle: the predictor dispatch a lockstep driver
    batches across managers (``trainer.evaluate_many``)."""

    params: object
    fs: FeatureSet
    n_active: int


@dataclasses.dataclass
class TrainRequest:
    """Staged-feedback handle for ``trainer.train_group_many``."""

    entry: Entry
    fs: FeatureSet
    n_active: int
    in_et: np.ndarray | None
    use_lucir: bool


@dataclasses.dataclass
class ManagerConfig:
    """Everything that shapes one manager: the predictor stack, the
    workload geometry, and the registered component choices."""

    predictor: PredictorConfig = CONFIG_QUICK
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    kind: str = "transformer"
    n_pages: int = 4096  # working-set size (clips predicted pages)
    n_blocks: int = 256  # dense-counter width (simulator: the padded bucket)
    capacity: int = 192  # device blocks (the prefetch budget base)
    pages_per_block: int = PAGES_PER_BLOCK
    use_thrash_term: bool = True
    use_lucir: bool = True
    classifier: str = "dfa"
    freq_table: str = "setassoc"
    pre_evict_budget: int = 32  # advisory victims per Actions
    #: streaming periodic re-classification (0 = legacy: classify every
    #: observed batch).  With a positive interval the classifier re-runs
    #: only every ``reclass_interval`` FAULTS (the consumer-reported
    #: clock; observed accesses are the fallback trigger so feedback-less
    #: consumers still re-classify); between windows the ACTIVE pattern's
    #: model keeps serving.
    reclass_interval: int = 0
    #: hysteresis: a proposed pattern must win ``reclass_hysteresis``
    #: CONSECUTIVE re-classification windows before it replaces the active
    #: one (>= 2 means a single disagreeing window can never flip; the
    #: displaced pattern's model entry stays warm in the table).
    reclass_hysteresis: int = 2


# --- Section IV-D gates (shared with the monolithic runtime) ----------------


def prefetch_warm(entry: Entry, pat: int) -> bool:
    """Pattern-aware aggressiveness gate: cold models and random-classified
    phases must not drive prefetch, and the PREVIOUS group's measured
    accuracy must clear a pattern-dependent floor before speculative
    migration is worth PCIe bandwidth."""
    acc_floor = 0.4 if pat == LINEAR else 0.6
    return entry.n_updates > 0 and pat not in (RANDOM, RANDOM_REUSE) and entry.last_acc >= acc_floor


def prefetch_mask(dense: np.ndarray, pred_pages: np.ndarray, last_acc: float, nb: int, cap: int,
                  pages_per_block: int = PAGES_PER_BLOCK) -> np.ndarray:
    """Section IV-D prefetch candidate selection: gate by repeated
    prediction and cap the in-flight budget, scaled by model confidence."""
    pblocks = predicted_blocks(pred_pages, pages_per_block)
    pblocks = pblocks[pblocks < nb]
    # confidence-scaled aggressiveness: a highly-accurate model may
    # prefetch every predicted block; a mediocre one only repeated ones
    min_freq = 1 if last_acc >= 0.7 else 2
    pblocks = pblocks[dense[pblocks] >= min_freq]
    budget = cap if last_acc >= 0.7 else cap // 2
    if len(pblocks) > budget:
        order = np.argsort(-dense[pblocks], kind="stable")
        pblocks = pblocks[order[:budget]]
    mask = np.zeros(nb, bool)
    mask[pblocks] = True
    return mask


@dataclasses.dataclass
class _Pending:
    """Per-round state carried from observe to feedback."""

    g0: int
    n: int  # batch length (validates Outcomes.was_evicted alignment)
    fs: FeatureSet
    pat: int
    entry: Entry
    n_active: int
    warm: bool


class OversubscriptionManager:
    """The classify -> predict -> policy-engine pipeline, one batch at a time.

    Components default to fresh registry builds (``cfg.classifier`` /
    ``cfg.freq_table`` / a ``Trainer`` of ``cfg.kind``); pass ``table`` to
    start from a Section V-A pretrained model table, or inject any
    component explicitly (tests, shared tables, exotic engines).
    """

    def __init__(
        self,
        cfg: ManagerConfig,
        *,
        table: ModelTable | None = None,
        trainer: Trainer | None = None,
        classifier=None,
        freq_table=None,
    ):
        self.cfg = cfg
        self.trainer = trainer if trainer is not None else Trainer(cfg.predictor, cfg.train, cfg.kind)
        self.table = table if table is not None else ModelTable(
            lambda s: self.trainer.new_params(s), n_slots=cfg.train.table_slots
        )
        self.classifier = classifier if classifier is not None else _registry.classifier_factory(cfg.classifier)()
        self.freq_table = freq_table if freq_table is not None else _registry.freq_table_factory(cfg.freq_table)()
        pcfg = cfg.predictor
        self.vocab = DeltaVocab(pcfg.delta_vocab)
        self.stream = OnlineFeatureStream(
            self.vocab, pcfg.history,
            page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab,
        )
        # accuracy bookkeeping (what LearnedRunResult reports).  Exact
        # counts, not concatenated per-sample arrays: an endless stream
        # must not grow resident memory per fault (top-1 = true/total is
        # the same float64 a mean over the concatenation produces).
        self.per_group: list[float] = []  # one float per batch
        self._corr_true = 0
        self._corr_n = 0
        self._warm_true = 0
        self._warm_n = 0
        self.n_predictions = 0
        # class-id -> raw delta decode array, grown with the vocabulary
        self._decode = np.zeros(max(pcfg.delta_vocab, 2), np.int64)
        self._decoded_upto = 0
        # flush cadence + advisory page-set chain.  The fault clock is the
        # consumer-reported cumulative count, re-based when a NEW consumer
        # restarts it from zero (the cross-consumer handoff) so intervals
        # keep advancing across the switch.
        self._flush_interval = 0
        self._interval = 0
        self._fault_base = 0
        self._fault_raw = 0
        self._chain_li = np.full(cfg.n_blocks, -1, np.int64)
        self._pending: _Pending | None = None
        # streaming periodic re-classification (cfg.reclass_interval > 0):
        # the active pattern, the challenger and its consecutive-window
        # streak, and the fault clock of the last classifier run
        self._active_pat: int | None = None
        self._cand_pat: int | None = None
        self._cand_streak = 0
        self._last_reclass = 0
        self._obs_accesses = 0  # fallback window clock (faults need feedback)
        self._last_reclass_obs = 0
        self.n_reclassifications = 0
        self.n_pattern_switches = 0

    # -- result views --------------------------------------------------------

    @property
    def n_classes(self) -> int:
        return self.vocab.n_classes

    @property
    def n_models(self) -> int:
        return self.table.n_models

    @property
    def top1(self) -> float:
        return self._corr_true / self._corr_n if self._corr_n else 0.0

    @property
    def warm_top1(self) -> float:
        """Top-1 excluding each pattern-model's first (cold) group."""
        return self._warm_true / self._warm_n if self._warm_n else self.top1

    # -- streaming protocol --------------------------------------------------

    def observe(self, batch: FaultBatch) -> Actions:
        """One full round: ingest a fault batch, return the engine's actions."""
        req = self.observe_begin(batch)
        corr = pred = None
        if req is not None:
            corr, pred = self.trainer.evaluate(req.params, req.fs, req.n_active)
        return self.observe_finish(corr, pred)

    def feedback(self, outcomes: Outcomes) -> None:
        """Close the last observed batch: flush cadence + causal fine-tune."""
        req = self.feedback_begin(outcomes)
        if req is not None:
            entry = self.trainer.train_group(
                req.entry, req.fs, req.n_active, in_et=req.in_et, use_lucir=req.use_lucir
            )
            self.feedback_finish(entry)

    # -- staged halves (lockstep drivers batch the model dispatches) ---------

    def observe_begin(self, batch: FaultBatch) -> EvalRequest | None:
        """Ingest + classify; returns the predictor dispatch (None when the
        batch yields no window samples — history warm-up or empty batch)."""
        if self._pending is not None:
            raise RuntimeError("observe() called twice without feedback()")
        batch = batch if isinstance(batch, FaultBatch) else FaultBatch(np.asarray(batch))
        g0, g1 = self.stream.append(batch.page, batch.pc, batch.tb)
        fs = self.stream.windows(g0, g1)
        blocks = (np.asarray(batch.page, np.int64) // self.cfg.pages_per_block)
        if self.cfg.reclass_interval > 0:
            pat = self._reclassify(blocks, batch.kernel)
        else:
            pat = self.classifier.classify(blocks, batch.kernel)
        entry = self.table.get(pat)
        self._pending = _Pending(
            g0=g0, n=g1 - g0, fs=fs, pat=pat, entry=entry,
            n_active=max(self.vocab.n_classes, 2),
            warm=prefetch_warm(entry, pat),  # the PREVIOUS group's accuracy
        )
        # advisory chain: demand touches land in the current interval
        seen = blocks[blocks < self.cfg.n_blocks]
        self._chain_li[seen] = self._interval
        if len(fs) == 0:
            return None
        return EvalRequest(entry.params, fs, self._pending.n_active)

    def observe_finish(self, corr: np.ndarray | None, pred_cls: np.ndarray | None) -> Actions:
        """Fold the predictor's output into the policy engine; emit actions."""
        p = self._pending
        if p is None:
            raise RuntimeError("observe_finish() without observe_begin()")
        counters = None
        prefetch = np.zeros(0, np.int64)
        accuracy = None
        if corr is not None and len(p.fs):
            accuracy = float(corr.mean())
            self.per_group.append(accuracy)
            self._corr_true += int(np.count_nonzero(corr))
            self._corr_n += len(corr)
            if p.entry.n_updates > 0:
                self._warm_true += int(np.count_nonzero(corr))
                self._warm_n += len(corr)
            self.n_predictions += len(p.fs)
            p.entry.last_acc = accuracy  # informs the NEXT group's gate
            # predicted classes -> raw deltas -> predicted pages
            pred_delta = self._decode_deltas(pred_cls)
            prev_page = self.stream.page_at(p.fs.t_index - 1).astype(np.int64)
            pred_pages = np.clip(prev_page + pred_delta, 0, self.cfg.n_pages - 1)
            if p.warm:
                self.freq_table.update(np.asarray(pred_pages, np.int64) // self.cfg.pages_per_block)
                # one dense export per batch: it feeds both the simulator's
                # `learned` eviction keys and the prefetch gate
                counters = self.freq_table.dense(self.cfg.n_blocks)
                mask = prefetch_mask(
                    counters, pred_pages, p.entry.last_acc,
                    self.cfg.n_blocks, self.cfg.capacity, self.cfg.pages_per_block,
                )
                prefetch = np.flatnonzero(mask)
                self._chain_li[prefetch] = self._interval  # staged = touched
        return Actions(
            prefetch_blocks=prefetch,
            pre_evict_blocks=self._pre_evict(counters),
            counters=counters,
            pattern=p.pat,
            accuracy=accuracy,
            n_samples=len(p.fs),
            warm=p.warm,
        )

    def feedback_begin(self, outcomes: Outcomes) -> TrainRequest | None:
        """Advance the flush/chain intervals; stage the fine-tune dispatch
        (None when the batch had no samples — bookkeeping still happens)."""
        p = self._pending
        if p is None:
            raise RuntimeError("feedback() without a pending observe()")
        raw = int(outcomes.fault_count)
        if raw < self._fault_raw:  # consumer switch: its clock restarted at 0
            self._fault_base += self._fault_raw
        self._fault_raw = raw
        interval_now = (self._fault_base + raw) // INTERVAL_FAULTS
        if interval_now > self._flush_interval:
            # frequency table flush cadence (every 3 fault-intervals)
            self.freq_table.on_intervals(interval_now - self._flush_interval)
            self._flush_interval = interval_now
        self._interval = max(self._interval, interval_now)
        if len(p.fs) == 0:
            self._pending = None
            return None
        if self.cfg.use_lucir:
            self.table.snapshot_prev(p.pat)
            p.entry = self.table.get(p.pat)
        in_et = None
        if self.cfg.use_thrash_term and outcomes.was_evicted is not None:
            we = np.asarray(outcomes.was_evicted)
            if len(we) != p.n:
                raise ValueError(
                    f"Outcomes.was_evicted must have one entry per access of the "
                    f"last observed batch (expected {p.n}, got {len(we)})"
                )
            in_et = we[p.fs.t_index - p.g0]
        return TrainRequest(p.entry, p.fs, p.n_active, in_et, self.cfg.use_lucir)

    def feedback_finish(self, entry: Entry) -> None:
        """Publish the fine-tuned entry back to the pattern table."""
        p = self._pending
        if p is None:
            raise RuntimeError("feedback_finish() without feedback_begin()")
        self.table.put(p.pat, entry)
        self._pending = None

    # -- internals -----------------------------------------------------------

    def _reclassify(self, blocks: np.ndarray, kernels: np.ndarray) -> int:
        """Periodic re-classification with hysteresis (cfg.reclass_interval
        faults per window; a challenger needs cfg.reclass_hysteresis
        consecutive agreeing windows to dethrone the active pattern).

        The window clock is the consumer-reported fault count, with the
        OBSERVED-ACCESS count as a fallback trigger: a feedback-less
        consumer (the serve sidecar's auto-close mode reports no faults)
        must still re-classify, and since every fault is an access the
        fallback can only make windows more frequent, never rarer."""
        clock = self._fault_base + self._fault_raw
        self._obs_accesses += len(blocks)
        due = (clock - self._last_reclass >= self.cfg.reclass_interval
               or self._obs_accesses - self._last_reclass_obs >= self.cfg.reclass_interval)
        if self._active_pat is None:  # first observation seeds the pattern
            self._active_pat = self.classifier.classify(blocks, kernels)
            self._last_reclass = clock
            self._last_reclass_obs = self._obs_accesses
            self.n_reclassifications += 1
        elif due:
            proposal = self.classifier.classify(blocks, kernels)
            self._last_reclass = clock
            self._last_reclass_obs = self._obs_accesses
            self.n_reclassifications += 1
            if proposal == self._active_pat:
                self._cand_pat, self._cand_streak = None, 0
            else:
                if proposal == self._cand_pat:
                    self._cand_streak += 1
                else:
                    self._cand_pat, self._cand_streak = proposal, 1
                if self._cand_streak >= max(self.cfg.reclass_hysteresis, 1):
                    # the displaced pattern's model entry stays warm in the
                    # table — flipping back later resumes where it left off
                    self._active_pat = proposal
                    self._cand_pat, self._cand_streak = None, 0
                    self.n_pattern_switches += 1
        return self._active_pat

    def _decode_deltas(self, pred_cls: np.ndarray) -> np.ndarray:
        """Vectorized class-id -> raw-delta decode (the grown-so-far slice
        of the vocabulary; unknown ids decode to delta 0, like the dict
        lookup's default)."""
        if self.vocab.n_classes > self._decoded_upto:
            for delta, cls in self.vocab.table.items():
                if cls >= self._decoded_upto:
                    self._decode[cls] = delta
            self._decoded_upto = self.vocab.n_classes
        return self._decode[np.asarray(pred_cls, np.int64)]

    def _pre_evict(self, counters: np.ndarray | None) -> np.ndarray:
        """Advisory victim ranking: oldest chain partition first, lowest
        prediction frequency inside it (the `learned` victim key), budgeted
        to the blocks the working set holds over capacity."""
        seen = np.flatnonzero(self._chain_li >= 0)
        budget = min(max(int(seen.size) - self.cfg.capacity, 0), self.cfg.pre_evict_budget)
        if budget == 0:
            return np.zeros(0, np.int64)
        dense = counters if counters is not None else self.freq_table.dense(self.cfg.n_blocks)
        age = np.clip(self._interval - self._chain_li[seen], 0, 2)
        key = (-age << 20) + dense[seen]  # lexicographic (-age, freq), smallest first
        order = np.argsort(key, kind="stable")
        return seen[order[:budget]]


# --- builtin component registrations ----------------------------------------
# The paper's classifier + frequency table enter the SAME registry a user
# plugin does. Guarded for idempotence under importlib.reload.
if "dfa" not in _registry.classifier_names():
    _registry.register_classifier("dfa", PatternClassifier)
if "setassoc" not in _registry.freq_table_names():
    _registry.register_freq_table("setassoc", PredictionFrequencyTable)
