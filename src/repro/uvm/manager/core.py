"""`OversubscriptionManager` — the paper's online pipeline as a streaming API.

The framework (Fig. 2) is an ONLINE system: a pattern classifier feeding a
per-pattern predictor whose predictions drive a policy engine that
prefetches and pre-evicts on the live fault stream.  This module is that
pipeline with the workload decoupled: a consumer pushes fault batches in
and gets management actions out, then reports what actually happened so
the predictor can fine-tune causally.

Stepwise protocol (one round per fault batch)::

    mgr = OversubscriptionManager(ManagerConfig(n_pages=..., n_blocks=..., capacity=...))
    actions = mgr.observe(FaultBatch(page=pages))   # classify -> predict -> engine
    ... apply actions.prefetch_blocks / actions.counters / actions.pre_evict_blocks ...
    mgr.feedback(Outcomes(was_evicted=..., fault_count=...))  # causal fine-tune

Consumers in-tree: :func:`repro.uvm.runtime.run_ours` (the trace simulator
driver — counters and top-1 bit-identical to the pre-refactor monolith,
pinned by tests/golden/ours_golden.json),
:class:`repro.serving.offload.LearnedOffloadManager` (KV-page offload at
serving time) and ``python -m repro.uvm.cli serve`` (a JSONL fault-stream
sidecar).

Every component is swappable through :mod:`repro.uvm.registry`:
``classifier`` (builtin ``dfa``), ``freq_table`` (builtin ``setassoc``),
``kind`` (the registered predictor architectures) — an alternative
classifier or engine is a ~20-line registration, exactly like PR 3's
eviction policies.

Lockstep drivers (``run_ours_many``) batch the model dispatches across
many managers through the staged halves ``observe_begin``/``observe_finish``
and ``feedback_begin``/``feedback_finish``; ``observe``/``feedback`` are
those halves glued together with this manager's own trainer.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pickle
import time

import numpy as np

from repro.configs.predictor_paper import CONFIG_QUICK, PredictorConfig
from repro.core.features import DeltaVocab, FeatureSet
from repro.core.incremental import Entry, TrainConfig, Trainer
from repro.core.model_table import ModelTable
from repro.core.pattern import LINEAR, RANDOM, RANDOM_REUSE, PatternClassifier
from repro.core.policy import (
    PallasPredictionFrequencyTable,
    PredictionFrequencyTable,
    predicted_blocks,
)
from repro.uvm import registry as _registry
from repro.uvm.manager.snapshot import STATE_VERSION, tree_to_host
from repro.uvm.manager.stream import _FIELDS as _STREAM_FIELDS
from repro.uvm.manager.stream import OnlineFeatureStream
from repro.uvm.trace import PAGES_PER_BLOCK

#: page-set-chain interval, in faults (= repro.uvm.simulator.INTERVAL; kept
#: literal so the manager stays importable without pulling the simulator)
INTERVAL_FAULTS = 64

#: the degraded-mode state machine's states, in promotion order
HEALTH_STATES = ("healthy", "degraded", "recovering")


# --- protocol payloads -------------------------------------------------------


@dataclasses.dataclass
class FaultBatch:
    """One batch of the demand stream: raw page ids plus the optional
    side-channel features the predictor consumes (absent channels are
    zeros, which hash to one bucket — harmless, just less signal).

    ``tenant`` tags each access with its workload (any hashable id, or a
    scalar for a whole-batch tag).  A plain :class:`OversubscriptionManager`
    ignores it; :class:`repro.uvm.manager.TenantMux` demultiplexes on it."""

    page: np.ndarray
    pc: np.ndarray | None = None
    tb: np.ndarray | None = None
    kernel: np.ndarray | None = None
    tenant: np.ndarray | None = None

    def __post_init__(self):
        self.page = np.asarray(self.page)
        n = len(self.page)
        z = lambda a: np.zeros(n, np.int32) if a is None else np.asarray(a)
        self.pc, self.tb, self.kernel = z(self.pc), z(self.tb), z(self.kernel)
        if self.tenant is not None and np.ndim(self.tenant) > 0:
            self.tenant = np.asarray(self.tenant)
            if len(self.tenant) != n:
                raise ValueError(
                    f"tenant tags must align with pages (expected {n}, got {len(self.tenant)})"
                )

    def __len__(self) -> int:
        return len(self.page)


@dataclasses.dataclass
class Actions:
    """The policy engine's output for one observed batch.

    ``prefetch_blocks`` — block ids to stage ahead of use (Section IV-D
    gating: repeated prediction + confidence-scaled budget; empty while the
    pattern model is cold/random).  ``pre_evict_blocks`` — advisory victim
    ranking, worst first (oldest chain partition, lowest prediction
    frequency — the `learned` eviction key); consumers with their own
    residency state may ignore it and read ``counters`` instead.
    ``counters`` — the dense per-block prediction-frequency export the
    simulator's `learned` policy consumes (``None`` when the prefetch gate
    is closed, matching the monolithic runtime's update cadence).
    ``health`` / ``fallback`` — the degraded-mode state machine's verdict
    for this batch: ``fallback=True`` means the learned path did not run
    and ``prefetch_blocks``/``pre_evict_blocks`` are the rule-based floor
    (buddy tree-prefetch + LRU victims)."""

    prefetch_blocks: np.ndarray
    pre_evict_blocks: np.ndarray
    counters: np.ndarray | None
    pattern: int
    accuracy: float | None  # this batch's strictly-causal top-1 (None: no samples)
    n_samples: int
    warm: bool
    health: str = "healthy"
    fallback: bool = False


@dataclasses.dataclass
class Outcomes:
    """What actually happened after the consumer applied a batch's actions:
    per-access E∪T membership (the thrashing-loss signal) and the
    cumulative far-fault count (advances the flush/chain intervals)."""

    was_evicted: np.ndarray | None = None  # bool per access of the LAST batch
    fault_count: int = 0


@dataclasses.dataclass
class EvalRequest:
    """Staged-observe handle: the predictor dispatch a lockstep driver
    batches across managers (``trainer.evaluate_many``)."""

    params: object
    fs: FeatureSet
    n_active: int


@dataclasses.dataclass
class TrainRequest:
    """Staged-feedback handle for ``trainer.train_group_many``."""

    entry: Entry
    fs: FeatureSet
    n_active: int
    in_et: np.ndarray | None
    use_lucir: bool


@dataclasses.dataclass
class HealthConfig:
    """Degraded-mode policy-engine knobs.  ``ManagerConfig.health=None``
    (the default) disables the state machine entirely: dispatch failures
    propagate and no validation runs — exact legacy behavior, which is
    what the bit-identity goldens pin.

    With health enabled the manager runs a three-state machine
    (``healthy -> degraded -> recovering -> healthy``): any dispatch
    exception, non-finite model output/params, or per-observe latency
    overrun demotes to ``degraded``, where the batch (and the next
    ``backoff`` batches) take the rule-based fallback path instead of the
    learned one.  When the backoff window expires the manager enters
    ``recovering`` and retries the learned path; ``recovery_successes``
    consecutive clean dispatches re-promote to ``healthy``, while another
    fault doubles the backoff (capped at ``backoff_max``)."""

    backoff_initial: int = 1  # fallback rounds after the first fault
    backoff_max: int = 64  # exponential-backoff ceiling (rounds)
    recovery_successes: int = 2  # clean dispatches to re-promote
    latency_budget_ms: float = 0.0  # per-observe dispatch budget (0 = none)
    check_params: bool = True  # validate entry params finite pre-dispatch


@dataclasses.dataclass
class ManagerConfig:
    """Everything that shapes one manager: the predictor stack, the
    workload geometry, and the registered component choices."""

    predictor: PredictorConfig = CONFIG_QUICK
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    kind: str = "transformer"
    n_pages: int = 4096  # working-set size (clips predicted pages)
    n_blocks: int = 256  # dense-counter width (simulator: the padded bucket)
    capacity: int = 192  # device blocks (the prefetch budget base)
    pages_per_block: int = PAGES_PER_BLOCK
    use_thrash_term: bool = True
    use_lucir: bool = True
    classifier: str = "dfa"
    freq_table: str = "setassoc"
    pre_evict_budget: int = 32  # advisory victims per Actions
    #: streaming periodic re-classification (0 = legacy: classify every
    #: observed batch).  With a positive interval the classifier re-runs
    #: only every ``reclass_interval`` FAULTS (the consumer-reported
    #: clock; observed accesses are the fallback trigger so feedback-less
    #: consumers still re-classify); between windows the ACTIVE pattern's
    #: model keeps serving.
    reclass_interval: int = 0
    #: hysteresis: a proposed pattern must win ``reclass_hysteresis``
    #: CONSECUTIVE re-classification windows before it replaces the active
    #: one (>= 2 means a single disagreeing window can never flip; the
    #: displaced pattern's model entry stays warm in the table).
    reclass_hysteresis: int = 2
    #: degraded-mode fallback (None = legacy: no health machine, dispatch
    #: failures propagate; see :class:`HealthConfig`)
    health: HealthConfig | None = None


# --- Section IV-D gates (shared with the monolithic runtime) ----------------


def prefetch_warm(entry: Entry, pat: int) -> bool:
    """Pattern-aware aggressiveness gate: cold models and random-classified
    phases must not drive prefetch, and the PREVIOUS group's measured
    accuracy must clear a pattern-dependent floor before speculative
    migration is worth PCIe bandwidth."""
    acc_floor = 0.4 if pat == LINEAR else 0.6
    return entry.n_updates > 0 and pat not in (RANDOM, RANDOM_REUSE) and entry.last_acc >= acc_floor


def prefetch_mask(dense: np.ndarray, pred_pages: np.ndarray, last_acc: float, nb: int, cap: int,
                  pages_per_block: int = PAGES_PER_BLOCK) -> np.ndarray:
    """Section IV-D prefetch candidate selection: gate by repeated
    prediction and cap the in-flight budget, scaled by model confidence."""
    pblocks = predicted_blocks(pred_pages, pages_per_block)
    pblocks = pblocks[pblocks < nb]
    # confidence-scaled aggressiveness: a highly-accurate model may
    # prefetch every predicted block; a mediocre one only repeated ones
    min_freq = 1 if last_acc >= 0.7 else 2
    pblocks = pblocks[dense[pblocks] >= min_freq]
    budget = cap if last_acc >= 0.7 else cap // 2
    if len(pblocks) > budget:
        order = np.argsort(-dense[pblocks], kind="stable")
        pblocks = pblocks[order[:budget]]
    mask = np.zeros(nb, bool)
    mask[pblocks] = True
    return mask


@dataclasses.dataclass
class _Pending:
    """Per-round state carried from observe to feedback."""

    g0: int
    n: int  # batch length (validates Outcomes.was_evicted alignment)
    fs: FeatureSet
    pat: int
    entry: Entry
    n_active: int
    warm: bool
    blocks: np.ndarray | None = None  # observed in-range blocks (fallback prefetch)
    fallback: bool = False  # degraded mode: emit rule-based actions, skip training


def _tree_finite(tree) -> bool:
    """True when every floating leaf of a pytree is finite."""
    import jax

    for leaf in jax.tree.leaves(tree):
        a = np.asarray(leaf)
        if np.issubdtype(a.dtype, np.floating) and not np.all(np.isfinite(a)):
            return False
    return True


def _cfg_signature(cfg: ManagerConfig) -> str:
    """Stable digest of the state-shaping config fields: a snapshot must
    only restore into an identically-configured manager.  ``health`` is
    excluded — the degraded-mode knobs shape behavior, not state layout,
    and enabling them on resume is legitimate."""
    d = dataclasses.asdict(cfg)
    d.pop("health", None)
    return hashlib.md5(json.dumps(d, sort_keys=True, default=str).encode()).hexdigest()[:12]


class OversubscriptionManager:
    """The classify -> predict -> policy-engine pipeline, one batch at a time.

    Components default to fresh registry builds (``cfg.classifier`` /
    ``cfg.freq_table`` / a ``Trainer`` of ``cfg.kind``); pass ``table`` to
    start from a Section V-A pretrained model table, or inject any
    component explicitly (tests, shared tables, exotic engines).
    """

    def __init__(
        self,
        cfg: ManagerConfig,
        *,
        table: ModelTable | None = None,
        trainer: Trainer | None = None,
        classifier=None,
        freq_table=None,
    ):
        self.cfg = cfg
        self.trainer = trainer if trainer is not None else Trainer(cfg.predictor, cfg.train, cfg.kind)
        self.table = table if table is not None else ModelTable(
            lambda s: self.trainer.new_params(s), n_slots=cfg.train.table_slots
        )
        self.classifier = classifier if classifier is not None else _registry.classifier_factory(cfg.classifier)()
        self.freq_table = freq_table if freq_table is not None else _registry.freq_table_factory(cfg.freq_table)()
        pcfg = cfg.predictor
        self.vocab = DeltaVocab(pcfg.delta_vocab)
        self.stream = OnlineFeatureStream(
            self.vocab, pcfg.history,
            page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab,
        )
        # accuracy bookkeeping (what LearnedRunResult reports).  Exact
        # counts, not concatenated per-sample arrays: an endless stream
        # must not grow resident memory per fault (top-1 = true/total is
        # the same float64 a mean over the concatenation produces).
        self.per_group: list[float] = []  # one float per batch
        self._corr_true = 0
        self._corr_n = 0
        self._warm_true = 0
        self._warm_n = 0
        self.n_predictions = 0
        # class-id -> raw delta decode array, grown with the vocabulary
        self._decode = np.zeros(max(pcfg.delta_vocab, 2), np.int64)
        self._decoded_upto = 0
        # flush cadence + advisory page-set chain.  The fault clock is the
        # consumer-reported cumulative count, re-based when a NEW consumer
        # restarts it from zero (the cross-consumer handoff) so intervals
        # keep advancing across the switch.
        self._flush_interval = 0
        self._interval = 0
        self._fault_base = 0
        self._fault_raw = 0
        self._chain_li = np.full(cfg.n_blocks, -1, np.int64)
        self._pending: _Pending | None = None
        # streaming periodic re-classification (cfg.reclass_interval > 0):
        # the active pattern, the challenger and its consecutive-window
        # streak, and the fault clock of the last classifier run
        self._active_pat: int | None = None
        self._cand_pat: int | None = None
        self._cand_streak = 0
        self._last_reclass = 0
        self._obs_accesses = 0  # fallback window clock (faults need feedback)
        self._last_reclass_obs = 0
        self.n_reclassifications = 0
        self.n_pattern_switches = 0
        # degraded-mode state machine (inert while cfg.health is None)
        self._health_state = "healthy"
        self._backoff = 0  # current episode's backoff width, doubles per relapse
        self._backoff_left = 0  # fallback rounds before the next learned retry
        self._recovery_left = 0  # clean dispatches still owed before re-promotion
        self.n_health_faults = 0
        self.n_fallbacks = 0
        self.n_recoveries = 0
        self.last_health_error: str | None = None

    # -- result views --------------------------------------------------------

    @property
    def n_classes(self) -> int:
        return self.vocab.n_classes

    @property
    def n_models(self) -> int:
        return self.table.n_models

    @property
    def top1(self) -> float:
        return self._corr_true / self._corr_n if self._corr_n else 0.0

    @property
    def warm_top1(self) -> float:
        """Top-1 excluding each pattern-model's first (cold) group."""
        return self._warm_true / self._warm_n if self._warm_n else self.top1

    @property
    def health_state(self) -> str:
        return self._health_state

    # -- streaming protocol --------------------------------------------------

    def observe(self, batch: FaultBatch) -> Actions:
        """One full round: ingest a fault batch, return the engine's actions."""
        req = self.observe_begin(batch)
        corr = pred = None
        if req is not None and self.guard_dispatch(req):
            t0 = time.perf_counter()
            try:
                corr, pred = self.trainer.evaluate(req.params, req.fs, req.n_active)
            except Exception as exc:  # noqa: BLE001 — degraded mode absorbs anything
                if self.cfg.health is None:
                    raise
                self.note_fault(exc)
                corr = pred = None
            else:
                if not self.check_result(corr, pred, elapsed_s=time.perf_counter() - t0):
                    corr = pred = None
        return self.observe_finish(corr, pred)

    def feedback(self, outcomes: Outcomes) -> None:
        """Close the last observed batch: flush cadence + causal fine-tune."""
        req = self.feedback_begin(outcomes)
        if req is not None:
            try:
                entry = self.trainer.train_group(
                    req.entry, req.fs, req.n_active, in_et=req.in_et, use_lucir=req.use_lucir
                )
            except Exception as exc:  # noqa: BLE001
                if self.cfg.health is None:
                    raise
                self.note_fault(exc)  # the entry update is lost; round still closes
                self._pending = None
                return
            self.feedback_finish(entry)

    # -- staged halves (lockstep drivers batch the model dispatches) ---------

    def observe_begin(self, batch: FaultBatch) -> EvalRequest | None:
        """Ingest + classify; returns the predictor dispatch (None when the
        batch yields no window samples — history warm-up or empty batch)."""
        if self._pending is not None:
            raise RuntimeError("observe() called twice without feedback()")
        batch = batch if isinstance(batch, FaultBatch) else FaultBatch(np.asarray(batch))
        g0, g1 = self.stream.append(batch.page, batch.pc, batch.tb)
        fs = self.stream.windows(g0, g1)
        blocks = (np.asarray(batch.page, np.int64) // self.cfg.pages_per_block)
        if self.cfg.reclass_interval > 0:
            pat = self._reclassify(blocks, batch.kernel)
        else:
            pat = self.classifier.classify(blocks, batch.kernel)
        entry = self.table.get(pat)
        self._pending = _Pending(
            g0=g0, n=g1 - g0, fs=fs, pat=pat, entry=entry,
            n_active=max(self.vocab.n_classes, 2),
            warm=prefetch_warm(entry, pat),  # the PREVIOUS group's accuracy
        )
        # advisory chain: demand touches land in the current interval
        seen = blocks[blocks < self.cfg.n_blocks]
        self._chain_li[seen] = self._interval
        self._pending.blocks = seen
        if self.cfg.health is not None and self._health_state == "degraded":
            if self._backoff_left > 0:
                # still inside the backoff window: the learned path must
                # not even be dispatched — this round takes the floor
                self._backoff_left -= 1
                self._pending.fallback = True
                return None
            self._health_state = "recovering"
            self._recovery_left = self.cfg.health.recovery_successes
        if len(fs) == 0:
            return None
        return EvalRequest(entry.params, fs, self._pending.n_active)

    def observe_finish(self, corr: np.ndarray | None, pred_cls: np.ndarray | None) -> Actions:
        """Fold the predictor's output into the policy engine; emit actions."""
        p = self._pending
        if p is None:
            raise RuntimeError("observe_finish() without observe_begin()")
        if p.fallback:
            self.n_fallbacks += 1
            return self._fallback_actions(p)
        counters = None
        prefetch = np.zeros(0, np.int64)
        accuracy = None
        if corr is not None and len(p.fs):
            accuracy = float(corr.mean())
            self.per_group.append(accuracy)
            self._corr_true += int(np.count_nonzero(corr))
            self._corr_n += len(corr)
            if p.entry.n_updates > 0:
                self._warm_true += int(np.count_nonzero(corr))
                self._warm_n += len(corr)
            self.n_predictions += len(p.fs)
            p.entry.last_acc = accuracy  # informs the NEXT group's gate
            # predicted classes -> raw deltas -> predicted pages
            pred_delta = self._decode_deltas(pred_cls)
            prev_page = self.stream.page_at(p.fs.t_index - 1).astype(np.int64)
            pred_pages = np.clip(prev_page + pred_delta, 0, self.cfg.n_pages - 1)
            if p.warm:
                self.freq_table.update(np.asarray(pred_pages, np.int64) // self.cfg.pages_per_block)
                # one dense export per batch: it feeds both the simulator's
                # `learned` eviction keys and the prefetch gate
                counters = self.freq_table.dense(self.cfg.n_blocks)
                mask = prefetch_mask(
                    counters, pred_pages, p.entry.last_acc,
                    self.cfg.n_blocks, self.cfg.capacity, self.cfg.pages_per_block,
                )
                prefetch = np.flatnonzero(mask)
                self._chain_li[prefetch] = self._interval  # staged = touched
        if (
            self.cfg.health is not None
            and self._health_state == "recovering"
            and corr is not None
        ):
            self._recovery_left -= 1
            if self._recovery_left <= 0:
                self._health_state = "healthy"
                self._backoff = 0
                self.n_recoveries += 1
        return Actions(
            prefetch_blocks=prefetch,
            pre_evict_blocks=self._pre_evict(counters),
            counters=counters,
            pattern=p.pat,
            accuracy=accuracy,
            n_samples=len(p.fs),
            warm=p.warm,
            health=self._health_state,
        )

    def feedback_begin(self, outcomes: Outcomes) -> TrainRequest | None:
        """Advance the flush/chain intervals; stage the fine-tune dispatch
        (None when the batch had no samples — bookkeeping still happens)."""
        p = self._pending
        if p is None:
            raise RuntimeError("feedback() without a pending observe()")
        raw = int(outcomes.fault_count)
        if raw < self._fault_raw:  # consumer switch: its clock restarted at 0
            self._fault_base += self._fault_raw
        self._fault_raw = raw
        interval_now = (self._fault_base + raw) // INTERVAL_FAULTS
        if interval_now > self._flush_interval:
            # frequency table flush cadence (every 3 fault-intervals)
            self.freq_table.on_intervals(interval_now - self._flush_interval)
            self._flush_interval = interval_now
        self._interval = max(self._interval, interval_now)
        if p.fallback or len(p.fs) == 0:
            # fallback rounds skip the fine-tune (the learned path never
            # saw this batch's predictions); the clocks above still advance
            self._pending = None
            return None
        if self.cfg.use_lucir:
            self.table.snapshot_prev(p.pat)
            p.entry = self.table.get(p.pat)
        in_et = None
        if self.cfg.use_thrash_term and outcomes.was_evicted is not None:
            we = np.asarray(outcomes.was_evicted)
            if len(we) != p.n:
                raise ValueError(
                    f"Outcomes.was_evicted must have one entry per access of the "
                    f"last observed batch (expected {p.n}, got {len(we)})"
                )
            in_et = we[p.fs.t_index - p.g0]
        return TrainRequest(p.entry, p.fs, p.n_active, in_et, self.cfg.use_lucir)

    def feedback_finish(self, entry: Entry) -> None:
        """Publish the fine-tuned entry back to the pattern table."""
        p = self._pending
        if p is None:
            raise RuntimeError("feedback_finish() without feedback_begin()")
        self.table.put(p.pat, entry)
        self._pending = None

    # -- degraded-mode health machine ----------------------------------------

    def guard_dispatch(self, req: EvalRequest | None) -> bool:
        """Pre-dispatch health check: ``False`` means the learned path must
        not run this round.  Non-finite entry params (a poisoned model) are
        quarantined by re-initializing the pattern's slot, so a later retry
        dispatches a fresh model instead of the same NaNs forever."""
        if self.cfg.health is None or req is None:
            return True
        if self.cfg.health.check_params and not _tree_finite(req.params):
            p = self._pending
            if p is not None:
                slot = self.table.slot_of(p.pat)
                self.table.slots[slot] = Entry(params=self.table.init_fn(slot))
            self.note_fault(ValueError("non-finite model params"))
            return False
        return True

    def check_result(self, corr, pred_cls, *, elapsed_s: float = 0.0) -> bool:
        """Post-dispatch validation: a non-finite predictor output or a
        latency-budget overrun demotes the learned path and sends THIS
        batch to the fallback floor."""
        if self.cfg.health is None:
            return True
        if corr is not None:
            for arr in (np.asarray(corr), np.asarray(pred_cls)):
                if np.issubdtype(arr.dtype, np.floating) and not np.all(np.isfinite(arr)):
                    self.note_fault(ValueError("non-finite predictor output"))
                    return False
        budget = self.cfg.health.latency_budget_ms
        if budget > 0 and elapsed_s * 1e3 > budget:
            self.note_fault(
                TimeoutError(f"observe dispatch took {elapsed_s * 1e3:.2f}ms > {budget}ms budget")
            )
            return False
        return True

    def note_fault(self, exc: BaseException | str) -> None:
        """Record a learned-path failure (dispatch exception, poisoned
        output, budget overrun) and demote: the current round falls back
        and the next ``backoff`` rounds skip the learned path entirely.
        Each relapse doubles the backoff up to ``backoff_max``; a full
        recovery resets it.  Lockstep drivers that own the dispatch
        (:class:`TenantMux`) call this when their batched call fails."""
        if self.cfg.health is None:
            return
        self.n_health_faults += 1
        self.last_health_error = str(exc)
        self._backoff = (
            self.cfg.health.backoff_initial
            if self._backoff == 0
            else min(self._backoff * 2, self.cfg.health.backoff_max)
        )
        self._backoff_left = self._backoff
        self._health_state = "degraded"
        self._recovery_left = 0
        if self._pending is not None:
            self._pending.fallback = True

    def _fallback_actions(self, p: _Pending) -> Actions:
        """The rule-based floor (the paper's baseline): tree-prefetch the
        observed blocks' buddy siblings, pre-evict pure-LRU by chain
        interval.  No learned component is touched — this is what a
        degraded manager serves until the learned path re-promotes."""
        blocks = p.blocks if p.blocks is not None else np.zeros(0, np.int64)
        buddies = np.unique(np.asarray(blocks, np.int64) ^ 1)  # 2-block tree nodes
        buddies = buddies[(buddies >= 0) & (buddies < self.cfg.n_blocks)]
        prefetch = buddies[: max(self.cfg.capacity // 2, 1)]
        self._chain_li[prefetch] = self._interval  # staged = touched
        return Actions(
            prefetch_blocks=prefetch,
            pre_evict_blocks=self._lru_pre_evict(),
            counters=None,
            pattern=p.pat,
            accuracy=None,
            n_samples=len(p.fs),
            warm=False,
            health=self._health_state,
            fallback=True,
        )

    def _lru_pre_evict(self) -> np.ndarray:
        """Pure-LRU advisory victims (oldest chain interval first) — the
        fallback ranking needs no frequency table."""
        seen = np.flatnonzero(self._chain_li >= 0)
        budget = min(max(int(seen.size) - self.cfg.capacity, 0), self.cfg.pre_evict_budget)
        if budget == 0:
            return np.zeros(0, np.int64)
        order = np.argsort(self._chain_li[seen], kind="stable")
        return seen[order[:budget]]

    # -- snapshot / restore --------------------------------------------------

    def state(self, *, include_freq_table: bool = True) -> dict:
        """Host-side snapshot of everything the online pipeline learned:
        model table, classifier, frequency table, delta vocabulary, the
        bounded feature stream, accuracy counters, fault clock, reclass
        hysteresis and health state.  Versioned and config-signed; restore
        into an identically-configured manager reproduces bit-identical
        ``Actions`` (pinned by goldens + hypothesis).

        Raises with a pending round: snapshots happen at batch boundaries
        only (after ``feedback``), where the protocol state is closed.
        ``include_freq_table=False`` is for :class:`TenantMux`'s shared
        table, which the mux serializes once instead of per tenant."""
        if self._pending is not None:
            raise RuntimeError("cannot snapshot with a pending observe(); close the round first")
        s = self.stream
        return {
            "version": STATE_VERSION,
            "cfg_sig": _cfg_signature(self.cfg),
            "table": {
                "n_slots": self.table.n_slots,
                "hits": self.table.hits,
                "misses": self.table.misses,
                "slots": {
                    slot: {
                        "params": tree_to_host(e.params),
                        "prev_params": tree_to_host(e.prev_params),
                        "opt_state": tree_to_host(e.opt_state),
                        "step": int(e.step),
                        "n_updates": int(e.n_updates),
                        "last_acc": float(e.last_acc),
                    }
                    for slot, e in self.table.slots.items()
                },
            },
            "classifier": pickle.dumps(self.classifier),
            "freq_table": pickle.dumps(self.freq_table) if include_freq_table else None,
            "vocab": {"capacity": self.vocab.capacity, "table": dict(self.vocab.table)},
            "stream": {"off": s._off, "rows": {f: getattr(s, f).copy() for f in _STREAM_FIELDS}},
            "accuracy": {
                "per_group": list(self.per_group),
                "corr_true": self._corr_true,
                "corr_n": self._corr_n,
                "warm_true": self._warm_true,
                "warm_n": self._warm_n,
                "n_predictions": self.n_predictions,
            },
            "decode": {"table": self._decode.copy(), "upto": self._decoded_upto},
            "clock": {
                "flush_interval": self._flush_interval,
                "interval": self._interval,
                "fault_base": self._fault_base,
                "fault_raw": self._fault_raw,
                "chain_li": self._chain_li.copy(),
            },
            "reclass": {
                "active_pat": self._active_pat,
                "cand_pat": self._cand_pat,
                "cand_streak": self._cand_streak,
                "last_reclass": self._last_reclass,
                "obs_accesses": self._obs_accesses,
                "last_reclass_obs": self._last_reclass_obs,
                "n_reclassifications": self.n_reclassifications,
                "n_pattern_switches": self.n_pattern_switches,
            },
            "health": {
                "state": self._health_state,
                "backoff": self._backoff,
                "backoff_left": self._backoff_left,
                "recovery_left": self._recovery_left,
                "n_health_faults": self.n_health_faults,
                "n_fallbacks": self.n_fallbacks,
                "n_recoveries": self.n_recoveries,
                "last_health_error": self.last_health_error,
            },
        }

    def restore(self, state: dict) -> None:
        """Inverse of :meth:`state` — validates the schema version and the
        config signature, then overwrites every learned component."""
        if state.get("version") != STATE_VERSION:
            raise ValueError(
                f"snapshot state version {state.get('version')!r} != supported {STATE_VERSION}"
            )
        if state.get("cfg_sig") != _cfg_signature(self.cfg):
            raise ValueError(
                "snapshot was taken under a different ManagerConfig; "
                "restore requires an identically-configured manager"
            )
        if self._pending is not None:
            raise RuntimeError("cannot restore over a pending observe()")
        t = state["table"]
        self.table.n_slots = t["n_slots"]
        self.table.hits, self.table.misses = t["hits"], t["misses"]
        self.table.slots = {
            slot: Entry(
                params=e["params"],
                prev_params=e["prev_params"],
                opt_state=e["opt_state"],
                step=e["step"],
                n_updates=e["n_updates"],
                last_acc=e["last_acc"],
            )
            for slot, e in t["slots"].items()
        }
        self.classifier = pickle.loads(state["classifier"])
        if state["freq_table"] is not None:
            self.freq_table = pickle.loads(state["freq_table"])
        self.vocab.capacity = state["vocab"]["capacity"]
        self.vocab.table = dict(state["vocab"]["table"])
        st = state["stream"]
        self.stream.vocab = self.vocab  # the stream encodes through OUR vocab
        self.stream._off = st["off"]
        for f in _STREAM_FIELDS:
            setattr(self.stream, f, st["rows"][f].copy())
        acc = state["accuracy"]
        self.per_group = list(acc["per_group"])
        self._corr_true, self._corr_n = acc["corr_true"], acc["corr_n"]
        self._warm_true, self._warm_n = acc["warm_true"], acc["warm_n"]
        self.n_predictions = acc["n_predictions"]
        dec = state["decode"]
        self._decode = dec["table"].copy()
        self._decoded_upto = dec["upto"]
        clk = state["clock"]
        self._flush_interval = clk["flush_interval"]
        self._interval = clk["interval"]
        self._fault_base, self._fault_raw = clk["fault_base"], clk["fault_raw"]
        self._chain_li = clk["chain_li"].copy()
        rc = state["reclass"]
        self._active_pat, self._cand_pat = rc["active_pat"], rc["cand_pat"]
        self._cand_streak = rc["cand_streak"]
        self._last_reclass, self._obs_accesses = rc["last_reclass"], rc["obs_accesses"]
        self._last_reclass_obs = rc["last_reclass_obs"]
        self.n_reclassifications = rc["n_reclassifications"]
        self.n_pattern_switches = rc["n_pattern_switches"]
        h = state["health"]
        self._health_state = h["state"]
        self._backoff, self._backoff_left = h["backoff"], h["backoff_left"]
        self._recovery_left = h["recovery_left"]
        self.n_health_faults = h["n_health_faults"]
        self.n_fallbacks = h["n_fallbacks"]
        self.n_recoveries = h["n_recoveries"]
        self.last_health_error = h["last_health_error"]

    # -- internals -----------------------------------------------------------

    def _reclassify(self, blocks: np.ndarray, kernels: np.ndarray) -> int:
        """Periodic re-classification with hysteresis (cfg.reclass_interval
        faults per window; a challenger needs cfg.reclass_hysteresis
        consecutive agreeing windows to dethrone the active pattern).

        The window clock is the consumer-reported fault count, with the
        OBSERVED-ACCESS count as a fallback trigger: a feedback-less
        consumer (the serve sidecar's auto-close mode reports no faults)
        must still re-classify, and since every fault is an access the
        fallback can only make windows more frequent, never rarer."""
        clock = self._fault_base + self._fault_raw
        self._obs_accesses += len(blocks)
        due = (clock - self._last_reclass >= self.cfg.reclass_interval
               or self._obs_accesses - self._last_reclass_obs >= self.cfg.reclass_interval)
        if self._active_pat is None:  # first observation seeds the pattern
            self._active_pat = self.classifier.classify(blocks, kernels)
            self._last_reclass = clock
            self._last_reclass_obs = self._obs_accesses
            self.n_reclassifications += 1
        elif due:
            proposal = self.classifier.classify(blocks, kernels)
            self._last_reclass = clock
            self._last_reclass_obs = self._obs_accesses
            self.n_reclassifications += 1
            if proposal == self._active_pat:
                self._cand_pat, self._cand_streak = None, 0
            else:
                if proposal == self._cand_pat:
                    self._cand_streak += 1
                else:
                    self._cand_pat, self._cand_streak = proposal, 1
                if self._cand_streak >= max(self.cfg.reclass_hysteresis, 1):
                    # the displaced pattern's model entry stays warm in the
                    # table — flipping back later resumes where it left off
                    self._active_pat = proposal
                    self._cand_pat, self._cand_streak = None, 0
                    self.n_pattern_switches += 1
        return self._active_pat

    def _decode_deltas(self, pred_cls: np.ndarray) -> np.ndarray:
        """Vectorized class-id -> raw-delta decode (the grown-so-far slice
        of the vocabulary; unknown ids decode to delta 0, like the dict
        lookup's default)."""
        if self.vocab.n_classes > self._decoded_upto:
            for delta, cls in self.vocab.table.items():
                if cls >= self._decoded_upto:
                    self._decode[cls] = delta
            self._decoded_upto = self.vocab.n_classes
        return self._decode[np.asarray(pred_cls, np.int64)]

    def _pre_evict(self, counters: np.ndarray | None) -> np.ndarray:
        """Advisory victim ranking: oldest chain partition first, lowest
        prediction frequency inside it (the `learned` victim key), budgeted
        to the blocks the working set holds over capacity."""
        seen = np.flatnonzero(self._chain_li >= 0)
        budget = min(max(int(seen.size) - self.cfg.capacity, 0), self.cfg.pre_evict_budget)
        if budget == 0:
            return np.zeros(0, np.int64)
        dense = counters if counters is not None else self.freq_table.dense(self.cfg.n_blocks)
        age = np.clip(self._interval - self._chain_li[seen], 0, 2)
        key = (-age << 20) + dense[seen]  # lexicographic (-age, freq), smallest first
        order = np.argsort(key, kind="stable")
        return seen[order[:budget]]


# --- builtin component registrations ----------------------------------------
# The paper's classifier + frequency table enter the SAME registry a user
# plugin does. Guarded for idempotence under importlib.reload.
if "dfa" not in _registry.classifier_names():
    _registry.register_classifier("dfa", PatternClassifier)
if "setassoc" not in _registry.freq_table_names():
    _registry.register_freq_table("setassoc", PredictionFrequencyTable)
if "setassoc_pallas" not in _registry.freq_table_names():
    # the REPRO_SIM_KERNELS freq-table engine: same 1024x16 semantics, hot
    # methods routed through repro.kernels.freq_table (bit-identical — both
    # tables are pinned against the loop oracle). NOTE: ``freq_table`` is
    # part of _cfg_signature, so snapshots taken on one engine restore only
    # onto the same engine.
    _registry.register_freq_table("setassoc_pallas", PallasPredictionFrequencyTable)
