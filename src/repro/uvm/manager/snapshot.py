"""Versioned, content-hashed snapshots of streaming-manager state.

A long-lived ``cli serve`` (or KV-offload) process carries state that is
expensive to lose: the per-pattern model params fine-tuned online, the
grown delta vocabulary, the frequency table, the classifier's DFA memory,
the fault clock.  :class:`SnapshotStore` persists the host-side state dict
:meth:`OversubscriptionManager.state` / :meth:`TenantMux.state` produce,
with the same crash-safety idiom as :class:`repro.checkpoint.Checkpointer`:

* everything for one step lands in ``snap_<step>.tmp/`` first and the
  directory is RENAMED to its final name only after all writes complete —
  a reader never observes a partial snapshot, a killed writer leaves only
  a ``.tmp`` turd that :meth:`clean_tmp` sweeps;
* the pickled payload is content-hashed (sha256, recorded in
  ``manifest.json``) and the digest is verified on :meth:`restore`, so a
  truncated or corrupted blob fails loudly instead of restoring garbage;
* ``keep`` bounds disk: older snapshots are garbage-collected after each
  successful save.

The payload itself is an opaque pickle — the manager owns its schema and
stamps it with :data:`STATE_VERSION` (validated by ``restore()`` on the
manager side) plus a config signature so a snapshot never restores into a
differently-shaped manager.
"""
from __future__ import annotations

import hashlib
import json
import pickle
import shutil
from pathlib import Path

import numpy as np

#: schema version of the manager/mux state dicts (bump on layout change)
STATE_VERSION = 1

#: on-disk snapshot container format (manifest layout)
SNAPSHOT_FORMAT = 1

_MANIFEST = "manifest.json"
_PAYLOAD = "state.pkl"


def tree_to_host(tree):
    """Deep-copy a jax pytree to host numpy (device buffers must not leak
    into a pickle: they deserialize as plain arrays anyway, and copying at
    save time decouples the snapshot from later in-place updates)."""
    if tree is None:
        return None
    import jax

    return jax.tree.map(lambda a: np.array(a), tree)


class SnapshotStore:
    """Atomic, hashed, GC'd snapshots under one directory.

    Layout per step (``Checkpointer``'s tmp-then-rename idiom)::

        <dir>/snap_000000042.tmp/     # staging (invisible to readers)
            state.pkl                 # pickled payload
            manifest.json             # {"format", "step", "sha256", "bytes", "extra"}
        <dir>/snap_000000042/         # atomic rename AFTER all writes land
    """

    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self.dir.mkdir(parents=True, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, state: dict, extra: dict | None = None) -> Path:
        """Persist one snapshot; returns the final directory."""
        blob = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)
        final = self.dir / f"snap_{step:09d}"
        tmp = self.dir / f"snap_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        (tmp / _PAYLOAD).write_bytes(blob)
        manifest = {
            "format": SNAPSHOT_FORMAT,
            "step": int(step),
            "sha256": hashlib.sha256(blob).hexdigest(),
            "bytes": len(blob),
            "extra": extra or {},
        }
        (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1, sort_keys=True))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"snap_{s:09d}", ignore_errors=True)

    def clean_tmp(self) -> list[Path]:
        """Sweep staging turds a killed writer left behind."""
        dead = sorted(self.dir.glob("snap_*.tmp"))
        for d in dead:
            shutil.rmtree(d, ignore_errors=True)
        return dead

    # -- read ----------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for d in self.dir.glob("snap_*"):
            if d.suffix == ".tmp" or not d.is_dir():
                continue
            try:
                out.append(int(d.name.split("_", 1)[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None) -> tuple[int, dict, dict]:
        """Load one snapshot (the latest by default), verifying the content
        hash; returns ``(step, state, extra)``."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no snapshots under {self.dir}")
        d = self.dir / f"snap_{step:09d}"
        manifest = json.loads((d / _MANIFEST).read_text())
        if manifest.get("format") != SNAPSHOT_FORMAT:
            raise ValueError(
                f"snapshot format {manifest.get('format')!r} != supported {SNAPSHOT_FORMAT}"
            )
        blob = (d / _PAYLOAD).read_bytes()
        digest = hashlib.sha256(blob).hexdigest()
        if digest != manifest["sha256"]:
            raise ValueError(
                f"snapshot {d.name} failed content-hash verification "
                f"(manifest {manifest['sha256'][:12]}…, payload {digest[:12]}…)"
            )
        return step, pickle.loads(blob), manifest.get("extra", {})
