"""`TenantMux` — multi-tenant streaming oversubscription management.

The paper's headline accuracy result covers *multiple concurrent GPGPU
workloads* (Section V-F: +10.2% top-1 on average, up to +30.2%): when
tenants share a GPU, one classifier->predictor pipeline over the MERGED
fault stream blends pattern classes inside every observation window and
the per-workload delta structure drowns.  The fix is per-workload
specialization: demultiplex the tenant-tagged fault stream into one
:class:`~repro.uvm.manager.OversubscriptionManager` per tenant, each with
its own classifier state, delta vocabulary, window history and per-pattern
model table, while the device-wide artifacts (the dense prediction
frequency export the `learned` eviction policy reads, the staged prefetch
set) are combined across tenants.

Protocol — the manager's stepwise rounds, lifted to a tagged stream::

    mux = TenantMux(cfg, tenants=("A", "B"))
    out = mux.observe(FaultBatch(page=pages, tenant=tags))   # demux -> per-tenant pipelines
    ... stage out.prefetch_blocks / out.counters ...
    mux.feedback(Outcomes(was_evicted=..., fault_count=...)) # split back per tenant

* ``observe`` splits the batch by tag (within-tenant order preserved),
  runs each present tenant's ``observe_begin``, batches every predictor
  dispatch through ONE ``Trainer.evaluate_many`` call, and combines the
  per-tenant actions into a :class:`MuxActions`.
* ``feedback`` splits ``was_evicted`` back along the same partition and
  forwards the GLOBAL fault clock to every tenant observed this round
  (each manager's 3-interval flush cadence advances on the device-wide
  far-fault count; absent tenants catch up on their next observation).
  ``feedback(..., tenant=k)`` instead closes tenant ``k``'s pending batch
  explicitly — the ``cli serve`` sidecar's per-line pairing.
* the staged halves (``observe_begin/observe_finish``,
  ``feedback_begin/feedback_finish``) return per-tenant request lists so
  lockstep drivers (``runtime.run_ours_many``) can batch model dispatches
  across lanes AND tenants in one vmapped call.

Frequency-table topology is configurable: ``shared_freq_table=False``
(default) gives every tenant an ISOLATED table — with it, demuxing a
:func:`repro.uvm.trace.concurrent` merge is exactly equivalent to running
each tenant's stream through its own standalone manager (property-pinned
in tests/test_multi.py); ``shared_freq_table=True`` makes all tenants
update ONE table (the paper's single 18KB SRAM budget, Section IV-D).
Tenants always share one :class:`~repro.core.incremental.Trainer` (jit
caches), never model state.
"""
from __future__ import annotations

import dataclasses
import pickle

import numpy as np

from repro.core.incremental import Trainer
from repro.core.model_table import ModelTable
from repro.uvm import registry as _registry
from repro.uvm.manager.core import (
    INTERVAL_FAULTS,
    Actions,
    EvalRequest,
    FaultBatch,
    ManagerConfig,
    Outcomes,
    OversubscriptionManager,
    TrainRequest,
    _cfg_signature,
)
from repro.uvm.manager.snapshot import STATE_VERSION

_UNSET = object()


@dataclasses.dataclass
class MuxActions:
    """One round's combined output: the device-wide artifacts a simulator
    (or any residency engine) stages, plus every tenant's own
    :class:`~repro.uvm.manager.Actions` for per-workload consumers.

    ``counters`` is the combined dense prediction-frequency export
    (elementwise max across tenant tables — tenants occupy disjoint page
    ranges, so the max is the union; one table serves directly when
    shared); ``None`` when no tenant's prefetch gate opened this round,
    matching the single-manager cadence (a stale export stays staged).
    ``pre_evict_blocks`` round-robins the tenants' advisory rankings so no
    tenant's victims dominate the head.

    ``budgets`` is the QoS capacity partition this round was observed
    under (tenant -> blocks), ``None`` on muxes without a budget
    controller — consumers that want the eviction-tier artifact itself
    call :meth:`TenantMux.evict_pref` with their residency mask."""

    per_tenant: dict
    prefetch_blocks: np.ndarray
    counters: np.ndarray | None
    pre_evict_blocks: np.ndarray
    budgets: dict | None = None

    @property
    def patterns(self) -> dict:
        return {k: a.pattern for k, a in self.per_tenant.items()}


def _stable_unique(parts: list[np.ndarray]) -> np.ndarray:
    """Concatenate + dedup preserving first-appearance order."""
    if not parts:
        return np.zeros(0, np.int64)
    cat = np.concatenate([np.asarray(p, np.int64) for p in parts])
    _, first = np.unique(cat, return_index=True)
    return cat[np.sort(first)]


def _round_robin(parts: list[np.ndarray]) -> np.ndarray:
    """Interleave the tenants' rankings fairly (worst-first per tenant)."""
    parts = [np.asarray(p, np.int64) for p in parts if len(p)]
    if not parts:
        return np.zeros(0, np.int64)
    width = max(len(p) for p in parts)
    out = [p[i] for i in range(width) for p in parts if i < len(p)]
    return _stable_unique([np.asarray(out, np.int64)])


class _SharedFreqTableView:
    """The shared frequency table as ONE tenant manager sees it: reads and
    updates pass through, but ``on_intervals`` is a no-op — the flush
    cadence is owned by the mux.  (Every manager computes the same
    device-interval delta from the global fault clock; letting each apply
    it would flush the one table N_tenants times per interval.)"""

    def __init__(self, table):
        self._table = table

    def update(self, blocks):
        self._table.update(blocks)

    def lookup(self, block):
        return self._table.lookup(block)

    def lookup_many(self, blocks):
        return self._table.lookup_many(blocks)

    def dense(self, n_blocks):
        return self._table.dense(n_blocks)

    def on_intervals(self, n):  # mux-owned (see TenantMux._advance_shared_clock)
        pass

    @property
    def tags(self):
        return self._table.tags

    @property
    def counters(self):
        return self._table.counters

    @property
    def flushes(self):
        return self._table.flushes


class TenantMux:
    """Demultiplex a tenant-tagged fault stream into per-tenant
    classifier->predictor pipelines (module docs have the protocol).

    ``tenants`` pre-declares the tenant keys (any hashables that survive a
    numpy equality test against the tag array — ints for trace merges,
    strings for the serve sidecar).  ``auto_create=True`` (the default)
    admits unseen tags by building their manager on first contact — the
    endless-stream sidecar mode; pass ``False`` to make an unknown tag a
    hard ``KeyError`` (the trace drivers, where the tenant set is known).

    ``tables`` seeds each tenant's per-pattern model table: a dict keyed
    by tenant, or ONE Section V-A pretrained master that every tenant
    clones (fine-tuning mutates entries — tenants must not share them).

    ``qos`` attaches a :class:`repro.uvm.qos.BudgetController`: every
    observed batch claims its tenant's blocks (first-toucher ownership),
    every feedback round feeds the tenant's thrash rate into the elastic
    rebalance, and :meth:`evict_pref` compiles the current budgets into
    the simulator's leading victim key.  ``None`` (default) = today's
    shared pool, bit-identical.
    """

    def __init__(
        self,
        cfg: ManagerConfig,
        tenants=(),
        *,
        shared_freq_table: bool = False,
        auto_create: bool = True,
        tables: dict | ModelTable | None = None,
        trainer: Trainer | None = None,
        qos=None,
    ):
        self.cfg = cfg
        self.shared_freq_table = shared_freq_table
        self.auto_create = auto_create
        self._tables = tables
        self.trainer = trainer if trainer is not None else Trainer(cfg.predictor, cfg.train, cfg.kind)
        self._shared_freq = _registry.freq_table_factory(cfg.freq_table)() if shared_freq_table else None
        self.qos = qos
        self.managers: dict = {}
        # released tenants' final stats, so departure doesn't erase them
        # from the run-level result views below
        self._departed: dict = {}
        self.per_group: list[float] = []  # batch accuracies in dispatch order
        self._round: list[tuple] | None = None  # [(tenant, positions, n)], last observe's split
        self._last_feedback: list[tuple] = []  # feedback_begin's pairs, for feedback_finish
        # mux-owned flush cadence for the SHARED table (managers hold
        # no-flush views); same rebase rule as the per-manager clock
        self._fault_base = 0
        self._fault_raw = 0
        self._flush_interval = 0
        for t in tenants:
            self._create(t)

    # -- tenant admission ----------------------------------------------------

    def _create(self, key) -> OversubscriptionManager:
        table = self._tables
        if isinstance(table, dict):
            table = table.get(key)
        elif isinstance(table, ModelTable):
            table = table.clone()  # one warm master, private per-tenant copies
        mgr = OversubscriptionManager(
            self.cfg, table=table, trainer=self.trainer,
            freq_table=_SharedFreqTableView(self._shared_freq) if self._shared_freq is not None else None,
        )
        self.managers[key] = mgr
        return mgr

    def tenant(self, key) -> OversubscriptionManager:
        """The tenant's manager (admitting the key if ``auto_create``)."""
        if key not in self.managers:
            if not self.auto_create:
                raise KeyError(f"unknown tenant {key!r}; declared: {list(self.managers)}")
            self._create(key)
        return self.managers[key]

    def release(self, key) -> None:
        """Retire a departed tenant: drop its manager so its (stale)
        frequency counters leave :meth:`_combined_dense`'s per-tenant max,
        and return its QoS claim so budgets rebalance to live tenants.
        A churned trace's early-leaving tenant would otherwise hold rows
        in the combined dense export — and a budget slice — forever.
        Idempotent; a re-appearing tag is re-admitted fresh.  The departed
        tenant's accuracy/model counts are retained so the run-level
        result views still cover it."""
        m = self.managers.pop(key, None)
        if m is not None:
            self._departed[key] = {
                "corr": (m._corr_true, m._corr_n), "warm": (m._warm_true, m._warm_n),
                "top1": m.top1, "n_predictions": m.n_predictions,
                "n_classes": m.n_classes, "n_models": m.n_models,
            }
        if self.qos is not None:
            self.qos.release(key)
        if self._round is not None:
            self._round = [r for r in self._round if r[0] != key] or None

    def _split(self, batch: FaultBatch) -> list[tuple]:
        """Partition one batch by tenant tag, first-appearance order,
        within-tenant access order preserved. Untagged batches route to
        the ``'default'`` tenant (the single-workload degenerate case)."""
        tags = batch.tenant
        if tags is None or np.ndim(tags) == 0:
            key = "default" if tags is None else (tags.item() if hasattr(tags, "item") else tags)
            return [(key, np.arange(len(batch)), batch)]
        keys, first = np.unique(tags, return_index=True)
        out = []
        for k in keys[np.argsort(first)]:
            idx = np.flatnonzero(tags == k)
            out.append((
                k.item() if hasattr(k, "item") else k,
                idx,
                FaultBatch(batch.page[idx], batch.pc[idx], batch.tb[idx], batch.kernel[idx]),
            ))
        return out

    # -- streaming protocol --------------------------------------------------

    def observe(self, batch: FaultBatch) -> MuxActions:
        """One full round: demux, per-tenant classify, ONE batched predictor
        dispatch, combined actions.  With ``cfg.health`` set, each tenant's
        pre-dispatch guard runs first (a tenant with poisoned params falls
        back alone) and a batched-dispatch failure demotes every tenant
        that dispatched — they all fall back this round."""
        pairs, evals = self.observe_requests(batch)
        out: list | BaseException = []
        if evals:
            try:
                out = self.trainer.evaluate_many(
                    [r.params for _, r in evals], [r.fs for _, r in evals],
                    [r.n_active for _, r in evals],
                )
            except Exception as exc:  # noqa: BLE001 — degraded mode absorbs anything
                out = exc
        return self.observe_apply(pairs, evals, out)

    def observe_requests(self, batch: FaultBatch):
        """The dispatch-staging half of :meth:`observe`: demux + classify
        via :meth:`observe_begin`, then run each tenant's pre-dispatch
        health guard.  Returns ``(pairs, evals)`` — all ``(tenant,
        request)`` pairs plus the guarded subset that should actually hit
        the trainer.  A lockstep server batches many muxes' ``evals``
        through ONE ``evaluate_many`` and hands each mux its result slice
        (or the shared exception) back via :meth:`observe_apply`."""
        pairs = self.observe_begin(batch)
        evals = [(k, r) for k, r in pairs if r is not None and self.managers[k].guard_dispatch(r)]
        return pairs, evals

    def observe_apply(self, pairs, evals, out) -> MuxActions:
        """The result-folding half of :meth:`observe`.  ``out`` is
        ``evaluate_many``'s result list aligned with ``evals`` — or the
        exception it raised, which (with ``cfg.health`` set) demotes every
        tenant that dispatched; they all fall back this round."""
        dispatched = {id(r) for _, r in evals}
        if isinstance(out, BaseException):
            if self.cfg.health is None:
                raise out
            for k, _r in evals:
                self.managers[k].note_fault(out)
            out = [None] * len(evals)
        else:
            out = [
                res if self.managers[k].check_result(*res) else None
                for (k, _r), res in zip(evals, out)
            ]
        results = iter(out)
        return self.observe_finish(
            [next(results) if (r is not None and id(r) in dispatched) else None for _, r in pairs]
        )

    def feedback(self, outcomes: Outcomes, *, tenant=_UNSET) -> None:
        """Close the last round (or one tenant's pending batch): split the
        outcome report, advance every observed tenant's fault clock, batch
        the fine-tune dispatches through ONE ``train_group_many``.  With
        ``cfg.health`` set, a batched train failure demotes every tenant
        whose fine-tune was staged (their entry updates are lost; the
        rounds still close)."""
        pairs, treqs = self.feedback_requests(outcomes, tenant=tenant)
        exc = None
        # dispatch even with zero staged trains: a chaos-wrapped trainer
        # draws its RNG per CALL, so skipping the empty call would shift
        # every later injection site of a seeded schedule
        try:
            self.trainer.train_group_many(
                [r.entry for _, r in treqs], [r.fs for _, r in treqs],
                [r.n_active for _, r in treqs],
                in_et_list=[r.in_et for _, r in treqs], use_lucir=self.cfg.use_lucir,
            )
        except Exception as e:  # noqa: BLE001
            exc = e
        self.feedback_apply(pairs, treqs, exc)

    def feedback_requests(self, outcomes: Outcomes, *, tenant=_UNSET):
        """The dispatch-staging half of :meth:`feedback`: split the outcome
        report and stage each tenant's fine-tune.  Returns ``(pairs,
        treqs)`` — all ``(tenant, request)`` pairs plus the non-``None``
        subset to hand to ``train_group_many`` (requests carry
        ``use_lucir``; a lockstep server batches them across muxes)."""
        pairs = self.feedback_begin(outcomes, tenant=tenant)
        treqs = [(k, r) for k, r in pairs if r is not None]
        return pairs, treqs

    def feedback_apply(self, pairs, treqs, exc) -> None:
        """The result-folding half of :meth:`feedback`.  ``exc`` is the
        exception ``train_group_many`` raised (entries are updated in
        place, so success carries no payload); with ``cfg.health`` set it
        demotes every tenant whose fine-tune was staged."""
        if exc is not None:
            if self.cfg.health is None:
                raise exc
            for k, _r in treqs:
                self.managers[k].note_fault(exc)
                self.managers[k]._pending = None
            self.feedback_finish([None] * len(pairs))
            return
        self.feedback_finish([r.entry if r is not None else None for _, r in pairs])

    # -- staged halves (lockstep drivers batch across lanes AND tenants) -----

    def observe_begin(self, batch: FaultBatch) -> list[tuple[object, EvalRequest | None]]:
        """Demux + per-tenant ingest/classify; returns ``(tenant, request)``
        pairs in first-appearance order (request ``None`` when that
        tenant's slice yields no window samples)."""
        batch = batch if isinstance(batch, FaultBatch) else FaultBatch(np.asarray(batch))
        split = self._split(batch)
        self._round = [(k, idx, len(idx)) for k, idx, _ in split]
        if self.qos is not None:
            for k, _idx, sub in split:
                self.qos.observe_blocks(
                    k, np.unique(np.asarray(sub.page, np.int64) // self.cfg.pages_per_block))
        return [(k, self.tenant(k).observe_begin(sub)) for k, idx, sub in split]

    def observe_finish(self, results: list) -> MuxActions:
        """Fold each tenant's predictor output; combine the device-wide
        artifacts. ``results`` aligns with ``observe_begin``'s pairs —
        ``(corr, pred_cls)`` per dispatched tenant, ``None`` otherwise."""
        if self._round is None:
            raise RuntimeError("observe_finish() without observe_begin()")
        per_tenant: dict = {}
        for (k, _idx, _n), res in zip(self._round, results):
            corr, pred = res if res is not None else (None, None)
            actions = self.managers[k].observe_finish(corr, pred)
            per_tenant[k] = actions
            if actions.accuracy is not None:
                self.per_group.append(actions.accuracy)
        warm_any = any(a.counters is not None for a in per_tenant.values())
        counters = self._combined_dense() if warm_any else None
        return MuxActions(
            per_tenant=per_tenant,
            prefetch_blocks=_stable_unique([a.prefetch_blocks for a in per_tenant.values()]),
            counters=counters,
            pre_evict_blocks=_round_robin([a.pre_evict_blocks for a in per_tenant.values()]),
            budgets=dict(self.qos.budgets) if self.qos is not None else None,
        )

    def feedback_begin(self, outcomes: Outcomes, *, tenant=_UNSET) -> list[tuple[object, TrainRequest | None]]:
        """Split the outcome report along the last round's partition (or
        hand it whole to one tenant) and stage each fine-tune dispatch."""
        self._advance_shared_clock(outcomes)
        if tenant is not _UNSET:
            out = [(tenant, self.tenant(tenant).feedback_begin(outcomes))]
            # the tenant's slot in a pending round (if any) is now closed —
            # a later round-level feedback must not replay it
            if self._round is not None:
                self._round = [r for r in self._round if r[0] != tenant] or None
            if self.qos is not None:
                we1 = outcomes.was_evicted
                self.qos.observe_pressure(
                    tenant, float(np.mean(we1)) if we1 is not None and len(we1) else 0.0)
                self.qos.step()
            self._last_feedback = out
            return out
        if self._round is None:
            raise RuntimeError("feedback() without a pending observe() round")
        we = None if outcomes.was_evicted is None else np.asarray(outcomes.was_evicted)
        out = []
        for k, idx, n in self._round:
            sub = Outcomes(
                was_evicted=None if we is None else we[idx],
                fault_count=outcomes.fault_count,  # the GLOBAL device clock
            )
            # the tenant's thrash rate this round (its own slice of the
            # report) is the budget controller's pressure signal
            if self.qos is not None:
                sw = sub.was_evicted
                self.qos.observe_pressure(k, float(np.mean(sw)) if sw is not None and len(sw) else 0.0)
            out.append((k, self.managers[k].feedback_begin(sub)))
        if self.qos is not None:
            self.qos.step()
        self._round = None
        self._last_feedback = out
        return out

    def feedback_finish(self, entries: list) -> None:
        """Publish each tenant's fine-tuned entry (aligned with
        ``feedback_begin``'s pairs; ``None`` = nothing was staged)."""
        for (k, _r), entry in zip(self._last_feedback, entries):
            if entry is not None:
                self.managers[k].feedback_finish(entry)

    # -- snapshot / restore --------------------------------------------------

    def state(self) -> dict:
        """Host-side snapshot of the whole mux: the shared frequency table
        (serialized ONCE — per-tenant states skip it), the mux-owned flush
        clock, the dispatch-order accuracy log, and every tenant's manager
        state in admission order.  Snapshots happen at round boundaries:
        raises while an observe round or any tenant batch is pending."""
        if self._round is not None:
            raise RuntimeError("cannot snapshot mid-round; feedback() the open observe first")
        for k, m in self.managers.items():
            if m._pending is not None:
                raise RuntimeError(f"cannot snapshot: tenant {k!r} has a pending batch")
        return {
            "version": STATE_VERSION,
            "cfg_sig": _cfg_signature(self.cfg),
            "shared_freq_table": self.shared_freq_table,
            "shared_freq": pickle.dumps(self._shared_freq) if self._shared_freq is not None else None,
            "clock": (self._fault_base, self._fault_raw, self._flush_interval),
            "per_group": list(self.per_group),
            "qos": self.qos.state() if self.qos is not None else None,
            "departed": {k: dict(v) for k, v in self._departed.items()},
            "tenants": [
                (k, m.state(include_freq_table=self._shared_freq is None))
                for k, m in self.managers.items()
            ],
        }

    def restore(self, state: dict) -> None:
        """Inverse of :meth:`state`: rebuilds every tenant's manager (same
        config, same shared-table topology) and restores each one."""
        if state.get("version") != STATE_VERSION:
            raise ValueError(
                f"snapshot state version {state.get('version')!r} != supported {STATE_VERSION}"
            )
        if state.get("cfg_sig") != _cfg_signature(self.cfg):
            raise ValueError(
                "snapshot was taken under a different ManagerConfig; "
                "restore requires an identically-configured mux"
            )
        if state.get("shared_freq_table") != self.shared_freq_table:
            raise ValueError("snapshot and mux disagree on shared_freq_table topology")
        if state["shared_freq"] is not None:
            self._shared_freq = pickle.loads(state["shared_freq"])
        self._fault_base, self._fault_raw, self._flush_interval = state["clock"]
        self.per_group = list(state["per_group"])
        # pre-QoS snapshots carry no "qos" entry; a budgeted mux restores
        # its controller only when the snapshot recorded one
        if self.qos is not None and state.get("qos") is not None:
            self.qos.restore(state["qos"])
        self._departed = {k: dict(v) for k, v in state.get("departed", {}).items()}
        self.managers = {}
        for k, mstate in state["tenants"]:
            self._create(k).restore(mstate)  # views rebind to the restored shared table
        self._round = None
        self._last_feedback = []

    # -- combined artifacts --------------------------------------------------

    def _advance_shared_clock(self, outcomes: Outcomes) -> None:
        """Advance the mux-owned flush cadence of the SHARED table from the
        global fault clock (one flush check per device interval, however
        many tenants reported it); no-op with isolated tables, where each
        manager owns its table's cadence."""
        if self._shared_freq is None:
            return
        raw = int(outcomes.fault_count)
        if raw < self._fault_raw:  # consumer switch: its clock restarted at 0
            self._fault_base += self._fault_raw
        self._fault_raw = raw
        interval_now = (self._fault_base + raw) // INTERVAL_FAULTS
        if interval_now > self._flush_interval:
            self._shared_freq.on_intervals(interval_now - self._flush_interval)
            self._flush_interval = interval_now

    def _combined_dense(self) -> np.ndarray:
        """Device-wide dense frequency export: the shared table directly,
        or the elementwise max across the isolated per-tenant tables
        (disjoint tenant page ranges make the max a union; -1 = never).
        Only LIVE tenants contribute — :meth:`release` drops a departed
        tenant's manager, so its stale counters stop shadowing the max."""
        nb = self.cfg.n_blocks
        if self._shared_freq is not None:
            return self._shared_freq.dense(nb)
        if not self.managers:
            return np.full(nb, -1, np.int32)  # every tenant released
        return np.maximum.reduce([m.freq_table.dense(nb) for m in self.managers.values()])

    def evict_pref(self, resident) -> np.ndarray | None:
        """The QoS leading victim key for ``resident`` (the simulator's
        bool residency mask) — ``None`` without a budget controller, which
        keeps budget-free drivers on the exact pre-QoS compiled path."""
        return None if self.qos is None else self.qos.evict_pref(resident)

    # -- result views (the shapes LearnedRunResult aggregates) ---------------

    @property
    def top1(self) -> float:
        t = sum(m._corr_true for m in self.managers.values())
        t += sum(d["corr"][0] for d in self._departed.values())
        n = sum(m._corr_n for m in self.managers.values())
        n += sum(d["corr"][1] for d in self._departed.values())
        return t / n if n else 0.0

    @property
    def warm_top1(self) -> float:
        t = sum(m._warm_true for m in self.managers.values())
        t += sum(d["warm"][0] for d in self._departed.values())
        n = sum(m._warm_n for m in self.managers.values())
        n += sum(d["warm"][1] for d in self._departed.values())
        return t / n if n else self.top1

    @property
    def n_predictions(self) -> int:
        return sum(m.n_predictions for m in self.managers.values()) + \
            sum(d["n_predictions"] for d in self._departed.values())

    @property
    def n_classes(self) -> int:
        return sum(m.n_classes for m in self.managers.values()) + \
            sum(d["n_classes"] for d in self._departed.values())

    @property
    def n_models(self) -> int:
        return sum(m.n_models for m in self.managers.values()) + \
            sum(d["n_models"] for d in self._departed.values())

    @property
    def per_tenant_top1(self) -> dict:
        out = {str(k): d["top1"] for k, d in self._departed.items()}
        out.update({str(k): m.top1 for k, m in self.managers.items()})
        return out

    # -- health views (the serve sidecar's summary line) ---------------------

    @property
    def n_health_faults(self) -> int:
        return sum(m.n_health_faults for m in self.managers.values())

    @property
    def n_fallbacks(self) -> int:
        return sum(m.n_fallbacks for m in self.managers.values())

    @property
    def n_recoveries(self) -> int:
        return sum(m.n_recoveries for m in self.managers.values())

    @property
    def health_states(self) -> dict:
        return {str(k): m.health_state for k, m in self.managers.items()}
