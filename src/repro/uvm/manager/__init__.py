"""`repro.uvm.manager` — the streaming oversubscription-management API.

The paper's online pipeline (pattern classifier -> per-pattern predictor ->
policy engine) as a workload-agnostic stepwise protocol:
``OversubscriptionManager.observe(FaultBatch) -> Actions`` plus
``feedback(Outcomes)`` for causal fine-tuning.  One manager implementation
drives the trace simulator (:func:`repro.uvm.runtime.run_ours`), the
serving KV-offload path (:class:`repro.serving.offload.LearnedOffloadManager`)
and the ``python -m repro.uvm.cli serve`` fault-stream sidecar.

Fault tolerance rides on top: :class:`HealthConfig` turns on the
degraded-mode state machine (fail-soft into rule-based actions),
``state()``/``restore()`` + :class:`SnapshotStore` checkpoint a live
manager/mux, and :class:`FaultInjector` replays seeded chaos schedules.

See docs/API.md ("The streaming manager", "Fault tolerance") for the
cookbook.
"""
from repro.uvm.manager.chaos import ChaosError, ChaosSchedule, FaultInjector
from repro.uvm.manager.core import (
    Actions,
    EvalRequest,
    FaultBatch,
    HEALTH_STATES,
    HealthConfig,
    INTERVAL_FAULTS,
    ManagerConfig,
    Outcomes,
    OversubscriptionManager,
    TrainRequest,
    prefetch_mask,
    prefetch_warm,
)
from repro.uvm.manager.multi import MuxActions, TenantMux
from repro.uvm.manager.snapshot import STATE_VERSION, SnapshotStore
from repro.uvm.manager.stream import OnlineFeatureStream

__all__ = [
    "OversubscriptionManager",
    "ManagerConfig",
    "HealthConfig",
    "FaultBatch",
    "Actions",
    "Outcomes",
    "EvalRequest",
    "TrainRequest",
    "TenantMux",
    "MuxActions",
    "OnlineFeatureStream",
    "SnapshotStore",
    "ChaosSchedule",
    "ChaosError",
    "FaultInjector",
    "prefetch_warm",
    "prefetch_mask",
    "INTERVAL_FAULTS",
    "HEALTH_STATES",
    "STATE_VERSION",
]
