"""`repro.uvm.manager` — the streaming oversubscription-management API.

The paper's online pipeline (pattern classifier -> per-pattern predictor ->
policy engine) as a workload-agnostic stepwise protocol:
``OversubscriptionManager.observe(FaultBatch) -> Actions`` plus
``feedback(Outcomes)`` for causal fine-tuning.  One manager implementation
drives the trace simulator (:func:`repro.uvm.runtime.run_ours`), the
serving KV-offload path (:class:`repro.serving.offload.LearnedOffloadManager`)
and the ``python -m repro.uvm.cli serve`` fault-stream sidecar.

See docs/API.md ("The streaming manager") for the cookbook.
"""
from repro.uvm.manager.core import (
    Actions,
    EvalRequest,
    FaultBatch,
    INTERVAL_FAULTS,
    ManagerConfig,
    Outcomes,
    OversubscriptionManager,
    TrainRequest,
    prefetch_mask,
    prefetch_warm,
)
from repro.uvm.manager.multi import MuxActions, TenantMux
from repro.uvm.manager.stream import OnlineFeatureStream

__all__ = [
    "OversubscriptionManager",
    "ManagerConfig",
    "FaultBatch",
    "Actions",
    "Outcomes",
    "EvalRequest",
    "TrainRequest",
    "TenantMux",
    "MuxActions",
    "OnlineFeatureStream",
    "prefetch_warm",
    "prefetch_mask",
    "INTERVAL_FAULTS",
]
