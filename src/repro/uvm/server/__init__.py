"""Async fault-stream serving: many concurrent JSONL clients, one
cross-connection microbatched model dispatch per tick.

The package splits the ``cli serve`` sidecar into reusable layers:

* :mod:`~repro.uvm.server.protocol` — the versioned JSONL line codec
  (observe / feedback / hello records, structured error lines) shared by
  ``cli serve``, the async server, and the load generator.
* :mod:`~repro.uvm.server.session` — :class:`StreamSession`, a sans-io
  per-connection state machine that turns input lines into staged
  :class:`~repro.uvm.manager.core.EvalRequest` /
  :class:`~repro.uvm.manager.core.TrainRequest` ticks and folds results
  back into action records.  ``cli serve`` drives one session inline;
  the server drives thousands through a shared dispatcher.
* :mod:`~repro.uvm.server.core` — :class:`FaultStreamServer`, the
  asyncio accept loop + :class:`MicrobatchDispatcher` lockstep engine
  that batches every session's staged halves through ONE vmapped
  ``Trainer.evaluate_many`` / ``train_group_many`` call per tick.
* :mod:`~repro.uvm.server.loadgen` — a deterministic multi-client load
  generator replaying exported fault logs at a target rate.
* :mod:`~repro.uvm.server.aot` — compile-once AOT export/reload of the
  trainer's jitted executables, bit-identical to the jit path.
"""
from repro.uvm.server.aot import AotCache, enable_aot
from repro.uvm.server.core import FaultStreamServer, MicrobatchDispatcher, ServerConfig
from repro.uvm.server.loadgen import LoadStats, make_connector, run_loadgen
from repro.uvm.server.protocol import ProtocolError, decode_line, encode_error, encode_record
from repro.uvm.server.session import EvalTick, StreamSession, SyncDispatch, TrainTick, drive

__all__ = [
    "AotCache",
    "EvalTick",
    "FaultStreamServer",
    "LoadStats",
    "MicrobatchDispatcher",
    "ProtocolError",
    "ServerConfig",
    "StreamSession",
    "SyncDispatch",
    "TrainTick",
    "decode_line",
    "drive",
    "enable_aot",
    "encode_error",
    "encode_record",
    "make_connector",
    "run_loadgen",
]
