"""Compile-once AOT export/reload of the trainer's jitted executables.

A fresh process pays ~17s of jax tracing + lowering before its first
serve dispatch (one trace per jitted scan per shape bucket).  This module
serializes each traced executable with :mod:`jax.export` the first time a
(function, argument-shapes, static-flags) combination runs and reloads
the StableHLO artifact from disk on the next process start — tracing and
lowering are skipped entirely (XLA still compiles the deserialized
module, which is the smaller share).  The exported path is bit-identical
to the jit path; ``tests/test_server.py`` pins that equality.

Usage::

    trainer = Trainer(pcfg, tcfg, kind)
    enable_aot(trainer, "~/.cache/repro-aot")   # wraps the jitted scans

Every failure in the AOT path (unserializable config, backend mismatch,
a stale artifact) falls back silently to the wrapped jit function and is
counted on :class:`AotCache`; serving never depends on the cache being
healthy.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
from jax import export as jax_export  # not reachable as `jax.export` on 0.4.x

from repro.optim.adamw import OptState

#: the jitted Trainer instance attributes worth exporting (the scans —
#: per-step fns are only used by `old_features`, too cheap to matter)
_EXPORTABLE = ("_eval_scan", "_train_scan", "_eval_scan_many", "_train_scan_many")

_MISSING = object()
_registered = False


def _ensure_registered() -> None:
    """jax.export serializes pytrees by registered structure; the
    optimizer state is a custom NamedTuple it must be taught once."""
    global _registered
    if not _registered:
        jax_export.register_namedtuple_serialization(
            OptState, serialized_name="repro.optim.adamw.OptState")
        _registered = True


class AotCache:
    """On-disk store of serialized exports, keyed by content signature."""

    def __init__(self, root):
        self.root = Path(os.path.expanduser(str(root)))
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0  # artifacts reloaded from disk (trace skipped)
        self.misses = 0  # traced + exported this process
        self.fallbacks = 0  # AOT path failed; jit path served the call

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses, "fallbacks": self.fallbacks}


def _canon(args):
    """Commit every leaf to a strongly-typed device array so the export
    specs and the later calls agree on dtypes (python scalars arrive
    weakly typed; `astype` onto the same dtype strips the weak flag)."""
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a).astype(jnp.asarray(a).dtype), args)


class _AotFn:
    """Wrapper over one jitted function: export-or-reload per call
    signature, jit fallback on any AOT failure."""

    def __init__(self, jit_fn, name: str, cache: AotCache, closure_sig: str):
        self._jit_fn = jit_fn
        self._name = name
        self._cache = cache
        self._closure_sig = closure_sig
        self._loaded: dict = {}  # key -> exported | None (poisoned: use jit)

    def _key(self, args, static: dict) -> str:
        leaves, treedef = jax.tree_util.tree_flatten(args)
        sig = json.dumps({
            "fn": self._name,
            "closure": self._closure_sig,
            "static": {k: repr(v) for k, v in sorted(static.items())},
            "tree": str(treedef),
            "leaves": [(str(l.shape), str(l.dtype)) for l in leaves],
            "jax": jax.__version__,
            "backend": jax.default_backend(),
        }, sort_keys=True)
        return hashlib.sha256(sig.encode()).hexdigest()[:32]

    def _load_or_export(self, key: str, args, static: dict):
        path = self.root_path(key)
        try:
            _ensure_registered()
            if path.exists():
                exported = jax_export.deserialize(path.read_bytes())
                self._cache.hits += 1
                return exported
            specs = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args)
            exported = jax_export.export(self._jit_fn)(*specs, **static)
            tmp = path.with_suffix(".tmp")
            tmp.write_bytes(exported.serialize())
            tmp.replace(path)  # atomic publish: concurrent processes race safely
            self._cache.misses += 1
            return exported
        except Exception:  # noqa: BLE001 — any AOT failure means "use jit"
            self._cache.fallbacks += 1
            return None

    def root_path(self, key: str) -> Path:
        return self._cache.root / f"{self._name}-{key}.jaxexport"

    def __call__(self, *args, **static):
        try:
            args = _canon(args)
            key = self._key(args, static)
        except Exception:  # noqa: BLE001
            self._cache.fallbacks += 1
            return self._jit_fn(*args, **static)
        exported = self._loaded.get(key, _MISSING)
        if exported is _MISSING:
            exported = self._load_or_export(key, args, static)
            self._loaded[key] = exported
        if exported is None:
            return self._jit_fn(*args, **static)
        try:
            return exported.call(*args)
        except Exception:  # noqa: BLE001 — e.g. an artifact from another backend
            self._cache.fallbacks += 1
            self._loaded[key] = None
            return self._jit_fn(*args, **static)


def enable_aot(trainer, cache) -> AotCache:
    """Wrap ``trainer``'s jitted scans with the export-or-reload path.

    Wrapping is per-instance (the process-wide ``_TRAINER_FN_CACHE`` stays
    untouched) and idempotent.  Returns the :class:`AotCache` (also set as
    ``trainer.aot_cache``) so callers can report hit/miss/fallback counts.
    """
    cache = cache if isinstance(cache, AotCache) else AotCache(cache)
    closure_sig = f"{trainer.pcfg!r}|{trainer.tcfg!r}|{trainer.kind}"
    for name in _EXPORTABLE:
        fn = getattr(trainer, name)
        if not isinstance(fn, _AotFn):
            setattr(trainer, name, _AotFn(fn, name, cache, closure_sig))
    trainer.aot_cache = cache
    return cache
