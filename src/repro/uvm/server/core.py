"""The asyncio fault-stream server: many sessions, one dispatch per tick.

:class:`FaultStreamServer` accepts JSONL fault-stream clients on a unix
and/or TCP socket, binds each connection to its own
:class:`~repro.uvm.manager.TenantMux`-backed
:class:`~repro.uvm.server.session.StreamSession` (health machine always
on, per-session checkpoints under ``checkpoint_dir/<session>/``), and
suspends every session at its staged
:class:`~repro.uvm.server.session.EvalTick` /
:class:`~repro.uvm.server.session.TrainTick`.
:class:`MicrobatchDispatcher` is the lockstep engine
(:func:`repro.uvm.runtime.run_ours_many` generalized across
connections): each tick it drains every session's staged halves and
executes them in ONE worker hop on a shared trainer, off the event loop
so new lines keep streaming in while the model dispatch runs.  How the
hop executes follows the repo's benched dispatch policy
(:func:`_resolve_engine`): one vmapped ``Trainer.evaluate_many`` /
``train_group_many`` across lanes on multi-device, a fused sweep of the
warm serial jits on a single device.  ``microbatch=False`` drops the
gathering entirely — every session-tick becomes its own executor task
and event-loop round-trip, the per-connection baseline
``benchmarks/serve_perf.py`` measures against.  All modes emit
bit-identical per-connection action streams (lanes are independent
models and ``evaluate_many`` is bit-identical to its serial fallback,
so neither tick composition nor dispatch order can leak between
sessions); a chaos-wrapped shared trainer is the one exception — its
seeded schedule fires per dispatch call, so only the deterministic
microbatched modes replay it reproducibly.

Isolation: a malformed line earns its connection a structured error
record, an overlong line closes that connection, and a failed batched
dispatch is absorbed by each session's degraded-mode health machine —
none of it stalls or corrupts the other sessions' action streams.
"""
from __future__ import annotations

import asyncio
import dataclasses
import os
from pathlib import Path

from repro.core.incremental import Trainer
from repro.uvm.manager import (
    ChaosSchedule,
    FaultInjector,
    ManagerConfig,
    SnapshotStore,
    TenantMux,
)
from repro.uvm.server.protocol import ProtocolError, encode_error
from repro.uvm.server.session import EvalTick, StreamSession, SyncDispatch, TrainTick


@dataclasses.dataclass
class ServerConfig:
    """Everything the server needs beyond the per-session ManagerConfig."""

    manager: ManagerConfig
    default_tenant: str = "default"
    shared_freq_table: bool = False
    max_sessions: int = 4096  # admission cap; excess connections are refused
    idle_timeout_s: float = 0.0  # close connections idle this long (0 = never)
    gather_spins: int = 2  # event-loop passes that gather staged halves per tick
    microbatch: bool = True  # False: per-connection serial dispatch (baseline)
    exec_mode: str = "auto"  # batched tick engine: 'auto' | 'vmap' | 'fused'
    checkpoint_dir: str | None = None  # named sessions snapshot under <dir>/<name>/
    checkpoint_every: int = 0
    resume: bool = False  # restore a named session's latest snapshot on hello
    inject: str | None = None  # chaos schedule for the SHARED trainer
    line_limit: int = 1 << 20  # bytes; longer lines close the connection
    # per-session QoS capacity partitioning: raw --qos-tier strings
    # (TENANT:FLOOR[:SHARE]); None/empty = the legacy shared pool.  Each
    # connection gets its OWN BudgetController — sessions are isolated
    qos_tiers: list | None = None
    qos_stability: str = "percentile"
    qos_interval: int = 1


def _resolve_engine(exec_mode: str) -> str:
    """How a gathered tick executes: ``vmap`` stacks every lane into one
    ``evaluate_many``/``train_group_many`` dispatch (pays on multi-device,
    where lanes shard across devices — the ``run_ours_many`` regime);
    ``fused`` sweeps the lanes through the already-warm serial jits inside
    ONE worker-thread hop (the single-device default: the repo's benched
    policy is that the vmapped path costs more than serial on one CPU
    device).  ``auto`` follows the same ``REPRO_OURS_BATCHED`` override
    the batch runtime uses (``1`` forces vmap, ``0`` forces fused)."""
    if exec_mode in ("vmap", "fused"):
        return exec_mode
    if exec_mode != "auto":
        raise ValueError(f"exec_mode must be auto|vmap|fused, got {exec_mode!r}")
    import jax

    knob = os.environ.get("REPRO_OURS_BATCHED", "")
    return "vmap" if knob != "0" and (knob == "1" or len(jax.devices()) > 1) else "fused"


class MicrobatchDispatcher:
    """Cross-connection lockstep dispatcher.

    Sessions ``submit()`` their staged tick and suspend on a future; the
    run loop wakes, spins the event loop ``gather_spins`` times so every
    connection with buffered input can stage its half too, then cuts the
    batch and executes it in ONE worker-thread hop (vmapped or fused per
    :func:`_resolve_engine`) so the socket side keeps streaming.  Results
    (or the shared exception — each session's health machine absorbs it)
    are scattered back to the futures.

    With ``microbatch=False`` there is no gathering at all: every
    session-tick is its own executor task plus its own event-loop
    round-trip, dispatch-equivalent to N independent ``cli serve``
    processes sharing warm jits — the per-connection serial baseline
    ``benchmarks/serve_perf.py`` measures against.
    """

    def __init__(self, trainer, *, use_lucir: bool = False, microbatch: bool = True,
                 gather_spins: int = 2, exec_mode: str = "auto"):
        self.trainer = trainer
        self.use_lucir = use_lucir
        self.microbatch = microbatch
        self.engine = _resolve_engine(exec_mode)
        self.gather_spins = gather_spins
        self._sync = SyncDispatch(trainer, use_lucir)
        self._pending: list = []  # [(tick, future)]
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        self.n_ticks = 0
        self.n_eval_requests = 0
        self.n_train_requests = 0
        self.max_eval_lanes = 0  # widest single gathered tick this run

    def start(self) -> None:
        self._wake = asyncio.Event()
        self._task = asyncio.ensure_future(self._run())

    async def stop(self) -> None:
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        for _tick, fut in self._pending:
            if not fut.done():
                fut.cancel()
        self._pending = []

    async def submit(self, tick):
        self._count(tick)
        if not self.microbatch:
            # per-connection dispatch: no gathering, one executor task and
            # one loop round-trip per session-tick (concurrent across
            # connections on the default pool)
            self.n_ticks += 1
            return await asyncio.get_running_loop().run_in_executor(
                None, self._sync, tick)
        fut = asyncio.get_running_loop().create_future()
        self._pending.append((tick, fut))
        self._wake.set()
        return await fut

    def _count(self, tick) -> None:
        if isinstance(tick, EvalTick):
            self.n_eval_requests += len(tick.reqs)
        else:
            self.n_train_requests += len(tick.reqs)

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            await self._wake.wait()
            for _ in range(self.gather_spins):
                await asyncio.sleep(0)
            batch, self._pending = self._pending, []
            self._wake.clear()
            if not batch:
                continue
            self.n_ticks += 1
            evals = [(t, f) for t, f in batch if isinstance(t, EvalTick)]
            trains = [(t, f) for t, f in batch if isinstance(t, TrainTick)]
            self.max_eval_lanes = max(
                self.max_eval_lanes, sum(len(t.reqs) for t, _ in evals))
            eval_out, train_out = await loop.run_in_executor(
                None, self._dispatch, [t for t, _ in evals], [t for t, _ in trains])
            for (_t, fut), res in zip(evals, eval_out):
                if not fut.done():
                    fut.set_result(res)
            for (_t, fut), res in zip(trains, train_out):
                if not fut.done():
                    fut.set_result(res)

    # -- worker-thread side (pure trainer calls, no loop state) --------------

    def _dispatch(self, evals: list, trains: list):
        if self.engine == "fused":
            # the gathered lanes sweep through the warm serial jits inside
            # this single worker hop — amortizes the executor/loop churn
            # without paying the single-device vmap penalty
            return [self._sync(t) for t in evals], [self._sync(t) for t in trains]
        return self._dispatch_evals(evals), self._dispatch_trains(trains)

    def _dispatch_evals(self, evals: list):
        flat = [r for t in evals for r in t.reqs]
        if not flat:
            return [[] for _ in evals]
        try:
            out = self.trainer.evaluate_many(
                [r.params for r in flat], [r.fs for r in flat], [r.n_active for r in flat])
        except Exception as exc:  # noqa: BLE001 — every session's health machine decides
            return [exc for _ in evals]
        results, i = [], 0
        for t in evals:
            results.append(out[i:i + len(t.reqs)])
            i += len(t.reqs)
        return results

    def _dispatch_trains(self, trains: list):
        flat = [r for t in trains for r in t.reqs]
        if not flat:
            return [None for _ in trains]
        try:
            self.trainer.train_group_many(
                [r.entry for r in flat], [r.fs for r in flat], [r.n_active for r in flat],
                in_et_list=[r.in_et for r in flat], use_lucir=self.use_lucir)
        except Exception as exc:  # noqa: BLE001
            return [exc for _ in trains]
        return [None for _ in trains]


class _Handle:
    __slots__ = ("name", "session", "writer", "last_active")

    def __init__(self, name, session, writer, last_active):
        self.name = name
        self.session = session
        self.writer = writer
        self.last_active = last_active


class FaultStreamServer:
    """Accept loop + session registry around :class:`MicrobatchDispatcher`."""

    def __init__(self, cfg: ServerConfig, *, trainer=None):
        self.cfg = cfg
        mcfg = cfg.manager
        self.trainer = trainer if trainer is not None else Trainer(mcfg.predictor, mcfg.train, mcfg.kind)
        self.injector = None
        if cfg.inject:
            # wrap the SHARED trainer: every session's dispatches draw from
            # one seeded schedule, exactly like serve --inject
            self.injector = FaultInjector(ChaosSchedule.parse(cfg.inject))
            self.trainer = self.injector.wrap_trainer(self.trainer)
        self.dispatcher = MicrobatchDispatcher(
            self.trainer, use_lucir=mcfg.use_lucir,
            microbatch=cfg.microbatch, gather_spins=cfg.gather_spins,
            exec_mode=cfg.exec_mode)
        self.sessions: dict = {}  # name -> _Handle
        self.stats = {"served": 0, "refused": 0, "idle_closed": 0, "resumed": 0}
        self._conn_seq = 0
        self._servers: list = []
        self._gc_task: asyncio.Task | None = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self, *, path: str | None = None, host: str | None = None,
                    port: int = 0) -> "FaultStreamServer":
        self.dispatcher.start()
        if self.cfg.idle_timeout_s > 0:
            self._gc_task = asyncio.ensure_future(self._gc())
        if path is not None:
            self._servers.append(await asyncio.start_unix_server(
                self._handle, path=path, limit=self.cfg.line_limit))
        if host is not None:
            self._servers.append(await asyncio.start_server(
                self._handle, host=host, port=port, limit=self.cfg.line_limit))
        if not self._servers:
            raise ValueError("server needs a unix socket path and/or a TCP host")
        return self

    @property
    def tcp_port(self) -> int | None:
        for srv in self._servers:
            for sock in srv.sockets:
                name = sock.getsockname()
                if isinstance(name, tuple):
                    return name[1]
        return None

    async def serve_forever(self) -> None:
        await asyncio.gather(*(s.serve_forever() for s in self._servers))

    async def shutdown(self, timeout: float = 30.0) -> None:
        """Graceful drain: stop accepting, close every client connection
        (their handlers run the normal EOF drain + final snapshot), wait
        for the registry to empty, then stop the dispatcher."""
        for srv in self._servers:
            srv.close()
        for handle in list(self.sessions.values()):
            handle.writer.close()
        deadline = asyncio.get_running_loop().time() + timeout
        while self.sessions and asyncio.get_running_loop().time() < deadline:
            await asyncio.sleep(0.02)
        if self._gc_task is not None:
            self._gc_task.cancel()
            try:
                await self._gc_task
            except asyncio.CancelledError:
                pass
            self._gc_task = None
        await self.dispatcher.stop()
        for srv in self._servers:
            await srv.wait_closed()

    def summary_line(self) -> str:
        d = self.dispatcher
        return (f"# server sessions={self.stats['served']} refused={self.stats['refused']} "
                f"idle_closed={self.stats['idle_closed']} resumed={self.stats['resumed']} "
                f"ticks={d.n_ticks} eval_requests={d.n_eval_requests} "
                f"train_requests={d.n_train_requests} max_eval_lanes={d.max_eval_lanes} "
                f"mode={f'batched-{d.engine}' if d.microbatch else 'serial'}")

    # -- per-connection plumbing ---------------------------------------------

    def _new_session(self, handle: _Handle) -> StreamSession:
        qos = None
        if self.cfg.qos_tiers:
            from repro.uvm.qos import BudgetController, parse_tier_flags

            # a fresh controller per connection: budgets partition each
            # session's OWN device capacity, never across sessions
            qos = BudgetController(
                self.cfg.manager.capacity, self.cfg.manager.n_blocks,
                tiers=parse_tier_flags(self.cfg.qos_tiers),
                stability=self.cfg.qos_stability, interval=self.cfg.qos_interval,
            )
        mux = TenantMux(self.cfg.manager, shared_freq_table=self.cfg.shared_freq_table,
                        trainer=self.trainer, qos=qos)
        return StreamSession(mux, default_tenant=self.cfg.default_tenant,
                             on_hello=lambda session, name: self._on_hello(handle, session, name))

    def _on_hello(self, handle: _Handle, session: StreamSession, name):
        if name is None:
            return None
        other = self.sessions.get(name)
        if other is not None and other is not handle:
            raise ProtocolError(f"session name {name!r} already in use")
        self.sessions.pop(handle.name, None)
        handle.name = session.name = name
        self.sessions[name] = handle
        if not self.cfg.checkpoint_dir:
            return None
        store = SnapshotStore(str(Path(self.cfg.checkpoint_dir) / name))
        store.clean_tmp()
        session.store = store
        session.checkpoint_every = self.cfg.checkpoint_every
        if self.cfg.resume and store.latest_step() is not None:
            batches, resume_lineno = session.resume_latest()
            self.stats["resumed"] += 1
            return (f"# resumed batch={batches} lineno={resume_lineno} "
                    f"tenants={len(session.mux.managers)} from {store.dir}")
        return None

    async def _run_gen(self, gen):
        """Drive one session generator, awaiting the dispatcher per tick."""
        try:
            tick = next(gen)
        except StopIteration as stop:
            return stop.value or []
        while True:
            result = await self.dispatcher.submit(tick)
            try:
                tick = gen.send(result)
            except StopIteration as stop:
                return stop.value or []

    async def _handle(self, reader, writer) -> None:
        loop = asyncio.get_running_loop()
        if len(self.sessions) >= self.cfg.max_sessions:
            self.stats["refused"] += 1
            with _swallow_transport_errors():
                writer.write((encode_error(
                    f"server full ({self.cfg.max_sessions} sessions)", 0) + "\n").encode())
                await writer.drain()
            writer.close()
            return
        handle = _Handle(f"conn-{self._conn_seq}", None, writer, loop.time())
        self._conn_seq += 1
        handle.session = session = self._new_session(handle)
        self.sessions[handle.name] = handle
        self.stats["served"] += 1
        try:
            while True:
                try:
                    raw = await reader.readline()
                except (asyncio.LimitOverrunError, ValueError):
                    # an overlong line poisons the stream framing: report
                    # it and drop the connection (others are unaffected)
                    session.errors += 1
                    with _swallow_transport_errors():
                        writer.write((encode_error(
                            "line too long", session.lineno + 1) + "\n").encode())
                        await writer.drain()
                    break
                except (ConnectionResetError, BrokenPipeError, OSError):
                    break
                if not raw:
                    break
                handle.last_active = loop.time()
                records = await self._run_gen(session.step(raw.decode("utf-8", "replace")))
                with _swallow_transport_errors():
                    for rec in records:
                        writer.write((rec + "\n").encode())
                    await writer.drain()
            # EOF / disconnect: close pending batches, flush the final
            # snapshot, answer with the same summary line `serve` prints
            await self._run_gen(session.drain())
            if session.store is not None:
                session.save_snapshot()
            with _swallow_transport_errors():
                writer.write((session.summary_line() + "\n").encode())
                await writer.drain()
        finally:
            self.sessions.pop(handle.name, None)
            with _swallow_transport_errors():
                writer.close()
                await writer.wait_closed()

    async def _gc(self) -> None:
        while True:
            await asyncio.sleep(max(self.cfg.idle_timeout_s / 4, 0.05))
            now = asyncio.get_running_loop().time()
            for handle in list(self.sessions.values()):
                if now - handle.last_active > self.cfg.idle_timeout_s:
                    # closing the transport EOFs the handler's readline;
                    # it drains + snapshots like any disconnect
                    self.stats["idle_closed"] += 1
                    handle.last_active = float("inf")  # close once
                    handle.writer.close()


class _swallow_transport_errors:
    """A peer that vanished mid-write must not take the handler down with
    a traceback — its session cleanup still runs."""

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, _tb):
        return exc_type is not None and issubclass(
            exc_type, (ConnectionResetError, BrokenPipeError, RuntimeError, OSError))
