"""The serve/server JSONL wire codec — one schema, every surface.

``cli serve``, the async :class:`~repro.uvm.server.core.FaultStreamServer`
and the load generator all speak the ``cli export`` /
:func:`repro.uvm.trace.to_fault_log` fault-log line schema::

    {"pages": [0, 1, 2, ...], "pc": [...], "tb": [...], "kernel": [...]}
    {"pages": [...], "tenant": "job-a"}
    {"feedback": {"was_evicted": [false, ...], "fault_count": 128}, "tenant": "job-a"}
    {"hello": {"session": "job-a"}}

plus the server-only ``hello`` record: a client's optional FIRST line
naming its session, which binds it to that session's checkpoint
directory (and resumes it under ``--resume``).  Malformed lines never
produce a traceback — they decode to a :class:`ProtocolError` whose
message ships back as a structured ``{"error": ..., "line": N}`` record.

Keeping the codec here (instead of inside ``cli serve``) is what keeps
the single-connection sidecar and the async server from drifting: both
decode with :func:`decode_line` and encode with :func:`encode_record` /
:func:`encode_error`, so a schema change lands on every surface at once.
"""
from __future__ import annotations

import json
import re

import numpy as np

_SESSION_NAME_RE = re.compile(r"^[A-Za-z0-9._-]{1,64}$")


class ProtocolError(ValueError):
    """A malformed JSONL line — reported as a structured error line, never
    a traceback (a long-lived sidecar must survive garbage input)."""


def decode_line(line: str, default_tenant: str):
    """Validate one JSONL line into ``(kind, (tenant, tagged), payload)``
    where kind is ``'observe'``, ``'feedback'`` or ``'hello'``.  Raises
    :class:`ProtocolError` with a one-line reason on anything malformed."""
    try:
        rec = json.loads(line)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"bad json: {e.msg}") from None
    if not isinstance(rec, dict):
        raise ProtocolError(f"line must be a JSON object, got {type(rec).__name__}")
    if "hello" in rec:
        if "pages" in rec or "feedback" in rec:
            raise ProtocolError("'hello' line must not carry 'pages' or 'feedback'")
        hello = rec["hello"]
        if not isinstance(hello, dict):
            raise ProtocolError("'hello' must be a JSON object")
        name = hello.get("session")
        if name is not None and (not isinstance(name, str) or not _SESSION_NAME_RE.match(name)):
            raise ProtocolError("'session' must match [A-Za-z0-9._-]{1,64}")
        return "hello", (None, False), {"session": name}
    tenant = rec.get("tenant", None)
    if tenant is not None and not isinstance(tenant, (str, int)):
        raise ProtocolError(f"'tenant' must be a string or int, got {type(tenant).__name__}")
    tagged = tenant is not None
    tenant = default_tenant if tenant is None else tenant
    if ("pages" in rec) == ("feedback" in rec):
        raise ProtocolError("line needs exactly one of 'pages' or 'feedback'")
    if "feedback" in rec:
        fb = rec["feedback"] or {}
        if not isinstance(fb, dict):
            raise ProtocolError("'feedback' must be a JSON object")
        we = fb.get("was_evicted")
        if we is not None and (not isinstance(we, list) or any(not isinstance(x, bool) for x in we)):
            raise ProtocolError("'was_evicted' must be a list of booleans")
        fc = fb.get("fault_count")
        if fc is not None and (isinstance(fc, bool) or not isinstance(fc, int) or fc < 0):
            raise ProtocolError("'fault_count' must be a non-negative integer")
        return "feedback", (tenant, tagged), {"was_evicted": we, "fault_count": fc}
    pages = rec["pages"]
    if not isinstance(pages, list) or any(isinstance(p, bool) or not isinstance(p, int) or p < 0 for p in pages):
        raise ProtocolError("'pages' must be a list of non-negative integers")
    sides = {}
    for ch in ("pc", "tb", "kernel"):
        v = rec.get(ch)
        if v is not None and (not isinstance(v, list) or len(v) != len(pages)
                              or any(isinstance(x, bool) or not isinstance(x, int) for x in v)):
            raise ProtocolError(f"'{ch}' must be a list of ints aligned with 'pages'")
        sides[ch] = v
    return "observe", (tenant, tagged), {"pages": np.asarray(pages, np.int64), **sides}


def encode_record(batch: int, actions, *, tenant=None, budget=None) -> str:
    """One JSON action line for an observed batch.  Field order is part of
    the wire contract — the kill-9/resume gates compare tails byte-for-
    byte, so serve and the server must emit identical strings.  ``budget``
    (the tenant's current QoS block budget) appears only on budgeted
    streams — legacy streams stay byte-identical."""
    rec = {
        "batch": batch,
        "pattern": actions.pattern,
        "n_samples": actions.n_samples,
        "accuracy": actions.accuracy,
        "warm": actions.warm,
        "health": actions.health,
        "fallback": actions.fallback,
        "prefetch_blocks": np.asarray(actions.prefetch_blocks).tolist(),
        "pre_evict_blocks": np.asarray(actions.pre_evict_blocks).tolist(),
    }
    if tenant is not None:
        rec["tenant"] = tenant
    if budget is not None:
        rec["budget"] = int(budget)
    return json.dumps(rec)


def encode_error(message: str, lineno: int) -> str:
    """The structured error record a malformed line earns."""
    return json.dumps({"error": message, "line": lineno})
