"""Deterministic multi-client load generator for the fault-stream server.

Replays an exported JSONL fault log (``cli export`` /
:func:`repro.uvm.trace.to_fault_log`) over N concurrent connections at a
target per-client rate, measuring closed-loop action latency (an observe
line's send → its action record's arrival) and sustained faults/sec.
Content is fully deterministic — seeded logs, seeded chaos — so the
per-client action streams it collects feed the bit-identity gates;
only the timing (and therefore the server's tick composition) varies,
which microbatching is designed to make invisible.

Two designated misbehaving clients exercise the isolation story:

* ``malformed_client`` injects a non-JSON line every ``malformed_every``
  data lines (each earns a structured error record, nothing else);
* ``chaos_client`` runs its outgoing lines through a seeded
  :meth:`~repro.uvm.manager.chaos.FaultInjector.transform_lines`
  schedule (drops/dups/reorders/losses — transport chaos, client-side).

Latency is only sampled on clean clients (a transformed stream's
send→action pairing is ill-defined).
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
from collections import deque

import numpy as np


@dataclasses.dataclass
class ClientResult:
    idx: int
    name: str | None
    lines_sent: int
    malformed_sent: int
    faults_sent: int  # pages across the observe lines actually delivered
    actions: list  # encoded action records, arrival order
    errors: int
    comments: list  # "# ..." lines (resume notices, the final summary)
    latencies_ms: list


@dataclasses.dataclass
class LoadStats:
    clients: int
    lines_sent: int
    actions: int
    errors: int
    faults: int  # total pages across every delivered observe line
    wall_s: float
    faults_per_s: float
    p50_ms: float
    p99_ms: float
    per_client: list  # ClientResult, client order


def _is_observe(line: str) -> bool:
    s = line.strip()
    return bool(s) and not s.startswith("#") and '"pages"' in s and '"feedback"' not in s


def _count_faults(line: str) -> int:
    try:
        rec = json.loads(line)
        return len(rec.get("pages", ())) if isinstance(rec, dict) else 0
    except json.JSONDecodeError:
        return 0


async def _run_client(idx: int, connect, lines: list, *, rate: float, hello: str | None,
                      chaos=None, malformed_every: int = 0,
                      line_limit: int = 1 << 20) -> ClientResult:
    loop = asyncio.get_running_loop()
    reader, writer = await connect(line_limit)
    clean = chaos is None and not malformed_every
    pending: deque = deque()  # send-times of in-flight observe lines
    res = ClientResult(idx, hello, 0, 0, 0, [], 0, [], [])

    async def read_loop():
        while True:
            raw = await reader.readline()
            if not raw:
                return
            s = raw.decode("utf-8", "replace").strip()
            if not s:
                continue
            if s.startswith("#"):
                res.comments.append(s)
                continue
            rec = json.loads(s)
            if "batch" in rec:
                if clean and pending:
                    res.latencies_ms.append((loop.time() - pending.popleft()) * 1e3)
                res.actions.append(s)
            elif "error" in rec:
                res.errors += 1

    reader_task = asyncio.ensure_future(read_loop())
    try:
        if hello is not None:
            writer.write((json.dumps({"hello": {"session": hello}}) + "\n").encode())
        out_lines = chaos.transform_lines(lines) if chaos is not None else lines
        start = loop.time()
        for line in out_lines:
            if rate > 0:  # steady per-client pacing
                target = start + res.lines_sent / rate
                delay = target - loop.time()
                if delay > 0:
                    await asyncio.sleep(delay)
            if malformed_every and res.lines_sent and res.lines_sent % malformed_every == 0:
                writer.write(b"malformed line from client\n")
                res.malformed_sent += 1
            if _is_observe(line):
                res.faults_sent += _count_faults(line)
                if clean:
                    pending.append(loop.time())
            writer.write((line.rstrip("\n") + "\n").encode())
            res.lines_sent += 1
            await writer.drain()
        writer.write_eof()  # half-close: the server drains + answers the summary
        await reader_task
    finally:
        reader_task.cancel()
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass
    return res


def make_connector(target: str):
    """``unix:/path`` or ``host:port`` -> an async ``connect(limit)``."""
    if target.startswith("unix:"):
        path = target[len("unix:"):]

        async def connect(limit):
            return await asyncio.open_unix_connection(path, limit=limit)
    else:
        host, _, port = target.rpartition(":")

        async def connect(limit):
            return await asyncio.open_connection(host or "127.0.0.1", int(port), limit=limit)
    return connect


async def run_loadgen(connect, lines: list, n_clients: int, *, rate: float = 0.0,
                      repeat: int = 1, hello_prefix: str | None = None,
                      chaos_schedules: dict | None = None, malformed_every: int = 0,
                      malformed_client: int | None = None,
                      line_limit: int = 1 << 20) -> LoadStats:
    """Drive ``n_clients`` concurrent replays of ``lines`` (``repeat``
    passes each) and aggregate the stats.  ``chaos_schedules`` maps client
    index -> a :class:`~repro.uvm.manager.chaos.FaultInjector`."""
    stream = list(lines) * repeat
    chaos_schedules = chaos_schedules or {}
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    results = await asyncio.gather(*(
        _run_client(
            i, connect, stream, rate=rate,
            hello=f"{hello_prefix}{i}" if hello_prefix else None,
            chaos=chaos_schedules.get(i),
            malformed_every=malformed_every if i == malformed_client else 0,
            line_limit=line_limit,
        )
        for i in range(n_clients)
    ))
    wall = loop.time() - t0
    lat = np.asarray(sorted(x for r in results for x in r.latencies_ms), float)
    served_faults = sum(r.faults_sent for r in results)
    return LoadStats(
        clients=n_clients,
        lines_sent=sum(r.lines_sent for r in results),
        actions=sum(len(r.actions) for r in results),
        errors=sum(r.errors for r in results),
        faults=served_faults,
        wall_s=wall,
        faults_per_s=served_faults / wall if wall > 0 else 0.0,
        p50_ms=float(np.percentile(lat, 50)) if len(lat) else 0.0,
        p99_ms=float(np.percentile(lat, 99)) if len(lat) else 0.0,
        per_client=list(results),
    )
