"""Sans-io per-connection serving state machine.

:class:`StreamSession` is ``cli serve``'s line loop factored out of the
CLI: one instance owns one client's :class:`~repro.uvm.manager.TenantMux`
plus the stream bookkeeping (pending batches, fault clock, line counter,
round-boundary checkpoints).  It is transport- and scheduler-agnostic:
``step(line)`` is a *generator* that yields :class:`EvalTick` /
:class:`TrainTick` dispatch requests and receives their results (or the
exception the dispatch raised) via ``send``, finally returning the list
of encoded output records.  ``cli serve`` drives each step to completion
inline with :func:`drive` + :class:`SyncDispatch`; the async server
suspends every session at its tick and microbatches the staged requests
of ALL sessions through one vmapped trainer call
(:class:`~repro.uvm.server.core.MicrobatchDispatcher`).

Because both surfaces run the exact same state machine and codec, the
action stream a client sees is byte-identical whether it is served by
``cli serve``, by the async server serially, or microbatched across
hundreds of other connections (``evaluate_many`` is bit-identical to its
serial fallback, so tick composition cannot leak between sessions).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.uvm.manager import FaultBatch, Outcomes
from repro.uvm.server.protocol import ProtocolError, decode_line, encode_error, encode_record


@dataclasses.dataclass
class EvalTick:
    """Staged ``evaluate_many`` half: dispatch ``reqs`` and send back the
    aligned result list (or the raised exception)."""

    reqs: list


@dataclasses.dataclass
class TrainTick:
    """Staged ``train_group_many`` half: dispatch ``reqs`` and send back
    ``None`` (entries update in place) or the raised exception.  Dispatch
    happens even with zero requests — a chaos-wrapped trainer draws its
    RNG per call, so an elided empty call would shift every later
    injection site of a seeded schedule."""

    reqs: list
    use_lucir: bool = False


class SyncDispatch:
    """Inline tick dispatcher: the single-connection (``cli serve``) and
    shutdown-drain path.  Mirrors ``TenantMux.observe``/``feedback``'s
    trainer calls exactly, returning exceptions as values."""

    def __init__(self, trainer, use_lucir: bool = False):
        self.trainer = trainer
        self.use_lucir = use_lucir

    def __call__(self, tick):
        if isinstance(tick, EvalTick):
            if not tick.reqs:
                return []
            try:
                return self.trainer.evaluate_many(
                    [r.params for r in tick.reqs], [r.fs for r in tick.reqs],
                    [r.n_active for r in tick.reqs],
                )
            except Exception as exc:  # noqa: BLE001 — the session decides
                return exc
        try:
            self.trainer.train_group_many(
                [r.entry for r in tick.reqs], [r.fs for r in tick.reqs],
                [r.n_active for r in tick.reqs],
                in_et_list=[r.in_et for r in tick.reqs], use_lucir=tick.use_lucir,
            )
            return None
        except Exception as exc:  # noqa: BLE001
            return exc


def drive(gen, dispatch):
    """Run one session generator to completion against an inline
    dispatcher; returns the session's encoded output records."""
    try:
        tick = next(gen)
        while True:
            tick = gen.send(dispatch(tick))
    except StopIteration as stop:
        return stop.value or []


class StreamSession:
    """One client's serving state: mux + stream bookkeeping + checkpoints.

    ``store``/``checkpoint_every`` reproduce ``cli serve``'s round-boundary
    snapshot cadence; :meth:`resume_latest` restores the newest snapshot
    and arms the consumed-line skip so a replayed stream's action tail is
    bit-identical to an uninterrupted run.  ``on_hello`` (server-side) is
    called with ``(session, name)`` when the client's ``hello`` line
    arrives — it may bind a checkpoint store, trigger a resume, or raise
    :class:`ProtocolError` (e.g. a session name already in use), which
    surfaces as a structured error record like any malformed line.
    """

    def __init__(self, mux, *, default_tenant: str = "default", store=None,
                 checkpoint_every: int = 0, on_hello=None):
        self.mux = mux
        self.default_tenant = default_tenant
        self.store = store
        self.checkpoint_every = checkpoint_every
        self.on_hello = on_hello
        self.name: str | None = None
        self.pending: dict = {}  # tenant -> pending batch length (None: closed)
        self.last_fault = 0
        self.last_tenant = default_tenant
        self.batches = 0
        self.errors = 0
        self.lineno = 0
        self.resume_lineno = 0
        self.checkpoint_due = False
        self._saw_traffic = False

    # -- checkpointing -------------------------------------------------------

    def extra_record(self) -> dict:
        return {"lineno": self.lineno, "batches": self.batches, "errors": self.errors,
                "last_fault": self.last_fault, "last_tenant": self.last_tenant}

    def save_snapshot(self) -> None:
        self.store.save(self.batches, self.mux.state(), extra=self.extra_record())

    def resume_latest(self):
        """Restore the newest snapshot in ``store``; returns
        ``(batches, resume_lineno)`` (the caller announces them)."""
        step, state, extra = self.store.restore()
        self.mux.restore(state)
        self.pending = {k: None for k in self.mux.managers}
        self.batches = extra.get("batches", step)
        self.errors = extra.get("errors", 0)
        self.last_fault = extra.get("last_fault", 0)
        self.last_tenant = extra.get("last_tenant", self.default_tenant)
        self.resume_lineno = extra.get("lineno", 0)
        return self.batches, self.resume_lineno

    def summary_line(self) -> str:
        mux = self.mux
        return (f"# serve batches={self.batches} predictions={mux.n_predictions} "
                f"patterns={mux.n_models} classes={mux.n_classes} top1={mux.top1:.3f} "
                f"tenants={len(mux.managers)} errors={self.errors} "
                f"health_faults={mux.n_health_faults} fallbacks={mux.n_fallbacks} "
                f"recoveries={mux.n_recoveries}")

    # -- the line loop (one generator per input line) ------------------------

    def _close(self, tenant, outcomes):
        pairs, treqs = self.mux.feedback_requests(outcomes, tenant=tenant)
        exc = yield TrainTick([r for _, r in treqs], self.mux.cfg.use_lucir)
        self.mux.feedback_apply(pairs, treqs, exc)
        self.pending[tenant] = None

    def step(self, line: str):
        """Process one raw input line.  Yields dispatch ticks, receives
        their results, and returns (``StopIteration.value``) the encoded
        records this line produced."""
        out: list[str] = []
        # snapshots happen only at fully-closed round boundaries (every
        # tenant's pending batch fed back); a due checkpoint waits here
        # until the boundary comes around
        if self.checkpoint_due and all(v is None for v in self.pending.values()):
            self.save_snapshot()
            self.checkpoint_due = False
        self.lineno += 1
        if self.lineno <= self.resume_lineno:
            return out  # consumed before the snapshot we restored from
        line = line.strip()
        if not line or line.startswith("#"):
            return out
        try:
            kind, (tenant, tagged), payload = decode_line(line, self.default_tenant)
            if kind == "hello":
                if self._saw_traffic:
                    raise ProtocolError("'hello' must precede any observe/feedback traffic")
                if self.on_hello is not None:
                    comment = self.on_hello(self, payload["session"])
                    if comment:
                        out.append(comment)
                return out
            self._saw_traffic = True
            if kind == "feedback":
                if not tagged:
                    tenant = self.last_tenant  # untagged: closes the previous batch
                we = payload["was_evicted"]
                if self.pending.get(tenant) is None and we is not None:
                    # an outcome report with nothing to apply it to is
                    # lost data -> error; a bare fault_count line merely
                    # seeds the clock (legacy input, accepted silently)
                    raise ProtocolError(f"feedback for tenant {tenant!r} without a pending batch")
                if we is not None and len(we) != self.pending[tenant]:
                    raise ProtocolError(
                        f"'was_evicted' must have one entry per access of tenant "
                        f"{tenant!r}'s pending batch (expected {self.pending[tenant]}, got {len(we)})"
                    )
                if payload["fault_count"] is not None:
                    self.last_fault = payload["fault_count"]
                if self.pending.get(tenant) is not None:
                    yield from self._close(tenant, Outcomes(
                        was_evicted=np.asarray(we, bool) if we is not None else None,
                        fault_count=self.last_fault,
                    ))
                return out
            if self.pending.get(tenant) is not None:  # auto-close (no outcome report)
                yield from self._close(tenant, Outcomes(fault_count=self.last_fault))
            pairs, evals = self.mux.observe_requests(FaultBatch(
                payload["pages"], payload["pc"], payload["tb"], payload["kernel"],
                tenant=tenant,
            ))
            result = []
            if evals:
                result = yield EvalTick([r for _, r in evals])
            macts = self.mux.observe_apply(pairs, evals, result)
            actions = macts.per_tenant[tenant]
            self.pending[tenant] = len(payload["pages"])
            self.last_tenant = tenant
            self.batches += 1
            out.append(encode_record(
                self.batches, actions, tenant=tenant if tagged else None,
                budget=None if macts.budgets is None else macts.budgets.get(tenant),
            ))
            if self.store is not None and self.checkpoint_every and self.batches % self.checkpoint_every == 0:
                self.checkpoint_due = True
        except ProtocolError as e:
            self.errors += 1
            out.append(encode_error(str(e), self.lineno))
        return out

    def drain(self):
        """Close every pending batch (stream end / graceful shutdown);
        same generator protocol as :meth:`step`."""
        for tenant, p in list(self.pending.items()):
            if p is not None:
                yield from self._close(tenant, Outcomes(fault_count=self.last_fault))
        return []
