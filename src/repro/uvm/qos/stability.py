"""Registered QoS stability scorers (the ``stability`` registry kind).

A scorer maps one tenant's per-round pressure history — its thrash rate
per access, clipped to ``[0, 1]`` — to a stability score in ``[0, 1]``:
1 = perfectly stable (safe to lend elastic capacity to), 0 = thrashing.
:class:`repro.uvm.qos.BudgetController` multiplies the score into the
tenant's elastic ``share`` weight, so unstable tenants' budgets shrink
toward their guaranteed floor while stable tenants absorb the slack.

Two builtins, both the shape of scroogevm's ``stability_assesser``
(jacquetpi — SNIPPETS.md 2), which scores a VM's oversubscribability from
a percentile of its observed usage history:

* ``percentile`` — 1 minus the q-th percentile of the recent window: one
  bad round is forgiven until it becomes the tail of the distribution.
* ``gmr`` — 1 minus the geometric mean ratio of the window: sustained
  pressure compounds multiplicatively, single spikes wash out (the
  GMR-style alternative scroogevm exposes next to the percentile one).

An empty history scores 1.0: a tenant is presumed stable until observed
otherwise (its guaranteed floor protects the others meanwhile).
"""
from __future__ import annotations

import numpy as np

from repro.uvm import registry as _registry


def percentile_scorer(q: float = 90.0, window: int = 16):
    """Scorer: ``1 - percentile_q(history[-window:])``, clipped to [0, 1]."""

    def score(history) -> float:
        h = np.clip(np.asarray(history, float)[-window:], 0.0, 1.0)
        if h.size == 0:
            return 1.0
        return float(np.clip(1.0 - np.percentile(h, q), 0.0, 1.0))

    return score


def gmr_scorer(window: int = 16, eps: float = 1e-6):
    """Scorer: ``1 - geomean(history[-window:])``, clipped to [0, 1]."""

    def score(history) -> float:
        h = np.clip(np.asarray(history, float)[-window:], 0.0, 1.0)
        if h.size == 0:
            return 1.0
        g = float(np.exp(np.log(h + eps).mean()) - eps)
        return float(np.clip(1.0 - g, 0.0, 1.0))

    return score


# Guarded for idempotence under importlib.reload, like the simulator's
# builtin policy/prefetcher registrations.
if "percentile" not in _registry.stability_names():
    _registry.register_stability("percentile", percentile_scorer)
    _registry.register_stability("gmr", gmr_scorer)
