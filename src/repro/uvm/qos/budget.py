"""The :class:`BudgetController`: elastic per-tenant capacity budgets.

Partition rule (scroogevm's tier0/tier1 split, per tenant instead of per
VM slice): every tenant is guaranteed ``floor * capacity`` blocks
outright; whatever capacity the floors leave over is the ELASTIC pool,
divided in proportion to ``share * stability`` where ``stability`` is a
registered scorer over the tenant's observed pressure history
(:mod:`repro.uvm.qos.stability`).  A thrashing tenant's score decays
toward 0, its budget shrinks toward its floor, and the reclaimed blocks
flow to stable tenants — rebalanced every ``interval`` feedback rounds.

The budgets become EVICTION TIERS, not hard caps: nothing stops a tenant
migrating blocks past its budget, but :meth:`evict_pref` marks every
resident block of an over-budget tenant (and every resident block nobody
owns) with ``-1`` in the simulator's leading victim key, so the packed
lexicographic argmin exhausts those before ANY under-budget tenant's
block is even considered.  When every tenant is within budget the total
residency is at most ``sum(budgets) <= capacity`` and no eviction happens
at all — which is what makes the fairness guarantee composable with any
registered eviction policy's own keys.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.uvm import registry as _registry


@dataclasses.dataclass(frozen=True)
class QosTier:
    """One tenant's QoS contract: a guaranteed ``floor`` fraction of device
    capacity (never reclaimed, whatever the tenant does) plus an elastic
    ``share`` weight for the pool the floors leave over."""

    floor: float = 0.0
    share: float = 1.0

    def __post_init__(self):
        if not 0.0 <= self.floor <= 1.0:
            raise ValueError(f"tier floor must be in [0, 1], got {self.floor}")
        if self.share < 0.0:
            raise ValueError(f"tier share must be >= 0, got {self.share}")


def parse_tier_flags(items) -> dict[str, QosTier]:
    """Parse repeated ``--qos-tier TENANT:FLOOR[:SHARE]`` flag values."""
    tiers: dict[str, QosTier] = {}
    for item in items or ():
        parts = str(item).split(":")
        if not 2 <= len(parts) <= 3 or not parts[0]:
            raise ValueError(
                f"bad --qos-tier {item!r}; expected TENANT:FLOOR[:SHARE] (e.g. A:0.5:1.0)"
            )
        tiers[parts[0]] = QosTier(
            floor=float(parts[1]), share=float(parts[2]) if len(parts) == 3 else 1.0
        )
    return tiers


class BudgetController:
    """Recompute per-tenant block budgets from observed behaviour and
    compile them (plus current residency) into the simulator's leading
    victim key.

    ``capacity`` is the device capacity in blocks, ``n_blocks`` the
    (bucket-padded) simulator block-space width.  ``tiers`` maps tenant
    keys to :class:`QosTier`; unknown tenants get ``default_tier``.
    Tenants are admitted on first contact (:meth:`observe_blocks`) and
    block ownership is learned first-toucher from the demand stream;
    :meth:`release` hands a departed tenant's claim back to the pool.
    """

    def __init__(
        self,
        capacity: int,
        n_blocks: int,
        *,
        tiers: dict | None = None,
        default_tier: QosTier = QosTier(),
        stability: str = "percentile",
        interval: int = 1,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.n_blocks = int(n_blocks)
        self.tiers = dict(tiers or {})
        self.default_tier = default_tier
        self.stability = stability
        self.interval = max(int(interval), 1)
        self._scorer = _registry.stability_factory(stability)()
        self.block_owner = np.full(self.n_blocks, -1, np.int32)
        self._index: dict = {}  # tenant key -> dense owner index (never reused)
        self._tier: dict = {}  # tenant key -> QosTier
        self._hist: dict = {}  # tenant key -> [pressure per round]
        self.budgets: dict = {}  # tenant key -> blocks
        self.scores: dict = {}  # tenant key -> last stability score
        self._round = 0

    # -- admission / departure ----------------------------------------------

    @property
    def tenants(self) -> tuple:
        return tuple(self._tier)

    def admit(self, tenant) -> None:
        """Declare a tenant (idempotent; also implicit in observe_blocks)."""
        if tenant in self._tier:
            return
        if tenant not in self._index:
            self._index[tenant] = len(self._index)
        self._tier[tenant] = self.tiers.get(tenant, self.default_tier)
        self._hist[tenant] = []
        self._recompute()

    def release(self, tenant) -> None:
        """Forget a departed tenant: its blocks return to the unowned pool
        (= preferred victims) and its budget slice rebalances to the live
        tenants on the next recompute."""
        if tenant not in self._tier:
            return
        self.block_owner[self.block_owner == self._index[tenant]] = -1
        del self._tier[tenant]
        del self._hist[tenant]
        self.budgets.pop(tenant, None)
        self.scores.pop(tenant, None)
        self._recompute()

    # -- observation ---------------------------------------------------------

    def observe_blocks(self, tenant, blocks) -> None:
        """Claim the unowned blocks of one tenant's demand batch
        (first-toucher ownership; admits the tenant on first contact)."""
        self.admit(tenant)
        b = np.asarray(blocks, np.int64)
        b = b[(b >= 0) & (b < self.n_blocks)]
        unowned = b[self.block_owner[b] < 0]
        self.block_owner[unowned] = self._index[tenant]

    def observe_pressure(self, tenant, pressure: float) -> None:
        """Record one round's pressure sample (thrash rate per access in
        [0, 1] — the mux feeds ``was_evicted.mean()``)."""
        self.admit(tenant)
        self._hist[tenant].append(float(np.clip(pressure, 0.0, 1.0)))

    def step(self) -> None:
        """Close one feedback round; recompute budgets every ``interval``."""
        self._round += 1
        if self._round % self.interval == 0:
            self._recompute()

    # -- the elastic split ----------------------------------------------------

    def _recompute(self) -> None:
        keys = list(self._tier)
        if not keys:
            self.budgets = {}
            return
        floors = np.array([self._tier[k].floor for k in keys], float)
        if floors.sum() > 1.0:  # over-promised floors scale down pro rata
            floors = floors / floors.sum()
        guaranteed = np.floor(floors * self.capacity).astype(np.int64)
        elastic = int(self.capacity - guaranteed.sum())
        self.scores = {k: float(self._scorer(self._hist[k])) for k in keys}
        w = np.array([self._tier[k].share * self.scores[k] for k in keys], float)
        if w.sum() <= 0.0:
            w = np.ones(len(keys), float)  # nobody scores: split evenly
        ew = np.floor(elastic * w / w.sum()).astype(np.int64)
        self.budgets = {k: int(guaranteed[i] + ew[i]) for i, k in enumerate(keys)}

    # -- the simulator-facing artifact ----------------------------------------

    def evict_pref(self, resident) -> np.ndarray:
        """The per-block leading victim key for the CURRENT residency:
        ``-1`` (evict first) on resident blocks of over-budget tenants and
        on resident blocks nobody owns, ``0`` elsewhere.  Constant for one
        segment, like every other packed-priority key."""
        resident = np.asarray(resident, bool)[: self.n_blocks]
        pref = np.zeros(self.n_blocks, np.int32)
        if not self._tier:
            return pref
        owner = self.block_owner
        idx_budget = np.zeros(len(self._index), np.int64)
        for k, i in self._index.items():
            idx_budget[i] = self.budgets.get(k, 0)
        owned = owner >= 0
        counts = np.bincount(owner[resident & owned], minlength=len(self._index))
        over = counts > idx_budget
        pref[resident & owned & over[np.clip(owner, 0, None)]] = -1
        pref[resident & ~owned] = -1
        return pref

    # -- snapshot / restore ----------------------------------------------------

    def state(self) -> dict:
        """Host-side snapshot (the scorer is rebuilt by name on restore)."""
        return {
            "stability": self.stability,
            "interval": self.interval,
            "round": self._round,
            "block_owner": self.block_owner.copy(),
            "index": dict(self._index),
            "tiers": {k: (t.floor, t.share) for k, t in self._tier.items()},
            "hist": {k: list(v) for k, v in self._hist.items()},
        }

    def restore(self, state: dict) -> None:
        self.stability = state["stability"]
        self._scorer = _registry.stability_factory(self.stability)()
        self.interval = state["interval"]
        self._round = state["round"]
        self.block_owner = np.asarray(state["block_owner"], np.int32).copy()
        self._index = dict(state["index"])
        self._tier = {k: QosTier(f, s) for k, (f, s) in state["tiers"].items()}
        self._hist = {k: list(v) for k, v in state["hist"].items()}
        self._recompute()
