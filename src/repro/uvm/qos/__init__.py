"""`repro.uvm.qos` — per-tenant capacity partitioning for the shared device.

The simulator evicts from ONE global capacity pool, so a thrashing tenant
can starve a well-behaved neighbour (the Section V-F fairness gap): the
victim keys say nothing about WHO owns a block.  This package closes the
gap with three pieces that sit between the :class:`~repro.uvm.manager.TenantMux`
and the simulator/server:

* **Budgeted eviction** — :meth:`BudgetController.evict_pref` compiles the
  current budgets + residency into the per-block int32 leading victim key
  the simulator's packed-priority tuple already supports
  (``repro.uvm.simulator.run_segment(..., evict_pref=...)``): blocks of
  over-budget tenants carry ``-1`` and are exhausted before ANY
  under-budget tenant loses a page.  All-``None`` budgets trace the exact
  pre-QoS program — the goldens pin that path bit for bit.
* **Elastic rebalancing** — :class:`BudgetController` recomputes budgets
  every ``interval`` rounds from observed per-tenant pressure (thrash per
  access), weighting each tenant's slice of the elastic pool by a
  registered ``stability`` scorer (:mod:`repro.uvm.qos.stability` —
  ``percentile`` and ``gmr``, scroogevm's ``stability_assesser`` shape).
* **Tiers** — :class:`QosTier` (guaranteed ``floor`` fraction + elastic
  ``share`` weight) per tenant, surfaced as ``QosSpec`` on
  :class:`~repro.uvm.api.specs.ModelSpec`, ``--qos-tier`` on ``cli
  serve``/``server``, and ``qos=`` on :func:`repro.uvm.runtime.run_ours`.

Block ownership is learned first-toucher from the observed fault stream
(tenants of a :func:`repro.uvm.trace.concurrent` merge occupy disjoint
block-aligned page ranges, so first-toucher IS the static owner there);
:meth:`BudgetController.release` returns a departed tenant's claim to the
pool so budgets rebalance to live tenants.
"""
from repro.uvm.qos.budget import BudgetController, QosTier, parse_tier_flags
from repro.uvm.qos import stability as stability  # noqa: F401  (registers builtins)

__all__ = ["BudgetController", "QosTier", "parse_tier_flags"]
