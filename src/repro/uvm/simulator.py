"""Trace-driven UVM device-memory simulator (the GPGPU-Sim replacement).

Pure-JAX ``lax.scan`` over the access stream with fixed-size per-block state
arrays (residency, LRU clocks, chain intervals, Belady next-use, learned
prediction frequency). Migration/eviction is at 64KB basic-block granularity
— the CUDA runtime's prefetch unit — and "pages thrashed" are reported as
blocks x 16 pages, matching the granularity of the paper's counters.

Eviction policies (Section II-C / IV-D):
    lru      — least-recently-used (CUDA driver default)
    random   — uniform random resident block
    belady   — MIN oracle (needs the precomputed next-use stream)
    hpe      — page-set chain (new/middle/old by fault interval) + LRU inside
    learned  — page-set chain + prediction-frequency table (the paper's engine)

Prefetchers (Section II-B):
    demand   — migrate only the faulted block
    tree     — NVIDIA tree-based neighbourhood prefetcher: after a migration,
               any [2,4,8,16,32]-block node above 50% valid occupancy gets its
               remaining blocks migrated
    none     — alias of demand; the learned prefetcher stages its blocks via
               :func:`apply_prefetch` between scan segments (async analogue)

Hot-path design (bit-identical to :mod:`repro.uvm.reference` for every
policy except ``random``, whose draws depend on array padding):

  * **fault-event compression** — consecutive accesses to the same block
    cannot fault after the first (the block was just migrated and is
    protected during its own step), so the trace is run-length-compressed
    on the host into per-run events carrying aggregate bookkeeping
    (final ``last_access``/``next_use``, pinned ``zero_copy`` mass, the
    interval-boundary fix-up for the page-set chain). The scan length
    shrinks by the repeat-run hit rate (1x-10x on the paper's suite).
  * **packed-priority eviction** — every policy's victim key is one
    uniform padded 3-tuple of int32 arrays (constant for the whole step:
    nothing an eviction changes feeds back into the keys), so victim
    selection is a chained masked-argmin over that tuple inside a
    ``while_loop`` whose body — including the ``random`` policy's PRNG
    draw — only executes on steps that actually evict, also under
    ``vmap``. (A fully vectorised sort-based "drop the ``occ - cap``
    lowest-ranked" variant was measured and rejected: batched ``cond``
    turns into ``select``, which forces the sort on every step.)
  * **traced cell parameters** — policy, prefetcher, capacity, and the
    valid-block count are runtime values (not Python branches), so one
    compiled scan per (batch, n_blocks, events) shape bucket serves every
    benchmark x policy x prefetch x oversubscription cell, and
    :func:`run_batch` ``vmap``s whole sweeps through it in a single scan.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.util import pow2_bucket
from repro.uvm.trace import PAGES_PER_BLOCK, Trace

CHUNK_BLOCKS = 32  # 2MB chunk = 32 x 64KB blocks
INTERVAL = 64  # page-set-chain interval, in faults (same as HPE)
NO_USE = np.int32(2**31 - 1)

POLICIES = ("lru", "random", "belady", "hpe", "learned")
PREFETCHERS = ("demand", "tree", "none")
POLICY_IDS = {"lru": 0, "random": 1, "belady": 2, "hpe": 3, "learned": 4}
PREFETCH_IDS = {"demand": 0, "tree": 1, "none": 0}


class SimState(NamedTuple):
    resident: jax.Array  # bool (NB,)
    pinned: jax.Array  # bool (NB,) zero-copy blocks (never migrated)
    evicted_once: jax.Array  # bool (NB,)
    last_access: jax.Array  # int32 (NB,)
    last_interval: jax.Array  # int32 (NB,)
    next_use: jax.Array  # int32 (NB,)
    freq: jax.Array  # int32 (NB,) prediction frequency (-1 = never predicted)
    occupancy: jax.Array  # int32
    fault_count: jax.Array  # int32
    thrash_events: jax.Array  # int32 (block-granular)
    migrations: jax.Array  # int32 blocks migrated
    faults: jax.Array  # int32 far-fault events
    zero_copy: jax.Array  # int32 remote accesses to pinned blocks
    time: jax.Array  # int32
    key: jax.Array


def init_state(n_blocks: int, seed: int = 0) -> SimState:
    z = jnp.zeros((), jnp.int32)
    return SimState(
        resident=jnp.zeros(n_blocks, bool),
        pinned=jnp.zeros(n_blocks, bool),
        evicted_once=jnp.zeros(n_blocks, bool),
        last_access=jnp.full(n_blocks, -1, jnp.int32),
        last_interval=jnp.full(n_blocks, -1, jnp.int32),
        next_use=jnp.full(n_blocks, NO_USE, jnp.int32),
        freq=jnp.full(n_blocks, -1, jnp.int32),
        occupancy=z,
        fault_count=z,
        thrash_events=z,
        migrations=z,
        faults=z,
        zero_copy=z,
        time=z,
        key=jax.random.key(seed),
    )


def _ensure_key(state: SimState) -> SimState:
    """Re-wrap ``key`` if it round-tripped through :func:`jax.random.key_data`.

    ``run()`` returns the state with the key flattened to raw ``uint32`` data
    (numpy-safe); feeding that state back in (the documented resume path)
    must restore the typed PRNG key or ``random`` eviction breaks.
    """
    key = jnp.asarray(state.key)
    if not jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.wrap_key_data(key)
    return state._replace(key=key)


def precompute_next_use(blocks: np.ndarray, n_blocks: int) -> np.ndarray:
    """next_use[t] = index of the next access to blocks[t] after t (else INF)."""
    b = np.asarray(blocks, np.int64)
    nxt = np.full(len(b), NO_USE, np.int64)
    if len(b):
        idx = np.arange(len(b))
        perm = np.lexsort((idx, b))  # positions grouped by block, time ascending
        same = b[perm][1:] == b[perm][:-1]
        nxt[perm[:-1][same]] = perm[1:][same]
    return np.minimum(nxt, NO_USE).astype(np.int32)


def next_use_for(trace: Trace) -> np.ndarray:
    """Per-trace cached :func:`precompute_next_use` (shared across cells)."""
    cached = getattr(trace, "_next_use_cache", None)
    if cached is None or len(cached) != len(trace):
        cached = precompute_next_use(trace.block.astype(np.int32), trace.n_blocks)
        trace._next_use_cache = cached
    return cached


class Events(NamedTuple):
    """Run-length-compressed access stream (host side).

    One event per maximal run of consecutive same-block accesses:
    ``blk`` the block, ``nxt`` the next-use index of the run's LAST access
    (the value ``next_use[blk]`` must hold after the run — the first
    access's value is only ever read for the protected block itself, so it
    cannot influence eviction), ``dt`` the run's first-access offset within
    the segment, ``rl`` the run length (0 marks a padding no-op event).
    """

    blk: np.ndarray  # int32 (E,)
    nxt: np.ndarray  # int32 (E,)
    dt: np.ndarray  # int32 (E,)
    rl: np.ndarray  # int32 (E,)
    n_access: int  # original segment length


def compress_events(blocks: np.ndarray, next_use: np.ndarray) -> Events:
    b = np.asarray(blocks, np.int32)
    n = len(b)
    if n == 0:
        e = np.zeros(0, np.int32)
        return Events(e, e, e, e, 0)
    change = np.empty(n, bool)
    change[0] = True
    np.not_equal(b[1:], b[:-1], out=change[1:])
    starts = np.nonzero(change)[0].astype(np.int32)
    run_len = np.diff(np.append(starts, n)).astype(np.int32)
    ends = starts + run_len - 1
    return Events(b[starts], np.asarray(next_use, np.int32)[ends], starts, run_len, n)


_bucket_pow2 = pow2_bucket


def bucket_blocks(n_valid: int) -> int:
    """Power-of-two state size >= pad_blocks(n_valid), so different
    benchmarks share one compiled scan. Padding blocks are never valid,
    never resident, and never migrated — they are inert. The 128 floor puts
    the entire quick-scale suite in ONE compile bucket (the padded per-step
    cost is noise next to a 1-2s XLA compile per extra shape)."""
    return _bucket_pow2(pad_blocks(n_valid), 128)


def _pad_events(ev: Events) -> Events:
    """Pad the event arrays to a power-of-two length with no-op (rl=0)
    events so scan lengths fall into a few compile buckets."""
    e = len(ev.blk)
    target = _bucket_pow2(e, 1024)
    if target == e:
        return ev
    pad = target - e

    def z(a):
        return np.concatenate([a, np.zeros(pad, np.int32)])

    return Events(z(ev.blk), z(ev.nxt), z(ev.dt), z(ev.rl), ev.n_access)


def _tree_mask(resident, blk, valid, n_blocks: int):
    """Blocks to prefetch per the tree-based neighbourhood prefetcher."""
    mask = jnp.zeros(n_blocks, bool)
    for size in (2, 4, 8, 16, CHUNK_BLOCKS):
        node = blk // size
        occ = resident.reshape(-1, size).sum(axis=1)[node]
        trigger = occ * 2 > size  # >50% of node valid
        in_node = (jnp.arange(n_blocks) // size) == node
        mask = mask | (in_node & trigger)
    return mask & valid & ~resident


def _policy_keys(state: SimState, policy_id, interval_now, t_now):
    """The policy's lexicographic victim-key tuple, padded to 3 int32 keys.

    Extra constant keys never change a lexicographic argmin, so every
    policy shares one (k1, k2, k3) shape and one sort."""
    la = state.last_access
    z = jnp.zeros_like(la)

    def k_lru():
        return la, z, z

    def k_random():
        r = jax.random.randint(jax.random.fold_in(state.key, t_now), la.shape, 0, 1 << 30, jnp.int32)
        return r, z, z

    def k_belady():
        return -state.next_use, z, z  # farthest next use evicted first

    def k_hpe():
        age = jnp.clip(interval_now - state.last_interval, 0, 2)  # 0=new..2=old
        return -age, la, z

    def k_learned():
        age = jnp.clip(interval_now - state.last_interval, 0, 2)
        return -age, state.freq, la

    return jax.lax.switch(policy_id, (k_lru, k_random, k_belady, k_hpe, k_learned))


def _lex_argmin(cand, *keys):
    """Index of the lexicographically-smallest key tuple among candidates."""
    for k in keys:
        kk = jnp.where(cand, k, jnp.iinfo(jnp.int32).max)
        cand = cand & (kk == kk.min())
    return jnp.argmax(cand)


def _evict_fit(state: SimState, capacity, policy_id, protect, interval_now, t_now) -> SimState:
    """Evict lowest-priority resident blocks until occupancy <= capacity.

    The victim keys are constant for the whole step (an eviction changes
    neither the remaining blocks' keys nor their evictability), so each
    victim is one chained masked-argmin over the precomputed tuple. The
    loop body — including the ``random`` policy's PRNG draw — only runs on
    steps that actually evict, which also holds under ``vmap`` (a batched
    ``while_loop`` skips the body once every lane's condition is false)."""
    base = ~state.pinned & ~protect

    def cond(c):
        resident, evicted_once, occ = c
        return (occ > capacity) & ((resident & base).any())

    def body(c):
        resident, evicted_once, occ = c
        k1, k2, k3 = _policy_keys(state, policy_id, interval_now, t_now)
        victim = _lex_argmin(resident & base, k1, k2, k3)
        return resident.at[victim].set(False), evicted_once.at[victim].set(True), occ - 1

    resident, evicted_once, occ = jax.lax.while_loop(
        cond, body, (state.resident, state.evicted_once, state.occupancy)
    )
    return state._replace(resident=resident, evicted_once=evicted_once, occupancy=occ)


def _scan_events(state: SimState, blk, nxt, dt, rl, capacity, policy_id, prefetch_id, n_valid):
    """One lane: scan the compressed event stream. All cell parameters are
    traced values — a single compile serves every (policy, prefetch,
    capacity, n_valid) combination of this shape."""
    n_blocks = state.resident.shape[0]
    iota = jnp.arange(n_blocks, dtype=jnp.int32)
    valid = iota < n_valid
    t0 = state.time

    def step(state: SimState, inp):
        b, nx, d, r = inp
        active = r > 0
        t_first = t0 + d
        t_last = t_first + r - 1
        is_pinned = state.pinned[b]
        fault = (~state.resident[b]) & (~is_pinned) & active

        # demand block migrates on fault; tree prefetch rides along
        mig = jnp.zeros(n_blocks, bool).at[b].set(fault)
        resident1 = state.resident | mig
        pf = jax.lax.cond(
            (prefetch_id == 1) & fault,
            lambda: _tree_mask(resident1, b, valid, n_blocks),
            lambda: jnp.zeros(n_blocks, bool),
        )
        mig = mig | pf
        newly = mig & ~state.resident
        n_new = newly.sum(dtype=jnp.int32)
        thrash = (newly & state.evicted_once).sum(dtype=jnp.int32)

        fault_i = fault.astype(jnp.int32)
        interval_now = state.fault_count // INTERVAL
        fc_after = state.fault_count + fault_i
        is_blk = (iota == b) & active

        # prefetched blocks count as freshly used by the DRIVER's LRU
        # (CUDA treats migrated pages as recently touched — otherwise LRU
        # instantly re-evicts them and the prefetcher ping-pongs); the
        # accessed block itself ends the run at its LAST touch.
        la = jnp.where(newly, t_first, state.last_access)
        la = jnp.where(is_blk, t_last, la)
        # ...but HPE's page-set chain only sees DEMAND touches: its
        # counters are not updated by prefetches (Section III-B — this is
        # precisely why Tree.+HPE collapses in Table II). The paper's own
        # engine ("learned") updates the chain with both (Section IV-D).
        li = jnp.where(jnp.where(policy_id == 4, newly, jnp.zeros_like(newly)), interval_now, state.last_interval)
        # repeat touches after a fault that crosses an interval boundary
        # land in the NEXT interval (the reference updates per access)
        li = jnp.where(is_blk, jnp.where(r > 1, fc_after // INTERVAL, interval_now), li)

        state2 = state._replace(
            resident=state.resident | newly,
            occupancy=state.occupancy + n_new,
            fault_count=fc_after,
            thrash_events=state.thrash_events + thrash,
            migrations=state.migrations + n_new,
            faults=state.faults + fault_i,
            zero_copy=state.zero_copy + is_pinned.astype(jnp.int32) * r,
            last_access=la,
            last_interval=li,
            next_use=jnp.where(is_blk, nx, state.next_use),
        )
        protect = jnp.zeros(n_blocks, bool).at[b].set(active)
        # padding events must not evict even if a caller handed us an
        # over-capacity state, so they see capacity == occupancy
        cap_eff = jnp.where(active, capacity, state2.occupancy)
        state3 = _evict_fit(state2, cap_eff, policy_id, protect, interval_now, t_first)
        out = {
            "fault": fault,
            "thrash": thrash,
            "was_evicted": state.evicted_once[b],
        }
        return state3._replace(time=jnp.where(active, t_last + 1, state.time)), out

    return jax.lax.scan(step, state, (blk, nxt, dt, rl))


@jax.jit
def _run_events(states, blk, nxt, dt, rl, capacity, policy_id, prefetch_id, n_valid):
    """Batched event scan: ``states`` and the cell parameters carry a
    leading lane axis; the event stream is shared across lanes."""
    return jax.vmap(
        lambda st, cap, pol, pf, nv: _scan_events(st, blk, nxt, dt, rl, cap, pol, pf, nv)
    )(states, capacity, policy_id, prefetch_id, n_valid)


def _stack_states(states: list[SimState]) -> SimState:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _lane(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


_INERT = ("lru", "demand")  # padding lane: huge capacity, cheapest policy


def _run_cells(
    states: list[SimState],
    ev: Events,
    cells: list[tuple[int, int, int]],  # (policy_id, prefetch_id, capacity)
    n_valid: int,
):
    """Run one compressed stream under many cells in a single vmapped scan.

    Lanes are padded to a power of two with inert no-evict lanes so batch
    sizes fall into a few compile buckets."""
    n_blocks = states[0].resident.shape[0]
    b_real = len(cells)
    # lane buckets {1, 8, 16, ...}: single runs stay cheap, sweeps share compiles
    b_pad = 1 if b_real == 1 else _bucket_pow2(b_real, 8)
    cells = list(cells) + [(POLICY_IDS[_INERT[0]], PREFETCH_IDS[_INERT[1]], n_blocks + 1)] * (b_pad - b_real)
    states = states + [init_state(n_blocks)] * (b_pad - b_real)
    ev = _pad_events(ev)
    pol = jnp.asarray(np.array([c[0] for c in cells], np.int32))
    pf = jnp.asarray(np.array([c[1] for c in cells], np.int32))
    cap = jnp.asarray(np.array([c[2] for c in cells], np.int32))
    nv = jnp.full(b_pad, n_valid, jnp.int32)
    out_states, outs = _run_events(
        _stack_states(states),
        jnp.asarray(ev.blk), jnp.asarray(ev.nxt), jnp.asarray(ev.dt), jnp.asarray(ev.rl),
        cap, pol, pf, nv,
    )
    return out_states, outs, b_real


def _decompress_outs(outs_lane: dict, ev: Events) -> dict:
    """Expand per-event scan outputs back to per-access arrays."""
    e = len(ev.blk)
    fault = np.zeros(ev.n_access, bool)
    thrash = np.zeros(ev.n_access, np.int32)
    ev_fault = np.asarray(outs_lane["fault"])[:e]
    ev_thrash = np.asarray(outs_lane["thrash"])[:e]
    ev_we = np.asarray(outs_lane["was_evicted"])[:e]
    fault[ev.dt] = ev_fault
    thrash[ev.dt] = ev_thrash
    was_evicted = np.repeat(ev_we, ev.rl)
    return {"fault": fault, "thrash": thrash, "was_evicted": was_evicted}


def run_segment(
    state: SimState,
    blocks: np.ndarray,
    next_use: np.ndarray,
    *,
    capacity: int,
    policy: str,
    prefetch: str,
    n_valid: int,
    want_outs: bool = True,
):
    """Run one trace segment (compress -> batched scan -> decompress)."""
    state = _ensure_key(state)
    ev = compress_events(blocks, next_use)
    if ev.n_access == 0:
        z = np.zeros(0)
        return state, {"fault": z.astype(bool), "thrash": z.astype(np.int32), "was_evicted": z.astype(bool)}
    cell = (POLICY_IDS[policy], PREFETCH_IDS[prefetch], int(capacity))
    out_states, outs, _ = _run_cells([state], ev, [cell], n_valid)
    st = _lane(out_states, 0)
    return st, (_decompress_outs(_lane(outs, 0), ev) if want_outs else None)


def _run_segment(state, blocks, next_use, n_blocks=None, capacity=None, policy=None, prefetch=None, n_valid=None, want_outs=True):
    """Back-compat wrapper with the pre-refactor keyword signature."""
    return run_segment(
        state, np.asarray(blocks), np.asarray(next_use),
        capacity=capacity, policy=policy, prefetch=prefetch, n_valid=n_valid, want_outs=want_outs,
    )


class SimResult(NamedTuple):
    state: SimState
    fault: np.ndarray
    thrash: np.ndarray
    was_evicted: np.ndarray

    @property
    def pages_thrashed(self) -> int:
        return int(self.state.thrash_events) * PAGES_PER_BLOCK

    @property
    def stats(self) -> dict:
        s = self.state
        return {
            "pages_thrashed": self.pages_thrashed,
            "faults": int(s.faults),
            "migrated_blocks": int(s.migrations),
            "zero_copy": int(s.zero_copy),
            "occupancy": int(s.occupancy),
        }


def capacity_for(n_blocks: int, oversubscription: float) -> int:
    """125% oversubscription => device memory = working set / 1.25."""
    return max(int(np.floor(n_blocks / oversubscription)), 1)


def pad_blocks(n_valid: int) -> int:
    return int(np.ceil(n_valid / CHUNK_BLOCKS) * CHUNK_BLOCKS)


def run(
    trace: Trace,
    *,
    policy: str = "lru",
    prefetch: str = "tree",
    oversubscription: float = 1.25,
    state: SimState | None = None,
    seed: int = 0,
) -> SimResult:
    """Run a full trace under (policy x prefetch) at an oversubscription level."""
    assert policy in POLICIES and prefetch in PREFETCHERS
    blocks = trace.block.astype(np.int32)
    cap = capacity_for(trace.n_blocks, oversubscription)
    nxt = next_use_for(trace)
    if state is not None:
        st = _ensure_key(jax.tree.map(jnp.asarray, state))
    else:
        st = init_state(bucket_blocks(trace.n_blocks), seed)
    st, outs = run_segment(
        st, blocks, nxt,
        capacity=cap, policy=policy,
        prefetch="demand" if prefetch == "none" else prefetch,
        n_valid=trace.n_blocks,
    )
    st = st._replace(key=jax.random.key_data(st.key))  # numpy-safe
    return SimResult(
        state=jax.tree.map(np.asarray, st),
        fault=outs["fault"],
        thrash=outs["thrash"],
        was_evicted=outs["was_evicted"],
    )


def run_batch(
    trace: Trace,
    cells: list[tuple[str, str, float]],
    *,
    seed: int = 0,
    seeds: list[int] | None = None,
) -> list[dict]:
    """Sweep many (policy, prefetch, oversubscription) cells over one trace
    in a single vmapped scan; returns one stats dict per cell, bit-identical
    (for non-``random`` policies) to running each cell through :func:`run`.
    """
    blocks = trace.block.astype(np.int32)
    nb = bucket_blocks(trace.n_blocks)
    ev = compress_events(blocks, next_use_for(trace))
    id_cells = []
    for policy, prefetch, oversub in cells:
        assert policy in POLICIES and prefetch in PREFETCHERS
        id_cells.append((
            POLICY_IDS[policy],
            PREFETCH_IDS["demand" if prefetch == "none" else prefetch],
            capacity_for(trace.n_blocks, oversub),
        ))
    lane_seeds = seeds if seeds is not None else [seed] * len(cells)
    states = [init_state(nb, s) for s in lane_seeds]
    out_states, _, b_real = _run_cells(states, ev, id_cells, trace.n_blocks)
    # one host sync for the whole sweep
    counters = jax.device_get({
        "thrash_events": out_states.thrash_events,
        "faults": out_states.faults,
        "migrations": out_states.migrations,
        "zero_copy": out_states.zero_copy,
        "occupancy": out_states.occupancy,
    })
    return [
        {
            "pages_thrashed": int(counters["thrash_events"][i]) * PAGES_PER_BLOCK,
            "faults": int(counters["faults"][i]),
            "migrated_blocks": int(counters["migrations"][i]),
            "zero_copy": int(counters["zero_copy"][i]),
            "occupancy": int(counters["occupancy"][i]),
        }
        for i in range(b_real)
    ]


@jax.jit
def _apply_prefetch_jit(state: SimState, mask, capacity, policy_id):
    newly = mask & ~state.resident & ~state.pinned
    n_new = newly.sum(dtype=jnp.int32)
    thrash = (newly & state.evicted_once).sum(dtype=jnp.int32)
    interval_now = state.fault_count // INTERVAL
    st = state._replace(
        resident=state.resident | newly,
        occupancy=state.occupancy + n_new,
        thrash_events=state.thrash_events + thrash,
        migrations=state.migrations + n_new,
        last_interval=jnp.where(newly, interval_now, state.last_interval),
        last_access=jnp.where(newly, state.time, state.last_access),
    )
    return _evict_fit(st, capacity, policy_id, jnp.zeros_like(newly), interval_now, state.time)


def apply_prefetch(state: SimState, blocks_mask, *, capacity: int, policy: str = "learned") -> SimState:
    """Stage externally-predicted prefetches (the learned runtime's async path)."""
    state = _ensure_key(state)
    return _apply_prefetch_jit(
        state, jnp.asarray(blocks_mask),
        jnp.asarray(capacity, jnp.int32), jnp.asarray(POLICY_IDS[policy], jnp.int32),
    )
