"""Trace-driven UVM device-memory simulator (the GPGPU-Sim replacement).

Pure-JAX ``lax.scan`` over the access stream with fixed-size per-block state
arrays (residency, LRU clocks, chain intervals, Belady next-use, learned
prediction frequency). Migration/eviction is at 64KB basic-block granularity
— the CUDA runtime's prefetch unit — and "pages thrashed" are reported as
blocks x 16 pages, matching the granularity of the paper's counters.

Eviction policies (Section II-C / IV-D):
    lru      — least-recently-used (CUDA driver default)
    random   — uniform random resident block
    belady   — MIN oracle (needs the precomputed next-use stream)
    hpe      — page-set chain (new/middle/old by fault interval) + LRU inside
    learned  — page-set chain + prediction-frequency table (the paper's engine)

Prefetchers (Section II-B):
    demand   — migrate only the faulted block
    tree     — NVIDIA tree-based neighbourhood prefetcher: after a migration,
               any [2,4,8,16,32]-block node above 50% valid occupancy gets its
               remaining blocks migrated
    none     — alias of demand; the learned prefetcher stages its blocks via
               :func:`apply_prefetch` between scan segments (async analogue)

Hot-path design — bit-identical to :mod:`repro.uvm.reference` for every
policy except ``random``: the random policy's victim draws are
``fold_in(key, t)`` over the padded block axis, so its draws (and therefore
its counters) depend on the padded state width, which the fast path is free
to change.  That padding-PRNG dependence is the ONE documented divergence;
every other policy's counters, per-access outputs and state arrays are
exact (see tests/test_properties.py and tests/test_sim_equivalence.py).

  * **fault-event compression** — consecutive accesses to the same block
    cannot fault after the first (the block was just migrated and is
    protected during its own step), so the trace is run-length-compressed
    on the host into per-run events carrying aggregate bookkeeping
    (final ``last_access``/``next_use``, pinned ``zero_copy`` mass, the
    interval-boundary fix-up for the page-set chain). The scan length
    shrinks by the repeat-run hit rate (1x-10x on the paper's suite).
  * **period-p event compression** — streaming traces interleave p arrays
    (block stream ``b0 b1 b2 b0 b1 b2 ...``), which plain RLE cannot
    shorten.  Fixed-period windows are detected host-side and each
    position's repeat occurrences merge into one stride-p aggregate event.
    Invariant: once the window's first period has run, a fault-free window
    stays fault-free (no fault => no migration => no eviction => residency
    frozen), so aggregates are pure bookkeeping.  Whether the window IS
    fault-free depends on runtime state, so it is verified in-scan (the
    ``pfault`` output); on divergence the segment transparently reruns on
    plain RLE events.  Compression is thus a pure scan-length optimisation
    with unconditionally exact counters (8x on AddVectors/StreamTriad).
  * **device-sharded sweeps** — multi-lane scans commit their lane axis to
    a 1-D mesh over ``jax.devices()`` when several devices are visible
    (``REPRO_SIM_SHARD=0`` disables); lanes are independent, so GSPMD
    partitions the sweep without communication and counters stay
    bit-identical to single-device runs.
  * **packed-priority eviction** — every policy's victim key is one
    uniform padded 3-tuple of int32 arrays (constant for the whole step:
    nothing an eviction changes feeds back into the keys), so victim
    selection is a chained masked-argmin over that tuple inside a
    ``while_loop`` whose body — including the ``random`` policy's PRNG
    draw — only executes on steps that actually evict, also under
    ``vmap``. (A fully vectorised sort-based "drop the ``occ - cap``
    lowest-ranked" variant was measured and rejected: batched ``cond``
    turns into ``select``, which forces the sort on every step.)
  * **Pallas victim selection** (``REPRO_SIM_KERNELS=1``, default off) —
    because the keys are constant per step, the whole multi-victim draw
    is one :mod:`repro.kernels.evict_select` kernel call: candidate mask
    + key tuple land in VMEM once and the chained masked-argmin loop runs
    in-core, instead of re-reading the state arrays per victim.  Counters
    are bit-identical to the scan path (the kernel runs the same loop;
    ``n_evict = min(occ - cap, candidates)`` and the victim SET is order
    free).  On CPU backends the kernel runs in interpret mode — same
    program as jnp ops, exercised by CI; compiled-path numbers are a
    TPU/GPU follow-up (BENCH_sim.json marks them pending).
  * **traced cell parameters** — policy, prefetcher, capacity, and the
    valid-block count are runtime values (not Python branches), so one
    compiled scan per (batch, n_blocks, events) shape bucket serves every
    benchmark x policy x prefetch x oversubscription cell, and
    :func:`run_batch` ``vmap``s whole sweeps through it in a single scan.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compat import lane_shardings
from repro.util import pow2_bucket
from repro.uvm import registry as _registry
from repro.uvm.registry import POLICY_IDS, PREFETCH_IDS
from repro.uvm.trace import PAGES_PER_BLOCK, Trace

CHUNK_BLOCKS = 32  # 2MB chunk = 32 x 64KB blocks
INTERVAL = 64  # page-set-chain interval, in faults (same as HPE)
NO_USE = np.int32(2**31 - 1)

# The BUILTIN strategy set (the paper's matrix). The LIVE set — builtins
# plus anything added via repro.uvm.api.register_policy/register_prefetcher
# — is registry.policy_names()/prefetcher_names(); POLICY_IDS/PREFETCH_IDS
# (imported from the registry) always reflect it.
POLICIES = ("lru", "random", "belady", "hpe", "learned")
PREFETCHERS = ("demand", "tree", "none")


class SimState(NamedTuple):
    resident: jax.Array  # bool (NB,)
    pinned: jax.Array  # bool (NB,) zero-copy blocks (never migrated)
    evicted_once: jax.Array  # bool (NB,)
    last_access: jax.Array  # int32 (NB,)
    last_interval: jax.Array  # int32 (NB,)
    next_use: jax.Array  # int32 (NB,)
    freq: jax.Array  # int32 (NB,) prediction frequency (-1 = never predicted)
    occupancy: jax.Array  # int32
    fault_count: jax.Array  # int32
    thrash_events: jax.Array  # int32 (block-granular)
    migrations: jax.Array  # int32 blocks migrated
    faults: jax.Array  # int32 far-fault events
    zero_copy: jax.Array  # int32 remote accesses to pinned blocks
    time: jax.Array  # int32
    key: jax.Array


def init_state(n_blocks: int, seed: int = 0) -> SimState:
    z = jnp.zeros((), jnp.int32)
    return SimState(
        resident=jnp.zeros(n_blocks, bool),
        pinned=jnp.zeros(n_blocks, bool),
        evicted_once=jnp.zeros(n_blocks, bool),
        last_access=jnp.full(n_blocks, -1, jnp.int32),
        last_interval=jnp.full(n_blocks, -1, jnp.int32),
        next_use=jnp.full(n_blocks, NO_USE, jnp.int32),
        freq=jnp.full(n_blocks, -1, jnp.int32),
        occupancy=z,
        fault_count=z,
        thrash_events=z,
        migrations=z,
        faults=z,
        zero_copy=z,
        time=z,
        key=jax.random.key(seed),
    )


def _ensure_key(state: SimState) -> SimState:
    """Re-wrap ``key`` if it round-tripped through :func:`jax.random.key_data`.

    ``run()`` returns the state with the key flattened to raw ``uint32`` data
    (numpy-safe); feeding that state back in (the documented resume path)
    must restore the typed PRNG key or ``random`` eviction breaks.
    """
    key = jnp.asarray(state.key)
    if not jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.wrap_key_data(key)
    return state._replace(key=key)


def precompute_next_use(blocks: np.ndarray, n_blocks: int) -> np.ndarray:
    """next_use[t] = index of the next access to blocks[t] after t (else INF)."""
    b = np.asarray(blocks, np.int64)
    nxt = np.full(len(b), NO_USE, np.int64)
    if len(b):
        idx = np.arange(len(b))
        perm = np.lexsort((idx, b))  # positions grouped by block, time ascending
        same = b[perm][1:] == b[perm][:-1]
        nxt[perm[:-1][same]] = perm[1:][same]
    return np.minimum(nxt, NO_USE).astype(np.int32)


def next_use_for(trace: Trace) -> np.ndarray:
    """Per-trace cached :func:`precompute_next_use` (shared across cells)."""
    cached = getattr(trace, "_next_use_cache", None)
    if cached is None or len(cached) != len(trace):
        cached = precompute_next_use(trace.block.astype(np.int32), trace.n_blocks)
        trace._next_use_cache = cached
    return cached


class Events(NamedTuple):
    """Compressed access stream (host side).

    One event covers ``rl`` accesses to block ``blk`` at segment offsets
    ``dt, dt + stride, ..., dt + (rl-1)*stride`` (``rl`` = 0 marks a padding
    no-op event).  ``nxt`` is the next-use index of the event's LAST covered
    access — the value ``next_use[blk]`` must hold after the event; earlier
    values are only ever read for the protected block itself, so they cannot
    influence eviction.  Two compression modes produce events:

    * ``stride == 1`` — a maximal run of consecutive same-block accesses.
      The block is protected during its own step, so accesses after the
      first cannot fault; merging them is unconditionally exact.
    * ``stride == p > 1`` — one position of a period-``p`` window (the
      ``_interleave`` idiom behind streaming traces): ``p`` distinct-ish
      blocks repeated ``r`` times.  The window's first period is emitted as
      ``p`` ordinary events; each position's remaining ``r-1`` occurrences
      are merged into one aggregate event.  Aggregates are exact ONLY if no
      covered access faults — verified at runtime via the ``pfault`` scan
      output; on divergence the caller reruns with ``periodic=False``
      (see :func:`run_segment` / :func:`run_batch`).
    """

    blk: np.ndarray  # int32 (E,)
    nxt: np.ndarray  # int32 (E,)
    dt: np.ndarray  # int32 (E,)
    rl: np.ndarray  # int32 (E,)
    stride: np.ndarray  # int32 (E,) access-index gap between covered accesses
    n_access: int  # original segment length


P_MAX = 8  # largest interleave period the host-side detector looks for
MIN_REPS = 4  # shortest window worth compressing (2p events vs ~r*p raw)


def _rle_parts(b: np.ndarray, nxt: np.ndarray, lo: int, hi: int):
    """Plain run-length events for the slice ``b[lo:hi]`` (stride == 1)."""
    n = hi - lo
    seg = b[lo:hi]
    change = np.empty(n, bool)
    change[0] = True
    np.not_equal(seg[1:], seg[:-1], out=change[1:])
    starts = (lo + np.nonzero(change)[0]).astype(np.int32)
    run_len = np.diff(np.append(starts, hi)).astype(np.int32)
    ends = starts + run_len - 1
    return seg[change], nxt[ends], starts, run_len, np.ones(len(starts), np.int32)


def _periodic_windows(b: np.ndarray) -> list[tuple[int, int, int]]:
    """Detect non-overlapping fixed-period windows: ``(start, p, reps)``.

    A window matches when ``b[t] == b[t-p]`` over its whole span.  Smaller
    periods claim coverage first; a window is kept only when its 2p events
    beat the run count plain RLE would emit for the same span.
    """
    n = len(b)
    covered = np.zeros(n, bool)
    boundary = np.empty(n, bool)  # boundary[i]: run starts at i (for the RLE-win check)
    boundary[0] = True
    np.not_equal(b[1:], b[:-1], out=boundary[1:])
    run_count = np.concatenate([[0], np.cumsum(boundary)])  # runs in b[:i] = run_count[i]
    wins = []
    for p in range(2, P_MAX + 1):
        if n < MIN_REPS * p:
            break
        m = b[p:] == b[:-p]
        edges = np.flatnonzero(np.diff(np.concatenate([[False], m, [False]]).astype(np.int8)))
        for s, e_m in zip(edges[0::2], edges[1::2]):
            length = (e_m - s) + p  # accesses b[s : s+length] are period-p
            if covered[s : s + length].any():
                bad = np.flatnonzero(covered[s : s + length])
                length = int(bad[0])
            r = length // p
            if r < MIN_REPS:
                continue
            length = r * p
            # worth it only if RLE would emit more than our 2p events
            if run_count[s + length] - run_count[s] <= 2 * p:
                continue
            covered[s : s + length] = True
            wins.append((int(s), p, r))
    wins.sort()
    return wins


def compress_events(blocks: np.ndarray, next_use: np.ndarray, *, periodic: bool = False) -> Events:
    b = np.asarray(blocks, np.int32)
    nxt_arr = np.asarray(next_use, np.int32)
    n = len(b)
    if n == 0:
        e = np.zeros(0, np.int32)
        return Events(e, e, e, e, e, 0)
    wins = _periodic_windows(b) if periodic else []
    if not wins:
        return Events(*_rle_parts(b, nxt_arr, 0, n), n)
    parts = []
    pos = 0
    for s, p, r in wins:
        if pos < s:
            parts.append(_rle_parts(b, nxt_arr, pos, s))
        j = np.arange(p, dtype=np.int32)
        ones = np.ones(p, np.int32)
        # first period: ordinary events (these may fault and evict)
        parts.append((b[s + j], nxt_arr[s + j], (s + j).astype(np.int32), ones, ones))
        # aggregates: position j's occurrences 2..r, spaced p apart
        parts.append((
            b[s + j],
            nxt_arr[s + (r - 1) * p + j],  # next use after the LAST occurrence
            (s + p + j).astype(np.int32),
            np.full(p, r - 1, np.int32),
            np.full(p, p, np.int32),
        ))
        pos = s + r * p
    if pos < n:
        parts.append(_rle_parts(b, nxt_arr, pos, n))
    cat = [np.concatenate([pt[i] for pt in parts]) for i in range(5)]
    return Events(*cat, n)


_bucket_pow2 = pow2_bucket


def bucket_blocks(n_valid: int) -> int:
    """Power-of-two state size >= pad_blocks(n_valid), so different
    benchmarks share one compiled scan. Padding blocks are never valid,
    never resident, and never migrated — they are inert. The 128 floor puts
    the entire quick-scale suite in ONE compile bucket (the padded per-step
    cost is noise next to a 1-2s XLA compile per extra shape)."""
    return _bucket_pow2(pad_blocks(n_valid), 128)


def _pad_events(ev: Events) -> Events:
    """Pad the event arrays to a power-of-two length with no-op (rl=0)
    events so scan lengths fall into a few compile buckets."""
    e = len(ev.blk)
    target = _bucket_pow2(e, 1024)
    if target == e:
        return ev
    pad = target - e

    def z(a, fill=0):
        return np.concatenate([a, np.full(pad, fill, np.int32)])

    return Events(z(ev.blk), z(ev.nxt), z(ev.dt), z(ev.rl), z(ev.stride, 1), ev.n_access)


def _tree_mask(resident, blk, valid, n_blocks: int):
    """Blocks to prefetch per the tree-based neighbourhood prefetcher."""
    mask = jnp.zeros(n_blocks, bool)
    for size in (2, 4, 8, 16, CHUNK_BLOCKS):
        node = blk // size
        occ = resident.reshape(-1, size).sum(axis=1)[node]
        trigger = occ * 2 > size  # >50% of node valid
        in_node = (jnp.arange(n_blocks) // size) == node
        mask = mask | (in_node & trigger)
    return mask & valid & ~resident


def _lru_keys(state: SimState, interval_now, t_now):
    return (state.last_access,)


def _random_keys(state: SimState, interval_now, t_now):
    r = jax.random.randint(
        jax.random.fold_in(state.key, t_now), state.last_access.shape, 0, 1 << 30, jnp.int32
    )
    return (r,)


def _belady_keys(state: SimState, interval_now, t_now):
    return (-state.next_use,)  # farthest next use evicted first


def _hpe_keys(state: SimState, interval_now, t_now):
    age = jnp.clip(interval_now - state.last_interval, 0, 2)  # 0=new..2=old
    return (-age, state.last_access)


def _learned_keys(state: SimState, interval_now, t_now):
    age = jnp.clip(interval_now - state.last_interval, 0, 2)
    return (-age, state.freq, state.last_access)


def _policy_keys(state: SimState, policy_id, interval_now, t_now, policy_fns: tuple | None = None):
    """The policy's lexicographic victim-key tuple, padded to 3 int32 keys.

    ``policy_fns`` is the registry branch table (builtins ride the same
    path a `register_policy` entry does) — passed down from the jit-cache
    key so the compiled switch always matches the table it was keyed on;
    ``None`` falls back to the live registry (direct/untraced callers).
    Extra constant keys never change a lexicographic argmin, so every
    policy shares one (k1, k2, k3) shape and one sort."""
    z = jnp.zeros_like(state.last_access)

    def pad(fn):
        def branch():
            ks = tuple(fn(state, interval_now, t_now))
            if not 1 <= len(ks) <= 3:
                raise ValueError(f"policy key_fn must return 1-3 keys, got {len(ks)}")
            ks = tuple(jnp.asarray(k, jnp.int32) for k in ks)
            return ks + (z,) * (3 - len(ks))

        return branch

    fns = policy_fns if policy_fns is not None else _registry.policy_branches()
    return jax.lax.switch(policy_id, tuple(pad(fn) for fn in fns))


def _lex_argmin(cand, *keys):
    """Index of the lexicographically-smallest key tuple among candidates."""
    for k in keys:
        kk = jnp.where(cand, k, jnp.iinfo(jnp.int32).max)
        cand = cand & (kk == kk.min())
    return jnp.argmax(cand)


def sim_kernels_enabled() -> bool:
    """Default for the ``kernels=None`` arguments: REPRO_SIM_KERNELS=1 routes
    victim selection through the Pallas kernel (and the manager's freq table
    through its kernelized subclass — see :mod:`repro.uvm.manager.core`)."""
    return os.environ.get("REPRO_SIM_KERNELS", "0").lower() not in ("0", "", "false")


def _kernel_interpret() -> bool:
    """Pallas interpret mode runs the kernels as jnp ops on backends with no
    Mosaic lowering (CPU CI) — bit-identical, just not faster."""
    return jax.default_backend() == "cpu"


def _evict_fit(state: SimState, capacity, policy_id, protect, interval_now, t_now,
               policy_fns: tuple | None = None, evict_pref=None,
               kernels: bool = False, interpret: bool = False) -> SimState:
    """Evict lowest-priority resident blocks until occupancy <= capacity.

    The victim keys are constant for the whole step (an eviction changes
    neither the remaining blocks' keys nor their evictability), so each
    victim is one chained masked-argmin over the precomputed tuple. The
    loop body — including the ``random`` policy's PRNG draw — only runs on
    steps that actually evict, which also holds under ``vmap`` (a batched
    ``while_loop`` skips the body once every lane's condition is false).

    ``evict_pref`` (optional int32 per-block array, constant for the step
    like every other key) is the QoS budget tier: it is prepended as the
    LEADING lexicographic key, so lower-preference blocks (an over-budget
    tenant's) are exhausted before ANY higher-preference block is
    considered, whatever the policy's own keys say.  ``None`` (the
    default) traces the exact pre-QoS program — bit-identical counters.

    ``kernels=True`` (a Python-static flag, part of the jit-cache key)
    replaces the while_loop with ONE :mod:`repro.kernels.evict_select`
    call selecting all ``min(max(occ - capacity, 0), |candidates|)``
    victims in-core.  Bit-identical because the keys are constant for the
    step (the ``random`` policy's draw is a pure ``fold_in`` — computing
    it once for n victims equals computing it n times) and the resulting
    resident/evicted_once/occupancy updates are victim-order free."""
    base = ~state.pinned & ~protect

    if kernels:
        from repro.kernels.evict_select import ops as _evict_ops

        cand = state.resident & base
        k1, k2, k3 = _policy_keys(state, policy_id, interval_now, t_now, policy_fns)
        keys = (k1, k2, k3) if evict_pref is None else (evict_pref, k1, k2, k3)
        n_evict = jnp.minimum(
            jnp.maximum(state.occupancy - capacity, 0), cand.sum(dtype=jnp.int32)
        )
        vict = _evict_ops.evict_select(cand, keys, n_evict, use_kernel=True, interpret=interpret)
        return state._replace(
            resident=state.resident & ~vict,
            evicted_once=state.evicted_once | vict,
            occupancy=state.occupancy - vict.sum(dtype=jnp.int32),
        )

    def cond(c):
        resident, evicted_once, occ = c
        return (occ > capacity) & ((resident & base).any())

    def body(c):
        resident, evicted_once, occ = c
        k1, k2, k3 = _policy_keys(state, policy_id, interval_now, t_now, policy_fns)
        keys = (k1, k2, k3) if evict_pref is None else (evict_pref, k1, k2, k3)
        victim = _lex_argmin(resident & base, *keys)
        return resident.at[victim].set(False), evicted_once.at[victim].set(True), occ - 1

    resident, evicted_once, occ = jax.lax.while_loop(
        cond, body, (state.resident, state.evicted_once, state.occupancy)
    )
    return state._replace(resident=resident, evicted_once=evicted_once, occupancy=occ)


def _scan_events(state: SimState, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                 policy_fns: tuple | None = None, prefetch_fns: tuple | None = None,
                 evict_pref=None, kernels: bool = False, interpret: bool = False):
    """One lane: scan the compressed event stream. All cell parameters are
    traced values — a single compile serves every (policy, prefetch,
    capacity, n_valid) combination of this shape. ``policy_fns`` /
    ``prefetch_fns`` are the registry branch tables the caller keyed its
    jit cache on (``None`` reads the live registry); ``evict_pref`` is the
    optional QoS leading victim key, constant for the whole segment (see
    :func:`_evict_fit`)."""
    n_blocks = state.resident.shape[0]
    iota = jnp.arange(n_blocks, dtype=jnp.int32)
    valid = iota < n_valid
    t0 = state.time

    def step(state: SimState, inp):
        b, nx, d, r, sd = inp
        active = r > 0
        t_first = t0 + d
        t_last = t_first + (r - 1) * sd
        is_pinned = state.pinned[b]
        fault = (~state.resident[b]) & (~is_pinned) & active

        # demand block migrates on fault; the registered prefetcher's mask
        # rides along (branch 0 — demand — migrates nothing extra)
        mig = jnp.zeros(n_blocks, bool).at[b].set(fault)
        resident1 = state.resident | mig
        zeros = lambda: jnp.zeros(n_blocks, bool)
        pf_fns = prefetch_fns if prefetch_fns is not None else _registry.prefetch_branches()
        branches = tuple(
            zeros if fn is None else (lambda fn=fn: fn(resident1, b, valid, n_blocks))
            for fn in pf_fns
        )
        pf = jax.lax.cond(fault, lambda: jax.lax.switch(prefetch_id, branches), zeros)
        mig = mig | pf
        newly = mig & ~state.resident
        n_new = newly.sum(dtype=jnp.int32)
        thrash = (newly & state.evicted_once).sum(dtype=jnp.int32)

        fault_i = fault.astype(jnp.int32)
        interval_now = state.fault_count // INTERVAL
        fc_after = state.fault_count + fault_i
        is_blk = (iota == b) & active

        # prefetched blocks count as freshly used by the DRIVER's LRU
        # (CUDA treats migrated pages as recently touched — otherwise LRU
        # instantly re-evicts them and the prefetcher ping-pongs); the
        # accessed block itself ends the run at its LAST touch.
        la = jnp.where(newly, t_first, state.last_access)
        la = jnp.where(is_blk, t_last, la)
        # ...but HPE's page-set chain only sees DEMAND touches: its
        # counters are not updated by prefetches (Section III-B — this is
        # precisely why Tree.+HPE collapses in Table II). The paper's own
        # engine ("learned") updates the chain with both (Section IV-D).
        li = jnp.where(jnp.where(policy_id == 4, newly, jnp.zeros_like(newly)), interval_now, state.last_interval)
        # repeat touches after a fault that crosses an interval boundary
        # land in the NEXT interval (the reference updates per access)
        li = jnp.where(is_blk, jnp.where(r > 1, fc_after // INTERVAL, interval_now), li)

        state2 = state._replace(
            resident=state.resident | newly,
            occupancy=state.occupancy + n_new,
            fault_count=fc_after,
            thrash_events=state.thrash_events + thrash,
            migrations=state.migrations + n_new,
            faults=state.faults + fault_i,
            zero_copy=state.zero_copy + is_pinned.astype(jnp.int32) * r,
            last_access=la,
            last_interval=li,
            next_use=jnp.where(is_blk, nx, state.next_use),
        )
        protect = jnp.zeros(n_blocks, bool).at[b].set(active)
        # padding events must not evict even if a caller handed us an
        # over-capacity state, so they see capacity == occupancy
        cap_eff = jnp.where(active, capacity, state2.occupancy)
        state3 = _evict_fit(state2, cap_eff, policy_id, protect, interval_now, t_first, policy_fns,
                            evict_pref, kernels, interpret)
        out = {
            "fault": fault,
            "thrash": thrash,
            "was_evicted": state.evicted_once[b],
            # a faulting periodic aggregate breaks the no-fault merge
            # assumption: the caller must rerun with plain RLE events
            "pfault": fault & (sd > 1),
        }
        return state3._replace(time=jnp.where(active, t_last + 1, state.time)), out

    return jax.lax.scan(step, state, (blk, nxt, dt, rl, stride))


@functools.lru_cache(maxsize=None)
def _jits_for(policy_fns: tuple, prefetch_fns: tuple, kernels: bool = False,
              interpret: bool = False):
    """The simulator's jitted entry points, keyed on the registry's branch
    tables (the ordered tuples of key/mask builder functions) plus the
    Pallas-kernel selection flags — the kernel and scan paths are distinct
    traced programs, so they get distinct compile caches.

    ``lax.switch`` clamps out-of-range indices, so a scan compiled under
    one table would silently run the wrong strategy for an id added later.
    The key tuples are CLOSED OVER by the traced scans (never re-read from
    the live registry), so key and compiled switch cannot disagree; keying
    on the table contents forces a fresh trace whenever the tables change
    AND re-hits the original compile when a ``registry.scoped()`` block
    restores them (the cache keys keep the builder functions alive, so
    identity can never be recycled onto a different function)."""

    def scan(st, blk, nxt, dt, rl, stride, cap, pol, pf, nv, ep=None):
        # the cache-key tables are CLOSED OVER here, so the compiled switch
        # can never disagree with the key (a concurrent registration between
        # key computation and tracing would otherwise alias)
        return _scan_events(st, blk, nxt, dt, rl, stride, cap, pol, pf, nv, policy_fns, prefetch_fns, ep,
                            kernels, interpret)

    # ``evict_pref=None`` is an empty pytree to jit, so the budget-free call
    # traces the EXACT pre-QoS program (not a zeros-keyed variant) — the
    # goldens pin that path bit for bit, and budget-free runs pay nothing.
    @jax.jit
    def run_events(states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                   evict_pref=None):
        if evict_pref is None:
            return jax.vmap(
                lambda st, cap, pol, pf, nv: scan(st, blk, nxt, dt, rl, stride, cap, pol, pf, nv)
            )(states, capacity, policy_id, prefetch_id, n_valid)
        return jax.vmap(
            lambda st, cap, pol, pf, nv, ep: scan(st, blk, nxt, dt, rl, stride, cap, pol, pf, nv, ep)
        )(states, capacity, policy_id, prefetch_id, n_valid, evict_pref)

    @jax.jit
    def run_events_lanes(states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                         evict_pref=None):
        if evict_pref is None:
            return jax.vmap(scan)(states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid)
        return jax.vmap(scan)(states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                              evict_pref)

    @jax.jit
    def apply_prefetch(state, mask, capacity, policy_id, evict_pref=None):
        newly = mask & ~state.resident & ~state.pinned
        n_new = newly.sum(dtype=jnp.int32)
        thrash = (newly & state.evicted_once).sum(dtype=jnp.int32)
        interval_now = state.fault_count // INTERVAL
        st = state._replace(
            resident=state.resident | newly,
            occupancy=state.occupancy + n_new,
            thrash_events=state.thrash_events + thrash,
            migrations=state.migrations + n_new,
            last_interval=jnp.where(newly, interval_now, state.last_interval),
            last_access=jnp.where(newly, state.time, state.last_access),
        )
        return _evict_fit(st, capacity, policy_id, jnp.zeros_like(newly), interval_now, state.time, policy_fns,
                          evict_pref, kernels, interpret)

    return run_events, run_events_lanes, apply_prefetch


def _jits(kernels: bool | None = None):
    """Resolve the jit triple for the requested eviction path.

    ``kernels=None`` reads :func:`sim_kernels_enabled` (the env default);
    an explicit bool pins the path regardless of environment.  Interpret
    mode is auto-selected per backend — callers never choose it."""
    k = sim_kernels_enabled() if kernels is None else bool(kernels)
    return _jits_for(_registry.policy_branches(), _registry.prefetch_branches(),
                     k, _kernel_interpret() if k else False)


def _run_events(states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                evict_pref=None, kernels: bool | None = None):
    """Batched event scan: ``states`` and the cell parameters carry a
    leading lane axis; the event stream is shared across lanes."""
    return _jits(kernels)[0](states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                             evict_pref)


def _stack_states(states: list[SimState]) -> SimState:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _lane(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


_INERT = ("lru", "demand")  # padding lane: huge capacity, cheapest policy


def _shard_lanes(stacked: SimState, lane_arrays: tuple, rep_arrays: tuple, b_pad: int):
    """Commit lane-stacked inputs to a cross-device lanes sharding.

    Lanes are fully independent, so GSPMD partitions the whole vmapped scan
    with no communication (the batched ``while_loop`` condition is the only
    cross-lane reduction).  No-ops on a single device, on an indivisible
    lane/device ratio, or with REPRO_SIM_SHARD=0 (checked inside
    :func:`lane_shardings`); any device_put failure (e.g. typed PRNG keys
    on an odd backend) falls back to unsharded execution — results are
    bit-identical either way, lanes just stop overlapping across devices."""
    lane_sh, rep_sh = lane_shardings(b_pad)
    if lane_sh is None:
        return stacked, lane_arrays, rep_arrays
    try:
        st = jax.tree.map(lambda x: jax.device_put(x, lane_sh), stacked)
        la = tuple(jax.device_put(x, lane_sh) for x in lane_arrays)
        ra = tuple(jax.device_put(x, rep_sh) for x in rep_arrays)
        return st, la, ra
    except Exception:
        return stacked, lane_arrays, rep_arrays


def _run_cells(
    states: list[SimState],
    ev: Events,
    cells: list[tuple[int, int, int]],  # (policy_id, prefetch_id, capacity)
    n_valid: int,
    evict_prefs: list | None = None,
    kernels: bool | None = None,
):
    """Run one compressed stream under many cells in a single vmapped scan.

    Lanes are padded to a power of two with inert no-evict lanes so batch
    sizes fall into a few compile buckets; when several devices are
    visible, lanes are sharded across them (see :func:`_shard_lanes`).
    ``evict_prefs`` (optional, one per cell, ``None`` entries = no budget)
    stacks into the per-lane QoS leading victim key; padding lanes and
    ``None`` entries ride as all-zero rows.  That fill is safe even for
    controllers emitting NEGATIVE prefs: a ``None`` lane's row is uniform
    (a constant leading key never changes an argmin), and within a real
    lane only the tail BEYOND ``len(pref)`` is zero-filled — those are
    padding blocks, which are never resident and so never candidates
    (tests/test_properties.py::test_evict_pref_padding_invariant pins
    this against mixed negative/``None``-interleaved lanes)."""
    n_blocks = states[0].resident.shape[0]
    b_real = len(cells)
    # lane buckets {1, 8, 16, ...}: single runs stay cheap, sweeps share compiles
    b_pad = 1 if b_real == 1 else _bucket_pow2(b_real, 8)
    cells = list(cells) + [(POLICY_IDS[_INERT[0]], PREFETCH_IDS[_INERT[1]], n_blocks + 1)] * (b_pad - b_real)
    states = states + [init_state(n_blocks)] * (b_pad - b_real)
    ev = _pad_events(ev)
    pol = jnp.asarray(np.array([c[0] for c in cells], np.int32))
    pf = jnp.asarray(np.array([c[1] for c in cells], np.int32))
    cap = jnp.asarray(np.array([c[2] for c in cells], np.int32))
    nv = jnp.full(b_pad, n_valid, jnp.int32)
    ep = None
    if evict_prefs is not None and any(p is not None for p in evict_prefs):
        ep = np.zeros((b_pad, n_blocks), np.int32)
        for i, p in enumerate(evict_prefs):
            if p is not None:
                ep[i, : len(p)] = np.asarray(p, np.int32)
        ep = jnp.asarray(ep)
    evs = tuple(jnp.asarray(getattr(ev, f)) for f in ("blk", "nxt", "dt", "rl", "stride"))
    if ep is None:
        stacked, (cap, pol, pf, nv), evs = _shard_lanes(_stack_states(states), (cap, pol, pf, nv), evs, b_pad)
    else:
        stacked, (cap, pol, pf, nv, ep), evs = _shard_lanes(
            _stack_states(states), (cap, pol, pf, nv, ep), evs, b_pad)
    out_states, outs = _run_events(stacked, *evs, cap, pol, pf, nv, ep, kernels)
    return out_states, outs, b_real


def _decompress_outs(outs_lane: dict, ev: Events) -> dict:
    """Expand per-event scan outputs back to per-access arrays.

    Periodic aggregates cover interleaved (non-contiguous) access indices,
    so per-access values are scattered to ``dt + k*stride`` rather than
    repeated contiguously."""
    e = len(ev.blk)
    fault = np.zeros(ev.n_access, bool)
    thrash = np.zeros(ev.n_access, np.int32)
    ev_fault = np.asarray(outs_lane["fault"])[:e]
    ev_thrash = np.asarray(outs_lane["thrash"])[:e]
    ev_we = np.asarray(outs_lane["was_evicted"])[:e]
    fault[ev.dt] = ev_fault
    thrash[ev.dt] = ev_thrash
    was_evicted = np.zeros(ev.n_access, bool)
    intra = np.arange(int(ev.rl.sum())) - np.repeat(np.cumsum(ev.rl) - ev.rl, ev.rl)
    pos = np.repeat(ev.dt, ev.rl) + intra * np.repeat(ev.stride, ev.rl)
    was_evicted[pos] = np.repeat(ev_we, ev.rl)
    return {"fault": fault, "thrash": thrash, "was_evicted": was_evicted}


def run_segment(
    state: SimState,
    blocks: np.ndarray,
    next_use: np.ndarray,
    *,
    capacity: int,
    policy: str,
    prefetch: str,
    n_valid: int,
    want_outs: bool = True,
    evict_pref: np.ndarray | None = None,
    kernels: bool | None = None,
):
    """Run one trace segment (compress -> batched scan -> decompress).

    Period-p compression is attempted first; if any periodic aggregate
    faulted (its merged occurrences are then not provably fault-free), the
    segment is rerun with plain run-length events — so the returned
    counters are always bit-identical to the per-access reference.

    ``evict_pref`` (optional int32 per-block array) is the QoS budget
    tier prepended as the LEADING victim key for the whole segment —
    lower values evict first (see :func:`_evict_fit`); budgets are
    per-segment constants, recomputed by the caller between segments.

    ``kernels`` selects the Pallas victim-selection path (``None`` =
    the ``REPRO_SIM_KERNELS`` env default) — counters are bit-identical
    either way (see :func:`_evict_fit`).
    """
    state = _ensure_key(state)
    blocks = np.asarray(blocks)
    next_use = np.asarray(next_use)
    cell = (POLICY_IDS[policy], PREFETCH_IDS[prefetch], int(capacity))
    for periodic in (True, False):
        ev = compress_events(blocks, next_use, periodic=periodic)
        if ev.n_access == 0:
            z = np.zeros(0)
            return state, {"fault": z.astype(bool), "thrash": z.astype(np.int32), "was_evicted": z.astype(bool)}
        out_states, outs, _ = _run_cells([state], ev, [cell], n_valid,
                                         None if evict_pref is None else [evict_pref], kernels)
        lane = _lane(outs, 0)
        if periodic and (ev.stride > 1).any() and bool(np.asarray(lane["pfault"]).any()):
            continue  # divergence: a merged occurrence may have faulted
        st = _lane(out_states, 0)
        return st, (_decompress_outs(lane, ev) if want_outs else None)


def _run_segment(state, blocks, next_use, n_blocks=None, capacity=None, policy=None, prefetch=None, n_valid=None, want_outs=True):
    """Back-compat wrapper with the pre-refactor keyword signature."""
    return run_segment(
        state, np.asarray(blocks), np.asarray(next_use),
        capacity=capacity, policy=policy, prefetch=prefetch, n_valid=n_valid, want_outs=want_outs,
    )


class SimResult(NamedTuple):
    state: SimState
    fault: np.ndarray
    thrash: np.ndarray
    was_evicted: np.ndarray

    @property
    def pages_thrashed(self) -> int:
        return int(self.state.thrash_events) * PAGES_PER_BLOCK

    @property
    def stats(self) -> dict:
        s = self.state
        return {
            "pages_thrashed": self.pages_thrashed,
            "faults": int(s.faults),
            "migrated_blocks": int(s.migrations),
            "zero_copy": int(s.zero_copy),
            "occupancy": int(s.occupancy),
        }


def capacity_for(n_blocks: int, oversubscription: float) -> int:
    """125% oversubscription => device memory = working set / 1.25."""
    return max(int(np.floor(n_blocks / oversubscription)), 1)


def pad_blocks(n_valid: int) -> int:
    return int(np.ceil(n_valid / CHUNK_BLOCKS) * CHUNK_BLOCKS)


def run(
    trace: Trace,
    *,
    policy: str = "lru",
    prefetch: str = "tree",
    oversubscription: float = 1.25,
    state: SimState | None = None,
    seed: int = 0,
    kernels: bool | None = None,
) -> SimResult:
    """Run a full trace under (policy x prefetch) at an oversubscription level."""
    assert policy in POLICY_IDS and prefetch in PREFETCH_IDS, (policy, prefetch)
    blocks = trace.block.astype(np.int32)
    cap = capacity_for(trace.n_blocks, oversubscription)
    nxt = next_use_for(trace)
    if state is not None:
        st = _ensure_key(jax.tree.map(jnp.asarray, state))
    else:
        st = init_state(bucket_blocks(trace.n_blocks), seed)
    st, outs = run_segment(
        st, blocks, nxt,
        capacity=cap, policy=policy,
        prefetch=prefetch,  # "none" aliases demand's id in the registry
        n_valid=trace.n_blocks,
        kernels=kernels,
    )
    st = st._replace(key=jax.random.key_data(st.key))  # numpy-safe
    return SimResult(
        state=jax.tree.map(np.asarray, st),
        fault=outs["fault"],
        thrash=outs["thrash"],
        was_evicted=outs["was_evicted"],
    )


def run_batch(
    trace: Trace,
    cells: list[tuple[str, str, float]],
    *,
    seed: int = 0,
    seeds: list[int] | None = None,
    kernels: bool | None = None,
) -> list[dict]:
    """Sweep many (policy, prefetch, oversubscription) cells over one trace
    in a single vmapped scan; returns one stats dict per cell, bit-identical
    (for non-``random`` policies) to running each cell through :func:`run`.
    """
    blocks = trace.block.astype(np.int32)
    nb = bucket_blocks(trace.n_blocks)
    nxt = next_use_for(trace)
    id_cells = []
    for policy, prefetch, oversub in cells:
        assert policy in POLICY_IDS and prefetch in PREFETCH_IDS, (policy, prefetch)
        id_cells.append((
            POLICY_IDS[policy],  # "none" aliases demand's id in the registry
            PREFETCH_IDS[prefetch],
            capacity_for(trace.n_blocks, oversub),
        ))
    lane_seeds = seeds if seeds is not None else [seed] * len(cells)
    states = [init_state(nb, s) for s in lane_seeds]
    for periodic in (True, False):
        ev = compress_events(blocks, nxt, periodic=periodic)
        out_states, outs, b_real = _run_cells(states, ev, id_cells, trace.n_blocks, kernels=kernels)
        if periodic and (ev.stride > 1).any() and bool(np.asarray(jnp.any(outs["pfault"]))):
            continue  # some lane's periodic merge diverged: rerun all on RLE
        break
    # one host sync for the whole sweep
    counters = jax.device_get({
        "thrash_events": out_states.thrash_events,
        "faults": out_states.faults,
        "migrations": out_states.migrations,
        "zero_copy": out_states.zero_copy,
        "occupancy": out_states.occupancy,
    })
    return [
        {
            "pages_thrashed": int(counters["thrash_events"][i]) * PAGES_PER_BLOCK,
            "faults": int(counters["faults"][i]),
            "migrated_blocks": int(counters["migrations"][i]),
            "zero_copy": int(counters["zero_copy"][i]),
            "occupancy": int(counters["occupancy"][i]),
        }
        for i in range(b_real)
    ]


def _run_events_lanes(states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                      evict_pref=None, kernels: bool | None = None):
    """Batched event scan where EVERY input carries a leading lane axis —
    unlike :func:`_run_events`, each lane walks its OWN event stream (the
    cross-benchmark case: different traces, same shape bucket)."""
    return _jits(kernels)[1](states, blk, nxt, dt, rl, stride, capacity, policy_id, prefetch_id, n_valid,
                             evict_pref)


def run_segments_many(
    states: list[SimState],
    segments: list[tuple[np.ndarray, np.ndarray]],  # (blocks, next_use) per lane
    cells: list[tuple[int, int, int]],  # (policy_id, prefetch_id, capacity) per lane
    n_valids: list[int],
    *,
    want_outs: bool = True,
    evict_prefs: list | None = None,
    kernels: bool | None = None,
) -> list[tuple[SimState, dict | None]]:
    """Run one trace segment per lane in bucketed vmapped scans.

    Lanes are grouped by (state width, padded event length); each group runs
    as ONE vmapped scan over stacked per-lane event streams (short lanes are
    padded with no-op events).  Lanes whose periodic aggregates diverged are
    rerun individually on plain RLE events, so every lane's counters stay
    bit-identical to the reference regardless of batching.

    ``evict_prefs`` (optional, one entry per lane, ``None`` = no budget)
    carries each lane's QoS leading victim key (see :func:`run_segment`);
    ``kernels`` selects the Pallas victim-selection path for every lane
    (``None`` = the ``REPRO_SIM_KERNELS`` env default).
    """
    results: list = [None] * len(states)
    eps = evict_prefs if evict_prefs is not None else [None] * len(states)
    groups: dict = {}
    for i, (st, (blocks, next_use)) in enumerate(zip(states, segments)):
        st = _ensure_key(st)
        ev = compress_events(np.asarray(blocks), np.asarray(next_use), periodic=True)
        if ev.n_access == 0:
            z = np.zeros(0)
            results[i] = (st, {"fault": z.astype(bool), "thrash": z.astype(np.int32), "was_evicted": z.astype(bool)})
            continue
        padded = _pad_events(ev)
        key = (st.resident.shape[0], len(padded.blk))
        # decompression must see the UNPADDED events (padding rows carry
        # dt=0 and would scatter junk over the first access's outputs)
        groups.setdefault(key, []).append((i, st, ev, padded))

    def _rle_rerun(i, st):
        """Exact single-lane rerun on plain RLE events (shares the b_pad=1
        compile bucket with run/run_segment)."""
        ev_r = compress_events(np.asarray(segments[i][0]), np.asarray(segments[i][1]))
        o_st, o_outs, _ = _run_cells([st], ev_r, [cells[i]], n_valids[i],
                                     None if eps[i] is None else [eps[i]], kernels)
        return _lane(o_st, 0), (_decompress_outs(_lane(o_outs, 0), ev_r) if want_outs else None)

    for (nb, e_len), lanes in groups.items():
        if len(lanes) < 4:
            # small groups route through the single-lane path: reuses the
            # compiled shapes every serial caller already has, instead of
            # minting one vmapped compile per odd lane count
            for i, st, ev, _ in lanes:
                out_states, outs, _ = _run_cells([st], ev, [cells[i]], n_valids[i],
                                                 None if eps[i] is None else [eps[i]], kernels)
                lane = _lane(outs, 0)
                if (ev.stride > 1).any() and bool(np.asarray(lane["pfault"]).any()):
                    results[i] = _rle_rerun(i, st)
                else:
                    results[i] = (_lane(out_states, 0), _decompress_outs(lane, ev) if want_outs else None)
            continue
        # lane counts fall into power-of-two buckets (inert padding lanes:
        # empty no-op event streams, never migrate) so every round of a
        # sweep reuses one compiled scan per bucket
        b_real = len(lanes)
        b_pad = _bucket_pow2(b_real, 4)
        idxs = [i for i, *_ in lanes]
        pad_ev = Events(*(np.zeros(e_len, np.int32),) * 5, 0)
        stacked = _stack_states([st for _, st, _, _ in lanes] + [init_state(nb)] * (b_pad - b_real))
        arrs = [
            jnp.asarray(np.stack([getattr(p, f) for *_, p in lanes] + [getattr(pad_ev, f)] * (b_pad - b_real)))
            for f in ("blk", "nxt", "dt", "rl", "stride")
        ]
        pad_cell = (POLICY_IDS[_INERT[0]], PREFETCH_IDS[_INERT[1]], nb + 1)
        cell_arr = [
            jnp.asarray(np.array([cells[i][k] for i in idxs] + [pad_cell[k]] * (b_pad - b_real), np.int32))
            for k in range(3)
        ]
        nv = jnp.asarray(np.array([n_valids[i] for i in idxs] + [nb] * (b_pad - b_real), np.int32))
        ep = None
        if any(eps[i] is not None for i in idxs):
            ep_np = np.zeros((b_pad, nb), np.int32)
            for j, i in enumerate(idxs):
                if eps[i] is not None:
                    ep_np[j, : len(eps[i])] = np.asarray(eps[i], np.int32)
            ep = jnp.asarray(ep_np)
        if ep is None:
            stacked, lane_arrs, _ = _shard_lanes(stacked, (*arrs, *cell_arr, nv), (), b_pad)
            *arrs, pol_a, pf_a, cap_a, nv = lane_arrs
        else:
            stacked, lane_arrs, _ = _shard_lanes(stacked, (*arrs, *cell_arr, nv, ep), (), b_pad)
            *arrs, pol_a, pf_a, cap_a, nv, ep = lane_arrs
        out_states, outs = _run_events_lanes(stacked, *arrs, cap_a, pol_a, pf_a, nv, ep, kernels)
        pdiv = np.asarray(outs["pfault"]).any(axis=1)
        for j, (i, st, ev, _) in enumerate(lanes):
            if pdiv[j]:
                results[i] = _rle_rerun(i, st)  # periodic merge diverged
            else:
                results[i] = (
                    _lane(out_states, j),
                    _decompress_outs(_lane(outs, j), ev) if want_outs else None,
                )
    return results


def _apply_prefetch_jit(state: SimState, mask, capacity, policy_id, evict_pref=None,
                        kernels: bool | None = None):
    return _jits(kernels)[2](state, mask, capacity, policy_id, evict_pref)


def apply_prefetch(state: SimState, blocks_mask, *, capacity: int, policy: str = "learned",
                   evict_pref: np.ndarray | None = None, kernels: bool | None = None) -> SimState:
    """Stage externally-predicted prefetches (the learned runtime's async
    path).  ``evict_pref`` is the optional QoS leading victim key for the
    fit-back eviction (see :func:`run_segment`); ``kernels`` selects the
    Pallas victim-selection path (``None`` = env default)."""
    state = _ensure_key(state)
    return _apply_prefetch_jit(
        state, jnp.asarray(blocks_mask),
        jnp.asarray(capacity, jnp.int32), jnp.asarray(POLICY_IDS[policy], jnp.int32),
        None if evict_pref is None else jnp.asarray(evict_pref, jnp.int32),
        kernels,
    )


# --- builtin registrations -------------------------------------------------
# The paper's strategy matrix enters the SAME registry a user plugin does;
# registration order fixes the traced ids (lru=0 .. learned=4, demand=0,
# tree=1, none->demand) that the goldens and the batch-padding _INERT lane
# rely on. Guarded for idempotence under importlib.reload.
if "lru" not in POLICY_IDS:
    _registry.register_policy("lru", _lru_keys)
    _registry.register_policy("random", _random_keys)
    _registry.register_policy("belady", _belady_keys)
    _registry.register_policy("hpe", _hpe_keys)
    _registry.register_policy("learned", _learned_keys)
    _registry.register_prefetcher("demand", None)
    _registry.register_prefetcher("tree", _tree_mask)
    _registry.register_prefetcher("none", alias_of="demand")
