"""Trace-driven UVM device-memory simulator (the GPGPU-Sim replacement).

Pure-JAX ``lax.scan`` over the access stream with fixed-size per-block state
arrays (residency, LRU clocks, chain intervals, Belady next-use, learned
prediction frequency). Migration/eviction is at 64KB basic-block granularity
— the CUDA runtime's prefetch unit — and "pages thrashed" are reported as
blocks x 16 pages, matching the granularity of the paper's counters.

Eviction policies (Section II-C / IV-D):
    lru      — least-recently-used (CUDA driver default)
    random   — uniform random resident block
    belady   — MIN oracle (needs the precomputed next-use stream)
    hpe      — page-set chain (new/middle/old by fault interval) + LRU inside
    learned  — page-set chain + prediction-frequency table (the paper's engine)

Prefetchers (Section II-B):
    demand   — migrate only the faulted block
    tree     — NVIDIA tree-based neighbourhood prefetcher: after a migration,
               any [2,4,8,16,32]-block node above 50% valid occupancy gets its
               remaining blocks migrated
    none     — alias of demand; the learned prefetcher stages its blocks via
               :func:`apply_prefetch` between scan segments (async analogue)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.uvm.trace import PAGES_PER_BLOCK, Trace

CHUNK_BLOCKS = 32  # 2MB chunk = 32 x 64KB blocks
INTERVAL = 64  # page-set-chain interval, in faults (same as HPE)
NO_USE = np.int32(2**31 - 1)

POLICIES = ("lru", "random", "belady", "hpe", "learned")
PREFETCHERS = ("demand", "tree", "none")


class SimState(NamedTuple):
    resident: jax.Array  # bool (NB,)
    pinned: jax.Array  # bool (NB,) zero-copy blocks (never migrated)
    evicted_once: jax.Array  # bool (NB,)
    last_access: jax.Array  # int32 (NB,)
    last_interval: jax.Array  # int32 (NB,)
    next_use: jax.Array  # int32 (NB,)
    freq: jax.Array  # int32 (NB,) prediction frequency (-1 = never predicted)
    occupancy: jax.Array  # int32
    fault_count: jax.Array  # int32
    thrash_events: jax.Array  # int32 (block-granular)
    migrations: jax.Array  # int32 blocks migrated
    faults: jax.Array  # int32 far-fault events
    zero_copy: jax.Array  # int32 remote accesses to pinned blocks
    time: jax.Array  # int32
    key: jax.Array


def init_state(n_blocks: int, seed: int = 0) -> SimState:
    z = jnp.zeros((), jnp.int32)
    return SimState(
        resident=jnp.zeros(n_blocks, bool),
        pinned=jnp.zeros(n_blocks, bool),
        evicted_once=jnp.zeros(n_blocks, bool),
        last_access=jnp.full(n_blocks, -1, jnp.int32),
        last_interval=jnp.full(n_blocks, -1, jnp.int32),
        next_use=jnp.full(n_blocks, NO_USE, jnp.int32),
        freq=jnp.full(n_blocks, -1, jnp.int32),
        occupancy=z,
        fault_count=z,
        thrash_events=z,
        migrations=z,
        faults=z,
        zero_copy=z,
        time=z,
        key=jax.random.key(seed),
    )


def precompute_next_use(blocks: np.ndarray, n_blocks: int) -> np.ndarray:
    """next_use[t] = index of the next access to blocks[t] after t (else INF)."""
    nxt = np.full(len(blocks), NO_USE, np.int64)
    last = np.full(n_blocks, NO_USE, np.int64)
    for t in range(len(blocks) - 1, -1, -1):
        nxt[t] = last[blocks[t]]
        last[blocks[t]] = t
    return np.minimum(nxt, NO_USE).astype(np.int32)


def _lex_argmin(cand, *keys):
    """Index of the lexicographically-smallest key tuple among candidates."""
    for k in keys:
        kk = jnp.where(cand, k, jnp.iinfo(jnp.int32).max)
        cand = cand & (kk == kk.min())
    return jnp.argmax(cand)


def _victim(state: SimState, policy: str, interval_now, evictable):
    """Eviction victim index under the given policy (exact int32 lexicographic)."""
    la = state.last_access
    if policy == "lru":
        keys = (la,)
    elif policy == "random":
        keys = (jax.random.randint(jax.random.fold_in(state.key, state.time), la.shape, 0, 1 << 30, jnp.int32),)
    elif policy == "belady":
        keys = (-state.next_use,)  # farthest next use evicted first
    elif policy == "hpe":
        age = jnp.clip(interval_now - state.last_interval, 0, 2)  # 0=new..2=old
        keys = (-age, la)
    elif policy == "learned":
        age = jnp.clip(interval_now - state.last_interval, 0, 2)
        keys = (-age, state.freq, la)
    else:
        raise ValueError(policy)
    return _lex_argmin(evictable, *keys)


def _evict_until_fit(state: SimState, capacity: int, policy: str, protect, interval_now):
    """Evict lowest-priority resident blocks until occupancy <= capacity."""

    def cond(c):
        resident, evicted_once, occ = c
        any_evictable = (resident & ~state.pinned & ~protect).any()
        return (occ > capacity) & any_evictable

    def body(c):
        resident, evicted_once, occ = c
        evictable = resident & ~state.pinned & ~protect
        victim = _victim(state._replace(resident=resident, evicted_once=evicted_once), policy, interval_now, evictable)
        resident = resident.at[victim].set(False)
        evicted_once = evicted_once.at[victim].set(True)
        return resident, evicted_once, occ - 1

    resident, evicted_once, occ = jax.lax.while_loop(
        cond, body, (state.resident, state.evicted_once, state.occupancy)
    )
    return state._replace(resident=resident, evicted_once=evicted_once, occupancy=occ)


def _tree_mask(resident, blk, valid, n_blocks: int):
    """Blocks to prefetch per the tree-based neighbourhood prefetcher."""
    mask = jnp.zeros(n_blocks, bool)
    for size in (2, 4, 8, 16, CHUNK_BLOCKS):
        node = blk // size
        occ = resident.reshape(-1, size).sum(axis=1)[node]
        trigger = occ * 2 > size  # >50% of node valid
        in_node = (jnp.arange(n_blocks) // size) == node
        mask = mask | (in_node & trigger)
    return mask & valid & ~resident


def make_step(n_blocks: int, capacity: int, policy: str, prefetch: str, n_valid: int):
    valid = jnp.arange(n_blocks) < n_valid

    def step(state: SimState, inp):
        blk, nxt = inp
        t = state.time
        is_pinned = state.pinned[blk]
        fault = (~state.resident[blk]) & (~is_pinned)

        # demand block migrates on fault
        mig = jnp.zeros(n_blocks, bool).at[blk].set(fault)
        resident1 = state.resident | mig
        if prefetch == "tree":
            pf = _tree_mask(resident1, blk, valid, n_blocks) & fault
            mig = mig | pf
        newly = mig & ~state.resident
        n_new = newly.sum(dtype=jnp.int32)
        thrash = (newly & state.evicted_once).sum(dtype=jnp.int32)

        interval_now = state.fault_count // INTERVAL
        state2 = state._replace(
            resident=state.resident | newly,
            occupancy=state.occupancy + n_new,
            fault_count=state.fault_count + fault.astype(jnp.int32),
            thrash_events=state.thrash_events + thrash,
            migrations=state.migrations + n_new,
            faults=state.faults + fault.astype(jnp.int32),
            zero_copy=state.zero_copy + is_pinned.astype(jnp.int32),
            # prefetched blocks count as freshly used by the DRIVER's LRU
            # (CUDA treats migrated pages as recently touched — otherwise LRU
            # instantly re-evicts them and the prefetcher ping-pongs)
            last_access=jnp.where(newly | (jnp.arange(n_blocks) == blk), t, state.last_access),
            # ...but HPE's page-set chain only sees DEMAND touches: its
            # counters are not updated by prefetches (Section III-B — this is
            # precisely why Tree.+HPE collapses in Table II). The paper's own
            # engine ("learned") updates the chain with both (Section IV-D).
            last_interval=jnp.where(
                (newly if policy == "learned" else jnp.zeros_like(newly)) | (jnp.arange(n_blocks) == blk),
                interval_now,
                state.last_interval,
            ),
            next_use=state.next_use.at[blk].set(nxt),
        )
        protect = jnp.zeros(n_blocks, bool).at[blk].set(True)
        state3 = _evict_until_fit(state2, capacity, policy, protect, interval_now)
        out = {
            "fault": fault,
            "thrash": thrash,
            "was_evicted": state.evicted_once[blk],
        }
        return state3._replace(time=t + 1), out

    return step


class SimResult(NamedTuple):
    state: SimState
    fault: np.ndarray
    thrash: np.ndarray
    was_evicted: np.ndarray

    @property
    def pages_thrashed(self) -> int:
        return int(self.state.thrash_events) * PAGES_PER_BLOCK

    @property
    def stats(self) -> dict:
        s = self.state
        return {
            "pages_thrashed": self.pages_thrashed,
            "faults": int(s.faults),
            "migrated_blocks": int(s.migrations),
            "zero_copy": int(s.zero_copy),
            "occupancy": int(s.occupancy),
        }


def capacity_for(n_blocks: int, oversubscription: float) -> int:
    """125% oversubscription => device memory = working set / 1.25."""
    return max(int(np.floor(n_blocks / oversubscription)), 1)


@partial(jax.jit, static_argnames=("n_blocks", "capacity", "policy", "prefetch", "n_valid"))
def _run_segment(state, blocks, next_use, n_blocks, capacity, policy, prefetch, n_valid):
    step = make_step(n_blocks, capacity, policy, prefetch, n_valid)
    return jax.lax.scan(step, state, (blocks, next_use))


def pad_blocks(n_valid: int) -> int:
    return int(np.ceil(n_valid / CHUNK_BLOCKS) * CHUNK_BLOCKS)


def run(
    trace: Trace,
    *,
    policy: str = "lru",
    prefetch: str = "tree",
    oversubscription: float = 1.25,
    state: SimState | None = None,
    seed: int = 0,
) -> SimResult:
    """Run a full trace under (policy x prefetch) at an oversubscription level."""
    assert policy in POLICIES and prefetch in PREFETCHERS
    blocks = trace.block.astype(np.int32)
    nb = pad_blocks(trace.n_blocks)
    cap = capacity_for(trace.n_blocks, oversubscription)
    nxt = precompute_next_use(blocks, nb)
    st = state if state is not None else init_state(nb, seed)
    st, outs = _run_segment(
        st, jnp.asarray(blocks), jnp.asarray(nxt),
        n_blocks=nb, capacity=cap, policy=policy,
        prefetch="demand" if prefetch == "none" else prefetch,
        n_valid=trace.n_blocks,
    )
    st = st._replace(key=jax.random.key_data(st.key))  # numpy-safe
    return SimResult(
        state=jax.tree.map(np.asarray, st),
        fault=np.asarray(outs["fault"]),
        thrash=np.asarray(outs["thrash"]),
        was_evicted=np.asarray(outs["was_evicted"]),
    )


def apply_prefetch(state: SimState, blocks_mask, *, capacity: int, policy: str = "learned") -> SimState:
    """Stage externally-predicted prefetches (the learned runtime's async path)."""
    newly = jnp.asarray(blocks_mask) & ~state.resident & ~state.pinned
    n_new = newly.sum(dtype=jnp.int32)
    thrash = (newly & state.evicted_once).sum(dtype=jnp.int32)
    interval_now = state.fault_count // INTERVAL
    st = state._replace(
        resident=state.resident | newly,
        occupancy=state.occupancy + n_new,
        thrash_events=state.thrash_events + thrash,
        migrations=state.migrations + n_new,
        last_interval=jnp.where(newly, interval_now, state.last_interval),
        last_access=jnp.where(newly, state.time, state.last_access),
    )
    return _evict_until_fit(st, capacity, policy, jnp.zeros_like(newly), interval_now)
