"""The paper's full system, end to end ("our solution" in Tables VI/VII and
Figs. 11-14): pattern classifier -> per-pattern predictor (CE + LUCIR +
thrashing loss) -> policy engine (prediction frequency table + page-set
chain) -> simulator GMMU ops.

The pipeline itself lives in :mod:`repro.uvm.manager` as the streaming
:class:`~repro.uvm.manager.OversubscriptionManager`; this module is the
TRACE-SIMULATOR driver over it.  Per group of accesses:

  1. ``manager.observe(FaultBatch)`` — classify the group's access pattern,
     fetch that pattern's model, predict each access's next page delta
     (STRICTLY before training on it), update the prediction frequency
     table and return the staged prefetches + dense counters (Section IV-D)
  2. export the counters to the simulator's `learned` eviction policy and
     stage the prefetch blocks (:func:`repro.uvm.simulator.apply_prefetch`)
  3. run the simulator segment (demand migration + learned eviction)
  4. ``manager.feedback(Outcomes)`` — fine-tune the model on the group,
     with the E∪T membership of each sample's target page feeding the
     thrashing term, and advance the flush cadence from the fault count

:func:`run_ours` runs one trace serially; :func:`run_ours_many` runs many
traces in lockstep with the same per-lane semantics, batching the
managers' staged predict / fine-tune dispatches through the vmapped
``Trainer`` methods and ``simulator.run_segments_many`` (lanes bucketed by
shape share one dispatch).  Lanes never share state, so per-benchmark
results match stand-alone runs.  Counters and top-1 are bit-identical to
the pre-manager monolith (pinned by tests/golden/ours_golden.json on all
11 benchmarks).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.predictor_paper import PredictorConfig
from repro.core.features import DeltaVocab, FeatureStream
from repro.core.incremental import TrainConfig, Trainer
from repro.core.model_table import ModelTable
from repro.core.pattern import PatternClassifier
from repro.uvm import simulator as S
from repro.uvm import timing
from repro.uvm.manager import (
    FaultBatch,
    HealthConfig,
    ManagerConfig,
    Outcomes,
    OversubscriptionManager,
    TenantMux,
    prefetch_mask,
    prefetch_warm,
)
from repro.uvm.trace import PAGES_PER_BLOCK, Trace

# back-compat aliases (pre-manager private helpers)
_prefetch_warm = prefetch_warm
_prefetch_mask = prefetch_mask


@dataclasses.dataclass
class LearnedRunResult:
    stats: dict
    top1: float
    n_predictions: int
    n_classes: int
    n_models: int
    per_group_acc: list
    warm_top1: float = 0.0  # excludes each pattern-model's first (cold) group
    n_accesses: int = 0  # trace length (0 only on results stored before it existed)
    #: per-tenant strictly-causal top-1 (multi-tenant mux runs only; keys
    #: are str(tenant) so the payload stays JSON-round-trippable)
    per_tenant_top1: dict | None = None
    #: per-tenant fairness accounting (multi-tenant runs only): str(tenant)
    #: -> {pages_thrashed, faults, accesses}, attributed to the tenant of
    #: the access that triggered each event — what table10 spreads
    per_tenant_stats: dict | None = None
    #: final per-tenant QoS block budgets (budgeted mux runs only)
    budgets: dict | None = None

    def ipc(self, pred_overhead_us: float = 1.0, n_accesses: int | None = None) -> float:
        # The predictor sits at the UVM backend and runs ASYNCHRONOUSLY with
        # kernel execution (Section V-A/C); only predictions consumed on the
        # fault-handling path serialise with execution, so the overhead is
        # charged per far-fault, not per prediction. This reproduces Fig. 13's
        # shape: negligible at 1us, catastrophic by 50-100us (comparable to
        # the 45us far-fault service itself).
        if n_accesses is None:
            n_accesses = self.n_accesses
        if not n_accesses:
            raise ValueError(
                "this result predates the n_accesses field (or was built with 0); "
                "pass ipc(..., n_accesses=len(trace)) explicitly"
            )
        charged = min(self.n_predictions, self.stats["faults"])
        return timing.ipc(self.stats, n_accesses, pred_overhead_us=pred_overhead_us, n_predictions=charged)


PRETRAIN_CACHE_DIR = Path("experiments/cache")


def _pretrain_cache_key(corpus, pcfg, tcfg, kind, target_acc, max_rounds) -> str:
    h = hashlib.md5()
    for tr in corpus:
        h.update(tr.name.encode())
        h.update(str(tr.n_pages).encode())
        # everything FeatureStream extracts (page, delta, pc, tb) + the
        # classifier input (kernel) — a change to ANY of them must miss
        for arr in (tr.page, tr.pc, tr.tb, tr.kernel):
            h.update(np.ascontiguousarray(arr))
    h.update(repr((pcfg, dataclasses.astuple(tcfg), kind, target_acc, max_rounds)).encode())
    return h.hexdigest()[:16]


def _table_to_host(table: ModelTable) -> dict:
    to_np = lambda t: None if t is None else jax.tree.map(np.asarray, t)
    return {
        "n_slots": table.n_slots,
        "slots": {
            s: {
                "params": to_np(e.params), "prev_params": to_np(e.prev_params),
                "opt_state": to_np(e.opt_state), "step": e.step,
                "n_updates": e.n_updates, "last_acc": e.last_acc,
            }
            for s, e in table.slots.items()
        },
    }


def _load_pretrain_blob(cache_path: Path) -> dict:
    """Read a pretrain memo, verifying integrity when possible.

    New memos are a checksummed envelope ``{"sha256", "payload"}`` (the
    payload is the pickled host table); a checksum mismatch means the file
    was torn or bit-rotted and raises so the caller recomputes.  Legacy
    memos (the raw host-table dict, including the committed
    experiments/cache ones) load unchanged — they predate the envelope."""
    obj = pickle.loads(cache_path.read_bytes())
    if isinstance(obj, dict) and "sha256" in obj and "payload" in obj:
        digest = hashlib.sha256(obj["payload"]).hexdigest()
        if digest != obj["sha256"]:
            raise ValueError(
                f"pretrain cache checksum mismatch: manifest {obj['sha256'][:12]} "
                f"!= payload {digest[:12]}"
            )
        return pickle.loads(obj["payload"])
    return obj  # legacy raw-dict memo


def _dump_pretrain_blob(blob: dict) -> bytes:
    """The checksummed envelope :func:`_load_pretrain_blob` verifies."""
    payload = pickle.dumps(blob)
    return pickle.dumps({"sha256": hashlib.sha256(payload).hexdigest(), "payload": payload})


def pretrain_table(
    corpus: list[Trace],
    pcfg: PredictorConfig,
    tcfg: TrainConfig,
    *,
    kind: str = "transformer",
    target_acc: float = 0.85,
    max_rounds: int = 4,
) -> ModelTable:
    """Section V-A: build a per-pattern corpus from (different-input) runs of
    5 benchmarks and pre-train each pattern's model until accuracy is
    reasonable, to hide the initial training latency.

    The paper treats this as an OFFLINE one-time step, so the resulting
    table (a deterministic function of corpus + configs) is memoised on
    disk under experiments/cache/ — re-deriving identical weights in every
    benchmark process would just re-pay the pretraining latency the design
    exists to hide. Set REPRO_PRETRAIN_CACHE=0 to disable.
    """
    trainer = Trainer(pcfg, tcfg, kind)
    use_cache = os.environ.get("REPRO_PRETRAIN_CACHE", "1") != "0"
    cache_path = PRETRAIN_CACHE_DIR / f"pretrain_{_pretrain_cache_key(corpus, pcfg, tcfg, kind, target_acc, max_rounds)}.pkl"
    if use_cache and cache_path.exists():
        try:
            blob = _load_pretrain_blob(cache_path)
            table = ModelTable(lambda s: trainer.new_params(s), n_slots=blob["n_slots"])
            from repro.core.model_table import Entry

            for s, e in blob["slots"].items():
                table.slots[s] = Entry(
                    params=e["params"], prev_params=e["prev_params"], opt_state=e["opt_state"],
                    step=e["step"], n_updates=e["n_updates"], last_acc=e["last_acc"],
                )
            return table
        except Exception as exc:
            # truncated/corrupt/checksum-failed memo: warn + retrain rather
            # than silently serving whatever half-pickle survived the crash
            import warnings

            warnings.warn(
                f"pretrain cache {cache_path} unreadable ({exc!r}); recomputing",
                RuntimeWarning, stacklevel=2,
            )
    table = ModelTable(lambda s: trainer.new_params(s), n_slots=tcfg.table_slots)
    classifier = PatternClassifier()
    groups = []  # (pattern, FeatureSet, n_active)
    for tr in corpus:
        vocab = DeltaVocab(pcfg.delta_vocab)
        stream = FeatureStream(tr, vocab, pcfg.history, page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab)
        half = len(tr) // 2
        for g0 in range(0, half, tcfg.group_size):
            g1 = min(g0 + tcfg.group_size, half)
            fs = stream.windows(g0, g1)
            if len(fs):
                pat = classifier.classify(tr.block[g0:g1], tr.kernel[g0:g1])
                groups.append((pat, fs, max(vocab.n_classes, 2)))
    for _ in range(max_rounds):
        accs = []
        for pat, fs, n_active in groups:
            entry = table.get(pat)
            corr, _ = trainer.evaluate(entry.params, fs, n_active)
            accs.append(corr.mean())
            # corpus accuracy seeds the prefetch gate CONSERVATIVELY: transfer
            # to an unseen trace is unproven until measured on it
            entry.last_acc = min(float(corr.mean()), 0.5)
            entry = trainer.train_group(entry, fs, n_active)
            table.put(pat, entry)
        if accs and float(np.mean(accs)) >= target_acc:
            break
    if use_cache:
        try:
            PRETRAIN_CACHE_DIR.mkdir(parents=True, exist_ok=True)
            # atomic publish: a killed writer must never leave a torn file
            tmp = cache_path.with_suffix(f".tmp.{os.getpid()}")
            tmp.write_bytes(_dump_pretrain_blob(_table_to_host(table)))
            os.replace(tmp, cache_path)
        except OSError:
            pass  # read-only checkouts still work, just without the memo
    return table


def _manager_config(
    trace: Trace,
    pcfg: PredictorConfig,
    tcfg: TrainConfig,
    *,
    oversubscription: float,
    kind: str,
    use_thrash_term: bool,
    use_lucir: bool,
    reclass_interval: int = 0,
    reclass_hysteresis: int = 2,
    health: HealthConfig | None = None,
) -> ManagerConfig:
    return ManagerConfig(
        predictor=pcfg, train=tcfg, kind=kind,
        n_pages=trace.n_pages,
        n_blocks=S.bucket_blocks(trace.n_blocks),
        capacity=S.capacity_for(trace.n_blocks, oversubscription),
        use_thrash_term=use_thrash_term, use_lucir=use_lucir,
        reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis,
        health=health,
        # REPRO_SIM_KERNELS routes the manager's freq table through its
        # Pallas engine too (bit-identical; note freq_table is part of the
        # snapshot signature, so snapshots don't cross engines)
        freq_table="setassoc_pallas" if S.sim_kernels_enabled() else "setassoc",
    )


def manager_for(
    trace: Trace,
    pcfg: PredictorConfig | None = None,
    tcfg: TrainConfig | None = None,
    *,
    oversubscription: float = 1.25,
    kind: str = "transformer",
    table: ModelTable | None = None,
    use_thrash_term: bool = True,
    use_lucir: bool = True,
    reclass_interval: int = 0,
    reclass_hysteresis: int = 2,
    health: HealthConfig | None = None,
) -> OversubscriptionManager:
    """An :class:`OversubscriptionManager` configured for one trace's
    geometry (padded block bucket + oversubscribed capacity) — the manager
    :func:`run_ours` drives, reusable by any other consumer of the same
    workload."""
    cfg = _manager_config(
        trace, pcfg or PredictorConfig(), tcfg or TrainConfig(),
        oversubscription=oversubscription, kind=kind,
        use_thrash_term=use_thrash_term, use_lucir=use_lucir,
        reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis,
        health=health,
    )
    return OversubscriptionManager(cfg, table=table)


def mux_for(
    trace: Trace,
    pcfg: PredictorConfig | None = None,
    tcfg: TrainConfig | None = None,
    *,
    oversubscription: float = 1.25,
    kind: str = "transformer",
    table: ModelTable | None = None,
    use_thrash_term: bool = True,
    use_lucir: bool = True,
    shared_freq_table: bool = False,
    reclass_interval: int = 0,
    reclass_hysteresis: int = 2,
    health: HealthConfig | None = None,
    trainer=None,
    qos=None,
) -> TenantMux:
    """A :class:`TenantMux` for a tenant-tagged concurrent trace
    (Section V-F): one manager per tenant over the MERGED geometry (tenants
    occupy disjoint page ranges of the shared device, so every pipeline
    sees global page ids and the combined artifacts line up with the
    simulator's block space).  ``table`` is a Section V-A master each
    tenant clones.

    ``qos`` opts the mux into per-tenant capacity partitioning: a
    :class:`~repro.uvm.api.specs.QosSpec` (tiers keyed by the trace's
    ``tenant_names``, resolved here against this trace's geometry) or an
    already-built :class:`~repro.uvm.qos.BudgetController`."""
    if trace.tenant is None:
        raise ValueError(f"trace {trace.name!r} has no tenant tags; use manager_for() instead")
    cfg = _manager_config(
        trace, pcfg or PredictorConfig(), tcfg or TrainConfig(),
        oversubscription=oversubscription, kind=kind,
        use_thrash_term=use_thrash_term, use_lucir=use_lucir,
        reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis,
        health=health,
    )
    if qos is not None and hasattr(qos, "controller"):  # a QosSpec
        qos = qos.controller(cfg.capacity, cfg.n_blocks, trace.tenant_names)
    tenants = [int(t) for t in np.unique(trace.tenant)]
    return TenantMux(
        cfg, tenants, shared_freq_table=shared_freq_table, auto_create=False,
        tables=table, trainer=trainer, qos=qos,
    )


def _group_batch(trace: Trace, g0: int, g1: int) -> FaultBatch:
    return FaultBatch(
        trace.page[g0:g1], trace.pc[g0:g1], trace.tb[g0:g1], trace.kernel[g0:g1],
        tenant=None if trace.tenant is None else trace.tenant[g0:g1],
    )


def _apply_actions(state, actions, nb: int, cap: int, evict_pref=None):
    """Stage one batch's actions into the simulator state: export the dense
    counters to the `learned` eviction keys, then apply the prefetches
    (``counters is None`` = the gate was closed; nothing to stage).
    ``evict_pref`` is the QoS leading victim key — prefetch-to-fit
    evictions respect the budgets exactly as demand evictions do."""
    if actions.counters is None:
        return state
    state = state._replace(freq=jnp.asarray(actions.counters))
    mask = np.zeros(nb, bool)
    mask[actions.prefetch_blocks] = True
    return S.apply_prefetch(
        state, jnp.asarray(mask), capacity=cap, policy="learned",
        evict_pref=evict_pref,
    )


def _state_stats(state) -> dict:
    return {
        "pages_thrashed": int(state.thrash_events) * PAGES_PER_BLOCK,
        "faults": int(state.faults),
        "migrated_blocks": int(state.migrations),
        "zero_copy": int(state.zero_copy),
        "occupancy": int(state.occupancy),
    }


def _result(mgr, state, n_accesses: int, per_tenant_stats: dict | None = None) -> LearnedRunResult:
    is_mux = isinstance(mgr, TenantMux)
    return LearnedRunResult(
        _state_stats(state), mgr.top1, mgr.n_predictions, mgr.n_classes,
        mgr.n_models, mgr.per_group, mgr.warm_top1, n_accesses,
        per_tenant_top1=mgr.per_tenant_top1 if is_mux else None,
        per_tenant_stats=per_tenant_stats,
        budgets={str(k): v for k, v in mgr.qos.budgets.items()}
        if is_mux and mgr.qos is not None else None,
    )


class _TenantLedger:
    """Per-tenant fairness accounting + QoS departure bookkeeping for one
    tenant-tagged trace: attributes each group's thrash/fault events to the
    tenant of the triggering access, and (budgeted runs only) releases a
    tenant from the mux once its last access is behind us, so its counters
    and budget slice rebalance to the tenants still running."""

    def __init__(self, trace: Trace, mgr):
        tn = np.asarray(trace.tenant)
        self.trace = trace
        self.mgr = mgr if isinstance(mgr, TenantMux) else None
        self.stats = {
            int(t): {"pages_thrashed": 0, "faults": 0, "accesses": 0}
            for t in np.unique(tn)
        }
        # releasing is observable (combined counters shrink), so it is
        # strictly an opt-in QoS behaviour — the budget-free goldens pin
        # the keep-forever legacy path
        self.departs = (
            {int(t): int(np.max(np.nonzero(tn == t)[0])) for t in np.unique(tn)}
            if self.mgr is not None and self.mgr.qos is not None else {}
        )

    def account(self, g0: int, g1: int, outs: dict) -> None:
        tn = self.trace.tenant[g0:g1]
        th = np.asarray(outs["thrash"])
        fa = np.asarray(outs["fault"])
        for t in np.unique(tn):
            m = tn == t
            d = self.stats[int(t)]
            d["pages_thrashed"] += int(th[m].sum()) * PAGES_PER_BLOCK
            d["faults"] += int(fa[m].sum())
            d["accesses"] += int(m.sum())
        if g1 < len(self.trace):  # keep final-group tenants admitted
            for t in [t for t, last in self.departs.items() if last < g1]:
                del self.departs[t]
                self.mgr.release(t)

    def result(self) -> dict:
        return {str(t): dict(d) for t, d in self.stats.items()}


def run_ours(
    trace: Trace,
    pcfg: PredictorConfig | None = None,
    tcfg: TrainConfig | None = None,
    *,
    oversubscription: float = 1.25,
    kind: str = "transformer",
    table: ModelTable | None = None,
    use_thrash_term: bool = True,
    use_lucir: bool = True,
    seed: int = 0,
    manager: OversubscriptionManager | TenantMux | None = None,
    multi_tenant: bool | None = None,
    shared_freq_table: bool = False,
    reclass_interval: int = 0,
    reclass_hysteresis: int = 2,
    health: HealthConfig | None = None,
    qos=None,
) -> LearnedRunResult:
    """Drive one trace through the streaming manager + simulator.

    Tenant-tagged concurrent traces (``trace.tenant`` set — every
    :func:`repro.uvm.trace.concurrent` merge) route through a
    :class:`TenantMux` by default: one classifier->predictor pipeline per
    tenant, combined prefetch/counter staging, ONE shared simulator over
    the merged device.  ``multi_tenant=False`` forces the pre-mux
    merged-single-manager treatment (the Section V-F baseline).

    Pass ``manager`` to drive an externally-built (possibly already warm)
    :class:`OversubscriptionManager` or :class:`TenantMux` instead of a
    fresh one — its config must match the trace's geometry.

    ``qos`` (a :class:`~repro.uvm.api.specs.QosSpec` or a built
    :class:`~repro.uvm.qos.BudgetController`) opts the mux run into
    per-tenant capacity partitioning: each segment carries the controller's
    budgets as the leading victim key, budgets rebalance from observed
    per-tenant pressure between groups, and a tenant whose accesses are
    exhausted is released so its slice flows to the tenants still running.
    Requires a tenant-tagged multi-tenant run; ``None`` (default) is the
    legacy shared pool, pinned bit-for-bit by the goldens.
    """
    pcfg = pcfg or PredictorConfig()
    tcfg = tcfg or TrainConfig()
    if multi_tenant is None:
        multi_tenant = trace.tenant is not None
    if qos is not None and not multi_tenant:
        raise ValueError("qos= requires a tenant-tagged multi-tenant run")
    if manager is not None:
        mgr = manager
    elif multi_tenant:
        mgr = mux_for(
            trace, pcfg, tcfg, oversubscription=oversubscription, kind=kind,
            table=table, use_thrash_term=use_thrash_term, use_lucir=use_lucir,
            shared_freq_table=shared_freq_table,
            reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis,
            health=health, qos=qos,
        )
    else:
        mgr = manager_for(
            trace, pcfg, tcfg, oversubscription=oversubscription, kind=kind,
            table=table, use_thrash_term=use_thrash_term, use_lucir=use_lucir,
            reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis,
            health=health,
        )
    nb, cap = mgr.cfg.n_blocks, mgr.cfg.capacity
    state = S.init_state(nb, seed)
    blocks = trace.block.astype(np.int32)
    nxt = S.next_use_for(trace)  # cached per trace across groups/cells
    ledger = _TenantLedger(trace, mgr) if trace.tenant is not None else None

    n = len(trace)
    # the manager's OWN training schedule decides the batch cadence — an
    # externally-passed manager must observe the group size it was built
    # with, not this call's tcfg default
    G = mgr.cfg.train.group_size
    for g0 in range(0, n, G):
        g1 = min(g0 + G, n)
        actions = mgr.observe(_group_batch(trace, g0, g1))
        # the QoS leading victim key for this segment: budgets vs CURRENT
        # residency (None on budget-free runs = the exact pre-QoS program)
        ep = (
            mgr.evict_pref(np.asarray(state.resident))
            if isinstance(mgr, TenantMux) else None
        )
        state = _apply_actions(state, actions, nb, cap, evict_pref=ep)
        state, outs = S.run_segment(
            state, blocks[g0:g1], nxt[g0:g1],
            capacity=cap, policy="learned", prefetch="demand", n_valid=trace.n_blocks,
            evict_pref=ep,
        )
        mgr.feedback(Outcomes(
            was_evicted=np.asarray(outs["was_evicted"]),
            fault_count=int(state.fault_count),
        ))
        if ledger is not None:
            ledger.account(g0, g1, outs)
    return _result(mgr, state, n, None if ledger is None else ledger.result())


@dataclasses.dataclass
class _Lane:
    """Per-trace runtime state for :func:`run_ours_many` (each lane owns its
    manager — model table, vocabulary, classifier, frequency table — and
    its simulator state; lanes are fully independent, exactly as serial
    runs are).  A tenant-tagged lane's ``mgr`` is a :class:`TenantMux`;
    its staged halves fan out per tenant, so one lockstep dispatch batches
    across lanes AND tenants."""

    trace: Trace
    mgr: OversubscriptionManager | TenantMux
    state: object
    blocks: np.ndarray
    nxt: np.ndarray
    ledger: object = None  # _TenantLedger on tenant-tagged lanes
    ep: np.ndarray | None = None  # this group's QoS leading victim key

    def observe_begin_all(self, batch) -> list:
        if isinstance(self.mgr, TenantMux):
            return [r for _, r in self.mgr.observe_begin(batch)]
        return [self.mgr.observe_begin(batch)]

    def observe_finish_all(self, results: list):
        if isinstance(self.mgr, TenantMux):
            return self.mgr.observe_finish(results)
        corr, pred = results[0] if results[0] is not None else (None, None)
        return self.mgr.observe_finish(corr, pred)

    def feedback_begin_all(self, outcomes) -> list:
        if isinstance(self.mgr, TenantMux):
            return [r for _, r in self.mgr.feedback_begin(outcomes)]
        return [self.mgr.feedback_begin(outcomes)]

    def feedback_finish_all(self, reqs: list) -> None:
        if isinstance(self.mgr, TenantMux):
            self.mgr.feedback_finish([r.entry if r is not None else None for r in reqs])
        elif reqs[0] is not None:
            self.mgr.feedback_finish(reqs[0].entry)


def run_ours_many(
    traces: list[Trace],
    pcfg: PredictorConfig | None = None,
    tcfg: TrainConfig | None = None,
    *,
    oversubscription: float = 1.25,
    kind: str = "transformer",
    tables: list[ModelTable] | None = None,
    use_thrash_term: bool = True,
    use_lucir: bool = True,
    seed: int = 0,
    multi_tenant: bool | None = None,
    shared_freq_table: bool = False,
    reclass_interval: int = 0,
    reclass_hysteresis: int = 2,
    health: HealthConfig | None = None,
    qos=None,
) -> list[LearnedRunResult]:
    """Run the full learned system over MANY traces in lockstep.

    The per-group streaming protocol of :func:`run_ours` (observe ->
    prefetch -> simulate -> feedback) is kept, but the managers' staged
    halves are driven so each stage batches across benchmarks: predictions
    and fine-tuning go through the vmapped ``Trainer.evaluate_many`` /
    ``train_group_many`` (lanes bucketed by shape share one dispatch), and
    simulator segments run through
    :func:`repro.uvm.simulator.run_segments_many` (per-lane event streams,
    one vmapped scan per shape bucket).  Lanes never interact — each trace
    keeps its own manager and simulator state.  The simulator stages are
    exactly per-lane-equivalent; the vmapped predictor reproduced serial
    floats bit-for-bit on CPU (tests/test_system.py pins counters AND top1
    against serial runs), but a backend whose batched kernels round
    differently could shift a prediction across a prefetch-gate threshold
    and with it the learned run's counters — if paper-table stability
    across device counts matters more than throughput, force the serial
    engine with ``REPRO_OURS_BATCHED=0``.

    ``qos`` (one :class:`~repro.uvm.api.specs.QosSpec`, applied to every
    tenant-tagged lane) opts those lanes into per-tenant capacity
    partitioning — each lane owns an independent
    :class:`~repro.uvm.qos.BudgetController`, exactly as serial
    :func:`run_ours` calls build one each.
    """
    pcfg = pcfg or PredictorConfig()
    tcfg = tcfg or TrainConfig()
    trainer = Trainer(pcfg, tcfg, kind)  # the shared batched dispatches
    lanes: list[_Lane] = []
    for li, trace in enumerate(traces):
        mt = trace.tenant is not None if multi_tenant is None else multi_tenant
        # mux_for rejects untagged traces, so an explicit multi_tenant=True
        # on one fails loudly here exactly as it does in run_ours
        build = mux_for if mt else manager_for
        kw = dict(
            oversubscription=oversubscription, kind=kind,
            table=tables[li] if tables is not None else None,
            use_thrash_term=use_thrash_term, use_lucir=use_lucir,
            reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis,
            health=health,
        )
        if build is mux_for:
            kw.update(shared_freq_table=shared_freq_table, trainer=trainer, qos=qos)
        elif qos is not None:
            raise ValueError(
                f"qos= requires tenant-tagged lanes; trace {trace.name!r} has none"
            )
        mgr = build(trace, pcfg, tcfg, **kw)
        lanes.append(_Lane(
            trace=trace, mgr=mgr, state=S.init_state(mgr.cfg.n_blocks, seed),
            blocks=trace.block.astype(np.int32), nxt=S.next_use_for(trace),
            ledger=_TenantLedger(trace, mgr) if trace.tenant is not None else None,
        ))
    G = tcfg.group_size
    max_n = max((len(l.trace) for l in lanes), default=0)
    for g0 in range(0, max_n, G):
        act = [l for l in lanes if g0 < len(l.trace)]
        # 1. observe every lane's group; the predictor dispatches batch
        #    through one vmapped evaluate per shape bucket (mux lanes fan
        #    out one request per tenant into the same dispatch)
        reqs = [
            (l, l.observe_begin_all(_group_batch(l.trace, g0, min(g0 + G, len(l.trace)))))
            for l in act
        ]
        flat = [r for _, rs in reqs for r in rs if r is not None]
        results = iter(trainer.evaluate_many(
            [r.params for r in flat], [r.fs for r in flat], [r.n_active for r in flat],
        ))
        for l, rs in reqs:
            actions = l.observe_finish_all([next(results) if r is not None else None for r in rs])
            # the lane's QoS leading victim key for this segment (None on
            # budget-free lanes = the exact pre-QoS vmapped program)
            l.ep = (
                l.mgr.evict_pref(np.asarray(l.state.resident))
                if isinstance(l.mgr, TenantMux) else None
            )
            # 2. stage counters + prefetches into the lane's simulator state
            l.state = _apply_actions(
                l.state, actions, l.mgr.cfg.n_blocks, l.mgr.cfg.capacity,
                evict_pref=l.ep,
            )

        # 3. simulator segments under the learned policy, vmapped across
        #    lanes (each lane has its own compressed event stream)
        seg = S.run_segments_many(
            [l.state for l in act],
            [(l.blocks[g0:min(g0 + G, len(l.trace))], l.nxt[g0:min(g0 + G, len(l.trace))]) for l in act],
            [(S.POLICY_IDS["learned"], S.PREFETCH_IDS["demand"], l.mgr.cfg.capacity) for l in act],
            [l.trace.n_blocks for l in act],
            evict_prefs=[l.ep for l in act],
        )
        # 4. feedback; the fine-tune dispatches batch through one vmapped
        #    train per bucket, then every manager publishes its entry
        treqs = []
        for l, (state, outs) in zip(act, seg):
            l.state = state
            treqs.append((l, l.feedback_begin_all(Outcomes(
                was_evicted=np.asarray(outs["was_evicted"]),
                fault_count=int(state.fault_count),
            )), outs))
        tflat = [r for _, rs, _ in treqs for r in rs if r is not None]
        trainer.train_group_many(
            [r.entry for r in tflat], [r.fs for r in tflat], [r.n_active for r in tflat],
            in_et_list=[r.in_et for r in tflat], use_lucir=use_lucir,
        )
        for l, rs, outs in treqs:
            l.feedback_finish_all(rs)
            # fairness accounting + QoS tenant departure, after the round
            # fully closes — same ordering as the serial run_ours loop
            if l.ledger is not None:
                l.ledger.account(g0, min(g0 + G, len(l.trace)), outs)

    return [
        _result(l.mgr, l.state, len(l.trace),
                None if l.ledger is None else l.ledger.result())
        for l in lanes
    ]
