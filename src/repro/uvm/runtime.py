"""The paper's full system, end to end ("our solution" in Tables VI/VII and
Figs. 11-14): pattern classifier -> per-pattern predictor (CE + LUCIR +
thrashing loss) -> policy engine (prediction frequency table + page-set
chain) -> simulator GMMU ops.

Per group of accesses:
  1. classify the group's access pattern; fetch that pattern's model
  2. predict each access's next page delta (STRICTLY before training on it)
  3. update the prediction frequency table; stage ALL predicted pages as
     prefetches (Section IV-D); export dense counters to the simulator's
     `learned` eviction policy
  4. run the simulator segment (demand migration + learned eviction)
  5. fine-tune the model on the group, with the E∪T membership of each
     sample's target page feeding the thrashing term

:func:`run_ours` runs one trace serially; :func:`run_ours_many` runs many
traces in lockstep with the same per-lane semantics, batching predict /
simulate / fine-tune across benchmarks through the vmapped ``Trainer``
methods and ``simulator.run_segments_many`` (lanes bucketed by shape share
one dispatch).  Lanes never share state, so per-benchmark results match
stand-alone runs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.predictor_paper import PredictorConfig
from repro.core.features import DeltaVocab, FeatureStream
from repro.core.incremental import TrainConfig, Trainer
from repro.core.model_table import ModelTable
from repro.core.pattern import LINEAR, RANDOM, RANDOM_REUSE, PatternClassifier
from repro.core.policy import PredictionFrequencyTable, predicted_blocks
from repro.uvm import simulator as S
from repro.uvm import timing
from repro.uvm.trace import PAGES_PER_BLOCK, Trace


@dataclasses.dataclass
class LearnedRunResult:
    stats: dict
    top1: float
    n_predictions: int
    n_classes: int
    n_models: int
    per_group_acc: list
    warm_top1: float = 0.0  # excludes each pattern-model's first (cold) group

    def ipc(self, pred_overhead_us: float = 1.0, n_accesses: int = 0) -> float:
        # The predictor sits at the UVM backend and runs ASYNCHRONOUSLY with
        # kernel execution (Section V-A/C); only predictions consumed on the
        # fault-handling path serialise with execution, so the overhead is
        # charged per far-fault, not per prediction. This reproduces Fig. 13's
        # shape: negligible at 1us, catastrophic by 50-100us (comparable to
        # the 45us far-fault service itself).
        charged = min(self.n_predictions, self.stats["faults"])
        return timing.ipc(self.stats, n_accesses, pred_overhead_us=pred_overhead_us, n_predictions=charged)


PRETRAIN_CACHE_DIR = Path("experiments/cache")


def _pretrain_cache_key(corpus, pcfg, tcfg, kind, target_acc, max_rounds) -> str:
    h = hashlib.md5()
    for tr in corpus:
        h.update(tr.name.encode())
        h.update(str(tr.n_pages).encode())
        # everything FeatureStream extracts (page, delta, pc, tb) + the
        # classifier input (kernel) — a change to ANY of them must miss
        for arr in (tr.page, tr.pc, tr.tb, tr.kernel):
            h.update(np.ascontiguousarray(arr))
    h.update(repr((pcfg, dataclasses.astuple(tcfg), kind, target_acc, max_rounds)).encode())
    return h.hexdigest()[:16]


def _table_to_host(table: ModelTable) -> dict:
    to_np = lambda t: None if t is None else jax.tree.map(np.asarray, t)
    return {
        "n_slots": table.n_slots,
        "slots": {
            s: {
                "params": to_np(e.params), "prev_params": to_np(e.prev_params),
                "opt_state": to_np(e.opt_state), "step": e.step,
                "n_updates": e.n_updates, "last_acc": e.last_acc,
            }
            for s, e in table.slots.items()
        },
    }


def pretrain_table(
    corpus: list[Trace],
    pcfg: PredictorConfig,
    tcfg: TrainConfig,
    *,
    kind: str = "transformer",
    target_acc: float = 0.85,
    max_rounds: int = 4,
) -> ModelTable:
    """Section V-A: build a per-pattern corpus from (different-input) runs of
    5 benchmarks and pre-train each pattern's model until accuracy is
    reasonable, to hide the initial training latency.

    The paper treats this as an OFFLINE one-time step, so the resulting
    table (a deterministic function of corpus + configs) is memoised on
    disk under experiments/cache/ — re-deriving identical weights in every
    benchmark process would just re-pay the pretraining latency the design
    exists to hide. Set REPRO_PRETRAIN_CACHE=0 to disable.
    """
    trainer = Trainer(pcfg, tcfg, kind)
    use_cache = os.environ.get("REPRO_PRETRAIN_CACHE", "1") != "0"
    cache_path = PRETRAIN_CACHE_DIR / f"pretrain_{_pretrain_cache_key(corpus, pcfg, tcfg, kind, target_acc, max_rounds)}.pkl"
    if use_cache and cache_path.exists():
        try:
            blob = pickle.loads(cache_path.read_bytes())
            table = ModelTable(lambda s: trainer.new_params(s), n_slots=blob["n_slots"])
            from repro.core.model_table import Entry

            for s, e in blob["slots"].items():
                table.slots[s] = Entry(
                    params=e["params"], prev_params=e["prev_params"], opt_state=e["opt_state"],
                    step=e["step"], n_updates=e["n_updates"], last_acc=e["last_acc"],
                )
            return table
        except Exception:
            pass  # truncated/corrupt memo: fall through and retrain
    table = ModelTable(lambda s: trainer.new_params(s), n_slots=tcfg.table_slots)
    classifier = PatternClassifier()
    groups = []  # (pattern, FeatureSet, n_active)
    for tr in corpus:
        vocab = DeltaVocab(pcfg.delta_vocab)
        stream = FeatureStream(tr, vocab, pcfg.history, page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab)
        half = len(tr) // 2
        for g0 in range(0, half, tcfg.group_size):
            g1 = min(g0 + tcfg.group_size, half)
            fs = stream.windows(g0, g1)
            if len(fs):
                pat = classifier.classify(tr.block[g0:g1], tr.kernel[g0:g1])
                groups.append((pat, fs, max(vocab.n_classes, 2)))
    for _ in range(max_rounds):
        accs = []
        for pat, fs, n_active in groups:
            entry = table.get(pat)
            corr, _ = trainer.evaluate(entry.params, fs, n_active)
            accs.append(corr.mean())
            # corpus accuracy seeds the prefetch gate CONSERVATIVELY: transfer
            # to an unseen trace is unproven until measured on it
            entry.last_acc = min(float(corr.mean()), 0.5)
            entry = trainer.train_group(entry, fs, n_active)
            table.put(pat, entry)
        if accs and float(np.mean(accs)) >= target_acc:
            break
    if use_cache:
        try:
            PRETRAIN_CACHE_DIR.mkdir(parents=True, exist_ok=True)
            # atomic publish: a killed writer must never leave a torn file
            tmp = cache_path.with_suffix(f".tmp.{os.getpid()}")
            tmp.write_bytes(pickle.dumps(_table_to_host(table)))
            os.replace(tmp, cache_path)
        except OSError:
            pass  # read-only checkouts still work, just without the memo
    return table


def _prefetch_warm(entry, pat) -> bool:
    """Pattern-aware aggressiveness gate (see the comment in run_ours):
    cold models and random-classified phases must not drive prefetch, and
    the PREVIOUS group's measured accuracy must clear a pattern-dependent
    floor before speculative migration is worth PCIe bandwidth."""
    acc_floor = 0.4 if pat == LINEAR else 0.6
    return entry.n_updates > 0 and pat not in (RANDOM, RANDOM_REUSE) and entry.last_acc >= acc_floor


def _prefetch_mask(dense: np.ndarray, pred_pages: np.ndarray, last_acc: float, nb: int, cap: int) -> np.ndarray:
    """Section IV-D prefetch candidate selection: gate by repeated
    prediction and cap the in-flight budget, scaled by model confidence."""
    pblocks = predicted_blocks(pred_pages, PAGES_PER_BLOCK)
    pblocks = pblocks[pblocks < nb]
    # confidence-scaled aggressiveness: a highly-accurate model may
    # prefetch every predicted block; a mediocre one only repeated ones
    min_freq = 1 if last_acc >= 0.7 else 2
    pblocks = pblocks[dense[pblocks] >= min_freq]
    budget = cap if last_acc >= 0.7 else cap // 2
    if len(pblocks) > budget:
        order = np.argsort(-dense[pblocks], kind="stable")
        pblocks = pblocks[order[:budget]]
    mask = np.zeros(nb, bool)
    mask[pblocks] = True
    return mask


def run_ours(
    trace: Trace,
    pcfg: PredictorConfig | None = None,
    tcfg: TrainConfig | None = None,
    *,
    oversubscription: float = 1.25,
    kind: str = "transformer",
    table: ModelTable | None = None,
    use_thrash_term: bool = True,
    use_lucir: bool = True,
    seed: int = 0,
) -> LearnedRunResult:
    pcfg = pcfg or PredictorConfig()
    tcfg = tcfg or TrainConfig()
    trainer = Trainer(pcfg, tcfg, kind)
    if table is None:
        table = ModelTable(lambda s: trainer.new_params(s), n_slots=tcfg.table_slots)
    vocab = DeltaVocab(pcfg.delta_vocab)
    stream = FeatureStream(trace, vocab, pcfg.history, page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab)
    classifier = PatternClassifier()
    freq_table = PredictionFrequencyTable()

    nb = S.bucket_blocks(trace.n_blocks)
    cap = S.capacity_for(trace.n_blocks, oversubscription)
    state = S.init_state(nb, seed)
    blocks = trace.block.astype(np.int32)
    nxt = S.next_use_for(trace)  # cached per trace across groups/cells
    dtable_cache: dict[int, int] = {}

    n = len(trace)
    per_group = []
    n_pred = 0
    all_corr = []
    warm_corr = []
    last_interval = 0
    for g0 in range(0, n, tcfg.group_size):
        g1 = min(g0 + tcfg.group_size, n)
        fs = stream.windows(g0, g1)
        pat = classifier.classify(blocks[g0:g1], trace.kernel[g0:g1])
        entry = table.get(pat)
        n_active = max(vocab.n_classes, 2)

        in_et = None
        # pattern-aware aggressiveness: cold models must not drive prefetch;
        # random-classified phases get eviction-only management (their delta
        # predictions are noise by construction — the same reasoning UVMSmart
        # uses to switch random phases to pinning); and the PREVIOUS group's
        # measured accuracy (known at decision time — no future info) must
        # clear a floor before speculative migration is worth PCIe bandwidth.
        # Pure streaming (no re-reference) is cheap to speculate on — wrong
        # blocks are evicted harmlessly; reuse patterns risk evicting hot
        # pages, so they need a higher confidence bar.
        warm = _prefetch_warm(entry, pat)
        if len(fs):
            # 2. strictly-causal prediction for the group
            corr, pred_cls = trainer.evaluate(entry.params, fs, n_active)
            per_group.append(float(corr.mean()))
            all_corr.append(corr)
            if entry.n_updates > 0:
                warm_corr.append(corr)
            n_pred += len(fs)
            entry.last_acc = float(corr.mean())  # informs the NEXT group's gate

            # 3. predicted pages -> frequency table + staged prefetches
            dtable_cache.update(vocab.decode_table())
            pred_delta = np.array([dtable_cache.get(int(c), 0) for c in pred_cls], np.int64)
            prev_page = trace.page[fs.t_index - 1].astype(np.int64)
            pred_pages = np.clip(prev_page + pred_delta, 0, trace.n_pages - 1)
        if len(fs) and warm:
            freq_table.update(np.asarray(pred_pages, np.int64) // PAGES_PER_BLOCK)
            # one dense export per group: it feeds both the simulator's
            # `learned` eviction keys and the prefetch gate below
            dense = freq_table.dense(nb)
            state = state._replace(freq=jnp.asarray(dense))
            # Section IV-D: "prefetching candidates will be selected from the
            # pages with the highest prediction frequency ... to control the
            # amount of prefetching while the oversubscription level is high":
            # gate by repeated prediction + cap the in-flight budget, so a
            # weakly-trained predictor cannot flood the device with garbage.
            mask = _prefetch_mask(dense, pred_pages, entry.last_acc, nb, cap)
            state = S.apply_prefetch(state, jnp.asarray(mask), capacity=cap, policy="learned")

        # 4. simulator segment under the learned policy
        state, outs = S._run_segment(
            state, jnp.asarray(blocks[g0:g1]), jnp.asarray(nxt[g0:g1]),
            n_blocks=nb, capacity=cap, policy="learned", prefetch="demand", n_valid=trace.n_blocks,
        )
        was_evicted = np.asarray(outs["was_evicted"])

        # frequency table flush cadence (every 3 fault-intervals)
        interval_now = int(state.fault_count) // S.INTERVAL
        if interval_now > last_interval:
            freq_table.on_intervals(interval_now - last_interval)
            last_interval = interval_now

        # 5. fine-tune on the group with E∪T flags
        if len(fs):
            if use_lucir:
                table.snapshot_prev(pat)
                entry = table.get(pat)
            in_et = was_evicted[fs.t_index - g0] if use_thrash_term else None
            entry = trainer.train_group(entry, fs, n_active, in_et=in_et, use_lucir=use_lucir)
            table.put(pat, entry)

    stats = {
        "pages_thrashed": int(state.thrash_events) * PAGES_PER_BLOCK,
        "faults": int(state.faults),
        "migrated_blocks": int(state.migrations),
        "zero_copy": int(state.zero_copy),
        "occupancy": int(state.occupancy),
    }
    top1 = float(np.concatenate(all_corr).mean()) if all_corr else 0.0
    warm = float(np.concatenate(warm_corr).mean()) if warm_corr else top1
    return LearnedRunResult(stats, top1, n_pred, vocab.n_classes, table.n_models, per_group, warm)


@dataclasses.dataclass
class _Lane:
    """Per-trace runtime state for :func:`run_ours_many` (each lane owns its
    model table, vocabulary, classifier, frequency table and simulator
    state — lanes are fully independent, exactly as serial runs are)."""

    trace: Trace
    table: ModelTable
    vocab: DeltaVocab
    stream: FeatureStream
    classifier: PatternClassifier
    freq_table: PredictionFrequencyTable
    nb: int
    cap: int
    state: object
    blocks: np.ndarray
    nxt: np.ndarray
    dtable: dict = dataclasses.field(default_factory=dict)
    per_group: list = dataclasses.field(default_factory=list)
    all_corr: list = dataclasses.field(default_factory=list)
    warm_corr: list = dataclasses.field(default_factory=list)
    n_pred: int = 0
    last_interval: int = 0


def run_ours_many(
    traces: list[Trace],
    pcfg: PredictorConfig | None = None,
    tcfg: TrainConfig | None = None,
    *,
    oversubscription: float = 1.25,
    kind: str = "transformer",
    tables: list[ModelTable] | None = None,
    use_thrash_term: bool = True,
    use_lucir: bool = True,
    seed: int = 0,
) -> list[LearnedRunResult]:
    """Run the full learned system over MANY traces in lockstep.

    The per-group serial pipeline of :func:`run_ours` (classify -> predict
    -> prefetch -> simulate -> fine-tune) is kept, but each stage is batched
    across benchmarks: predictions and fine-tuning go through the vmapped
    ``Trainer.evaluate_many`` / ``train_group_many`` (lanes bucketed by
    shape share one dispatch), and simulator segments run through
    :func:`repro.uvm.simulator.run_segments_many` (per-lane event streams,
    one vmapped scan per shape bucket).  Lanes never interact — each trace
    keeps its own model table, vocabulary, frequency table and simulator
    state.  The simulator stages are exactly per-lane-equivalent; the
    vmapped predictor reproduced serial floats bit-for-bit on CPU
    (tests/test_system.py pins counters AND top1 against serial runs), but
    a backend whose batched kernels round differently could shift a
    prediction across a prefetch-gate threshold and with it the learned
    run's counters — if paper-table stability across device counts matters
    more than throughput, force the serial engine with
    ``REPRO_OURS_BATCHED=0``.
    """
    pcfg = pcfg or PredictorConfig()
    tcfg = tcfg or TrainConfig()
    trainer = Trainer(pcfg, tcfg, kind)
    lanes: list[_Lane] = []
    for li, trace in enumerate(traces):
        table = tables[li] if tables is not None else ModelTable(lambda s: trainer.new_params(s), n_slots=tcfg.table_slots)
        vocab = DeltaVocab(pcfg.delta_vocab)
        nb = S.bucket_blocks(trace.n_blocks)
        lanes.append(_Lane(
            trace=trace, table=table, vocab=vocab,
            stream=FeatureStream(trace, vocab, pcfg.history, page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab),
            classifier=PatternClassifier(), freq_table=PredictionFrequencyTable(),
            nb=nb, cap=S.capacity_for(trace.n_blocks, oversubscription),
            state=S.init_state(nb, seed), blocks=trace.block.astype(np.int32),
            nxt=S.next_use_for(trace),
        ))
    G = tcfg.group_size
    max_n = max((len(l.trace) for l in lanes), default=0)
    for g0 in range(0, max_n, G):
        act = [l for l in lanes if g0 < len(l.trace)]
        work = []  # (lane, g1, fs, pat, entry, n_active)
        for l in act:
            g1 = min(g0 + G, len(l.trace))
            fs = l.stream.windows(g0, g1)
            pat = l.classifier.classify(l.blocks[g0:g1], l.trace.kernel[g0:g1])
            entry = l.table.get(pat)
            work.append((l, g1, fs, pat, entry, max(l.vocab.n_classes, 2)))

        # 2. strictly-causal predictions for every lane's group, one
        #    vmapped dispatch per shape bucket
        evals = [w for w in work if len(w[2])]
        results = trainer.evaluate_many(
            [w[4].params for w in evals], [w[2] for w in evals], [w[5] for w in evals],
        )
        for (l, g1, fs, pat, entry, n_active), (corr, pred_cls) in zip(evals, results):
            warm = _prefetch_warm(entry, pat)  # uses the PREVIOUS group's acc
            l.per_group.append(float(corr.mean()))
            l.all_corr.append(corr)
            if entry.n_updates > 0:
                l.warm_corr.append(corr)
            l.n_pred += len(fs)
            entry.last_acc = float(corr.mean())  # informs the NEXT group's gate
            # 3. predicted pages -> frequency table + staged prefetches
            l.dtable.update(l.vocab.decode_table())
            pred_delta = np.array([l.dtable.get(int(c), 0) for c in pred_cls], np.int64)
            prev_page = l.trace.page[fs.t_index - 1].astype(np.int64)
            pred_pages = np.clip(prev_page + pred_delta, 0, l.trace.n_pages - 1)
            if warm:
                l.freq_table.update(np.asarray(pred_pages, np.int64) // PAGES_PER_BLOCK)
                dense = l.freq_table.dense(l.nb)
                l.state = l.state._replace(freq=jnp.asarray(dense))
                mask = _prefetch_mask(dense, pred_pages, entry.last_acc, l.nb, l.cap)
                l.state = S.apply_prefetch(l.state, jnp.asarray(mask), capacity=l.cap, policy="learned")

        # 4. simulator segments under the learned policy, vmapped across
        #    lanes (each lane has its own compressed event stream)
        cell = lambda l: (S.POLICY_IDS["learned"], S.PREFETCH_IDS["demand"], l.cap)
        seg = S.run_segments_many(
            [l.state for l, *_ in work],
            [(l.blocks[g0:g1], l.nxt[g0:g1]) for l, g1, *_ in work],
            [cell(l) for l, *_ in work],
            [l.trace.n_blocks for l, *_ in work],
        )
        train_entries, train_fs, train_na, train_et = [], [], [], []
        train_work = []
        for (l, g1, fs, pat, entry, n_active), (state, outs) in zip(work, seg):
            l.state = state
            interval_now = int(state.fault_count) // S.INTERVAL
            if interval_now > l.last_interval:
                l.freq_table.on_intervals(interval_now - l.last_interval)
                l.last_interval = interval_now
            if len(fs):
                if use_lucir:
                    l.table.snapshot_prev(pat)
                    entry = l.table.get(pat)
                was_evicted = np.asarray(outs["was_evicted"])
                train_entries.append(entry)
                train_fs.append(fs)
                train_na.append(n_active)
                train_et.append(was_evicted[fs.t_index - g0] if use_thrash_term else None)
                train_work.append((l, pat, entry))

        # 5. fine-tune every lane's model, one vmapped dispatch per bucket
        trainer.train_group_many(train_entries, train_fs, train_na, in_et_list=train_et, use_lucir=use_lucir)
        for l, pat, entry in train_work:
            l.table.put(pat, entry)

    out = []
    for l in lanes:
        stats = {
            "pages_thrashed": int(l.state.thrash_events) * PAGES_PER_BLOCK,
            "faults": int(l.state.faults),
            "migrated_blocks": int(l.state.migrations),
            "zero_copy": int(l.state.zero_copy),
            "occupancy": int(l.state.occupancy),
        }
        top1 = float(np.concatenate(l.all_corr).mean()) if l.all_corr else 0.0
        warm = float(np.concatenate(l.warm_corr).mean()) if l.warm_corr else top1
        out.append(LearnedRunResult(stats, top1, l.n_pred, l.vocab.n_classes, l.table.n_models, l.per_group, warm))
    return out
