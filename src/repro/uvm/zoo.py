"""Drifting-workload zoo: seeded generators BEYOND the fixed 11-benchmark
suite, built to exercise the streaming re-classification machinery
(`ManagerConfig.reclass_interval`/`reclass_hysteresis`) and the
`TenantMux`'s churn handling rather than a steady-state pattern.

Four families:

* :func:`phase_trace` — phase-CHANGE traces splicing between registered
  base patterns at configurable switch points, abrupt or gradual (a
  seeded probabilistic blend window around each boundary);
* :func:`tenant_churn` — multi-tenant merges where sessions JOIN late and
  LEAVE early mid-stream (`trace.concurrent` with per-tenant ``starts`` +
  truncated spans);
* irregular single-pattern generators past the paper's suite:
  :func:`pointer_chase` (permutation-chain walk, firmly random-classified),
  :func:`random_scan` (fresh uniform draws — unmemorizable noise) and
  :func:`strided_noise` (fixed stride with a seeded fraction of random
  interruptions) — registered in :data:`PATTERNS` and usable anywhere a
  benchmark name is (``Session``/CLI/sweeps resolve through
  :func:`get_trace`);
* external replay — zoo (or any) traces export through
  :func:`repro.uvm.trace.to_fault_log` and real logs ingest through
  :func:`repro.uvm.trace.from_fault_log`.

Everything is deterministic under a fixed seed, and phase segments are
BIT-EQUAL to their standalone base-pattern traces outside the blend
windows (property-tested in tests/test_zoo.py): the drift benchmark
(benchmarks/tables.py::table9) depends on each phase being the genuine
article, not a lookalike.

The declarative API reaches the zoo through ``WorkloadSpec.drift``
(:class:`repro.uvm.api.specs.DriftSpec`) — see docs/API.md.
"""
from __future__ import annotations

import numpy as np

from repro.uvm import trace as T
from repro.uvm.trace import Trace, _align


# ---------------------------------------------------------------------------
# Irregular base patterns beyond the 11-benchmark suite.
# ---------------------------------------------------------------------------


def pointer_chase(scale: float = 1.0, seed: int = 11, passes: int = 3) -> Trace:
    """Linked-structure traversal: a seeded permutation cycle walked
    pointer-by-pointer.  Deltas are near-unique (no dominant stride), so the
    DFA classifies it firmly random; repeated passes over the same chain add
    cross-kernel re-reference (random REUSE) — the irregular-application
    shape the 11-benchmark suite lacks."""
    n = _align(int(768 * scale))
    b = T._Builder("PtrChase", n, seed)
    order = b.rng.permutation(n)
    # next[order[i]] = order[i+1]: one big cycle; the walk IS the pointer chain
    chain = np.empty(n, np.int64)
    chain[order[:-1]] = order[1:]
    chain[order[-1]] = order[0]
    cur = int(order[0])
    walk = np.empty(n, np.int64)
    for i in range(n):
        walk[i] = cur
        cur = int(chain[cur])
    for p in range(passes):
        b.emit(walk, pc=p % 2)
        b.next_kernel()
    return b.build()


def strided_noise(scale: float = 1.0, seed: int = 12, stride: int = 8,
                  noise: float = 0.2, iters: int = 3) -> Trace:
    """Strided sweep with seeded random interruptions: a fixed ``stride``
    walk where a ``noise`` fraction of accesses gather random pages (TLB
    shootdowns, helper-structure lookups).  Sits between the suite's clean
    streaming and pure random — the stride still dominates, but the noise
    floor drags the DFA's linearity score toward the mixed boundary."""
    n = _align(int(1024 * scale))
    b = T._Builder("StridedNoise", n, seed)
    steps = n  # the stride walk wraps `stride` times per pass, touching every page
    for it in range(iters):
        base = (np.arange(steps) * stride + it) % n
        jam = b.rng.random(steps) < noise
        pages = np.where(jam, b.rng.integers(0, n, steps), base)
        b.emit(pages, pc=it % 2)
        b.next_kernel()
    return b.build()


def random_scan(scale: float = 1.0, seed: int = 13, iters: int = 3) -> Trace:
    """Uniform random pages, FRESH draws every kernel: unlike
    :func:`pointer_chase` (whose repeated walk a capable predictor can
    memorize) there is nothing to learn here.  As a drift phase it is pure
    model poison — training on it only scrambles whatever model absorbs it,
    which is exactly what benchmarks/tables.py::table9 uses it for: a
    re-classifying manager quarantines the noise in the RANDOM entry while
    a frozen-pattern manager feeds it to the phase-A model."""
    n = _align(int(1024 * scale))
    b = T._Builder("RandomScan", n, seed)
    for it in range(iters):
        b.emit(b.rng.integers(0, n, n), pc=it % 2)
        b.next_kernel()
    return b.build()


#: the zoo's registered single-pattern workloads — resolvable anywhere a
#: benchmark name is (Session traces, CLI --benchmark choices, sweeps)
PATTERNS = {
    "PtrChase": pointer_chase,
    "RandomScan": random_scan,
    "StridedNoise": strided_noise,
}

#: access-pattern category of the zoo entries (extends trace.CATEGORY)
CATEGORY = {
    "PtrChase": "random",
    "RandomScan": "random",
    "StridedNoise": "mixed",
}


def get_trace(name: str, scale: float = 1.0) -> Trace:
    """Zoo-aware benchmark resolution: the paper's 11 generators first
    (:data:`repro.uvm.trace.BENCHMARKS`), then the zoo's :data:`PATTERNS`."""
    if name in T.BENCHMARKS:
        return T.BENCHMARKS[name](scale=scale)
    if name in PATTERNS:
        return PATTERNS[name](scale=scale)
    raise KeyError(f"unknown workload {name!r}; one of "
                   f"{sorted(T.BENCHMARKS) + sorted(PATTERNS)}")


def workload_names() -> list[str]:
    """Every resolvable workload name: the 11-benchmark suite + the zoo."""
    return sorted(T.BENCHMARKS) + sorted(PATTERNS)


# ---------------------------------------------------------------------------
# Phase-change traces.
# ---------------------------------------------------------------------------


def _blend(out_tail: np.ndarray, in_head: np.ndarray, rng) -> np.ndarray:
    """Probabilistic boundary merge: interleave the outgoing phase's tail
    with the incoming phase's head, drawing the incoming side with a
    probability that ramps 0 -> 1 across the window.  Each side's internal
    order is preserved (it is a MERGE of two subsequences, never a shuffle),
    so per-phase access order survives the gradual switch.  Returns indices
    into the virtual concatenation [out_tail, in_head]."""
    na, nb = len(out_tail), len(in_head)
    ia = ib = 0
    order = np.empty(na + nb, np.int64)
    for j in range(na + nb):
        p_in = (j + 1) / (na + nb + 1)
        take_b = ib < nb and (ia >= na or rng.random() < p_in)
        if take_b:
            order[j] = na + ib
            ib += 1
        else:
            order[j] = ia
            ia += 1
    return order


def phase_trace(phases, scale: float = 1.0, seed: int = 0, segment: int = 1500,
                switch: str = "abrupt", mix_window: int = 0, name: str | None = None) -> Trace:
    """A workload whose access pattern CHANGES mid-stream: ``segment``
    accesses of each named base pattern (benchmark or zoo entry), spliced in
    order over a shared page space (``n_pages`` = the widest phase — a phase
    change over one allocation, not a tenant switch).

    ``switch='abrupt'`` concatenates the segments exactly: every segment is
    bit-equal to the first ``segment`` accesses of its standalone generator
    (the property tests pin this).  ``switch='gradual'`` additionally blends
    each boundary: the last ``mix_window`` accesses of the outgoing phase
    and the first ``mix_window`` of the incoming one are merged with a
    seeded ramping probability — per-phase access order is preserved, and
    everything outside the windows stays bit-equal."""
    phases = tuple(phases)
    if len(phases) < 2:
        raise ValueError("phase_trace needs at least two phases")
    if switch not in ("abrupt", "gradual"):
        raise ValueError(f"unknown switch {switch!r}; 'abrupt' or 'gradual'")
    segs = []
    for p in phases:
        tr = get_trace(p, scale=scale)
        segs.append(tr.slice(0, min(len(tr), segment)))
    n_pages = max(s.n_pages for s in segs)
    fields = ("page", "pc", "tb", "kernel")
    chunks = {f: [getattr(s, f) for s in segs] for f in fields}
    if switch == "gradual" and mix_window > 0:
        rng = np.random.default_rng(seed)
        for b in range(len(segs) - 1):
            w = min(mix_window, len(chunks["page"][b]), len(chunks["page"][b + 1]))
            if w == 0:
                continue
            order = _blend(chunks["page"][b][-w:], chunks["page"][b + 1][:w], rng)
            for f in fields:
                window = np.concatenate([chunks[f][b][-w:], chunks[f][b + 1][:w]])[order]
                chunks[f][b] = np.concatenate([chunks[f][b][:-w], window[:w]])
                chunks[f][b + 1] = np.concatenate([window[w:], chunks[f][b + 1][w:]])
    label = name or ("drift:" + ">".join(phases) + (f"|{switch}" if switch != "abrupt" else ""))
    return Trace(
        label,
        *(np.concatenate(chunks[f]).astype(np.int32) for f in fields),
        n_pages,
    )


# ---------------------------------------------------------------------------
# Tenant-churn streams.
# ---------------------------------------------------------------------------


def tenant_churn(tenants, scale: float = 1.0, seed: int = 0,
                 joins=(), spans=(), slice_len: int = 256) -> Trace:
    """A multi-tenant merge where sessions JOIN and LEAVE mid-run: tenant
    ``i`` is admitted only after ``joins[i]`` merged accesses
    (``trace.concurrent``'s ``starts``) and is truncated to ``spans[i]``
    accesses when positive (it leaves when its trace runs out).

    ``joins=()`` auto-staggers the arrivals evenly across the first half of
    the stream; ``spans=()`` keeps every tenant's full trace.  The result is
    tenant-tagged like any concurrent trace, so `run_ours`/`Session` route
    it through the :class:`~repro.uvm.manager.TenantMux`, whose
    ``auto_create`` admission and per-tenant clock catch-up are exactly
    what churn stresses."""
    tenants = tuple(tenants)
    parts = []
    for i, nm in enumerate(tenants):
        tr = get_trace(nm, scale=scale)
        span = spans[i] if i < len(spans) and spans[i] else len(tr)
        parts.append(tr.slice(0, min(len(tr), span)))
    if not joins:
        total = sum(len(p) for p in parts)
        joins = tuple(i * total // (2 * max(len(parts), 1)) for i in range(len(parts)))
    tr = T.concurrent(parts, seed=seed, slice_len=slice_len, starts=list(joins))
    tr.name = "churn:" + "+".join(tenants)
    return tr
