"""Plugin registries for eviction policies, prefetchers, and predictors.

The simulator's victim-key builders (``lru``/``random``/``belady``/``hpe``/
``learned``), its prefetch mask builders (``demand``/``tree``), and the
predictor architectures (``transformer``/``lstm``/``cnn``/``mlp``) are all
REGISTERED default entries of the tables below, not hardwired branches.  A
new strategy is a ~20-line registration that rides the existing
packed-priority vmapped scan — no edits to ``repro/uvm/simulator.py``:

    from repro.uvm.api import register_policy

    def mru_keys(state, interval_now, t_now):
        # most-recently-used first: larger last_access = better victim
        return (-state.last_access,)

    register_policy("mru", mru_keys)
    S.run_batch(trace, [("mru", "tree", 1.25), ...])   # vmapped as usual

Contracts:

* **policy key_fn(state, interval_now, t_now)** returns a tuple of up to 3
  int32 arrays shaped like ``state.last_access`` — the lexicographic victim
  key (smallest evicts first).  Keys must be constant for the whole step
  (nothing an eviction changes may feed back into them); that invariant is
  what lets ``_evict_fit`` pick victims by chained masked-argmin without
  re-ranking.
* **prefetcher mask_fn(resident, blk, valid, n_blocks)** returns a bool
  mask of blocks to migrate alongside a faulted block (it runs only on
  faulting steps; ``resident`` already includes the demand block).
* **predictor builder(cfg)** returns ``(init_fn(rng) -> params,
  forward(params, batch) -> (logits, features))`` — the
  :func:`repro.core.baselines_nn.make_model` contract.
* **classifier factory()** returns a fresh stateful pattern classifier
  with ``classify(blocks, kernels) -> pattern_id`` and ``reset()`` — the
  :class:`repro.core.pattern.PatternClassifier` contract.  Builtin:
  ``dfa``.  Used by :class:`repro.uvm.manager.OversubscriptionManager`.
* **freq-table factory()** returns a fresh prediction-frequency engine
  with ``update(blocks)`` / ``lookup_many(blocks)`` / ``dense(n_blocks)``
  / ``on_intervals(n)`` — the
  :class:`repro.core.policy.PredictionFrequencyTable` contract.  Builtin:
  ``setassoc`` (the paper's 1024x16 set-associative table).
* **stability factory(**kw)** returns a scorer ``score(history) ->
  float in [0, 1]`` mapping one tenant's per-round pressure history (its
  thrash rate per access) to how oversubscribable that tenant currently
  is (1 = perfectly stable, 0 = thrashing) — the shape of scroogevm's
  ``stability_assesser``.  Builtins: ``percentile``, ``gmr``.  Used by
  :class:`repro.uvm.qos.BudgetController` to weight elastic budgets.

Registration order is identity: entry ids are assigned densely in
registration order and traced into the compiled scans as runtime values, so
the builtin ids (lru=0 .. learned=4, demand=0, tree=1) are stable and the
golden counters are unaffected by later registrations.  The simulator keys
its jitted entry points on the branch tables themselves
(:func:`policy_branches` / :func:`prefetch_branches`), so a scan compiled
under one table is never reused with a different one — and restoring the
tables (:func:`scoped`) re-hits the original compiles.  A monotonic
version counter additionally tracks policy/prefetcher registrations for
diagnostics.

Names are single-owner: registering an existing name raises ``ValueError``.
Tests (or notebooks) that want throwaway registrations should use
:func:`scoped`, which restores all three tables on exit.
"""
from __future__ import annotations

import contextlib
from typing import Callable, NamedTuple

__all__ = [
    "register_policy",
    "register_prefetcher",
    "register_predictor",
    "register_classifier",
    "register_freq_table",
    "register_stability",
    "policy_names",
    "prefetcher_names",
    "predictor_names",
    "classifier_names",
    "freq_table_names",
    "stability_names",
    "policy_branches",
    "prefetch_branches",
    "predictor_builder",
    "classifier_factory",
    "freq_table_factory",
    "stability_factory",
    "registry_version",
    "scoped",
    "POLICY_IDS",
    "PREFETCH_IDS",
]


class _PolicyEntry(NamedTuple):
    name: str
    pid: int
    key_fn: Callable  # (state, interval_now, t_now) -> tuple of int32 arrays


class _PrefetchEntry(NamedTuple):
    name: str
    pid: int
    mask_fn: Callable | None  # (resident, blk, valid, n_blocks) -> bool mask


_POLICIES: dict[str, _PolicyEntry] = {}
_PREFETCHERS: dict[str, _PrefetchEntry] = {}
_PREDICTORS: dict[str, Callable] = {}
_CLASSIFIERS: dict[str, Callable] = {}
_FREQ_TABLES: dict[str, Callable] = {}
_STABILITY: dict[str, Callable] = {}

# name -> dense id (aliases share the target's id). These dict OBJECTS are
# stable — the simulator imports and holds them — so registrations made
# after import are visible everywhere.
POLICY_IDS: dict[str, int] = {}
PREFETCH_IDS: dict[str, int] = {}

_VERSION = [0]


def registry_version() -> int:
    """Monotonic counter bumped by every policy/prefetcher registration
    (diagnostics; predictor registrations never enter the simulator's
    branch tables and so never bump it). The simulator's jit caches key on
    the branch tables themselves, not on this counter."""
    return _VERSION[0]


def _claim(table: dict, name: str, kind: str) -> None:
    if not name or not isinstance(name, str):
        raise ValueError(f"{kind} name must be a non-empty string, got {name!r}")
    if name in table:
        raise ValueError(f"{kind} {name!r} is already registered")


def register_policy(name: str, key_fn: Callable) -> None:
    """Register an eviction policy by its victim-key builder.

    ``key_fn(state, interval_now, t_now)`` must return a tuple of 1-3 int32
    arrays shaped like ``state.last_access``; the resident block with the
    lexicographically-smallest key is evicted first.
    """
    _claim(_POLICIES, name, "policy")
    entry = _PolicyEntry(name, len(_POLICIES), key_fn)
    _POLICIES[name] = entry
    POLICY_IDS[name] = entry.pid
    _VERSION[0] += 1


def register_prefetcher(name: str, mask_fn: Callable | None = None, *, alias_of: str | None = None) -> None:
    """Register a prefetcher by its migration-mask builder.

    ``mask_fn(resident, blk, valid, n_blocks)`` returns the bool mask of
    extra blocks to migrate when block ``blk`` faults (``mask_fn=None``
    means demand-only: no extra migration).  ``alias_of`` registers a
    second name for an existing entry (same id — e.g. ``none`` -> ``demand``).
    """
    _claim(_PREFETCHERS, name, "prefetcher")
    if alias_of is not None:
        if mask_fn is not None:
            raise ValueError("pass either mask_fn or alias_of, not both")
        if alias_of not in _PREFETCHERS:
            raise ValueError(f"alias_of target {alias_of!r} is not a registered prefetcher")
        target = _PREFETCHERS[alias_of]
        entry = _PrefetchEntry(name, target.pid, target.mask_fn)
    else:
        n_real = len({e.pid for e in _PREFETCHERS.values()})
        entry = _PrefetchEntry(name, n_real, mask_fn)
    _PREFETCHERS[name] = entry
    PREFETCH_IDS[name] = entry.pid
    _VERSION[0] += 1


def register_predictor(name: str, builder: Callable) -> None:
    """Register a predictor architecture.

    ``builder(cfg: PredictorConfig)`` returns ``(init_fn, forward)`` per the
    :func:`repro.core.baselines_nn.make_model` contract; the name becomes a
    valid ``kind`` for ``Trainer`` / ``run_protocol`` / ``ModelSpec``.
    Predictors never enter the simulator's branch tables, so this does NOT
    bump :func:`registry_version` (no pointless scan re-traces).
    """
    _claim(_PREDICTORS, name, "predictor")
    _PREDICTORS[name] = builder


def register_classifier(name: str, factory: Callable) -> None:
    """Register an access-pattern classifier by a zero-arg factory.

    ``factory()`` returns a fresh STATEFUL classifier instance exposing
    ``classify(blocks, kernels) -> pattern_id`` and ``reset()`` (the
    :class:`repro.core.pattern.PatternClassifier` contract); the name
    becomes a valid ``classifier`` for
    :class:`repro.uvm.manager.OversubscriptionManager`.  Classifiers never
    enter the simulator's branch tables (no version bump).
    """
    _claim(_CLASSIFIERS, name, "classifier")
    _CLASSIFIERS[name] = factory


def register_freq_table(name: str, factory: Callable) -> None:
    """Register a prediction-frequency engine by a zero-arg factory.

    ``factory()`` returns a fresh table exposing ``update(blocks)``,
    ``lookup_many(blocks)``, ``dense(n_blocks)`` and ``on_intervals(n)``
    (the :class:`repro.core.policy.PredictionFrequencyTable` contract);
    the name becomes a valid ``freq_table`` for the manager.  Frequency
    tables never enter the simulator's branch tables (no version bump).
    """
    _claim(_FREQ_TABLES, name, "freq-table")
    _FREQ_TABLES[name] = factory


def register_stability(name: str, factory: Callable) -> None:
    """Register a QoS stability scorer by a keyword-arg factory.

    ``factory(**kw)`` returns a scorer callable ``score(history) -> float``
    mapping a tenant's per-round pressure history (1-D array, thrash rate
    per access, higher = worse) into ``[0, 1]`` (1 = stable, safe to lend
    capacity to; 0 = thrashing); the name becomes a valid ``stability``
    for :class:`repro.uvm.qos.BudgetController` / ``QosSpec``.  Stability
    scorers never enter the simulator's branch tables (no version bump).
    """
    _claim(_STABILITY, name, "stability")
    _STABILITY[name] = factory


def policy_names() -> tuple[str, ...]:
    return tuple(_POLICIES)


def prefetcher_names() -> tuple[str, ...]:
    return tuple(_PREFETCHERS)


def predictor_names() -> tuple[str, ...]:
    return tuple(_PREDICTORS)


def classifier_names() -> tuple[str, ...]:
    return tuple(_CLASSIFIERS)


def freq_table_names() -> tuple[str, ...]:
    return tuple(_FREQ_TABLES)


def stability_names() -> tuple[str, ...]:
    return tuple(_STABILITY)


def policy_branches() -> tuple[Callable, ...]:
    """Victim-key builders ordered by id (the ``lax.switch`` branch table)."""
    return tuple(e.key_fn for e in sorted(_POLICIES.values(), key=lambda e: e.pid))


def prefetch_branches() -> tuple[Callable | None, ...]:
    """Mask builders ordered by id, one per DISTINCT id (aliases collapse)."""
    by_id: dict[int, Callable | None] = {}
    for e in _PREFETCHERS.values():
        by_id.setdefault(e.pid, e.mask_fn)
    return tuple(by_id[i] for i in sorted(by_id))


def predictor_builder(name: str) -> Callable:
    try:
        return _PREDICTORS[name]
    except KeyError:
        raise KeyError(f"unknown predictor kind {name!r}; registered: {sorted(_PREDICTORS)}") from None


def classifier_factory(name: str) -> Callable:
    try:
        return _CLASSIFIERS[name]
    except KeyError:
        raise KeyError(f"unknown classifier {name!r}; registered: {sorted(_CLASSIFIERS)}") from None


def freq_table_factory(name: str) -> Callable:
    try:
        return _FREQ_TABLES[name]
    except KeyError:
        raise KeyError(f"unknown freq-table {name!r}; registered: {sorted(_FREQ_TABLES)}") from None


def stability_factory(name: str) -> Callable:
    try:
        return _STABILITY[name]
    except KeyError:
        raise KeyError(f"unknown stability scorer {name!r}; registered: {sorted(_STABILITY)}") from None


@contextlib.contextmanager
def scoped():
    """Restore all registry TABLES on exit — for tests and notebooks that
    register throwaway entries.

    The version counter is NOT rolled back: it is monotonic (a version
    number must never refer to two different table states). The simulator's
    jit caches key on the tables themselves, so exiting a scope re-hits the
    compiles that existed before it."""
    saved = (
        dict(_POLICIES), dict(_PREFETCHERS), dict(_PREDICTORS),
        dict(POLICY_IDS), dict(PREFETCH_IDS), _VERSION[0],
        dict(_CLASSIFIERS), dict(_FREQ_TABLES), dict(_STABILITY),
    )
    try:
        yield
    finally:
        _POLICIES.clear(); _POLICIES.update(saved[0])
        _PREFETCHERS.clear(); _PREFETCHERS.update(saved[1])
        _PREDICTORS.clear(); _PREDICTORS.update(saved[2])
        POLICY_IDS.clear(); POLICY_IDS.update(saved[3])
        PREFETCH_IDS.clear(); PREFETCH_IDS.update(saved[4])
        _CLASSIFIERS.clear(); _CLASSIFIERS.update(saved[6])
        _FREQ_TABLES.clear(); _FREQ_TABLES.update(saved[7])
        _STABILITY.clear(); _STABILITY.update(saved[8])
        if _VERSION[0] != saved[5]:
            _VERSION[0] += 1  # restored tables are a NEW state for the jits
