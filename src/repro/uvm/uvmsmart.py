"""UVMSmart baseline (Ganguly et al., DATE'21) — the paper's SOTA comparison.

An adaptive runtime with (1) a DFA detection engine over interconnect
traffic, (2) a dynamic policy engine choosing among existing policies, and
(3) delayed migration / pinning. Reimplemented against our simulator:

  per epoch (kernel segment):
    streaming      -> demand migration + LRU (prefetch garbage hurts streams)
    random(+reuse) -> pin the coldest blocks of the epoch (zero-copy) when
                      oversubscribed, migrate the hot ones
    regular/mixed  -> tree prefetcher + LRU (the default driver behaviour)

Pinning persists across epochs (the paper notes excessive pinning is risky —
that emerges here as zero-copy latency in the IPC proxy).
"""
from __future__ import annotations

import numpy as np

from repro.core.pattern import LINEAR, MIXED, MIXED_REUSE, RANDOM, RANDOM_REUSE, PatternClassifier
from repro.uvm import simulator as S
from repro.uvm.trace import Trace


def run_uvmsmart(trace: Trace, *, oversubscription: float = 1.25, epoch: int = 2048, seed: int = 0):
    nb = S.bucket_blocks(trace.n_blocks)
    cap = S.capacity_for(trace.n_blocks, oversubscription)
    state = S.init_state(nb, seed)
    classifier = PatternClassifier()
    blocks = trace.block.astype(np.int32)
    nxt = S.next_use_for(trace)  # cached per trace across cells

    import jax.numpy as jnp

    n = len(trace)
    for lo in range(0, n, epoch):
        hi = min(lo + epoch, n)
        pat = classifier.classify(blocks[lo:hi], trace.kernel[lo:hi])
        if pat in (RANDOM, RANDOM_REUSE):
            # delayed migration: pin this epoch's coldest blocks (zero-copy)
            seg = blocks[lo:hi]
            uniq, counts = np.unique(seg, return_counts=True)
            cold = uniq[counts <= max(np.percentile(counts, 30), 1)]
            pinned = np.asarray(state.pinned)
            pinned = pinned.copy()
            pinned[cold] = True
            state = state._replace(pinned=jnp.asarray(pinned))
            policy, prefetch = "lru", "demand"
        elif pat == LINEAR:
            policy, prefetch = "lru", "demand"
        else:  # regular / mixed / reuse
            policy, prefetch = "lru", "tree"
        state, _ = S._run_segment(
            state, blocks[lo:hi], nxt[lo:hi],
            n_blocks=nb, capacity=cap, policy=policy, prefetch=prefetch, n_valid=trace.n_blocks,
            want_outs=False,  # the epoch loop only carries the state
        )

    stats = {
        "pages_thrashed": int(state.thrash_events) * 16,
        "faults": int(state.faults),
        "migrated_blocks": int(state.migrations),
        "zero_copy": int(state.zero_copy),
        "occupancy": int(state.occupancy),
    }
    return stats
