"""Dense decoder-only LM family (qwen2 / qwen3 / granite / qwen1.5).

Layers are stacked along a leading "layers" axis and executed with
``lax.scan`` + full rematerialisation so the lowered HLO stays small for the
512-device dry-run and activation memory is bounded by one layer's live set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.params import Spec, prefix, subtree


def block_specs(cfg, n_layers) -> dict[str, Spec]:
    st = (n_layers,)
    sp = {}
    sp.update(prefix(L.attn_specs(cfg, stack=st), "attn"))
    sp.update(prefix(L.norm_specs(cfg, stack=st), "norm1"))
    sp.update(prefix(L.norm_specs(cfg, stack=st), "norm2"))
    sp.update(prefix(L.mlp_specs(cfg, stack=st), "mlp"))
    return sp


def param_specs(cfg, max_seq: int = 0) -> dict[str, Spec]:
    sp = {}
    sp.update(prefix(L.embed_specs(cfg), "embed"))
    sp.update(prefix(block_specs(cfg, cfg.num_layers), "blocks"))
    sp.update(prefix(L.norm_specs(cfg), "final_norm"))
    return sp


def block(lp, x, cfg, *, positions, causal=True):
    h, kv = L.self_attention(subtree(lp, "attn"), L.apply_norm(lp, "norm1", x, cfg), cfg, positions=positions, causal=causal)
    x = x + h
    h = L.mlp(subtree(lp, "mlp"), L.apply_norm(lp, "norm2", x, cfg), cfg)
    x = x + h
    return constrain(x, "batch", "act_seq", None), kv


def decode_block(lp, x, cfg, *, cache_k, cache_v, pos):
    h, kv = L.decode_self_attention(subtree(lp, "attn"), L.apply_norm(lp, "norm1", x, cfg), cfg, cache_k=cache_k, cache_v=cache_v, pos=pos)
    x = x + h
    h = L.mlp(subtree(lp, "mlp"), L.apply_norm(lp, "norm2", x, cfg), cfg)
    return x + h, kv


def backbone(params, x, cfg, *, positions, causal=True, collect_kv=False):
    """Run the stacked blocks. x: (B, S, D) embeddings."""
    blocks = subtree(params, "blocks")

    def body(carry, lp):
        y, kv = block(lp, carry, cfg, positions=positions, causal=causal)
        return y, kv if collect_kv else None

    x, kvs = jax.lax.scan(jax.checkpoint(body), x, blocks)
    x = L.apply_norm(params, "final_norm", x, cfg)
    return x, kvs


def hidden(params, batch, cfg):
    tokens = batch["tokens"]
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    x = constrain(x, "batch", "act_seq", None)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _ = backbone(params, x, cfg, positions=positions)
    return x, {}


def forward(params, batch, cfg):
    x, aux = hidden(params, batch, cfg)
    return L.unembed(subtree(params, "embed"), x, cfg), aux


def build_cache(kvs, cfg):
    """Stacked (L, B, S, K, HD) K/V -> cache dict (bf16 or int8+scales)."""
    if cfg.kv_quant == "int8":
        kq, ks = L.kv_quantize(kvs[0])
        vq, vs = L.kv_quantize(kvs[1])
        return {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}
    return {"k": kvs[0].astype(jnp.bfloat16), "v": kvs[1].astype(jnp.bfloat16)}


def prefill(params, batch, cfg):
    tokens = batch["tokens"]
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, kvs = backbone(params, x, cfg, positions=positions, collect_kv=True)
    logits = L.unembed(subtree(params, "embed"), x[:, -1:], cfg)
    return logits, build_cache(kvs, cfg)


def decode_step(params, batch, cache, cfg):
    """One token. batch: {token: (B,), pos: scalar int32}."""
    token, pos = batch["token"], batch["pos"]
    x = L.embed(subtree(params, "embed"), token[:, None], cfg)
    blocks = subtree(params, "blocks")

    if cfg.kv_quant == "int8":

        def body_q8(carry, xs):
            lp, ck, cks, cv, cvs = xs
            h, st = L.decode_self_attention_q8(
                subtree(lp, "attn"), L.apply_norm(lp, "norm1", carry, cfg), cfg,
                cache_k=ck, k_scale=cks, cache_v=cv, v_scale=cvs, pos=pos,
            )
            y = carry + h
            h = L.mlp(subtree(lp, "mlp"), L.apply_norm(lp, "norm2", y, cfg), cfg)
            return y + h, st

        x, (nk, nks, nv, nvs) = jax.lax.scan(
            body_q8, x, (blocks, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"])
        )
        new_cache = {"k": nk, "k_scale": nks, "v": nv, "v_scale": nvs}
    else:

        def body(carry, xs):
            lp, ck, cv = xs
            y, (ck, cv) = decode_block(lp, carry, cfg, cache_k=ck, cache_v=cv, pos=pos)
            return y, (ck, cv)

        x, (nk, nv) = jax.lax.scan(body, x, (blocks, cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}
    x = L.apply_norm(params, "final_norm", x, cfg)
    logits = L.unembed(subtree(params, "embed"), x, cfg)
    return logits, new_cache


def cache_specs(cfg, batch: int, seq_len: int) -> dict[str, Spec]:
    shp = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
    axes = ("layers", "batch", "kv_seq", "kv_heads", None)
    sp = {"k": Spec(shp, axes, "zeros"), "v": Spec(shp, axes, "zeros")}
    if cfg.kv_quant == "int8":
        sshp = shp[:-1] + (1,)
        sp["k_scale"] = Spec(sshp, axes, "zeros")
        sp["v_scale"] = Spec(sshp, axes, "zeros")
    return sp
