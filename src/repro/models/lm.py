"""Unified model interface over the six assigned families.

Every family module exposes:
    param_specs(cfg, max_seq) -> {path: Spec}
    forward(params, batch, cfg) -> (logits, aux)         # teacher-forced
    prefill(params, batch, cfg) -> (logits_last, cache)
    decode_step(params, batch, cache, cfg) -> (logits, cache)
    cache_specs(cfg, batch, seq_len) -> {path: Spec}

This module adds: init, abstract param trees, train/prefill/decode step
builders, per-(arch x shape) ``input_specs`` (ShapeDtypeStruct stand-ins, the
dry-run contract), and analytic parameter/FLOP accounting for the roofline.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import dense, encdec, hybrid, mamba2, moe, vlm
from repro.models import layers as L
from repro.models import params as prm
from repro.optim import adamw as optim

FAMILIES = {
    "dense": dense,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


def module(cfg: ModelConfig):
    return FAMILIES[cfg.family]


def param_specs(cfg: ModelConfig, max_seq: int = 0) -> dict[str, prm.Spec]:
    return module(cfg).param_specs(cfg, max_seq=max_seq)


def init(rng, cfg: ModelConfig, max_seq: int = 0, dtype=jnp.float32) -> prm.Params:
    return prm.init_params(rng, param_specs(cfg, max_seq), dtype)


def param_count(cfg: ModelConfig, max_seq: int = 0) -> int:
    return prm.param_count(param_specs(cfg, max_seq))


def active_param_count(cfg: ModelConfig, max_seq: int = 0) -> int:
    """Params touched per token (MoE: only top_k of num_experts routed)."""
    specs = param_specs(cfg, max_seq)
    total = 0
    for path, s in specs.items():
        n = int(np.prod(s.shape))
        if cfg.family == "moe" and "/moe/w" in path:
            n = int(n * cfg.top_k / max(s.shape[1], 1))  # (L, E, ...)
        total += n
    return total


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def forward(params, batch, cfg: ModelConfig):
    """Teacher-forced forward with the mixed-precision cast applied (the
    public entry point; family modules expect compute-dtype params)."""
    return module(cfg).forward(prm.cast_tree(params, compute_dtype(cfg)), batch, cfg)


def loss_fn(params, batch, cfg: ModelConfig, loss_chunk: int = 1024):
    inputs = {**batch, "tokens": batch["tokens"][:, :-1]}
    labels = batch["tokens"][:, 1:]
    cparams = prm.cast_tree(params, compute_dtype(cfg))
    x, aux = module(cfg).hidden(cparams, inputs, cfg)
    loss = L.chunked_ce_loss(prm.subtree(cparams, "embed"), x, labels, cfg, loss_chunk)
    total = loss + aux.get("aux_loss", jnp.zeros((), jnp.float32))
    return total, {"ce_loss": loss, **aux}


def make_train_step(cfg: ModelConfig, opt: optim.Optimizer):
    def train_step(params, opt_state, batch, step):
        (total, metrics), grads = jax.value_and_grad(partial(loss_fn, cfg=cfg), has_aux=True)(params, batch)
        updates, opt_state, gnorm = opt.update(grads, opt_state, params, step)
        params = optim.apply_updates(params, updates)
        metrics = {**metrics, "total_loss": total, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_grad_step(cfg: ModelConfig):
    """Gradient-only step (used by the compression/accumulation paths)."""

    def grad_step(params, batch):
        (total, metrics), grads = jax.value_and_grad(partial(loss_fn, cfg=cfg), has_aux=True)(params, batch)
        return grads, {**metrics, "total_loss": total}

    return grad_step


def make_prefill(cfg: ModelConfig):
    def prefill(params, batch):
        return module(cfg).prefill(prm.cast_tree(params, compute_dtype(cfg)), batch, cfg)

    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch, cache):
        return module(cfg).decode_step(prm.cast_tree(params, compute_dtype(cfg)), batch, cache, cfg)

    return decode_step


# ---------------------------------------------------------------------------
# Input specs — ShapeDtypeStruct stand-ins for every (arch x shape) cell.
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(d) for d in shape), dtype)


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM: patches live inside the assigned seq_len; text gets the rest."""
    if cfg.family == "vlm":
        return seq_len - cfg.num_patches
    return seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {"tokens": _sds((B, text_len(cfg, S) + 1), jnp.int32)}
    elif shape.kind == "prefill":
        specs = {"tokens": _sds((B, text_len(cfg, S)), jnp.int32)}
    else:  # decode
        specs = {"token": _sds((B,), jnp.int32), "pos": _sds((), jnp.int32)}
    if cfg.family == "encdec" and shape.kind in ("train", "prefill"):
        specs["frames"] = _sds((B, cfg.enc_len, cfg.enc_feat), jnp.bfloat16)
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        specs["patches"] = _sds((B, cfg.num_patches, cfg.patch_feat), jnp.bfloat16)
    return specs


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, tuple]:
    """Logical axes for each batch input (for in_shardings)."""
    out = {}
    for name, s in batch_specs(cfg, shape).items():
        if name == "pos":
            out[name] = ()
        else:
            out[name] = ("batch",) + (None,) * (len(s.shape) - 1)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, prm.Spec]:
    return module(cfg).cache_specs(cfg, shape.global_batch, shape.seq_len)


def cache_dtype(path: str, cfg: ModelConfig | None = None) -> Any:
    if path in ("ssm",):
        return jnp.float32
    if cfg is not None and cfg.kv_quant == "int8" and path in ("k", "v"):
        return jnp.int8
    return jnp.bfloat16


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    return {p: _sds(s.shape, cache_dtype(p, cfg)) for p, s in cache_specs(cfg, shape).items()}


def make_batch(rng, cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.Array]:
    """Materialised random batch (smoke tests / examples) matching batch_specs."""
    specs = batch_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        rng, k = jax.random.split(rng)
        if s.dtype == jnp.int32 and name != "pos":
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, jnp.int32)
        elif name == "pos":
            out[name] = jnp.asarray(shape.seq_len - 1, jnp.int32)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return out
