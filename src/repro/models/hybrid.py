"""Zamba2-style hybrid: Mamba-2 backbone + ONE shared attention block whose
weights are reused at every application (every ``attn_every`` SSM layers).
Each application keeps its own KV cache (weights shared, cache not).

Layer layout for num_layers=81, attn_every=6:
  13 groups of (6 mamba layers -> shared attn block) + 3 tail mamba layers.
Simplification vs. the released checkpoint: the shared block consumes the
residual stream directly (no concat with the original embedding, no LoRA
per-application adapters) — recorded in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import dense, layers as L, mamba2
from repro.models.params import Spec, prefix, subtree


def group_layout(cfg) -> tuple[int, int, int]:
    """(n_groups, group_size, n_tail)."""
    g = cfg.attn_every
    n_groups = cfg.num_layers // g
    return n_groups, g, cfg.num_layers - n_groups * g


def param_specs(cfg, max_seq: int = 0) -> dict[str, Spec]:
    n_groups, g, tail = group_layout(cfg)
    sp = {}
    sp.update(prefix(L.embed_specs(cfg), "embed"))
    sp.update(prefix(mamba2.block_specs(cfg, n_groups * g), "mamba"))
    if tail:
        sp.update(prefix(mamba2.block_specs(cfg, tail), "mamba_tail"))
    # one shared transformer block (unstacked)
    sp.update(prefix(L.attn_specs(cfg), "shared/attn"))
    sp.update(prefix(L.norm_specs(cfg), "shared/norm1"))
    sp.update(prefix(L.norm_specs(cfg), "shared/norm2"))
    sp.update(prefix(L.mlp_specs(cfg), "shared/mlp"))
    sp.update(prefix(L.norm_specs(cfg), "final_norm"))
    return sp


def _reshape_group(tree, n_groups, g):
    return jax.tree.map(lambda a: a.reshape((n_groups, g) + a.shape[1:]), tree)


def backbone_forward(params, batch, cfg, *, collect=False):
    tokens = batch["tokens"]
    n_groups, g, tail = group_layout(cfg)
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    x = constrain(x, "batch", "act_seq", None)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    shared = subtree(params, "shared")
    mamba_groups = _reshape_group(subtree(params, "mamba"), n_groups, g)

    def mamba_body(carry, lp):
        y, st = mamba2.block(lp, carry, cfg, collect_state=collect)
        return y, st

    def group_body(carry, glp):
        y, states = jax.lax.scan(jax.checkpoint(mamba_body), carry, glp)
        h, kv = L.self_attention(
            subtree(shared, "attn"), L.apply_norm(shared, "norm1", y, cfg), cfg, positions=positions
        )
        y = y + h
        h = L.mlp(subtree(shared, "mlp"), L.apply_norm(shared, "norm2", y, cfg), cfg)
        y = constrain(y + h, "batch", "act_seq", None)
        return y, (states, kv if collect else None)

    # checkpoint the WHOLE group: otherwise the outer scan stacks the shared
    # attention/MLP residuals of all 13 applications (§Perf cell A-2); only
    # the (B,S,D) group boundaries are saved.
    x, (mstates, kvs) = jax.lax.scan(jax.checkpoint(group_body), x, mamba_groups)
    tail_states = None
    if tail:
        x, tail_states = jax.lax.scan(
            jax.checkpoint(mamba_body), x, subtree(params, "mamba_tail")
        )
    x = L.apply_norm(params, "final_norm", x, cfg)
    return x, (mstates, tail_states, kvs)


def hidden(params, batch, cfg):
    x, _ = backbone_forward(params, batch, cfg)
    return x, {}


def forward(params, batch, cfg):
    x, aux = hidden(params, batch, cfg)
    return L.unembed(subtree(params, "embed"), x, cfg), aux


def prefill(params, batch, cfg):
    x, (mstates, tail_states, kvs) = backbone_forward(params, batch, cfg, collect=True)
    logits = L.unembed(subtree(params, "embed"), x[:, -1:], cfg)
    n_groups, g, tail = group_layout(cfg)

    def full(i):  # join (n_groups, g, B, ...) main + (tail, B, ...) tail
        main = mstates[i].reshape((n_groups * g,) + mstates[i].shape[2:])
        return jnp.concatenate([main, tail_states[i]], 0) if tail else main

    cache = {
        "conv_x": full(0),
        "conv_b": full(1),
        "conv_c": full(2),
        "ssm": full(3).astype(jnp.float32),
        "k": kvs[0].astype(jnp.bfloat16),  # (n_apps, B, S, K, HD)
        "v": kvs[1].astype(jnp.bfloat16),
    }
    return logits, cache


STATE_KEYS = ("conv_x", "conv_b", "conv_c", "ssm")


def decode_step(params, batch, cache, cfg):
    token, pos = batch["token"], batch["pos"]
    n_groups, g, tail = group_layout(cfg)
    x = L.embed(subtree(params, "embed"), token[:, None], cfg)
    shared = subtree(params, "shared")
    mamba_all = subtree(params, "mamba")
    mamba_groups = _reshape_group(mamba_all, n_groups, g)
    main = tuple(cache[k][: n_groups * g].reshape((n_groups, g) + cache[k].shape[1:]) for k in STATE_KEYS)

    def mamba_body(carry, xs):
        lp, cx, cb, cc, sst = xs
        h, st = mamba2.mixer_decode(
            subtree(lp, "mixer"), L.apply_norm(lp, "norm", carry, cfg), cfg,
            conv_x=cx, conv_b=cb, conv_c=cc, ssm_state=sst,
        )
        return carry + h, st

    def group_body(carry, xs):
        glp, gx, gb, gc, gs, ck, cv = xs
        y, nstates = jax.lax.scan(mamba_body, carry, (glp, gx, gb, gc, gs))
        h, (ck, cv) = L.decode_self_attention(
            subtree(shared, "attn"), L.apply_norm(shared, "norm1", y, cfg), cfg, cache_k=ck, cache_v=cv, pos=pos
        )
        y = y + h
        h = L.mlp(subtree(shared, "mlp"), L.apply_norm(shared, "norm2", y, cfg), cfg)
        return y + h, nstates + (ck, cv)

    x, outs = jax.lax.scan(group_body, x, (mamba_groups,) + main + (cache["k"], cache["v"]))
    new_states = [t.reshape((n_groups * g,) + t.shape[2:]) for t in outs[:4]]
    nk, nv = outs[4], outs[5]
    if tail:
        tail_in = tuple(cache[k][n_groups * g :] for k in STATE_KEYS)
        x, tstates = jax.lax.scan(mamba_body, x, (subtree(params, "mamba_tail"),) + tail_in)
        new_states = [jnp.concatenate([m, t], 0) for m, t in zip(new_states, tstates)]
    x = L.apply_norm(params, "final_norm", x, cfg)
    logits = L.unembed(subtree(params, "embed"), x, cfg)
    out_cache = dict(zip(STATE_KEYS, new_states))
    out_cache.update({"k": nk, "v": nv})
    return logits, out_cache


def cache_specs(cfg, batch: int, seq_len: int) -> dict[str, Spec]:
    n_groups, _, _ = group_layout(cfg)
    sp = mamba2.cache_specs(cfg, batch, seq_len)
    sp["k"] = Spec((n_groups, batch, seq_len, cfg.num_kv_heads, cfg.head_dim), ("apps", "batch", "kv_seq", "kv_heads", None), "zeros")
    sp["v"] = Spec((n_groups, batch, seq_len, cfg.num_kv_heads, cfg.head_dim), ("apps", "batch", "kv_seq", "kv_heads", None), "zeros")
    return sp
