"""Mamba-2 (SSD, state-space duality) family — attention-free LM.

The sequence mixer follows the chunked SSD algorithm of arXiv:2405.21060:
within-chunk quadratic term + across-chunk state recurrence (lax.scan over
chunks). The within-chunk compute is the kernel hot-spot
(repro.kernels.ssd_scan provides the Pallas TPU kernel; this module uses the
ops dispatcher, which defaults to the pure-XLA path).

Decode is the O(1) recurrent form carrying (conv tail, SSM state) per layer —
this is why mamba2-370m (and the zamba2 hybrid) are the two archs that run the
long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.params import Spec, prefix, subtree


def mixer_specs(cfg, stack=()) -> dict[str, Spec]:
    st = tuple("layers" for _ in stack)
    D, H, P, N, W = cfg.d_model, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.conv_width
    return {
        "wz": Spec(stack + (D, H, P), st + ("embed", "ssm_heads", None)),
        "wx": Spec(stack + (D, H, P), st + ("embed", "ssm_heads", None)),
        "wB": Spec(stack + (D, N), st + ("embed", None)),
        "wC": Spec(stack + (D, N), st + ("embed", None)),
        "wdt": Spec(stack + (D, H), st + ("embed", "ssm_heads")),
        "dt_bias": Spec(stack + (H,), st + ("ssm_heads",), "zeros"),
        "A_log": Spec(stack + (H,), st + ("ssm_heads",), "zeros"),
        "Dskip": Spec(stack + (H,), st + ("ssm_heads",), "ones"),
        # the depthwise conv runs SEPARATELY on x / B / C: concatenating the
        # head-sharded x with the replicated B/C would force an all-gather of
        # the whole x stream every layer (EXPERIMENTS.md §Perf cell A-4b)
        "conv_wx": Spec(stack + (W, H, P), st + (None, "ssm_heads", None), "lecun"),
        "conv_bx": Spec(stack + (H, P), st + ("ssm_heads", None), "zeros"),
        "conv_wB": Spec(stack + (W, N), st + (None, None), "lecun"),
        "conv_bB": Spec(stack + (N,), st + (None,), "zeros"),
        "conv_wC": Spec(stack + (W, N), st + (None, None), "lecun"),
        "conv_bC": Spec(stack + (N,), st + (None,), "zeros"),
        # HEAD-GROUPED gated RMSNorm (per-head statistics over P): a full
        # d_inner norm would all-gather the head-sharded y/z streams every
        # layer (§Perf cell A-5); grouped norm is the standard TP variant.
        "gate_norm": Spec(stack + (H, P), st + ("ssm_heads", None), "ones"),
        "wo": Spec(stack + (H, P, D), st + ("ssm_heads", None, "embed")),
    }


def block_specs(cfg, n_layers) -> dict[str, Spec]:
    st = (n_layers,)
    sp = prefix(mixer_specs(cfg, stack=st), "mixer")
    sp.update(prefix(L.norm_specs(cfg, stack=st), "norm"))
    return sp


def param_specs(cfg, max_seq: int = 0) -> dict[str, Spec]:
    sp = {}
    sp.update(prefix(L.embed_specs(cfg), "embed"))
    sp.update(prefix(block_specs(cfg, cfg.num_layers), "blocks"))
    sp.update(prefix(L.norm_specs(cfg), "final_norm"))
    return sp


def causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,...C); w: (W,...C); b: (...C,).
    Channel dims may be multi-axis ((H,P) for x, (N,) for B/C) — the shift-sum
    form preserves whatever sharding the channel axes carry."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0)) + ((0, 0),) * (x.ndim - 2))
    S = x.shape[1]
    out = sum(pad[:, i : i + S] * w[i] for i in range(W))
    return out + b


def conv_step(state, xnew, w, b):
    """state: (B, W-1, ...C) previous raw inputs; xnew: (B, ...C)."""
    full = jnp.concatenate([state, xnew[:, None]], axis=1)  # (B, W, ...C)
    y = sum(full[:, i] * w[i] for i in range(w.shape[0]))
    return y + b, full[:, 1:]


def _project(p, xin, cfg):
    z = jnp.einsum("bsd,dhp->bshp", xin, p["wz"])
    xs = jnp.einsum("bsd,dhp->bshp", xin, p["wx"])
    b = xin @ p["wB"]
    c = xin @ p["wC"]
    dt_raw = jnp.einsum("bsd,dh->bsh", xin, p["wdt"]) + p["dt_bias"]
    return z, xs, b, c, dt_raw


def mixer(p, x, cfg, *, collect_state=False):
    """Full-sequence SSD mixer. x: (B,S,D)."""
    Bb, S, D = x.shape
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    z, xs_raw, b_raw, c_raw, dt_raw = _project(p, x, cfg)
    xs = jax.nn.silu(causal_conv(xs_raw, p["conv_wx"], p["conv_bx"]))  # (B,S,H,P)
    b = jax.nn.silu(causal_conv(b_raw, p["conv_wB"], p["conv_bB"]))
    c = jax.nn.silu(causal_conv(c_raw, p["conv_wC"], p["conv_bC"]))
    dt = jax.nn.softplus(dt_raw)  # (B,S,H)

    from repro.kernels.ssd_scan import ops as ssd_ops

    y, final_state = ssd_ops.ssd(xs, dt, p["A_log"], b, c, chunk=cfg.ssm_chunk)
    y = y + cfg_dskip(p) * xs
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)  # per-head stats over P
    out = jnp.einsum("bshp,hpd->bsd", y, p["wo"])
    if collect_state:
        W = cfg.conv_width
        tails = (xs_raw[:, -(W - 1):], b_raw[:, -(W - 1):], c_raw[:, -(W - 1):])
        return out, tails + (final_state,)
    return out, None


def cfg_dskip(p):
    return p["Dskip"][None, None, :, None]


def mixer_decode(p, x, cfg, *, conv_x, conv_b, conv_c, ssm_state, **_):
    """One-step recurrence. x: (B,1,D); conv_x: (B,W-1,H,P); conv_b/c:
    (B,W-1,N); ssm_state: (B,H,P,N)."""
    Bb = x.shape[0]
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    z, xs, b, c, dt_raw = _project(p, x, cfg)
    yx, conv_x = conv_step(conv_x, xs[:, 0], p["conv_wx"], p["conv_bx"])
    yb, conv_b = conv_step(conv_b, b[:, 0], p["conv_wB"], p["conv_bB"])
    yc, conv_c = conv_step(conv_c, c[:, 0], p["conv_wC"], p["conv_bC"])
    xs1 = jax.nn.silu(yx)  # (B,H,P)
    b1 = jax.nn.silu(yb)
    c1 = jax.nn.silu(yc)
    dt = jax.nn.softplus(dt_raw[:, 0])  # (B,H)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    decay = jnp.exp(dt.astype(jnp.float32) * a)  # (B,H)
    update = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(jnp.float32), b1.astype(jnp.float32), xs1.astype(jnp.float32))
    ssm_state = ssm_state * decay[:, :, None, None] + update
    yh = jnp.einsum("bn,bhpn->bhp", c1.astype(jnp.float32), ssm_state).astype(x.dtype)
    yh = yh + p["Dskip"][None, :, None] * xs1
    yh = L.rms_norm(yh[:, None] * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)  # (B,1,H,P)
    out = jnp.einsum("bshp,hpd->bsd", yh, p["wo"])
    return out, (conv_x, conv_b, conv_c, ssm_state)


def block(lp, x, cfg, *, collect_state=False):
    h, st = mixer(subtree(lp, "mixer"), L.apply_norm(lp, "norm", x, cfg), cfg, collect_state=collect_state)
    return constrain(x + h, "batch", "act_seq", None), st


def hidden(params, batch, cfg):
    tokens = batch["tokens"]
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    x = constrain(x, "batch", "act_seq", None)
    blocks = subtree(params, "blocks")

    def body(carry, lp):
        y, _ = block(lp, carry, cfg)
        return y, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, blocks)
    x = L.apply_norm(params, "final_norm", x, cfg)
    return x, {}


def forward(params, batch, cfg):
    x, aux = hidden(params, batch, cfg)
    return L.unembed(subtree(params, "embed"), x, cfg), aux


def prefill(params, batch, cfg):
    tokens = batch["tokens"]
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    blocks = subtree(params, "blocks")

    def body(carry, lp):
        y, st = block(lp, carry, cfg, collect_state=True)
        return y, st

    x, (cx, cb, cc, states) = jax.lax.scan(jax.checkpoint(body), x, blocks)
    x = L.apply_norm(params, "final_norm", x, cfg)
    logits = L.unembed(subtree(params, "embed"), x[:, -1:], cfg)
    return logits, {"conv_x": cx, "conv_b": cb, "conv_c": cc, "ssm": states.astype(jnp.float32)}


def decode_step(params, batch, cache, cfg):
    token = batch["token"]
    x = L.embed(subtree(params, "embed"), token[:, None], cfg)
    blocks = subtree(params, "blocks")

    def body(carry, xs):
        lp, cx, cb, cc, sst = xs
        h, (cx, cb, cc, sst) = mixer_decode(
            subtree(lp, "mixer"), L.apply_norm(lp, "norm", carry, cfg), cfg,
            conv_x=cx, conv_b=cb, conv_c=cc, ssm_state=sst,
        )
        return carry + h, (cx, cb, cc, sst)

    x, (nx, nb, nc_, ns) = jax.lax.scan(body, x, (blocks, cache["conv_x"], cache["conv_b"], cache["conv_c"], cache["ssm"]))
    x = L.apply_norm(params, "final_norm", x, cfg)
    logits = L.unembed(subtree(params, "embed"), x, cfg)
    return logits, {"conv_x": nx, "conv_b": nb, "conv_c": nc_, "ssm": ns}


def cache_specs(cfg, batch: int, seq_len: int) -> dict[str, Spec]:
    # O(1) state — seq_len only documents the context the state summarises.
    H, P, N, W = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.conv_width
    return {
        "conv_x": Spec((cfg.num_layers, batch, W - 1, H, P), ("layers", "batch", None, "ssm_heads", None), "zeros"),
        "conv_b": Spec((cfg.num_layers, batch, W - 1, N), ("layers", "batch", None, None), "zeros"),
        "conv_c": Spec((cfg.num_layers, batch, W - 1, N), ("layers", "batch", None, None), "zeros"),
        "ssm": Spec((cfg.num_layers, batch, H, P, N), ("layers", "batch", "ssm_heads", None, None), "zeros"),
    }
