"""Shared model layers: norms, RoPE, GQA attention (chunked online-softmax),
GLU/GELU MLPs, embeddings. Pure functions over flat param dicts.

Attention dispatches to the Pallas kernels (repro.kernels) when
``REPRO_USE_PALLAS=1``; the default is the pure-XLA chunked implementation,
which is also the lowering target for the multi-pod dry-run (Pallas kernels
are validated separately in interpret mode — see tests/kernels)."""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.params import Spec

# KV-chunk size for the online-softmax attention scan. 1024 keeps the largest
# transient (B,K,G,S,C) score block bounded for 32k prefill.
ATTN_KV_CHUNK = 1024


def use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)) * scale + bias


def apply_norm(params, pre, x, cfg):
    if cfg.norm == "ln":
        return layer_norm(x, params[f"{pre}/scale"], params[f"{pre}/bias"], cfg.norm_eps)
    return rms_norm(x, params[f"{pre}/scale"], cfg.norm_eps)


def norm_specs(cfg, d=None, stack=()) -> dict[str, Spec]:
    d = d or cfg.d_model
    stack_axes = tuple("layers" for _ in stack)
    out = {"scale": Spec(stack + (d,), stack_axes + (None,), "ones")}
    if cfg.norm == "ln":
        out["bias"] = Spec(stack + (d,), stack_axes + (None,), "zeros")
    return out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if ang.ndim == 2:  # (S, D/2) -> broadcast over batch
        ang = ang[None]
    ang = ang[..., None, :]  # (B, S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(length: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


# ---------------------------------------------------------------------------
# Attention core — chunked online-softmax (flash-style, pure XLA)
# ---------------------------------------------------------------------------

def _attend_chunked(q, k, v, *, q_offset, causal, kv_len=None, kv_chunk=ATTN_KV_CHUNK):
    """Online-softmax attention with a scan over KV chunks.

    q: (B, S, K, G, D) grouped query; k, v: (B, T, K, D).
    q_offset: scalar or (B,) — absolute position of q[.., 0] for causal masking.
    kv_len: optional scalar/(B,) — valid KV prefix length (decode with cache).
    Returns (B, S, K, G, D).
    """
    B, S, K, G, D = q.shape
    T = k.shape[1]
    scale = D ** -0.5
    qf = (q * scale).astype(q.dtype)
    nchunk = max(T // kv_chunk, 1)
    kv_chunk = T // nchunk
    kc = k.reshape(B, nchunk, kv_chunk, K, D)
    vc = v.reshape(B, nchunk, kv_chunk, K, D)

    q_pos = (jnp.asarray(q_offset)[..., None] + jnp.arange(S)).astype(jnp.int32)  # (S,) or (B,S)
    if q_pos.ndim == 1:
        q_pos = q_pos[None]  # (1, S)

    def body(carry, xs):
        acc, m, l = carry
        kci, vci, start = xs
        # scores: (B, K, G, S, C)
        s = jnp.einsum("bskgd,bckd->bkgsc", qf, kci, preferred_element_type=jnp.float32)
        k_pos = start + jnp.arange(kv_chunk, dtype=jnp.int32)
        mask = jnp.ones((1, S, kv_chunk), jnp.bool_)
        if causal:
            mask = mask & (q_pos[:, :, None] >= k_pos[None, None, :])
        if kv_len is not None:
            lv = jnp.asarray(kv_len)
            lv = lv[:, None, None] if lv.ndim == 1 else lv[None, None, None]
            mask = mask & (k_pos[None, None, :] < lv)
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        pv = jnp.einsum("bkgsc,bckd->bkgsd", p.astype(q.dtype), vci, preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l), None

    # Sequence-parallel attention: carries are seq-sharded like Q, so the
    # online-softmax scan never reshards score-shaped tensors (the naive
    # sharding all-gathers (B,K,G,S,C) fp32 scores every chunk).
    acc0 = constrain(jnp.zeros((B, K, G, S, D), jnp.float32), "batch", None, None, "act_seq", None)
    m0 = constrain(jnp.full((B, K, G, S), -jnp.inf, jnp.float32), "batch", None, None, "act_seq")
    l0 = constrain(jnp.zeros((B, K, G, S), jnp.float32), "batch", None, None, "act_seq")
    starts = (jnp.arange(nchunk) * kv_chunk).astype(jnp.int32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    # checkpoint: masks/probabilities are rematerialised in the backward pass
    # instead of being stacked across kv chunks as scan residuals (a (nchunk,
    # B, K, G, S, C) fp32/pred tensor otherwise dominates peak memory).
    (acc, m, l), _ = jax.lax.scan(jax.checkpoint(body), (acc0, m0, l0), (kc_t, vc_t, starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (B, S, K, G, D)


def _attend_single_token(q, k, v, *, kv_len):
    """Decode (S==1) attention in ONE pass: no kv-chunk scan, so XLA SPMD
    keeps the contraction sharded over a kv_seq-sharded cache (partial
    softmax stats reduce with a cheap psum) instead of all-gathering the
    cache and looping chunks on every chip (§Perf cell C)."""
    B, S, K, G, D = q.shape
    T = k.shape[1]
    s = jnp.einsum(
        "bskgd,btkd->bkgst", (q * D**-0.5).astype(q.dtype), k,
        preferred_element_type=jnp.float32,
    )  # (B,K,G,1,T)
    if kv_len is not None:
        s = jnp.where(jnp.arange(T) < jnp.asarray(kv_len), s, -1e30)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), v, preferred_element_type=jnp.float32)
    l = jnp.moveaxis(p.sum(-1), 3, 1)[..., None]  # (B,S,K,G,1)
    return (out / jnp.maximum(l, 1e-30)).astype(q.dtype)


def attention_core(q, k, v, *, causal, q_offset=0, kv_len=None):
    """q: (B,S,H,D); k,v: (B,T,K,D). Grouped-query attention."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    if use_pallas() and S > 1:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(qg, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len)
    elif use_pallas() and S == 1:
        from repro.kernels.decode_attention import ops as da_ops

        out = da_ops.decode_attention(qg, k, v, q_offset=q_offset, kv_len=kv_len, causal=causal)
    elif S == 1:
        out = _attend_single_token(qg, k, v, kv_len=kv_len)
    else:
        out = _attend_chunked(qg, k, v, q_offset=q_offset, causal=causal, kv_len=kv_len)
    return out.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def attn_specs(cfg, stack=()) -> dict[str, Spec]:
    st = tuple("layers" for _ in stack)
    D, H, K, HD = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    sp = {
        "wq": Spec(stack + (D, H, HD), st + ("embed", "heads", None)),
        "wk": Spec(stack + (D, K, HD), st + ("embed", "kv_heads", None)),
        "wv": Spec(stack + (D, K, HD), st + ("embed", "kv_heads", None)),
        "wo": Spec(stack + (H, HD, D), st + ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = Spec(stack + (H, HD), st + ("heads", None), "zeros")
        sp["bk"] = Spec(stack + (K, HD), st + ("kv_heads", None), "zeros")
        sp["bv"] = Spec(stack + (K, HD), st + ("kv_heads", None), "zeros")
    if cfg.qk_norm:
        sp["q_norm"] = Spec(stack + (HD,), st + (None,), "ones")
        sp["k_norm"] = Spec(stack + (HD,), st + (None,), "ones")
    return sp


def _project_qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos == "rope" and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def self_attention(p, x, cfg, *, positions, causal=True):
    """Full-sequence self attention (train / prefill). Returns (out, (k, v)).

    Sharding: Q stays sequence-sharded (Megatron-SP style); K/V are gathered
    to full sequence once per layer (small relative to score traffic).
    """
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = constrain(q, "batch", "act_seq", None, None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    out = attention_core(q, k, v, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def decode_self_attention(p, x, cfg, *, cache_k, cache_v, pos):
    """One-token self attention against a cache. x: (B, 1, D); pos: scalar."""
    q, k, v = _project_qkv(p, x, cfg, jnp.asarray(pos)[None])
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    out = attention_core(q, ck, cv, causal=False, kv_len=pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (ck, cv)


def cross_attention_specs(cfg, stack=()) -> dict[str, Spec]:
    return attn_specs(cfg, stack)


def cross_attention(p, x, enc_kv, cfg):
    """x: (B,S,D); enc_kv: (k, v) each (B,T,K,HD) precomputed from encoder."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    k, v = enc_kv
    out = attention_core(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_cross_kv(p, enc_out, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


# ---------------------------------------------------------------------------
# int8 KV-cache quantisation (beyond-paper serving feature)
# ---------------------------------------------------------------------------

def kv_quantize(x):
    """Symmetric per-(token, head) int8 over head_dim. x: (..., D).
    Returns (int8 values, bf16 scales (..., 1))."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / jnp.maximum(s, 1e-8)), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.bfloat16)


def kv_dequantize(q, s):
    return q.astype(jnp.bfloat16) * s


def decode_self_attention_q8(p, x, cfg, *, cache_k, k_scale, cache_v, v_scale, pos):
    """One-token self attention against an int8 cache: the new token's K/V
    quantise into the cache; attention reads the dequantised view (the int8
    stream halves HBM read traffic; the dequant fuses into the dot on TPU)."""
    q, k, v = _project_qkv(p, x, cfg, jnp.asarray(pos)[None])
    kq, ks = kv_quantize(k)
    vq, vs = kv_quantize(v)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, kq, pos, axis=1)
    cks = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, vq, pos, axis=1)
    cvs = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, pos, axis=1)
    out = attention_core(q, kv_dequantize(ck, cks), kv_dequantize(cv, cvs), causal=False, kv_len=pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (ck, cks, cv, cvs)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg, stack=(), d_ff=None) -> dict[str, Spec]:
    st = tuple("layers" for _ in stack)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wg": Spec(stack + (D, F), st + ("embed", "ff")),
            "wu": Spec(stack + (D, F), st + ("embed", "ff")),
            "wd": Spec(stack + (F, D), st + ("ff", "embed")),
        }
    return {
        "w1": Spec(stack + (D, F), st + ("embed", "ff")),
        "b1": Spec(stack + (F,), st + ("ff",), "zeros"),
        "w2": Spec(stack + (F, D), st + ("ff", "embed")),
        "b2": Spec(stack + (D,), st + (None,), "zeros"),
    }


def mlp(p, x, cfg):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
        h = constrain(h, "batch", "act_seq", "ff")
        return h @ p["wd"]
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    h = constrain(h, "batch", "act_seq", "ff")
    return h @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_specs(cfg) -> dict[str, Spec]:
    V, D = cfg.padded_vocab, cfg.d_model
    sp = {"embedding": Spec((V, D), ("vocab", "embed"), "normal", 0.02)}
    if not cfg.tie_embeddings:
        sp["unembed"] = Spec((D, V), ("embed", "vocab"))
    return sp


def embed(params, tokens, cfg):
    # params arrive pre-cast to the compute dtype (lm.forward / step builders)
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x, cfg):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(x.dtype))
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))


def ce_loss(logits, labels, vocab_size, mask=None, reduce="mean"):
    """Cross-entropy with padded-vocab masking. logits: (..., Vp)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        pad = jnp.arange(vp) >= vocab_size
        logits = jnp.where(pad, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
    if reduce == "sum":
        return nll.sum()
    denom = mask.sum() if mask is not None else nll.size
    return nll.sum() / jnp.maximum(denom, 1.0)


def chunked_ce_loss(embed_params, x, labels, cfg, chunk: int = 1024):
    """CE over the full vocab without materialising (B, S, V) logits.

    Scans over sequence chunks; the per-chunk logits are rematerialised in the
    backward pass (jax.checkpoint), so live memory is (B, chunk, V_shard).
    """
    B, S, D = x.shape
    if S % chunk != 0:
        chunk = S  # single shot for irregular smoke shapes
    nc = S // chunk
    xc = jnp.swapaxes(x.reshape(B, nc, chunk, D), 0, 1)
    lc = jnp.swapaxes(labels.reshape(B, nc, chunk), 0, 1)

    def body(tot, xs):
        xcb, lcb = xs
        logits = unembed(embed_params, xcb, cfg)
        return tot + ce_loss(logits, lcb, cfg.vocab_size, reduce="sum"), None

    tot, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), (xc, lc))
    return tot / (B * S)
