"""Parameter specs: one flat dict of path -> Spec per model.

A Spec carries the array shape, the *logical* axis names (used by the sharding
resolver in ``repro.distributed.sharding``), and the initializer. Models build
their full parameter tree from specs, so the dry-run can create
ShapeDtypeStruct stand-ins without allocating anything.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Spec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    init: str = "lecun"  # lecun | normal | zeros | ones
    scale: float = 1.0

    def check(self, path: str = "?") -> "Spec":
        if len(self.shape) != len(self.axes):
            raise ValueError(f"{path}: shape {self.shape} vs axes {self.axes}")
        return self


ParamSpecs = dict[str, Spec]
Params = dict[str, jax.Array]


def _fan_in(spec: Spec) -> int:
    # For stacked layer params the leading "layers"/"experts" axes are not fan-in.
    dims = [d for d, a in zip(spec.shape, spec.axes) if a not in ("layers", "experts", "groups", "apps")]
    if len(dims) >= 2:
        return int(np.prod(dims[:-1]))
    return max(dims[0] if dims else 1, 1)


def init_one(key: jax.Array, spec: Spec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(dtype)
    if spec.init == "lecun":
        std = spec.scale / math.sqrt(_fan_in(spec))
        return (std * jax.random.normal(key, spec.shape)).astype(dtype)
    raise ValueError(spec.init)


def init_params(key: jax.Array, specs: ParamSpecs, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(specs))
    return {
        path: init_one(k, spec.check(path), dtype)
        for k, (path, spec) in zip(keys, sorted(specs.items()))
    }


def abstract_params(specs: ParamSpecs, dtype=jnp.float32) -> dict[str, jax.ShapeDtypeStruct]:
    return {p: jax.ShapeDtypeStruct(s.shape, dtype) for p, s in specs.items()}


def axes_tree(specs: ParamSpecs) -> dict[str, tuple[str | None, ...]]:
    return {p: s.axes for p, s in specs.items()}


def param_count(specs: ParamSpecs) -> int:
    return int(sum(np.prod(s.shape) for s in specs.values()))


def param_bytes(specs: ParamSpecs, bytes_per: int = 4) -> int:
    return param_count(specs) * bytes_per


def cast_tree(tree, dtype=jnp.bfloat16):
    """Mixed precision: cast float params to the compute dtype at use-sites.

    Master copies stay fp32 in the optimizer; gradients flow back in fp32
    through the (differentiable) cast.
    """
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a, tree
    )


def prefix(d: ParamSpecs, pre: str) -> ParamSpecs:
    return {f"{pre}/{k}": v for k, v in d.items()}


def subtree(params: Params, pre: str) -> Params:
    pre = pre + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}
