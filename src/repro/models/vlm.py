"""InternVL2-style VLM: InternLM2 decoder backbone + stub ViT frontend.

Per the assignment, the vision tower is a STUB: ``input_specs`` supplies
precomputed patch embeddings (B, num_patches, patch_feat); a linear
projector (the real model's MLP projector) lifts them to d_model and they are
prepended to the token sequence. The decode path is identical to the dense
family (the KV cache spans patches + text inside the assigned seq_len).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import dense, layers as L
from repro.models.params import Spec, prefix, subtree


def param_specs(cfg, max_seq: int = 0) -> dict[str, Spec]:
    sp = dense.param_specs(cfg, max_seq)
    sp["projector/w"] = Spec((cfg.patch_feat, cfg.d_model), (None, "embed"))
    sp["projector/b"] = Spec((cfg.d_model,), (None,), "zeros")
    return sp


def _embed_multimodal(params, batch, cfg):
    tokens, patches = batch["tokens"], batch["patches"]
    tx = L.embed(subtree(params, "embed"), tokens, cfg)
    px = patches.astype(tx.dtype) @ params["projector/w"].astype(tx.dtype) + params["projector/b"]
    return jnp.concatenate([px, tx], axis=1)


def hidden(params, batch, cfg):
    x = _embed_multimodal(params, batch, cfg)
    x = constrain(x, "batch", "act_seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _ = dense.backbone(params, x, cfg, positions=positions)
    # only the text positions carry labels (patch positions are inputs only)
    return x[:, cfg.num_patches :], {}


def forward(params, batch, cfg):
    x, aux = hidden(params, batch, cfg)
    return L.unembed(subtree(params, "embed"), x, cfg), aux


def prefill(params, batch, cfg):
    x = _embed_multimodal(params, batch, cfg)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, kvs = dense.backbone(params, x, cfg, positions=positions, collect_kv=True)
    logits = L.unembed(subtree(params, "embed"), x[:, -1:], cfg)
    return logits, dense.build_cache(kvs, cfg)


decode_step = dense.decode_step  # cache-only; identical to dense
cache_specs = dense.cache_specs
