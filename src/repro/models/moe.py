"""Mixture-of-experts family (olmoe-1b-7b, qwen2-moe-a2.7b).

Dispatch is capacity-bounded scatter/gather ("dropping" MoE): token->slot
ranks come from a cumsum over the routing one-hot, tokens are scattered into a
per-expert (E, C, D) buffer that is expert-sharded on the model axis, expert
FFNs run as one batched einsum, and outputs are gathered back and combined
with the gates. XLA inserts the data->expert all-to-alls from the sharding
constraints.

Experts whose published count does not divide the mesh (qwen2-moe: 60) are
padded to the next multiple of 16 with router-logit masking (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import pad_to
from repro.distributed.compat import shard_map
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.params import Spec, prefix, subtree


def padded_experts(cfg) -> int:
    return pad_to(cfg.num_experts, 16) if cfg.num_experts > 16 else cfg.num_experts


def capacity(cfg, tokens: int) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(pad_to(c, 8), 8)


def moe_specs(cfg, stack=()) -> dict[str, Spec]:
    st = tuple("layers" for _ in stack)
    D, F, Ep = cfg.d_model, cfg.moe_d_ff, padded_experts(cfg)
    sp = {
        # router is tiny — replicate it so the shard_map EP dispatch can read
        # it without a gather
        "router": Spec(stack + (D, Ep), st + (None, None)),
        "wg": Spec(stack + (Ep, D, F), st + ("experts", "embed", "ff")),
        "wu": Spec(stack + (Ep, D, F), st + ("experts", "embed", "ff")),
        "wd": Spec(stack + (Ep, F, D), st + ("experts", "ff", "embed")),
    }
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        sp["shared_wg"] = Spec(stack + (D, Fs), st + ("embed", "ff"))
        sp["shared_wu"] = Spec(stack + (D, Fs), st + ("embed", "ff"))
        sp["shared_wd"] = Spec(stack + (Fs, D), st + ("ff", "embed"))
        # qwen2-moe gates the shared expert with a sigmoid over a linear probe
        sp["shared_gate"] = Spec(stack + (D, 1), st + ("embed", None), "zeros")
    return sp


def _local_dispatch(xf, logits, cfg, E, Ep, C, dtype):
    """Capacity-bounded scatter dispatch over LOCAL tokens (no comms)."""
    k = cfg.top_k
    T = xf.shape[0]
    if Ep > E:
        logits = jnp.where(jnp.arange(Ep) >= E, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    ohk = jax.nn.one_hot(idx, Ep, dtype=jnp.float32)
    f_e = ohk.sum(1).mean(0)  # per-expert routed fraction (local moments)
    p_e = probs.mean(0)
    aux = (f_e, p_e)

    flat_e = idx.reshape(-1)
    oh = jax.nn.one_hot(flat_e, Ep, dtype=jnp.int32)
    slot = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1
    keep = slot < C
    slot = jnp.where(keep, slot, 0)
    tok_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    xg = jnp.take(xf, tok_idx, axis=0) * keep[:, None].astype(dtype)
    buf = jnp.zeros((Ep, C, xf.shape[-1]), dtype).at[flat_e, slot].add(xg, mode="drop")
    return buf, (flat_e, slot, keep, gates, tok_idx, T), aux


def _local_combine(out_buf, meta, dtype, D):
    flat_e, slot, keep, gates, tok_idx, T = meta
    yk = out_buf[flat_e, slot] * (gates.reshape(-1)[:, None] * keep[:, None]).astype(dtype)
    return jnp.zeros((T, D), dtype).at[tok_idx].add(yk, mode="drop")


def moe_ffn_ep(p, x, cfg, mesh):
    """Expert-parallel dispatch under shard_map (§Perf cell B).

    Tokens stay on their (data, seq) shard; per-chip local top-k + capacity
    scatter builds an (Ep, C_loc, D) buffer; a TILED all-to-all over the
    model axis exchanges expert slices (each chip keeps only its Ep/16
    experts at 16x the local capacity); expert FFNs run as one batched
    einsum; the reverse all-to-all returns outputs for local combine. The
    pjit scatter fallback lowers to DENSE fp32 all-reduces of token-sized
    buffers — this path replaces them with two a2a's of the dispatched
    tokens only.
    """
    from jax.sharding import PartitionSpec as P

    Bb, S, D = x.shape
    E, Ep = cfg.num_experts, padded_experts(cfg)
    msize = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dshards = 1
    for a in batch_axes:
        dshards *= mesh.shape[a]
    t_loc = (Bb // dshards) * (S // msize)
    C_loc = max(int(t_loc * cfg.top_k * cfg.capacity_factor / E), 8)

    def shard_fn(xl, router, wg, wu, wd):
        # xl: (B_loc, S_loc, D) — flatten local tokens
        b_l, s_l, _ = xl.shape
        xf = xl.reshape(b_l * s_l, D)
        logits = (xf @ router).astype(jnp.float32)
        buf, meta, (f_e, p_e) = _local_dispatch(xf, logits, cfg, E, Ep, C_loc, xl.dtype)
        # exchange: (Ep, C, D) -> (Ep/m, C*m, D)
        bufx = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1, tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bufx, wg)) * jnp.einsum("ecd,edf->ecf", bufx, wu)
        outb = jnp.einsum("ecf,efd->ecd", h, wd)
        # reverse exchange: (Ep/m, C*m, D) -> (Ep, C, D)
        outb = jax.lax.all_to_all(outb, "model", split_axis=1, concat_axis=0, tiled=True)
        y = _local_combine(outb, meta, xl.dtype, D)
        # global load-balance moments (matches the scatter path exactly)
        axes = ("model",) + batch_axes
        f_g = jax.lax.pmean(f_e, axes)
        p_g = jax.lax.pmean(p_e, axes)
        aux = E * jnp.sum(f_g * p_g) / cfg.top_k
        return y.reshape(b_l, s_l, D), aux

    xspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], "model", None)
    out = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(xspec, P(None, None), P("model", None, None), P("model", None, None), P("model", None, None)),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return out


def moe_ffn(p, x, cfg):
    """x: (B, S, D) -> (B, S, D), aux load-balance loss."""
    import os

    from repro.distributed import sharding as shd

    mesh = shd.active_mesh()
    Ep = p["wg"].shape[0]
    if (
        mesh is not None
        and "model" in mesh.shape
        and Ep % mesh.shape["model"] == 0
        and os.environ.get("REPRO_MOE_IMPL", "ep") == "ep"
        and x.shape[0] % max(mesh.shape.get("data", 1) * mesh.shape.get("pod", 1), 1) == 0
        and x.shape[1] % mesh.shape["model"] == 0
    ):
        y, aux = moe_ffn_ep(p, x, cfg, mesh)
        if cfg.num_shared_experts:
            y = y + _shared_expert(p, x.reshape(-1, x.shape[-1]), cfg).reshape(x.shape)
        return y, aux
    return _moe_ffn_scatter(p, x, cfg)


def _shared_expert(p, xf, cfg):
    sh = jax.nn.silu(xf @ p["shared_wg"]) * (xf @ p["shared_wu"])
    sh = constrain(sh, "batch", "ff")
    sh = sh @ p["shared_wd"]
    return jax.nn.sigmoid(xf @ p["shared_gate"].astype(xf.dtype)) * sh


def _moe_ffn_scatter(p, x, cfg):
    """Paper-faithful baseline dispatch (pure pjit scatter; §Perf cell B baseline)."""
    Bb, S, D = x.shape
    T = Bb * S
    E, Ep, k = cfg.num_experts, p["wg"].shape[0], cfg.top_k
    C = capacity(cfg, T)
    xf = x.reshape(T, D)
    xf = constrain(xf, "batch", None)

    logits = (xf @ p["router"].astype(jnp.float32)).astype(jnp.float32)  # (T, Ep)
    if Ep > E:
        logits = jnp.where(jnp.arange(Ep) >= E, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    ohk = jax.nn.one_hot(idx, Ep, dtype=jnp.float32)  # (T, k, Ep)
    f_e = ohk.sum(1).mean(0)  # fraction routed per expert
    p_e = probs.mean(0)
    aux = E * jnp.sum(f_e * p_e) / k

    # slot ranks within each expert via cumsum over the flattened choices
    flat_e = idx.reshape(-1)  # (T*k,)
    oh = jax.nn.one_hot(flat_e, Ep, dtype=jnp.int32)  # (T*k, Ep)
    ranks = jnp.cumsum(oh, axis=0) * oh  # 1-based rank where active
    slot = ranks.sum(-1) - 1  # (T*k,)
    keep = slot < C
    slot = jnp.where(keep, slot, 0)

    tok_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    xg = jnp.take(xf, tok_idx, axis=0) * keep[:, None].astype(x.dtype)  # (T*k, D)

    buf = jnp.zeros((Ep, C, D), x.dtype).at[flat_e, slot].add(xg, mode="drop")
    buf = constrain(buf, "experts", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = constrain(h, "experts", None, "ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    out_buf = constrain(out_buf, "experts", None, None)

    yk = out_buf[flat_e, slot] * (gates.reshape(-1)[:, None] * keep[:, None]).astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok_idx].add(yk, mode="drop")
    y = constrain(y, "batch", None)

    if cfg.num_shared_experts:
        sh = jax.nn.silu(xf @ p["shared_wg"]) * (xf @ p["shared_wu"])
        sh = constrain(sh, "batch", "ff")
        sh = sh @ p["shared_wd"]
        y = y + jax.nn.sigmoid(xf @ p["shared_gate"].astype(x.dtype)) * sh

    return y.reshape(Bb, S, D), aux


def block_specs(cfg, n_layers) -> dict[str, Spec]:
    st = (n_layers,)
    sp = {}
    sp.update(prefix(L.attn_specs(cfg, stack=st), "attn"))
    sp.update(prefix(L.norm_specs(cfg, stack=st), "norm1"))
    sp.update(prefix(L.norm_specs(cfg, stack=st), "norm2"))
    sp.update(prefix(moe_specs(cfg, stack=st), "moe"))
    return sp


def param_specs(cfg, max_seq: int = 0) -> dict[str, Spec]:
    sp = {}
    sp.update(prefix(L.embed_specs(cfg), "embed"))
    sp.update(prefix(block_specs(cfg, cfg.num_layers), "blocks"))
    sp.update(prefix(L.norm_specs(cfg), "final_norm"))
    return sp


def block(lp, x, cfg, *, positions, causal=True):
    h, kv = L.self_attention(subtree(lp, "attn"), L.apply_norm(lp, "norm1", x, cfg), cfg, positions=positions, causal=causal)
    x = x + h
    h, aux = moe_ffn(subtree(lp, "moe"), L.apply_norm(lp, "norm2", x, cfg), cfg)
    x = x + h
    return constrain(x, "batch", "act_seq", None), kv, aux


def backbone(params, x, cfg, *, positions, causal=True, collect_kv=False):
    blocks = subtree(params, "blocks")

    def body(carry, lp):
        y, aux_sum = carry
        y, kv, aux = block(lp, y, cfg, positions=positions, causal=causal)
        return (y, aux_sum + aux), kv if collect_kv else None

    (x, aux), kvs = jax.lax.scan(jax.checkpoint(body), (x, jnp.zeros((), jnp.float32)), blocks)
    x = L.apply_norm(params, "final_norm", x, cfg)
    return x, kvs, aux / cfg.num_layers


def hidden(params, batch, cfg):
    tokens = batch["tokens"]
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    x = constrain(x, "batch", "act_seq", None)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _, aux = backbone(params, x, cfg, positions=positions)
    return x, {"aux_loss": cfg.router_aux_weight * aux}


def forward(params, batch, cfg):
    x, aux = hidden(params, batch, cfg)
    return L.unembed(subtree(params, "embed"), x, cfg), aux


def prefill(params, batch, cfg):
    tokens = batch["tokens"]
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, kvs, _ = backbone(params, x, cfg, positions=positions, collect_kv=True)
    logits = L.unembed(subtree(params, "embed"), x[:, -1:], cfg)
    return logits, {"k": kvs[0].astype(jnp.bfloat16), "v": kvs[1].astype(jnp.bfloat16)}


def decode_step(params, batch, cache, cfg):
    token, pos = batch["token"], batch["pos"]
    x = L.embed(subtree(params, "embed"), token[:, None], cfg)
    blocks = subtree(params, "blocks")

    def body(carry, xs):
        lp, ck, cv = xs
        h, kv = L.decode_self_attention(subtree(lp, "attn"), L.apply_norm(lp, "norm1", carry, cfg), cfg, cache_k=ck, cache_v=cv, pos=pos)
        y = carry + h
        h, _ = moe_ffn(subtree(lp, "moe"), L.apply_norm(lp, "norm2", y, cfg), cfg)
        return y + h, kv

    x, (nk, nv) = jax.lax.scan(body, x, (blocks, cache["k"], cache["v"]))
    x = L.apply_norm(params, "final_norm", x, cfg)
    logits = L.unembed(subtree(params, "embed"), x, cfg)
    return logits, {"k": nk, "v": nv}


def cache_specs(cfg, batch: int, seq_len: int) -> dict[str, Spec]:
    shp = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
    axes = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": Spec(shp, axes, "zeros"), "v": Spec(shp, axes, "zeros")}
