"""Whisper-medium style encoder-decoder.

The conv audio frontend is a STUB per the assignment: inputs carry
precomputed frame embeddings (B, enc_len, enc_feat) which a linear projection
lifts to d_model (standing in for the two conv layers). Encoder uses
sinusoidal positions + bidirectional attention; decoder uses learned positions
+ causal self-attention + cross-attention into the encoder states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.params import Spec, prefix, subtree


def param_specs(cfg, max_seq: int = 448) -> dict[str, Spec]:
    sp = {}
    sp.update(prefix(L.embed_specs(cfg), "embed"))
    sp["pos_emb"] = Spec((max(max_seq, 8), cfg.d_model), (None, "embed"), "normal", 0.01)
    sp["frontend/w"] = Spec((cfg.enc_feat, cfg.d_model), (None, "embed"))
    sp["frontend/b"] = Spec((cfg.d_model,), (None,), "zeros")
    # encoder blocks
    est = (cfg.enc_layers,)
    sp.update(prefix(L.attn_specs(cfg, stack=est), "enc/attn"))
    sp.update(prefix(L.norm_specs(cfg, stack=est), "enc/norm1"))
    sp.update(prefix(L.norm_specs(cfg, stack=est), "enc/norm2"))
    sp.update(prefix(L.mlp_specs(cfg, stack=est), "enc/mlp"))
    sp.update(prefix(L.norm_specs(cfg), "enc_final_norm"))
    # decoder blocks
    dst = (cfg.num_layers,)
    sp.update(prefix(L.attn_specs(cfg, stack=dst), "dec/self_attn"))
    sp.update(prefix(L.attn_specs(cfg, stack=dst), "dec/cross_attn"))
    sp.update(prefix(L.norm_specs(cfg, stack=dst), "dec/norm1"))
    sp.update(prefix(L.norm_specs(cfg, stack=dst), "dec/norm2"))
    sp.update(prefix(L.norm_specs(cfg, stack=dst), "dec/norm3"))
    sp.update(prefix(L.mlp_specs(cfg, stack=dst), "dec/mlp"))
    sp.update(prefix(L.norm_specs(cfg), "final_norm"))
    return sp


def encode(params, frames, cfg):
    """frames: (B, enc_len, enc_feat) stub frontend output."""
    w = params["frontend/w"]
    x = frames.astype(w.dtype) @ w + params["frontend/b"]
    x = x + L.sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
    x = constrain(x, "batch", "act_seq", None)

    def body(carry, lp):
        h, _ = L.self_attention(subtree(lp, "attn"), L.apply_norm(lp, "norm1", carry, cfg), cfg, positions=None, causal=False)
        y = carry + h
        h = L.mlp(subtree(lp, "mlp"), L.apply_norm(lp, "norm2", y, cfg), cfg)
        return constrain(y + h, "batch", "act_seq", None), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, subtree(params, "enc"))
    return L.apply_norm(params, "enc_final_norm", x, cfg)


def _dec_embed(params, tokens, cfg, pos0=0):
    x = L.embed(subtree(params, "embed"), tokens, cfg)
    pe = jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos0, tokens.shape[1], axis=0)
    return x + pe.astype(x.dtype)[None]


def decode_blocks(params, x, enc_out, cfg, *, collect_kv=False):
    """Teacher-forced decoder over full seq. Returns (x, (self_k, self_v, cross_k, cross_v))."""
    positions = None  # learned positions added at embedding

    def body(carry, lp):
        h, kv = L.self_attention(subtree(lp, "self_attn"), L.apply_norm(lp, "norm1", carry, cfg), cfg, positions=positions, causal=True)
        y = carry + h
        cp = subtree(lp, "cross_attn")
        enc_kv = L.encode_cross_kv(cp, enc_out, cfg)
        h = L.cross_attention(cp, L.apply_norm(lp, "norm2", y, cfg), enc_kv, cfg)
        y = y + h
        h = L.mlp(subtree(lp, "mlp"), L.apply_norm(lp, "norm3", y, cfg), cfg)
        y = constrain(y + h, "batch", "act_seq", None)
        return y, (kv + enc_kv) if collect_kv else None

    x, kvs = jax.lax.scan(jax.checkpoint(body), x, subtree(params, "dec"))
    return L.apply_norm(params, "final_norm", x, cfg), kvs


def hidden(params, batch, cfg):
    enc_out = encode(params, batch["frames"], cfg)
    x = _dec_embed(params, batch["tokens"], cfg)
    x, _ = decode_blocks(params, x, enc_out, cfg)
    return x, {}


def forward(params, batch, cfg):
    x, aux = hidden(params, batch, cfg)
    return L.unembed(subtree(params, "embed"), x, cfg), aux


def prefill(params, batch, cfg):
    enc_out = encode(params, batch["frames"], cfg)
    x = _dec_embed(params, batch["tokens"], cfg)
    x, kvs = decode_blocks(params, x, enc_out, cfg, collect_kv=True)
    logits = L.unembed(subtree(params, "embed"), x[:, -1:], cfg)
    sk, sv, ck, cv = kvs
    cache = {
        "k": sk.astype(jnp.bfloat16),
        "v": sv.astype(jnp.bfloat16),
        "cross_k": ck.astype(jnp.bfloat16),
        "cross_v": cv.astype(jnp.bfloat16),
    }
    return logits, cache


def decode_step(params, batch, cache, cfg):
    token, pos = batch["token"], batch["pos"]
    x = _dec_embed(params, token[:, None], cfg, pos0=pos)

    def body(carry, xs):
        lp, ck, cv, xk, xv = xs
        h, (ck, cv) = L.decode_self_attention(subtree(lp, "self_attn"), L.apply_norm(lp, "norm1", carry, cfg), cfg, cache_k=ck, cache_v=cv, pos=pos)
        y = carry + h
        h = L.cross_attention(subtree(lp, "cross_attn"), L.apply_norm(lp, "norm2", y, cfg), (xk, xv), cfg)
        y = y + h
        h = L.mlp(subtree(lp, "mlp"), L.apply_norm(lp, "norm3", y, cfg), cfg)
        return y + h, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (subtree(params, "dec"), cache["k"], cache["v"], cache["cross_k"], cache["cross_v"])
    )
    x = L.apply_norm(params, "final_norm", x, cfg)
    logits = L.unembed(subtree(params, "embed"), x, cfg)
    return logits, {"k": nk, "v": nv, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}


def cache_specs(cfg, batch: int, seq_len: int) -> dict[str, Spec]:
    axes = ("layers", "batch", "kv_seq", "kv_heads", None)
    self_shp = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
    cross_shp = (cfg.num_layers, batch, cfg.enc_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": Spec(self_shp, axes, "zeros"),
        "v": Spec(self_shp, axes, "zeros"),
        "cross_k": Spec(cross_shp, axes, "zeros"),
        "cross_v": Spec(cross_shp, axes, "zeros"),
    }
