"""Logical-axis sharding resolver.

Every parameter/activation declares *logical* axes ("embed", "ff", "heads",
"batch", ...). A rule table maps logical axes to preferred mesh axes; the
resolver checks divisibility and axis reuse, and silently falls back to
replication when a published dimension does not divide the mesh (e.g.
qwen2-0.5b's 14 Q heads on a 16-way model axis). This keeps all 10 assigned
architectures lowerable on the same production mesh without per-arch
hand-written PartitionSpecs.

Model code calls :func:`constrain` on activations; outside of an active mesh
context (CPU smoke tests) it is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> ordered tuple of mesh axes to try (greedy, product must divide).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "act_seq": ("model",),  # Megatron-SP style sequence sharding between layers
    "act_embed": (),
    # parameters
    "embed": ("data",),  # FSDP shard over the data axis
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": (),
    "conv": (),
    # KV cache
    "kv_seq": ("model",),  # fallback when kv_heads cannot shard
    # stacking axes — always replicated
    "layers": (),
    "apps": (),
    "groups": (),
}

# Pure-DP variant (no TP): used by hillclimb experiments.
FSDP_ONLY_RULES = {**DEFAULT_RULES, "ff": (), "heads": (), "kv_heads": (), "vocab": (), "experts": (), "act_seq": ()}


class _Ctx(threading.local):
    def __init__(self):
        self.stack: list[tuple[Mesh, dict[str, tuple[str, ...]]]] = []


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    """Activate (mesh, rules) for :func:`constrain` during tracing."""
    _CTX.stack.append((mesh, dict(DEFAULT_RULES if rules is None else rules)))
    try:
        yield
    finally:
        _CTX.stack.pop()


def active_mesh() -> Mesh | None:
    return _CTX.stack[-1][0] if _CTX.stack else None


# Dims are resolved in priority order (not positional order), so that e.g. a
# KV cache (layers, batch, kv_seq, kv_heads, head_dim) gives the model axis to
# kv_heads when divisible and only falls back to kv_seq otherwise.
_PRIORITY = {
    "experts": 0,
    "heads": 1,
    "kv_heads": 1,
    "ssm_heads": 1,
    "ff": 2,
    "vocab": 2,
    "batch": 3,
    "embed": 4,
    "act_seq": 5,
    "kv_seq": 6,
}


def resolve_spec(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict[str, tuple[str, ...]] | None = None,
) -> P:
    """Map logical axes to a PartitionSpec, respecting divisibility + axis reuse."""
    rules = DEFAULT_RULES if rules is None else rules
    used: set[str] = set()
    out: list = [None] * len(list(shape))
    order = sorted(
        (i for i, name in enumerate(axes) if name is not None),
        key=lambda i: (_PRIORITY.get(axes[i], 10), i),
    )
    for i in order:
        dim, name = shape[i], axes[i]
        if name not in rules:
            raise KeyError(f"no sharding rule for logical axis {name!r}")
        assigned: list[str] = []
        prod = 1
        for mesh_axis in rules[name]:
            if mesh_axis not in mesh.shape or mesh_axis in used:
                continue
            size = mesh.shape[mesh_axis]
            if dim % (prod * size) != 0:
                continue
            assigned.append(mesh_axis)
            prod *= size
        for a in assigned:
            used.add(a)
        if not assigned:
            out[i] = None
        elif len(assigned) == 1:
            out[i] = assigned[0]
        else:
            out[i] = tuple(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(mesh: Mesh, axes: Sequence[str | None], shape: Sequence[int], rules=None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(axes, shape, mesh, rules))


def params_shardings(mesh: Mesh, specs: dict, rules=None) -> dict[str, NamedSharding]:
    """Shardings for a flat {path: Spec} tree (repro.models.params.Spec)."""
    return {p: named_sharding(mesh, s.axes, s.shape, rules) for p, s in specs.items()}


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Attach a sharding constraint from logical axes; no-op without a mesh."""
    if not _CTX.stack:
        return x
    mesh, rules = _CTX.stack[-1]
    spec = resolve_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def per_device_bytes(mesh: Mesh, axes: Sequence[str | None], shape: Sequence[int], dtype_bytes: int, rules=None) -> int:
    """Analytic per-device footprint of one array under the resolver."""
    spec = resolve_spec(axes, shape, mesh, rules)
    total = int(np.prod(shape)) * dtype_bytes
    denom = 1
    for entry in spec:
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            denom *= mesh.shape[a]
    return total // max(denom, 1)
