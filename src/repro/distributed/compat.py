"""JAX API compatibility shims.

``jax.shard_map`` only exists as a top-level export on newer JAX; on the
0.4.x line it lives in ``jax.experimental.shard_map`` with ``check_rep``
instead of ``check_vma``. The pinned container ships 0.4.37, so the seed's
``jax.shard_map`` call sites raised AttributeError in every multi-device
test.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool | None = None):
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
