"""JAX API compatibility shims.

``jax.shard_map`` only exists as a top-level export on newer JAX; on the
0.4.x line it lives in ``jax.experimental.shard_map`` with ``check_rep``
instead of ``check_vma``. The pinned container ships 0.4.37, so the seed's
``jax.shard_map`` call sites raised AttributeError in every multi-device
test.
"""
from __future__ import annotations

import os

import jax
import numpy as np


def lanes_mesh(n_lanes: int):
    """1-D device mesh over all local devices for lane-sharded sweeps.

    Returns ``None`` when sharding is pointless or unsafe: a single device,
    or a lane count the device count does not divide (lane buckets are
    powers of two, so any power-of-two device count divides them; odd
    device counts fall back to single-device execution).
    """
    devs = jax.devices()
    if len(devs) <= 1 or n_lanes % len(devs) != 0:
        return None
    from jax.sharding import Mesh

    return Mesh(np.array(devs), ("lanes",))


def lane_shardings(n_lanes: int):
    """(lane-sharded, replicated) NamedShardings for a sweep of ``n_lanes``
    independent lanes, or ``(None, None)`` on a single device.

    REPRO_SIM_SHARD=0 is the documented kill switch for ALL lane-sharded
    sweep dispatches (simulator scans and trainer lanes alike) — checked
    here so every caller honours it."""
    if os.environ.get("REPRO_SIM_SHARD", "1") == "0":
        return None, None
    mesh = lanes_mesh(n_lanes)
    if mesh is None:
        return None, None
    from jax.sharding import NamedSharding, PartitionSpec

    return NamedSharding(mesh, PartitionSpec("lanes")), NamedSharding(mesh, PartitionSpec())


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool | None = None):
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
