"""Gradient compression for cross-pod all-reduce: int8 quantisation with
error feedback (EF-SGD style).

At 1000+-node scale the pod axis crosses slow DCI links; quantising the
gradient all-reduce 4x (fp32 -> int8 + per-block fp32 scale) cuts that
traffic proportionally. Error feedback accumulates the quantisation residual
locally and re-injects it next step, preserving convergence (Karimireddy et
al., 2019).

`compressed_psum` runs inside shard_map: quantise -> psum int32 -> dequantise.
(int8 values are summed in int32 to avoid overflow across <=2^15 shards.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def quantize(x: jax.Array, block: int = 256):
    """Symmetric per-block int8. Returns (q, scale, shape)."""
    flat = x.reshape(-1)
    pad = (-len(flat)) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), x.shape


def dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: int(jnp.prod(jnp.asarray(shape)))].reshape(shape) if flat.size else flat.reshape(shape)


def _deq_size(shape):
    n = 1
    for d in shape:
        n *= d
    return n


def compressed_psum(x: jax.Array, axis_name: str, block: int = 256):
    """Quantised all-reduce over a mesh axis (use inside shard_map)."""
    q, scale, shape = quantize(x, block)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)  # conservative shared scale
    n = jax.lax.psum(1, axis_name)
    # average of per-shard scales; dequantise the summed ints with it
    avg_scale = ssum / n
    flat = (qsum.astype(jnp.float32) * avg_scale).reshape(-1)
    return flat[: _deq_size(shape)].reshape(shape)


class ErrorFeedback:
    """Residual accumulator: g_t' = g_t + e_{t-1}; e_t = g_t' - Q(g_t')."""

    @staticmethod
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, residual, block: int = 256):
        """Returns (quantised-effective grads, new residual)."""

        def one(g, e):
            g = g.astype(jnp.float32) + e
            q, s, shp = quantize(g, block)
            deq = dequantize(q, s, shp)
            return deq, g - deq

        flat = jax.tree.map(one, grads, residual)
        comp = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        return comp, res


def make_compressed_allreduce(mesh, axis_name: str = "pod", block: int = 256):
    """Grad all-reduce over `axis_name` in int8, other axes untouched.

    Usage in the trainer: grads are already reduced over data/model by XLA
    (from the loss), and the POD axis reduction is done explicitly here so it
    can be compressed.
    """

    def allreduce(tree):
        def one(g):
            spec = P(*([None] * g.ndim))

            def f(x):
                return compressed_psum(x, axis_name, block) / jax.lax.psum(1, axis_name)

            return shard_map(f, mesh=mesh, in_specs=spec, out_specs=spec)(g)

        return jax.tree.map(one, tree)

    return allreduce
