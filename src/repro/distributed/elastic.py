"""Elastic scaling + straggler mitigation (control plane).

At 1000+ nodes, device loss is routine. The contract here:

  1. `plan_mesh(n_devices)` — choose the best (pod, data, model) factorisation
     for whatever survives, preferring to keep the model axis (resharding TP
     state is the expensive part) and shrinking data parallelism first.
  2. `ElasticController` — drives the restart loop: on failure, re-plan the
     mesh, restore the latest checkpoint resharded onto it (the checkpointer
     is mesh-agnostic), and adjust the data pipeline's shard count; batches
     are (seed, step)-deterministic so no data is replayed or skipped.
  3. straggler mitigation — deadline-based microbatch drop with gradient
     renormalisation: with k of m microbatches landed by the deadline, scale
     the partial sum by m/k (unbiased under random stragglers) instead of
     stalling the step. `StragglerPolicy.combine` implements the math; the
     launcher applies it per accumulation window.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def plan_mesh(n_devices: int, *, prefer_model: int = 16, max_pod: int = 64) -> tuple[int, int, int]:
    """(pod, data, model) for the surviving device count.

    Keeps model parallelism at the preferred width when divisible (TP reshard
    is costly); splits the rest into pod x data with pods as square as
    reasonable. Falls back to smaller model widths, then pure DP.
    """
    for model in sorted({d for d in _divisors(n_devices) if d <= prefer_model}, reverse=True):
        rest = n_devices // model
        pods = max((p for p in _divisors(rest) if p <= max_pod and rest // p >= p), default=1)
        return pods, rest // pods, model
    return 1, n_devices, 1


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based microbatch skip with unbiased renormalisation."""

    n_microbatches: int
    min_fraction: float = 0.75  # below this, the step must stall (quality floor)

    def combine(self, partial_sums, landed: int):
        """partial_sums: accumulated grads over `landed` microbatches.
        Returns (grads, ok): grads scaled to the full-batch expectation."""
        if landed < int(np.ceil(self.min_fraction * self.n_microbatches)):
            return partial_sums, False
        scale = self.n_microbatches / landed

        import jax

        return jax.tree.map(lambda g: g * scale, partial_sums), True


@dataclasses.dataclass
class ElasticEvent:
    step: int
    n_devices_before: int
    n_devices_after: int
    mesh_before: tuple
    mesh_after: tuple


class ElasticController:
    """Restart-loop bookkeeping (unit-tested logic; the launcher wires it to
    real device enumeration + the checkpointer)."""

    def __init__(self, n_devices: int, prefer_model: int = 16):
        self.prefer_model = prefer_model
        self.mesh_shape = plan_mesh(n_devices, prefer_model=prefer_model)
        self.n_devices = n_devices
        self.events: list[ElasticEvent] = []

    @property
    def data_shards(self) -> int:
        pod, data, _ = self.mesh_shape
        return pod * data

    def on_failure(self, step: int, surviving: int) -> tuple[int, int, int]:
        """Re-plan after device loss; records the event; returns new shape."""
        new_shape = plan_mesh(surviving, prefer_model=self.prefer_model)
        self.events.append(ElasticEvent(step, self.n_devices, surviving, self.mesh_shape, new_shape))
        self.mesh_shape, self.n_devices = new_shape, surviving
        return new_shape

    def global_batch_for(self, per_shard_batch: int) -> int:
        return per_shard_batch * self.data_shards
