"""Small shared helpers."""
from __future__ import annotations


def pow2_bucket(n: int, minimum: int) -> int:
    """Smallest power of two >= max(n, minimum) — the shape-bucketing rule
    used so varying lengths fall into a handful of XLA compile shapes."""
    return 1 << max(int(max(n, 1) - 1).bit_length(), minimum.bit_length() - 1)
