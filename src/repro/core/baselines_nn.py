"""Learning-based baseline predictors for Fig. 10: LSTM, CNN, MLP.

Same embeddings + cosine head as the paper's dual-Transformer predictor —
only the sequence encoder differs — so Fig. 10 isolates the encoder choice,
as the paper does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.predictor_paper import PredictorConfig
from repro.core import predictor as P
from repro.models.params import Spec, init_params, prefix


def _embed_head_specs(cfg: PredictorConfig) -> dict[str, Spec]:
    d = cfg.d_model
    return {
        "embed/page": Spec((cfg.page_vocab, d), (None, None), "normal", 0.02),
        "embed/delta": Spec((cfg.delta_vocab, d), (None, None), "normal", 0.02),
        "embed/pc": Spec((cfg.pc_vocab, d), (None, None), "normal", 0.02),
        "embed/tb": Spec((cfg.tb_vocab, d), (None, None), "normal", 0.02),
        "pos": Spec((cfg.history, d), (None, None), "normal", 0.01),
        "head/proj": Spec((2 * d, d), (None, None)),
        "head/classes": Spec((cfg.delta_vocab, d), (None, None), "normal", 0.02),
    }


def _combined_embed(params, batch):
    x = (
        jnp.take(params["embed/page"], batch["page"], 0)
        + jnp.take(params["embed/delta"], batch["delta"], 0)
        + jnp.take(params["embed/pc"], batch["pc"], 0)
        + jnp.take(params["embed/tb"], batch["tb"], 0)
        + params["pos"][None]
    )
    return x  # (B, T, d)


# --- LSTM -------------------------------------------------------------------

def lstm_specs(cfg) -> dict[str, Spec]:
    d = cfg.d_model
    sp = _embed_head_specs(cfg)
    sp.update(prefix({
        "wx": Spec((d, 4 * d), (None, None)),
        "wh": Spec((d, 4 * d), (None, None)),
        "b": Spec((4 * d,), (None,), "zeros"),
        "proj": Spec((d, 2 * d), (None, None)),
    }, "enc"))
    return sp


def lstm_features(params, batch, cfg):
    x = _combined_embed(params, batch)
    d = cfg.d_model

    def cell(carry, xt):
        h, c = carry
        z = xt @ params["enc/wx"] + h @ params["enc/wh"] + params["enc/b"]
        i, f, g, o = jnp.split(z, 4, -1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    B = x.shape[0]
    (h, _), _ = jax.lax.scan(cell, (jnp.zeros((B, d)), jnp.zeros((B, d))), jnp.moveaxis(x, 1, 0))
    f = (h @ params["enc/proj"]) @ params["head/proj"]
    return f.astype(jnp.float32)


# --- CNN --------------------------------------------------------------------

def cnn_specs(cfg) -> dict[str, Spec]:
    d = cfg.d_model
    sp = _embed_head_specs(cfg)
    sp.update(prefix({
        "w1": Spec((3, d, d), (None, None, None)),
        "b1": Spec((d,), (None,), "zeros"),
        "w2": Spec((3, d, d), (None, None, None)),
        "b2": Spec((d,), (None,), "zeros"),
        "proj": Spec((d, 2 * d), (None, None)),
    }, "enc"))
    return sp


def _conv1d(x, w, b):
    """x: (B,T,d) 'same' causal-ish conv with kernel (k, d_in, d_out)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    T = x.shape[1]
    return sum(pad[:, i : i + T] @ w[i] for i in range(k)) + b


def cnn_features(params, batch, cfg):
    x = _combined_embed(params, batch)
    h = jax.nn.relu(_conv1d(x, params["enc/w1"], params["enc/b1"]))
    h = jax.nn.relu(_conv1d(h, params["enc/w2"], params["enc/b2"]))
    f = (h.mean(1) @ params["enc/proj"]) @ params["head/proj"]
    return f.astype(jnp.float32)


# --- MLP --------------------------------------------------------------------

def mlp_specs(cfg) -> dict[str, Spec]:
    d = cfg.d_model
    sp = _embed_head_specs(cfg)
    sp.update(prefix({
        "w1": Spec((cfg.history * d, 2 * d), (None, None)),
        "b1": Spec((2 * d,), (None,), "zeros"),
        "w2": Spec((2 * d, 2 * d), (None, None)),
        "b2": Spec((2 * d,), (None,), "zeros"),
    }, "enc"))
    return sp


def mlp_features(params, batch, cfg):
    x = _combined_embed(params, batch)
    h = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(h @ params["enc/w1"] + params["enc/b1"])
    h = jax.nn.relu(h @ params["enc/w2"] + params["enc/b2"])
    return (h @ params["head/proj"]).astype(jnp.float32)


# --- unified factory ---------------------------------------------------------

def _builder_from_specs(specs, feat):
    """Wrap a (specs(cfg), features(params, batch, cfg)) pair — the shape all
    cosine-head encoders share — into the registry builder contract."""

    def build(cfg: PredictorConfig):
        def fwd(params, batch):
            f = feat(params, batch, cfg)
            return P.cosine_logits(params, f, cfg), f

        return (lambda rng: init_params(rng, specs(cfg))), fwd

    return build


def make_model(cfg: PredictorConfig, kind: str):
    """Returns (init_fn(rng)->params, forward_fn(params, batch)->(logits, feats)).

    ``kind`` is looked up in the predictor registry — the builtin
    architectures below are default entries, and anything added via
    :func:`repro.uvm.api.register_predictor` becomes a valid ``kind`` for
    ``Trainer`` / ``run_protocol`` / ``ModelSpec``."""
    return _registry.predictor_builder(kind)(cfg)


from repro.uvm import registry as _registry  # noqa: E402  (leaf module, no cycle)

if "transformer" not in _registry.predictor_names():  # idempotent under reload
    _registry.register_predictor(
        "transformer", lambda cfg: ((lambda rng: P.init(rng, cfg)), (lambda p, b: P.forward(p, b, cfg)))
    )
    _registry.register_predictor("lstm", _builder_from_specs(lstm_specs, lstm_features))
    _registry.register_predictor("cnn", _builder_from_specs(cnn_specs, cnn_features))
    _registry.register_predictor("mlp", _builder_from_specs(mlp_specs, mlp_features))
