"""Policy engine (Section IV-D, Fig. 9).

The prediction frequency table is a 1024-set, 16-way set-associative cache
keyed by 64KB basic block, with 6-bit saturating counters, flushed every 3
intervals (interval = 64 faults, as in HPE). Counters record how often a
block appears in the current intervals' predictions — a proxy for its
importance in the near-future access stream.

  * prefetch candidates = predicted blocks, highest counter first
  * eviction candidates = lowest counter within the oldest non-empty chain
    partition (the chain itself lives in the simulator state; the engine
    exports the dense counter array the simulator's `learned` policy reads).
Blocks never predicted have frequency -1 (evicted first).

``update`` is vectorized: a batch of predicted blocks is stably grouped by
set, consecutive same-block runs within each set collapse into one
saturating increment, and the remaining per-set sequences are walked in
"waves" (the k-th distinct-block run of every set updates in one scatter).
Way-conflict evictions, insertion order and counter saturation are exactly
the per-block reference semantics — :class:`LoopPredictionFrequencyTable`
keeps the original loop as the equality oracle
(tests/test_manager.py pins them against each other on hypothesis streams).
"""
from __future__ import annotations

import numpy as np

COUNTER_MAX = 63  # 6-bit
FLUSH_INTERVALS = 3


class PredictionFrequencyTable:
    def __init__(self, n_sets: int = 1024, ways: int = 16):
        self.n_sets, self.ways = n_sets, ways
        self.tags = np.full((n_sets, ways), -1, np.int64)
        self.counters = np.zeros((n_sets, ways), np.int32)
        self.intervals_since_flush = 0
        self.flushes = 0

    def update(self, blocks: np.ndarray):
        """Count one prediction per block occurrence (batched).

        Bit-identical to the per-block loop: within a set, order is the
        arrival order; a run of k same-block occurrences is one saturating
        ``min(c + k, MAX)``; misses claim the first empty way, else evict
        the lowest-counter way (first on ties, like ``argmin``).
        """
        b = np.asarray(blocks, np.int64).ravel()
        if b.size == 0:
            return
        s = b % self.n_sets
        order = np.argsort(s, kind="stable")  # per-set arrival order preserved
        bs, ss = b[order], s[order]
        # collapse consecutive same-(set, block) runs: k touches with no
        # intervening same-set traffic are one saturating +k
        change = np.empty(len(bs), bool)
        change[0] = True
        change[1:] = (bs[1:] != bs[:-1]) | (ss[1:] != ss[:-1])
        starts = np.flatnonzero(change)
        run_len = np.diff(np.append(starts, len(bs)))
        rb, rs = bs[starts], ss[starts]
        # wave index = position of the run within its set's sequence; sets
        # are disjoint rows, so each wave is one conflict-free scatter
        set_start = np.empty(len(rb), bool)
        set_start[0] = True
        set_start[1:] = rs[1:] != rs[:-1]
        grp = np.flatnonzero(set_start)
        within = np.arange(len(rb)) - np.repeat(grp, np.diff(np.append(grp, len(rb))))
        for k in range(int(within.max()) + 1):
            m = within == k
            self._update_wave(rb[m], rs[m], run_len[m])

    def _update_wave(self, b: np.ndarray, s: np.ndarray, k: np.ndarray):
        """One batched update of distinct sets: ``k[i]`` touches of block
        ``b[i]`` in set ``s[i]``."""
        row_tags = self.tags[s]  # (m, ways)
        hit = row_tags == b[:, None]
        is_hit = hit.any(axis=1)
        # first hit way / first empty way / lowest counter (first on ties)
        empty = row_tags == -1
        ins_way = np.where(empty.any(axis=1), empty.argmax(axis=1), self.counters[s].argmin(axis=1))
        way = np.where(is_hit, hit.argmax(axis=1), ins_way)
        self.tags[s, way] = b
        base = np.where(is_hit, self.counters[s, way], 0)
        self.counters[s, way] = np.minimum(base + k, COUNTER_MAX).astype(np.int32)

    def lookup(self, block: int) -> int:
        return int(self.lookup_many(np.array([block]))[0])

    def lookup_many(self, blocks: np.ndarray) -> np.ndarray:
        """Batched :meth:`lookup`: current counter per block, -1 on miss."""
        b = np.asarray(blocks, np.int64).ravel()
        row_tags = self.tags[b % self.n_sets]
        hit = row_tags == b[:, None]
        cnt = np.take_along_axis(self.counters[b % self.n_sets], hit.argmax(axis=1)[:, None], axis=1)[:, 0]
        return np.where(hit.any(axis=1), cnt, -1).astype(np.int64)

    def dense(self, n_blocks: int) -> np.ndarray:
        """Dense per-block counter array for the simulator (-1 = never)."""
        out = np.full(n_blocks, -1, np.int32)
        valid = self.tags >= 0
        tags = self.tags[valid]
        cnts = self.counters[valid]
        in_range = tags < n_blocks
        out[tags[in_range]] = cnts[in_range]
        return out

    def on_intervals(self, n_new_intervals: int):
        self.intervals_since_flush += n_new_intervals
        if self.intervals_since_flush >= FLUSH_INTERVALS:
            self.tags.fill(-1)
            self.counters.fill(0)
            self.intervals_since_flush = 0
            self.flushes += 1

    def storage_bits(self) -> int:
        """18KB per the paper: (6*16 + 48)/8 * 1024 bytes."""
        return self.n_sets * (6 * self.ways + 48)


class LoopPredictionFrequencyTable(PredictionFrequencyTable):
    """The original per-block ``update`` loop, frozen as the semantics
    oracle for the vectorized table (and the `--manager` perf baseline)."""

    def update(self, blocks: np.ndarray):
        for b in np.asarray(blocks, np.int64):
            s = int(b % self.n_sets)
            row_tags = self.tags[s]
            hit = np.nonzero(row_tags == b)[0]
            if len(hit):
                w = hit[0]
            else:
                empty = np.nonzero(row_tags == -1)[0]
                w = empty[0] if len(empty) else int(np.argmin(self.counters[s]))
                self.tags[s, w] = b
                self.counters[s, w] = 0
            self.counters[s, w] = min(self.counters[s, w] + 1, COUNTER_MAX)


class PallasPredictionFrequencyTable(PredictionFrequencyTable):
    """Pallas-kernelized ``update``/``lookup_many`` (the ``REPRO_SIM_KERNELS``
    path, registered as ``setassoc_pallas``).

    State stays host-side numpy exactly like the base class (``dense``,
    ``on_intervals``, pickling/snapshots all inherit), but the hot methods
    stream through :mod:`repro.kernels.freq_table` — the whole (S, W) table
    lives in VMEM for the batch instead of round-tripping numpy scatter
    waves.  Bit-identical to the base table (both are pinned against the
    loop oracle); block ids must fit int32, which the manager's page-range
    clipping already guarantees.  Interpret mode is auto-selected on CPU
    backends (same program as jnp ops — the CI gate); compiled-path speed
    is a TPU/GPU follow-up (BENCH_sim.json marks it pending).
    """

    def update(self, blocks: np.ndarray):
        b = np.asarray(blocks, np.int64).ravel()
        if b.size == 0:
            return
        from repro.kernels.freq_table import ops  # lazy: default path stays jax-free

        tags, counters = ops.freq_update(
            self.tags, self.counters, b, use_kernel=True, interpret=ops.default_interpret()
        )
        self.tags = np.asarray(tags).astype(np.int64)
        self.counters = np.asarray(counters).astype(np.int32)

    def lookup_many(self, blocks: np.ndarray) -> np.ndarray:
        b = np.asarray(blocks, np.int64).ravel()
        if b.size == 0:
            return np.zeros(0, np.int64)
        from repro.kernels.freq_table import ops

        out = ops.freq_lookup(
            self.tags, self.counters, b, use_kernel=True, interpret=ops.default_interpret()
        )
        return np.asarray(out).astype(np.int64)


def predicted_blocks(pred_pages: np.ndarray, pages_per_block: int = 16) -> np.ndarray:
    return np.unique(np.asarray(pred_pages, np.int64) // pages_per_block)


def rank_prefetches(table: PredictionFrequencyTable, blocks: np.ndarray, limit: int | None = None) -> np.ndarray:
    """Prefetch candidates ordered by prediction frequency (highest first)."""
    blocks = np.asarray(blocks, np.int64)
    freq = table.lookup_many(blocks) if len(blocks) else np.zeros(0, np.int64)
    order = np.argsort(-freq, kind="stable")
    out = blocks[order]
    return out if limit is None else out[:limit]
