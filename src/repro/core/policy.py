"""Policy engine (Section IV-D, Fig. 9).

The prediction frequency table is a 1024-set, 16-way set-associative cache
keyed by 64KB basic block, with 6-bit saturating counters, flushed every 3
intervals (interval = 64 faults, as in HPE). Counters record how often a
block appears in the current intervals' predictions — a proxy for its
importance in the near-future access stream.

  * prefetch candidates = predicted blocks, highest counter first
  * eviction candidates = lowest counter within the oldest non-empty chain
    partition (the chain itself lives in the simulator state; the engine
    exports the dense counter array the simulator's `learned` policy reads).
Blocks never predicted have frequency -1 (evicted first).
"""
from __future__ import annotations

import numpy as np

COUNTER_MAX = 63  # 6-bit
FLUSH_INTERVALS = 3


class PredictionFrequencyTable:
    def __init__(self, n_sets: int = 1024, ways: int = 16):
        self.n_sets, self.ways = n_sets, ways
        self.tags = np.full((n_sets, ways), -1, np.int64)
        self.counters = np.zeros((n_sets, ways), np.int32)
        self.intervals_since_flush = 0
        self.flushes = 0

    def update(self, blocks: np.ndarray):
        """Count one prediction per block occurrence."""
        for b in np.asarray(blocks, np.int64):
            s = int(b % self.n_sets)
            row_tags = self.tags[s]
            hit = np.nonzero(row_tags == b)[0]
            if len(hit):
                w = hit[0]
            else:
                empty = np.nonzero(row_tags == -1)[0]
                w = empty[0] if len(empty) else int(np.argmin(self.counters[s]))
                self.tags[s, w] = b
                self.counters[s, w] = 0
            self.counters[s, w] = min(self.counters[s, w] + 1, COUNTER_MAX)

    def lookup(self, block: int) -> int:
        s = int(block % self.n_sets)
        hit = np.nonzero(self.tags[s] == block)[0]
        return int(self.counters[s, hit[0]]) if len(hit) else -1

    def dense(self, n_blocks: int) -> np.ndarray:
        """Dense per-block counter array for the simulator (-1 = never)."""
        out = np.full(n_blocks, -1, np.int32)
        valid = self.tags >= 0
        tags = self.tags[valid]
        cnts = self.counters[valid]
        in_range = tags < n_blocks
        out[tags[in_range]] = cnts[in_range]
        return out

    def on_intervals(self, n_new_intervals: int):
        self.intervals_since_flush += n_new_intervals
        if self.intervals_since_flush >= FLUSH_INTERVALS:
            self.tags.fill(-1)
            self.counters.fill(0)
            self.intervals_since_flush = 0
            self.flushes += 1

    def storage_bits(self) -> int:
        """18KB per the paper: (6*16 + 48)/8 * 1024 bytes."""
        return self.n_sets * (6 * self.ways + 48)


def predicted_blocks(pred_pages: np.ndarray, pages_per_block: int = 16) -> np.ndarray:
    return np.unique(np.asarray(pred_pages, np.int64) // pages_per_block)


def rank_prefetches(table: PredictionFrequencyTable, blocks: np.ndarray, limit: int | None = None) -> np.ndarray:
    """Prefetch candidates ordered by prediction frequency (highest first)."""
    blocks = np.asarray(blocks, np.int64)
    freq = np.array([table.lookup(int(b)) for b in blocks])
    order = np.argsort(-freq, kind="stable")
    out = blocks[order]
    return out if limit is None else out[:limit]
