"""Thrashing-aware incremental page predictor (Section IV-B, Fig. 8).

Two Transformer blocks learn complementary views of the access stream:
  * REGULAR block: page-address + page-delta embeddings (strides, reuse)
  * IRREGULAR block: PC + thread-block-ID embeddings (pointer chase, etc.)
Each block's last-position output is scaled by a learnable gate; the concat
goes through a linear layer into a LUCIR cosine classifier over delta
classes. Reuses the framework's dense transformer blocks (repro.models.dense)
so the predictor trains on the same distributed substrate as the LM zoo.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.predictor_paper import PredictorConfig
from repro.models import dense
from repro.models import layers as L
from repro.models.params import Spec, init_params, prefix, subtree


def _block_cfg(cfg: PredictorConfig) -> ModelConfig:
    return ModelConfig(
        name=f"{cfg.name}-block",
        family="dense",
        num_layers=cfg.num_layers,
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_heads,
        d_ff=cfg.d_ff,
        vocab_size=2,  # unused; blocks only
        head_dim=cfg.d_model // cfg.num_heads,
        rope_theta=10_000.0,
    )


def param_specs(cfg: PredictorConfig) -> dict[str, Spec]:
    d = cfg.d_model
    bc = _block_cfg(cfg)
    sp: dict[str, Spec] = {
        "embed/page": Spec((cfg.page_vocab, d), (None, None), "normal", 0.02),
        "embed/delta": Spec((cfg.delta_vocab, d), (None, None), "normal", 0.02),
        "embed/pc": Spec((cfg.pc_vocab, d), (None, None), "normal", 0.02),
        "embed/tb": Spec((cfg.tb_vocab, d), (None, None), "normal", 0.02),
        "pos": Spec((cfg.history, d), (None, None), "normal", 0.01),
        "gate/reg": Spec((), (), "ones"),
        "gate/irr": Spec((), (), "ones"),
        "head/proj": Spec((2 * d, d), (None, None)),
        "head/classes": Spec((cfg.delta_vocab, d), (None, None), "normal", 0.02),
    }
    sp.update(prefix(dense.block_specs(bc, cfg.num_layers), "reg"))
    sp.update(prefix(dense.block_specs(bc, cfg.num_layers), "irr"))
    sp.update(prefix(L.norm_specs(bc), "reg_final"))
    sp.update(prefix(L.norm_specs(bc), "irr_final"))
    return sp


def init(rng, cfg: PredictorConfig, dtype=jnp.float32):
    return init_params(rng, param_specs(cfg), dtype)


def _run_block(params, pre, x, cfg: PredictorConfig):
    bc = _block_cfg(cfg)
    positions = jnp.arange(cfg.history, dtype=jnp.int32)

    def body(carry, lp):
        y, _ = dense.block(lp, carry, bc, positions=positions)
        return y, None

    x, _ = jax.lax.scan(body, x, subtree(params, pre))
    return L.apply_norm(params, f"{pre}_final", x, bc)


def features(params, batch, cfg: PredictorConfig):
    """batch: {page, delta, pc, tb} each (B, T) int32. Returns (B, d) fp32."""
    pos = params["pos"][None]
    reg_x = jnp.take(params["embed/page"], batch["page"], 0) + jnp.take(params["embed/delta"], batch["delta"], 0) + pos
    irr_x = jnp.take(params["embed/pc"], batch["pc"], 0) + jnp.take(params["embed/tb"], batch["tb"], 0) + pos
    reg_f = _run_block(params, "reg", reg_x, cfg)[:, -1]
    irr_f = _run_block(params, "irr", irr_x, cfg)[:, -1]
    f = jnp.concatenate([params["gate/reg"] * reg_f, params["gate/irr"] * irr_f], -1)
    return (f @ params["head/proj"]).astype(jnp.float32)


def cosine_logits(params, f, cfg: PredictorConfig):
    """LUCIR cosine classifier: scale * cos(feature, class weight)."""
    fn = f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-8)
    w = params["head/classes"].astype(jnp.float32)
    wn = w / (jnp.linalg.norm(w, axis=-1, keepdims=True) + 1e-8)
    return cfg.cosine_scale * (fn @ wn.T)


def forward(params, batch, cfg: PredictorConfig):
    f = features(params, batch, cfg)
    return cosine_logits(params, f, cfg), f


def predict_topk(params, batch, cfg: PredictorConfig, k: int = 1, n_active: int | None = None):
    logits, _ = forward(params, batch, cfg)
    if n_active is not None:
        logits = jnp.where(jnp.arange(logits.shape[-1]) >= n_active, -1e30, logits)
    return jax.lax.top_k(logits, k)


def param_count(cfg: PredictorConfig) -> int:
    import numpy as np

    return int(sum(np.prod(s.shape) for s in param_specs(cfg).values()))
