"""The paper's contribution: a pattern-aware, thrashing-aware, incrementally
trained page predictor + policy engine for oversubscription management.

Pipeline (Fig. 7): features -> pattern classifier -> pattern-based model
table -> dual-Transformer page predictor (CE + LUCIR + thrashing loss) ->
policy engine (prediction frequency table + page-set chain) -> GMMU ops,
driven end-to-end by repro.uvm.runtime.
"""
