"""Feature extraction from the demand stream (Section IV-A step 1/4).

Inputs per access: page address, page delta, PC, thread-block ID. The delta
vocabulary GROWS online (Table III) — new deltas get fresh class ids until
the configured capacity, then hash into the existing space. Windows of
``history`` accesses form one sample; the label is the next access's delta
class.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.uvm.trace import Trace


class DeltaVocab:
    """Online-growing delta -> class-id map with bounded capacity."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.table: dict[int, int] = {}

    @property
    def n_classes(self) -> int:
        return len(self.table)

    def encode_one(self, delta: int) -> int:
        if delta in self.table:
            return self.table[delta]
        if len(self.table) < self.capacity:
            self.table[delta] = len(self.table)
            return self.table[delta]
        return hash(delta) % self.capacity  # overflow: hash into existing ids

    def encode(self, deltas: np.ndarray) -> np.ndarray:
        return np.fromiter((self.encode_one(int(d)) for d in deltas), np.int32, len(deltas))

    def decode_table(self) -> dict[int, int]:
        return {v: k for k, v in self.table.items()}


@dataclasses.dataclass
class FeatureSet:
    page: np.ndarray   # (N, T) hashed page ids
    delta: np.ndarray  # (N, T) delta class ids
    pc: np.ndarray     # (N, T)
    tb: np.ndarray     # (N, T)
    label: np.ndarray  # (N,) next delta class id
    label_page: np.ndarray  # (N,) next raw page id (for the policy engine)
    t_index: np.ndarray  # (N,) trace position of the label access

    def __len__(self):
        return len(self.label)

    def slice(self, lo, hi):
        return FeatureSet(*(getattr(self, f.name)[lo:hi] for f in dataclasses.fields(self)))


def extract(trace: Trace, vocab: DeltaVocab, history: int = 10, *, page_vocab=4096, pc_vocab=512, tb_vocab=512, start: int = 0, stop: int | None = None) -> FeatureSet:
    """Build windowed samples for trace[start:stop] (vocab grows in order)."""
    stop = len(trace) if stop is None else stop
    page = trace.page[:stop].astype(np.int64)
    deltas = np.diff(page, prepend=page[0])
    dcls = vocab.encode(deltas)
    ph = (page % page_vocab).astype(np.int32)
    pch = (trace.pc[:stop] % pc_vocab).astype(np.int32)
    tbh = (trace.tb[:stop] % tb_vocab).astype(np.int32)

    lo = max(start, history)
    n = max(stop - lo, 0)
    if n == 0:
        e = np.zeros((0, history), np.int32)
        z = np.zeros((0,), np.int32)
        return FeatureSet(e, e.copy(), e.copy(), e.copy(), z, z.copy(), z.copy())

    idx = lo + np.arange(n)[:, None] - np.arange(history, 0, -1)[None, :]  # (N, T)
    return FeatureSet(
        page=ph[idx],
        delta=dcls[idx],
        pc=pch[idx],
        tb=tbh[idx],
        label=dcls[lo : lo + n].astype(np.int32),
        label_page=trace.page[lo : lo + n].astype(np.int32),
        t_index=(lo + np.arange(n)).astype(np.int32),
    )


class FeatureStream:
    """Incremental feature encoder for the online runtime: appends trace
    segments (growing the delta vocab in arrival order) and yields window
    samples for any [lo, hi) span without re-encoding the prefix."""

    def __init__(self, trace: Trace, vocab: DeltaVocab, history: int = 10, *, page_vocab=4096, pc_vocab=512, tb_vocab=512):
        self.trace = trace
        self.vocab = vocab
        self.history = history
        self.page_vocab, self.pc_vocab, self.tb_vocab = page_vocab, pc_vocab, tb_vocab
        self.encoded_upto = 0
        n = len(trace)
        self._dcls = np.zeros(n, np.int32)
        self._ph = (trace.page.astype(np.int64) % page_vocab).astype(np.int32)
        self._pch = (trace.pc % pc_vocab).astype(np.int32)
        self._tbh = (trace.tb % tb_vocab).astype(np.int32)

    def ensure(self, upto: int):
        upto = min(upto, len(self.trace))
        if upto <= self.encoded_upto:
            return
        lo = self.encoded_upto
        page = self.trace.page.astype(np.int64)
        prev = page[lo - 1] if lo > 0 else page[0]
        deltas = np.diff(page[: upto], prepend=prev)[lo:]
        self._dcls[lo:upto] = self.vocab.encode(deltas)
        self.encoded_upto = upto

    def windows(self, lo: int, hi: int) -> FeatureSet:
        self.ensure(hi)
        lo = max(lo, self.history)
        n = max(hi - lo, 0)
        if n == 0:
            e = np.zeros((0, self.history), np.int32)
            z = np.zeros((0,), np.int32)
            return FeatureSet(e, e.copy(), e.copy(), e.copy(), z, z.copy(), z.copy())
        idx = lo + np.arange(n)[:, None] - np.arange(self.history, 0, -1)[None, :]
        return FeatureSet(
            page=self._ph[idx],
            delta=self._dcls[idx],
            pc=self._pch[idx],
            tb=self._tbh[idx],
            label=self._dcls[lo:hi].astype(np.int32),
            label_page=self.trace.page[lo:hi].astype(np.int32),
            t_index=(lo + np.arange(n)).astype(np.int32),
        )


def unique_deltas_per_phase(trace: Trace, n_phases: int = 3) -> list[int]:
    """Table III: cumulative unique page deltas at each program phase."""
    page = trace.page.astype(np.int64)
    deltas = np.diff(page, prepend=page[0])
    out = []
    for p in range(1, n_phases + 1):
        out.append(int(len(np.unique(deltas[: len(deltas) * p // n_phases]))))
    return out
