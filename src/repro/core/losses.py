"""The paper's loss (Eqs. 2-3):

    L = (1/|N|) sum_N [ L_CE + lambda * L_dis^G ] + (mu/|S|) sum_S L_thra

  * L_CE     — cross-entropy over delta classes (active classes only; the
               class space grows incrementally).
  * L_dis^G  — LUCIR's geodesic (cosine) feature-distillation term against
               the previous model's features: consolidates old knowledge when
               new classes arrive (anti catastrophic forgetting).
  * L_thra   — Eq. 2: the ADDITIVE INVERSE of CE restricted to the subset S
               of samples whose target page is already evicted (E) or
               thrashed (T). Minimising it pushes probability away from pages
               that would thrash (again).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ce(logits, labels, n_active: int):
    lm = jnp.where(jnp.arange(logits.shape[-1]) >= n_active, -1e30, logits)
    lse = jax.nn.logsumexp(lm, -1)
    ll = jnp.take_along_axis(lm, labels[:, None], 1)[:, 0]
    return lse - ll  # per-sample nll


def lucir_distill(f_new, f_old):
    """1 - cos(f_new, sg(f_old)) per sample (LUCIR's L_dis^G)."""
    f_old = jax.lax.stop_gradient(f_old)
    nn_ = f_new / (jnp.linalg.norm(f_new, axis=-1, keepdims=True) + 1e-8)
    no = f_old / (jnp.linalg.norm(f_old, axis=-1, keepdims=True) + 1e-8)
    return 1.0 - jnp.sum(nn_ * no, -1)


def thrash_term(logits, labels, in_et, n_active: int):
    """Eq. 2 over the S subset: sum y_i log p_i == -CE (mean over S)."""
    nll = ce(logits, labels, n_active)
    s = in_et.astype(jnp.float32)
    return -(nll * s).sum() / jnp.maximum(s.sum(), 1.0)


def total_loss(
    logits,
    f_new,
    labels,
    *,
    n_active: int,
    f_old=None,
    in_et=None,
    lam: float = 0.5,
    mu: float = 0.5,
):
    """Eq. 3. f_old None => no distillation (first group); in_et None => no
    thrashing info (pure prediction experiments, Figs. 4/10)."""
    nll = ce(logits, labels, n_active)
    loss = nll.mean()
    metrics = {"ce": loss}
    if f_old is not None:
        dis = lucir_distill(f_new, f_old).mean()
        loss = loss + lam * dis
        metrics["lucir"] = dis
    if in_et is not None:
        th = thrash_term(logits, labels, in_et, n_active)
        loss = loss + mu * th
        metrics["thrash_term"] = th
    metrics["total"] = loss
    return loss, metrics


def top1_accuracy(logits, labels, n_active: int):
    lm = jnp.where(jnp.arange(logits.shape[-1]) >= n_active, -1e30, logits)
    return (lm.argmax(-1) == labels).mean()
