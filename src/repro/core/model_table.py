"""Pattern-based model table (Section IV-C).

A direct-mapped cache of per-pattern predictor weights: indexed by a hash of
the access-pattern id, returning that pattern's weights (plus the previous
snapshot needed by the LUCIR term, and the optimizer state so fine-tuning
resumes). All architectures are identical, so entries are interchangeable
pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


@dataclasses.dataclass
class Entry:
    params: Any
    prev_params: Any | None = None  # previous model (LUCIR distillation target)
    opt_state: Any | None = None
    step: int = 0
    n_updates: int = 0
    last_acc: float = 0.0  # top-1 on the most recent group (prefetch gate)


class ModelTable:
    def __init__(self, init_fn, n_slots: int = 8):
        self.init_fn = init_fn  # (slot_seed) -> params
        self.n_slots = n_slots
        self.slots: dict[int, Entry] = {}
        self.hits = 0
        self.misses = 0

    def slot_of(self, pattern_id: int) -> int:
        return hash(pattern_id) % self.n_slots

    def get(self, pattern_id: int) -> Entry:
        s = self.slot_of(pattern_id)
        if s not in self.slots:
            self.misses += 1
            self.slots[s] = Entry(params=self.init_fn(s))
        else:
            self.hits += 1
        return self.slots[s]

    def put(self, pattern_id: int, entry: Entry):
        self.slots[self.slot_of(pattern_id)] = entry

    def snapshot_prev(self, pattern_id: int):
        """Store the current weights as the LUCIR distillation target."""
        e = self.get(pattern_id)
        e.prev_params = jax.tree.map(lambda a: a, e.params)

    def clone(self) -> "ModelTable":
        """Independent copy (runs fine-tune entries in place; benchmarks
        reusing one pretrained table must not leak state across runs)."""
        import copy

        t = ModelTable(self.init_fn, self.n_slots)
        for s, e in self.slots.items():
            t.slots[s] = Entry(
                params=jax.tree.map(lambda a: a, e.params),
                prev_params=jax.tree.map(lambda a: a, e.prev_params) if e.prev_params is not None else None,
                opt_state=jax.tree.map(lambda a: a, e.opt_state) if e.opt_state is not None else None,
                step=e.step,
                n_updates=e.n_updates,
                last_acc=e.last_acc,
            )
        return t

    @property
    def n_models(self) -> int:
        return len(self.slots)

    def footprint_bytes(self, bytes_per_param: int = 4) -> int:
        total = 0
        for e in self.slots.values():
            n = sum(x.size for x in jax.tree.leaves(e.params))
            total += n * bytes_per_param * (2 if e.prev_params is not None else 1)
        return total
