"""DFA access-pattern classifier (Section IV-C, after UVMSmart).

Scans the 64KB basic-block migration stream of a window, measures the
linearity/randomness of block address transitions and re-referencing across
kernel boundaries, and classifies into 6 categories:

    0 Linear/Streaming   3 Linear Reuse/Regular
    1 Random             4 Random Reuse
    2 Mixed/Irregular    5 Mixed Reuse
"""
from __future__ import annotations

import numpy as np

LINEAR, RANDOM, MIXED, LINEAR_REUSE, RANDOM_REUSE, MIXED_REUSE = range(6)

NAMES = ["Linear/Streaming", "Random", "Mixed/Irregular", "Linear Reuse", "Random Reuse", "Mixed Reuse"]


class PatternClassifier:
    def __init__(self, lin_hi: float = 0.6, lin_lo: float = 0.3, reref_thr: float = 0.2):
        self.lin_hi, self.lin_lo, self.reref_thr = lin_hi, lin_lo, reref_thr
        self.seen_by_kernel: dict[int, set[int]] = {}

    def classify(self, blocks: np.ndarray, kernels: np.ndarray) -> int:
        blocks = np.asarray(blocks)
        if len(blocks) < 2:
            return LINEAR
        d = np.diff(blocks.astype(np.int64))
        # linearity = stride dominance: streaming (even interleaved multi-array
        # streaming) is covered by a handful of fixed strides; random gather
        # spreads over many distinct deltas.
        _, counts = np.unique(d, return_counts=True)
        top = np.sort(counts)[::-1][:3].sum()
        lin = float(top / len(d))

        # re-reference across kernel boundaries
        reref = 0
        total = 0
        for b, k in zip(blocks, kernels):
            k = int(k)
            prev = any(b in s for kk, s in self.seen_by_kernel.items() if kk < k)
            reref += prev
            total += 1
            self.seen_by_kernel.setdefault(k, set()).add(int(b))
        rr = reref / max(total, 1)

        if lin >= self.lin_hi:
            base = LINEAR
        elif lin <= self.lin_lo:
            base = RANDOM
        else:
            base = MIXED
        return base + 3 if rr >= self.reref_thr else base

    def reset(self):
        self.seen_by_kernel.clear()
