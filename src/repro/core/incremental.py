"""Training protocols for the page predictor (Sections III-C, IV-B, V-A/B).

  * online_single — ONE model, plain CE, train on group k-1 / predict group k
                    (the existing-learning-based-works protocol, Fig. 4).
  * online_multi  — pattern-aware model table, plain CE (Fig. 6 'multiple').
  * ours          — pattern-aware table + LUCIR distillation + (optionally)
                    the thrashing term (the full Section IV design).
  * offline       — train one model on a random 50% of samples (future info!)
                    then predict everything in temporal order: the paper's
                    upper bound (Figs. 4/11).

Every protocol measures top-1 accuracy on a group BEFORE the model trains on
it (strictly causal evaluation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.predictor_paper import PredictorConfig
from repro.core import losses
from repro.core.baselines_nn import make_model
from repro.core.features import DeltaVocab, FeatureSet, FeatureStream
from repro.core.model_table import Entry, ModelTable
from repro.core.pattern import PatternClassifier
from repro.distributed.compat import lane_shardings
from repro.optim import adamw
from repro.util import pow2_bucket as _pow2_rows
from repro.uvm.trace import Trace


def _shard_lane_trees(n_lanes: int, *trees):
    """Commit lane-stacked pytrees to a cross-device lanes sharding (lanes
    are independent models/groups, so GSPMD partitions the vmapped dispatch
    without communication).  No-op on a single device or when the lane
    count does not divide the devices; any device_put failure falls back to
    unsharded inputs."""
    lane_sh, _ = lane_shardings(n_lanes)
    if lane_sh is None:
        return trees
    try:
        return tuple(jax.tree.map(lambda x: jax.device_put(x, lane_sh), t) for t in trees)
    except Exception:
        return trees


@dataclasses.dataclass
class TrainConfig:
    group_size: int = 2048  # accesses per train/predict group (paper: 50M instr)
    epochs: int = 3
    batch_size: int = 256
    lr: float = 3e-3
    seed: int = 0
    table_slots: int = 8


def _batch_of(fs: FeatureSet, idx) -> dict:
    return {
        "page": jnp.asarray(fs.page[idx]),
        "delta": jnp.asarray(fs.delta[idx]),
        "pc": jnp.asarray(fs.pc[idx]),
        "tb": jnp.asarray(fs.tb[idx]),
    }


def _build_trainer_fns(pcfg: PredictorConfig, kind: str, lr: float):
    init_fn, forward = make_model(pcfg, kind)
    opt = adamw.adamw(lr, weight_decay=0.01)

    def train_step(params, opt_state, batch, labels, n_active, step, f_old, in_et, use_lucir, use_thrash):
        def lf(p):
            logits, f = forward(p, batch)
            return losses.total_loss(
                logits, f, labels,
                n_active=n_active,
                f_old=f_old if use_lucir else None,
                in_et=in_et if use_thrash else None,
                lam=pcfg.lucir_lambda, mu=pcfg.thrash_mu,
            )

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        updates, opt_state, _ = opt.update(grads, opt_state, params, step)
        params = adamw.apply_updates(params, updates)
        return params, opt_state, metrics

    def eval_step(params, batch, labels, n_active):
        logits, f = forward(params, batch)
        lm = jnp.where(jnp.arange(logits.shape[-1]) >= n_active, -1e30, logits)
        return (lm.argmax(-1) == labels), lm.argmax(-1), f

    # Whole-group drivers: the per-batch python loops used to pay one jit
    # dispatch + one blocking device->host sync PER BATCH (the dominant cost
    # of run_ours once compiles are shared). Scanning over a precomputed
    # batch-index matrix runs the IDENTICAL per-batch computation — same
    # shapes, same op sequence, host-identical index construction — in one
    # dispatch with one sync at the end.
    def eval_scan(params, feats, labels, pidx, n_active):
        def body(_, idx):
            batch = {k: v[idx] for k, v in feats.items()}
            c, p, _ = eval_step(params, batch, labels[idx], n_active)
            return None, (c, p)

        _, (cs, ps) = jax.lax.scan(body, None, pidx)
        return cs, ps

    def train_scan(params, opt_state, step0, feats, labels, et, prev_params, idx_mat, valid, n_active, use_lucir, use_thrash):
        # idx_mat is padded to a bucketed row count so one compiled scan
        # serves every group size; padded rows (valid=False) leave the carry
        # untouched — numerically a strict no-op.
        def body(carry, xs):
            idx, v = xs

            def do(c):
                params, opt_state, step = c
                batch = {k: x[idx] for k, x in feats.items()}
                if use_lucir:
                    f_old = forward(prev_params, batch)[1]
                else:
                    f_old = jnp.zeros((idx.shape[0], pcfg.d_model))
                if use_thrash:
                    bet = et[idx]
                else:
                    bet = jnp.zeros((idx.shape[0],), bool)
                p, o, _ = train_step(
                    params, opt_state, batch, labels[idx], n_active, step, f_old, bet,
                    use_lucir=use_lucir, use_thrash=use_thrash,
                )
                return (p, o, step + 1)

            return jax.lax.cond(v, do, lambda c: c, carry), None

        (params, opt_state, _), _ = jax.lax.scan(body, (params, opt_state, step0), (idx_mat, valid))
        return params, opt_state

    # Cross-benchmark lanes: the SAME per-group computation vmapped over a
    # leading lane axis (params, features, labels, schedules, n_active all
    # stacked). One dispatch serves every benchmark in the shape bucket.
    def eval_scan_many(params, feats, labels, pidx, n_active):
        return jax.vmap(eval_scan)(params, feats, labels, pidx, n_active)

    def train_scan_many(params, opt_state, step0, feats, labels, et, prev_params, idx_mat, valid, n_active, use_lucir, use_thrash):
        return jax.vmap(
            lambda p, o, s, f, l, e, pp, im, v, na: train_scan(p, o, s, f, l, e, pp, im, v, na, use_lucir, use_thrash)
        )(params, opt_state, step0, feats, labels, et, prev_params, idx_mat, valid, n_active)

    # n_active is a traced arg (class count grows); use_lucir/use_thrash static
    return (
        init_fn, forward, opt,
        jax.jit(train_step, static_argnames=("use_lucir", "use_thrash")),
        jax.jit(eval_step),
        jax.jit(eval_scan),
        jax.jit(train_scan, static_argnames=("use_lucir", "use_thrash")),
        jax.jit(eval_scan_many),
        jax.jit(train_scan_many, static_argnames=("use_lucir", "use_thrash")),
    )


# One jitted train/eval pair per (config, architecture, lr): Trainer used to
# rebuild (and so recompile) its jits per INSTANCE, which put ~6s of XLA
# compilation in front of every run_ours/run_protocol call — the dominant
# cost of the table6/fig11 sweeps. The closures are pure functions of the
# (hashable, frozen) PredictorConfig + kind + lr, so sharing them is exact.
_TRAINER_FN_CACHE: dict = {}


class Trainer:
    """Jitted train/eval for one predictor architecture."""

    def __init__(self, pcfg: PredictorConfig, tcfg: TrainConfig, kind: str = "transformer"):
        self.pcfg, self.tcfg, self.kind = pcfg, tcfg, kind
        cache_key = (pcfg, kind, tcfg.lr)
        if cache_key not in _TRAINER_FN_CACHE:
            _TRAINER_FN_CACHE[cache_key] = _build_trainer_fns(pcfg, kind, tcfg.lr)
        (self.init_fn, self.forward, self.opt, self._train_step, self._eval_step,
         self._eval_scan, self._train_scan,
         self._eval_scan_many, self._train_scan_many) = _TRAINER_FN_CACHE[cache_key]

    @staticmethod
    def _stage(fs: FeatureSet):
        """Stage the group's features on device, padded to a power-of-two
        sample count so every group shares one compiled scan (each distinct
        array length would otherwise re-trace + re-lower it — several
        seconds per variant even with a warm persistent cache). Batch
        indices only ever address the first len(fs) rows, so padding rows
        are unreachable and the gathered batches are unchanged."""
        n_pad = _pow2_rows(len(fs), 1024) - len(fs)

        def pad(a):
            a = np.asarray(a)
            if n_pad:
                a = np.concatenate([a, np.zeros((n_pad,) + a.shape[1:], a.dtype)])
            return jnp.asarray(a)

        return (
            {"page": pad(fs.page), "delta": pad(fs.delta), "pc": pad(fs.pc), "tb": pad(fs.tb)},
            pad(fs.label),
        )

    def new_params(self, seed: int = 0):
        return self.init_fn(jax.random.key(seed))

    def _eval_schedule(self, n: int) -> np.ndarray:
        """Padded batch-index matrix for one group (host-identical to the
        old per-batch loop's index construction)."""
        B = self.tcfg.batch_size
        rows = []
        for lo in range(0, n, B):
            idx = np.arange(lo, min(lo + B, n))
            pad = B - len(idx)
            rows.append(np.concatenate([idx, np.zeros(pad, int)]) if pad else idx)
        n_rows = len(rows)
        rows += [np.zeros(B, np.int64)] * (_pow2_rows(n_rows, 8) - n_rows)  # compile-bucket rows
        return np.stack(rows).astype(np.int32)

    def evaluate(self, params, fs: FeatureSet, n_active: int):
        """Top-1 correctness per sample + predicted class ids (all batches in
        one scanned dispatch; only the final padded batch carries junk rows,
        which are sliced off exactly as the per-batch loop did)."""
        n = len(fs)
        if n == 0:
            return np.zeros(0, bool), np.zeros(0, np.int32)
        pidx = self._eval_schedule(n)
        feats, labels = self._stage(fs)
        cs, ps = self._eval_scan(params, feats, labels, jnp.asarray(pidx), n_active)
        out = jax.device_get((cs, ps))  # one sync for the whole group
        correct = out[0].reshape(-1)[:n].astype(bool)
        pred = out[1].reshape(-1)[:n].astype(np.int32)
        return correct, pred

    # Below this lane count, batched dispatch is not worth a fresh vmapped
    # trace: the serial jits are already compiled (and shared with every
    # serial caller).  At or above it, lane counts pad to powers of two so
    # every sweep round hits one of a handful of compiled shapes.
    MIN_VMAP_LANES = 4

    @staticmethod
    def _pad_lanes(lanes: list, b_pad: int) -> list:
        """Pad a lane group by replicating its first lane (outputs of the
        padding lanes are discarded; replication keeps every array shape
        and dtype identical without inventing degenerate inputs)."""
        return lanes + [lanes[0]] * (b_pad - len(lanes))

    def evaluate_many(self, params_list: list, fs_list: list, n_active_list: list):
        """Batched :meth:`evaluate` across lanes (one model + feature group
        per lane — the cross-benchmark case).  Lanes are grouped by their
        padded (sample, schedule) shapes; each group runs as one vmapped
        scan over stacked params.  Returns one (correct, pred) per lane."""
        results: list = [None] * len(fs_list)
        groups: dict = {}
        for i, fs in enumerate(fs_list):
            n = len(fs)
            if n == 0:
                results[i] = (np.zeros(0, bool), np.zeros(0, np.int32))
                continue
            pidx = self._eval_schedule(n)  # host-cheap; shapes decide the bucket
            groups.setdefault((_pow2_rows(n, 1024), pidx.shape[0]), []).append((i, pidx))
        for lanes in groups.values():
            if len(lanes) < self.MIN_VMAP_LANES:
                for i, _ in lanes:
                    results[i] = self.evaluate(params_list[i], fs_list[i], n_active_list[i])
                continue
            idxs = [i for i, _ in lanes]
            # device staging only happens once the bucket is known to vmap
            staged = [(i, *self._stage(fs_list[i]), p) for i, p in lanes]
            staged = self._pad_lanes(staged, _pow2_rows(len(staged), self.MIN_VMAP_LANES))
            pidxs = [i for i, *_ in staged]
            params = jax.tree.map(lambda *xs: jnp.stack(xs), *[params_list[i] for i in pidxs])
            feats = {k: jnp.stack([f[k] for _, f, _, _ in staged]) for k in staged[0][1]}
            labels = jnp.stack([l for _, _, l, _ in staged])
            pidx = jnp.asarray(np.stack([p for _, _, _, p in staged]))
            na = jnp.asarray(np.array([n_active_list[i] for i in pidxs], np.int32))
            lanes = staged
            params, feats, labels, pidx, na = _shard_lane_trees(len(lanes), params, feats, labels, pidx, na)
            cs, ps = self._eval_scan_many(params, feats, labels, pidx, na)
            out = jax.device_get((cs, ps))  # one sync per shape bucket
            for j, i in enumerate(idxs):
                n = len(fs_list[i])
                results[i] = (
                    out[0][j].reshape(-1)[:n].astype(bool),
                    out[1][j].reshape(-1)[:n].astype(np.int32),
                )
        return results

    def old_features(self, prev_params, fs: FeatureSet, idx):
        if prev_params is None:
            return None
        _, _, f = self._eval_step(prev_params, _batch_of(fs, idx), jnp.asarray(fs.label[idx]), 1)
        return f

    def _train_schedule(self, n: int, rng):
        """Padded batch-index schedule for one group (per-epoch permutation,
        full batches, tiny-group resize fallback) — host-identical rng call
        sequence to the original per-batch loop."""
        tc = self.tcfg
        rows = []
        for _ in range(tc.epochs):
            order = rng.permutation(n)
            for lo in range(0, n - tc.batch_size + 1, tc.batch_size):
                rows.append(order[lo : lo + tc.batch_size])
            if n < tc.batch_size:  # tiny group: single padded batch
                rows.append(np.resize(order, tc.batch_size))
        n_steps = len(rows)
        n_pad = _pow2_rows(n_steps, 16) - n_steps  # one compiled scan per step-count bucket
        rows += [np.zeros(tc.batch_size, np.int64)] * n_pad
        valid = np.arange(len(rows)) < n_steps
        return np.stack(rows).astype(np.int32), valid, n_steps

    def _stage_et(self, in_et, n: int):
        if in_et is None:
            return jnp.zeros(1, bool)
        et_np = np.asarray(in_et, bool)  # pad to the features' sample bucket
        return jnp.asarray(np.concatenate([et_np, np.zeros(_pow2_rows(n, 1024) - n, bool)]))

    def train_group(self, entry: Entry, fs: FeatureSet, n_active: int, *, in_et=None, use_lucir=False, rng=None):
        """Fine-tune on one group (a few epochs) in ONE scanned dispatch."""
        tc = self.tcfg
        if entry.opt_state is None:
            entry.opt_state = self.opt.init(entry.params)
        n = len(fs)
        if n == 0:
            return entry
        rng = np.random.default_rng(tc.seed if rng is None else rng)
        use_l = use_lucir and entry.prev_params is not None
        idx_mat, valid, n_steps = self._train_schedule(n, rng)
        feats, labels = self._stage(fs)
        et = self._stage_et(in_et, n)
        prev = entry.prev_params if use_l else entry.params  # ignored unless use_lucir
        entry.params, entry.opt_state = self._train_scan(
            entry.params, entry.opt_state, jnp.asarray(entry.step, jnp.int32),
            feats, labels, et, prev, jnp.asarray(idx_mat), jnp.asarray(valid),
            jnp.asarray(n_active, jnp.int32),
            use_lucir=use_l, use_thrash=in_et is not None,
        )
        entry.step += n_steps
        entry.n_updates += 1
        return entry

    def train_group_many(self, entries: list, fs_list: list, n_active_list: list, *, in_et_list=None, use_lucir=False):
        """Batched :meth:`train_group` across lanes (one entry + group per
        lane).  Lanes are grouped by (sample bucket, step bucket, LUCIR
        eligibility, thrash-term presence) — the static jit flags and array
        shapes that must agree inside one vmapped dispatch.  Entries are
        updated in place, exactly as the serial path does."""
        tc = self.tcfg
        in_et_list = in_et_list if in_et_list is not None else [None] * len(entries)
        groups: dict = {}
        for i, (entry, fs) in enumerate(zip(entries, fs_list)):
            n = len(fs)
            if n == 0:
                continue
            if entry.opt_state is None:
                entry.opt_state = self.opt.init(entry.params)
            use_l = use_lucir and entry.prev_params is not None
            # the schedule is host-cheap and its shape decides the bucket;
            # device staging waits until the bucket is known to vmap
            idx_mat, valid, n_steps = self._train_schedule(n, np.random.default_rng(tc.seed))
            key = (_pow2_rows(n, 1024), idx_mat.shape[0], use_l, in_et_list[i] is not None)
            groups.setdefault(key, []).append((i, idx_mat, valid, n_steps))
        for (_, _, use_l, use_thrash), lanes in groups.items():
            if len(lanes) < self.MIN_VMAP_LANES:
                for i, *_ in lanes:
                    self.train_group(
                        entries[i], fs_list[i], n_active_list[i],
                        in_et=in_et_list[i], use_lucir=use_lucir,
                    )
                continue
            idxs = [i for i, *_ in lanes]
            lanes = [
                (i, *self._stage(fs_list[i]), self._stage_et(in_et_list[i], len(fs_list[i])), m, v, s)
                for i, m, v, s in lanes
            ]
            lanes = self._pad_lanes(lanes, _pow2_rows(len(lanes), self.MIN_VMAP_LANES))
            pidxs = [i for i, *_ in lanes]
            stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
            params = stack([entries[i].params for i in pidxs])
            opt_state = stack([entries[i].opt_state for i in pidxs])
            prev = stack([entries[i].prev_params if use_l else entries[i].params for i in pidxs])
            step0 = jnp.asarray(np.array([entries[i].step for i in pidxs], np.int32))
            feats = {k: jnp.stack([f[k] for _, f, *_ in lanes]) for k in lanes[0][1]}
            labels = jnp.stack([l for _, _, l, *_ in lanes])
            et = jnp.stack([e for _, _, _, e, *_ in lanes])
            idx_mat = jnp.asarray(np.stack([m for _, _, _, _, m, _, _ in lanes]))
            valid = jnp.asarray(np.stack([v for _, _, _, _, _, v, _ in lanes]))
            na = jnp.asarray(np.array([n_active_list[i] for i in pidxs], np.int32))
            params, opt_state, step0, feats, labels, et, prev, idx_mat, valid, na = _shard_lane_trees(
                len(lanes), params, opt_state, step0, feats, labels, et, prev, idx_mat, valid, na,
            )
            new_params, new_opt = self._train_scan_many(
                params, opt_state, step0, feats, labels, et, prev, idx_mat, valid, na,
                use_lucir=use_l, use_thrash=use_thrash,
            )
            # only the real lanes (padding replicas of lane 0 are discarded)
            for j, (i, *_, n_steps) in zip(range(len(idxs)), lanes):
                entries[i].params = jax.tree.map(lambda x: x[j], new_params)
                entries[i].opt_state = jax.tree.map(lambda x: x[j], new_opt)
                entries[i].step += n_steps
                entries[i].n_updates += 1
        return entries


@dataclasses.dataclass
class RunResult:
    top1: float
    per_group: list
    n_classes: int
    n_models: int
    n_samples: int
    predictions: np.ndarray  # predicted class id per sample
    t_index: np.ndarray
    correct: np.ndarray


def run_protocol(
    trace: Trace,
    pcfg: PredictorConfig,
    tcfg: TrainConfig,
    *,
    mode: str = "ours",
    kind: str = "transformer",
    in_et_flags: np.ndarray | None = None,  # per-access E∪T membership (thrash term)
    table: ModelTable | None = None,
) -> RunResult:
    assert mode in ("online_single", "online_multi", "ours", "offline")
    trainer = Trainer(pcfg, tcfg, kind)
    vocab = DeltaVocab(pcfg.delta_vocab)
    stream = FeatureStream(trace, vocab, pcfg.history, page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab)
    classifier = PatternClassifier()

    if mode == "offline":
        fs = stream.windows(0, len(trace))
        n_active = max(vocab.n_classes, 2)
        rng = np.random.default_rng(tcfg.seed)
        train_idx = rng.permutation(len(fs))[: len(fs) // 2]
        entry = Entry(params=trainer.new_params(tcfg.seed))
        sub = fs.slice(0, len(fs))  # full; train on the random half
        half = FeatureSet(*(getattr(fs, f.name)[train_idx] for f in dataclasses.fields(fs)))
        for _ in range(3):  # extra passes — it has future knowledge anyway
            entry = trainer.train_group(entry, half, n_active)
        correct, pred = trainer.evaluate(entry.params, fs, n_active)
        return RunResult(float(correct.mean()), [float(correct.mean())], vocab.n_classes, 1, len(fs), pred, fs.t_index, correct)

    if table is None:
        table = ModelTable(lambda s: trainer.new_params(s), n_slots=tcfg.table_slots)
    multi = mode in ("online_multi", "ours")
    use_lucir = mode == "ours"

    n = len(trace)
    G = tcfg.group_size
    per_group = []
    all_correct = np.zeros(0, bool)
    all_pred = np.zeros(0, np.int32)
    all_t = np.zeros(0, np.int32)
    for g0 in range(0, n, G):
        g1 = min(g0 + G, n)
        fs = stream.windows(g0, g1)
        if len(fs) == 0:
            continue
        n_active = max(vocab.n_classes, 2)
        pat = classifier.classify(trace.block[g0:g1], trace.kernel[g0:g1]) if multi else 0
        entry = table.get(pat)
        correct, pred = trainer.evaluate(entry.params, fs, n_active)  # predict BEFORE training
        per_group.append(float(correct.mean()))
        all_correct = np.concatenate([all_correct, correct])
        all_pred = np.concatenate([all_pred, pred])
        all_t = np.concatenate([all_t, fs.t_index])
        if use_lucir:
            table.snapshot_prev(pat)
            entry = table.get(pat)
        in_et = in_et_flags[fs.t_index] if in_et_flags is not None and mode == "ours" else None
        entry = trainer.train_group(entry, fs, n_active, in_et=in_et, use_lucir=use_lucir)
        table.put(pat, entry)

    top1 = float(all_correct.mean()) if len(all_correct) else 0.0
    return RunResult(top1, per_group, vocab.n_classes, table.n_models, len(all_correct), all_pred, all_t, all_correct)
